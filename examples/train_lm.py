"""Fault-tolerant LM training end-to-end: deterministic data pipeline,
AdamW, checkpoint/restart, a simulated mid-run failure with retry.

    PYTHONPATH=src python examples/train_lm.py
(drop --smoke inside for the full 135M smollm config on real hardware)
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--smoke", "--steps", "80", "--ckpt-every",
                "25", "--fail-at", "11", "--ckpt-dir",
                "checkpoints/example_train"]
    main()
