"""End-to-end CEC inference serving (the paper's deployment scenario):
three LM versions on an edge fleet, OMAD steering admission + routing
online from measured feedback, real decode steps on CPU.

    PYTHONPATH=src python examples/cec_serving.py
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--intervals", "8", "--requests", "18",
                "--nodes", "12", "--fail-node-at", "5"]
    main()
