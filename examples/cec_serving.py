"""End-to-end CEC inference serving (the paper's deployment scenario):
three LM versions on an edge fleet, OMAD steering admission + routing
online from measured feedback, real decode steps on CPU.

    PYTHONPATH=src python examples/cec_serving.py

(REPRO_EXAMPLES_SMOKE=1 shrinks the run for the CI examples-smoke job.)
"""
import os
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    if os.environ.get("REPRO_EXAMPLES_SMOKE"):
        args = ["--intervals", "4", "--requests", "8", "--nodes", "10",
                "--fail-node-at", "2"]
    else:
        args = ["--intervals", "8", "--requests", "18", "--nodes", "12",
                "--fail-node-at", "5"]
    sys.argv = [sys.argv[0], *args]
    main()
