"""Multi-tenant serving: K tenants, one vmapped control step per interval.

A traffic trace shapes per-tenant demand, a RouterFleet advances all K
control planes in one donated jitted step, and the serving plane reads
the published FleetView (DESIGN.md §15).

    PYTHONPATH=src python examples/multi_tenant_serving.py

(REPRO_EXAMPLES_SMOKE=1 shrinks the run for the CI examples-smoke job.)
"""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import initial_state, named_scenarios
from repro.serve import RouterFleet, poisson_trace

K = 3 if os.environ.get("REPRO_EXAMPLES_SMOKE") else 8   # tenants
T = 6 if os.environ.get("REPRO_EXAMPLES_SMOKE") else 30  # control intervals

# K tenants: independent edge fleets with hidden (measured-only) utilities
sc = named_scenarios(horizon=T, n=10, p=0.4)["steady"]
tenants = [initial_state(sc, seed=s) for s in range(K)]
measured = [lambda lams, b=t.bank: np.asarray(jax.vmap(b.total)(jnp.asarray(lams)))
            for t in tenants]

fleet = RouterFleet([t.graph() for t in tenants], [60.0] * K)
demand = poisson_trace(T, K, seed=0).demand(60.0)   # [T, K] arrivals

for t in range(T):
    fleet.set_demand(demand[t])          # traced-leaf update, no retrace
    rec = fleet.control_step(measured)   # one donated vmapped step for all K
print("per-tenant admission splits:\n", np.round(fleet.view.admission_split(), 2))
print("mean net utility per tenant:", np.round(rec["utility"], 2))
