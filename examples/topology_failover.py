"""Online adaptation to topology churn (paper Fig. 11 behaviour), driven
by the scenario engine: declare the churn as an event timeline and let
``run_scenario`` advance OMAD across it with warm-started iterates — the
exploration-mix φ restart now lives in the library
(``core.routing.warm_start_phi``), not in example code.

    PYTHONPATH=src python examples/topology_failover.py

(REPRO_EXAMPLES_SMOKE=1 shrinks the run for the CI examples-smoke job.)
"""
import os

from repro.core import (Rewire, Scenario, run_scenario, scenario_metrics,
                        serving_defaults)

smoke = bool(os.environ.get("REPRO_EXAMPLES_SMOKE"))
horizon = 40 if smoke else 120
scenario = Scenario(
    "failover", horizon=horizon,
    # device mobility at mid-run: 30% of the links move to new endpoints
    events=(Rewire(at=horizon // 2, frac=0.3, seed=9),),
    topo_kwargs={"n": 25, "p": 0.2}, mean_capacity=10.0, lam_total=60.0,
)

# one vmapped program per segment; the solver core's SolverState is
# threaded (warm-started) across the event boundary
seeds = (0, 1) if smoke else (0, 1, 2, 3)
res = run_scenario(scenario, seeds=seeds, config=serving_defaults())
m = scenario_metrics(res, recovery_frac=0.95)
(ev,) = m["events"]

print(f"converged before churn: U = {ev.u_pre:.3f} "
      f"({len(seeds)}-seed mean)")
print(f"after rewire at t={ev.at}: U drops to {ev.u_drop:.3f}, "
      f"re-converges to {ev.u_final:.3f}")
print(f"recovery: 95% of pre-event utility in ~{ev.recovery_iters:.0f} "
      f"iters on {ev.recovered_frac:.0%} of seeds; "
      f"dynamic regret {m['dynamic_regret']:.1f}")
