"""Online adaptation to node churn (paper Fig. 11 behaviour, programmatic):
the single-loop optimizer re-converges after the network topology changes
mid-run, without restarting from scratch.

    PYTHONPATH=src python examples/topology_failover.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import build_random_cec, make_bank, solve_jowr
from repro.topo import connected_er

bank = make_bank("log", 3, seed=0, lam_total=60.0)
g1 = build_random_cec(connected_er(25, 0.2, seed=1), 3, 10.0, seed=0)
r1 = solve_jowr(g1, bank, 60.0, method="single", eta_outer=0.05,
                eta_inner=3.0, outer_iters=120)
print(f"converged on topology A: U = {float(r1.utility_traj[-1]):.3f}")

# topology change: links churn (device mobility); warm-start with an
# exploration mix so multiplicatively-zeroed edges can be rediscovered
g2 = build_random_cec(connected_er(25, 0.2, seed=9), 3, 10.0, seed=0)
uniform = g2.uniform_phi()
mixed = 0.9 * r1.phi * g2.out_mask + 0.1 * uniform
s = mixed.sum(-1, keepdims=True)
phi0 = jnp.where(s > 0, mixed / jnp.where(s > 0, s, 1.0), uniform)

r2 = solve_jowr(g2, bank, 60.0, method="single", eta_outer=0.05,
                eta_inner=3.0, outer_iters=120, lam0=r1.lam, phi0=phi0)
traj = np.asarray(r2.utility_traj)
print(f"after change: U drops to {traj[0]:.3f}, "
      f"re-converges to {traj[-1]:.3f} in ~{np.argmax(traj > traj[-1] - 0.05)} iters")
