"""Fit-then-switch: learned utility gradients on a live serving sim.

The serving control plane normally pays 2W+1 measured traffic admissions
per control interval (the two-point perturbation sweep).  With
``grad_policy="auto"`` the router fits a parametric utility surrogate to
what it measures anyway, and — once the fitter's held-out error clears
its bar — migrates live to ``grad_mode="learned"``: one admission per
interval, gradient taken analytically through the implicit routing layer
(DESIGN.md §16).  This example drives real continuous-batching decode
traffic (`ServingSim`) and prints the interval-by-interval migration.

    PYTHONPATH=src python examples/learned_utilities.py

(REPRO_EXAMPLES_SMOKE=1 shrinks the run for the CI examples-smoke job.)
"""
import dataclasses
import os

import jax
import numpy as np

from repro.configs import get_config
from repro.core import Scenario
from repro.models import model as M
from repro.serve import ServingSim

SMOKE = bool(os.environ.get("REPRO_EXAMPLES_SMOKE"))
T = 8 if SMOKE else 30

cfg = dataclasses.replace(get_config("smollm-135m", smoke=True),
                          dtype="float32")
params = M.init(cfg, jax.random.PRNGKey(0))
sc = Scenario("learned-serving", horizon=T,
              topo_kwargs={"n": 10 if SMOKE else 12, "p": 0.35},
              n_sessions=3, mean_capacity=20.0, lam_total=12.0)
sim = ServingSim(sc, cfg=cfg, params=params, seed=0,
                 requests_per_interval=4 if SMOKE else 8,
                 engine_steps_per_interval=6, prompt_len=4,
                 max_new_tokens=3, max_batch=2, max_len=24,
                 grad_policy="auto", util_family="log")
# earn the switch quickly on a short horizon: the defaults are tuned for
# long-running fleets, not an 8-interval demo
sim.router.fitter.min_samples = 12
sim.router.fitter.refit_every = 4
sim.router.fitter.fit_steps = 600

report = sim.run()

W = sim.router.graph.n_sessions
print(f"\n{T} control intervals, W={W} sessions "
      f"(sampled interval = {2 * W + 1} measured admissions)")
print(f"{'t':>3s} {'mode':>8s} {'admissions':>10s} {'net utility':>12s}")
total_calls = 0
for t, h in enumerate(h for h in sim.router.history if "mode" in h):
    total_calls += h["oracle_calls"]
    print(f"{t:3d} {h['mode']:>8s} {h['oracle_calls']:10d} "
          f"{h['utility']:12.3f}")
sampled_cost = T * (2 * W + 1)
print(f"\nmeasured admissions: {total_calls} "
      f"(all-sampled would be {sampled_cost}; "
      f"{sampled_cost / total_calls:.1f}x reduction)")
print(f"fitter: holdout_error={sim.router.fitter.holdout_error:.4f} "
      f"fits={sim.router.fitter.n_fits} drift={sim.router.fitter.drift:.3f}")
print(f"tokens served: {report.tokens_served}")
assert np.isfinite(report.utility).all()
