"""Quickstart: solve the paper's JOWR problem in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py

(REPRO_EXAMPLES_SMOKE=1 shrinks the run for the CI examples-smoke job.)
"""
import os

import numpy as np

from repro.core import Problem, SolverConfig, build_random_cec, make_bank, run
from repro.topo import connected_er

# 1. a CEC fleet: 25 edge devices, 3 DNN model versions (paper §IV setup)
adj = connected_er(n=25, p=0.2, seed=1)
graph = build_random_cec(adj, n_versions=3, mean_link_capacity=10.0, seed=0)

# 2. unknown utilities (the solver only ever observes scalar feedback)
bank = make_bank("log", n_sessions=3, seed=0, lam_total=60.0)

# 3. the problem (what is optimized) and the solver config (how):
#    single-loop online OMAD — `repro.configs.cec_paper.solver_config()`
#    and `solver.paper_defaults()/serving_defaults()` are named presets
problem = Problem.create(graph, bank, lam_total=60.0, cost="exp")
config = SolverConfig(method="single", eta_outer=0.05, eta_inner=3.0)

iters = 60 if os.environ.get("REPRO_EXAMPLES_SMOKE") else 200
res = run(problem, config, iters=iters)

print("allocation Λ* =", np.round(np.asarray(res.lam), 2))
print("network utility trajectory:",
      [round(float(u), 2) for u in res.utility_traj[:: iters // 5]])
print("final utility U =", round(float(res.utility_traj[-1]), 3))
