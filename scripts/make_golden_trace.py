"""Regenerate the committed golden-trace fixture (tests/golden/).

The fixture pins the Fig. 7 topology's ``gs_oma`` utility trajectory —
the fused control step end to end: perturbation basis, oracle
observations, mirror ascent, exact box-simplex projection, committed
observation.  ``tests/test_golden_trace.py`` asserts every future run
matches within tolerance, so numerical drift in the control plane is
caught by tier-1 instead of by benchmark eyeballing.

Regenerate ONLY when the control-step semantics change *intentionally*
(and say so in the commit message):

    PYTHONPATH=src python scripts/make_golden_trace.py
"""
from __future__ import annotations

import os
import pathlib

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np

GOLDEN = pathlib.Path(__file__).resolve().parents[1] / "tests" / "golden"

# The pinned configuration — mirrored by tests/test_golden_trace.py.
CONFIG = dict(n=25, p=0.2, adj_seed=1, instance_seed=0, n_sessions=3,
              mean_capacity=10.0, bank_kind="log", bank_seed=0,
              lam_total=60.0, method="nested", outer_iters=20,
              inner_iters=10, delta=0.5, eta_outer=0.05, eta_inner=3.0)


def solve(cfg=CONFIG):
    from repro.core import build_random_cec, make_bank, solve_jowr
    from repro.topo import connected_er

    graph = build_random_cec(
        connected_er(cfg["n"], cfg["p"], seed=cfg["adj_seed"]),
        cfg["n_sessions"], cfg["mean_capacity"], seed=cfg["instance_seed"])
    bank = make_bank(cfg["bank_kind"], cfg["n_sessions"],
                     seed=cfg["bank_seed"], lam_total=cfg["lam_total"])
    return solve_jowr(graph, bank, cfg["lam_total"], method=cfg["method"],
                      outer_iters=cfg["outer_iters"],
                      inner_iters=cfg["inner_iters"], delta=cfg["delta"],
                      eta_outer=cfg["eta_outer"], eta_inner=cfg["eta_inner"])


def main() -> pathlib.Path:
    res = solve()
    GOLDEN.mkdir(parents=True, exist_ok=True)
    path = GOLDEN / "fig7_gs_oma_traj.npz"
    np.savez(path,
             utility_traj=np.asarray(res.utility_traj, np.float64),
             lam=np.asarray(res.lam, np.float64),
             **{f"cfg_{k}": v for k, v in CONFIG.items()
                if not isinstance(v, str)},
             cfg_method=CONFIG["method"], cfg_bank_kind=CONFIG["bank_kind"])
    print(f"wrote {path}: final U = {float(res.utility_traj[-1]):.6f}, "
          f"lam = {np.asarray(res.lam)}")
    return path


if __name__ == "__main__":
    main()
