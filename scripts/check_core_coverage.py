"""Coverage floor gate for the solver-facing packages (CI bench-smoke job).

Reads a ``coverage.json`` produced by ``pytest --cov=repro
--cov-report=json``, prints a per-file summary for each gated package,
and fails when any package's aggregate line coverage drops below its
recorded floor.

Gated packages:

* ``src/repro/core/`` — the control-plane core; floor recorded at PR 4
  (the sparse-engine PR that introduced this gate).
* ``src/repro/parallel/`` — the sharding/collectives layer the fleet
  engine (``run_batch_sharded``, DESIGN.md §14) rides on; floor recorded
  at PR 6 (~32% measured in-process, gated at 25%).  Far lower than
  core's on purpose and honestly so: the multi-device tier (ring
  all-reduce bodies, MoE all-to-all, the LM mesh-rule functions) runs in
  subprocesses under ``--xla_force_host_platform_device_count=8``, which
  pytest-cov cannot see — the in-process 1-device parity + property
  tests (fleet specs, pad/unpad, shard_map compat, int8 collectives,
  annotate) are what this gate actually guards.
* ``src/repro/obs/`` — the observability subsystem (ISSUE 10, DESIGN.md
  §18): telemetry rings, paper-invariant monitors, trace/JSONL export.
  Pure host-visible code with a dedicated suite (tests/test_obs.py);
  floor 85%.

Floors are *minus a small flake margin* under what the suite measures.
Policy: ratchet them upward as coverage grows; never lower one to make a
PR pass — delete the untested code or test it.  Override for local
experiments only: ``REPRO_CORE_COV_MIN=<percent>`` /
``REPRO_PARALLEL_COV_MIN=<percent>``.

Usage:  python scripts/check_core_coverage.py [coverage.json]
"""
from __future__ import annotations

import json
import os
import pathlib
import sys

# (path marker, recorded floor %, local-override env var); keep in sync
# with reality by ratcheting, not lowering.
GATES = (
    ("repro/core/", 80.0, "REPRO_CORE_COV_MIN"),
    ("repro/parallel/", 25.0, "REPRO_PARALLEL_COV_MIN"),
    # the observability subsystem (ISSUE 10, DESIGN.md §18): rings,
    # monitors, exporters are all host-visible pure code, so the tier-1
    # suite should cover nearly all of it — gated at 85%.
    ("repro/obs/", 85.0, "REPRO_OBS_COV_MIN"),
)

# per-file floors for the differentiable-core modules (PR 8): the implicit
# VJP and the hypergradient loop are correctness-critical math whose
# failure mode is a silently wrong gradient, so they carry their own bar
# on top of the package aggregate.  The control megakernel (PR 9,
# DESIGN.md §17) joins them: its kernel body runs as Python under
# interpret mode on the CI backend, so pytest-cov sees every executed
# line — measured 100% under the tier-1 suite, gated at 90% flake margin.
FILE_GATES = (
    ("repro/core/implicit.py", 85.0),
    ("repro/core/hypergrad.py", 85.0),
    ("repro/kernels/control_megakernel.py", 90.0),
)


def _gate(data: dict, marker: str, floor: float) -> int:
    rows = []
    covered = statements = 0
    for fname, info in sorted(data["files"].items()):
        if marker not in fname.replace("\\", "/"):
            continue
        s = info["summary"]
        covered += s["covered_lines"]
        statements += s["num_statements"]
        rows.append((fname, s["num_statements"], s["covered_lines"],
                     s["percent_covered"]))
    if not statements:
        print(f"error: no files matching '{marker}'", file=sys.stderr)
        return 2

    print(f"{'file':58s} {'stmts':>6s} {'cover':>6s} {'pct':>7s}")
    for fname, n, c, pct in rows:
        print(f"{fname:58s} {n:6d} {c:6d} {pct:6.1f}%")
    total = 100.0 * covered / statements
    print(f"{'TOTAL src/' + marker:58s} {statements:6d} {covered:6d} "
          f"{total:6.1f}%  (floor {floor:.1f}%)")

    if total < floor:
        print(f"FAIL: {marker} coverage {total:.1f}% is below the recorded "
              f"floor {floor:.1f}% — add tests (or, for a deliberate "
              "removal of tested code, ratchet consciously in "
              "scripts/check_core_coverage.py with a commit-message note)",
              file=sys.stderr)
        return 1
    return 0


def _file_gate(data: dict, marker: str, floor: float) -> int:
    for fname, info in data["files"].items():
        if marker in fname.replace("\\", "/"):
            pct = info["summary"]["percent_covered"]
            print(f"{fname:58s} {pct:6.1f}%  (file floor {floor:.1f}%)")
            if pct < floor:
                print(f"FAIL: {marker} coverage {pct:.1f}% is below its "
                      f"per-file floor {floor:.1f}%", file=sys.stderr)
                return 1
            return 0
    print(f"error: no file matching '{marker}' in coverage data",
          file=sys.stderr)
    return 2


def main(path: str = "coverage.json") -> int:
    data = json.loads(pathlib.Path(path).read_text())
    rc = 0
    for marker, default_floor, env in GATES:
        floor = float(os.environ.get(env, default_floor))
        rc = max(rc, _gate(data, marker, floor))
        print()
    for marker, floor in FILE_GATES:
        rc = max(rc, _file_gate(data, marker, floor))
    return rc


if __name__ == "__main__":
    raise SystemExit(main(*sys.argv[1:]))
