"""Coverage floor gate for the control-plane core (CI bench-smoke job).

Reads a ``coverage.json`` produced by ``pytest --cov=repro
--cov-report=json``, prints a per-file summary for ``src/repro/core/``,
and fails when the aggregate line coverage of that package drops below
the recorded floor.

The floor is the level recorded at PR 4 (the sparse-engine PR that
introduced this gate) minus a small flake margin.  Policy: ratchet it
*upward* as coverage grows; never lower it to make a PR pass — delete the
untested code or test it.  Override for local experiments only:
``REPRO_CORE_COV_MIN=<percent>``.

Usage:  python scripts/check_core_coverage.py [coverage.json]
"""
from __future__ import annotations

import json
import os
import pathlib
import sys

# Recorded at PR 4 (see module docstring); keep in sync with reality by
# ratcheting, not lowering.
CORE_FLOOR_PERCENT = 80.0

CORE_MARKER = "repro/core/"


def main(path: str = "coverage.json") -> int:
    floor = float(os.environ.get("REPRO_CORE_COV_MIN", CORE_FLOOR_PERCENT))
    data = json.loads(pathlib.Path(path).read_text())
    rows = []
    covered = statements = 0
    for fname, info in sorted(data["files"].items()):
        if CORE_MARKER not in fname.replace("\\", "/"):
            continue
        s = info["summary"]
        covered += s["covered_lines"]
        statements += s["num_statements"]
        rows.append((fname, s["num_statements"], s["covered_lines"],
                     s["percent_covered"]))
    if not statements:
        print(f"error: no files matching '{CORE_MARKER}' in {path}",
              file=sys.stderr)
        return 2

    print(f"{'file':58s} {'stmts':>6s} {'cover':>6s} {'pct':>7s}")
    for fname, n, c, pct in rows:
        print(f"{fname:58s} {n:6d} {c:6d} {pct:6.1f}%")
    total = 100.0 * covered / statements
    print(f"{'TOTAL src/repro/core/':58s} {statements:6d} {covered:6d} "
          f"{total:6.1f}%  (floor {floor:.1f}%)")

    if total < floor:
        print(f"FAIL: core coverage {total:.1f}% is below the recorded "
              f"floor {floor:.1f}% — add tests (or, for a deliberate "
              "removal of tested code, ratchet consciously in "
              "scripts/check_core_coverage.py with a commit-message note)",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main(*sys.argv[1:]))
