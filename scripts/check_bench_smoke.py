"""Structural diff-gate for the committed BENCH_smoke.json (CI bench-smoke).

The root ``BENCH_smoke.json`` is a *convenience snapshot* of the smoke
summary; the CI artifact uploaded from the bench-smoke job is the
canonical record for any given commit (README "Benchmarks").  The
snapshot still must not rot: a PR that adds or removes a benchmark
module without regenerating it would leave the committed file lying
about what the suite runs.

This gate compares the freshly-written summary against the version
committed at HEAD **structurally** — module set, per-module status, and
the failed list.  Timings (``seconds``, ``med_latency_us``), versions
and rows are run-dependent by design and ignored.  On mismatch it exits
non-zero with the per-module delta and the one-line fix:

    PYTHONPATH=src python -m benchmarks.run --smoke   # then commit
    git add BENCH_smoke.json                          # BENCH_smoke.json

Usage:  python scripts/check_bench_smoke.py [fresh.json]
"""
from __future__ import annotations

import json
import pathlib
import subprocess
import sys


def _structure(summary: dict) -> dict:
    mods = summary.get("modules", {})
    return {
        "modules": {name: info.get("status") for name, info in mods.items()},
        "failed": sorted(summary.get("failed", [])),
    }


def _committed(path: str = "BENCH_smoke.json") -> dict | None:
    try:
        out = subprocess.run(["git", "show", f"HEAD:{path}"],
                             capture_output=True, text=True, check=True)
        return json.loads(out.stdout)
    except (subprocess.CalledProcessError, json.JSONDecodeError):
        return None


def main(fresh_path: str = "BENCH_smoke.json") -> int:
    fresh = json.loads(pathlib.Path(fresh_path).read_text())
    committed = _committed()
    if committed is None:
        print("FAIL: no BENCH_smoke.json committed at HEAD — run the smoke "
              "suite and commit its summary:\n"
              "  PYTHONPATH=src python -m benchmarks.run --smoke\n"
              "  git add BENCH_smoke.json", file=sys.stderr)
        return 1

    want, got = _structure(fresh), _structure(committed)
    if want == got:
        print(f"BENCH_smoke.json structure is current "
              f"({len(want['modules'])} modules, "
              f"{len(want['failed'])} failed)")
        return 0

    fresh_mods, old_mods = want["modules"], got["modules"]
    for name in sorted(set(fresh_mods) | set(old_mods)):
        a, b = old_mods.get(name), fresh_mods.get(name)
        if a != b:
            print(f"  {name}: committed={a!r} fresh={b!r}", file=sys.stderr)
    if want["failed"] != got["failed"]:
        print(f"  failed: committed={got['failed']} fresh={want['failed']}",
              file=sys.stderr)
    print("FAIL: committed BENCH_smoke.json is structurally stale against "
          "this run — regenerate and commit it "
          "(PYTHONPATH=src python -m benchmarks.run --smoke; "
          "git add BENCH_smoke.json).  The uploaded CI artifact stays the "
          "canonical per-commit record.", file=sys.stderr)
    return 1


if __name__ == "__main__":
    raise SystemExit(main(*sys.argv[1:]))
