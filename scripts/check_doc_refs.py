"""Docs-as-CI: every ``DESIGN.md §N`` reference must resolve (ISSUE 7).

The codebase cites DESIGN.md sections from docstrings the way papers
cite equations — ``(DESIGN.md §15.2)`` — and the design doc marks each
PR's sections with a ``(PR n)`` tag.  Both conventions rot silently:
a renumbered section orphans every citation, and a merged PR that keeps
claiming "(this PR)" misdates the doc.  This checker makes both a CI
failure (wired next to the coverage gate in ci.yml):

1. every ``DESIGN.md §N[.M]`` reference in ``--src`` Python files must
   match a ``## §N`` / ``### §N.M`` heading in ``--design``;
2. at most the *newest* top-level section may carry ``(this PR)`` —
   anything older must have been renamed to its ``(PR n)`` tag when the
   next PR landed.

Exits non-zero listing every violation (``tests/test_doc_refs.py``
includes the planted-broken-reference negative test).
"""
from __future__ import annotations

import argparse
import pathlib
import re
import sys

REF = re.compile(r"DESIGN\.md\s+§(\d+(?:\.\d+)?)")
HEADING = re.compile(r"^#{2,3}\s+§(\d+(?:\.\d+)?)\b(.*)$")


def design_sections(design: pathlib.Path) -> tuple[set[str], list[str]]:
    """(section numbers, '(this PR)' violations) from the design doc."""
    sections: set[str] = set()
    this_pr: list[tuple[str, int]] = []
    for lineno, line in enumerate(design.read_text().splitlines(), 1):
        m = HEADING.match(line)
        if not m:
            continue
        sections.add(m.group(1))
        if "(this PR)" in m.group(2) and "." not in m.group(1):
            this_pr.append((m.group(1), lineno))
    top = [int(s) for s in sections if "." not in s]
    newest = max(top) if top else None
    errors = [
        f"{design}:{lineno}: §{num} claims '(this PR)' but §{newest} is "
        f"newer — rename to its '(PR n)' tag"
        for num, lineno in this_pr if int(num) != newest]
    return sections, errors


def check_refs(design: pathlib.Path,
               src_dirs: list[pathlib.Path]) -> list[str]:
    sections, errors = design_sections(design)
    for src in src_dirs:
        for path in sorted(src.rglob("*.py")):
            for lineno, line in enumerate(
                    path.read_text().splitlines(), 1):
                for m in REF.finditer(line):
                    if m.group(1) not in sections:
                        errors.append(
                            f"{path}:{lineno}: reference to DESIGN.md "
                            f"§{m.group(1)} — no such heading in {design}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--design", type=pathlib.Path,
                    default=pathlib.Path("DESIGN.md"))
    ap.add_argument("--src", type=pathlib.Path, action="append",
                    help="source roots to scan (default: src)")
    args = ap.parse_args(argv)
    src_dirs = args.src or [pathlib.Path("src")]
    errors = check_refs(args.design, src_dirs)
    for e in errors:
        print(e, file=sys.stderr)
    if errors:
        print(f"{len(errors)} broken doc reference(s)", file=sys.stderr)
        return 1
    print("doc refs OK", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
