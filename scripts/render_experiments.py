"""Render EXPERIMENTS.md §Dry-run/§Roofline tables from the JSON artifacts.

The narrative sections (§Repro, §Perf iteration log) are maintained by hand
in EXPERIMENTS.md between the AUTOGEN markers this script rewrites.

    PYTHONPATH=src python scripts/render_experiments.py
"""
from __future__ import annotations

import json
import pathlib
import re
import sys

sys.path.insert(0, "src")

from repro.roofline.analysis import render_table  # noqa: E402

ROOT = pathlib.Path(__file__).resolve().parent.parent


def dryrun_table() -> str:
    rows = []
    for f in sorted((ROOT / "experiments/dryrun").glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"skipped ({r['reason']}) | — | — | — |")
            continue
        coll = {k: int(v["count"]) for k, v in r["collectives"].items()
                if k != "total"}
        # donated outputs alias inputs: resident = args + (out − aliased)
        mem = (r["arg_bytes_per_device"] + r["output_bytes_per_device"]
               - r.get("alias_bytes_per_device", 0)) / 2**30
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"ok ({r['compile_s']}s) | {mem:.2f} | "
            f"{r['temp_bytes_per_device']/2**30:.2f} | {coll} |")
    head = ("| arch | shape | mesh | compile | args+out GiB/dev | "
            "temp GiB/dev | collectives |\n|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def perf_table() -> str:
    f = ROOT / "experiments/paper/perf_iterations.json"
    if not f.exists():
        return "(perf run pending)"
    rows = ["| cell | variant | compute ms | memory ms | collective ms | "
            "bottleneck | roofline frac | verdict |",
            "|---|---|---|---|---|---|---|---|"]
    for r in json.loads(f.read_text()):
        rows.append(
            f"| {r['arch']} × {r['shape']} | {r['variant']} | "
            f"{r['compute_s']*1e3:.2f} | {r['memory_s']*1e3:.2f} | "
            f"{r['collective_s']*1e3:.2f} | {r['bottleneck']} | "
            f"{r['roofline_fraction']:.3f} | {r.get('verdict', '—')} |")
    return "\n".join(rows)


def replace_block(text: str, tag: str, body: str) -> str:
    pat = re.compile(rf"<!-- AUTOGEN:{tag} -->.*?<!-- /AUTOGEN:{tag} -->",
                     re.S)
    repl = f"<!-- AUTOGEN:{tag} -->\n{body}\n<!-- /AUTOGEN:{tag} -->"
    assert pat.search(text), f"missing AUTOGEN block {tag}"
    return pat.sub(repl, text)


def main() -> None:
    md = (ROOT / "EXPERIMENTS.md").read_text()
    md = replace_block(md, "dryrun", dryrun_table())
    md = replace_block(md, "roofline_baseline",
                       render_table(str(ROOT / "experiments/roofline"),
                                    adjusted=False))
    md = replace_block(md, "roofline_adjusted",
                       render_table(str(ROOT / "experiments/roofline"),
                                    adjusted=True))
    md = replace_block(md, "perf", perf_table())
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
