"""Paper Table II + Figs. 12–15: OMD-RT across the four named topologies.

Each topology row is an ensemble of B capacity/deployment draws on the
fixed adjacency, solved on the batched path (one vmapped OMD-RT program);
OPT is Frank–Wolfe per instance and the paper's "iterations to within 1%
of OPT" statistic is averaged over the ensemble.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CECGraphBatch, build_random_cec, frank_wolfe_routing,
                        get_cost, solve_routing_batch)
from repro.topo import make_topology

from . import common
from .common import dump, emit, timeit

LAM = jnp.array([15.0, 15.0, 15.0])


def main() -> list[dict]:
    cost = get_cost("exp")
    B = common.scaled(4, 2)
    iters = common.scaled(150, 10)
    fw_iters = common.scaled(200, 25)
    rows = []
    for name in common.scaled(("abilene", "balanced_tree", "fog", "geant"),
                              ("abilene", "fog")):
        adj, cbar = make_topology(name)
        graphs = [build_random_cec(adj, 3, cbar, seed=s) for s in range(B)]
        batch = CECGraphBatch.from_graphs(graphs)
        phi0 = batch.uniform_phi()
        omd = jax.jit(lambda p, b=batch: solve_routing_batch(
            b, cost, LAM, p, 3.0, iters))
        (_, traj), secs = timeit(omd, phi0)
        traj = np.asarray(traj)                           # [B, iters]
        d_opt = np.array([frank_wolfe_routing(g, cost, LAM,
                                              n_iters=fw_iters)[1]
                          for g in graphs])
        # per-instance iterations to within 1% of OPT; -1 = never reached,
        # excluded from the ensemble mean so the statistic stays honest
        it99 = []
        for b in range(B):
            within = np.nonzero(traj[b] <= d_opt[b] * 1.01)[0]
            it99.append(int(within[0]) if within.size else -1)
        reached = [i for i in it99 if i >= 0]
        row = {"topology": name, "n": batch.n_phys, "cbar": cbar,
               "n_instances": B,
               "omd_final": float(traj[:, -1].mean()),
               "opt": float(d_opt.mean()),
               "iters_to_1pct": float(np.mean(reached)) if reached else -1.0,
               "n_not_within_1pct": B - len(reached),
               "iters_to_1pct_per_instance": it99}
        rows.append(row)
        emit(f"table2.{name}", secs / B,
             f"B={B};cost={row['omd_final']:.3f};opt={row['opt']:.3f};"
             f"it_1pct={row['iters_to_1pct']:.1f}")
        if not common.SMOKE:             # convergence needs the full run
            assert (traj[:, -1] <= d_opt * 1.02).all(), name
    dump("table2_topologies", rows)
    return rows


if __name__ == "__main__":
    main()
