"""Paper Table II + Figs. 12–15: OMD-RT across the four named topologies."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (build_random_cec, frank_wolfe_routing, get_cost,
                        solve_routing)
from repro.topo import make_topology

from .common import dump, emit, timeit

LAM = jnp.array([15.0, 15.0, 15.0])


def main() -> list[dict]:
    cost = get_cost("exp")
    rows = []
    for name in ("abilene", "balanced_tree", "fog", "geant"):
        adj, cbar = make_topology(name)
        g = build_random_cec(adj, 3, cbar, seed=0)
        phi0 = g.uniform_phi()
        omd = jax.jit(lambda p, g=g: solve_routing(g, cost, LAM, p, 3.0, 150))
        (_, traj), secs = timeit(omd, phi0)
        _, d_opt = frank_wolfe_routing(g, cost, LAM, n_iters=200)
        traj = np.asarray(traj)
        # iterations to within 1% of OPT
        within = np.nonzero(traj <= d_opt * 1.01)[0]
        it99 = int(within[0]) if within.size else -1
        row = {"topology": name, "n": g.n_phys, "cbar": cbar,
               "omd_final": float(traj[-1]), "opt": d_opt, "iters_to_1pct": it99}
        rows.append(row)
        emit(f"table2.{name}", secs,
             f"cost={traj[-1]:.3f};opt={d_opt:.3f};it_1pct={it99}")
        assert traj[-1] <= d_opt * 1.02, name
    dump("table2_topologies", rows)
    return rows


if __name__ == "__main__":
    main()
