"""Non-stationary scenario suite: dynamic regret + recovery (DESIGN.md §10).

Runs every named scenario (``core.scenario.named_scenarios``) batched over
seeds — each segment is one vmapped XLA program on the ``CECGraphBatch``
path — and reports per-scenario wall-clock, dynamic regret against the
segment self-comparator, and per-event recovery: utility before the
event, at the event, at segment end, and iterations until the trajectory
re-crosses ``RECOVERY_FRAC`` of the pre-event level.

The churn acceptance bar asserted in ``tests/test_scenario.py`` (≥95 % of
pre-event utility recovered within the post-event budget) is reported
here as the ``link_churn`` row.
"""
from __future__ import annotations

import numpy as np

from repro.core import (named_scenarios, run_scenario, scenario_metrics,
                        serving_defaults)

from . import common
from .common import dump, emit, timeit

RECOVERY_FRAC = 0.95


def main() -> list[dict]:
    horizon = common.scaled(100, 12)
    n, p = common.scaled((25, 0.2), (12, 0.35))
    seeds = tuple(range(common.scaled(8, 2)))

    rows = []
    for name, sc in named_scenarios(horizon=horizon, n=n, p=p).items():
        res, secs = timeit(
            lambda sc=sc: run_scenario(sc, seeds=seeds,
                                       config=serving_defaults()),
            warmup=0, iters=1)
        m = scenario_metrics(res, recovery_frac=RECOVERY_FRAC)
        traj = np.asarray(res.utility_traj).mean(0)
        row = {"scenario": name, "n_seeds": len(seeds), "horizon": horizon,
               "seconds_cold": secs, "dynamic_regret": m["dynamic_regret"],
               "u_final": float(traj[-1]),
               "events": [r._asdict() for r in m["events"]]}
        rows.append(row)
        ev = m["events"][0] if m["events"] else None
        detail = (f"u_pre={ev.u_pre:.2f};u_drop={ev.u_drop:.2f};"
                  f"rec_iters={ev.recovery_iters:.0f};"
                  f"rec_frac={ev.recovered_frac:.2f}" if ev else "no_events")
        emit(f"bench_scenarios.{name}", secs,
             f"regret={m['dynamic_regret']:.1f};{detail}")
    dump("bench_scenarios", rows)
    return rows


if __name__ == "__main__":
    main()
