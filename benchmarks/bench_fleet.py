"""Fleet-scale ensemble solving: sharded vs vmap throughput (DESIGN.md §14).

The paper's evaluation sweeps thousands of random instance draws; PR-1
made that one vmapped XLA program (``run_batch``) and §14 shards the
instance axis over a device mesh (``run_batch_sharded``).  This bench
answers the operational question — *how many power-law fleets does each
driver solve per wall-clock second?* — on a batch of distinct
``topo.make_fleet("power_law")`` draws tiled to fleet size.

The headline row asserts the smoke bar: on the single CPU device CI runs
on, the sharded driver's 1-device mesh traces to the *same* vmapped
executable plus shard_map bookkeeping, so its throughput must stay
within noise of the vmap path (≥ 0.75× on a 1-warmup smoke run — an
honest bound: CPU CI timing jitter makes a strict ≥ 1× assert flaky,
and any real dispatch pathology lands far below it).  Multi-device
speedups are reported when the process actually has devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8`` reproduces the
CI sharding job locally); CPU fake devices share the same cores, so the
number is a scaling *proof*, not a perf claim — real fleets shard over
real accelerators.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.batch import CECGraphBatch, run_batch, run_batch_sharded
from repro.core.graph import build_random_cec
from repro.core.solver import SolverConfig
from repro.core.utility import make_bank
from repro.launch.mesh import fleet_mesh
from repro.topo import make_fleet

from . import common
from .common import dump, emit, timeit

W = 2                       # sessions per instance
N_NODES = 12                # physical nodes per power-law fleet draw
N_DISTINCT = 8              # distinct seeds tiled to the batch size
SMOKE_RATIO_FLOOR = 0.75    # 1-device sharded/vmap throughput bar (see doc)


def _fleet_batch(n_instances: int) -> tuple[CECGraphBatch, list]:
    graphs = [build_random_cec(make_fleet("power_law", N_NODES, seed=s),
                               W, 10.0, seed=s) for s in range(N_DISTINCT)]
    tiled = [graphs[i % N_DISTINCT] for i in range(n_instances)]
    banks = [make_bank("log", W, seed=i % N_DISTINCT)
             for i in range(n_instances)]
    return CECGraphBatch.from_graphs(tiled), banks


def main() -> list[dict]:
    iters = common.scaled(20, 3)
    fleet_sizes = common.scaled([1024, 4096], [8])
    config = SolverConfig(method="single", delta=0.5, eta_outer=0.05,
                          eta_inner=3.0, inner_iters=1)
    mesh = fleet_mesh()
    ndev = mesh.shape["fleet"]

    rows = []
    for B in fleet_sizes:
        batch, banks = _fleet_batch(B)

        vmap_fn = jax.jit(lambda b, bk: run_batch(
            b, bk, 4.0, config, iters=iters))
        sharded_fn = jax.jit(lambda b, bk: run_batch_sharded(
            b, bk, 4.0, config, iters=iters, mesh=mesh))

        from repro.core.batch import stack_banks
        stacked = stack_banks(banks)
        ref, t_vmap = timeit(vmap_fn, batch, stacked)
        got, t_shard = timeit(sharded_fn, batch, stacked)

        # the two drivers must be solving the same fleet
        drift = float(jnp.max(jnp.abs(ref.lam - got.lam)))
        assert drift <= 1e-6, f"sharded/vmap drift {drift} at B={B}"

        vmap_ips = B / t_vmap
        shard_ips = B / t_shard
        ratio = shard_ips / vmap_ips
        rec = {"fleet_size": B, "iters": iters, "n_devices": int(ndev),
               "vmap_instances_per_s": vmap_ips,
               "sharded_instances_per_s": shard_ips,
               "sharded_over_vmap": ratio}
        emit(f"fleet.B{B}.vmap_solve", t_vmap,
             f"ips={vmap_ips:.0f};iters={iters}")
        emit(f"fleet.B{B}.sharded_solve", t_shard,
             f"ips={shard_ips:.0f};ratio={ratio:.2f};ndev={ndev}")
        rows.append(rec)

    if common.SMOKE and int(ndev) == 1:
        r = rows[0]["sharded_over_vmap"]
        assert r >= SMOKE_RATIO_FLOOR, (
            f"1-device sharded throughput fell to {r:.2f}x of vmap — "
            f"shard_map dispatch overhead regression (floor "
            f"{SMOKE_RATIO_FLOOR}x)")

    dump("bench_fleet", rows)
    return rows
