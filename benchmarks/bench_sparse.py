"""Dense-vs-sparse scaling: the CECGraphSparse representation win.

For fleet-sized sparse topologies (``topo`` generators: grid / geometric /
power-law at N ∈ {256, 1024, 4096}) this measures the OMD-RT control-plane
iteration on both representations — per-iteration latency of the jitted
``solve_routing`` scan and resident state bytes (graph + φ pytree leaves).
The dense path is pinned via ``dispatch.sparse_dispatch(huge)`` so the
auto-policy can't silently convert the baseline being measured.

Smoke (CI) runs the headline case, power_law at N=1024, and asserts the
PR-4 acceptance bar: ≥5× latency *or* ≥4× state-memory improvement for
sparse over dense, plus trajectory agreement (the two representations must
be computing the same iteration).  N=4096 runs sparse-only (the dense
build alone would materialize ~800 MB of masks — the point of the PR).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (InfeasibleTopology, build_augmented,
                        build_augmented_sparse, dispatch, get_cost,
                        solve_routing)
from repro.core.graph import random_deployment
from repro.core.sparse import state_nbytes
from repro.topo import make_fleet

from . import common
from .common import dump, emit, timeit

LAM = jnp.array([20.0, 20.0, 20.0])
W = 3
DENSE_CAP = 2048          # beyond this the dense build is the bottleneck


def _draw_fleet(adj: np.ndarray, n: int, seed: int, mean_cap: float = 10.0):
    """Randomized capacities + deployment on a fleet adjacency (feasible)."""
    for t in range(20):
        rng = np.random.default_rng(seed + 1000 * t)
        link = rng.uniform(0.05, 2.0, (n, n)).astype(np.float32) * mean_cap
        link = np.maximum(link, link.T)
        comp = (rng.uniform(0.5, 1.5, n) * mean_cap).astype(np.float32)
        deploy = random_deployment(n, W, rng)
        try:
            return deploy, link, comp, build_augmented_sparse(
                adj, deploy, link, comp)
        except InfeasibleTopology:
            continue
    raise InfeasibleTopology(f"no feasible fleet draw at n={n}")


def _time_routing(graph, phi0, iters: int):
    cost = get_cost("exp")
    fn = jax.jit(lambda p: solve_routing(graph, cost, LAM, p, 1.0, iters))
    (_, traj), sec = timeit(fn, phi0)
    return np.asarray(traj), sec / iters


def main() -> list[dict]:
    iters = common.scaled(10, 2)
    cases = common.scaled(
        [("grid_2d", 256), ("random_geometric", 256), ("power_law", 1024),
         ("power_law", 4096)],
        [("power_law", 1024)])

    rows = []
    for kind, n in cases:
        adj = make_fleet(kind, n, seed=1)
        deploy, link, comp, gs = _draw_fleet(adj, n, seed=0)
        phi_s = gs.uniform_phi()
        traj_s, t_s = _time_routing(gs, phi_s, iters)
        mem_s = state_nbytes(gs, phi_s)
        rec = {"kind": kind, "n": n, "n_edges": gs.n_edges,
               "d_max": gs.d_max, "d_in_max": gs.d_in_max,
               "depth_max": gs.depth_max, "density": gs.density,
               "sparse_us_per_iter": t_s * 1e6,
               "sparse_state_mb": mem_s / 1e6}
        emit(f"sparse.{kind}_{n}.omd_iter_sparse", t_s,
             f"E={gs.n_edges};d_max={gs.d_max};depth={gs.depth_max}")

        if n <= DENSE_CAP:
            gd = build_augmented(adj, deploy, link, comp)
            phi_d = gd.uniform_phi()
            with dispatch.sparse_dispatch(threshold=1 << 30):
                traj_d, t_d = _time_routing(gd, phi_d, iters)
            mem_d = state_nbytes(gd, phi_d)
            rec.update(dense_us_per_iter=t_d * 1e6,
                       dense_state_mb=mem_d / 1e6,
                       latency_ratio=t_d / t_s, memory_ratio=mem_d / mem_s)
            emit(f"sparse.{kind}_{n}.omd_iter_dense", t_d,
                 f"lat_x={t_d / t_s:.1f};mem_x={mem_d / mem_s:.1f}")
            np.testing.assert_allclose(traj_d, traj_s, rtol=1e-4, atol=1e-4)
            if n >= 1024:            # PR-4 acceptance bar (smoke-asserted)
                assert (rec["latency_ratio"] >= 5.0
                        or rec["memory_ratio"] >= 4.0), rec
        rows.append(rec)

    dump("bench_sparse", rows)
    return rows


if __name__ == "__main__":
    main()
