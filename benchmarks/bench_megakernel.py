"""One-kernel control step vs the stitched per-phase path (DESIGN.md §17).

Times ``solver.step`` through the fused control megakernel
(``kernels/control_megakernel.py``, one ``pallas_call`` per outer
iteration) against the stitched path it replaces (a ``lax.scan`` of
per-phase Pallas kernels under ``kernel_dispatch``), on identical
problems, and publishes the compiled-cost roofline rows from
``repro.roofline.extract.control_roofline_rows`` into the perf
trajectory (``TRAJECTORY_ROWS = True`` → rows land in
``benchmarks/trajectory/BENCH_<sha>.json``).

Two bars:
  * ``SMOKE_SPEEDUP_BAR`` (CI, CPU interpret): the fused kernel must beat
    the stitched *kernel* path by ≥1.2× at the gate shape.  Both sides
    pay the interpret tax, so the ratio isolates what fusion removes —
    per-``pallas_call`` dispatch and inter-phase traffic — and holds
    off-TPU (measured ~1.5–2× at the gate shape; the jnp einsum path is
    separately reported for context but not gated, since off-TPU it is
    the production dispatch choice and the kernels exist for validation).
  * ``TPU_SPEEDUP_BAR`` (real hardware only): the §17 claim proper,
    checked only when ``jax.default_backend() == "tpu"``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common
from .common import dump, emit, scaled

TRAJECTORY_ROWS = True

SMOKE_SPEEDUP_BAR = 1.2   # fused vs stitched-kernels, CPU interpret
TPU_SPEEDUP_BAR = 1.2     # fused vs stitched-kernels, real TPU

# (n_phys, n_sessions, k_iters) — the first is the CI gate shape (chosen
# for a comfortable interpret-mode margin over the smoke bar: measured
# ≥1.5× across runs, vs the 1.2× gate)
GATE_SHAPE = (32, 8, 3)
FULL_SHAPES = ((32, 8, 3), (32, 10, 2))


def _setup(n_phys: int, n_sessions: int, k_iters: int):
    from repro.core import build_random_cec, solver
    from repro.core.problem import Problem
    from repro.topo import connected_er

    g = build_random_cec(connected_er(n_phys, 0.35, seed=3), n_sessions,
                         10.0, seed=0)
    problem = Problem.create(g, lam_total=8.0, cost="exp")
    config = solver.SolverConfig(method="nested", delta=0.5, eta_outer=0.05,
                                 eta_inner=0.05, inner_iters=k_iters,
                                 grad_mode="sampled")
    state = solver.init(problem, config)
    tau = jnp.ones((2 * g.n_sessions,), jnp.float32)
    return problem, config, state, tau


def _time_variant(problem, config, state, tau, ctx, reps: int = 3) -> float:
    """Seconds per fused control step, traced under dispatch context
    ``ctx`` (``fused_step``'s cache keys on ``dispatch.state_key()``, so
    each context gets its own executable).  Min over ``reps`` timed calls
    — the speedup gate compares two ~0.4 s interpret programs, where a
    single-sample ratio (what ``common.timeit`` yields under smoke's
    1-iter clamp) jitters past the bar's margin."""
    import time

    from repro.core import solver

    with ctx:
        fn = solver.fused_step(config)
        # first call traces — must happen inside the dispatch override
        jax.block_until_ready(fn(problem, state, tau))
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(problem, state, tau))
            best = min(best, time.perf_counter() - t0)
    return best


def main() -> list[dict]:
    from repro.core import dispatch
    from repro.roofline import extract

    on_tpu = jax.default_backend() == "tpu"
    shapes = (GATE_SHAPE,) if common.SMOKE else FULL_SHAPES
    rows: list[dict] = []
    for n_phys, n_sessions, k_iters in shapes:
        problem, config, state, tau = _setup(n_phys, n_sessions, k_iters)
        n_bar = problem.graph.n_bar
        t_mega = _time_variant(problem, config, state, tau,
                               dispatch.megakernel_dispatch(1))
        t_stitch = _time_variant(problem, config, state, tau,
                                 dispatch.kernel_dispatch(1))
        speedup = t_stitch / t_mega
        mode = "tpu" if on_tpu else "interpret"
        tag = f"n{n_phys}_W{n_sessions}_K{k_iters}"
        emit(f"megakernel.fused.{tag}", t_mega, f"{mode};1 pallas_call/step")
        emit(f"megakernel.stitched.{tag}", t_stitch,
             f"{mode};speedup={speedup:.2f}x")
        rows.append({"bench": "control_step", "mode": mode,
                     "n_phys": n_phys, "n_bar": int(n_bar),
                     "n_sessions": n_sessions, "k_iters": k_iters,
                     "megakernel_s": t_mega, "stitched_kernels_s": t_stitch,
                     "speedup": speedup})
        if not common.SMOKE:
            # jnp einsum path for context (the off-TPU production choice)
            t_jnp = _time_variant(problem, config, state, tau,
                                  dispatch.kernel_dispatch(10**9))
            rows[-1]["stitched_jnp_s"] = t_jnp
            emit(f"megakernel.jnp.{tag}", t_jnp, f"{mode};context-only")

        bar = TPU_SPEEDUP_BAR if on_tpu else SMOKE_SPEEDUP_BAR
        gate = on_tpu or (n_phys, n_sessions, k_iters) == GATE_SHAPE
        if gate:
            assert speedup >= bar, (
                f"megakernel speedup regressed at {tag}: {speedup:.2f}x < "
                f"{bar}x vs the stitched kernel path "
                f"({'TPU' if on_tpu else 'CPU interpret'} bar)")
            rows[-1]["bar"] = bar

    # compiled-cost roofline rows (lower+compile only — no execution);
    # exact on TPU, indicative under interpret (see extract docstring)
    gn, gw, gk = GATE_SHAPE
    costs = extract.control_step_costs(
        n_nodes=scaled(gn, 12), n_sessions=scaled(gw, 3),
        k_iters=scaled(gk, 2))
    rows.extend(extract.control_roofline_rows(costs))

    dump("bench_megakernel", rows)
    return rows


if __name__ == "__main__":
    common.set_smoke(True)
    main()
