"""Batched JOWR engine: per-instance wall-clock for batch sizes {1, 8, 32}.

Measures the tentpole claim directly: solving B Connected-ER(25, .2)
instances as one vmapped XLA program (``run_batch`` — ``jax.vmap`` of
``solver.run``) vs a Python loop of jitted per-instance ``solver.run``
calls over the same draws.
Reports seconds/instance for both and the batching speedup.
``measure_seq_vs_batched`` is the single implementation of that
measurement — the §Perf control-plane cell in perf_iterations.py reuses
it with its own B/outer_iters.

On a single-core CPU the vmapped program can lose to the loop at large B
(batched einsums trade cache locality for parallel width); the speedup
column is the signal to watch on parallel backends, where the instance
axis maps onto hardware.
"""
from __future__ import annotations

import jax

from repro.core import (CECGraphBatch, Problem, SolverConfig,
                        build_random_cec, make_bank, run, run_batch,
                        stack_banks)
from repro.topo import connected_er

from . import common
from .common import dump, emit, timeit

LAM_TOTAL = 60.0
CONFIG = SolverConfig(method="single", eta_outer=0.05, eta_inner=3.0)


def measure_seq_vs_batched(B: int, outer_iters: int,
                           graphs=None, banks=None) -> tuple[float, float]:
    """(sequential seconds, batched seconds) for the same B-instance OMAD
    ensemble: a Python loop of jitted per-instance ``solver.run`` calls
    vs one jitted ``run_batch`` program."""
    if graphs is None:
        n = common.scaled(25, 12)
        graphs = [build_random_cec(connected_er(n, 0.2, seed=1 + s), 3,
                                   10.0, seed=s) for s in range(B)]
    if banks is None:
        banks = [make_bank("log", 3, seed=s, lam_total=LAM_TOTAL)
                 for s in range(B)]
    graphs, banks = graphs[:B], banks[:B]

    seq = jax.jit(lambda g, bk: run(
        Problem(graph=g, bank=bk, lam_total=LAM_TOTAL), CONFIG,
        iters=outer_iters))
    _, t_seq = timeit(lambda: [seq(g, bk) for g, bk in zip(graphs, banks)])

    batch = CECGraphBatch.from_graphs(graphs)
    fn = jax.jit(lambda bk: run_batch(batch, bk, LAM_TOTAL, CONFIG,
                                      iters=outer_iters))
    _, t_batched = timeit(fn, stack_banks(banks))
    return t_seq, t_batched


def main() -> list[dict]:
    outer = common.scaled(30, 3)
    b_max = common.scaled(32, 2)
    n = common.scaled(25, 12)
    graphs = [build_random_cec(connected_er(n, 0.2, seed=1 + s), 3, 10.0,
                               seed=s) for s in range(b_max)]
    banks = [make_bank("log", 3, seed=s, lam_total=LAM_TOTAL)
             for s in range(b_max)]

    rows = []
    for B in common.scaled((1, 8, 32), (1, 2)):
        t_seq, t_batched = measure_seq_vs_batched(B, outer, graphs, banks)
        row = {"B": B, "outer_iters": outer,
               "batched_s_per_instance": t_batched / B,
               "sequential_s_per_instance": t_seq / B,
               "speedup": t_seq / t_batched}
        rows.append(row)
        emit(f"bench_batched.B{B}", t_batched / B,
             f"seq={t_seq/B*1e6:.1f}us/inst;speedup={t_seq/t_batched:.2f}x")
    dump("bench_batched", rows)
    return rows


if __name__ == "__main__":
    main()
