"""§Perf hillclimbing harness: baseline vs optimization variants for the
three selected cells (EXPERIMENTS.md §Perf).

Each iteration re-lowers the cell with one optimization flag flipped
(REPRO_PERF_VARIANT) in a fresh subprocess, extracts the scan-corrected
roofline inputs, and logs hypothesis → before → after → verdict.

Cells (selection rationale in EXPERIMENTS.md):
  deepseek-coder-33b × train_4k   — most collective-bound baseline
  qwen2-vl-72b × decode_32k       — worst roofline fraction (serving)
  jamba-1.5-large-398b × train_4k — paper-scale MoE/hybrid, memory-bound

Plus one control-plane cell on the batched JOWR path: sequential jitted
per-instance solves vs one vmapped ``run_batch`` program over the
same ensemble (hypothesis: vmap amortizes per-solve dispatch and compiles
one fused scan → per-instance time drops).
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

from . import common
from .common import dump, emit

CELLS = [
    ("deepseek-coder-33b", "train_4k",
     ["bf16params", "attnbatch", "fsdp256+bf16params"]),
    ("qwen2-vl-72b", "decode_32k",
     ["tpserve", "int8kv", "tpserve+int8kv"]),
    ("jamba-1.5-large-398b", "train_4k",
     ["attnbatch", "cf10", "hybridshard"]),
]

HYPOTHESES = {
    "bf16params": "bf16 weights halve every FSDP all-gather / grad "
                  "reduce payload → wire ≈ −45%",
    "attnbatch": "explicit batch-only attention sharding replaces GSPMD "
                 "involuntary replication of mid-attention tensors → "
                 "wire down on attn-heavy cells",
    "tpserve": "TP-only serving weights: zero per-step parameter "
               "all-gathers → decode wire ≈ −90%",
    "int8kv": "int8 KV cache halves decode cache traffic → memory ≈ −45%",
    "cf10": "MoE capacity 1.25→1.0 cuts expert compute/memory ≈ −20%",
    "fsdp256": "pure ZeRO-3 over all 256 chips removes per-layer TP "
               "partial-sum all-reduces (~2 TB/chip) for ~3× param "
               "gathers (~200 GB) → wire ≈ −75%",
    "hybridshard": "FSDP dense weights + expert-parallel MoE: drops TP "
                   "activation all-reduces on the non-expert 78%% of the "
                   "model → wire ≈ −25%",
    "batched_vmap": "one vmapped run_batch program over B instances "
                    "amortizes per-solve dispatch vs a Python loop of "
                    "jitted solves → per-instance time drops",
}


def control_plane_rows(B: int = 8, outer_iters: int = 20) -> list[dict]:
    """Batched control-plane cell: sequential vs vmapped JOWR ensemble."""
    from .bench_batched import measure_seq_vs_batched

    t_seq, t_bat = measure_seq_vs_batched(B, outer_iters=outer_iters)

    verdict = "confirmed" if t_bat < t_seq * 0.95 else (
        "neutral" if t_bat < t_seq * 1.05 else "refuted")
    rows = [
        {"arch": "cec_control_plane", "shape": f"omad_B{B}",
         "variant": "sequential", "s_per_instance": t_seq / B},
        {"arch": "cec_control_plane", "shape": f"omad_B{B}",
         "variant": "batched_vmap", "hypothesis": HYPOTHESES["batched_vmap"],
         "verdict": verdict, "s_per_instance": t_bat / B,
         "speedup": t_seq / t_bat},
    ]
    emit(f"perf.cec_control_plane.omad_B{B}.sequential", t_seq / B, "baseline")
    emit(f"perf.cec_control_plane.omad_B{B}.batched_vmap", t_bat / B,
         f"speedup={t_seq/t_bat:.2f}x;{verdict}")
    return rows


def run_variant(arch: str, shape: str, variant: str,
                out_root: str = "experiments/perf") -> dict:
    out = pathlib.Path(out_root) / variant.replace("+", "_")
    f = out / f"{arch}__{shape}.json"
    if not f.exists():
        env = dict(os.environ, PYTHONPATH="src",
                   REPRO_PERF_VARIANT=variant)
        r = subprocess.run(
            [sys.executable, "-m", "repro.roofline.extract", "--arch", arch,
             "--shape", shape, "--out", str(out)],
            env=env, capture_output=True, text=True, cwd=".")
        if not f.exists():
            raise RuntimeError(f"{arch}/{shape}/{variant}: "
                               + r.stdout[-500:] + r.stderr[-500:])
    return json.loads(f.read_text())


def main() -> list[dict]:
    from repro.configs import SHAPES, get_config
    from repro.roofline.analysis import analytic_bytes, roofline_terms

    if common.SMOKE:
        # the roofline cells re-lower multi-hundred-B models in
        # subprocesses — far past the smoke budget; the batched
        # control-plane cell alone exercises this module's solve paths
        return control_plane_rows(B=2, outer_iters=3)

    rows = control_plane_rows()
    for arch, shape, variants in CELLS:
        base = run_variant(arch, shape, "baseline")
        cfg = get_config(arch)

        base_flops = base["flash_adjusted"]["flops"]

        def terms(rec, variant):
            big = cfg.approx_params() > 100e9
            train = shape == "train_4k"
            pb = 2 if (big or not train or "bf16params" in variant) else 4
            kb = 1 if "int8kv" in variant else 2
            hbm = analytic_bytes(cfg, SHAPES[shape], rec["chips"],
                                 param_bytes=pb, kv_bytes=kb,
                                 moment_bytes=2 if big else 4)
            # compute is sharding-invariant: use the baseline measurement
            # (per-chip flops under exotic shardings reflect partitioner
            # replication choices, not useful work)
            return roofline_terms(base_flops, hbm,
                                  rec["wire_bytes_per_chip"], 1)

        t0 = terms(base, "baseline")
        rows.append({"arch": arch, "shape": shape, "variant": "baseline",
                     **{k: v for k, v in t0.items()}})
        emit(f"perf.{arch}.{shape}.baseline",
             max(t0["compute_s"], t0["memory_s"], t0["collective_s"]),
             f"bottleneck={t0['bottleneck']};frac={t0['roofline_fraction']:.3f}")
        for v in variants:
            rec = run_variant(arch, shape, v)
            t = terms(rec, v)
            dom0 = max(t0["compute_s"], t0["memory_s"], t0["collective_s"])
            dom = max(t["compute_s"], t["memory_s"], t["collective_s"])
            verdict = "confirmed" if dom < dom0 * 0.95 else (
                "neutral" if dom < dom0 * 1.05 else "refuted")
            rows.append({"arch": arch, "shape": shape, "variant": v,
                         "hypothesis": " + ".join(
                             HYPOTHESES[p] for p in v.split("+")),
                         "verdict": verdict, **{k: vv for k, vv in t.items()}})
            emit(f"perf.{arch}.{shape}.{v}", dom,
                 f"dom {dom0*1e3:.1f}ms→{dom*1e3:.1f}ms;"
                 f"bneck={t['bottleneck']};{verdict}")
    dump("perf_iterations", rows)
    return rows


if __name__ == "__main__":
    main()
