"""Shared benchmark utilities: timing, CSV emission, artifact dump."""
from __future__ import annotations

import json
import pathlib
import time

ART = pathlib.Path("experiments/paper")


def timeit(fn, *args, warmup: int = 1, iters: int = 3):
    """(result, seconds-per-call) with block_until_ready semantics."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / iters


def emit(name: str, seconds: float, derived: str):
    print(f"{name},{seconds*1e6:.1f},{derived}")


def dump(name: str, obj):
    ART.mkdir(parents=True, exist_ok=True)
    (ART / f"{name}.json").write_text(json.dumps(obj, indent=1))
