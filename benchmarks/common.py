"""Shared benchmark utilities: timing, CSV emission, artifact dump, smoke.

Smoke mode (``python -m benchmarks.run --smoke``, used by the CI bench
job) shrinks every module to import-and-execute scale: call
:func:`scaled` for any size-like constant and it returns the tiny value
instead, and :func:`timeit` clamps to 1 warmup / 1 iter.  Smoke numbers
are *execution proofs*, not performance data — the JSON summary the CI
job uploads is for trend eyeballing and import/runtime regression
catching, never for perf claims.
"""
from __future__ import annotations

import json
import pathlib
import time

ART = pathlib.Path("experiments/paper")

SMOKE = False

# (name, seconds) for every emit() of the process — the harness
# (benchmarks/run.py) snapshots len(RECORDS) around each module and slices
# its rows out to compute the per-bench median latency recorded in the
# perf-trajectory entry (BENCH_*.json).
RECORDS: list[tuple[str, float]] = []


def set_smoke(on: bool) -> None:
    """Flip smoke mode (call before importing/running bench modules)."""
    global SMOKE
    SMOKE = bool(on)


def scaled(normal, smoke):
    """``normal`` at full scale, ``smoke`` under ``--smoke``."""
    return smoke if SMOKE else normal


def timeit(fn, *args, warmup: int = 1, iters: int = 3):
    """(result, seconds-per-call) with block_until_ready semantics."""
    import jax

    if SMOKE:
        warmup, iters = min(warmup, 1), 1
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / iters


def emit(name: str, seconds: float, derived: str):
    RECORDS.append((name, seconds))
    print(f"{name},{seconds*1e6:.1f},{derived}")


def dump(name: str, obj):
    ART.mkdir(parents=True, exist_ok=True)
    (ART / f"{name}.json").write_text(json.dumps(obj, indent=1))
