"""Multi-tenant streaming control plane under churn (DESIGN.md §15).

The operational question for the RouterFleet: *how many session control
decisions per second does the fleet sustain, and how long is one control
interval end-to-end* — microbatched measured-utility callback, donated
vmapped step, front-buffer publish — under each named arrival process
(``serve.traffic.named_traces``: poisson / diurnal / flash_crowd)?

Per trace the bench drives ``T`` control intervals, re-scaling per-tenant
demand from the trace each interval (a traced-leaf update — never a
retrace) and timing each interval wall-to-wall (callback included —
that's the honest control latency the serving plane sees).  Reported:
p50/p99/mean interval latency and ``sessions_per_s`` = K·W session
decisions / p50 interval.  The flash-crowd leg additionally consumes a
``NodeFail`` scenario event mid-trace, so the timing covers live
topology churn (same-shape splice, no retrace).

The headline row asserts the smoke bar: the fleet must clear
``SPEEDUP_FLOOR ×`` the throughput of K independent ``CECRouter``s
stepped in a Python loop over the same timeline (the K-fold vmap win is
far larger at real K; the floor is honest about 1-warmup CPU smoke
jitter, cf. ``bench_fleet.SMOKE_RATIO_FLOOR``), and the two must agree
on the final Λ to 1e-5 — the bench re-proves the parity contract it
benchmarks (``tests/test_fleet.py``).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scenario import NodeFail, initial_state, named_scenarios
from repro.serve import CECRouter, RouterFleet
from repro.serve.traffic import named_traces

from . import common
from .common import dump, emit

# keep the per-trace latency rows in the perf-trajectory entry
# (benchmarks/run.py strips "rows" for modules that don't opt in)
TRAJECTORY_ROWS = True

# fleet vs K-router-loop throughput smoke bar: observed 1.3–2.8× at the
# smoke K=4 on CPU CI-class hardware (the win grows with K); the floor
# sits under the observed minimum because 1-warmup smoke timing jitters
SPEEDUP_FLOOR = 1.15


def _tenants(K, *, n, horizon):
    sc = named_scenarios(horizon=horizon, n=n, p=0.4)["steady"]
    states = [initial_state(sc, seed=s) for s in range(K)]
    graphs = [st.graph() for st in states]
    fns = [
        (lambda lams, b=st.bank:
         np.asarray(jax.vmap(b.total)(jnp.asarray(lams))))
        for st in states]
    return sc, states, graphs, fns


def _drive_fleet(fleet, fns, demand, events=None):
    """Per-interval wall latencies (s) over one demand timeline."""
    lat = []
    for t in range(demand.shape[0]):
        t0 = time.perf_counter()
        if events and t in events:
            events[t]()
        fleet.set_demand(demand[t])
        fleet.control_step(fns)
        jax.block_until_ready(fleet.view.lam)
        lat.append(time.perf_counter() - t0)
    return np.asarray(lat)


def _drive_routers(routers, fns, demand):
    lat = []
    for t in range(demand.shape[0]):
        t0 = time.perf_counter()
        for k, (r, fn) in enumerate(zip(routers, fns)):
            r.on_demand_change(float(demand[t, k]))
            r.control_step(fn)
        jax.block_until_ready([r.state.lam for r in routers])
        lat.append(time.perf_counter() - t0)
    return np.asarray(lat)


def main() -> list[dict]:
    K = common.scaled(32, 4)
    n_nodes = common.scaled(12, 8)
    T = common.scaled(40, 6)
    sc, states, graphs, fns = _tenants(K, n=n_nodes, horizon=8)
    W = graphs[0].n_sessions
    base = np.full(K, sc.lam_total, np.float32)
    traces = named_traces(T, K, seed=0)

    rows = []
    speedup = None
    for name, trace in traces.items():
        demand = trace.demand(base)          # [T, K] = provisioned × shape
        fleet = RouterFleet(graphs, base, depth_max=graphs[0].depth_max + 2)
        # compile outside the timed loop: step, publish, demand rescale
        fleet.set_demand(demand[0])
        fleet.control_step(fns)

        events = None
        if name == "flash_crowd":
            scn = states[0]
            ev = NodeFail(at=1, count=1, seed=17)
            events = {T // 2:
                      (lambda: fleet.apply_scenario_event(0, scn, ev))}
        lat = _drive_fleet(fleet, fns, demand, events)

        p50 = float(np.percentile(lat, 50))
        p99 = float(np.percentile(lat, 99))
        sessions_per_s = K * W / p50
        rec = {"trace": name, "n_tenants": K, "n_sessions": W,
               "intervals": T,
               "p50_ms": p50 * 1e3, "p99_ms": p99 * 1e3,
               "mean_ms": float(lat.mean()) * 1e3,
               "sessions_per_s": sessions_per_s}

        if name == "poisson":
            # K-independent-router baseline + parity re-proof (no mid-
            # trace events on this leg, so the two timelines are equal)
            routers = [CECRouter(g, lam_total=float(b))
                       for g, b in zip(graphs, base)]
            for k, (r, fn) in enumerate(zip(routers, fns)):
                r.on_demand_change(float(demand[0, k]))
                r.control_step(fn)
            lat_seq = _drive_routers(routers, fns, demand)
            drift = max(
                float(jnp.max(jnp.abs(fleet.view.lam[k] - r.state.lam)))
                for k, r in enumerate(routers))
            assert drift <= 1e-5, f"fleet/router drift {drift}"
            speedup = float(np.median(lat_seq) / np.median(lat))
            rec["speedup_vs_sequential"] = speedup
        rows.append(rec)
        emit(f"serving.{name}.K{K}.interval", p50,
             f"p99_ms={p99*1e3:.2f};sessions_per_s={sessions_per_s:.0f}")

    if common.SMOKE:
        assert speedup is not None and speedup >= SPEEDUP_FLOOR, (
            f"fleet control throughput fell to {speedup:.2f}x of the "
            f"K-router loop — vmap/donation regression (floor "
            f"{SPEEDUP_FLOOR}x at K={K})")

    dump("bench_serving", rows)
    return rows
