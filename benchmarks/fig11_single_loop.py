"""Paper Fig. 11: nested vs single loop; topology change at iteration 50.

Reproduces both claims: (i) OMAD reaches the same optimum with 1 routing
iteration per observation (vs 40 for nested) — a ~40× drop in
control-plane work per outer step; (ii) both re-converge online after the
network topology changes mid-run, single-loop from a worse initial point.

Runs on the batched path: B instance pairs (pre-/post-change draws) solve
as one vmapped ``run_batch`` program per phase, threading the solver
core's stacked ``SolverState`` across the change (φ re-mixed through
``warm_start_phi``); curves are ensemble means.
"""
from __future__ import annotations

import numpy as np

from repro.core import (CECGraphBatch, SolverConfig, build_random_cec,
                        make_bank, run_batch, warm_start_phi)
from repro.topo import connected_er

from . import common
from .common import dump, emit, timeit

LAM_TOTAL = 60.0


def main() -> list[dict]:
    B = common.scaled(4, 2)
    n = common.scaled(25, 12)
    phase = common.scaled(50, 5)         # outer iterations per phase
    bank = make_bank("log", 3, seed=0, lam_total=LAM_TOTAL)
    batch1 = CECGraphBatch.from_graphs([
        build_random_cec(connected_er(n, 0.2, seed=1 + s), 3, 10.0, seed=s)
        for s in range(B)])
    batch2 = CECGraphBatch.from_graphs([
        build_random_cec(connected_er(n, 0.2, seed=9 + s), 3, 10.0, seed=s)
        for s in range(B)])

    rows = []
    for method, inner in (("nested", common.scaled(40, 5)), ("single", 1)):
        config = SolverConfig(method=method, eta_outer=0.05, eta_inner=3.0,
                              inner_iters=inner)

        def run(config=config):
            r1 = run_batch(batch1, bank, LAM_TOTAL, config, iters=phase)
            warm = r1.state._replace(
                phi=warm_start_phi(r1.state.phi, batch2.out_mask))
            r2 = run_batch(batch2, bank, LAM_TOTAL, config, iters=phase,
                           state=warm)
            return r1, r2

        (r1, r2), secs = timeit(run, warmup=0, iters=1)
        traj = np.concatenate([np.asarray(r1.utility_traj),
                               np.asarray(r2.utility_traj)], axis=1).mean(0)
        routing_iters_per_outer = 2 * batch1.n_sessions * inner
        rows.append({"method": method, "n_instances": B,
                     "traj": traj.tolist(),
                     "u_before_change": float(traj[phase - 1]),
                     "u_after_drop": float(traj[phase]),
                     "u_final": float(traj[-1]),
                     "routing_iters_per_outer": routing_iters_per_outer})
        # single cold call: compile time included, so emit the total rather
        # than a per-instance figure comparable to the warmed benchmarks
        emit(f"fig11.{method}", secs,
             f"cold_total_incl_compile;B={B};U{phase-1}={traj[phase-1]:.3f};"
             f"U{phase}={traj[phase]:.3f};Ufinal={traj[-1]:.3f};"
             f"rt_iters/outer={routing_iters_per_outer}")
    # both converge to the same post-change optimum
    if not common.SMOKE:
        assert abs(rows[0]["u_final"] - rows[1]["u_final"]) < 0.5
    dump("fig11_single_loop", rows)
    return rows


if __name__ == "__main__":
    main()
