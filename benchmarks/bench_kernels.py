"""Kernel micro-benchmarks: jnp reference path timings on CPU + control-
plane step scaling with fleet size (the Pallas kernels themselves target
TPU; interpret-mode timing is not meaningful, so we time the jnp
execution paths that the kernels replace and report the roofline-model
speedup the fused kernel buys on v5e)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_random_cec, get_cost, omd_step
from repro.kernels import ref
from repro.kernels.ops import flow_step_op, omd_update_op
from repro.topo import connected_er

from . import common
from .common import dump, emit, timeit


def _pallas_interpret_rows() -> list[dict]:
    """Execute the Pallas control-plane kernels (interpret mode off-TPU)
    against their einsum oracles — the CI smoke proof that the kernel
    path itself still runs, not just the jnp path it replaces."""
    W, N = 3, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    t = jnp.abs(jax.random.normal(ks[0], (W, N)))
    phi = jnp.abs(jax.random.normal(ks[1], (W, N, N)))
    inj = jnp.abs(jax.random.normal(ks[2], (W, N)))
    got = flow_step_op(t, phi, inj, interpret=True)
    err_flow = float(jnp.abs(got - ref.flow_step_ref(t, phi, inj)).max())
    mask = (phi > 0.5).astype(jnp.float32)
    got2 = omd_update_op(phi * mask, phi, mask, 1.0, interpret=True)
    err_omd = float(jnp.abs(
        got2 - ref.omd_update_ref(phi * mask, phi, mask, 1.0)).max())
    assert err_flow < 1e-4 and err_omd < 1e-4, (err_flow, err_omd)
    emit("kernels.pallas_interpret", 0.0,
         f"flow_err={err_flow:.2e};omd_err={err_omd:.2e}")
    return [{"bench": "pallas_interpret", "n": N,
             "flow_step_err": err_flow, "omd_update_err": err_omd}]


def main() -> list[dict]:
    rows = _pallas_interpret_rows()
    cost = get_cost("exp")
    lam3 = jnp.array([20.0, 20.0, 20.0])

    # control-plane iteration vs fleet size (dense masked-tensor path)
    for n in common.scaled((25, 50, 100, 200, 400), (25, 50)):
        g = build_random_cec(connected_er(n, min(0.2, 8.0 / n), seed=1), 3,
                             10.0, seed=0)
        phi = g.uniform_phi()
        stepf = jax.jit(lambda p, g=g: omd_step(g, cost, p, lam3, 3.0).phi)
        _, secs = timeit(stepf, phi, warmup=1, iters=5)
        nb = g.n_bar
        # HBM-bound estimate for the fused omd_update kernel on v5e:
        # one read+write of phi/delta/mask [W,N,N] f32 at 819 GB/s
        bytes_moved = 4 * 3 * nb * nb * 4
        v5e_est = bytes_moved / 819e9
        rows.append({"bench": "omd_step", "n": n, "cpu_s": secs,
                     "v5e_kernel_est_s": v5e_est})
        emit(f"kernels.omd_step.n{n}", secs,
             f"v5e_fused_est_us={v5e_est*1e6:.2f}")

    # flash-attention oracle FLOPs check (ref path, small shape)
    S = common.scaled(512, 128)
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 8, S, 64), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 8, S, 64), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 8, S, 64), jnp.float32)
    att = jax.jit(lambda a, b, c: ref.mha_ref(a, b, c, causal=True))
    _, secs = timeit(att, q, k, v, warmup=1, iters=3)
    flops = 4 * 8 * S * S * 64 / 2  # causal
    rows.append({"bench": f"mha_ref_{S}", "cpu_s": secs,
                 "gflops_cpu": flops / secs / 1e9})
    emit(f"kernels.mha_ref_{S}", secs, f"gflops={flops/secs/1e9:.2f}")
    dump("bench_kernels", rows)
    return rows


if __name__ == "__main__":
    main()
