"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only fig7,...]``
prints ``name,us_per_call,derived`` CSV rows and writes JSON artifacts to
experiments/paper/ (consumed by EXPERIMENTS.md).
"""
from __future__ import annotations

import argparse
import sys
import traceback

MODULES = ("fig7_routing_convergence", "fig8_9_network_size",
           "fig10_utility_functions", "fig11_single_loop",
           "table2_topologies", "bench_kernels", "bench_batched",
           "perf_iterations")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = {s.strip() for s in args.only.split(",") if s.strip()}

    print("name,us_per_call,derived")
    failed = []
    for mod in MODULES:
        if only and not any(mod.startswith(o) for o in only):
            continue
        try:
            m = __import__(f"benchmarks.{mod}", fromlist=["main"])
            m.main()
        except Exception as e:  # noqa: BLE001
            failed.append((mod, repr(e)))
            traceback.print_exc()
    if failed:
        print("FAILED:", failed, file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
