"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only fig7,...] [--smoke]``
prints ``name,us_per_call,derived`` CSV rows and writes JSON artifacts to
experiments/paper/ (consumed by EXPERIMENTS.md).

``--smoke`` is the CI gate (tiny sizes, 1 warmup / 1 iter — see
``common.set_smoke``): it exercises every module's kernel and batch paths
end-to-end, writes a ``BENCH_smoke.json`` summary at the repo root (the
uploaded CI artifact), and exits non-zero on any import or runtime error.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import platform
import sys
import time
import traceback

from . import common

MODULES = ("fig7_routing_convergence", "fig8_9_network_size",
           "fig10_utility_functions", "fig11_single_loop",
           "table2_topologies", "bench_kernels", "bench_batched",
           "bench_scenarios", "perf_iterations")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, 1 warmup/1 iter; write BENCH_smoke.json")
    args = ap.parse_args()
    only = {s.strip() for s in args.only.split(",") if s.strip()}
    common.set_smoke(args.smoke)

    print("name,us_per_call,derived")
    failed, summary = [], {}
    for mod in MODULES:
        if only and not any(mod.startswith(o) for o in only):
            continue
        t0 = time.perf_counter()
        try:
            m = __import__(f"benchmarks.{mod}", fromlist=["main"])
            rows = m.main()
            summary[mod] = {"status": "ok",
                            "seconds": round(time.perf_counter() - t0, 3),
                            "rows": rows if isinstance(rows, (list, dict))
                            else None}
        except Exception as e:  # noqa: BLE001
            failed.append((mod, repr(e)))
            summary[mod] = {"status": "error", "error": repr(e),
                            "seconds": round(time.perf_counter() - t0, 3)}
            traceback.print_exc()

    if args.smoke:
        import jax

        out = {"smoke": True, "python": platform.python_version(),
               "jax": jax.__version__, "backend": jax.default_backend(),
               "modules": summary,
               "failed": [m for m, _ in failed]}
        pathlib.Path("BENCH_smoke.json").write_text(
            json.dumps(out, indent=1, default=str))
        print(f"wrote BENCH_smoke.json ({len(summary)} modules, "
              f"{len(failed)} failed)", file=sys.stderr)

    if failed:
        print("FAILED:", failed, file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
