"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only fig7,...] [--smoke]``
prints ``name,us_per_call,derived`` CSV rows and writes JSON artifacts to
experiments/paper/ (consumed by EXPERIMENTS.md).

``--smoke`` is the CI gate (tiny sizes, 1 warmup / 1 iter — see
``common.set_smoke``): it exercises every module's kernel and batch paths
end-to-end, writes a ``BENCH_smoke.json`` summary at the repo root (the
uploaded CI artifact), and exits non-zero on any import or runtime error.
It additionally appends one *perf-trajectory* entry per commit under
``benchmarks/trajectory/BENCH_<shortsha>.json`` (stable schema: commit,
commit date, per-bench median latency) — entries are committed with the
PR that produced them, so the trajectory accumulates across PRs instead
of one file being overwritten in place.  Smoke numbers are execution
proofs for trend eyeballing, never perf claims.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import platform
import statistics
import subprocess
import sys
import time
import traceback

from . import common

MODULES = ("fig7_routing_convergence", "fig8_9_network_size",
           "fig10_utility_functions", "fig11_single_loop",
           "table2_topologies", "bench_kernels", "bench_batched",
           "bench_scenarios", "bench_router", "bench_sparse",
           "bench_fleet", "bench_serving", "bench_learned",
           "bench_megakernel", "bench_obs", "perf_iterations")

TRAJECTORY_DIR = pathlib.Path("benchmarks/trajectory")
TRAJECTORY_SCHEMA = 3


def _git(*args: str) -> str:
    try:
        return subprocess.run(["git", *args], capture_output=True,
                              text=True, check=True).stdout.strip()
    except Exception:  # noqa: BLE001 — detached/dirty/missing git all OK
        return "unknown"


def _tree_dirty() -> bool:
    """Uncommitted changes beyond the bench artifacts themselves."""
    status = _git("status", "--porcelain")
    if status == "unknown":
        return True
    return any(
        line and "BENCH_smoke.json" not in line
        and "benchmarks/trajectory/" not in line
        for line in status.splitlines())


def write_trajectory_entry(summary: dict) -> pathlib.Path:
    """One BENCH_<shortsha>.json per commit so the trajectory accumulates.

    Schema (stable across PRs — consumers may rely on these keys):
      schema: int, commit: str, date: str (commit ISO date), dirty: bool
      (worktree had non-artifact changes beyond ``commit`` when measured),
      smoke: bool, jax/backend/python: str, benches: {module: {status,
      seconds, med_latency_us|None}} — ``med_latency_us`` is the median
      over the module's emitted CSV rows.  Only full runs write an entry
      (``--only`` subsets would masquerade as a complete record).

    Schema 2 (additive): a module that sets ``TRAJECTORY_ROWS = True``
    keeps its per-row records under ``benches.<module>.rows`` — e.g.
    ``bench_serving``'s p50/p99 control-interval latency per churn trace
    (README "Perf trajectory" documents how to read them).  Every other
    module still has its rows stripped to keep entries small.

    Schema 3 (additive): ``dirty`` and ``jax_version`` are first-class,
    always-present keys (``jax`` stays as the legacy alias).  Consumers
    must go through :func:`read_trajectory`, which back-fills both on
    schema-1/2 rows instead of KeyError-ing on history.
    """
    import jax

    commit = _git("rev-parse", "--short", "HEAD")
    entry = {
        "schema": TRAJECTORY_SCHEMA,
        "commit": commit,
        "date": _git("show", "-s", "--format=%cI", "HEAD"),
        "dirty": _tree_dirty(),
        "smoke": common.SMOKE,
        "python": platform.python_version(),
        "jax": jax.__version__,
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "benches": summary,
    }
    TRAJECTORY_DIR.mkdir(parents=True, exist_ok=True)
    path = TRAJECTORY_DIR / f"BENCH_{commit}.json"
    path.write_text(json.dumps(entry, indent=1, default=str))
    return path


def read_trajectory(directory: pathlib.Path | str = TRAJECTORY_DIR
                    ) -> list[dict]:
    """Load every trajectory entry, oldest first, schema-tolerantly.

    Pre-schema-3 rows lack the first-class ``dirty``/``jax_version``
    keys; rather than make every consumer special-case history, this
    reader back-fills them (``jax_version`` from the legacy ``jax`` key,
    ``dirty`` conservatively ``True`` when a row predates the flag) and
    guarantees ``benches`` exists.  Newer keys pass through untouched —
    the schema only ever grows.
    """
    entries = []
    for path in sorted(pathlib.Path(directory).glob("BENCH_*.json")):
        entry = json.loads(path.read_text())
        entry.setdefault("jax_version", entry.get("jax", "unknown"))
        entry.setdefault("dirty", True)
        entry.setdefault("benches", {})
        entries.append(entry)
    entries.sort(key=lambda e: e.get("date", ""))
    return entries


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, 1 warmup/1 iter; write BENCH_smoke.json"
                         " + a benchmarks/trajectory/ entry")
    args = ap.parse_args()
    only = {s.strip() for s in args.only.split(",") if s.strip()}
    common.set_smoke(args.smoke)

    print("name,us_per_call,derived")
    failed, summary = [], {}
    for mod in MODULES:
        if only and not any(mod.startswith(o) for o in only):
            continue
        t0 = time.perf_counter()
        n_records = len(common.RECORDS)
        try:
            m = __import__(f"benchmarks.{mod}", fromlist=["main"])
            rows = m.main()
            lat = [s for _, s in common.RECORDS[n_records:]]
            summary[mod] = {"status": "ok",
                            "seconds": round(time.perf_counter() - t0, 3),
                            "med_latency_us":
                                round(statistics.median(lat) * 1e6, 1)
                                if lat else None,
                            "rows": rows if isinstance(rows, (list, dict))
                            else None}
        except Exception as e:  # noqa: BLE001
            failed.append((mod, repr(e)))
            summary[mod] = {"status": "error", "error": repr(e),
                            "med_latency_us": None,
                            "seconds": round(time.perf_counter() - t0, 3)}
            traceback.print_exc()

    if args.smoke:
        import jax

        out = {"smoke": True, "python": platform.python_version(),
               "jax": jax.__version__, "backend": jax.default_backend(),
               "modules": summary,
               "failed": [m for m, _ in failed]}
        pathlib.Path("BENCH_smoke.json").write_text(
            json.dumps(out, indent=1, default=str))
        print(f"wrote BENCH_smoke.json ({len(summary)} modules, "
              f"{len(failed)} failed)", file=sys.stderr)
        if not only:        # a --only subset is not a trajectory point
            def _keeps_rows(mod: str) -> bool:
                m = sys.modules.get(f"benchmarks.{mod}")
                return bool(getattr(m, "TRAJECTORY_ROWS", False))

            traj = write_trajectory_entry(
                {mod: (s if _keeps_rows(mod)
                       else {k: v for k, v in s.items() if k != "rows"})
                 for mod, s in summary.items()})
            print(f"wrote {traj}", file=sys.stderr)

    if failed:
        print("FAILED:", failed, file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
