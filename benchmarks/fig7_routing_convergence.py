"""Paper Fig. 7: OMD-RT vs SGP vs OPT convergence on Connected-ER(25, .2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (build_random_cec, frank_wolfe_routing, get_cost,
                        solve_routing, solve_routing_sgp, total_cost)
from repro.topo import connected_er

from .common import dump, emit, timeit

LAM = jnp.array([20.0, 20.0, 20.0])


def main() -> list[dict]:
    g = build_random_cec(connected_er(25, 0.2, seed=1), 3, 10.0, seed=0)
    cost = get_cost("exp")
    phi0 = g.uniform_phi()

    omd = jax.jit(lambda p: solve_routing(g, cost, LAM, p, 3.0, 100))
    sgp = jax.jit(lambda p: solve_routing_sgp(g, cost, LAM, p, 0.5, 100))
    (_, tr_o), t_o = timeit(omd, phi0)
    (_, tr_s), t_s = timeit(sgp, phi0)
    _, d_opt = frank_wolfe_routing(g, cost, LAM, n_iters=300)

    tr_o, tr_s = np.asarray(tr_o), np.asarray(tr_s)
    rec = {
        "omd_traj": tr_o.tolist(), "sgp_traj": tr_s.tolist(),
        "opt_cost": d_opt,
        "omd_it10": float(tr_o[10]), "sgp_it10": float(tr_s[10]),
        "omd_final": float(tr_o[-1]), "sgp_final": float(tr_s[-1]),
    }
    dump("fig7_routing_convergence", rec)
    emit("fig7.omd_rt_100it", t_o,
         f"final={tr_o[-1]:.3f};it10={tr_o[10]:.3f};opt={d_opt:.3f}")
    emit("fig7.sgp_100it", t_s,
         f"final={tr_s[-1]:.3f};it10={tr_s[10]:.3f}")
    assert tr_o[10] <= tr_s[10] + 1e-3, "OMD-RT must lead SGP early (paper)"
    assert abs(tr_o[-1] - d_opt) / d_opt < 0.01
    return [rec]


if __name__ == "__main__":
    main()
