"""Paper Fig. 7: OMD-RT vs SGP vs OPT convergence on Connected-ER(25, .2).

As in the paper's evaluation, curves are averaged over a batch of random
instance draws; both solvers run through the batched path
(``solve_routing_batch``: one vmapped XLA program per method for all B
instances), the OPT reference is Frank–Wolfe per instance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CECGraphBatch, build_random_cec, frank_wolfe_routing,
                        get_cost, solve_routing_batch)
from repro.topo import connected_er

from . import common
from .common import dump, emit, timeit

LAM = jnp.array([20.0, 20.0, 20.0])


def main() -> list[dict]:
    B = common.scaled(4, 2)
    n = common.scaled(25, 12)
    iters = common.scaled(100, 10)
    fw_iters = common.scaled(300, 30)
    graphs = [build_random_cec(connected_er(n, 0.2, seed=1 + s), 3, 10.0,
                               seed=s) for s in range(B)]
    batch = CECGraphBatch.from_graphs(graphs)
    cost = get_cost("exp")
    phi0 = batch.uniform_phi()

    omd = jax.jit(lambda p: solve_routing_batch(batch, cost, LAM, p, 3.0,
                                                iters))
    sgp = jax.jit(lambda p: solve_routing_batch(batch, cost, LAM, p, 0.5,
                                                iters, method="sgp"))
    (_, tr_o), t_o = timeit(omd, phi0)
    (_, tr_s), t_s = timeit(sgp, phi0)
    d_opt = np.array([frank_wolfe_routing(g, cost, LAM, n_iters=fw_iters)[1]
                      for g in graphs])

    tr_o, tr_s = np.asarray(tr_o), np.asarray(tr_s)     # [B, iters]
    it = min(10, iters - 1)
    mo, ms, mopt = tr_o.mean(0), tr_s.mean(0), float(d_opt.mean())
    rec = {
        "n_instances": B,
        "omd_traj": mo.tolist(), "sgp_traj": ms.tolist(),
        "opt_cost": mopt, "opt_per_instance": d_opt.tolist(),
        "omd_it10": float(mo[it]), "sgp_it10": float(ms[it]),
        "omd_final": float(mo[-1]), "sgp_final": float(ms[-1]),
    }
    dump("fig7_routing_convergence", rec)
    emit(f"fig7.omd_rt_{iters}it", t_o / B,
         f"B={B};final={mo[-1]:.3f};it10={mo[it]:.3f};opt={mopt:.3f}")
    emit(f"fig7.sgp_{iters}it", t_s / B,
         f"B={B};final={ms[-1]:.3f};it10={ms[it]:.3f}")
    assert mo[it] <= ms[it] + 1e-3, "OMD-RT must lead SGP early (paper)"
    if not common.SMOKE:                 # convergence needs the full run
        np.testing.assert_allclose(tr_o[:, -1], d_opt, rtol=0.01)
    return [rec]


if __name__ == "__main__":
    main()
