"""Paper Figs. 8–9: total cost and running time vs network size n.

Each size point is an ensemble of B random instances solved on the batched
path — one vmapped XLA program per method — with per-instance wall-clock
reported as batched-time/B; OPT is Frank–Wolfe per instance.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CECGraphBatch, build_random_cec, frank_wolfe_routing,
                        get_cost, solve_routing_batch)
from repro.topo import connected_er

from . import common
from .common import dump, emit, timeit

LAM = jnp.array([20.0, 20.0, 20.0])


def main() -> list[dict]:
    cost = get_cost("exp")
    B = common.scaled(4, 2)
    iters = common.scaled(50, 8)
    fw_iters = common.scaled(150, 25)
    rows = []
    for n in common.scaled((20, 25, 30, 35, 40), (12, 15)):
        graphs = [build_random_cec(connected_er(n, 0.2, seed=1 + s), 3, 10.0,
                                   seed=s) for s in range(B)]
        batch = CECGraphBatch.from_graphs(graphs)
        phi0 = batch.uniform_phi()
        omd = jax.jit(lambda p, b=batch: solve_routing_batch(
            b, cost, LAM, p, 3.0, iters))
        sgp = jax.jit(lambda p, b=batch: solve_routing_batch(
            b, cost, LAM, p, 0.5, iters, method="sgp"))
        (_, tr_o), t_o = timeit(omd, phi0)
        (_, tr_s), t_s = timeit(sgp, phi0)
        t0 = time.perf_counter()
        d_opt = np.array([frank_wolfe_routing(g, cost, LAM,
                                              n_iters=fw_iters)[1]
                          for g in graphs])
        t_opt = (time.perf_counter() - t0) / B
        tr_o, tr_s = np.asarray(tr_o), np.asarray(tr_s)
        row = {"n": n, "n_instances": B,
               "omd_cost": float(tr_o[:, -1].mean()),
               "sgp_cost": float(tr_s[:, -1].mean()),
               "opt_cost": float(d_opt.mean()),
               "omd_s": t_o / B, "sgp_s": t_s / B, "opt_s": t_opt}
        rows.append(row)
        emit(f"fig8_9.n{n}.omd", t_o / B,
             f"B={B};cost={row['omd_cost']:.3f};opt={row['opt_cost']:.3f}")
        emit(f"fig8_9.n{n}.sgp", t_s / B, f"B={B};cost={row['sgp_cost']:.3f}")
        emit(f"fig8_9.n{n}.opt_fw", t_opt, f"cost={row['opt_cost']:.3f}")
    dump("fig8_9_network_size", rows)
    return rows


if __name__ == "__main__":
    main()
