"""Paper Figs. 8–9: total cost and running time vs network size n."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (build_random_cec, frank_wolfe_routing, get_cost,
                        solve_routing, solve_routing_sgp)
from repro.topo import connected_er

from .common import dump, emit, timeit

LAM = jnp.array([20.0, 20.0, 20.0])
ITERS = 50


def main() -> list[dict]:
    cost = get_cost("exp")
    rows = []
    for n in (20, 25, 30, 35, 40):
        g = build_random_cec(connected_er(n, 0.2, seed=1), 3, 10.0, seed=0)
        phi0 = g.uniform_phi()
        omd = jax.jit(lambda p, g=g: solve_routing(g, cost, LAM, p, 3.0, ITERS))
        sgp = jax.jit(lambda p, g=g: solve_routing_sgp(g, cost, LAM, p, 0.5,
                                                       ITERS))
        (_, tr_o), t_o = timeit(omd, phi0)
        (_, tr_s), t_s = timeit(sgp, phi0)
        t0 = time.perf_counter()
        _, d_opt = frank_wolfe_routing(g, cost, LAM, n_iters=150)
        t_opt = time.perf_counter() - t0
        row = {"n": n, "omd_cost": float(tr_o[-1]), "sgp_cost": float(tr_s[-1]),
               "opt_cost": d_opt, "omd_s": t_o, "sgp_s": t_s, "opt_s": t_opt}
        rows.append(row)
        emit(f"fig8_9.n{n}.omd", t_o, f"cost={tr_o[-1]:.3f};opt={d_opt:.3f}")
        emit(f"fig8_9.n{n}.sgp", t_s, f"cost={tr_s[-1]:.3f}")
        emit(f"fig8_9.n{n}.opt_fw", t_opt, f"cost={d_opt:.3f}")
    dump("fig8_9_network_size", rows)
    return rows


if __name__ == "__main__":
    main()
