"""Paper Fig. 10: GS-OMA under the four unknown-utility families."""
from __future__ import annotations

import numpy as np

from repro.core import (Problem, SolverConfig, build_random_cec,
                        exact_gradient_allocation, get_cost, make_bank, run)
from repro.topo import connected_er

from . import common
from .common import dump, emit, timeit

LAM_TOTAL = 60.0


def main() -> list[dict]:
    n = common.scaled(25, 12)
    g = build_random_cec(connected_er(n, 0.2, seed=1), 3, 10.0, seed=0)
    cost = get_cost("exp")
    config = SolverConfig(method="nested", eta_outer=0.05, eta_inner=3.0,
                          inner_iters=common.scaled(40, 5))
    rows = []
    for kind in ("linear", "sqrt", "quadratic", "log"):
        bank = make_bank(kind, 3, seed=0, lam_total=LAM_TOTAL)
        problem = Problem.create(g, bank, lam_total=LAM_TOTAL, cost=cost)
        # the paper observes linear utilities need ~400 outer iterations
        # while log needs ~30 (Fig. 10) — same behaviour here
        iters = common.scaled(400 if kind == "linear" else 80, 6)
        res, secs = timeit(
            lambda p=problem, it=iters: run(p, config, iters=it),
            warmup=0, iters=1)
        _, _, u_star = exact_gradient_allocation(
            g, cost, bank, LAM_TOTAL, eta=0.1,
            outer_iters=common.scaled(150, 10),
            inner_iters=common.scaled(50, 10), eta_inner=3.0)
        traj = np.asarray(res.utility_traj)
        row = {"kind": kind, "traj": traj.tolist(), "final": float(traj[-1]),
               "genie": u_star, "lam": np.asarray(res.lam).tolist()}
        rows.append(row)
        emit(f"fig10.{kind}", secs,
             f"U={traj[-1]:.3f};genie={u_star:.3f};gap={u_star-traj[-1]:.4f}")
        if not common.SMOKE:             # near-genie needs the full run
            assert traj[-1] > u_star - max(0.05 * abs(u_star), 0.5), kind
    dump("fig10_utility_functions", rows)
    return rows


if __name__ == "__main__":
    main()
