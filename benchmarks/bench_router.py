"""Serving control-plane latency: fused control step vs legacy host loop.

The paper's whole point is *online* operation — the control loop's
iteration latency bounds how fast the fleet adapts under churn.  This
bench measures one `CECRouter.control_step` (all 2W perturbed
observations + mirror ascent + exact projection + committed observation,
one jitted call, DESIGN.md §11) at W ∈ {4, 16, 64} sessions, on both the
jnp path and the Pallas kernel-dispatch path (interpret mode off-TPU —
an execution proof, not a perf number there), against the pre-PR-3
implementation preserved below: a Python ``for w in range(W)`` loop with
2W host round-trips of NumPy mirror-ascent math.

Smoke mode (CI) asserts the acceptance bar: ≥5× fused-over-legacy at
W=16 on CPU.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import build_random_cec, dispatch, get_cost
from repro.core.allocation import _project_box_simplex
from repro.core.flow import total_cost
from repro.core.routing import solve_routing
from repro.serve import CECRouter
from repro.topo import connected_er

from . import common
from .common import dump, emit, timeit

SPEEDUP_BAR = 5.0     # acceptance: fused ≥ 5× legacy at W=16 (smoke/CI)


def _legacy_control_step(graph, cost, lam, phi, lam_total, utility_fn,
                         delta=0.5, eta_outer=0.05, eta_inner=3.0):
    """The PR-2 ``CECRouter.control_step``: per-observation host loop.

    Kept verbatim as the comparison baseline — and imported by
    ``tests/test_serve.py::test_control_step_parity_with_reference_loop``
    as the parity oracle, so the speedup bar and the parity guarantee
    always describe the same code: 2W sequential `solve_routing`
    dispatches, a `float()` device sync per observation, NumPy
    mirror-ascent arithmetic.  Returns (Λ', φ) *without* the committed
    observation the fused step appends.
    """
    W = graph.n_sessions
    g = np.zeros(W, np.float32)
    for w in range(W):
        ew = jnp.zeros(W).at[w].set(1.0)
        for sign in (+1.0, -1.0):
            lam_p = lam + sign * delta * ew
            phi, _ = solve_routing(graph, cost, lam_p, phi, eta_inner, 1)
            u = utility_fn(np.asarray(lam_p)) - float(
                total_cost(graph, cost, phi, lam_p))
            g[w] += sign * u / (2 * delta)
    z = eta_outer * (g - g.max())
    wts = np.asarray(lam) * np.exp(z)
    lam = jnp.asarray(lam_total * wts / wts.sum())
    return _project_box_simplex(lam, lam_total, delta), phi


def _make_graph(W: int, seed: int = 0):
    n = max(20, 2 * W)             # one version per node ⇒ n ≥ W, headroom
    p = min(0.35, max(0.12, 6.0 / n))
    return build_random_cec(connected_er(n, p, seed=seed), W, 12.0,
                            seed=seed)


def main() -> list[dict]:
    session_counts = common.scaled((4, 16, 64), (4, 16))
    rows = []
    for W in session_counts:
        graph = _make_graph(W)
        lam_total = 3.0 * W
        quality = np.linspace(1.0, 2.0, W)
        batched_fn = lambda lams: np.atleast_2d(lams) @ quality
        scalar_fn = lambda lam: float(np.asarray(lam) @ quality)

        router = CECRouter(graph, lam_total=lam_total)
        _, fused_s = timeit(lambda: router.control_step(batched_fn),
                            warmup=1, iters=common.scaled(10, 2))

        lam0 = jnp.full((W,), lam_total / W)
        phi0 = graph.uniform_phi()
        _, legacy_s = timeit(
            lambda: _legacy_control_step(graph, get_cost("exp"), lam0, phi0,
                                         lam_total, scalar_fn),
            warmup=1, iters=common.scaled(3, 1))

        speedup = legacy_s / fused_s
        rows.append({"W": W, "n_bar": graph.n_bar, "path": "jnp",
                     "fused_us": fused_s * 1e6, "legacy_us": legacy_s * 1e6,
                     "speedup": speedup})
        emit(f"bench_router.W{W}.jnp", fused_s,
             f"legacy_us={legacy_s*1e6:.0f};speedup={speedup:.1f}x")

        # kernel-dispatch path: interpret mode off-TPU is an execution
        # proof of the fused step on the Pallas branch, far slower than
        # the fused einsums — smoke keeps it to the smallest W
        if not common.SMOKE or W == session_counts[0]:
            with dispatch.kernel_dispatch(1):
                krouter = CECRouter(graph, lam_total=lam_total)
                _, kernel_s = timeit(lambda: krouter.control_step(batched_fn),
                                     warmup=1, iters=1)
            rows.append({"W": W, "n_bar": graph.n_bar, "path": "kernel",
                         "fused_us": kernel_s * 1e6})
            emit(f"bench_router.W{W}.kernel", kernel_s,
                 "interpret" if dispatch.kernel_interpret() else "tpu")

    if common.SMOKE:
        bar = next(r for r in rows if r["W"] == 16 and r["path"] == "jnp")
        assert bar["speedup"] >= SPEEDUP_BAR, (
            f"fused control step only {bar['speedup']:.1f}x over the legacy "
            f"loop at W=16 (acceptance bar: {SPEEDUP_BAR}x)")
    dump("bench_router", rows)
    return rows


if __name__ == "__main__":
    main()
