"""Learned-utility serving: oracle-call economy vs utility parity (§16).

The operational claim behind ``grad_mode="learned"`` (DESIGN.md §16.2,
§16.4): once the router's :class:`~repro.core.utility.OnlineFitter`
earns the switch, a control interval costs **one** measured admission
instead of 2W+1 — while the achieved network utility stays at the
sampled controller's level.  This bench runs the claim end-to-end on a
live ``CECRouter`` pair over the same measured environment (a log
``UtilityBank`` the controllers can only observe):

* ``sampled`` — the classic two-point controller, 2W+1 measured
  admissions every interval;
* ``learned`` — ``grad_policy="auto"``: samples until the fitter's
  holdout clears, then migrates live to the analytic gradient through
  the implicit routing layer.

Reported per mode: total and steady-state measured admissions
("oracle calls" — each is a real traffic perturbation the serving plane
must admit), final net utility, and utility as a fraction of the *genie*
(``core.opt_baseline.exact_gradient_allocation`` — true u', no bandit
feedback).  The smoke bars are the ISSUE acceptance criteria and fail
the bench loudly:

* learned final utility ≥ ``UTILITY_FLOOR`` (99%) of sampled's;
* total measured admissions reduced ≥ ``CALL_REDUCTION_FLOOR`` (2×).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_random_cec, get_cost, make_bank
from repro.core.opt_baseline import exact_gradient_allocation
from repro.serve import CECRouter
from repro.topo import connected_er

from . import common
from .common import dump, emit

TRAJECTORY_ROWS = True

UTILITY_FLOOR = 0.99       # learned ≥ 99% of sampled net utility
CALL_REDUCTION_FLOOR = 2.0  # ≥ 2× fewer measured admissions overall


def _environment(*, n, seed):
    graph = build_random_cec(connected_er(n, 0.35, seed=seed), 3, 10.0,
                             seed=0)
    bank = make_bank("log", graph.n_sessions, seed=0)

    def util(lams):
        lams = np.atleast_2d(np.asarray(lams))
        return np.asarray(jax.vmap(bank.total)(jnp.asarray(lams)))

    return graph, bank, util


def _drive(router, util, intervals):
    for _ in range(intervals):
        router.control_step(util)
    hist = [h for h in router.history if "mode" in h]
    return {
        "final_utility": float(np.mean([h["utility"]
                                        for h in hist[-5:]])),
        "total_oracle_calls": int(sum(h["oracle_calls"] for h in hist)),
        "steady_calls_per_interval": int(hist[-1]["oracle_calls"]),
        "modes": [h["mode"] for h in hist],
    }


def main() -> list[dict]:
    n_nodes = common.scaled(12, 10)
    T = common.scaled(150, 80)
    lam_total = 15.0
    graph, bank, util = _environment(n=n_nodes, seed=2)
    W = graph.n_sessions

    # the genie: true marginal utilities, no bandit feedback — the
    # ceiling both measured controllers chase
    _, _, u_genie = exact_gradient_allocation(
        graph, get_cost("exp"), bank, lam_total,
        outer_iters=common.scaled(300, 120),
        inner_iters=common.scaled(100, 60))
    u_genie = float(u_genie)

    results = {}
    for mode, policy in (("sampled", "sampled"), ("learned", "auto")):
        router = CECRouter(graph, lam_total=lam_total, grad_policy=policy,
                           util_family="log")
        if router.fitter is not None:
            router.fitter.min_samples = 20
            router.fitter.refit_every = 8
            router.fitter.fit_steps = 1500
            router.fitter.threshold = 0.02
        results[mode] = _drive(router, util, T)

    rows = []
    for mode, r in results.items():
        switch_at = r["modes"].index("learned") \
            if "learned" in r["modes"] else None
        rec = {"mode": mode, "intervals": T, "n_sessions": W,
               "final_utility": r["final_utility"],
               "utility_vs_genie": r["final_utility"] / u_genie,
               "total_oracle_calls": r["total_oracle_calls"],
               "steady_calls_per_interval": r["steady_calls_per_interval"],
               "switch_interval": switch_at}
        rows.append(rec)
        emit(f"learned.{mode}.T{T}", 0.0,
             f"utility={r['final_utility']:.3f};"
             f"vs_genie={rec['utility_vs_genie']:.4f};"
             f"calls={r['total_oracle_calls']}")

    s, l = results["sampled"], results["learned"]
    reduction = s["total_oracle_calls"] / l["total_oracle_calls"]
    parity = l["final_utility"] / s["final_utility"]
    rows.append({"mode": "summary", "call_reduction": reduction,
                 "utility_parity": parity, "genie_utility": u_genie})
    emit(f"learned.summary.T{T}", 0.0,
         f"call_reduction={reduction:.2f}x;parity={parity:.4f}")

    # the ISSUE acceptance bars — a regression here is a broken PR, not
    # a slow one, so assert instead of reporting
    assert parity >= UTILITY_FLOOR, (
        f"learned utility {l['final_utility']:.3f} is below "
        f"{UTILITY_FLOOR:.0%} of sampled {s['final_utility']:.3f}")
    assert reduction >= CALL_REDUCTION_FLOOR, (
        f"oracle-call reduction {reduction:.2f}x is below the "
        f"{CALL_REDUCTION_FLOOR}x bar "
        f"({l['total_oracle_calls']} vs {s['total_oracle_calls']} calls)")
    assert l["steady_calls_per_interval"] == 1

    dump("bench_learned", rows)
    return rows


if __name__ == "__main__":
    main()
