"""Observability overhead + export proof (repro/obs/, DESIGN.md §18).

Two claims the subsystem stands on, measured:

* **recording is ~free** — one `CECRouter.control_step` with a telemetry
  ring enabled vs the same router with recording off.  The ring rides
  the same jitted executable (donated alongside the state), so the gap
  should be noise, not a tax; the emitted `overhead` column is the
  ratio (telemetry / baseline).
* **the exports are real** — every smoke run writes the two §18.3
  artifacts CI uploads: a Chrome trace-event timeline
  (``experiments/obs/obs_trace.json`` — control intervals, dispatch
  decisions, scenario events) and a metrics JSONL
  (``experiments/obs/obs_metrics.jsonl`` — per-interval ring rows plus
  the monitor-verdict record).  Both paths land in the perf-trajectory
  entry (``TRAJECTORY_ROWS``) so each commit's artifacts are one
  ``jq`` away.

The verdict summary asserts the run was healthy: an event-free steady
router must not trip any paper-invariant monitor.
"""
from __future__ import annotations

import json
import pathlib

import jax
import numpy as np

from repro.core import build_random_cec, make_bank
from repro.obs import export as obs_export
from repro.obs import trace as obs_trace
from repro.serve import CECRouter
from repro.topo import connected_er

from . import common
from .common import emit, timeit

OBS_ART = pathlib.Path("experiments/obs")

TRAJECTORY_ROWS = True   # keep artifact paths + verdicts in BENCH_<sha>.json


def _router(W: int, telemetry: int, seed: int = 0) -> CECRouter:
    n = max(16, 2 * W)
    graph = build_random_cec(connected_er(n, 0.25, seed=seed), W, 12.0,
                             seed=seed)
    return CECRouter(graph, lam_total=3.0 * W, telemetry=telemetry)


def _utility_fn(W: int):
    bank = make_bank("log", W, seed=1, lam_total=3.0 * W)
    return lambda lams: np.asarray(
        jax.vmap(bank.total)(np.atleast_2d(np.asarray(lams))))


def main() -> list[dict]:
    W = common.scaled(16, 4)
    intervals = common.scaled(40, 6)
    capacity = common.scaled(64, 8)
    fn = _utility_fn(W)

    # -- recording overhead: telemetry ring on vs off ----------------------
    base = _router(W, telemetry=0)
    _, base_s = timeit(lambda: base.control_step(fn))
    tracer = obs_trace.Tracer()
    obs_trace.install_tracer(tracer)
    try:
        router = _router(W, telemetry=capacity)
        _, tel_s = timeit(lambda: router.control_step(fn))
        for _ in range(intervals - len(router.history)):
            router.control_step(fn)
        verdicts = router.verdicts()
        OBS_ART.mkdir(parents=True, exist_ok=True)
        trace_path = obs_export.write_chrome_trace(OBS_ART / "obs_trace.json")
        metrics_path = obs_export.write_metrics_jsonl(
            OBS_ART / "obs_metrics.jsonl", router.tel, verdicts=verdicts,
            name="bench_obs")
    finally:
        obs_trace.uninstall_tracer()

    overhead = tel_s / base_s
    emit(f"obs/control_step_W{W}_baseline", base_s, "telemetry=0")
    emit(f"obs/control_step_W{W}_recording", tel_s,
         f"ring[{capacity}] overhead={overhead:.3f}x")

    # the exports must be well-formed (CI uploads them as-is)
    doc = json.loads(trace_path.read_text())
    assert doc["traceEvents"], "empty Chrome trace"
    lines = metrics_path.read_text().splitlines()
    assert len(lines) >= 2, "metrics JSONL missing rows"
    tail = json.loads(lines[-1])
    assert tail["name"] == "bench_obs.verdicts"
    tripped = sorted(k for k, v in tail.items()
                     if isinstance(v, dict) and v.get("trip"))
    assert not tripped, f"monitors tripped on a steady run: {tripped}"

    return [{
        "name": "bench_obs", "sessions": W, "intervals": intervals,
        "ring_capacity": capacity,
        "overhead_ratio": round(overhead, 4),
        "trace_events": len(doc["traceEvents"]),
        "metrics_rows": len(lines),
        "artifacts": {"chrome_trace": str(trace_path),
                      "metrics_jsonl": str(metrics_path)},
        "verdicts": {k: v for k, v in tail.items()
                     if isinstance(v, dict)},
    }]


if __name__ == "__main__":
    main()
