"""Golden-trace regression: the Fig. 7 gs_oma utility trajectory.

The committed NPZ (tests/golden/, written by scripts/make_golden_trace.py)
pins the full fused control step — perturbation order, oracle
observations, mirror ascent, exact projection, committed observation — on
the paper's main instance.  Any numerical drift in that path now fails
tier-1 instead of surfacing as a silent benchmark regression.  The
tolerance absorbs cross-platform/JAX-version instruction reordering; a
real semantic change blows straight through it (and should regenerate the
fixture with an explicit commit-message note).
"""
import pathlib
import sys

import numpy as np

_ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(_ROOT) not in sys.path:          # scripts/ is a namespace package
    sys.path.insert(0, str(_ROOT))

GOLDEN = pathlib.Path(__file__).parent / "golden" / "fig7_gs_oma_traj.npz"


def test_gs_oma_matches_golden_trace():
    from scripts.make_golden_trace import CONFIG, solve

    ref = np.load(GOLDEN)
    # the fixture must have been generated from this exact configuration
    assert int(ref["cfg_outer_iters"]) == CONFIG["outer_iters"]
    assert float(ref["cfg_lam_total"]) == CONFIG["lam_total"]
    assert str(ref["cfg_method"]) == CONFIG["method"]

    res = solve()
    np.testing.assert_allclose(
        np.asarray(res.utility_traj, np.float64), ref["utility_traj"],
        rtol=2e-4, atol=2e-3,
        err_msg="gs_oma utility trajectory drifted from the golden trace — "
                "if intentional, regenerate via scripts/make_golden_trace.py")
    np.testing.assert_allclose(np.asarray(res.lam, np.float64), ref["lam"],
                               rtol=2e-4, atol=2e-3)
