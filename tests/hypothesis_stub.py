"""Bundled property-test sampler — the *executing* fallback for
environments without ``hypothesis`` installed.

Test modules import this when ``from hypothesis import ...`` fails:

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from hypothesis_stub import given, settings, st

Unlike the pre-PR-4 stub, this does **not** skip: ``@given`` runs the
property ``max_examples`` times against deterministically seeded random
draws (seed derived from the test's qualified name, so failures reproduce
run-to-run) and re-raises the first failure annotated with the drawn
example.  What it does not do is everything that makes hypothesis worth
installing — shrinking, coverage-guided generation, the example database
— so it is a fallback of last resort, not an alternative.

CI must never land here: the test jobs set ``REPRO_REQUIRE_REAL_HYPOTHESIS
=1``, which turns this import into an immediate error, so a CI image that
silently lost the real dependency fails loudly instead of testing less
(the property suite's acceptance bar is "executes under real hypothesis").
Only the strategy combinators this repo's tests use are implemented;
extending the suite with a new combinator means adding it here too (or,
better, running with hypothesis installed).
"""
from __future__ import annotations

import inspect
import os
import warnings
import zlib

import numpy as np

if os.environ.get("REPRO_REQUIRE_REAL_HYPOTHESIS"):
    raise ModuleNotFoundError(
        "hypothesis is required here (REPRO_REQUIRE_REAL_HYPOTHESIS is "
        "set): pip install -r requirements-dev.txt — the bundled sampler "
        "fallback is disabled")

warnings.warn(
    "property tests are executing under the bundled sampler "
    "(tests/hypothesis_stub.py) — install hypothesis for shrinking and "
    "smarter generation",
    stacklevel=2)

_DEFAULT_EXAMPLES = 25


class _Strategy:
    """A draw rule; ``example(rng)`` produces one value."""

    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: np.random.Generator):
        return self._draw(rng)


class _DataSentinel:
    """Marker returned by ``st.data()``."""


class _DataObject:
    """Interactive draw handle passed for ``st.data()`` parameters."""

    def __init__(self, rng: np.random.Generator):
        self._rng = rng
        self.drawn: list = []

    def draw(self, strategy: _Strategy, label: str | None = None):
        value = strategy.example(self._rng)
        self.drawn.append(value if label is None else (label, value))
        return value


class st:
    """The strategy combinators used by this repo's test suite."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value: float, max_value: float, allow_nan: bool = False,
               allow_infinity: bool = False, width: int = 64) -> _Strategy:
        def draw(rng):
            r = rng.random()
            if r < 0.05:                    # boundary bias, like hypothesis
                v = float(min_value)
            elif r < 0.10:
                v = float(max_value)
            else:
                v = float(rng.uniform(min_value, max_value))
            return float(np.float32(v)) if width == 32 else v
        return _Strategy(draw)

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        return _Strategy(lambda rng: [
            elements.example(rng)
            for _ in range(int(rng.integers(min_size, max_size + 1)))])

    @staticmethod
    def tuples(*elements: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: tuple(e.example(rng) for e in elements))

    @staticmethod
    def just(value) -> _Strategy:
        return _Strategy(lambda rng: value)

    @staticmethod
    def one_of(*strategies: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: strategies[
            int(rng.integers(len(strategies)))].example(rng))

    @staticmethod
    def data() -> _DataSentinel:
        return _DataSentinel()


def settings(*args, **kwargs):
    """``@settings(max_examples=..., deadline=...)`` — records the options
    for the ``@given`` wrapper underneath it (deadline is ignored)."""
    if args and callable(args[0]):          # bare @settings
        return args[0]

    def deco(fn):
        fn._stub_settings = dict(kwargs)
        return fn

    return deco


def given(*args, **strategies):
    """Run the property against ``max_examples`` seeded random draws."""
    if args:
        raise TypeError("the bundled sampler supports keyword strategies "
                        "only — use @given(name=st....)")

    def deco(fn):
        seed0 = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())

        def wrapper(*a, **kw):
            # @settings may sit above @given (lands on wrapper) or below
            # it (lands on fn) — real hypothesis accepts both orders
            opts = (getattr(wrapper, "_stub_settings", None)
                    or getattr(fn, "_stub_settings", {}))
            n = int(opts.get("max_examples", _DEFAULT_EXAMPLES))
            for ex in range(n):
                rng = np.random.default_rng((seed0, ex))
                drawn = {}
                for name, s in strategies.items():
                    drawn[name] = (_DataObject(rng)
                                   if isinstance(s, _DataSentinel)
                                   else s.example(rng))
                try:
                    fn(*a, **kw, **drawn)
                except Exception as e:  # noqa: BLE001 — annotate + re-raise
                    shown = {k: (v.drawn if isinstance(v, _DataObject) else v)
                             for k, v in drawn.items()}
                    raise AssertionError(
                        f"property falsified on example {ex + 1}/{n}: "
                        f"{shown!r}") from e

        # hide the strategy parameters from pytest's fixture resolution
        # while keeping any real fixture parameters visible
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strategies])
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco
