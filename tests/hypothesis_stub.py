"""Fallback for environments without ``hypothesis`` installed.

Test modules import this when ``from hypothesis import ...`` fails, so
only the property-based tests skip — the rest of the module still runs:

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from hypothesis_stub import given, settings, st

``given`` replaces the test with an argument-less skip stub (no fixture
resolution is attempted on the hypothesis strategy parameters);
``settings`` is a pass-through; ``st`` swallows any strategy expression
evaluated at decoration time.
"""
from __future__ import annotations

import pytest

_REASON = "hypothesis is not installed (pip install -r requirements-dev.txt)"


class _AnyStrategy:
    """Accepts any ``st.<strategy>(...)`` chain used at decoration time."""

    def __getattr__(self, name):
        return lambda *a, **k: self

    def __call__(self, *a, **k):
        return self


st = _AnyStrategy()


def settings(*args, **kwargs):
    if args and callable(args[0]):          # bare @settings
        return args[0]
    return lambda fn: fn                    # @settings(...)


def given(*args, **kwargs):
    def deco(fn):
        @pytest.mark.skip(reason=_REASON)
        def stub():
            pass

        stub.__name__ = fn.__name__
        stub.__doc__ = fn.__doc__
        return stub

    return deco
