"""OMD-RT correctness: monotone descent (Thm. 4), global optimality vs the
independent Frank–Wolfe solver, KKT conditions (Thm. 3), SGP baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # only the property tests skip; the rest of the module still runs
    from hypothesis_stub import given, settings, st

from repro.core import (frank_wolfe_routing, get_cost, kkt_residual,
                        project_simplex_masked, solve_routing,
                        solve_routing_sgp, sparsify, total_cost)

from conftest import random_phi

LAM = jnp.array([20.0, 20.0, 20.0])


def test_omd_monotone_descent(er25_cec):
    """Theorem 4: with η ≤ c/L_D every OMD step decreases the cost."""
    g = er25_cec
    cost = get_cost("exp")
    _, traj = solve_routing(g, cost, LAM, g.uniform_phi(), 0.2, 150)
    traj = np.asarray(traj)
    assert (np.diff(traj) <= 1e-4).all(), "cost increased along OMD-RT"


def test_omd_monotone_descent_sparse(er25_cec):
    """Theorem 4 holds identically on the edge-list representation."""
    gs = sparsify(er25_cec)
    cost = get_cost("exp")
    _, traj = solve_routing(gs, cost, LAM, gs.uniform_phi(), 0.2, 150)
    traj = np.asarray(traj)
    assert (np.diff(traj) <= 1e-4).all(), "cost increased along sparse OMD-RT"
    assert float(kkt_residual(gs, cost,
                              solve_routing(gs, cost, LAM, gs.uniform_phi(),
                                            5.0, 800)[0], LAM)) < 0.02


def test_dynamic_regret_shrinks_with_step_budget():
    """DESIGN §8 exercised: OMAD/GS-OMA dynamic regret is sublinear, so the
    per-iteration regret against the genie optimum shrinks as the step
    budget grows (the convexity claim, measured rather than asserted in
    prose)."""
    from repro.core import run_scenario, scenario_metrics, segment_optima
    from repro.core.scenario import Scenario

    def make(T):
        return Scenario("steady", horizon=T, topology="connected_er",
                        topo_kwargs={"n": 12, "p": 0.35}, n_sessions=3,
                        mean_capacity=10.0, bank_kind="log", lam_total=45.0)

    budgets = (8, 24, 72)
    opt = segment_optima(make(budgets[0]), (0,), outer_iters=80,
                         inner_iters=40)          # horizon-independent genie
    per_step = []
    for T in budgets:
        res = run_scenario(make(T), seeds=(0,), method="nested",
                           inner_iters=4, eta_inner=3.0)
        m = scenario_metrics(res, opt_utilities=opt)
        per_step.append(m["dynamic_regret"] / T)
    assert per_step[1] < 0.75 * per_step[0], per_step
    assert per_step[2] < 0.75 * per_step[1], per_step


def test_omd_reaches_frank_wolfe_optimum(er25_cec):
    g = er25_cec
    cost = get_cost("exp")
    phi, _ = solve_routing(g, cost, LAM, g.uniform_phi(), 3.0, 400)
    d_omd = float(total_cost(g, cost, phi, LAM))
    _, d_fw = frank_wolfe_routing(g, cost, LAM, n_iters=300)
    assert abs(d_omd - d_fw) / d_fw < 5e-3, (d_omd, d_fw)


def test_omd_kkt_conditions(er25_cec):
    """Thm. 3: equal marginal costs on the support at φ*."""
    g = er25_cec
    cost = get_cost("exp")
    phi, _ = solve_routing(g, cost, LAM, g.uniform_phi(), 5.0, 800)
    assert float(kkt_residual(g, cost, phi, LAM)) < 0.02


def test_sgp_converges_same_optimum(er25_cec):
    g = er25_cec
    cost = get_cost("exp")
    phi_o, _ = solve_routing(g, cost, LAM, g.uniform_phi(), 3.0, 400)
    phi_s, _ = solve_routing_sgp(g, cost, LAM, g.uniform_phi(), 0.5, 400)
    d_o = float(total_cost(g, cost, phi_o, LAM))
    d_s = float(total_cost(g, cost, phi_s, LAM))
    assert abs(d_o - d_s) / d_o < 1e-2


def test_omd_faster_than_sgp_early(er25_cec):
    """The paper's headline: OMD-RT leads SGP in the first iterations."""
    g = er25_cec
    cost = get_cost("exp")
    _, tr_o = solve_routing(g, cost, LAM, g.uniform_phi(), 3.0, 10)
    _, tr_s = solve_routing_sgp(g, cost, LAM, g.uniform_phi(), 0.5, 10)
    assert float(tr_o[-1]) <= float(tr_s[-1]) + 1e-3


def test_rows_remain_stochastic(er25_cec):
    g = er25_cec
    cost = get_cost("exp")
    phi, _ = solve_routing(g, cost, LAM, g.uniform_phi(), 3.0, 50)
    rows = np.asarray(phi).sum(-1)
    has_out = np.asarray(g.out_mask).sum(-1) > 0
    np.testing.assert_allclose(rows[has_out], 1.0, atol=1e-5)
    assert (np.asarray(phi) >= 0).all()
    assert (np.asarray(phi)[np.asarray(g.out_mask) == 0] == 0).all()


# ---------------------------------------------------------------------------
# masked simplex projection (the SGP per-node QP)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(data=st.data(), d=st.integers(2, 12))
def test_simplex_projection_feasible(data, d):
    y = np.array(data.draw(st.lists(
        st.floats(-5, 5, allow_nan=False, width=32), min_size=d, max_size=d)),
        np.float32)
    mask = np.array(data.draw(st.lists(st.booleans(), min_size=d, max_size=d)),
                    np.float32)
    if mask.sum() == 0:
        mask[0] = 1.0
    v = np.asarray(project_simplex_masked(jnp.asarray(y)[None],
                                          jnp.asarray(mask)[None]))[0]
    assert (v >= -1e-6).all()
    assert abs(v.sum() - 1.0) < 1e-4
    assert (v[mask == 0] == 0).all()


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_simplex_projection_is_closest_point(seed):
    """Projection beats random feasible points in Euclidean distance."""
    rng = np.random.default_rng(seed)
    d = 8
    y = rng.normal(size=d).astype(np.float32) * 3
    mask = (rng.random(d) > 0.3).astype(np.float32)
    if mask.sum() == 0:
        mask[:] = 1.0
    v = np.asarray(project_simplex_masked(jnp.asarray(y)[None],
                                          jnp.asarray(mask)[None]))[0]
    dv = ((v - y) ** 2).sum()
    for _ in range(64):
        z = rng.random(d) * mask
        z = z / z.sum()
        assert dv <= ((z - y) ** 2).sum() + 1e-4
