"""Traffic-generator contracts (ISSUE 7): determinism, calibration,
periodicity, spike mass, and event/trace composition (DESIGN.md §15.4)."""
import numpy as np
import pytest

from repro.serve.traffic import (TrafficTrace, diurnal_trace,
                                 flash_crowd_trace, named_traces,
                                 poisson_trace, scenario_base_demand)


def test_fixed_seed_determinism():
    a = poisson_trace(50, 4, seed=7)
    b = poisson_trace(50, 4, seed=7)
    np.testing.assert_array_equal(a.factors, b.factors)
    c = poisson_trace(50, 4, seed=8)
    assert (a.factors != c.factors).any()
    # the deterministic generators are trivially reproducible too
    np.testing.assert_array_equal(diurnal_trace(50, 4).factors,
                                  diurnal_trace(50, 4).factors)


def test_poisson_mean_rate_within_clt_tolerance():
    """Factors are Poisson(r)/r: mean 1, sd 1/sqrt(r) per sample.  Over
    T·K samples the sample mean lands within 5 sigma of 1."""
    r = 400.0
    tr = poisson_trace(200, 8, seed=0, requests_per_interval=r)
    n = tr.factors.size
    tol = 5.0 / np.sqrt(r * n)
    assert abs(tr.factors.mean() - 1.0) < tol
    # per-sample fluctuation is calibrated too (generous 3-sigma-ish band)
    assert 0.8 / np.sqrt(r) < tr.factors.std() < 1.2 / np.sqrt(r)


def test_diurnal_periodicity_and_mean():
    period = 12
    tr = diurnal_trace(3 * period, 5, period=period, amplitude=0.4)
    np.testing.assert_allclose(tr.factors[:period], tr.factors[period:2 * period],
                               atol=1e-6)
    np.testing.assert_allclose(tr.factors[:period].mean(0), 1.0, atol=1e-6)
    # phase stagger: aggregate demand is flatter than any single tenant
    agg = tr.factors.mean(1)
    assert agg.std() < tr.factors[:, 0].std() * 0.5
    assert (tr.factors > 0).all()


def test_flash_crowd_spike_mass():
    mag, width = 3.0, 8
    tr = flash_crowd_trace(64, 3, at=20, magnitude=mag, width=width, tenant=1)
    excess = tr.factors - 1.0
    # only the hit tenant spikes; total excess mass is the closed form
    assert (excess[:, [0, 2]] == 0).all()
    np.testing.assert_allclose(excess[:, 1].sum(),
                               (mag - 1.0) * (width + 1) / 2, rtol=1e-6)
    assert tr.factors[20, 1] == pytest.approx(mag)
    assert (tr.factors[20 + width:, 1] == 1.0).all()
    # correlated variant hits every tenant identically
    allhit = flash_crowd_trace(64, 3, at=20, magnitude=mag, width=width,
                               tenant=None)
    np.testing.assert_array_equal(allhit.factors[:, 0], allhit.factors[:, 2])


def test_named_traces_cover_the_suite():
    traces = named_traces(40, 3, seed=1)
    assert set(traces) == {"poisson", "diurnal", "flash_crowd"}
    for tr in traces.values():
        assert tr.factors.shape == (40, 3)


def test_trace_validation():
    with pytest.raises(ValueError):
        TrafficTrace("bad", np.ones(5))            # not [T, K]
    with pytest.raises(ValueError):
        TrafficTrace("bad", -np.ones((5, 2)))      # negative intensity
    with pytest.raises(ValueError):
        flash_crowd_trace(10, 2, at=10)            # spike outside horizon


def test_scenario_events_and_trace_compose_without_double_counting():
    """Effective demand = event-driven base × trace factor.  A DemandShift
    steps the base exactly once; the trace never re-applies it."""
    from repro.core.scenario import DemandShift, Scenario

    sc = Scenario("surge", horizon=20,
                  events=(DemandShift(at=10, lam_total=90.0),),
                  lam_total=60.0)
    base = scenario_base_demand(sc)
    assert base.shape == (20,)
    assert (base[:10] == 60.0).all() and (base[10:] == 90.0).all()

    tr = diurnal_trace(20, 3, period=10, amplitude=0.3)
    demand = tr.demand(base)
    assert demand.shape == (20, 3)
    # the product form exactly: no hidden rescaling on either side
    np.testing.assert_allclose(demand, base[:, None] * tr.factors, rtol=1e-7)
    # the step is the ratio of the bases wherever the trace repeats:
    # period 10 makes factors[t] == factors[t+10], so the demand ratio
    # across the event is exactly 90/60 — applied once, not squared
    np.testing.assert_allclose(demand[10:] / demand[:10], 90.0 / 60.0,
                               rtol=1e-6)


def test_demand_broadcast_shapes():
    tr = diurnal_trace(6, 3, period=3)
    assert tr.demand(60.0).shape == (6, 3)
    np.testing.assert_allclose(tr.demand([10.0, 20.0, 30.0])[:, 2],
                               30.0 * tr.factors[:, 2], rtol=1e-7)
    assert tr.demand(np.full(6, 5.0)).shape == (6, 3)
