"""Implicit fixed-point layer: VJP correctness and learned-mode parity.

The contracts of DESIGN.md §16.1–16.2:

* ``fixed_point_solve``'s implicit-function-theorem VJP matches central
  finite differences to ≤1e-4 through the full oracle (``oracle_observe``
  → ``solve_routing_implicit``), dense AND sparse.  The comparison runs
  in float64 (``jax.experimental.enable_x64``): in f32 the FD reference
  itself carries ~1e-3 of roundoff, which would swamp the bar.
* the layer composes with jit and vmap (the learned solver path wraps it
  in both).
* ``grad_mode="learned"`` with an *exact* surrogate reproduces the
  sampled controller's converged utility to ≤1e-3, and a *fitted*
  surrogate stays within the same bar once its holdout error is small —
  the golden migration check.
* at the oracle fixed point the implicit gradient equals the
  envelope-theorem genie gradient ``core.allocation.
  exact_allocation_gradient`` (Theorem 1's marginal form).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (build_random_cec, get_cost, make_bank,
                        paper_defaults, sparsify, total_cost)
from repro.core import solver as _solver
from repro.core.implicit import fixed_point_solve
from repro.core.problem import Problem
from repro.core.routing import oracle_observe, solve_routing_implicit
from repro.topo import connected_er

ETA = 0.2
FD_ETA = 0.5           # hotter OMD step for the FD check (fast contraction)
FD_WARM = 6000         # warm-start depth: φ0 ≈ φ*, so the N-step implicit
FD_ITERS = 800         # solve is *at* the fixed point the IFT assumes


def _graph():
    return build_random_cec(connected_er(10, 0.4, seed=2), 3, 10.0, seed=0)


def _f64(tree):
    def cast(x):
        x = jnp.asarray(x)
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(jnp.float64)
        return x

    return jax.tree_util.tree_map(cast, tree)


def _fd_vs_implicit(graph, phi_init):
    """max |implicit grad − central FD| of Λ ↦ D(Λ, φ*(Λ)).

    The IFT VJP is exact only *at* the fixed point, so φ0 is first
    warm-started to convergence (a λ-independent constant — FD and the
    implicit gradient differentiate the same map either way); without
    the warm start the FD reference measures the truncated iteration's
    gradient instead, and the two differ by the forward truncation.
    """
    from repro.core.routing import solve_routing

    cost = get_cost("exp")
    W = graph.n_sessions
    lam = jnp.full((W,), 4.0, jnp.float64)
    phi0, _ = solve_routing(graph, cost, lam, phi_init, FD_ETA, FD_WARM)

    def D(lam):
        phi = solve_routing_implicit(graph, cost, lam, phi0, FD_ETA,
                                     FD_ITERS, bwd_iters=FD_ITERS)
        return total_cost(graph, cost, phi, lam)

    g = jax.grad(D)(lam)
    eps = 1e-4
    fd = np.zeros(W)
    for w in range(W):
        e = jnp.zeros(W, jnp.float64).at[w].set(eps)
        fd[w] = (float(D(lam + e)) - float(D(lam - e))) / (2 * eps)
    return float(jnp.max(jnp.abs(g - np.asarray(fd))))


def test_implicit_vjp_matches_fd_dense():
    with jax.experimental.enable_x64():
        g = _f64(_graph())
        err = _fd_vs_implicit(g, _f64(g.uniform_phi()))
    assert err <= 1e-4, err


def test_implicit_vjp_matches_fd_sparse():
    with jax.experimental.enable_x64():
        gs = _f64(sparsify(_graph()))
        err = _fd_vs_implicit(gs, _f64(gs.uniform_phi()))
    assert err <= 1e-4, err


def test_fixed_point_forward_matches_plain_scan():
    """The implicit layer's forward is the same scan ``solve_routing``
    runs — bit-identical φ* (the golden-trace guarantee)."""
    from repro.core.routing import solve_routing

    g = _graph()
    cost = get_cost("exp")
    lam = jnp.full((g.n_sessions,), 4.0, jnp.float32)
    phi_ref, _ = solve_routing(g, cost, lam, g.uniform_phi(), ETA, 60)
    phi_imp = solve_routing_implicit(g, cost, lam, g.uniform_phi(), ETA, 60)
    np.testing.assert_array_equal(np.asarray(phi_ref), np.asarray(phi_imp))


def test_implicit_jit_and_vmap_compose():
    g = _graph()
    cost = get_cost("exp")
    W = g.n_sessions
    phi0 = g.uniform_phi()

    def D(lam):
        phi, d = oracle_observe(g, cost, lam, phi0, ETA, 80)
        return d

    lam = jnp.full((W,), 4.0, jnp.float32)
    g_eager = jax.grad(D)(lam)
    g_jit = jax.jit(jax.grad(D))(lam)
    np.testing.assert_allclose(np.asarray(g_eager), np.asarray(g_jit),
                               rtol=1e-5, atol=1e-6)
    lams = jnp.stack([lam, lam * 1.2, lam * 0.8])
    g_vmap = jax.vmap(jax.grad(D))(lams)
    assert g_vmap.shape == (3, W)
    assert bool(jnp.isfinite(g_vmap).all())
    np.testing.assert_allclose(np.asarray(g_vmap[0]), np.asarray(g_eager),
                               rtol=1e-4, atol=1e-5)


def test_fixed_point_solve_simple_contraction():
    """Sanity on a closed-form fixed point: x* = a/(1−c) for
    x ← c·x + a, with dx*/da = 1/(1−c) exactly."""
    c = 0.5

    def f(x, a):
        return c * x + a

    def xstar(a):
        return fixed_point_solve(f, jnp.float32(0.0), a, n_iters=60)

    a = jnp.float32(1.5)
    np.testing.assert_allclose(float(xstar(a)), 3.0, rtol=1e-5)
    np.testing.assert_allclose(float(jax.grad(xstar)(a)), 2.0, rtol=1e-4)


def test_learned_gradient_matches_envelope_at_fixed_point(small_cec):
    """∇_Λ[Σu − D(Λ, φ*(Λ))] from the implicit layer equals the
    envelope/Theorem-1 genie gradient at the oracle fixed point."""
    from repro.core.allocation import exact_allocation_gradient
    from repro.core.routing import solve_routing

    g = small_cec
    cost = get_cost("exp")
    W = g.n_sessions
    bank = make_bank("log", W, seed=0)
    lam = jnp.full((W,), 5.0, jnp.float32)
    phi_star, _ = solve_routing(g, cost, lam, g.uniform_phi(), ETA, 1500)

    def net_u(lam):
        phi, d = oracle_observe(g, cost, lam, phi_star, ETA, 400)
        return bank.total(lam) - d

    g_imp = jax.grad(net_u)(lam)
    phi_end = solve_routing_implicit(g, cost, lam, phi_star, ETA, 400)
    g_env = exact_allocation_gradient(g, cost, bank, lam, phi_end)
    np.testing.assert_allclose(np.asarray(g_imp), np.asarray(g_env),
                               rtol=2e-3, atol=2e-3)


def _run_modes(problem_sampled, problem_learned, iters=40):
    cfg_s = paper_defaults().replace(inner_iters=20)
    cfg_l = cfg_s.replace(grad_mode="learned")
    res_s = _solver.run(problem_sampled, cfg_s, iters=iters)
    res_l = _solver.run(problem_learned, cfg_l, iters=iters)
    return res_s, res_l


def test_learned_with_exact_surrogate_reproduces_sampled(small_cec):
    """grad_mode="learned" with the true bank as surrogate converges to
    the sampled controller's utility (≤1e-3 relative) — the analytic
    gradient path is the same optimization, minus the perturbation
    sweep."""
    bank = make_bank("log", small_cec.n_sessions, seed=0)
    prob = Problem.create(small_cec, bank, lam_total=20.0)
    res_s, res_l = _run_modes(prob, prob)
    u_s, u_l = float(res_s.utility_traj[-1]), float(res_l.utility_traj[-1])
    assert abs(u_l - u_s) / abs(u_s) <= 1e-3, (u_s, u_l)


def test_learned_with_fitted_surrogate_golden(small_cec):
    """The golden migration check: a log-family surrogate fitted to
    box-sampled bank observations drives the learned controller to the
    sampled controller's converged utility (≤1e-3 relative)."""
    from repro.core.utility import fit_utilities, get_family

    W = small_cec.n_sessions
    bank = make_bank("log", W, seed=0)
    fam = get_family("log")
    rng = np.random.default_rng(0)
    lams = jnp.asarray(rng.uniform(0.3, 19.0, size=(256, W)), jnp.float32)
    utils = jax.vmap(bank.total)(lams)
    params = fam.init_params(W, seed=0)
    for _ in range(3):
        params, _ = fit_utilities(fam, params, lams, utils,
                                  steps=2000, lr=0.1)
    prob_s = Problem.create(small_cec, bank, lam_total=20.0)
    prob_l = prob_s.with_utilities("log", params)
    res_s, res_l = _run_modes(prob_s, prob_l)
    u_s = float(res_s.utility_traj[-1])
    # price the learned trajectory's final Λ with the TRUE bank — the
    # surrogate must land the controller at the same operating point
    from repro.core.flow import total_cost as _tc

    cost = get_cost("exp")
    u_l = float(bank.total(res_l.lam)
                - _tc(small_cec, cost, res_l.phi, res_l.lam))
    assert abs(u_l - u_s) / abs(u_s) <= 1e-3, (u_s, u_l)


def test_learned_mode_without_surrogate_or_bank_errors(small_cec):
    cfg = paper_defaults().replace(grad_mode="learned")
    prob = Problem.create(small_cec, None, lam_total=20.0)
    with pytest.raises(ValueError, match="learned"):
        _solver.run(prob, cfg, iters=2)
