"""Augmented-graph invariants: DAG/loop-freedom, per-session masks,
feasibility, topology generators."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # only the property tests skip; the rest of the module still runs
    from hypothesis_stub import given, settings, st

from repro.core import build_random_cec
from repro.core.graph import InfeasibleTopology, build_augmented, random_deployment
from repro.topo import (abilene, balanced_tree, connected_er, fog, geant,
                        make_topology)


def _is_dag(edge_mask: np.ndarray) -> bool:
    n = edge_mask.shape[0]
    indeg = (edge_mask > 0).sum(0)
    stack = [i for i in range(n) if indeg[i] == 0]
    seen = 0
    while stack:
        i = stack.pop()
        seen += 1
        for j in np.nonzero(edge_mask[i])[0]:
            indeg[j] -= 1
            if indeg[j] == 0:
                stack.append(int(j))
    return seen == n


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(8, 40),
       w=st.integers(2, 5))
def test_augmented_graph_invariants(seed, n, w):
    g = build_random_cec(connected_er(n, 0.3, seed=seed), w, 10.0, seed=seed)
    out = np.asarray(g.out_mask)
    edge = np.asarray(g.edge_mask)
    deploy = np.asarray(g.deploy)
    sinks = np.asarray(g.sinks)

    # structural loop-freedom: ANY routing in the mask is cycle-free
    assert _is_dag(edge)
    # every session admits traffic at S
    assert (out[:, g.src].sum(-1) > 0).all()
    # deploying nodes forward their session only to the virtual sink
    for ww in range(w):
        rows = np.nonzero(deploy[ww])[0]
        assert (out[ww, rows].sum(-1) == 1).all()
        assert (out[ww, rows, sinks[ww]] == 1).all()
    # sinks have no out-edges
    assert (out[:, sinks].sum(-1) == 0).all()
    # every edge head with session-w in-flow potential has out-capacity for w
    for ww in range(w):
        recv = out[ww].sum(0) > 0            # nodes that can receive w
        phys = recv[: g.n_phys]
        can_fwd = out[ww, : g.n_phys].sum(-1) > 0
        assert (~phys | can_fwd).all(), "received traffic must be forwardable"


def test_each_version_must_be_deployed():
    adj = connected_er(10, 0.4, seed=0)
    deploy = np.zeros((3, 10), bool)
    deploy[0, :5] = True
    deploy[1, 5:] = True        # version 2 missing
    with pytest.raises(InfeasibleTopology):
        build_augmented(adj, deploy, np.ones((10, 10)), np.ones(10))


def test_random_deployment_covers_all_versions():
    rng = np.random.default_rng(0)
    for _ in range(20):
        d = random_deployment(12, 4, rng)
        assert (d.sum(0) == 1).all()
        assert (d.sum(1) >= 1).all()


@pytest.mark.parametrize("name,n,degmin", [
    ("abilene", 11, 1), ("balanced_tree", 14, 1), ("fog", 15, 2),
    ("geant", 22, 2), ("connected_er", 25, 1),
])
def test_topology_generators(name, n, degmin):
    adj, cbar = make_topology(name)
    assert adj.shape[0] == n
    assert (adj == adj.T).all()
    assert not adj.diagonal().any()
    assert (adj.sum(0) >= degmin).all()
    assert cbar > 0


def test_paper_table2_shapes():
    """Paper Table II node counts."""
    assert abilene().shape[0] == 11
    assert balanced_tree().shape[0] == 14
    assert fog().shape[0] == 15
    assert geant().shape[0] == 22
    # Abilene has exactly 14 physical links
    assert abilene().sum() // 2 == 14


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_uniform_phi_is_feasible(seed):
    g = build_random_cec(connected_er(12, 0.35, seed=seed), 3, 10.0,
                         seed=seed)
    phi = np.asarray(g.uniform_phi())
    rows = phi.sum(-1)
    has_out = np.asarray(g.out_mask).sum(-1) > 0
    np.testing.assert_allclose(rows[has_out], 1.0, atol=1e-6)
    assert (phi[np.asarray(g.out_mask) == 0] == 0).all()
