"""Per-arch smoke tests (deliverable f) + KV-cache/state parity checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": toks}
    if cfg.enc_dec:
        # audio frontend stub: precomputed frame embeddings feed the encoder
        enc = rng.normal(size=(B, cfg.enc_seq, cfg.d_model)) * 0.02
        batch["enc_embeds"] = jnp.asarray(enc, cfg.dtype)
    elif cfg.frontend:
        # vision frontend stub: precomputed patch embeddings replace tokens
        emb = rng.normal(size=(B, S, cfg.d_model)) * 0.02
        batch = {"embeds": jnp.asarray(emb, cfg.dtype), "labels": toks}
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    """Reduced config: one forward/train step, shape + finiteness asserts."""
    cfg = get_config(arch, smoke=True)
    params = M.init(cfg, KEY)
    batch = make_batch(cfg)
    loss, metrics = M.train_loss(cfg, params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: NaN loss"
    logits, aux = M.forward(cfg, params, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # gradients flow and are finite
    g = jax.grad(lambda p: M.train_loss(cfg, p, batch)[0])(params)
    leaves = jax.tree_util.tree_leaves(g)
    assert all(np.isfinite(np.asarray(l, np.float32)).all() for l in leaves)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_prefill_decode(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init(cfg, KEY)
    batch = make_batch(cfg)
    logits, cache = M.prefill(cfg, params, batch, max_len=24)
    assert logits.shape == (2, cfg.vocab)
    for _ in range(3):
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        logits, cache = M.decode_step(cfg, params, tok, cache)
        assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    assert (np.asarray(cache["len"]) == 19).all()


@pytest.mark.parametrize("arch", ["deepseek-coder-33b", "jamba-1.5-large-398b",
                                  "xlstm-1.3b", "whisper-large-v3",
                                  "qwen2-vl-72b"])
def test_decode_matches_forward(arch):
    """Autoregressive cache path must reproduce the parallel forward pass.

    Covers every mixer kind: attn KV cache, mamba SSM+conv state,
    mLSTM/sLSTM recurrent state, cross-attention cache, M-RoPE offsets.
    """
    cfg = dataclasses.replace(get_config(arch, smoke=True), dtype="float32")
    if cfg.moe is not None:
        # capacity drops are batch-size dependent by design; parity needs a
        # drop-free capacity so the cache path sees identical expert outputs
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = M.init(cfg, KEY)
    B, S = 2, 12
    batch = make_batch(cfg, B, S, seed=1)

    full_logits, _ = M.forward(cfg, params, batch)        # [B,S,V]

    # prefill on the first S0 tokens, then decode the rest one by one
    S0 = 7
    pre = {k: (v[:, :S0] if k in ("tokens", "embeds", "labels") else v)
           for k, v in batch.items()}
    logits, cache = M.prefill(cfg, params, pre, max_len=S)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits[:, S0 - 1]),
                               rtol=2e-3, atol=2e-3)
    if cfg.frontend and not cfg.enc_dec:
        return  # decode continues from tokens; prefix was raw embeds
    for s in range(S0, S):
        tok = batch["tokens"][:, s:s + 1]
        logits, cache = M.decode_step(cfg, params, tok, cache)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, s]),
            rtol=2e-3, atol=2e-3,
            err_msg=f"{arch} step {s}")


def test_moe_dispatch_capacity_drops_are_bounded():
    """With capacity_factor ≥ 1 and balanced tokens, few drops occur and
    the output stays close to a dense-evaluation oracle."""
    cfg = get_config("qwen2-moe-a2.7b", smoke=True)
    cfg = dataclasses.replace(cfg, dtype="float32")
    from repro.models import layers as L
    moe = cfg.moe
    params = L.moe_init(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32) * 0.5
    y, aux = L.moe_apply(cfg, params, x)
    assert np.isfinite(np.asarray(y)).all()
    assert y.shape == x.shape
    # dense oracle: evaluate every expert on every token, combine by gates
    T = 64
    xt = x.reshape(T, cfg.d_model)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    gval, gidx = jax.lax.top_k(probs, moe.top_k)
    gval = gval / gval.sum(-1, keepdims=True)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, params["wi"]))
    h = h * jnp.einsum("td,edf->tef", xt, params["wg"])
    ye = jnp.einsum("tef,efd->ted", h, params["wo"])
    dense = jnp.zeros_like(xt)
    for k in range(moe.top_k):
        dense = dense + gval[:, k, None] * jnp.take_along_axis(
            ye, gidx[:, k, None, None].repeat(cfg.d_model, -1), 1)[:, 0]
    dense = dense + L.mlp_apply(cfg, params["shared"], xt)
    # capacity drops make this approximate; demand 95% token agreement
    err = np.linalg.norm(np.asarray(y.reshape(T, -1) - dense), axis=-1)
    scale = np.linalg.norm(np.asarray(dense), axis=-1) + 1e-6
    assert (err / scale < 1e-3).mean() > 0.9


def test_param_count_analytics():
    """approx_params matches the published sizes within tolerance."""
    expect = {"deepseek-coder-33b": 33e9, "smollm-135m": 135e6,
              "jamba-1.5-large-398b": 398e9, "qwen2-moe-a2.7b": 14.3e9,
              "xlstm-1.3b": 1.3e9, "qwen2-vl-72b": 72e9}
    for arch, n in expect.items():
        got = get_config(arch).approx_params()
        assert abs(got - n) / n < 0.25, (arch, got, n)


def test_long_context_applicability():
    from repro.configs import applicable
    ok, _ = applicable(get_config("jamba-1.5-large-398b"), "long_500k")
    assert ok
    ok, _ = applicable(get_config("xlstm-1.3b"), "long_500k")
    assert ok
    for arch in ("deepseek-coder-33b", "qwen2-vl-72b", "whisper-large-v3"):
        ok, why = applicable(get_config(arch), "long_500k")
        assert not ok and why
