import os

# Tests must see the single real CPU device (the 512-device fake platform is
# reserved for launch/dryrun.py, which sets XLA_FLAGS before importing jax).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(autouse=True, scope="module")
def _bound_compiled_executable_state():
    """Drop compiled executables at every test-module boundary.

    A single -x -q run of the whole suite keeps every jitted executable
    of every module alive in one process; past ~320 tests the
    accumulated compiler state makes jaxlib's CPU backend_compile
    segfault deterministically on the next large scan (observed on
    jaxlib 0.4.36 — the faulting test is innocent and passes in any
    shorter run).  Modules never share compiled artifacts on purpose
    (cross-module caches are keyed on configs rebuilt per module), so
    clearing between modules only costs recompiles, not correctness.
    """
    yield
    import jax

    jax.clear_caches()


@pytest.fixture(scope="session")
def small_cec():
    """A small feasible CEC instance shared across core tests."""
    from repro.core import build_random_cec
    from repro.topo import connected_er

    adj = connected_er(15, 0.3, seed=3)
    return build_random_cec(adj, 3, 10.0, seed=0)


@pytest.fixture(scope="session")
def er25_cec():
    """The paper's main Connected-ER(25, 0.2) instance."""
    from repro.core import build_random_cec
    from repro.topo import connected_er

    adj = connected_er(25, 0.2, seed=1)
    return build_random_cec(adj, 3, 10.0, seed=0)


def random_phi(graph, seed=0):
    """A random feasible routing configuration (row-stochastic on mask)."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    raw = rng.uniform(0.1, 1.0, size=graph.out_mask.shape).astype(np.float32)
    raw = raw * np.asarray(graph.out_mask)
    s = raw.sum(-1, keepdims=True)
    return jnp.asarray(np.where(s > 0, raw / np.where(s > 0, s, 1.0), 0.0))
