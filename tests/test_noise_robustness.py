"""Online robustness: the router converges under noisy utility feedback
(the paper's practical setting — measured QoE/latency is stochastic)."""
import numpy as np

from repro.core import build_random_cec
from repro.serve import CECRouter
from repro.topo import connected_er


def test_router_converges_with_noisy_observations():
    g = build_random_cec(connected_er(12, 0.3, seed=4), 3, 20.0, seed=0)
    rng = np.random.default_rng(0)
    quality = np.array([1.0, 1.5, 2.0])

    def noisy_utility(lam):
        clean = float((quality * np.log1p(lam)).sum()) * 10.0
        return clean + rng.normal(0.0, 0.5)          # ~5% observation noise

    router = CECRouter(g, lam_total=15.0, eta_outer=0.03)
    for _ in range(25):
        router.control_step(noisy_utility)
    lam = np.asarray(router.lam)
    # monotone quality ladder → allocation should be ordered despite noise
    assert lam[2] > lam[0], lam
    np.testing.assert_allclose(lam.sum(), 15.0, rtol=1e-3)
    # trailing iterates stay in a tight band (no noise-driven divergence)
    tail = np.stack([h["lam"] for h in router.history[-10:]])
    assert tail.std(0).max() < 0.5
