"""Hypergradient step-size tuning (DESIGN.md §16.3).

``tune_etas`` must *improve* deliberately detuned (η_outer, η_inner) by
ascending the rollout-tail utility through the implicit layer, return a
drop-in ``SolverConfig``, and refuse the Pallas kernel path (where η is
baked static).  These tests pin behaviour, not specific tuned values —
the meta-objective is nonconvex and the gradient is truncated (module
docstring of ``core/hypergrad.py``), so the contract is "better than the
detuned start", not "finds the global optimum".
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (build_random_cec, make_bank, paper_defaults,
                        serving_defaults, tune_etas)
from repro.core import dispatch, solver as _solver
from repro.core.hypergrad import rollout_objective
from repro.core.problem import Problem
from repro.topo import connected_er


@pytest.fixture(scope="module")
def problem():
    g = build_random_cec(connected_er(12, 0.35, seed=3), 3, 10.0, seed=0)
    bank = make_bank("log", g.n_sessions, seed=0)
    return Problem.create(g, bank, lam_total=15.0)


def test_tune_improves_detuned_steps(problem):
    detuned = paper_defaults().replace(eta_outer=0.002, eta_inner=0.05,
                                       inner_iters=5)
    res = tune_etas(problem, detuned, meta_iters=8, rollout_iters=8, tail=3)
    assert res.objective.shape == (9,)
    assert res.etas.shape == (9, 2)
    # the returned pair is the argmax of what was actually measured...
    best = int(np.argmax(res.objective))
    np.testing.assert_allclose(res.etas[best],
                               [res.eta_outer, res.eta_inner], rtol=1e-6)
    # ...and beats the detuned start by a real margin
    assert res.objective[best] > res.objective[0] + 0.1, res.objective
    assert res.eta_outer > detuned.eta_outer
    # the result is a drop-in config
    assert res.config.eta_outer == res.eta_outer
    out = _solver.run(problem, res.config, iters=10)
    assert bool(jnp.isfinite(out.utility_traj).all())


def test_rollout_objective_requires_bank(problem):
    import dataclasses

    bankless = dataclasses.replace(problem, bank=None)
    cfg = serving_defaults()
    state0 = _solver.init(bankless, cfg)
    with pytest.raises(ValueError, match="bank"):
        rollout_objective(bankless, cfg, state0,
                          jnp.zeros(2), iters=4, tail=2)


def test_step_with_etas_refuses_kernel_dispatch(problem):
    cfg = serving_defaults()
    state = _solver.init(problem, cfg)
    task_u = jnp.zeros((2 * problem.graph.n_sessions,), jnp.float32)
    with dispatch.kernel_dispatch(1):   # force kernels at any size
        with pytest.raises(NotImplementedError, match="kernel"):
            _solver.step_with_etas(problem, cfg, state, task_u,
                                   jnp.float32(0.05), jnp.float32(3.0))


def test_step_with_etas_matches_step_at_config_etas(problem):
    """With η's equal to the config's, the traced-η step is the plain
    sampled step — same committed state, same info."""
    cfg = serving_defaults()
    state = _solver.init(problem, cfg)
    W = problem.graph.n_sessions
    bank = problem.bank
    import jax

    task_u = jax.vmap(bank.total)(
        _solver.perturbed_allocations(state.lam, cfg.delta))
    s_ref, i_ref = _solver.step(problem, cfg, state, task_u)
    s_eta, i_eta = _solver.step_with_etas(
        problem, cfg, state, task_u,
        jnp.float32(cfg.eta_outer), jnp.float32(cfg.eta_inner))
    np.testing.assert_allclose(np.asarray(s_ref.lam), np.asarray(s_eta.lam),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(float(i_ref.cost), float(i_eta.cost),
                               rtol=1e-6)
