"""One-kernel fused control step: parity, precision, compat (DESIGN.md §17).

The megakernel (``kernels/control_megakernel.py``) replaces the whole
``lax.scan``-of-observations control iteration — perturbation sweep,
K-iteration routing oracle, cost evaluation, two-point gradient, mirror
ascent, exact box-simplex projection, committed observation — with one
``pallas_call``.  This suite pins it against the stitched jnp reference
(``solver._sampled_step``) on both layouts, checks the bf16 storage mode
against the committed golden trace within the §17.3 bounds, and proves
the dispatch wiring composes with jit / vmap / shard_map.  Everything
runs in Pallas interpret mode on CPU (``dispatch.kernel_interpret``), so
the fused path is validated wherever CI runs, not just on TPU.
"""
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_random_cec, dispatch
from repro.core import solver as S
from repro.core.graph import sparsify
from repro.core.problem import Problem
from repro.topo import connected_er

_ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(_ROOT) not in sys.path:          # scripts/ is a namespace package
    sys.path.insert(0, str(_ROOT))

PARITY_TOL = 1e-5          # f32 storage vs the stitched jnp reference
LAM_TOTAL = 8.0


def _setup(n=12, n_sessions=3, k_iters=3, seed=3, sparse=False):
    g = build_random_cec(connected_er(n, 0.35, seed=seed), n_sessions,
                         10.0, seed=0)
    if sparse:
        g = sparsify(g)
    problem = Problem.create(g, lam_total=LAM_TOTAL, cost="exp")
    config = S.SolverConfig(method="nested", delta=0.5, eta_outer=0.05,
                            eta_inner=0.05, inner_iters=k_iters,
                            grad_mode="sampled")
    state = S.init(problem, config)
    tau = jnp.asarray(
        np.random.default_rng(0).uniform(1.0, 5.0, 2 * g.n_sessions),
        jnp.float32)
    return problem, config, state, tau


def _ref_and_mega(problem, config, state, tau):
    ref = S.step(problem, config, state, tau)
    with dispatch.megakernel_dispatch(1):
        mega = S.step(problem, config, state, tau)
    return ref, mega


# ---------------------------------------------------------------------------
# f32 parity vs the stitched reference — dense and sparse layouts
# ---------------------------------------------------------------------------

def test_dense_parity_f32():
    problem, config, state, tau = _setup()
    (rs, ri), (ms, mi) = _ref_and_mega(problem, config, state, tau)
    np.testing.assert_allclose(ms.lam, rs.lam, atol=PARITY_TOL)
    np.testing.assert_allclose(ms.phi, rs.phi, atol=PARITY_TOL)
    np.testing.assert_allclose(mi.grad, ri.grad, atol=PARITY_TOL)
    np.testing.assert_allclose(float(mi.cost), float(ri.cost),
                               rtol=PARITY_TOL, atol=PARITY_TOL)
    assert int(ms.t) == int(rs.t) == int(state.t) + 1


def test_sparse_parity_f32():
    problem, config, state, tau = _setup(sparse=True)
    (rs, ri), (ms, mi) = _ref_and_mega(problem, config, state, tau)
    np.testing.assert_allclose(ms.lam, rs.lam, atol=PARITY_TOL)
    np.testing.assert_allclose(ms.phi.rows, rs.phi.rows, atol=PARITY_TOL)
    np.testing.assert_allclose(ms.phi.src, rs.phi.src, atol=PARITY_TOL)
    np.testing.assert_allclose(mi.grad, ri.grad, atol=PARITY_TOL)
    np.testing.assert_allclose(float(mi.cost), float(ri.cost),
                               rtol=PARITY_TOL, atol=PARITY_TOL)


@pytest.mark.parametrize("k_iters", [1, 4])
def test_parity_across_oracle_depths(k_iters):
    """K=1 is OMAD (Alg. 3); deeper K exercises the k-loop grid axis."""
    problem, config, state, tau = _setup(k_iters=k_iters)
    (rs, _), (ms, _) = _ref_and_mega(problem, config, state, tau)
    np.testing.assert_allclose(ms.lam, rs.lam, atol=PARITY_TOL)
    np.testing.assert_allclose(ms.phi, rs.phi, atol=PARITY_TOL)


def test_multi_step_trajectory_parity():
    """Three threaded steps stay in lockstep — VMEM state re-seeds
    correctly between kernel invocations (no stale-scratch carryover)."""
    problem, config, state, tau = _setup()
    ref_st, mega_st = state, state
    for k in range(3):
        ref_st, _ = S.step(problem, config, ref_st, tau)
        with dispatch.megakernel_dispatch(1):
            mega_st, _ = S.step(problem, config, mega_st, tau)
        np.testing.assert_allclose(mega_st.lam, ref_st.lam, atol=PARITY_TOL)
        np.testing.assert_allclose(mega_st.phi, ref_st.phi, atol=PARITY_TOL)
        assert int(mega_st.t) == k + 1


# ---------------------------------------------------------------------------
# bf16 storage mode (DESIGN.md §17.3) — golden-trace bounds
# ---------------------------------------------------------------------------

def test_bf16_storage_tracks_golden_trace(monkeypatch):
    """bf16 φ-storage (f32 accumulate) on the committed Fig. 7 golden
    config: 20 outer iterations stay within the documented §17.3 drift
    bounds (utility rtol ≲1e-3 of a |U|~80 trajectory, λ within 0.2 of
    λ_total=60).  Measured drift is ~2.3e-4 rel / 0.083 abs — the bounds
    carry ~2.5× headroom, so a storage-path regression fails loudly."""
    from scripts.make_golden_trace import solve

    golden = np.load(pathlib.Path(__file__).parent / "golden"
                     / "fig7_gs_oma_traj.npz")
    monkeypatch.setenv("REPRO_MEGAKERNEL_PHI_DTYPE", "bfloat16")
    with dispatch.megakernel_dispatch(1):
        res = solve()
    np.testing.assert_allclose(np.asarray(res.utility_traj, np.float64),
                               golden["utility_traj"], rtol=1e-3, atol=0.05)
    np.testing.assert_allclose(np.asarray(res.lam, np.float64),
                               golden["lam"], atol=0.2)


def test_f32_megakernel_matches_golden_trace():
    """The f32 megakernel reproduces the golden trajectory within the
    *golden* tolerance itself (measured ≤4e-5) — the fused path is a
    drop-in for the pinned control-step semantics, not a variant."""
    from scripts.make_golden_trace import solve

    golden = np.load(pathlib.Path(__file__).parent / "golden"
                     / "fig7_gs_oma_traj.npz")
    with dispatch.megakernel_dispatch(1):
        res = solve()
    np.testing.assert_allclose(np.asarray(res.utility_traj, np.float64),
                               golden["utility_traj"], rtol=2e-4, atol=2e-3)
    np.testing.assert_allclose(np.asarray(res.lam, np.float64),
                               golden["lam"], rtol=2e-4, atol=2e-3)


def test_bf16_phi_dtype_knob_validated(monkeypatch):
    monkeypatch.setenv("REPRO_MEGAKERNEL_PHI_DTYPE", "float16")
    with pytest.raises(ValueError, match="float32.*bfloat16"):
        dispatch.megakernel_phi_dtype()


# ---------------------------------------------------------------------------
# jit / vmap / shard_map compat
# ---------------------------------------------------------------------------

def test_jit_parity():
    problem, config, state, tau = _setup()
    ref, _ = S.step(problem, config, state, tau)
    with dispatch.megakernel_dispatch(1):
        jitted = jax.jit(lambda s, u: S.step(problem, config, s, u))
        got, _ = jitted(state, tau)
    np.testing.assert_allclose(got.lam, ref.lam, atol=PARITY_TOL)
    np.testing.assert_allclose(got.phi, ref.phi, atol=PARITY_TOL)


def test_vmap_over_observations():
    """vmap over the [2W] task-utility axis (a RouterFleet batching
    tenant observations) matches per-row fused steps."""
    problem, config, state, tau = _setup()
    taus = jnp.stack([tau, tau * 1.5, tau * 0.25])
    with dispatch.megakernel_dispatch(1):
        batched = jax.vmap(lambda u: S.step(problem, config, state, u))
        states, infos = batched(taus)
        for b in range(taus.shape[0]):
            one_s, one_i = S.step(problem, config, state, taus[b])
            np.testing.assert_allclose(states.lam[b], one_s.lam,
                                       atol=PARITY_TOL)
            np.testing.assert_allclose(states.phi[b], one_s.phi,
                                       atol=PARITY_TOL)
            np.testing.assert_allclose(infos.grad[b], one_i.grad,
                                       atol=PARITY_TOL)


def test_batched_solve_matches_jnp_path():
    """solve_jowr_batch (fused_step_batch's vmap-of-steps) under
    megakernel dispatch reproduces the jnp-path trajectories."""
    from repro.core import CECGraphBatch, make_bank, solve_jowr_batch

    graphs = [build_random_cec(connected_er(12, 0.35, seed=10 + s), 3,
                               8.0, seed=s) for s in range(2)]
    banks = [make_bank("log", 3, seed=s, lam_total=LAM_TOTAL)
             for s in range(2)]
    batch = CECGraphBatch.from_graphs(graphs)
    kw = dict(method="nested", eta_outer=0.05, eta_inner=3.0,
              outer_iters=4, inner_iters=2)
    ref = solve_jowr_batch(batch, banks, LAM_TOTAL, **kw)
    with dispatch.megakernel_dispatch(1):
        got = solve_jowr_batch(batch, banks, LAM_TOTAL, **kw)
    np.testing.assert_allclose(got.lam, ref.lam, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got.utility_traj),
                               np.asarray(ref.utility_traj), atol=1e-3)


def test_sharded_fleet_inherits_megakernel():
    """run_batch_sharded (shard_map over the fleet axis) composes with
    the megakernel and matches the unsharded vmap path."""
    from repro.core import CECGraphBatch, make_bank, run_batch
    from repro.core.batch import run_batch_sharded
    from repro.launch.mesh import fleet_mesh

    graphs = [build_random_cec(connected_er(12, 0.35, seed=20 + s), 3,
                               8.0, seed=s)
              for s in range(jax.device_count())]
    banks = [make_bank("log", 3, seed=s, lam_total=LAM_TOTAL)
             for s in range(len(graphs))]
    batch = CECGraphBatch.from_graphs(graphs)
    config = S.SolverConfig(method="nested", delta=0.5, eta_outer=0.05,
                            eta_inner=3.0, inner_iters=2,
                            grad_mode="sampled")
    with dispatch.megakernel_dispatch(1):
        ref = run_batch(batch, banks, LAM_TOTAL, config, iters=3)
        got = run_batch_sharded(batch, banks, LAM_TOTAL, config, iters=3,
                                mesh=fleet_mesh())
    np.testing.assert_allclose(got.lam, ref.lam, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got.utility_traj),
                               np.asarray(ref.utility_traj), atol=1e-4)


# ---------------------------------------------------------------------------
# dispatch policy (DESIGN.md §17.2/§17.4)
# ---------------------------------------------------------------------------

def test_policy_off_by_default_on_cpu():
    assert not dispatch.use_megakernel(10_000, 8)


def test_policy_engages_under_override_and_respects_vmem():
    with dispatch.megakernel_dispatch(1):
        assert dispatch.use_megakernel(16, 3)
        # a fleet-scale graph whose resident φ exceeds the VMEM budget
        # must fall back to the stitched path even when forced
        assert not dispatch.use_megakernel(8192, 64)
    assert not dispatch.use_megakernel(16, 3)


def test_bf16_doubles_admissible_size():
    """§17.3: halving the φ itemsize roughly doubles what fits."""
    n = 1024
    w = 16
    assert not dispatch.megakernel_fits(w, n, itemsize=4)
    assert dispatch.megakernel_fits(w, n, itemsize=2)


def test_env_knobs_reread_after_import(monkeypatch):
    """§17.4 regression: dispatch knobs used to be bound at import, so a
    late os.environ mutation was a silent no-op.  Now every policy query
    and ``state_key()`` re-reads the environment."""
    key0 = dispatch.state_key()
    monkeypatch.setenv("REPRO_MEGAKERNEL_NBAR_THRESHOLD", "7")
    assert dispatch.megakernel_threshold() == 7
    assert dispatch.state_key() != key0
    # the env knob is an explicit opt-in: the policy engages off-TPU
    assert dispatch.use_megakernel(8, 2)
    monkeypatch.setenv("REPRO_MEGAKERNEL_PHI_DTYPE", "bfloat16")
    assert dispatch.megakernel_phi_dtype() == "bfloat16"
    assert "bfloat16" in dispatch.state_key()
    monkeypatch.delenv("REPRO_MEGAKERNEL_NBAR_THRESHOLD")
    monkeypatch.delenv("REPRO_MEGAKERNEL_PHI_DTYPE")
    assert dispatch.state_key() == key0
