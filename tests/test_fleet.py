"""RouterFleet contracts (ISSUE 7, DESIGN.md §15): K-tenant parity with
independent CECRouters (steady + churn), double-buffer discipline,
buffer donation, no-retrace churn, and the microbatched callback."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import solver as _solver
from repro.core.batch import fused_step_batch
from repro.core.scenario import (event_schedule, initial_state,
                                 named_scenarios)
from repro.serve import CECRouter, RouterFleet

PARITY_ATOL = 1e-5     # the ISSUE acceptance bar; in practice bit-identical


def _make_tenants(n_tenants, *, scenario="steady", horizon=20, n=10, p=0.4):
    sc = named_scenarios(horizon=horizon, n=n, p=p)[scenario]
    states = [initial_state(sc, seed=s) for s in range(n_tenants)]
    graphs = [st.graph() for st in states]
    fns = [
        (lambda lams, b=st.bank:
         np.asarray(jax.vmap(b.total)(jnp.asarray(lams))))
        for st in states]
    return sc, states, graphs, fns


def _donation_supported():
    x = jnp.ones(4)
    jax.jit(lambda v: v + 1.0, donate_argnums=0)(x)
    return x.is_deleted()


def test_fleet_parity_with_independent_routers():
    """K stacked tenants advance exactly like K CECRouters: same Λ, same
    net utility, same replica weights, interval for interval."""
    sc, _, graphs, fns = _make_tenants(3)
    lam_totals = [60.0, 45.0, 75.0]
    routers = [CECRouter(g, lam_total=lt)
               for g, lt in zip(graphs, lam_totals)]
    fleet = RouterFleet(graphs, lam_totals)
    for _ in range(6):
        recs = [r.control_step(fn) for r, fn in zip(routers, fns)]
        frec = fleet.control_step(fns)
        for k, r in enumerate(routers):
            np.testing.assert_allclose(frec["lam"][k], recs[k]["lam"],
                                       atol=PARITY_ATOL)
            np.testing.assert_allclose(frec["utility"][k],
                                       recs[k]["utility"], atol=PARITY_ATOL)
    for k, r in enumerate(routers):
        np.testing.assert_allclose(
            fleet.view.replica_weights()[k][:, : r.graph.n_phys],
            r.replica_weights(), atol=PARITY_ATOL)


def test_fleet_parity_under_churn_timeline():
    """The acceptance bar's hard half: parity holds through a scenario
    timeline (node failures + demand surge) consumed per tenant."""
    sc, scn_states, graphs, fns = _make_tenants(
        2, scenario="flash_crowd", horizon=24)
    routers = [CECRouter(g, lam_total=sc.lam_total) for g in graphs]
    fleet = RouterFleet(graphs, [sc.lam_total] * 2)
    schedule = {at: evs for at, evs in event_schedule(sc) if evs}
    r_states = list(scn_states)
    f_states = list(scn_states)
    for t in range(sc.horizon):
        for ev in schedule.get(t, ()):
            for k in range(2):
                r_states[k] = routers[k].apply_scenario_event(r_states[k], ev)
                f_states[k] = fleet.apply_scenario_event(k, f_states[k], ev)
        recs = [r.control_step(fn) for r, fn in zip(routers, fns)]
        frec = fleet.control_step(fns)
        for k in range(2):
            np.testing.assert_allclose(frec["lam"][k], recs[k]["lam"],
                                       atol=PARITY_ATOL)
    # demand surge actually landed: fleet totals follow the events
    np.testing.assert_allclose(fleet.lam_totals,
                               [r_states[0].lam_total] * 2)


def test_published_view_survives_donated_steps():
    """Double-buffer discipline (DESIGN.md §15.2): a FleetView taken
    before N further control steps still reads cleanly afterwards —
    its buffers are computed copies, never aliases of donated state."""
    _, _, graphs, fns = _make_tenants(2)
    fleet = RouterFleet(graphs, [60.0, 60.0])
    fleet.control_step(fns)
    view = fleet.view
    lam_snapshot = np.asarray(view.lam).copy()
    for _ in range(3):
        fleet.control_step(fns)
    # old front still alive and unchanged; new front has moved on
    assert not view.lam.is_deleted()
    np.testing.assert_array_equal(np.asarray(view.lam), lam_snapshot)
    assert (np.asarray(fleet.view.lam) != lam_snapshot).any()
    # serving-plane reads: split is a distribution, weights rows sum to 1
    split = fleet.view.admission_split()
    np.testing.assert_allclose(split.sum(-1), 1.0, atol=1e-5)
    w = fleet.view.replica_weights()
    np.testing.assert_allclose(w.sum(-1), 1.0, atol=1e-4)


def test_steady_state_step_donates_buffers():
    """Donation invariant (DESIGN.md §15.3): after a control step the
    previous stacked state is dead — XLA reused its buffers."""
    if not _donation_supported():
        pytest.skip("backend ignores donate_argnums (documented deviation, "
                    "DESIGN.md §15.3)")
    _, _, graphs, fns = _make_tenants(2)
    fleet = RouterFleet(graphs, [60.0, 60.0])
    old = fleet.state
    fleet.control_step(fns)
    assert old.lam.is_deleted()
    # opting out keeps the old state readable
    fleet_nd = RouterFleet(graphs, [60.0, 60.0], donate=False)
    old = fleet_nd.state
    fleet_nd.control_step(fns)
    assert not old.lam.is_deleted()
    np.testing.assert_allclose(np.asarray(fleet.view.lam),
                               np.asarray(fleet_nd.view.lam),
                               atol=PARITY_ATOL)


def test_demand_and_same_shape_churn_never_retrace():
    """Demand is a traced leaf and churn is same-shape by construction:
    the fleet's compiled step count stays at one executable."""
    _, scn_states, graphs, fns = _make_tenants(2)
    # depth headroom so the rewired graph below still fits the layout
    fleet = RouterFleet(graphs, [60.0, 60.0],
                        depth_max=max(g.depth_max for g in graphs) + 4)
    step = fused_step_batch(fleet.config, cost=fleet.cost_name,
                            donate=fleet.donate)
    if not hasattr(step, "_cache_size"):
        pytest.skip("jax version without jit cache introspection")
    fleet.control_step(fns)
    n0 = step._cache_size()
    fleet.set_demand([80.0, 55.0])
    fleet.control_step(fns)
    from repro.core.scenario import Rewire, apply_event
    new_scn = apply_event(scn_states[0], Rewire(at=1, frac=0.3, seed=3))
    fleet.update_tenant_graph(0, new_scn.graph())
    fleet.control_step(fns)
    assert step._cache_size() == n0


def test_set_demand_projects_onto_each_tenants_box():
    _, _, graphs, _ = _make_tenants(3)
    fleet = RouterFleet(graphs, [60.0, 60.0, 60.0])
    fleet.set_demand([90.0, 30.0, 60.0])
    lam = np.asarray(fleet.state.lam)
    np.testing.assert_allclose(lam.sum(-1), [90.0, 30.0, 60.0], rtol=1e-5)
    delta = fleet.config.delta
    for k, tot in enumerate([90.0, 30.0, 60.0]):
        assert (lam[k] >= delta - 1e-5).all()
        assert (lam[k] <= tot - delta + 1e-5).all()
    with pytest.raises(ValueError):
        fleet.set_demand([1.0, 2.0])        # wrong tenant count


def test_microbatched_callback_contract():
    """One fleet-batched call covers every tenant's perturbation sweep;
    per-tenant callables are called once per measurement each; a
    wrong-shaped batched callback is an error, not a fallback."""
    _, _, graphs, _ = _make_tenants(2)
    fleet = RouterFleet(graphs, [60.0, 60.0])
    K, W = fleet.n_tenants, fleet.n_sessions
    calls = []

    def fleet_batched(lams):
        calls.append(np.asarray(lams).shape)
        return np.ones(np.asarray(lams).shape[:2], np.float32)

    fleet.control_step(fleet_batched)
    # exactly two microbatches: the [K, 2W, W] sweep + the committed [K, 1, W]
    assert calls == [(K, 2 * W, W), (K, 1, W)]

    with pytest.raises(TypeError):
        fleet.control_step(lambda lams: np.ones(3, np.float32))


def test_fleet_construction_validates():
    _, _, graphs, _ = _make_tenants(2)
    with pytest.raises(ValueError):
        RouterFleet(graphs, [60.0])          # one demand per tenant
    fleet = RouterFleet(graphs, [60.0, 60.0])
    big = dataclasses.replace(graphs[0])
    with pytest.raises(ValueError):
        # a tenant outgrowing the fleet layout must raise, not retrace
        from repro.core.batch import pad_graph
        fleet.update_tenant_graph(0, pad_graph(big, fleet.batch.n_phys + 2))


def test_fleet_live_migration_to_learned_gradients():
    """Under a learned ``grad_policy`` the fleet samples until *every*
    tenant's fitter clears its holdout bar, then migrates live: one
    measured admission per tenant per interval instead of 2W+1, with net
    utility within 1% of an all-sampled twin (DESIGN.md §16.4)."""
    sc, _, graphs, fns = _make_tenants(2)
    fleet = RouterFleet(graphs, [60.0, 60.0], grad_policy="auto",
                        util_family="log")
    twin = RouterFleet(graphs, [60.0, 60.0])
    for f in fleet.fitters:
        f.min_samples, f.refit_every, f.fit_steps = 20, 8, 1500
        f.threshold = 0.02          # earn the switch with a tight surrogate
    # long enough that post-switch refits sharpen the surrogate at the
    # operating point (the learned steady state converges onto sampled's)
    for _ in range(70):
        rec = fleet.control_step(fns)
        ref = twin.control_step(fns)
    assert rec["mode"] == "learned"
    modes = [h["mode"] for h in fleet.history if "mode" in h]
    assert modes[0] == "sampled" and "learned" in modes
    # the whole point: an interval costs 1 oracle call instead of 2W+1
    assert rec["oracle_calls"] == 1
    assert ref["oracle_calls"] == 2 * fleet.n_sessions + 1
    assert float(rec["utility"].sum()) >= 0.99 * float(ref["utility"].sum())


def test_fleet_learned_interval_skips_perturbation_measurements():
    """In learned mode the measured-utility callback sees exactly one
    admission per tenant (the committed Λ) — no perturbation sweep."""
    sc, _, graphs, fns = _make_tenants(2)
    fleet = RouterFleet(graphs, [60.0, 60.0], grad_policy="learned",
                        util_family="log")
    for f in fleet.fitters:
        f.min_samples, f.refit_every, f.fit_steps = 20, 8, 800
    seen = []

    def counting(k):
        def fn(lams):
            seen.append(lams.shape[0])
            return fns[k](lams)
        return fn

    wrapped = [counting(k) for k in range(2)]
    while fleet._grad_mode_now() != "learned":
        fleet.control_step(wrapped)
    seen.clear()
    fleet.control_step(wrapped)
    assert seen == [1, 1], seen   # one committed admission per tenant
