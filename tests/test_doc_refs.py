"""The docs-as-CI gate gates (ISSUE 7): the real tree passes, and the
checker actually fails on a planted broken §-reference / stale tag."""
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
SCRIPT = ROOT / "scripts" / "check_doc_refs.py"

# build the markers by concatenation so the checker scanning *this* repo
# never mistakes the planted fixtures below for live references
REF = "DESIGN.md " + "§"


def _run(*args, cwd=ROOT):
    return subprocess.run([sys.executable, str(SCRIPT), *args],
                          capture_output=True, text=True, cwd=cwd)


def test_real_tree_passes():
    out = _run("--src", "src", "--src", "benchmarks")
    assert out.returncode == 0, out.stderr


def test_planted_broken_reference_fails(tmp_path):
    (tmp_path / "DESIGN.md").write_text(
        "# doc\n## §1 Real section\n")
    src = tmp_path / "src"
    src.mkdir()
    (src / "ok.py").write_text(f'"""fine ({REF}1)."""\n')
    assert _run("--design", str(tmp_path / "DESIGN.md"),
                "--src", str(src)).returncode == 0
    (src / "bad.py").write_text(f'"""rotten ({REF}99.2)."""\n')
    out = _run("--design", str(tmp_path / "DESIGN.md"), "--src", str(src))
    assert out.returncode == 1
    assert "§99.2" in out.stderr and "bad.py" in out.stderr


def test_stale_this_pr_tag_fails(tmp_path):
    (tmp_path / "DESIGN.md").write_text(
        "# doc\n"
        "## §1 Old section (this PR)\n"
        "## §2 Newer section\n")
    src = tmp_path / "src"
    src.mkdir()
    out = _run("--design", str(tmp_path / "DESIGN.md"), "--src", str(src))
    assert out.returncode == 1
    assert "(this PR)" in out.stderr
    # only the newest section may claim it
    (tmp_path / "DESIGN.md").write_text(
        "# doc\n"
        "## §1 Old section (PR 1)\n"
        "## §2 Newer section (this PR)\n")
    assert _run("--design", str(tmp_path / "DESIGN.md"),
                "--src", str(src)).returncode == 0
