"""Batched multi-instance JOWR path + kernel dispatch (DESIGN.md §9).

(a) ``solve_jowr_batch`` over stacked instances must reproduce the
    per-instance ``solve_jowr`` trajectories — vmap and depth/size padding
    are exact, not approximate.
(b) The size-based kernel dispatch (``core.dispatch``) must be transparent:
    forcing the Pallas path (interpret mode) through ``flow.propagate`` /
    ``routing.omd_step`` matches both the jnp solver path and the einsum
    oracles in ``kernels/ref.py``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CECGraphBatch, build_random_cec, dispatch, get_cost,
                        make_bank, pad_graph, solve_jowr, solve_jowr_batch,
                        solve_routing, solve_routing_batch, stack_banks)
from repro.core.flow import propagate
from repro.core.routing import omd_step
from repro.kernels import ref
from repro.kernels.ops import flow_step_op, omd_update_op
from repro.topo import connected_er

LAM_TOTAL = 60.0
KW = dict(method="single", eta_outer=0.05, eta_inner=3.0, outer_iters=25)


@pytest.fixture(scope="module")
def er_ensemble():
    graphs = [build_random_cec(connected_er(15, 0.3, seed=10 + s), 3, 8.0,
                               seed=s) for s in range(4)]
    banks = [make_bank("log", 3, seed=s, lam_total=LAM_TOTAL)
             for s in range(4)]
    return graphs, banks


# ---------------------------------------------------------------------------
# (a) batched solve == sequential solves
# ---------------------------------------------------------------------------

def test_batched_matches_sequential(er_ensemble):
    graphs, banks = er_ensemble
    batch = CECGraphBatch.from_graphs(graphs)
    res = solve_jowr_batch(batch, stack_banks(banks), LAM_TOTAL, **KW)
    assert res.utility_traj.shape == (4, KW["outer_iters"])
    for b in range(4):
        want = solve_jowr(graphs[b], banks[b], LAM_TOTAL, **KW)
        np.testing.assert_allclose(np.asarray(res.utility_traj[b]),
                                   np.asarray(want.utility_traj),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(res.lam[b]),
                                   np.asarray(want.lam),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(res.phi[b]),
                                   np.asarray(want.phi),
                                   rtol=1e-5, atol=1e-5)


def test_batched_broadcasts_single_bank(er_ensemble):
    graphs, _ = er_ensemble
    bank = make_bank("sqrt", 3, seed=7, lam_total=LAM_TOTAL)
    batch = CECGraphBatch.from_graphs(graphs[:2])
    res = solve_jowr_batch(batch, bank, LAM_TOTAL, **KW)
    want = solve_jowr(graphs[1], bank, LAM_TOTAL, **KW)
    np.testing.assert_allclose(np.asarray(res.utility_traj[1]),
                               np.asarray(want.utility_traj),
                               rtol=1e-5, atol=1e-5)


def test_batch_pads_mixed_physical_sizes():
    """Instances of different N embed exactly into the common size."""
    g_small = build_random_cec(connected_er(12, 0.35, seed=2), 3, 8.0, seed=0)
    g_big = build_random_cec(connected_er(15, 0.3, seed=3), 3, 8.0, seed=1)
    batch = CECGraphBatch.from_graphs([g_small, g_big])
    assert batch.n_phys == 15 and batch.n_bar == g_big.n_bar
    bank = make_bank("log", 3, seed=0, lam_total=LAM_TOTAL)
    res = solve_jowr_batch(batch, bank, LAM_TOTAL, **KW)
    for b, g in enumerate([g_small, g_big]):
        want = solve_jowr(g, bank, LAM_TOTAL, **KW)
        np.testing.assert_allclose(np.asarray(res.utility_traj[b]),
                                   np.asarray(want.utility_traj),
                                   rtol=1e-4, atol=1e-3)


def test_pad_graph_preserves_solution(small_cec):
    """Relaxation steps past an instance's own depth are fixed-point no-ops."""
    padded = pad_graph(small_cec, small_cec.n_phys + 5,
                       small_cec.depth_max + 3)
    lam = jnp.array([15.0, 20.0, 25.0])
    t0 = np.asarray(propagate(small_cec, small_cec.uniform_phi(), lam))
    t1 = np.asarray(propagate(padded, padded.uniform_phi(), lam))
    np.testing.assert_allclose(t1[:, : small_cec.n_phys],
                               t0[:, : small_cec.n_phys], rtol=1e-5,
                               atol=1e-5)
    # the relocated virtual source/sinks carry the same rates
    np.testing.assert_allclose(t1[:, padded.src], t0[:, small_cec.src],
                               rtol=1e-5, atol=1e-5)


def test_solve_routing_batch_matches_sequential(er_ensemble):
    graphs, _ = er_ensemble
    batch = CECGraphBatch.from_graphs(graphs)
    cost = get_cost("exp")
    lam = jnp.array([15.0, 15.0, 15.0])
    phi, traj = solve_routing_batch(batch, cost, lam, batch.uniform_phi(),
                                    3.0, 30)
    assert traj.shape == (4, 30)
    for b in range(4):
        want_phi, want_traj = solve_routing(graphs[b], cost, lam,
                                            graphs[b].uniform_phi(), 3.0, 30)
        np.testing.assert_allclose(np.asarray(traj[b]),
                                   np.asarray(want_traj),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(phi[b]), np.asarray(want_phi),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# (b) kernel dispatch (interpret=True) == einsum references
# ---------------------------------------------------------------------------

def test_dispatch_flow_matches_jnp_path(er25_cec):
    g = er25_cec
    lam = jnp.array([10.0, 20.0, 30.0])
    phi = g.uniform_phi()
    assert not dispatch.use_kernels(g.n_bar)      # default: jnp path
    want = propagate(g, phi, lam)
    with dispatch.kernel_dispatch(1):
        assert dispatch.use_kernels(g.n_bar)
        got = propagate(g, phi, lam)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_dispatch_omd_matches_jnp_path(er25_cec):
    g = er25_cec
    cost = get_cost("exp")
    lam = jnp.array([20.0, 20.0, 20.0])
    phi = g.uniform_phi()
    want = omd_step(g, cost, phi, lam, 1.0).phi
    with dispatch.kernel_dispatch(1):
        got = omd_step(g, cost, phi, lam, 1.0).phi
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_dispatch_full_routing_solve(er25_cec):
    """Kernels inside the scanned oracle: trajectories must agree."""
    g = er25_cec
    cost = get_cost("exp")
    lam = jnp.array([20.0, 20.0, 20.0])
    phi0 = g.uniform_phi()
    want_phi, want_traj = solve_routing(g, cost, lam, phi0, 3.0, 25)
    with dispatch.kernel_dispatch(1):
        got_phi, got_traj = solve_routing(g, cost, lam, phi0, 3.0, 25)
    np.testing.assert_allclose(np.asarray(got_traj), np.asarray(want_traj),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_phi), np.asarray(want_phi),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("W,N", [(3, 29), (2, 150)])
def test_flow_op_matches_einsum_ref(W, N):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    t = jnp.abs(jax.random.normal(ks[0], (W, N)))
    phi = jnp.abs(jax.random.normal(ks[1], (W, N, N)))
    inj = jnp.abs(jax.random.normal(ks[2], (W, N)))
    got = flow_step_op(t, phi, inj, interpret=True)
    want = ref.flow_step_ref(t, phi, inj)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("W,N,eta", [(3, 29, 3.0), (2, 150, 0.5)])
def test_omd_op_matches_einsum_ref(W, N, eta):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    mask = (jax.random.uniform(ks[0], (W, N, N)) > 0.4).astype(jnp.float32)
    raw = jnp.abs(jax.random.normal(ks[1], (W, N, N))) * mask
    s = raw.sum(-1, keepdims=True)
    phi = jnp.where(s > 0, raw / jnp.where(s > 0, s, 1.0), 0.0)
    delta = jnp.abs(jax.random.normal(ks[2], (W, N, N)))
    got = omd_update_op(phi, delta, mask, eta, interpret=True)
    want = ref.omd_update_ref(phi, delta, mask, eta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_batched_solve_under_kernel_dispatch(er_ensemble):
    """vmap composes with the Pallas interpret path end-to-end."""
    graphs, banks = er_ensemble
    batch = CECGraphBatch.from_graphs(graphs[:2])
    stacked = stack_banks(banks[:2])
    kw = dict(KW, outer_iters=5)
    want = solve_jowr_batch(batch, stacked, LAM_TOTAL, **kw)
    with dispatch.kernel_dispatch(1):
        got = solve_jowr_batch(batch, stacked, LAM_TOTAL, **kw)
    np.testing.assert_allclose(np.asarray(got.utility_traj),
                               np.asarray(want.utility_traj),
                               rtol=1e-4, atol=1e-4)
