"""Shim-parity suite for the solver core (DESIGN.md §13).

Every legacy entry point (``solve_jowr``, ``gs_oma``, ``omad``,
``solve_jowr_batch``, ``CECRouter``) is a projection of the one
``Problem``/``SolverConfig``/``SolverState`` engine — these tests pin
that claim *bit-exactly*: the old call and the equivalent first-class
call must produce identical trajectories (tolerance 1e-12, in practice
0.0 — they execute the same compiled program), on the dense and the
auto-sparsified path alike.  The golden trace
(tests/golden/fig7_gs_oma_traj.npz, tests/test_golden_trace.py) pins the
engine itself across time; this module pins the facade against the
engine.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CECGraphBatch, Problem, SolverConfig, SolverState,
                        build_random_cec, dispatch, get_cost, gs_oma,
                        make_bank, omad, paper_defaults, resolve_cost,
                        run_batch, serving_defaults, solve_jowr,
                        solve_jowr_batch)
from repro.core import solver as S
from repro.topo import connected_er

LAM_TOTAL = 30.0


def _instance(n=12, p=0.35, seed=1, W=3):
    g = build_random_cec(connected_er(n, p, seed=seed), W, 10.0, seed=0)
    bank = make_bank("log", W, seed=0, lam_total=LAM_TOTAL)
    return g, bank


def _assert_traj_equal(old, new):
    """Bit-level parity (≤1e-12) across every shared result field."""
    for name in ("utility_traj", "lam_traj", "lam", "phi"):
        a = np.asarray(getattr(old, name), np.float64)
        b = np.asarray(getattr(new, name), np.float64)
        np.testing.assert_allclose(a, b, rtol=0.0, atol=1e-12, err_msg=name)


# ---------------------------------------------------------------------------
# old call → new call, bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method,inner", [("nested", 4), ("single", 1)])
def test_solve_jowr_is_a_shim_over_run(method, inner):
    g, bank = _instance()
    old = solve_jowr(g, bank, LAM_TOTAL, method=method, eta_inner=3.0,
                     outer_iters=8, inner_iters=inner)
    problem = Problem.create(g, bank, lam_total=LAM_TOTAL, cost="exp")
    config = SolverConfig(method=method, eta_inner=3.0, inner_iters=inner)
    new = S.run(problem, config, iters=8)
    _assert_traj_equal(old, new)


def test_gs_oma_and_omad_are_shims_over_run():
    g, bank = _instance()
    cost = get_cost("exp")
    problem = Problem.create(g, bank, lam_total=LAM_TOTAL, cost=cost)
    old_nested = gs_oma(g, cost, bank, LAM_TOTAL, eta_inner=3.0,
                        outer_iters=6, inner_iters=3)
    new_nested = S.run(problem, SolverConfig(method="nested", eta_inner=3.0,
                                             inner_iters=3), iters=6)
    _assert_traj_equal(old_nested, new_nested)

    old_single = omad(g, cost, bank, LAM_TOTAL, eta_inner=3.0, outer_iters=6)
    new_single = S.run(problem, SolverConfig(method="single", eta_inner=3.0),
                       iters=6)
    _assert_traj_equal(old_single, new_single)


def test_solve_jowr_batch_is_a_shim_over_run_batch():
    graphs = [build_random_cec(connected_er(12, 0.35, seed=3 + b), 3, 10.0,
                               seed=b) for b in range(3)]
    banks = [make_bank("log", 3, seed=b, lam_total=LAM_TOTAL)
             for b in range(3)]
    batch = CECGraphBatch.from_graphs(graphs)
    old = solve_jowr_batch(batch, banks, LAM_TOTAL, method="single",
                           eta_inner=3.0, outer_iters=6)
    new = run_batch(batch, banks, LAM_TOTAL,
                    SolverConfig(method="single", eta_inner=3.0), iters=6)
    _assert_traj_equal(old, new)
    # ... and the batched engine is the single-instance engine, lane-wise
    solo = S.run(Problem.create(graphs[1], banks[1], lam_total=LAM_TOTAL),
                 SolverConfig(method="single", eta_inner=3.0), iters=6)
    np.testing.assert_allclose(np.asarray(new.utility_traj[1]),
                               np.asarray(solo.utility_traj),
                               rtol=1e-5, atol=1e-5)


def test_sparse_path_shim_parity():
    """The auto-sparsified representation goes through the same single
    conversion point (Problem.canonical) for old and new calls."""
    g, bank = _instance(n=16, p=0.3)
    with dispatch.sparse_dispatch(1, 1.0):
        old = solve_jowr(g, bank, LAM_TOTAL, method="single", eta_inner=3.0,
                         outer_iters=5)
        new = S.run(Problem.create(g, bank, lam_total=LAM_TOTAL),
                    SolverConfig(method="single", eta_inner=3.0), iters=5)
    _assert_traj_equal(old, new)
    # the representation never leaks: dense in → dense out
    assert new.phi.shape == g.out_mask.shape
    assert new.state.phi.shape == g.out_mask.shape


def test_router_control_steps_match_fused_step_exactly():
    """CECRouter == Problem + SolverConfig + SolverState: driving
    solver.fused_step by hand with the same measured utilities reproduces
    the router's trajectory bit-for-bit (same executable, same inputs)."""
    from repro.serve import CECRouter

    g, _ = _instance(n=10, p=0.4, seed=2)
    quality = np.array([1.0, 1.5, 2.0], np.float32)

    def measured(lams):
        return np.atleast_2d(np.asarray(lams)) @ quality

    router = CECRouter(g, lam_total=12.0)
    recs = [router.control_step(measured) for _ in range(4)]

    config = serving_defaults()
    problem = Problem(graph=g, bank=None, lam_total=jnp.float32(12.0),
                      cost=resolve_cost("exp"))
    state = S.init(problem, config)
    for rec in recs:
        pert = S.perturbed_allocations(state.lam, config.delta)
        task_u = jnp.asarray(np.asarray(measured(np.asarray(pert)),
                                        np.float32))
        state, info = S.fused_step(config)(problem, state, task_u)
        np.testing.assert_array_equal(np.asarray(state.lam), rec["lam"])
        np.testing.assert_array_equal(float(info.cost), rec["cost"])
        np.testing.assert_array_equal(np.asarray(info.grad), rec["grad"])
    assert int(router.state.t) == int(state.t) == 4


def test_run_scenario_accepts_config(monkeypatch):
    """run_scenario(config=...) ≡ run_scenario(legacy knobs)."""
    from repro.core import Scenario, run_scenario

    sc = Scenario("steady", horizon=6, topo_kwargs={"n": 12, "p": 0.35},
                  mean_capacity=10.0, lam_total=LAM_TOTAL)
    legacy = run_scenario(sc, seeds=(0, 1), eta_inner=3.0)
    cfg = SolverConfig(method="single", eta_inner=3.0)
    first_class = run_scenario(sc, seeds=(0, 1), config=cfg)
    _assert_traj_equal(legacy, first_class)


# ---------------------------------------------------------------------------
# the engine itself: init/step/run contract
# ---------------------------------------------------------------------------

def test_run_equals_manual_step_loop():
    g, bank = _instance()
    problem = Problem.create(g, bank, lam_total=LAM_TOTAL)
    config = SolverConfig(method="single", eta_inner=3.0)
    res = S.run(problem, config, iters=5)

    state = S.init(problem, config)
    for k in range(5):
        task_u = jax.vmap(bank.total)(
            S.perturbed_allocations(state.lam, config.delta))
        state, info = S.step(problem, config, state, task_u)
        np.testing.assert_allclose(np.asarray(res.lam_traj[k]),
                                   np.asarray(state.lam), rtol=1e-5,
                                   atol=1e-5)
        np.testing.assert_allclose(float(res.cost_traj[k]), float(info.cost),
                                   rtol=1e-5, atol=1e-5)
    assert int(state.t) == 5


def test_run_threads_state_across_calls():
    """run(10) == run(5) ∘ run(5, state=...) — the scenario-segment
    contract."""
    g, bank = _instance()
    problem = Problem.create(g, bank, lam_total=LAM_TOTAL)
    config = SolverConfig(method="single", eta_inner=3.0)
    whole = S.run(problem, config, iters=10)
    first = S.run(problem, config, iters=5)
    second = S.run(problem, config, iters=5, state=first.state)
    np.testing.assert_allclose(
        np.asarray(whole.utility_traj),
        np.concatenate([np.asarray(first.utility_traj),
                        np.asarray(second.utility_traj)]),
        rtol=1e-5, atol=1e-5)
    assert int(second.state.t) == 10


def test_result_unifies_the_legacy_records():
    """Result carries the JOWRResult fields plus the ControlStep/history
    diagnostics (cost, grad) per iteration."""
    g, bank = _instance()
    res = S.run(Problem.create(g, bank, lam_total=LAM_TOTAL),
                SolverConfig(method="single", eta_inner=3.0), iters=4)
    T, W = 4, g.n_sessions
    assert res.utility_traj.shape == (T,)
    assert res.lam_traj.shape == (T, W)
    assert res.cost_traj.shape == (T,)
    assert res.grad_traj.shape == (T, W)
    assert isinstance(res.state, SolverState)
    # the recorded utility decomposes as bank.total(Λ^t) − cost^t
    task = np.asarray(jax.vmap(bank.total)(res.lam_traj))
    np.testing.assert_allclose(np.asarray(res.utility_traj),
                               task - np.asarray(res.cost_traj),
                               rtol=1e-5, atol=1e-5)


def test_run_is_jit_and_vmap_compatible():
    """Problem is a pytree: run jits with lam_total traced (demand shifts
    reuse the executable)."""
    g, bank = _instance()
    config = SolverConfig(method="single", eta_inner=3.0)

    @jax.jit
    def solve(lam_total):
        problem = Problem(graph=g, bank=bank, lam_total=lam_total,
                          cost=get_cost("exp"))
        return S.run(problem, config, iters=3).utility_traj

    u1 = solve(jnp.float32(LAM_TOTAL))
    eager = S.run(Problem.create(g, bank, lam_total=LAM_TOTAL), config,
                  iters=3).utility_traj
    np.testing.assert_allclose(np.asarray(u1), np.asarray(eager), rtol=1e-5,
                               atol=1e-5)
    u2 = solve(jnp.float32(LAM_TOTAL * 1.25))      # no retrace, new demand
    assert not np.allclose(np.asarray(u1), np.asarray(u2))


# ---------------------------------------------------------------------------
# validation / presets
# ---------------------------------------------------------------------------

def test_problem_validate_errors():
    g, bank = _instance()
    with pytest.raises(TypeError, match="CECGraph"):
        Problem(graph=np.zeros((3, 3)), bank=bank,
                lam_total=LAM_TOTAL).validate()
    with pytest.raises(ValueError, match="sessions"):
        Problem(graph=g, bank=make_bank("log", 5, seed=0),
                lam_total=LAM_TOTAL).validate()
    with pytest.raises(ValueError, match="positive"):
        Problem(graph=g, bank=bank, lam_total=0.0).validate()
    with pytest.raises(TypeError, match="CostFn"):
        Problem(graph=g, bank=bank, lam_total=LAM_TOTAL,
                cost="exp").validate()          # names go through create()
    with pytest.raises(KeyError, match="registered costs"):
        Problem.create(g, bank, lam_total=LAM_TOTAL, cost="expo")


def test_solver_config_validation_and_presets():
    with pytest.raises(ValueError, match="valid methods"):
        SolverConfig(method="bogus")
    with pytest.raises(ValueError, match="delta"):
        SolverConfig(delta=0.0)
    with pytest.raises(ValueError, match="inner_iters"):
        SolverConfig(inner_iters=0)
    paper, serving = paper_defaults(), serving_defaults()
    # the documented (intentional) divergence, pinned: the serving plane
    # runs the hot K=1 oracle, the offline evaluation the gentle nested one
    assert (paper.method, paper.eta_inner, paper.inner_iters) == \
        ("nested", 0.05, 50)
    assert (serving.method, serving.eta_inner, serving.oracle_iters) == \
        ("single", 3.0, 1)
    assert SolverConfig(method="single", inner_iters=50).oracle_iters == 1
    # configs are hashable jit-cache keys
    assert hash(paper) != hash(serving)
    assert dataclasses.replace(paper, method="single") != paper


def test_run_continuation_recanonicalizes_sparse():
    """A carried dense state must not pin a continuation to the dense
    path: run(state=...) re-applies the representation policy (the φ is
    re-laid-out onto the edge slots), and split == whole bit-exactly."""
    g, bank = _instance(n=16, p=0.3)
    problem = Problem.create(g, bank, lam_total=LAM_TOTAL)
    config = SolverConfig(method="single", eta_inner=3.0)
    with dispatch.sparse_dispatch(1, 1.0):
        whole = S.run(problem, config, iters=6)
        first = S.run(problem, config, iters=3)
        assert first.state.phi.shape == g.out_mask.shape   # dense contract
        second = S.run(problem, config, iters=3, state=first.state)
    np.testing.assert_allclose(
        np.asarray(whole.utility_traj, np.float64),
        np.concatenate([np.asarray(first.utility_traj, np.float64),
                        np.asarray(second.utility_traj, np.float64)]),
        rtol=0.0, atol=1e-12)
    _assert_traj_equal(
        whole, second._replace(
            utility_traj=whole.utility_traj,
            lam_traj=jnp.concatenate([first.lam_traj, second.lam_traj])))


def test_run_rejects_state_plus_warm_start_overrides():
    """state= and phi0=/lam0= are mutually exclusive — silently dropping
    a caller's warm-start override would be an invisible wrong answer."""
    g, bank = _instance()
    problem = Problem.create(g, bank, lam_total=LAM_TOTAL)
    config = SolverConfig(method="single", eta_inner=3.0)
    prev = S.run(problem, config, iters=2)
    with pytest.raises(ValueError, match="not both"):
        S.run(problem, config, iters=2, state=prev.state,
              phi0=g.uniform_phi())


def test_run_without_bank_points_at_step():
    g, _ = _instance()
    with pytest.raises(ValueError, match="solver.step"):
        S.run(Problem(graph=g, bank=None, lam_total=LAM_TOTAL),
              SolverConfig(), iters=2)


def test_paper_preset_module():
    from repro.configs import cec_paper

    cfg = cec_paper.solver_config()
    assert cfg.eta_inner == 3.0 and cfg.method == "single"
    assert cec_paper.solver_config(method="nested").inner_iters == 50
    problem = cec_paper.build_problem()
    assert problem.n_sessions == 3
    assert float(np.asarray(problem.lam_total)) == 60.0
