"""Fleet-scale sharded control plane == single-device reference."""
import os
import subprocess
import sys


def _run(code: str, ndev: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={ndev}",
               PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, cwd="/root/repo")
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


def test_sharded_routing_matches_reference():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import build_random_cec, get_cost, solve_routing
from repro.core.distributed import solve_routing_sharded
from repro.launch.mesh import make_mesh
from repro.topo import connected_er

# n chosen so n_bar = 29 pads awkwardly → exercises uneven shard fallback?
# use 28 phys nodes → n_bar = 32, divisible by the 4×2 mesh
g = build_random_cec(connected_er(28, 0.25, seed=3), 3, 10.0, seed=0)
assert g.n_bar % 8 == 0, g.n_bar
mesh = make_mesh((4, 2), ("data", "model"))
cost = get_cost("exp")
lam = jnp.array([15.0, 20.0, 25.0])
phi0 = g.uniform_phi()

ref_phi, ref_traj = solve_routing(g, cost, lam, phi0, 2.0, 40)
got_phi, got_traj = solve_routing_sharded(g, cost, lam, phi0, 2.0, 40, mesh)
np.testing.assert_allclose(np.asarray(got_traj), np.asarray(ref_traj),
                           rtol=1e-4, atol=1e-4)
np.testing.assert_allclose(np.asarray(got_phi), np.asarray(ref_phi),
                           rtol=1e-3, atol=1e-4)
print("SHARDED_OK")
""")
    assert "SHARDED_OK" in out


def test_control_plane_lowers_at_fleet_scale():
    """N=2048-node control plane compiles SPMD on an 8-device mesh."""
    out = _run("""
from repro.core.distributed import lower_control_plane
from repro.launch.mesh import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
compiled = lower_control_plane(2045, 3, mesh, n_iters=5)
ca = compiled.cost_analysis()
ca = ca[0] if isinstance(ca, (list, tuple)) else ca
assert ca.get("flops", 0) > 0
print("FLEET_OK", ca.get("flops"))
""")
    assert "FLEET_OK" in out
