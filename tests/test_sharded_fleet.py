"""Sharded fleet solving (DESIGN.md §14): parity, padding, properties.

``run_batch_sharded`` must be a *drop-in* for ``run_batch`` — same
``Result``, lane for lane.  This module is the contract:

* **1-device parity is bit-identical** (the shard_map body is the same
  vmapped program; a 1-device mesh adds no reduction reordering).
* **Forced multi-device parity is ≤ 1e-6** (subprocess with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; XLA may fuse
  differently per shard), covering dense and sparse fleets, uneven fleet
  sizes that need shard padding, carried ``SolverState``s, and a full
  scenario timeline.
* **Property tests** pin the promoted sharding helpers: fleet-axis spec
  construction over arbitrary ranks, the pad/unpad roundtrip over
  arbitrary (fleet size, shard count), and the ``shard_map_compat`` shim.

In-process tests run on the single conftest-pinned CPU device — they are
the coverage carriers for the new paths; the subprocess tier proves the
multi-device story on every PR (CI job ``sharded-multidevice``).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from hypothesis_stub import given, settings, st

from repro.core.batch import (CECGraphBatch, CECGraphSparseBatch, run_batch,
                              run_batch_sharded)
from repro.core.graph import build_random_cec, sparsify
from repro.core.solver import SolverConfig
from repro.core.utility import make_bank
from repro.launch.mesh import fleet_mesh
from repro.parallel.sharding import (FLEET_AXIS, fleet_axis, fleet_padded_size,
                                     fleet_spec, fleet_specs, pad_fleet,
                                     unpad_fleet)
from repro.topo import make_fleet

CONFIG = SolverConfig(method="single", delta=0.5, eta_outer=0.05,
                      eta_inner=3.0, inner_iters=1)

# On the conftest-pinned single CPU device the sharded driver traces to the
# same vmapped executable — parity is bit-identical.  The CI job
# ``sharded-multidevice`` re-runs this module under
# XLA_FLAGS=--xla_force_host_platform_device_count=8, where per-shard XLA
# fusion may reorder float ops: tolerance relaxes to 1e-6.
TOL = 1e-12 if jax.device_count() == 1 else 1e-6


def _graphs(n_instances=3, n=8, sparse=False):
    gs = [build_random_cec(make_fleet("power_law", n, seed=s), 2, 10.0,
                           seed=s) for s in range(n_instances)]
    return [sparsify(g) for g in gs] if sparse else gs


def _dense_batch(n_instances=3):
    return CECGraphBatch.from_graphs(_graphs(n_instances))


def _sparse_batch(n_instances=3):
    return CECGraphSparseBatch.from_graphs(_graphs(n_instances, sparse=True))


def _max_abs_diff(a, b) -> float:
    return jax.tree_util.tree_reduce(
        max, jax.tree_util.tree_map(
            lambda x, y: float(jnp.max(jnp.abs(x - y))), a, b))


# ---------------------------------------------------------------------------
# in-process parity (1 real CPU device — bit-identical)
# ---------------------------------------------------------------------------

def test_sharded_matches_vmap_dense_bitwise():
    batch = _dense_batch()
    banks = [make_bank("log", 2, seed=s) for s in range(3)]
    ref = run_batch(batch, banks, 4.0, CONFIG, iters=10)
    got = run_batch_sharded(batch, banks, 4.0, CONFIG, iters=10,
                            mesh=fleet_mesh())
    assert _max_abs_diff(ref, got) <= TOL


def test_sharded_matches_vmap_sparse_broadcast_bank():
    batch = _sparse_batch()
    bank = make_bank("log", 2, seed=0)        # single bank, broadcast
    ref = run_batch(batch, bank, 4.0, CONFIG, iters=8)
    got = run_batch_sharded(batch, bank, 4.0, CONFIG, iters=8)
    assert _max_abs_diff(ref, got) <= TOL


def test_sharded_state_threading_matches_vmap():
    """A Result.state from one driver warm-starts the other exactly."""
    batch = _dense_batch()
    bank = make_bank("log", 2, seed=0)
    first = run_batch_sharded(batch, bank, 4.0, CONFIG, iters=6)
    ref = run_batch(batch, bank, 4.0, CONFIG, iters=6, state=first.state)
    got = run_batch_sharded(batch, bank, 4.0, CONFIG, iters=6,
                            state=first.state)
    assert _max_abs_diff(ref, got) <= TOL
    assert float(jnp.max(jnp.abs(got.state.t - first.state.t - 6))) == 0


def test_sharded_phi0_lam0_overrides_match_vmap():
    batch = _dense_batch()
    bank = make_bank("log", 2, seed=0)
    phi0 = batch.uniform_phi()
    lam0 = jnp.full((3, 2), 2.0, jnp.float32)
    ref = run_batch(batch, bank, 4.0, CONFIG, iters=5, phi0=phi0, lam0=lam0)
    got = run_batch_sharded(batch, bank, 4.0, CONFIG, iters=5, phi0=phi0,
                            lam0=lam0)
    assert _max_abs_diff(ref, got) <= TOL


def test_scenario_sharded_driver_matches_unsharded():
    from repro.core.scenario import named_scenarios, run_scenario

    sc = named_scenarios(horizon=12, n=8)["link_churn"]
    ref = run_scenario(sc, seeds=(0, 1, 2))
    got = run_scenario(sc, seeds=(0, 1, 2), mesh=fleet_mesh())
    assert float(jnp.max(jnp.abs(ref.utility_traj - got.utility_traj))) \
        <= TOL
    assert float(jnp.max(jnp.abs(ref.lam - got.lam))) <= TOL
    assert float(jnp.max(jnp.abs(ref.phi - got.phi))) <= TOL


def test_fleet_mesh_shape_and_validation():
    mesh = fleet_mesh()
    assert mesh.axis_names == (FLEET_AXIS,)
    assert fleet_axis(mesh) == FLEET_AXIS
    assert mesh.shape[FLEET_AXIS] == jax.device_count()
    try:
        fleet_mesh(n_devices=jax.device_count() + 1)
    except ValueError:
        pass
    else:
        raise AssertionError("oversubscribed fleet_mesh must raise")


def test_state_key_covers_fleet_mesh():
    """Jit caches keyed on dispatch.state_key() must not alias meshes."""
    from repro.core import dispatch

    base = dispatch.state_key()
    mesh = fleet_mesh()
    with dispatch.fleet_dispatch(mesh):
        inside = dispatch.state_key()
        assert inside != base
        assert inside[-1] == dispatch.mesh_fingerprint(mesh)
    assert dispatch.state_key() == base


# ---------------------------------------------------------------------------
# forced multi-device parity (subprocess, 8 fake CPU devices)
# ---------------------------------------------------------------------------

def _run_subprocess(code: str, ndev: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={ndev}",
               PYTHONPATH="src")
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


def test_multidevice_parity_dense_sparse_uneven():
    """8-device mesh: dense + sparse fleets, uneven B=5 needing padding,
    state threading — all within 1e-6 of the vmap reference."""
    out = _run_subprocess("""
import jax, jax.numpy as jnp
assert jax.device_count() == 8, jax.device_count()
from repro.core.batch import (CECGraphBatch, CECGraphSparseBatch, run_batch,
                              run_batch_sharded)
from repro.core.graph import build_random_cec, sparsify
from repro.core.solver import SolverConfig
from repro.core.utility import make_bank
from repro.launch.mesh import fleet_mesh
from repro.topo import make_fleet

cfg = SolverConfig(method="single", delta=0.5, eta_outer=0.05,
                   eta_inner=3.0, inner_iters=1)
mesh = fleet_mesh()
diff = lambda a, b: jax.tree_util.tree_reduce(
    max, jax.tree_util.tree_map(
        lambda x, y: float(jnp.max(jnp.abs(x - y))), a, b))

# uneven fleet: B=5 on 8 shards pads to 8, sliced back to 5
gs = [build_random_cec(make_fleet("power_law", 8, seed=s), 2, 10.0, seed=s)
      for s in range(5)]
batch = CECGraphBatch.from_graphs(gs)
banks = [make_bank("log", 2, seed=s) for s in range(5)]
ref = run_batch(batch, banks, 4.0, cfg, iters=8)
got = run_batch_sharded(batch, banks, 4.0, cfg, iters=8, mesh=mesh)
d = diff(ref, got)
assert d <= 1e-6, f"dense uneven parity {d}"
assert got.lam.shape == ref.lam.shape == (5, 2)

# state threading across the sharded boundary
ref2 = run_batch(batch, banks, 4.0, cfg, iters=8, state=ref.state)
got2 = run_batch_sharded(batch, banks, 4.0, cfg, iters=8, state=got.state,
                         mesh=mesh)
d = diff(ref2, got2)
assert d <= 1e-6, f"state-threaded parity {d}"

# sparse fleet, even B=8, broadcast bank
gs = [sparsify(build_random_cec(make_fleet("power_law", 8, seed=s), 2, 10.0,
                                seed=s)) for s in range(8)]
sbatch = CECGraphSparseBatch.from_graphs(gs)
bank = make_bank("log", 2, seed=0)
ref = run_batch(sbatch, bank, 4.0, cfg, iters=8)
got = run_batch_sharded(sbatch, bank, 4.0, cfg, iters=8, mesh=mesh)
d = diff(ref, got)
assert d <= 1e-6, f"sparse parity {d}"
print("MULTIDEV_OK")
""")
    assert "MULTIDEV_OK" in out


def test_multidevice_scenario_timeline():
    """run_scenario(mesh=...) on 8 devices tracks the unsharded run
    through warm-started event boundaries (B=3 seeds pad to 8)."""
    out = _run_subprocess("""
import jax, jax.numpy as jnp
assert jax.device_count() == 8
from repro.core.scenario import named_scenarios, run_scenario
from repro.launch.mesh import fleet_mesh

sc = named_scenarios(horizon=10, n=8)["link_churn"]
ref = run_scenario(sc, seeds=(0, 1, 2))
got = run_scenario(sc, seeds=(0, 1, 2), mesh=fleet_mesh())
assert ref.utility_traj.shape == got.utility_traj.shape
d = float(jnp.max(jnp.abs(ref.utility_traj - got.utility_traj)))
assert d <= 1e-6, f"scenario parity {d}"
d = float(jnp.max(jnp.abs(ref.lam - got.lam)))
assert d <= 1e-6, f"scenario lam parity {d}"
print("SCENARIO_OK")
""")
    assert "SCENARIO_OK" in out


def test_multidevice_submesh():
    """A fleet mesh over a strict subset of the devices still agrees."""
    out = _run_subprocess("""
import jax, jax.numpy as jnp
assert jax.device_count() == 8
from repro.core.batch import CECGraphBatch, run_batch, run_batch_sharded
from repro.core.graph import build_random_cec
from repro.core.solver import SolverConfig
from repro.core.utility import make_bank
from repro.launch.mesh import fleet_mesh
from repro.topo import make_fleet

cfg = SolverConfig(method="single", delta=0.5, eta_outer=0.05,
                   eta_inner=3.0, inner_iters=1)
gs = [build_random_cec(make_fleet("power_law", 8, seed=s), 2, 10.0, seed=s)
      for s in range(3)]
batch = CECGraphBatch.from_graphs(gs)
bank = make_bank("log", 2, seed=0)
ref = run_batch(batch, bank, 4.0, cfg, iters=6)
got = run_batch_sharded(batch, bank, 4.0, cfg, iters=6,
                        mesh=fleet_mesh(n_devices=3))
d = jax.tree_util.tree_reduce(
    max, jax.tree_util.tree_map(
        lambda x, y: float(jnp.max(jnp.abs(x - y))), ref, got))
assert d <= 1e-6, f"submesh parity {d}"
print("SUBMESH_OK")
""")
    assert "SUBMESH_OK" in out


# ---------------------------------------------------------------------------
# property tests for the promoted sharding helpers
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(ndim=st.integers(0, 6))
def test_fleet_spec_shards_leading_axis_only(ndim):
    spec = fleet_spec(ndim)
    if ndim == 0:
        assert tuple(spec) == ()
    else:
        assert spec[0] == FLEET_AXIS
        assert all(e is None for e in tuple(spec)[1:])


@settings(max_examples=50, deadline=None)
@given(size=st.integers(1, 64), n_shards=st.integers(1, 16))
def test_fleet_padded_size_properties(size, n_shards):
    p = fleet_padded_size(size, n_shards)
    assert p % n_shards == 0
    assert size <= p < size + n_shards


@settings(max_examples=30, deadline=None)
@given(size=st.integers(1, 12), n_shards=st.integers(1, 8),
       trailing=st.integers(0, 2), seed=st.integers(0, 1000))
def test_pad_unpad_roundtrip_is_bit_exact(size, n_shards, trailing, seed):
    """unpad(pad(x)) == x bitwise; pad lanes replicate the last row."""
    rng = np.random.default_rng(seed)
    shape = (size,) + (3,) * trailing
    tree = {"a": jnp.asarray(rng.normal(size=shape), jnp.float32),
            "b": jnp.asarray(rng.integers(0, 9, size=(size, 2)))}
    padded = pad_fleet(tree, n_shards)
    p = fleet_padded_size(size, n_shards)
    assert padded["a"].shape[0] == p
    back = unpad_fleet(padded, size)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))
        # every pad lane is the last real instance, so shards stay feasible
        for i in range(size, p):
            np.testing.assert_array_equal(np.asarray(padded[k][i]),
                                          np.asarray(tree[k][-1]))


@settings(max_examples=30, deadline=None)
@given(ndims=st.lists(st.integers(0, 4), min_size=1, max_size=4),
       shard=st.booleans())
def test_fleet_specs_tree_matches_leaf_ranks(ndims, shard):
    tree = [jnp.zeros((2,) * n) for n in ndims]
    specs = fleet_specs(tree, shard=shard)
    for n, spec in zip(ndims, specs):
        want = fleet_spec(n) if shard and n else None
        if want is None:
            assert tuple(spec) == ()
        else:
            assert spec == want


def test_shard_map_compat_identity_on_one_device():
    """The compat shim runs the body and honours specs on any jax version."""
    from repro.parallel.collectives import shard_map_compat

    mesh = fleet_mesh(n_devices=1)
    x = jnp.arange(12.0).reshape(4, 3)
    out = shard_map_compat(lambda a: a * 2, mesh,
                           fleet_specs(x), fleet_specs(x))(x)
    np.testing.assert_allclose(np.asarray(out), 2 * np.asarray(x))


def test_fleet_sizes_in_process():
    """B=1 and B=4 through the full driver (1-device mesh: n_shards=1 is
    the no-pad fast path; the size bookkeeping must stay exact)."""
    bank = make_bank("log", 2, seed=0)
    for rows in (1, 4):
        batch = CECGraphBatch.from_graphs(_graphs(n_instances=rows))
        ref = run_batch(batch, bank, 4.0, CONFIG, iters=3)
        got = run_batch_sharded(batch, bank, 4.0, CONFIG, iters=3)
        assert got.lam.shape[0] == rows
        assert _max_abs_diff(ref, got) <= TOL
