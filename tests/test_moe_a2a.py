"""All-to-all expert-parallel MoE vs dense oracle (8 fake devices)."""
import os
import subprocess
import sys


def test_moe_a2a_matches_dense_oracle():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.parallel.moe_a2a import moe_a2a_apply, moe_dense_oracle

mesh = make_mesh((2, 4), ("data", "model"))
E, D, F = 8, 32, 64
ks = jax.random.split(jax.random.PRNGKey(0), 4)
params = {
    "router": jax.random.normal(ks[0], (D, E)) * 0.5,
    "wi": jax.random.normal(ks[1], (E, D, F)) / jnp.sqrt(D),
    "wo": jax.random.normal(ks[2], (E, F, D)) / jnp.sqrt(F),
}
x = jax.random.normal(ks[3], (4, 16, D)) * 0.5

# capacity chosen drop-free so the comparison is exact
got = moe_a2a_apply(mesh, params, x, capacity_factor=16.0)
want = moe_dense_oracle(params, x)
np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                           rtol=2e-4, atol=2e-4)

# HLO actually contains all-to-alls (the point of the schedule)
lowered = jax.jit(lambda p, xx: moe_a2a_apply(mesh, p, xx,
                                              capacity_factor=16.0))
txt = lowered.lower(params, x).compile().as_text()
assert "all-to-all" in txt, "expected all-to-all dispatch in HLO"
print("A2A_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, cwd="/root/repo")
    assert "A2A_OK" in r.stdout, r.stdout + r.stderr
