"""GS-OMA / OMAD correctness: convergence to the genie optimum under
bandit feedback (Thms. 1/2/5), feasibility invariants, utility properties."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # only the property tests skip; the rest of the module still runs
    from hypothesis_stub import given, settings, st

from repro.core import (allocation_kkt_residual, exact_gradient_allocation,
                        get_cost, gs_oma, make_bank, omad, solve_jowr)

LAM_TOTAL = 60.0


@pytest.fixture(scope="module")
def genie(er25_cec):
    cost = get_cost("exp")
    bank = make_bank("log", 3, seed=0, lam_total=LAM_TOTAL)
    lam, phi, U = exact_gradient_allocation(
        er25_cec, cost, bank, LAM_TOTAL, eta=0.1, outer_iters=200,
        inner_iters=50, eta_inner=3.0)
    return bank, lam, U


def test_gs_oma_matches_genie(er25_cec, genie):
    bank, lam_ref, U_ref = genie
    res = gs_oma(er25_cec, get_cost("exp"), bank, LAM_TOTAL, delta=0.5,
                 eta_outer=0.05, eta_inner=3.0, outer_iters=80,
                 inner_iters=40)
    assert float(res.utility_traj[-1]) > U_ref - 0.05
    np.testing.assert_allclose(np.asarray(res.lam), np.asarray(lam_ref),
                               atol=0.6)


def test_omad_matches_genie(er25_cec, genie):
    bank, lam_ref, U_ref = genie
    res = omad(er25_cec, get_cost("exp"), bank, LAM_TOTAL, delta=0.5,
               eta_outer=0.05, eta_inner=3.0, outer_iters=300)
    assert float(res.utility_traj[-1]) > U_ref - 0.05
    np.testing.assert_allclose(np.asarray(res.lam), np.asarray(lam_ref),
                               atol=0.6)


def test_allocation_feasibility(er25_cec):
    """Σλ = λ_total and box constraints hold along the whole trajectory."""
    bank = make_bank("sqrt", 3, seed=1, lam_total=LAM_TOTAL)
    res = gs_oma(er25_cec, get_cost("exp"), bank, LAM_TOTAL, delta=0.5,
                 eta_outer=0.05, eta_inner=3.0, outer_iters=30,
                 inner_iters=20)
    traj = np.asarray(res.lam_traj)
    np.testing.assert_allclose(traj.sum(-1), LAM_TOTAL, rtol=1e-4)
    assert (traj >= 0.5 - 1e-4).all()
    assert (traj <= LAM_TOTAL - 0.5 + 1e-4).all()


def test_allocation_kkt_at_optimum(er25_cec, genie):
    """Theorem 1: equal ∂U/∂λ_w across sessions at Λ*."""
    bank, _, _ = genie
    res = omad(er25_cec, get_cost("exp"), bank, LAM_TOTAL, delta=0.5,
               eta_outer=0.05, eta_inner=3.0, outer_iters=400)
    assert float(allocation_kkt_residual(
        er25_cec, get_cost("exp"), bank, res.lam, res.phi)) < 0.05


@pytest.mark.parametrize("kind", ["linear", "sqrt", "quadratic", "log"])
def test_all_utility_families_converge(small_cec, kind):
    """Fig. 10: GS-OMA converges for every unknown-utility family."""
    bank = make_bank(kind, 3, seed=2, lam_total=LAM_TOTAL)
    res = solve_jowr(small_cec, bank, LAM_TOTAL, method="nested",
                     eta_outer=0.05, eta_inner=3.0, outer_iters=60,
                     inner_iters=30)
    u = np.asarray(res.utility_traj)
    assert np.isfinite(u).all()
    # converged: last-10 variation tiny relative to total improvement
    spread = u[-10:].max() - u[-10:].min()
    assert spread < 0.05 * max(abs(u[-1] - u[0]), 1.0) + 1e-3


@settings(max_examples=20, deadline=None)
@given(kind=st.sampled_from(["linear", "sqrt", "quadratic", "log"]),
       seed=st.integers(0, 1000))
def test_utility_monotone_concave(kind, seed):
    """Assumptions 1–3 hold for every generated utility bank."""
    bank = make_bank(kind, 4, seed=seed, lam_total=LAM_TOTAL)
    lam = jnp.linspace(0.0, LAM_TOTAL, 121)
    vals = np.asarray(jnp.stack([bank.per_session(jnp.full((4,), l))
                                 for l in lam]))
    assert np.isfinite(vals).all()
    d1 = np.diff(vals, axis=0)
    assert (d1 >= -1e-4).all(), "utility must be monotone increasing"
    d2 = np.diff(vals, 2, axis=0)
    assert (d2 <= 1e-4).all(), "utility must be concave"
