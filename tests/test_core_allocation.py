"""GS-OMA / OMAD correctness: convergence to the genie optimum under
bandit feedback (Thms. 1/2/5), feasibility invariants, utility properties."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # only the property tests skip; the rest of the module still runs
    from hypothesis_stub import given, settings, st

from repro.core import (allocation_kkt_residual, exact_gradient_allocation,
                        get_cost, gs_oma, make_bank, omad, solve_jowr,
                        total_cost)
from repro.core.allocation import _project_box_simplex

LAM_TOTAL = 60.0


@pytest.fixture(scope="module")
def genie(er25_cec):
    cost = get_cost("exp")
    bank = make_bank("log", 3, seed=0, lam_total=LAM_TOTAL)
    lam, phi, U = exact_gradient_allocation(
        er25_cec, cost, bank, LAM_TOTAL, eta=0.1, outer_iters=200,
        inner_iters=50, eta_inner=3.0)
    return bank, lam, U


def test_gs_oma_matches_genie(er25_cec, genie):
    bank, lam_ref, U_ref = genie
    res = gs_oma(er25_cec, get_cost("exp"), bank, LAM_TOTAL, delta=0.5,
                 eta_outer=0.05, eta_inner=3.0, outer_iters=80,
                 inner_iters=40)
    assert float(res.utility_traj[-1]) > U_ref - 0.05
    np.testing.assert_allclose(np.asarray(res.lam), np.asarray(lam_ref),
                               atol=0.6)


def test_omad_matches_genie(er25_cec, genie):
    bank, lam_ref, U_ref = genie
    res = omad(er25_cec, get_cost("exp"), bank, LAM_TOTAL, delta=0.5,
               eta_outer=0.05, eta_inner=3.0, outer_iters=300)
    assert float(res.utility_traj[-1]) > U_ref - 0.05
    np.testing.assert_allclose(np.asarray(res.lam), np.asarray(lam_ref),
                               atol=0.6)


def test_allocation_feasibility(er25_cec):
    """Σλ = λ_total and box constraints hold along the whole trajectory."""
    bank = make_bank("sqrt", 3, seed=1, lam_total=LAM_TOTAL)
    res = gs_oma(er25_cec, get_cost("exp"), bank, LAM_TOTAL, delta=0.5,
                 eta_outer=0.05, eta_inner=3.0, outer_iters=30,
                 inner_iters=20)
    traj = np.asarray(res.lam_traj)
    np.testing.assert_allclose(traj.sum(-1), LAM_TOTAL, rtol=1e-4)
    assert (traj >= 0.5 - 1e-4).all()
    assert (traj <= LAM_TOTAL - 0.5 + 1e-4).all()


def test_allocation_kkt_at_optimum(er25_cec, genie):
    """Theorem 1: equal ∂U/∂λ_w across sessions at Λ*."""
    bank, _, _ = genie
    res = omad(er25_cec, get_cost("exp"), bank, LAM_TOTAL, delta=0.5,
               eta_outer=0.05, eta_inner=3.0, outer_iters=400)
    assert float(allocation_kkt_residual(
        er25_cec, get_cost("exp"), bank, res.lam, res.phi)) < 0.05


@pytest.mark.parametrize("kind", ["linear", "sqrt", "quadratic", "log"])
def test_all_utility_families_converge(small_cec, kind):
    """Fig. 10: GS-OMA converges for every unknown-utility family."""
    bank = make_bank(kind, 3, seed=2, lam_total=LAM_TOTAL)
    res = solve_jowr(small_cec, bank, LAM_TOTAL, method="nested",
                     eta_outer=0.05, eta_inner=3.0, outer_iters=60,
                     inner_iters=30)
    u = np.asarray(res.utility_traj)
    assert np.isfinite(u).all()
    # converged: last-10 variation tiny relative to total improvement
    spread = u[-10:].max() - u[-10:].min()
    assert spread < 0.05 * max(abs(u[-1] - u[0]), 1.0) + 1e-3


def test_utility_traj_reports_committed_iterate(small_cec):
    """The recorded U_t is the paper's U(Λ^t, φ^t): the final trajectory
    value must match an independent evaluation at (result.lam, result.phi)
    — previously U_t was priced with the φ left over from the last
    *perturbed* observation (Λ^t − δ·e_W)."""
    cost = get_cost("exp")
    bank = make_bank("log", 3, seed=3, lam_total=LAM_TOTAL)
    res = gs_oma(small_cec, cost, bank, LAM_TOTAL, delta=0.5,
                 eta_outer=0.05, eta_inner=3.0, outer_iters=12,
                 inner_iters=5)
    want = float(bank.total(res.lam)
                 - total_cost(small_cec, cost, res.phi, res.lam))
    np.testing.assert_allclose(float(res.utility_traj[-1]), want,
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# exact box-simplex projection (Alg. 1 line 9)
# ---------------------------------------------------------------------------

def _assert_projection_ok(y, lam_total, delta):
    x = np.asarray(_project_box_simplex(jnp.asarray(y, jnp.float32),
                                        lam_total, delta))
    # Σλ_w = λ_total to 1e-6 (relative — float32 summation floor), bounds
    # respected; the old rescale-then-clip broke the sum whenever a
    # coordinate saturated a bound
    np.testing.assert_allclose(x.sum(-1), lam_total, rtol=1e-6)
    assert (x >= delta - 1e-5).all()
    assert (x <= lam_total - delta + 1e-5).all()
    return x


def test_project_box_simplex_saturation_regression():
    """The documented failure of the old composition: one coordinate far
    above the box pins at λ−δ and the rescale leaves Σ ≠ λ."""
    x = _assert_projection_ok([30.0, 0.1, 0.1], 10.0, 0.5)
    np.testing.assert_allclose(x, [9.0, 0.5, 0.5], atol=1e-5)


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("W", [2, 3, 8, 64])
def test_project_box_simplex_random_iterates(seed, W):
    rng = np.random.default_rng(seed)
    y = rng.uniform(-LAM_TOTAL, 2 * LAM_TOTAL, W)
    x = _assert_projection_ok(y, LAM_TOTAL, 0.5)
    # idempotent == fixed point on feasible inputs (x is feasible)
    x2 = np.asarray(_project_box_simplex(jnp.asarray(x), LAM_TOTAL, 0.5))
    np.testing.assert_allclose(x2, x, atol=1e-4)


def test_project_box_simplex_batched_matches_rows():
    """[B, W] stacks (the scenario engine's per-instance iterates) project
    exactly like their rows."""
    rng = np.random.default_rng(7)
    Y = rng.uniform(-20.0, 80.0, (5, 4)).astype(np.float32)
    got = np.asarray(_project_box_simplex(jnp.asarray(Y), LAM_TOTAL, 0.5))
    want = np.stack([np.asarray(_project_box_simplex(jnp.asarray(r),
                                                     LAM_TOTAL, 0.5))
                     for r in Y])
    np.testing.assert_allclose(got, want, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), W=st.integers(2, 48),
       lam_total=st.floats(4.0, 200.0), delta=st.floats(0.01, 0.08))
def test_project_box_simplex_properties(seed, W, lam_total, delta):
    """Property sweep: Σ exact (1e-6 rel), bounds, idempotency — for any
    feasible (λ, δ) pair and arbitrary random iterates."""
    rng = np.random.default_rng(seed)
    y = rng.uniform(-2.0 * lam_total, 3.0 * lam_total, W)
    x = _assert_projection_ok(y, lam_total, delta)
    x2 = np.asarray(_project_box_simplex(jnp.asarray(x), lam_total, delta))
    np.testing.assert_allclose(x2, x, atol=1e-3 * lam_total)


# ---------------------------------------------------------------------------
# legacy shims thread the outer counter (t-threading regression)
# ---------------------------------------------------------------------------

def test_legacy_shims_thread_the_outer_counter(small_cec):
    """Regression: ``control_step``/``fused_control_step`` used to rebuild
    ``SolverState`` with a hard ``t=0`` every call, so a legacy host loop
    silently froze the solver clock at zero — every t-dependent schedule
    saw iteration 0 forever.  The shims now accept the previous call's
    ``ControlStep.t`` and return the advanced counter, and a threaded
    legacy loop reproduces ``solver.run``'s scan exactly (same iterates,
    same clock)."""
    import jax

    from repro.core import solver as S
    from repro.core.allocation import (control_step, fused_control_step,
                                       perturbed_allocations)
    from repro.core.problem import Problem
    from repro.core.solver import SolverConfig

    cost = get_cost("exp")
    bank = make_bank("log", 3, seed=0, lam_total=LAM_TOTAL)
    problem = Problem(graph=small_cec, bank=bank, lam_total=LAM_TOTAL,
                      cost=cost)
    config = SolverConfig.from_legacy(delta=0.5, eta_outer=0.05,
                                      eta_inner=3.0, inner_iters=2)
    ref = S.run(problem, config, iters=3)
    assert int(ref.state.t) == 3

    state = S.init(problem, config)
    fn = fused_control_step("exp", delta=0.5, eta_outer=0.05,
                            eta_inner=3.0, inner_iters=2)
    lam, phi, t = state.lam, state.phi, 0
    for k in range(3):
        tau = jax.vmap(bank.total)(perturbed_allocations(lam, 0.5))
        out = fn(small_cec, lam, phi, tau, LAM_TOTAL, t=t)
        lam, phi, t = out.lam, out.phi, out.t
        assert int(t) == k + 1          # would stay 1 under the old reset
    np.testing.assert_allclose(np.asarray(lam), np.asarray(ref.lam),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(phi), np.asarray(ref.phi),
                               atol=1e-5)

    # the eager shim advances an arbitrary threaded counter too
    tau = jax.vmap(bank.total)(perturbed_allocations(state.lam, 0.5))
    out = control_step(small_cec, cost, state.lam, state.phi, tau,
                       lam_total=LAM_TOTAL, delta=0.5, eta_outer=0.05,
                       eta_inner=3.0, inner_iters=2, t=7)
    assert int(out.t) == 8


@settings(max_examples=20, deadline=None)
@given(kind=st.sampled_from(["linear", "sqrt", "quadratic", "log"]),
       seed=st.integers(0, 1000))
def test_utility_monotone_concave(kind, seed):
    """Assumptions 1–3 hold for every generated utility bank."""
    bank = make_bank(kind, 4, seed=seed, lam_total=LAM_TOTAL)
    lam = jnp.linspace(0.0, LAM_TOTAL, 121)
    vals = np.asarray(jnp.stack([bank.per_session(jnp.full((4,), l))
                                 for l in lam]))
    assert np.isfinite(vals).all()
    d1 = np.diff(vals, axis=0)
    assert (d1 >= -1e-4).all(), "utility must be monotone increasing"
    d2 = np.diff(vals, 2, axis=0)
    assert (d2 <= 1e-4).all(), "utility must be concave"
