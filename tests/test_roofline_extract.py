"""roofline/extract contracts: import purity + control-kernel rows.

The import-time ``XLA_FLAGS`` mutation this module used to perform
(``--xla_force_host_platform_device_count=512``) poisoned every later
jax user in the process — any benchmark or test that imported the
roofline after a clean start suddenly ran the CPU backend with 512 fake
devices.  The flag is now scoped to the CLI's re-exec'd subprocess only;
these tests pin that, plus the control-kernel cost-extraction surface
the megakernel bench publishes to the perf trajectory.
"""
import json
import os
import subprocess
import sys

import pytest


def test_import_leaves_environment_untouched():
    """Importing the module in a fresh interpreter must not create or
    edit XLA_FLAGS (the regression this file exists for)."""
    code = (
        "import os\n"
        "before = os.environ.get('XLA_FLAGS')\n"
        "import repro.roofline.extract\n"
        "assert os.environ.get('XLA_FLAGS') == before, os.environ.get("
        "'XLA_FLAGS')\n"
        "assert 'xla_force_host_platform_device_count' not in "
        "os.environ.get('XLA_FLAGS', '')\n"
        "print('clean')\n")
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, cwd=".")
    assert r.returncode == 0, r.stderr
    assert "clean" in r.stdout


def test_import_does_not_multiply_devices():
    """The concrete symptom of the old side effect: a fresh process that
    imports the roofline then initialises jax must see the real device
    count, not 512 fakes."""
    code = (
        "import repro.roofline.extract\n"
        "import jax\n"
        "assert jax.device_count() < 512, jax.device_count()\n"
        "print('devices', jax.device_count())\n")
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, cwd=".")
    assert r.returncode == 0, r.stderr


def test_forced_device_scoping_predicate():
    from repro.roofline import extract

    flags = os.environ.get("XLA_FLAGS")
    try:
        os.environ.pop("XLA_FLAGS", None)
        assert extract._needs_forced_devices()
        os.environ["XLA_FLAGS"] = extract.FORCED_DEVICE_FLAG
        assert not extract._needs_forced_devices()
    finally:
        if flags is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = flags


@pytest.fixture(scope="module")
def control_costs():
    from repro.roofline import extract

    return extract.control_step_costs(n_nodes=8, n_sessions=2, k_iters=1)


def test_control_step_costs_schema(control_costs):
    for variant in ("megakernel", "stitched"):
        rec = control_costs[variant]
        assert rec["flops"] > 0 and rec["bytes"] > 0
        assert rec["intensity"] == pytest.approx(
            rec["flops"] / rec["bytes"])
    shape = control_costs["shape"]
    assert shape["n_sessions"] == 2 and shape["k_iters"] == 1
    assert shape["phi_dtype"] == "float32"


def test_control_costs_restore_dispatch_env(control_costs):
    """Cost extraction temporarily forces the megakernel + φ dtype; both
    overrides must be unwound (the §17.4 knobs are process-global)."""
    from repro.core import dispatch

    assert "REPRO_MEGAKERNEL_PHI_DTYPE" not in os.environ
    assert not dispatch._megakernel_explicit()


def test_control_roofline_rows_schema(control_costs):
    from repro.roofline import extract
    from repro.roofline.analysis import HBM_BW, PEAK_FLOPS

    rows = extract.control_roofline_rows(control_costs)
    by_metric = {r["metric"]: r for r in rows}
    ridge = PEAK_FLOPS / HBM_BW
    for variant in ("megakernel", "stitched"):
        r = by_metric[f"roofline.control_step.{variant}"]
        assert r["ridge_flop_per_byte"] == pytest.approx(ridge)
        assert r["bound"] in ("compute", "memory")
        assert 0.0 <= r["attained_peak_fraction"] <= 1.0
        json.dumps(r)          # trajectory rows must be JSON-serializable
    assert "roofline.control_step.bytes_ratio" in by_metric


def test_legacy_cli_flags_preserved():
    """benchmarks/perf_iterations.run_variant shells out with
    ``--arch/--shape/--out`` — the *real* CLI parser must keep accepting
    them (checked via --help so no sweep is compiled)."""
    from repro.roofline import extract

    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    r = subprocess.run(
        [sys.executable, "-m", "repro.roofline.extract", "--help"],
        env=env, capture_output=True, text=True, cwd=".")
    assert r.returncode == 0, r.stderr
    for flag in ("--arch", "--shape", "--out", "--control"):
        assert flag in r.stdout
    # and the entry points the subprocess contract rests on exist
    assert callable(extract.analyze_cell)
    assert callable(extract.main)
