"""Flow-model invariants: conservation, gradient identities (paper §II-C,
eq. (18)–(21))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # only the property tests skip; the rest of the module still runs
    from hypothesis_stub import given, settings, st

from repro.core import (cost_and_state, get_cost, link_flows, marginals,
                        phi_gradient, propagate, total_cost)
from repro.core.graph import build_random_cec
from repro.topo import connected_er

from conftest import random_phi


def _instance(n, p, seed):
    return build_random_cec(connected_er(n, p, seed=seed), 3, 10.0, seed=seed)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(8, 30))
def test_flow_conservation(seed, n):
    """All admitted traffic drains into its sink: t_{D_w}(w) = λ_w."""
    g = _instance(n, 0.35, seed)
    phi = random_phi(g, seed)
    lam = jnp.asarray(np.random.default_rng(seed).uniform(1, 30, g.n_sessions),
                      jnp.float32)
    t = propagate(g, phi, lam)
    sink_rates = np.asarray(t)[np.arange(g.n_sessions), np.asarray(g.sinks)]
    np.testing.assert_allclose(sink_rates, np.asarray(lam), rtol=2e-5)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_node_conservation(seed):
    """Inflow equals outflow at every relay node (eq. (1))."""
    g = _instance(16, 0.3, seed)
    phi = random_phi(g, seed + 1)
    lam = jnp.array([10.0, 20.0, 30.0])
    t = propagate(g, phi, lam)
    f = np.asarray(t[:, :, None] * phi)            # session link flows
    inject = np.asarray(g.injection(lam))
    inflow = f.sum(1) + inject                     # [W, Nb]
    outflow = f.sum(2)
    # at non-sink nodes, t_i(w) = inflow; outflow = t_i (rows are stochastic
    # wherever t>0), so inflow == outflow off the sinks
    sinks = np.asarray(g.sinks)
    mask = np.ones(g.n_bar, bool)
    mask[sinks] = False
    np.testing.assert_allclose(inflow[:, mask], outflow[:, mask],
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("cost_name", ["exp", "mm1", "linear", "quad"])
def test_marginal_broadcast_matches_autodiff(er25_cec, cost_name):
    """Gallager's recursion (eq. 18–21) == jax.grad of the flow model."""
    g = er25_cec
    cost = get_cost(cost_name)
    phi = random_phi(g, 7)
    lam = jnp.array([15.0, 20.0, 25.0])

    _, t, F = cost_and_state(g, cost, phi, lam)
    delta, _ = marginals(g, cost, phi, t, F)
    analytic = np.asarray(phi_gradient(t, delta))

    auto = np.asarray(jax.grad(
        lambda p: total_cost(g, cost, p, lam))(phi))
    m = np.asarray(g.out_mask) > 0
    np.testing.assert_allclose(analytic[m], auto[m], rtol=2e-3, atol=2e-3)


def test_cost_derivatives_match_value_grad():
    """CostFn.deriv must equal d/dF of CostFn.value (all registry entries)."""
    F = jnp.linspace(0.0, 40.0, 97)
    C = jnp.full_like(F, 10.0)
    for name in ["exp", "mm1", "linear", "quad"]:
        c = get_cost(name)
        g = jax.vmap(jax.grad(lambda f, cc: c.value(f, cc)))(F, C)
        np.testing.assert_allclose(np.asarray(g), np.asarray(c.deriv(F, C)),
                                   rtol=1e-4, atol=1e-5, err_msg=name)


def test_link_flow_additivity(small_cec):
    """F_ij = Σ_w t_i(w)φ_ij(w): doubling Λ doubles every link flow."""
    g = small_cec
    phi = random_phi(g, 3)
    lam = jnp.array([5.0, 7.0, 9.0])
    F1 = link_flows(g, phi, propagate(g, phi, lam))
    F2 = link_flows(g, phi, propagate(g, phi, 2 * lam))
    np.testing.assert_allclose(np.asarray(F2), 2 * np.asarray(F1),
                               rtol=1e-5, atol=1e-5)
