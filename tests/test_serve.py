"""Serving plane: continuous-batching engine correctness + CEC router."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import build_random_cec
from repro.models import model as M
from repro.serve import CECRouter, InferenceEngine, Request
from repro.topo import connected_er


def _cfg():
    return dataclasses.replace(get_config("smollm-135m", smoke=True),
                               dtype="float32")


def test_continuous_batching_matches_sequential():
    """Ragged slots (different arrival times/lengths) must produce the
    same tokens as decoding each request alone."""
    cfg = _cfg()
    params = M.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (5, 9, 3)]

    # sequential reference
    def solo(prompt, new=6):
        lg, cache = M.prefill(cfg, params, {"tokens": jnp.asarray(prompt)[None]},
                              max_len=32)
        out = [int(jnp.argmax(lg[0]))]
        for _ in range(new - 1):
            lg, cache = M.decode_step(
                cfg, params, jnp.asarray([[out[-1]]], jnp.int32), cache)
            out.append(int(jnp.argmax(lg[0])))
        return out

    want = [solo(p) for p in prompts]

    # max_batch < #requests forces queueing → ragged slot reuse
    eng2 = InferenceEngine(cfg, params, max_batch=2, max_len=32)
    reqs = [Request(i, p, max_new_tokens=6) for i, p in enumerate(prompts)]
    for r in reqs:
        eng2.submit(r)
    eng2.drain()
    for r, w in zip(reqs, want):
        assert r.output[:6] == w, (r.rid, r.output, w)


def test_engine_serves_all_under_slot_pressure():
    cfg = _cfg()
    params = M.init(cfg, jax.random.PRNGKey(0))
    eng = InferenceEngine(cfg, params, max_batch=2, max_len=24)
    rng = np.random.default_rng(1)
    reqs = [Request(i, rng.integers(0, cfg.vocab, 4).astype(np.int32),
                    max_new_tokens=4) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.drain()
    assert all(r.done for r in reqs)
    assert eng.tokens_served >= 5 * 3


def test_cec_router_dispatch_consistency():
    g = build_random_cec(connected_er(10, 0.35, seed=2), 3, 20.0, seed=0)
    router = CECRouter(g, lam_total=12.0)
    split = router.admission_split()
    np.testing.assert_allclose(split.sum(), 1.0, atol=1e-6)
    w = router.replica_weights()
    dep = np.asarray(g.deploy)
    # weights live only on deploying replicas and sum to 1 per version
    assert (w[~dep.astype(bool)] == 0).all()
    np.testing.assert_allclose(w.sum(-1), 1.0, atol=1e-5)

    # a few control steps with a synthetic measured utility improve Λ
    quality = np.array([1.0, 1.5, 2.0])
    for _ in range(5):
        router.control_step(lambda lam: float((quality * lam).sum()) * 0.5)
    lam = np.asarray(router.lam)
    np.testing.assert_allclose(lam.sum(), 12.0, rtol=1e-4)
    assert lam[2] > lam[0]        # shifted toward the higher-quality version


def test_router_topology_change_keeps_feasibility():
    g1 = build_random_cec(connected_er(10, 0.35, seed=2), 3, 20.0, seed=0)
    router = CECRouter(g1, lam_total=12.0)
    router.control_step(lambda lam: float(np.sum(lam)))
    g2 = build_random_cec(connected_er(10, 0.35, seed=7), 3, 20.0, seed=0)
    router.on_topology_change(g2)
    phi = np.asarray(router.phi)
    mask = np.asarray(g2.out_mask)
    assert (phi[mask == 0] == 0).all()
    rows = phi.sum(-1)
    np.testing.assert_allclose(rows[mask.sum(-1) > 0], 1.0, atol=1e-5)
    # churn warm-start: every allowed edge keeps exploration mass — the
    # multiplicative update can only revive what this mix seeds
    assert (phi[mask > 0] > 0).all()


def test_router_consumes_scenario_event_stream():
    """The serving control plane consumes the same declarative events the
    scenario engine sweeps offline (DESIGN.md §10)."""
    from repro.core import DemandShift, NodeFail, Scenario, initial_state

    sc = Scenario("fleet", horizon=10, topo_kwargs={"n": 12, "p": 0.35},
                  mean_capacity=20.0, lam_total=12.0)
    state = initial_state(sc, seed=0)
    router = CECRouter(state.graph(), lam_total=12.0)
    router.control_step(lambda lam: float(np.sum(lam)))

    state = router.apply_scenario_event(state, NodeFail(at=1, count=2,
                                                        seed=4))
    assert state.alive.sum() == 10
    mask = np.asarray(router.graph.out_mask)
    phi = np.asarray(router.phi)
    dead = np.nonzero(~state.alive)[0]
    assert (mask[:, dead, :] == 0).all()          # failed nodes have no edges
    assert (phi[mask == 0] == 0).all()
    assert (phi[mask > 0] > 0).all()              # warm-start exploration
    np.testing.assert_allclose(phi.sum(-1)[mask.sum(-1) > 0], 1.0, atol=1e-5)

    state = router.apply_scenario_event(state, DemandShift(at=2,
                                                           lam_total=18.0))
    assert router.lam_total == 18.0
    np.testing.assert_allclose(np.asarray(router.lam).sum(), 18.0, rtol=1e-4)

    # the router keeps serving after the event stream
    rec = router.control_step(lambda lam: float(np.sum(lam)))
    np.testing.assert_allclose(rec["lam"].sum(), 18.0, rtol=1e-4)
    # dispatch weights stay consistent on the post-churn fleet
    w = router.replica_weights()
    alive_dep = np.asarray(router.graph.deploy)
    assert (w[~alive_dep.astype(bool)] == 0).all()
    np.testing.assert_allclose(w.sum(-1), 1.0, atol=1e-5)
