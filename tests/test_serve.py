"""Serving plane: continuous-batching engine correctness + CEC router."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import build_random_cec, get_cost, total_cost
from repro.core.routing import solve_routing
from repro.models import model as M
from repro.serve import CECRouter, InferenceEngine, Request, ServingSim
from repro.topo import connected_er


def _cfg():
    return dataclasses.replace(get_config("smollm-135m", smoke=True),
                               dtype="float32")


def _solo(cfg, params, prompt, new, max_len=64):
    """Sequential single-request reference with a roomy cache window."""
    lg, cache = M.prefill(cfg, params, {"tokens": jnp.asarray(prompt)[None]},
                          max_len=max_len)
    out = [int(jnp.argmax(lg[0]))]
    for _ in range(new - 1):
        lg, cache = M.decode_step(
            cfg, params, jnp.asarray([[out[-1]]], jnp.int32), cache)
        out.append(int(jnp.argmax(lg[0])))
    return out


def test_continuous_batching_matches_sequential():
    """Ragged slots (different arrival times/lengths) must produce the
    same tokens as decoding each request alone."""
    cfg = _cfg()
    params = M.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (5, 9, 3)]

    # sequential reference
    def solo(prompt, new=6):
        lg, cache = M.prefill(cfg, params, {"tokens": jnp.asarray(prompt)[None]},
                              max_len=32)
        out = [int(jnp.argmax(lg[0]))]
        for _ in range(new - 1):
            lg, cache = M.decode_step(
                cfg, params, jnp.asarray([[out[-1]]], jnp.int32), cache)
            out.append(int(jnp.argmax(lg[0])))
        return out

    want = [solo(p) for p in prompts]

    # max_batch < #requests forces queueing → ragged slot reuse
    eng2 = InferenceEngine(cfg, params, max_batch=2, max_len=32)
    reqs = [Request(i, p, max_new_tokens=6) for i, p in enumerate(prompts)]
    for r in reqs:
        eng2.submit(r)
    eng2.drain()
    for r, w in zip(reqs, want):
        assert r.output[:6] == w, (r.rid, r.output, w)


def test_engine_serves_all_under_slot_pressure():
    cfg = _cfg()
    params = M.init(cfg, jax.random.PRNGKey(0))
    eng = InferenceEngine(cfg, params, max_batch=2, max_len=24)
    rng = np.random.default_rng(1)
    reqs = [Request(i, rng.integers(0, cfg.vocab, 4).astype(np.int32),
                    max_new_tokens=4) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.drain()
    assert all(r.done for r in reqs)
    assert eng.tokens_served >= 5 * 3


def test_engine_max_len_boundary():
    """Prompt + generation at/over the cache window: every decode write
    must stay inside the grafted window (the old ``>=`` check let a
    window-filling prompt's first decode write one slot past it) and the
    truncated tokens must match a roomy-window reference — corruption from
    an out-of-window write would diverge them."""
    cfg = _cfg()
    params = M.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    L = 16
    for S in (L - 3, L - 1, L):
        prompt = rng.integers(0, cfg.vocab, S).astype(np.int32)
        eng = InferenceEngine(cfg, params, max_batch=2, max_len=L)
        req = Request(0, prompt, max_new_tokens=8)
        eng.submit(req)
        eng.drain()
        # window capacity: prefill holds S entries and emits one token;
        # each further token costs one cache write at index S+k
        want_n = min(8, L - S + 1)
        assert len(req.output) == want_n, (S, req.output)
        assert req.output == _solo(cfg, params, prompt, want_n)


def test_engine_rejects_oversized_prompt_and_caps_generation():
    cfg = _cfg()
    params = M.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    eng = InferenceEngine(cfg, params, max_batch=2, max_len=12)
    with pytest.raises(ValueError):
        eng.submit(Request(0, rng.integers(0, cfg.vocab, 13).astype(np.int32)))
    # max_new_tokens=1 is satisfied by the prefill token alone — the old
    # admit path still scheduled a decode step and over-generated
    req = Request(1, rng.integers(0, cfg.vocab, 4).astype(np.int32),
                  max_new_tokens=1)
    eng.submit(req)
    eng.drain()
    assert len(req.output) == 1


def test_cec_router_dispatch_consistency():
    g = build_random_cec(connected_er(10, 0.35, seed=2), 3, 20.0, seed=0)
    router = CECRouter(g, lam_total=12.0)
    split = router.admission_split()
    np.testing.assert_allclose(split.sum(), 1.0, atol=1e-6)
    w = router.replica_weights()
    dep = np.asarray(g.deploy)
    # weights live only on deploying replicas and sum to 1 per version
    assert (w[~dep.astype(bool)] == 0).all()
    np.testing.assert_allclose(w.sum(-1), 1.0, atol=1e-5)

    # a few control steps with a synthetic measured utility improve Λ
    quality = np.array([1.0, 1.5, 2.0])
    for _ in range(5):
        router.control_step(lambda lam: float((quality * lam).sum()) * 0.5)
    lam = np.asarray(router.lam)
    np.testing.assert_allclose(lam.sum(), 12.0, rtol=1e-4)
    assert lam[2] > lam[0]        # shifted toward the higher-quality version


def test_router_topology_change_keeps_feasibility():
    g1 = build_random_cec(connected_er(10, 0.35, seed=2), 3, 20.0, seed=0)
    router = CECRouter(g1, lam_total=12.0)
    router.control_step(lambda lam: float(np.sum(lam)))
    g2 = build_random_cec(connected_er(10, 0.35, seed=7), 3, 20.0, seed=0)
    router.on_topology_change(g2)
    phi = np.asarray(router.phi)
    mask = np.asarray(g2.out_mask)
    assert (phi[mask == 0] == 0).all()
    rows = phi.sum(-1)
    np.testing.assert_allclose(rows[mask.sum(-1) > 0], 1.0, atol=1e-5)
    # churn warm-start: every allowed edge keeps exploration mass — the
    # multiplicative update can only revive what this mix seeds
    assert (phi[mask > 0] > 0).all()


def test_router_consumes_scenario_event_stream():
    """The serving control plane consumes the same declarative events the
    scenario engine sweeps offline (DESIGN.md §10)."""
    from repro.core import DemandShift, NodeFail, Scenario, initial_state

    sc = Scenario("fleet", horizon=10, topo_kwargs={"n": 12, "p": 0.35},
                  mean_capacity=20.0, lam_total=12.0)
    state = initial_state(sc, seed=0)
    router = CECRouter(state.graph(), lam_total=12.0)
    router.control_step(lambda lam: float(np.sum(lam)))

    state = router.apply_scenario_event(state, NodeFail(at=1, count=2,
                                                        seed=4))
    assert state.alive.sum() == 10
    mask = np.asarray(router.graph.out_mask)
    phi = np.asarray(router.phi)
    dead = np.nonzero(~state.alive)[0]
    assert (mask[:, dead, :] == 0).all()          # failed nodes have no edges
    assert (phi[mask == 0] == 0).all()
    assert (phi[mask > 0] > 0).all()              # warm-start exploration
    np.testing.assert_allclose(phi.sum(-1)[mask.sum(-1) > 0], 1.0, atol=1e-5)

    state = router.apply_scenario_event(state, DemandShift(at=2,
                                                           lam_total=18.0))
    assert router.lam_total == 18.0
    np.testing.assert_allclose(np.asarray(router.lam).sum(), 18.0, rtol=1e-4)

    # the router keeps serving after the event stream
    rec = router.control_step(lambda lam: float(np.sum(lam)))
    np.testing.assert_allclose(rec["lam"].sum(), 18.0, rtol=1e-4)
    # dispatch weights stay consistent on the post-churn fleet
    w = router.replica_weights()
    alive_dep = np.asarray(router.graph.deploy)
    assert (w[~alive_dep.astype(bool)] == 0).all()
    np.testing.assert_allclose(w.sum(-1), 1.0, atol=1e-5)


def test_control_step_parity_with_reference_loop():
    """The fused device-resident step reproduces the per-observation host
    loop (the pre-PR-3 router semantics, preserved verbatim as
    ``benchmarks.bench_router._legacy_control_step`` — one reference, the
    bench's speedup baseline and this parity oracle) within 1e-5."""
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks.bench_router import _legacy_control_step

    g = build_random_cec(connected_er(12, 0.35, seed=4), 3, 15.0, seed=1)
    cost = get_cost("exp")
    quality = np.array([1.0, 1.4, 1.8])
    scalar_fn = lambda lam: float((quality * np.asarray(lam)).sum())

    router = CECRouter(g, lam_total=12.0)
    want_lam, phi = _legacy_control_step(
        g, cost, jnp.asarray(router.lam), g.uniform_phi(), 12.0, scalar_fn,
        delta=router.delta, eta_outer=router.eta_outer,
        eta_inner=router.eta_inner)
    # ... plus the committed observation the fused step appends
    want_phi, _ = solve_routing(g, cost, want_lam, phi, router.eta_inner, 1)
    want_cost = float(total_cost(g, cost, want_phi, want_lam))

    rec = router.control_step(scalar_fn)
    np.testing.assert_allclose(rec["lam"], np.asarray(want_lam),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(router.phi), np.asarray(want_phi),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(rec["cost"], want_cost, rtol=1e-5, atol=1e-5)


def test_router_under_churn_recovers_utility():
    """Live mirror of ``test_link_churn_recovers_pre_event_utility``: the
    router consumes the named link_churn timeline mid-serving and the
    measured network utility re-crosses 95% of its pre-event level within
    the post-event budget (DESIGN.md §11)."""
    from repro.core import event_schedule, initial_state, named_scenarios

    sc = named_scenarios(horizon=40, n=12, p=0.35)["link_churn"]
    state = initial_state(sc, seed=0)
    bank = state.bank

    def measured(lams):                       # batched bank observation
        return np.asarray(jax.vmap(bank.total)(jnp.asarray(lams)))

    router = CECRouter(state.graph(), lam_total=sc.lam_total)
    schedule = {at: evs for at, evs in event_schedule(sc) if evs}
    utilities = []
    for t in range(sc.horizon):
        for ev in schedule.get(t, ()):
            state = router.apply_scenario_event(state, ev)
        utilities.append(router.control_step(measured)["utility"])
    u = np.asarray(utilities)
    (t0,) = sc.event_times                    # the rewire boundary
    pre = u[t0 - 5:t0].mean()
    post = u[t0:]
    recovered = post >= 0.95 * pre
    assert recovered.any() and int(np.argmax(recovered)) <= 30
    assert post[-1] >= 0.95 * pre             # and it holds at the end


def test_serving_sim_end_to_end():
    """Engine traffic + fused router + scenario events in one loop: the
    serving counterpart of run_scenario (what is benchmarked is what
    serves)."""
    from repro.core import NodeFail, Scenario

    cfg = _cfg()
    params = M.init(cfg, jax.random.PRNGKey(0))
    sc = Scenario("fleet", horizon=6, topo_kwargs={"n": 12, "p": 0.35},
                  n_sessions=3, mean_capacity=20.0, lam_total=12.0,
                  events=(NodeFail(at=3, count=1, seed=4),))
    sim = ServingSim(sc, cfg=cfg, params=params, seed=0,
                     requests_per_interval=4, engine_steps_per_interval=6,
                     prompt_len=4, max_new_tokens=3, max_batch=2, max_len=24)
    rep = sim.run()
    assert rep.utility.shape == (6,) and np.isfinite(rep.utility).all()
    assert rep.tokens_served > 0 and rep.tokens.sum() == rep.tokens_served
    assert [k for _, k in rep.events] == ["NodeFail"]
    # admission split stays feasible through the event
    np.testing.assert_allclose(rep.lam.sum(-1), 12.0, rtol=1e-4)
    assert (rep.goodput > 0).all()


def test_router_migrates_to_learned_and_drift_demotes():
    """grad_policy="auto": the router samples until the fitter's holdout
    clears, migrates to learned gradients (1 measured admission per
    interval instead of 2W+1), and demotes itself when the measured
    environment moves from under the surrogate (DESIGN.md §16.4)."""
    from repro.core import make_bank

    g = build_random_cec(connected_er(10, 0.35, seed=2), 3, 20.0, seed=0)
    W = g.n_sessions
    bank = make_bank("log", W, seed=0)
    scale = [1.0]

    def util(lams):
        lams = np.atleast_2d(np.asarray(lams))
        return scale[0] * np.asarray(
            jax.vmap(bank.total)(jnp.asarray(lams)))

    router = CECRouter(g, lam_total=12.0, grad_policy="auto",
                       util_family="log")
    router.fitter.min_samples, router.fitter.refit_every = 20, 8
    router.fitter.fit_steps = 800
    for _ in range(12):
        rec = router.control_step(util)
    assert rec["mode"] == "learned"
    assert rec["oracle_calls"] == 1
    modes = [h["mode"] for h in router.history if "mode" in h]
    assert modes[0] == "sampled"
    assert {h["oracle_calls"] for h in router.history
            if h.get("mode") == "sampled"} == {2 * W + 1}
    # the environment moves hard: measured utilities scale 2.5× — the
    # drift EMA crosses its threshold and the router falls back
    scale[0] = 2.5
    demoted = False
    for _ in range(6):
        rec = router.control_step(util)
        demoted = demoted or rec["mode"] == "sampled"
    assert demoted


def test_router_learned_pinned_policy_stays_learned():
    """grad_policy="learned" is the pinned variant: drift is tracked but
    never demotes."""
    from repro.core import make_bank

    g = build_random_cec(connected_er(10, 0.35, seed=2), 3, 20.0, seed=0)
    bank = make_bank("log", g.n_sessions, seed=0)
    scale = [1.0]

    def util(lams):
        lams = np.atleast_2d(np.asarray(lams))
        return scale[0] * np.asarray(
            jax.vmap(bank.total)(jnp.asarray(lams)))

    router = CECRouter(g, lam_total=12.0, grad_policy="learned",
                       util_family="log")
    router.fitter.min_samples, router.fitter.refit_every = 20, 8
    router.fitter.fit_steps = 800
    for _ in range(10):
        rec = router.control_step(util)
    assert rec["mode"] == "learned"
    scale[0] = 2.5
    for _ in range(4):
        rec = router.control_step(util)
        assert rec["mode"] == "learned"


def test_router_rejects_unknown_grad_policy():
    g = build_random_cec(connected_er(10, 0.35, seed=2), 3, 20.0, seed=0)
    with pytest.raises(ValueError, match="grad_policy"):
        CECRouter(g, lam_total=12.0, grad_policy="leraned")
