"""Pallas kernels vs jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import (flash_attention_op, flow_step_op,
                               flow_step_sparse_op, omd_update_op,
                               omd_update_sparse_op)

KEY = jax.random.PRNGKey(0)


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KH,S,T,hd,causal", [
    (1, 4, 4, 128, 128, 64, True),      # MHA causal
    (2, 8, 2, 256, 256, 64, True),      # GQA
    (1, 4, 1, 64, 192, 128, False),     # MQA, non-causal, S != T
    (2, 6, 3, 96, 96, 32, True),        # non-pow2 heads, padded blocks
    (1, 2, 2, 8, 1024, 128, True),      # short q, long kv (decode-ish)
])
def test_flash_attention_matches_ref(B, H, KH, S, T, hd, causal, dtype):
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (B, H, S, hd), dtype)
    k = _rand(ks[1], (B, KH, T, hd), dtype)
    v = _rand(ks[2], (B, KH, T, hd), dtype)
    got = flash_attention_op(q, k, v, causal=causal, interpret=True)
    want = ref.mha_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_q_offset_and_kv_len():
    """Decode semantics: queries placed at the cache tail, padding masked."""
    B, H, S, T, hd = 1, 4, 8, 256, 64
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (B, H, S, hd), jnp.float32)
    k = _rand(ks[1], (B, H, T, hd), jnp.float32)
    v = _rand(ks[2], (B, H, T, hd), jnp.float32)
    got = flash_attention_op(q, k, v, causal=True, q_offset=100, kv_len=108,
                             interpret=True)
    want = ref.mha_ref(q, k, v, causal=True, q_offset=100, kv_len=108)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("W,N", [(3, 29), (1, 128), (4, 200), (2, 384)])
def test_flow_step_matches_ref(W, N, dtype):
    ks = jax.random.split(KEY, 3)
    t = jnp.abs(_rand(ks[0], (W, N), dtype))
    phi = jnp.abs(_rand(ks[1], (W, N, N), dtype))
    inj = jnp.abs(_rand(ks[2], (W, N), dtype))
    got = flow_step_op(t, phi, inj)
    want = ref.flow_step_ref(t, phi, inj)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("W,N,eta", [(3, 29, 0.5), (2, 128, 3.0),
                                     (1, 257, 1.0)])
def test_omd_update_matches_ref(W, N, eta):
    ks = jax.random.split(KEY, 3)
    mask = (jax.random.uniform(ks[0], (W, N, N)) > 0.5).astype(jnp.float32)
    raw = jnp.abs(_rand(ks[1], (W, N, N), jnp.float32)) * mask
    s = raw.sum(-1, keepdims=True)
    phi = jnp.where(s > 0, raw / jnp.where(s > 0, s, 1), 0.0)
    delta = jnp.abs(_rand(ks[2], (W, N, N), jnp.float32)) * 5
    got = omd_update_op(phi, delta, mask, eta)
    want = ref.omd_update_ref(phi, delta, mask, eta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # rows remain stochastic
    rows = np.asarray(got).sum(-1)
    has = np.asarray(mask).sum(-1) > 0
    np.testing.assert_allclose(rows[has], 1.0, atol=1e-5)


def test_omd_kernel_agrees_with_core_routing_step(er25_cec):
    """End-to-end: the kernel reproduces core.routing.omd_step's update."""
    from repro.core import get_cost, omd_step
    from repro.core.flow import cost_and_state
    from repro.core.marginal import marginals

    g = er25_cec
    cost = get_cost("exp")
    lam = jnp.array([20.0, 20.0, 20.0])
    phi = g.uniform_phi()
    _, t, F = cost_and_state(g, cost, phi, lam)
    delta, _ = marginals(g, cost, phi, t, F)
    want = omd_step(g, cost, phi, lam, 1.0).phi
    got = omd_update_op(phi, delta, g.out_mask, 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_flow_kernel_agrees_with_core_propagate(er25_cec):
    from repro.core.flow import propagate

    g = er25_cec
    lam = jnp.array([10.0, 20.0, 30.0])
    phi = g.uniform_phi()
    inject = g.injection(lam)
    t = inject
    for _ in range(g.depth_max):
        t = flow_step_op(t, phi, inject)
    want = propagate(g, phi, lam)
    np.testing.assert_allclose(np.asarray(t), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("W,N,D,Din", [(3, 29, 6, 5), (1, 128, 16, 16),
                                       (2, 200, 9, 3), (3, 64, 130, 140)])
def test_flow_step_sparse_matches_ref(W, N, D, Din):
    """Sparse gather step vs oracle over random in-lists (incl. >128 slots)."""
    rng = np.random.default_rng(N * 7 + D)
    t = jnp.asarray(rng.uniform(0, 2, (W, N)), jnp.float32)
    rows = jnp.asarray(rng.uniform(0, 1, (W, N, D)), jnp.float32)
    base = jnp.asarray(rng.uniform(0, 1, (W, N)), jnp.float32)
    in_src = jnp.asarray(rng.integers(0, N, (N, Din)), jnp.int32)
    in_slot = jnp.asarray(rng.integers(0, D, (N, Din)), jnp.int32)
    in_mask = jnp.asarray(rng.random((N, Din)) > 0.4, jnp.float32)
    got = flow_step_sparse_op(t, rows, base, in_src, in_slot, in_mask)
    want = ref.flow_step_sparse_ref(t, rows, base, in_src, in_slot, in_mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("W,R,C,eta", [(3, 29, 7, 0.5), (2, 128, 130, 3.0),
                                       (1, 257, 2, 1.0), (3, 1, 40, 1.0)])
def test_omd_update_sparse_matches_ref(W, R, C, eta):
    """Rectangular [W, R, C] slot rows (incl. the 1-row source layout)."""
    ks = jax.random.split(KEY, 3)
    mask = (jax.random.uniform(ks[0], (W, R, C)) > 0.5).astype(jnp.float32)
    raw = jnp.abs(_rand(ks[1], (W, R, C), jnp.float32)) * mask
    s = raw.sum(-1, keepdims=True)
    phi = jnp.where(s > 0, raw / jnp.where(s > 0, s, 1), 0.0)
    delta = jnp.abs(_rand(ks[2], (W, R, C), jnp.float32)) * 5
    got = omd_update_sparse_op(phi, delta, mask, eta)
    want = ref.omd_update_sparse_ref(phi, delta, mask, eta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    rows = np.asarray(got).sum(-1)
    has = np.asarray(mask).sum(-1) > 0
    np.testing.assert_allclose(rows[has], 1.0, atol=1e-5)


def test_sparse_kernels_agree_with_core_sparse_step(er25_cec):
    """End-to-end: kernels reproduce core.sparse's jnp relay/update math."""
    from repro.core import get_cost, sparsify
    from repro.core import sparse as sp
    from repro.core.flow import cost_and_state
    from repro.core.marginal import marginals

    gs = sparsify(er25_cec)
    cost = get_cost("exp")
    lam = jnp.array([20.0, 20.0, 20.0])
    phi = gs.uniform_phi()
    base = sp.source_inflow(gs, phi, lam)
    t0 = gs.injection(lam)
    got = flow_step_sparse_op(t0, phi.rows, base, gs.in_src, gs.in_slot,
                              gs.in_mask)
    want = base + sp._relay_inflow(gs, phi.rows, t0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    _, t, F = cost_and_state(gs, cost, phi, lam)
    delta, _ = marginals(gs, cost, phi, t, F)
    upd = omd_update_sparse_op(phi.rows, delta.rows, gs.out_mask, 1.0)
    want_upd = sp.eg_update(phi.rows, delta.rows, gs.out_mask, 1.0)
    np.testing.assert_allclose(np.asarray(upd), np.asarray(want_upd),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,di,ds", [(2, 128, 128, 16), (1, 256, 64, 8),
                                       (2, 96, 200, 16)])
def test_mamba_scan_matches_ref(B, S, di, ds, dtype):
    from repro.kernels.ops import mamba_scan_op

    ks = jax.random.split(KEY, 5)
    u = _rand(ks[0], (B, S, di), dtype)
    dt = jnp.abs(_rand(ks[1], (B, S, di), dtype)) * 0.1
    A = -jnp.abs(_rand(ks[2], (di, ds), jnp.float32))
    Bm = _rand(ks[3], (B, S, ds), dtype)
    Cm = _rand(ks[4], (B, S, ds), dtype)
    got = mamba_scan_op(u, dt, A, Bm, Cm)
    want = ref.mamba_scan_ref(u, dt, A, Bm, Cm)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_mamba_kernel_matches_model_layer_scan():
    """The kernel agrees with the layers._mamba_scan training path."""
    from repro.kernels.ops import mamba_scan_op
    from repro.models.layers import _mamba_scan

    ks = jax.random.split(KEY, 5)
    B, S, di, ds = 2, 128, 64, 16
    u = _rand(ks[0], (B, S, di), jnp.float32)
    dt = jnp.abs(_rand(ks[1], (B, S, di), jnp.float32)) * 0.1
    A = -jnp.abs(_rand(ks[2], (di, ds), jnp.float32))
    Bm = _rand(ks[3], (B, S, ds), jnp.float32)
    Cm = _rand(ks[4], (B, S, ds), jnp.float32)
    want, _ = _mamba_scan(u, dt, A, Bm, Cm, None)
    got = mamba_scan_op(u, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
