"""API-surface snapshot: the public entry points are a contract.

Pins ``repro.__all__`` and the exact ``inspect.signature`` of every
public solver entry point, so signature drift (a renamed keyword, a
changed default, a dropped parameter) fails tier-1 instead of silently
breaking downstream callers.  Intentional changes must update the
snapshot here *and* the DESIGN.md §13 migration table in the same
commit.
"""
import dataclasses
import inspect

import repro


def _sig(fn) -> str:
    return str(inspect.signature(fn))


# The pinned surface: name -> exact signature string.  These are the
# entry points the shim-parity suite (tests/test_solver_core.py) proves
# equivalent; their keywords are load-bearing for examples/, benchmarks/
# and external callers.
PINNED_SIGNATURES = {
    # -- the solver core --------------------------------------------------
    "init": "(problem: 'Problem', config: 'SolverConfig', *, phi0=None, "
            "lam0: 'Array | None' = None) -> 'SolverState'",
    "step": "(problem: 'Problem', config: 'SolverConfig', "
            "state: 'SolverState', task_utilities: 'Array', telemetry=None) "
            "-> 'tuple[SolverState, StepInfo] | tuple'",
    "run": "(problem: 'Problem', config: 'SolverConfig', *, iters: 'int', "
           "state: 'SolverState | None' = None, phi0=None, "
           "lam0: 'Array | None' = None) -> 'Result'",
    "fused_step": "(config: 'SolverConfig', *, donate: 'bool' = False)",
    "run_batch": "(batch: 'CECGraphBatch | CECGraphSparseBatch', "
                 "banks: 'UtilityBank | Sequence[UtilityBank]', lam_total, "
                 "config: 'SolverConfig', *, iters: 'int', cost='exp', "
                 "state: 'SolverState | None' = None, "
                 "phi0: 'Array | None' = None, "
                 "lam0: 'Array | None' = None) -> '_solver.Result'",
    "run_batch_sharded": "(batch: 'CECGraphBatch | CECGraphSparseBatch', "
                         "banks: 'UtilityBank | Sequence[UtilityBank]', "
                         "lam_total, config: 'SolverConfig', *, "
                         "iters: 'int', cost='exp', mesh=None, "
                         "state: 'SolverState | None' = None, "
                         "phi0: 'Array | None' = None, "
                         "lam0: 'Array | None' = None) -> '_solver.Result'",
    # -- legacy shims (keyword-compatible, frozen) ------------------------
    "solve_jowr":
        "(graph: 'CECGraph', bank: 'UtilityBank', lam_total: 'float', *, "
        "method: 'Method' = 'single', cost_name: 'str' = 'exp', "
        "delta: 'float' = 0.5, eta_outer: 'float' = 0.05, "
        "eta_inner: 'float' = 0.05, outer_iters: 'int' = 100, "
        "inner_iters: 'int' = 50, phi0=None, lam0=None) -> 'JOWRResult'",
    "gs_oma":
        "(graph: 'CECGraph', cost: 'CostFn', bank: 'UtilityBank', "
        "lam_total: 'float', *, delta: 'float' = 0.5, "
        "eta_outer: 'float' = 0.05, eta_inner: 'float' = 0.05, "
        "outer_iters: 'int' = 100, inner_iters: 'int' = 50, "
        "phi0: 'Array | None' = None, lam0: 'Array | None' = None) "
        "-> 'JOWRResult'",
    "omad":
        "(graph: 'CECGraph', cost: 'CostFn', bank: 'UtilityBank', "
        "lam_total: 'float', *, delta: 'float' = 0.5, "
        "eta_outer: 'float' = 0.05, eta_inner: 'float' = 0.05, "
        "outer_iters: 'int' = 100, phi0=None, lam0=None) -> 'JOWRResult'",
    "solve_jowr_batch":
        "(batch: 'CECGraphBatch | CECGraphSparseBatch', "
        "banks: 'UtilityBank | Sequence[UtilityBank]', lam_total: 'float', "
        "*, method: 'Method' = 'single', cost_name: 'str' = 'exp', "
        "delta: 'float' = 0.5, eta_outer: 'float' = 0.05, "
        "eta_inner: 'float' = 0.05, outer_iters: 'int' = 100, "
        "inner_iters: 'int' = 50, phi0: 'Array | None' = None, "
        "lam0: 'Array | None' = None) -> 'JOWRResult'",
    "solve_routing":
        "(graph: 'CECGraph | CECGraphSparse', cost: 'CostFn', "
        "lam: 'Array', phi0, eta: 'float', n_iters: 'int') "
        "-> 'tuple[Array, Array]'",
    "run_scenario":
        "(scenario: 'Scenario', *, seeds: 'Sequence[int]' = (0,), "
        "method: 'Method' = 'single', cost_name: 'str' = 'exp', "
        "delta: 'float' = 0.5, eta_outer: 'float' = 0.05, "
        "eta_inner: 'float' = 3.0, inner_iters: 'int' = 1, "
        "explore: 'float' = 0.1, config: 'SolverConfig | None' = None, "
        "mesh=None) -> 'ScenarioResult'",
}

PINNED_ALL = [
    "Problem", "SolverConfig", "SolverState", "StepInfo", "Result",
    "init", "step", "run", "fused_step", "run_batch", "run_batch_sharded",
    "paper_defaults", "serving_defaults",
    "solve_jowr", "gs_oma", "omad", "solve_jowr_batch", "solve_routing",
    "run_scenario", "Scenario", "scenario_metrics", "named_scenarios",
    "CECGraph", "CECGraphSparse", "CECGraphBatch", "UtilityBank",
    "build_random_cec", "build_augmented", "build_augmented_sparse",
    "make_bank", "get_cost", "resolve_cost",
    "UtilityFamily", "get_family", "fit_utilities", "OnlineFitter",
    "fixed_point_solve", "tune_etas",
    "CECRouter", "InferenceEngine", "ServingSim",
    "core", "configs", "topo", "kernels", "serve", "parallel",
    "models", "train", "optim", "data", "launch", "roofline",
    "obs",
]

PINNED_SOLVER_CONFIG_FIELDS = (
    "method", "delta", "eta_outer", "eta_inner", "inner_iters", "grad_mode",
    "telemetry")
PINNED_SOLVER_STATE_FIELDS = ("lam", "phi", "t")
PINNED_RESULT_FIELDS = ("lam", "phi", "utility_traj", "lam_traj",
                        "cost_traj", "grad_traj", "state", "telemetry")
PINNED_ROUTER_FIELDS = ("graph", "lam_total", "delta", "eta_outer",
                        "eta_inner", "inner_iters", "cost_name", "config",
                        "grad_policy", "util_family", "telemetry")


def test_repro_all_is_pinned():
    assert list(repro.__all__) == PINNED_ALL


def test_every_exported_name_resolves():
    for name in repro.__all__:
        assert getattr(repro, name) is not None, name


def test_entry_point_signatures_are_pinned():
    drift = {}
    for name, want in PINNED_SIGNATURES.items():
        got = _sig(getattr(repro, name))
        if got != want:
            drift[name] = (want, got)
    assert not drift, (
        "public entry-point signature drift (update this snapshot AND the "
        f"DESIGN.md §13 migration table intentionally): {drift}")


def test_dataclass_and_state_fields_are_pinned():
    from repro.core import Result, SolverConfig, SolverState
    from repro.serve import CECRouter

    assert tuple(f.name for f in dataclasses.fields(SolverConfig)) == \
        PINNED_SOLVER_CONFIG_FIELDS
    assert SolverState._fields == PINNED_SOLVER_STATE_FIELDS
    assert Result._fields == PINNED_RESULT_FIELDS
    assert tuple(f.name for f in dataclasses.fields(CECRouter)) == \
        PINNED_ROUTER_FIELDS


def test_legacy_result_shapes_are_pinned():
    from repro.core import ControlStep, JOWRResult

    assert JOWRResult._fields == ("lam", "phi", "utility_traj", "lam_traj")
    # t rides at the END so positional unpacking of the first four legacy
    # fields keeps working (the t-threading bugfix — legacy loops used to
    # have their counter silently reset to 0 every call)
    assert ControlStep._fields == ("lam", "phi", "grad", "cost", "t")


def test_solver_core_is_the_only_update_site():
    """The bandit engine's mirror-ascent exp-reweighting lives exactly
    once in src/ — in core/solver.py.  The genie comparator
    (core/opt_baseline.py, true-gradient, no box projection) is a
    deliberately *different* algorithm and the one allowed look-alike;
    the pre-PR-3 host loop preserved in benchmarks/bench_router.py is
    the one allowed copy outside src/.  The control megakernel
    (kernels/control_megakernel.py, DESIGN.md §17) is the one allowed
    copy *inside* src/: the fused kernel must carry the update in its
    own body by construction, and tests/test_megakernel.py pins it to
    solver.step at ≤1e-5 so the copies cannot drift apart silently."""
    import pathlib

    src = pathlib.Path(repro.__file__).parent
    hits = [p.relative_to(src).as_posix()
            for p in sorted(src.rglob("*.py"))
            if "jnp.exp(z)" in p.read_text()]
    assert hits == ["core/opt_baseline.py", "core/solver.py",
                    "kernels/control_megakernel.py"], hits
