"""Fault tolerance: atomic checkpoints, restart, elastic reshard, runner
recovery, data-pipeline determinism."""
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import SyntheticLM, batch_for_step
from repro.optim import AdamW
from repro.train import checkpoint as ckpt


def make_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": jnp.ones((3, 3), jnp.bfloat16)}}


def test_save_restore_roundtrip(tmp_path):
    tree = make_tree()
    ckpt.save(tmp_path, 7, tree, extra={"next_step": 7})
    assert ckpt.latest_step(tmp_path) == 7
    like = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)
    got, extra = ckpt.restore(tmp_path, 7, like)
    assert extra["next_step"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_retention_keeps_last_k(tmp_path):
    tree = make_tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, s, tree, keep=2)
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert steps == ["step_00000004", "step_00000005"]


def test_partial_write_is_invisible(tmp_path):
    """A crash mid-save (leftover .tmp dir) must not corrupt latest_step."""
    tree = make_tree()
    ckpt.save(tmp_path, 3, tree)
    (tmp_path / "step_00000009.tmp").mkdir()
    (tmp_path / "step_00000009.tmp" / "garbage").write_text("x")
    assert ckpt.latest_step(tmp_path) == 3


def test_elastic_reshard_restore(tmp_path):
    """Restore onto a different mesh: the elastic-scaling path."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    code = f"""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import checkpoint as ckpt
tree = {{"w": jnp.arange(64.0).reshape(8, 8)}}
mesh1 = jax.make_mesh((4, 2), ("data", "model"))
sh1 = {{"w": NamedSharding(mesh1, P("data", "model"))}}
t1 = jax.tree_util.tree_map(jax.device_put, tree, sh1)
ckpt.save(r"{tmp_path}", 1, t1)
# restore onto a DIFFERENT mesh shape (simulating node loss: 8 -> 4 devs)
mesh2 = jax.sharding.Mesh(np.array(jax.devices()[:4]).reshape(2, 2),
                          ("data", "model"))
sh2 = {{"w": NamedSharding(mesh2, P("model", "data"))}}
like = {{"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}}
got, _ = ckpt.restore(r"{tmp_path}", 1, like, sh2)
assert got["w"].sharding.mesh.shape == {{"data": 2, "model": 2}}
np.testing.assert_array_equal(np.asarray(got["w"]),
                              np.arange(64.0).reshape(8, 8))
print("ELASTIC_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, cwd="/root/repo")
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr


def test_runner_retry_and_resume(tmp_path):
    """Simulated step failure retries; a fresh runner resumes and the data
    pipeline regenerates identical batches."""
    from repro.configs import get_config
    from repro.models import model as M
    from repro.train.runner import RunnerConfig, TrainRunner
    from repro.train.steps import make_train_step

    cfg = get_config("smollm-135m", smoke=True)
    params = M.init(cfg, jax.random.PRNGKey(0))
    optim = AdamW()
    step_fn = jax.jit(make_train_step(cfg, optim, remat=False))
    data = SyntheticLM(seed=0, global_batch=4, seq_len=32, vocab=cfg.vocab)
    rc = RunnerConfig(total_steps=10, ckpt_every=5,
                      ckpt_dir=str(tmp_path), fail_at=(3,))
    r1 = TrainRunner(rc, step_fn, params, optim.init(params), data)
    out1 = r1.run()
    assert len(out1["metrics"]) == 10

    rc2 = RunnerConfig(total_steps=14, ckpt_every=5, ckpt_dir=str(tmp_path))
    r2 = TrainRunner(rc2, step_fn, params, optim.init(params), data)
    out2 = r2.run()
    steps = [m["step"] for m in out2["metrics"]]
    assert steps[0] == 10 and steps[-1] == 13    # resumed, not restarted


def test_data_pipeline_determinism_and_elasticity():
    b1 = batch_for_step(0, 5, 16, 32, 1000, host_id=0, n_hosts=1)
    again = batch_for_step(0, 5, 16, 32, 1000, host_id=0, n_hosts=1)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(again["tokens"]))
    # re-partitioning over 4 hosts reproduces the same global batch
    parts = [batch_for_step(0, 5, 16, 32, 1000, host_id=h, n_hosts=4)
             for h in range(4)]
    glob = np.concatenate([np.asarray(p["tokens"]) for p in parts])
    np.testing.assert_array_equal(glob, np.asarray(b1["tokens"]))


def test_prefetch_iterator_resumes_mid_stream():
    data = SyntheticLM(seed=1, global_batch=4, seq_len=16, vocab=100)
    it = data.iterate(start_step=7)
    s, b = next(it)
    assert s == 7
    np.testing.assert_array_equal(np.asarray(b["tokens"]),
                                  np.asarray(data.batch(7)["tokens"]))
