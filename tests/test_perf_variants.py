"""§Perf variant mechanics: int8 KV cache quantization correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import layers as L
from repro.models import model as M


def test_kv_quant_roundtrip_error():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16), jnp.float32)
    q = L.kv_quantize(x, jnp.int8)
    assert q.dtype == jnp.int8
    back = L.kv_dequantize(q, jnp.float32)
    assert float(jnp.abs(back - x).max()) <= L.KV_QUANT_SCALE * 0.51 + 1e-6


def test_int8_kv_decode_close_to_bf16():
    """Decode with an int8 cache tracks the fp32 path (argmax-stable on a
    smoke model with smooth logits)."""
    cfg = dataclasses.replace(get_config("smollm-135m", smoke=True),
                              dtype="float32")
    params = M.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, cfg.vocab)
    batch = {"tokens": toks}

    def run(kv_dtype):
        lg, cache = M.prefill(cfg, params, batch, max_len=16)
        if kv_dtype is not None:
            cache = jax.tree_util.tree_map(lambda x: x, cache)
            # re-quantize by replaying prefill into an int8 cache
            cache_q = M.init_cache(cfg, 2, 16, dtype=kv_dtype)
            lgq, cache = M.prefill(cfg, params, batch, max_len=16)
            for k in cache_q:
                if k == "len":
                    cache_q[k] = cache[k]
                    continue
                cache_q[k] = jax.tree_util.tree_map(
                    lambda tgt, src: L.kv_quantize(src, tgt.dtype)
                    if tgt.dtype == jnp.int8 else src,
                    cache_q[k], cache[k])
            cache = cache_q
        outs = []
        tok = jnp.argmax(lg, -1).astype(jnp.int32)[:, None]
        for _ in range(4):
            lg2, cache = M.decode_step(cfg, params, tok, cache)
            outs.append(np.asarray(lg2))
            tok = jnp.argmax(lg2, -1).astype(jnp.int32)[:, None]
        return outs

    ref = run(None)
    q = run(jnp.int8)
    for a, b in zip(ref, q):
        assert np.isfinite(b).all()
        # logits close enough that relative ordering is mostly preserved
        corr = np.corrcoef(a.ravel(), b.ravel())[0, 1]
        assert corr > 0.98, corr
