"""Non-stationary scenario engine (DESIGN.md §10).

(a) Static parity: an event-free scenario IS the static engine — its
    trajectory must reproduce per-seed ``solve_jowr`` to machine
    precision (the batched segment solve is exactly PR-1's
    ``solve_jowr_batch``; observed bitwise-equal on CPU).
(b) Churn recovery: after a link-rewire event the warm-started solver
    must recover ≥95% of pre-event utility within the post-event budget.
(c) Event semantics: liveness masks keep the node-index space stable,
    demand/bank/capacity events transform the state as declared, and
    ``warm_start_phi`` re-seeds exploration mass everywhere it must.
"""
import numpy as np
import pytest

from repro.core import (BankSwap, CapacityScale, DemandShift, NodeFail,
                        NodeJoin, Rewire, Scenario, apply_event,
                        build_random_cec, compile_segments, initial_state,
                        make_bank, named_scenarios, run_scenario,
                        scenario_metrics, solve_jowr, warm_start_phi)
from repro.topo import connected_er

KW = dict(topology="connected_er", topo_kwargs={"n": 15, "p": 0.3},
          n_sessions=3, mean_capacity=10.0, bank_kind="log", lam_total=60.0)
RECOVERY_FRAC = 0.95
POST_EVENT_BUDGET = 30        # iterations allowed to re-cross the bar


# ---------------------------------------------------------------------------
# (a) static parity
# ---------------------------------------------------------------------------

def test_event_free_scenario_matches_solve_jowr():
    sc = Scenario("steady", horizon=25, **KW)
    seeds = (0, 1)
    res = run_scenario(sc, seeds=seeds, eta_outer=0.05, eta_inner=3.0)
    assert res.utility_traj.shape == (2, 25)
    assert len(res.segments) == 1 and res.segments[0].events == ()
    for b, s in enumerate(seeds):
        g = build_random_cec(connected_er(15, 0.3, seed=1 + s), 3, 10.0,
                             seed=s)
        bank = make_bank("log", 3, seed=s, lam_total=60.0)
        want = solve_jowr(g, bank, 60.0, method="single", eta_outer=0.05,
                          eta_inner=3.0, outer_iters=25)
        np.testing.assert_allclose(np.asarray(res.utility_traj[b]),
                                   np.asarray(want.utility_traj),
                                   rtol=1e-6, atol=1e-5)
        np.testing.assert_allclose(np.asarray(res.lam[b]),
                                   np.asarray(want.lam),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(res.phi[b]),
                                   np.asarray(want.phi),
                                   rtol=1e-6, atol=1e-6)


def test_run_scenario_is_deterministic():
    sc = named_scenarios(horizon=16, n=12, p=0.35)["link_churn"]
    a = run_scenario(sc, seeds=(0,))
    b = run_scenario(sc, seeds=(0,))
    assert np.array_equal(np.asarray(a.utility_traj),
                          np.asarray(b.utility_traj))


# ---------------------------------------------------------------------------
# (b) churn recovery — the paper's online-adaptation claim, asserted
# ---------------------------------------------------------------------------

def test_link_churn_recovers_pre_event_utility():
    sc = named_scenarios(horizon=60, n=15, p=0.3)["link_churn"]
    res = run_scenario(sc, seeds=(0, 1, 2))
    m = scenario_metrics(res, recovery_frac=RECOVERY_FRAC)
    (ev,) = m["events"]
    assert ev.kinds == ("Rewire",)
    # every seed re-crosses 95% of its pre-event utility ...
    assert ev.recovered_frac == 1.0
    # ... within the post-event budget ...
    assert ev.recovery_iters <= POST_EVENT_BUDGET
    # ... and holds it at segment end (ensemble mean)
    assert ev.u_final >= RECOVERY_FRAC * ev.u_pre
    assert m["dynamic_regret"] >= 0.0          # self-comparator property


# ---------------------------------------------------------------------------
# (c) event + warm-start semantics
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def state0():
    return initial_state(Scenario("s", horizon=10, **KW), seed=0)


def test_node_fail_join_round_trip(state0):
    failed = apply_event(state0, NodeFail(at=1, count=3, seed=2))
    assert failed.alive.sum() == state0.alive.sum() - 3
    g = failed.graph()                       # feasible by construction
    dead = np.nonzero(~failed.alive)[0]
    out = np.asarray(g.out_mask)
    assert (out[:, dead, :] == 0).all() and (out[:, :, dead] == 0).all()
    # fail → join(all) restores the exact original augmented graph:
    # the index space never moved, deployment rows were only masked
    joined = apply_event(failed, NodeJoin(at=2))
    assert (joined.alive == state0.alive).all()
    np.testing.assert_array_equal(np.asarray(joined.graph().out_mask),
                                  np.asarray(state0.graph().out_mask))


def test_node_fail_never_strands_a_version(state0):
    for ev_seed in range(5):
        st = apply_event(state0, NodeFail(at=1, count=4, seed=ev_seed))
        assert (st.deploy[:, st.alive].sum(1) > 0).all()
        st.graph()                           # must not raise


def test_capacity_demand_bank_events(state0):
    st = apply_event(state0, CapacityScale(at=1, link=0.5, compute=2.0))
    np.testing.assert_allclose(st.link_capacity,
                               0.5 * state0.link_capacity)
    np.testing.assert_allclose(st.compute_capacity,
                               2.0 * state0.compute_capacity)
    st = apply_event(st, DemandShift(at=2, lam_total=75.0))
    assert st.lam_total == 75.0
    st = apply_event(st, BankSwap(at=3, bank_kind="sqrt", seed=1))
    assert st.bank.kind == "sqrt"
    assert state0.bank.kind == "log"         # originals are never mutated


def test_rewire_preserves_link_count_and_connectivity(state0):
    st = apply_event(state0, Rewire(at=1, frac=0.4, seed=7))
    assert st.adj.sum() == state0.adj.sum()
    assert (st.adj != state0.adj).any()
    st.graph()                               # connected → builds fine


def test_events_outside_horizon_rejected():
    with pytest.raises(ValueError):
        Scenario("bad", horizon=10, events=(Rewire(at=0),), **KW)
    with pytest.raises(ValueError):
        Scenario("bad", horizon=10, events=(Rewire(at=10),), **KW)


def test_compile_segments_share_static_metadata():
    sc = named_scenarios(horizon=20, n=12, p=0.35)["node_failure"]
    segs = compile_segments(sc, seeds=(0, 1))
    assert [s.start for s in segs] == [0, 8, 16]
    assert sum(s.n_iters for s in segs) == 20
    meta = {(s.batch.n_bar, s.batch.depth_max, s.batch.src) for s in segs}
    assert len(meta) == 1                    # one shared XLA program shape


def test_warm_start_phi_seeds_exploration_mass(state0):
    g1 = state0.graph()
    st2 = apply_event(state0, Rewire(at=1, frac=0.5, seed=3))
    g2 = st2.graph()
    phi = warm_start_phi(g1.uniform_phi(), g2.out_mask, explore=0.1)
    phi = np.asarray(phi)
    mask = np.asarray(g2.out_mask)
    assert (phi[mask == 0] == 0).all()
    rows = phi.sum(-1)
    np.testing.assert_allclose(rows[mask.sum(-1) > 0], 1.0, atol=1e-5)
    # every allowed edge — including freshly created ones the old φ never
    # saw — carries strictly positive probability
    assert (phi[mask > 0] > 0).all()


def test_named_catalog_constructs():
    scs = named_scenarios(horizon=40)
    assert {"steady", "link_churn", "node_failure", "capacity_drift",
            "demand_surge", "utility_swap", "flash_crowd"} <= set(scs)
    for sc in scs.values():
        assert list(sc.events) == sorted(sc.events, key=lambda e: e.at)


def test_demand_shift_rescales_allocation():
    sc = Scenario("surge", horizon=12,
                  events=(DemandShift(at=6, lam_total=75.0),), **KW)
    res = run_scenario(sc, seeds=(0,))
    lam_t = np.asarray(res.lam_traj)[0]      # [T, W]
    np.testing.assert_allclose(lam_t[:6].sum(-1), 60.0, rtol=1e-4)
    np.testing.assert_allclose(lam_t[6:].sum(-1), 75.0, rtol=1e-4)
