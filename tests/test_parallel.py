"""Distribution layer: sharding rules, collectives, annotations, and a
reduced-mesh end-to-end pjit train step executed on 8 fake devices."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # only the property tests skip; the rest of the module still runs
    from hypothesis_stub import given, settings, st

from repro.parallel.collectives import (dequantize_int8,
                                        error_feedback_compress,
                                        quantize_dequantize_int8,
                                        quantize_int8)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), scale=st.floats(1e-3, 1e3))
def test_int8_roundtrip_error_bound(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64,)) * scale, jnp.float32)
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    amax = float(jnp.abs(x).max())
    assert float(jnp.abs(back - x).max()) <= amax / 127.0 + 1e-7


def test_error_feedback_is_unbiased_over_steps():
    """Accumulated EF residual keeps the long-run mean exact."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    resid = jnp.zeros_like(x)
    total = jnp.zeros_like(x)
    n = 50
    for _ in range(n):
        out, resid = error_feedback_compress(x, resid)
        total = total + out
    np.testing.assert_allclose(np.asarray(total / n), np.asarray(x),
                               atol=2e-2)


def test_quantize_dequantize_preserves_zero_and_dtype():
    x = jnp.zeros((8, 8), jnp.bfloat16)
    y = quantize_dequantize_int8(x)
    assert y.dtype == x.dtype
    assert float(jnp.abs(y).max()) == 0.0


def _run_subprocess(code: str, ndev: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={ndev}",
               PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, cwd="/root/repo")
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


def test_ring_all_reduce_matches_psum():
    out = _run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.parallel.collectives import ring_all_reduce
mesh = make_mesh((4, 2), ("data", "model"))
x = jnp.arange(32.0).reshape(8, 4)
got = ring_all_reduce(x, mesh, axis="data")
np.testing.assert_allclose(np.asarray(got), 4 * np.asarray(x), rtol=1e-6)
print("RING_OK")
""")
    assert "RING_OK" in out


def test_pjit_train_step_runs_on_fake_mesh():
    """Real execution (not just lowering) of the sharded train step on a
    2×4 mesh: finite loss/grad-norm with the expected shapes, and the
    loss strictly decreases when the same batch is descended three times.

    Formerly an xfail: the old assert compared losses across *fresh*
    batches under the default cosine schedule (lr = 0 at step 0 — the
    warmup ramp), so the "decrease" was noise and flipped sign across
    jax versions/backends.  Repeating one batch under a constant lr makes
    descent a property of the optimizer, not of batch luck, and holds on
    every backend in the CI matrix.
    """
    out = _run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.data import batch_for_step
from repro.launch.mesh import make_mesh, dp_axes
from repro.models import model as M
from repro.optim import AdamW
from repro.parallel.annotate import activation_sharding
from repro.parallel.sharding import batch_specs, make_shardings, param_specs
from repro.train.steps import make_train_step

cfg = get_config("smollm-135m", smoke=True)
mesh = make_mesh((2, 4), ("data", "model"))
params = M.init(cfg, jax.random.PRNGKey(0))
optim = AdamW()
opt = optim.init(params)
pspec = make_shardings(mesh, param_specs(
    cfg, jax.tree_util.tree_map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), params), mesh))
params = jax.tree_util.tree_map(jax.device_put, params, pspec)
opt = type(opt)(step=opt.step, mu=jax.tree_util.tree_map(jax.device_put, opt.mu, pspec),
                nu=jax.tree_util.tree_map(jax.device_put, opt.nu, pspec))
batch = batch_for_step(0, 0, 4, 32, cfg.vocab)
bspec = make_shardings(mesh, batch_specs(cfg, batch, mesh))
batch = jax.tree_util.tree_map(jax.device_put, batch, bspec)
with mesh, activation_sharding(mesh, dp_axes(mesh)):
    step = jax.jit(make_train_step(cfg, optim, lr_fn=lambda s: 3e-3,
                                   remat=False))
    losses, gnorms = [], []
    for s in range(3):
        params, opt, m = step(params, opt, batch)
        assert m["loss"].shape == (), m["loss"].shape
        losses.append(float(m["loss"]))
        gnorms.append(float(m["grad_norm"]))
assert all(np.isfinite(losses)) and all(np.isfinite(gnorms)), (losses, gnorms)
assert all(g > 0 for g in gnorms), gnorms
assert losses[1] < losses[0] and losses[2] < losses[1], losses
print("LOSSES", losses)
print("PJIT_OK")
""")
    assert "PJIT_OK" in out


def test_param_specs_divisibility_everywhere():
    """Every rule-produced spec must divide its dim for every arch on the
    production meshes (this is what made granite/qwen2-moe compile)."""
    code = """
import jax
from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.parallel.sharding import param_specs

mesh = make_mesh((2, 4), ("data", "model"))
for arch in ARCH_IDS:
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda k: M.init(cfg, k), jax.random.PRNGKey(0))
    specs = param_specs(cfg, shapes, mesh)
    def check(path, leaf, spec):
        for d, e in zip(leaf.shape, tuple(spec) + (None,) * 9):
            if e is None: continue
            axes = e if isinstance(e, tuple) else (e,)
            n = 1
            for a in axes: n *= mesh.shape[a]
            assert d % n == 0, (arch, path, leaf.shape, spec)
    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), shapes, specs)
print("SPECS_OK")
"""
    out = _run_subprocess(code)
    assert "SPECS_OK" in out


def test_annotate_noop_without_mapping():
    from repro.parallel.annotate import data_parallel_size, shard
    x = jnp.ones((4, 4))
    assert shard(x, "batch", "model") is x
    assert data_parallel_size() == 1
