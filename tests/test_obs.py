"""Observability subsystem contracts (ISSUE 10, DESIGN.md §18).

Four tiers, mirroring the subsystem's layering:

* **ring semantics** — wrap-around, chronological ``order``, annotate-
  latest, NaN seeding (``repro.obs.telemetry`` in isolation);
* **zero-cost recording** — ``fused_step`` / ``fused_step_batch`` with
  telemetry enabled donate the ring alongside the state (steady state
  allocates nothing) and never retrace across ≥10 intervals;
* **monitor fidelity** — no false trips on event-free runs of every
  named scenario, the regret monitor's accounting agrees with the
  ``segment_optima`` genie ≤1e-6, verdicts are bit-identical between
  the fleet vmap and per-lane evaluation (this module also runs in the
  CI ``sharded-multidevice`` job under 8 forced CPU devices), and the
  golden Fig. 7 trajectory never trips the descent monitor;
* **export formats** — Chrome trace-event JSON and metrics JSONL are
  valid and carry the spans/records the wiring promises.
"""
import dataclasses
import json
import pathlib
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Problem, SolverConfig, resolve_cost
from repro.core import solver as _solver
from repro.core.batch import fused_step_batch
from repro.core.graph import build_random_cec
from repro.core.scenario import (initial_state, named_scenarios,
                                 run_scenario, scenario_metrics,
                                 segment_optima)
from repro.core.utility import make_bank
from repro.obs import telemetry as obs_tel
from repro.obs import trace as obs_trace
from repro.obs.export import (export_ring, metrics_rows, write_chrome_trace,
                              write_metrics_jsonl)
from repro.obs.monitors import (check_state, dynamic_regret,
                                monotone_descent)
from repro.serve import CECRouter, RouterFleet
from repro.topo import connected_er

GOLDEN = pathlib.Path(__file__).parent / "golden" / "fig7_gs_oma_traj.npz"

CONFIG = SolverConfig(method="single", delta=0.5, eta_outer=0.05,
                      eta_inner=3.0, inner_iters=1)


def _instance(seed=0, n=10, p=0.4, n_sessions=2, lam_total=60.0):
    graph = build_random_cec(connected_er(n, p, seed=seed), n_sessions,
                             10.0, seed=seed)
    bank = make_bank("log", n_sessions, seed=seed, lam_total=lam_total)
    problem = Problem(graph=graph, bank=bank,
                      lam_total=jnp.float32(lam_total),
                      cost=resolve_cost("exp")).canonical().validate()
    return problem, bank


def _donation_supported():
    x = jnp.ones(4)
    jax.jit(lambda v: v + 1.0, donate_argnums=0)(x)
    return x.is_deleted()


def _ring_from_utilities(u, capacity=None):
    """A Telemetry carrying just a utility trajectory (monitor-input
    fixture — dtype follows ``u`` so x64 tests keep f64 accounting)."""
    u = jnp.asarray(u)
    c = int(u.shape[0]) if capacity is None else int(capacity)
    tel = obs_tel.init_ring(c, 1)
    zeros = jnp.zeros((c,), u.dtype)
    return dataclasses.replace(
        tel, utility=u.astype(u.dtype), cost=zeros, grad_norm=zeros,
        proj_residual=zeros, wall_clock_us=zeros,
        head=jnp.int32(u.shape[0]), count=jnp.int32(u.shape[0]))


# ---------------------------------------------------------------------------
# ring semantics
# ---------------------------------------------------------------------------

class _St(NamedTuple):
    lam: jnp.ndarray


class _In(NamedTuple):
    grad: jnp.ndarray
    cost: jnp.ndarray


def _record_n(tel, n, w=2):
    for i in range(n):
        st = _St(lam=jnp.full((w,), 1.0 + i))
        info = _In(grad=jnp.ones((w,)), cost=jnp.float32(10.0 + i))
        tel = obs_tel.record(tel, st, info, lam_total=2.0 * (1.0 + i),
                             delta=0.0, oracle_calls=5)
        tel = obs_tel.annotate(tel, utility=jnp.float32(100.0 + i))
    return tel


def test_ring_wraparound_and_order():
    tel = obs_tel.init_ring(4, 2)
    tel = _record_n(tel, 6)
    assert int(tel.head) == 6
    assert int(tel.count) == 4            # saturated at capacity
    idx, valid = obs_tel.order(tel)
    assert bool(valid.all())
    cols = export_ring(tel)
    # oldest surviving row is interval 2; newest is interval 5
    np.testing.assert_allclose(cols["utility"], [102.0, 103, 104, 105])
    np.testing.assert_allclose(cols["cost"], [12.0, 13, 14, 15])
    np.testing.assert_allclose(cols["lam"][:, 0], [3.0, 4, 5, 6])
    assert (cols["oracle_calls"] == 5).all()
    # the exact-projection residual of a feasible Λ is ~0
    assert cols["proj_residual"].max() < 1e-5
    # wall-clock was never annotated: NaN survives to export
    assert np.isnan(cols["wall_clock_us"]).all()


def test_partial_ring_masks_unwritten_slots():
    tel = _record_n(obs_tel.init_ring(8, 2), 3)
    assert int(tel.count) == 3
    _, valid = obs_tel.order(tel)
    assert int(np.asarray(valid).sum()) == 3
    cols = export_ring(tel)
    assert cols["utility"].shape == (3,)
    np.testing.assert_allclose(cols["utility"], [100.0, 101, 102])


def test_annotate_patches_only_latest_row():
    tel = _record_n(obs_tel.init_ring(4, 2), 2)
    tel = obs_tel.annotate(tel, wall_clock_us=jnp.float32(42.0))
    cols = export_ring(tel)
    assert np.isnan(cols["wall_clock_us"][0])
    assert cols["wall_clock_us"][1] == 42.0


# ---------------------------------------------------------------------------
# zero-cost recording: donation + no retrace
# ---------------------------------------------------------------------------

def test_fused_step_telemetry_donates_and_never_retraces():
    """≥10 intervals through the telemetry-enabled fused step: the ring
    and state are donated every interval (steady state allocates
    nothing) and the executable never retraces (ISSUE 10 acceptance)."""
    problem, bank = _instance(seed=1)
    config = CONFIG.replace(telemetry=5)
    fn = _solver.fused_step(config, donate=True)
    state = _solver.init(problem, config)
    tel = obs_tel.init_ring(config.telemetry, problem.graph.n_sessions)
    check_donation = _donation_supported()
    sizes = []
    for t in range(12):
        task_u = jax.vmap(bank.total)(
            _solver.perturbed_allocations(state.lam, config.delta))
        old_state, old_tel = state, tel
        state, info, tel = fn(problem, state, task_u, tel)
        if check_donation:
            assert old_state.lam.is_deleted(), t
            if t > 0:          # the initial ring may alias init constants
                assert old_tel.utility.is_deleted(), t
        if hasattr(fn, "_cache_size"):
            sizes.append(fn._cache_size())
    if sizes:
        assert sizes == [sizes[0]] * len(sizes), "fused step retraced"
    assert int(tel.head) == 12
    assert int(tel.count) == 5
    cols = export_ring(tel)
    assert np.isfinite(cols["cost"]).all()
    assert (cols["oracle_calls"]
            == 2 * problem.graph.n_sessions + 1).all()


def test_fused_step_batch_telemetry_donates_and_never_retraces():
    """The fleet analogue: a [K]-stacked ring donated through ≥10
    ``fused_step_batch`` intervals with a stable jit cache."""
    graphs = [build_random_cec(connected_er(10, 0.4, seed=s), 2, 10.0,
                               seed=s) for s in range(2)]
    fleet = RouterFleet(graphs, [60.0, 55.0], telemetry=6)
    assert fleet.config.telemetry == 6
    fns = [(lambda lams, b=make_bank("log", 2, seed=s):
            np.asarray(jax.vmap(b.total)(jnp.asarray(lams))))
           for s in range(2)]
    step = fused_step_batch(fleet.config, cost=fleet.cost_name,
                            donate=fleet.donate)
    check_donation = _donation_supported() and fleet.donate
    sizes = []
    for t in range(11):
        old_tel = fleet.tel
        fleet.control_step(fns)
        if check_donation:
            assert old_tel.utility.is_deleted(), t
        if hasattr(step, "_cache_size"):
            sizes.append(step._cache_size())
    if sizes:
        assert sizes == [sizes[0]] * len(sizes), "fleet step retraced"
    assert [int(h) for h in np.asarray(fleet.tel.head)] == [11, 11]
    # the published view carries per-lane verdicts and survives the
    # donated steps (double-buffer discipline extends to §18 outputs)
    assert fleet.view.verdicts is not None
    v = fleet.view.verdicts["kkt_gap"]
    assert np.asarray(v.value).shape == (2,)
    cols = export_ring(fleet.tel)
    assert cols["utility"].shape[0] == 2
    assert np.isfinite(cols["utility"]).all()


# ---------------------------------------------------------------------------
# monitor fidelity
# ---------------------------------------------------------------------------

def test_monitors_no_false_positives_on_event_free_scenarios():
    """Event-free runs of every named scenario, default thresholds:
    nothing trips, and the exact projection keeps budget feasibility
    below even its warn level (the ISSUE's no-false-positive bar)."""
    scenarios = named_scenarios(horizon=18, n=10, p=0.4)
    for i, (sname, sc) in enumerate(sorted(scenarios.items())):
        sc = dataclasses.replace(sc, events=())
        st = initial_state(sc, seed=i)
        problem = Problem(graph=st.graph(), bank=st.bank,
                          lam_total=jnp.float32(st.lam_total),
                          cost=resolve_cost("exp")).canonical().validate()
        config = CONFIG.replace(telemetry=sc.horizon)
        res = _solver.run(problem, config, iters=sc.horizon)
        verdicts = check_state(problem, res.state, res.telemetry)
        for mname, v in verdicts.items():
            assert not bool(np.asarray(v.trip).any()), \
                f"{mname} tripped on event-free {sname}: {float(v.value)}"
        assert not bool(verdicts["budget_feasibility"].warn), sname


def test_regret_monitor_agrees_with_genie_accounting():
    """``dynamic_regret`` on a per-interval genie comparator reproduces
    ``scenario_metrics``'s Σ_seg Σ_t (U*_seg − U_t) to ≤1e-6 (f64)."""
    sc = named_scenarios(horizon=16, n=10, p=0.4)["demand_surge"]
    res = run_scenario(sc, seeds=(0,), config=CONFIG)
    genie = segment_optima(sc, (0,), outer_iters=60, inner_iters=40)
    expected = scenario_metrics(res, opt_utilities=genie)["dynamic_regret"]
    traj = np.asarray(res.utility_traj[0], np.float64)
    comp = np.zeros_like(traj)
    for j, seg in enumerate(res.segments):
        comp[seg.start:seg.start + seg.n_iters] = genie[0, j]
    from jax.experimental import enable_x64
    with enable_x64():
        tel = _ring_from_utilities(jnp.asarray(traj, jnp.float64))
        got = float(dynamic_regret(tel, jnp.asarray(comp)).value)
    assert abs(got - expected) <= 1e-6 * max(1.0, abs(expected))


def test_fleet_verdicts_bitwise_match_per_lane():
    """Lane k of the vmapped ``fleet_verdicts`` equals the scalar
    monitors on tenant k alone — bit-identical, on 1 device and on the
    CI job's 8 forced CPU devices alike."""
    graphs = [build_random_cec(connected_er(10, 0.4, seed=s), 2, 10.0,
                               seed=s) for s in range(3)]
    lam_totals = [60.0, 45.0, 75.0]
    fleet = RouterFleet(graphs, lam_totals, telemetry=4)
    fns = [(lambda lams, b=make_bank("log", 2, seed=s):
            np.asarray(jax.vmap(b.total)(jnp.asarray(lams))))
           for s in range(3)]
    for _ in range(3):
        fleet.control_step(fns)
    stacked = fleet.view.verdicts
    graph = fleet.batch.stacked_graph()
    lane = lambda tree, k: jax.tree_util.tree_map(lambda x: x[k], tree)
    for k in range(3):
        problem = Problem(graph=lane(graph, k), bank=None,
                          lam_total=jnp.float32(lam_totals[k]),
                          cost=resolve_cost(fleet.cost_name))
        solo = check_state(problem, lane(fleet.state, k),
                           lane(fleet.tel, k))
        assert set(solo) == set(stacked)
        for mname, v in solo.items():
            sv = stacked[mname]
            np.testing.assert_array_equal(
                np.asarray(sv.value)[k], np.asarray(v.value),
                err_msg=f"{mname} lane {k} value drifted under vmap")
            assert bool(np.asarray(sv.warn)[k]) == bool(v.warn), mname
            assert bool(np.asarray(sv.trip)[k]) == bool(v.trip), mname


def test_state_monitors_cover_sparse_representation():
    """The flow/capacity monitors evaluate the sparse graph through the
    same recursion the sparse engine runs — no dense fallback, verdicts
    stay healthy on a converged sparse solve."""
    from repro.core.graph import sparsify

    graph = sparsify(build_random_cec(connected_er(12, 0.35, seed=4), 2,
                                      10.0, seed=4))
    bank = make_bank("log", 2, seed=4)
    problem = Problem(graph=graph, bank=bank, lam_total=jnp.float32(60.0),
                      cost=resolve_cost("exp")).canonical().validate()
    res = _solver.run(problem, CONFIG.replace(telemetry=8), iters=12)
    verdicts = check_state(problem, res.state, res.telemetry)
    for mname, v in verdicts.items():
        assert not bool(np.asarray(v.trip).any()), mname


def test_write_chrome_trace_requires_a_tracer(tmp_path):
    assert obs_trace.current_tracer() is None
    with pytest.raises(ValueError, match="install_tracer"):
        write_chrome_trace(tmp_path / "t.json")


def test_golden_trajectory_never_trips_descent_monitor():
    """The committed Fig. 7 gs_oma trajectory ascends monotonically —
    the Theorem-4 descent monitor stays strictly below its warn level
    (ISSUE 10 acceptance pin on the golden fixture)."""
    ref = np.load(GOLDEN)
    tel = _ring_from_utilities(
        jnp.asarray(ref["utility_traj"], jnp.float32))
    v = monotone_descent(tel)
    assert float(v.value) <= 0.0          # no one-interval drop at all
    assert not bool(v.warn) and not bool(v.trip)
    # regret against the trajectory's own best is non-negative and the
    # final-interval term is 0 — the accounting is anchored correctly
    best = float(ref["utility_traj"].max())
    r = dynamic_regret(tel, jnp.float32(best))
    assert float(r.value) >= -1e-4


# ---------------------------------------------------------------------------
# export formats: Chrome trace + metrics JSONL
# ---------------------------------------------------------------------------

def _router_with_history(capacity=4, steps=3, seed=2):
    graph = build_random_cec(connected_er(10, 0.4, seed=seed), 2, 10.0,
                             seed=seed)
    bank = make_bank("log", 2, seed=seed)
    router = CECRouter(graph, lam_total=60.0, telemetry=capacity)
    fn = lambda lams: np.asarray(jax.vmap(bank.total)(jnp.asarray(lams)))
    for _ in range(steps):
        router.control_step(fn)
    return router


def test_chrome_trace_export_is_valid(tmp_path):
    tracer = obs_trace.Tracer()
    obs_trace.install_tracer(tracer)
    try:
        router = _router_with_history()
        sc = named_scenarios(horizon=8, n=10, p=0.4)["link_churn"]
        run_scenario(sc, seeds=(0,), config=CONFIG)
        path = write_chrome_trace(tmp_path / "trace.json")
    finally:
        obs_trace.uninstall_tracer()
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    assert events and doc["displayTimeUnit"] == "ms"
    for ev in events:
        assert set(ev) >= {"name", "cat", "ph", "ts", "pid", "tid"}
        assert ev["ph"] in ("X", "i")
        if ev["ph"] == "X":
            assert ev["dur"] >= 0
    names = [ev["name"] for ev in events]
    assert names.count("router.interval") == 3          # one span per step
    assert any(n.startswith("solver.dispatch:") for n in names)
    assert "scenario.segment" in names                  # run_scenario spans
    assert any(n.startswith("event:") for n in names)   # churn instants
    # timestamps are monotone within the sort the writer promises
    ts = [ev["ts"] for ev in events]
    assert ts == sorted(ts)
    del router


def test_metrics_jsonl_export_is_valid(tmp_path):
    router = _router_with_history(capacity=4, steps=5)
    verdicts = router.verdicts()
    path = write_metrics_jsonl(tmp_path / "metrics.jsonl", router.tel,
                               verdicts=verdicts, name="router")
    rows = [json.loads(line) for line in path.read_text().splitlines()]
    assert len(rows) == 5                 # 4 interval rows + verdict row
    for i, row in enumerate(rows[:-1]):
        assert row["name"] == "router"
        assert row["t"] == 1 + i          # ring kept the last 4 of 5
        assert isinstance(row["utility"], float)
        assert isinstance(row["lam"], list) and len(row["lam"]) == 2
        assert row["oracle_calls"] == 5   # 2W+1 sampled admissions
        assert row["wall_clock_us"] > 0.0
    tail = rows[-1]
    assert tail["name"] == "router.verdicts"
    for mname in ("flow_conservation", "capacity_slack", "kkt_gap",
                  "monotone_descent", "budget_feasibility"):
        assert set(tail[mname]) == {"value", "warn", "trip"}
        assert tail[mname]["trip"] is False


def test_metrics_rows_rejects_fleet_stacked_ring():
    graphs = [build_random_cec(connected_er(8, 0.5, seed=s), 2, 10.0,
                               seed=s) for s in range(2)]
    fleet = RouterFleet(graphs, [60.0, 60.0], telemetry=3)
    with pytest.raises(ValueError, match="lane"):
        metrics_rows(fleet.tel)


def test_solver_run_threads_telemetry_through_scan():
    """``Result.telemetry`` holds the scan's ring: count saturates at
    capacity and the annotated utilities are exactly the trajectory
    tail (written device-side inside the same scan iteration)."""
    problem, _ = _instance(seed=3)
    res = _solver.run(problem, CONFIG.replace(telemetry=6), iters=10)
    tel = res.telemetry
    assert tel is not None and int(tel.count) == 6 and int(tel.head) == 10
    cols = export_ring(tel)
    np.testing.assert_array_equal(cols["utility"],
                                  np.asarray(res.utility_traj[-6:]))
    np.testing.assert_array_equal(cols["lam"],
                                  np.asarray(res.lam_traj[-6:]))
    # telemetry off → no ring on the result, and no ring work in the scan
    assert _solver.run(problem, CONFIG, iters=3).telemetry is None


def test_trajectory_reader_tolerates_old_schemas(tmp_path):
    """Schema-3 rows carry ``dirty``/``jax_version`` first-class; the
    reader back-fills both on historical rows instead of KeyError-ing
    (satellite: old-row tolerance rides the schema bump)."""
    from benchmarks.run import TRAJECTORY_SCHEMA, read_trajectory

    assert TRAJECTORY_SCHEMA >= 3
    (tmp_path / "BENCH_old1.json").write_text(json.dumps(
        {"schema": 1, "commit": "old1", "date": "2026-01-01T00:00:00+00:00",
         "smoke": True, "jax": "0.4.30", "benches": {"fig7": {}}}))
    (tmp_path / "BENCH_new1.json").write_text(json.dumps(
        {"schema": 3, "commit": "new1", "date": "2026-02-01T00:00:00+00:00",
         "smoke": True, "dirty": False, "jax": "0.4.37",
         "jax_version": "0.4.37", "benches": {}}))
    old, new = read_trajectory(tmp_path)
    assert old["commit"] == "old1" and new["commit"] == "new1"
    assert old["jax_version"] == "0.4.30"     # back-filled from legacy key
    assert old["dirty"] is True               # conservative default
    assert new["jax_version"] == "0.4.37" and new["dirty"] is False
    # the committed trajectory itself must load through the same reader
    real = read_trajectory()
    assert real and all("jax_version" in e and "dirty" in e for e in real)
