"""Dense↔sparse representation parity (DESIGN.md §12).

The edge-list path must be *the same solver* in a different layout:
property-based checks over random graphs, random alive-masks and random φ
for flow propagation, total cost, the marginal-cost broadcast, one OMD
step, and a full ``solve_jowr`` run — all within 1e-5 of the dense path —
plus structural identity of the two sparse constructors, pad/batch
equivalence, and the Pallas sparse-kernel dispatch.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from hypothesis_stub import given, settings, st

from repro.core import (CECGraphSparse, CECGraphSparseBatch, SparsePhi,
                        build_augmented, build_augmented_sparse, dispatch,
                        get_cost, make_bank, omd_step, pad_sparse_graph,
                        propagate, solve_jowr, solve_routing,
                        solve_routing_batch, sparsify, total_cost)
from repro.core import sparse as sp
from repro.core.flow import cost_and_state
from repro.core.graph import draw_instance
from repro.core.marginal import marginals
from repro.topo import connected_er

from conftest import random_phi

COST = get_cost("exp")


def _instance(n, p, seed):
    return draw_instance(connected_er(n, p, seed=seed), 3, 10.0, seed)


def _alive_instance(n, seed, n_dead):
    """A feasible alive-masked instance (retrying the kill set)."""
    from repro.core import InfeasibleTopology

    inst = _instance(n, 0.35, seed)
    rng = np.random.default_rng(seed)
    for _ in range(30):
        alive = np.ones(n, bool)
        alive[rng.choice(n, size=n_dead, replace=False)] = False
        try:
            g = build_augmented(connected_er(n, 0.35, seed=seed),
                                inst.deploy, inst.link_capacity,
                                inst.compute_capacity, alive=alive)
            return g, alive
        except InfeasibleTopology:
            continue
    pytest.skip("no feasible alive-mask draw")


def _lam(graph, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(2, 25, graph.n_sessions), jnp.float32)


def _sparse_pair(graph, seed):
    gs = sparsify(graph)
    phi = random_phi(graph, seed)
    return gs, phi, sp.phi_to_sparse(gs, phi)


# ---------------------------------------------------------------------------
# structure
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(8, 40))
def test_sparse_builders_identical(seed, n):
    """sparsify(build_augmented(x)) == build_augmented_sparse(x), leafwise."""
    inst = _instance(n, 0.35, seed)
    adj = connected_er(n, 0.35, seed=seed)
    a = sparsify(inst.graph)
    b = build_augmented_sparse(adj, inst.deploy, inst.link_capacity,
                               inst.compute_capacity)
    assert (a.d_max, a.d_src, a.d_in_max, a.depth_max, a.n_edges) == \
           (b.d_max, b.d_src, b.d_in_max, b.depth_max, b.n_edges)
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_phi_layout_roundtrip(seed):
    """dense→sparse→dense is the identity on masked routing tensors."""
    g = _instance(14, 0.35, seed).graph
    gs = sparsify(g)
    phi = random_phi(g, seed)
    back = sp.phi_to_dense(gs, sp.phi_to_sparse(gs, phi))
    np.testing.assert_allclose(np.asarray(back), np.asarray(phi), atol=1e-6)


def test_sparse_density_metadata(small_cec):
    gs = sparsify(small_cec)
    assert gs.n_edges == int(np.asarray(small_cec.edge_mask).sum())
    assert 0.0 < gs.density < 1.0


# ---------------------------------------------------------------------------
# flow / cost / marginals / OMD-step parity
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(8, 48))
def test_flow_and_cost_parity(seed, n):
    g = _instance(n, 0.35, seed).graph
    gs, phi, phis = _sparse_pair(g, seed)
    lam = _lam(g, seed)
    t_d = np.asarray(propagate(g, phi, lam))
    t_s = np.asarray(propagate(gs, phis, lam))
    np.testing.assert_allclose(t_d, t_s, rtol=1e-5, atol=1e-5)
    D_d = float(total_cost(g, COST, phi, lam))
    D_s = float(total_cost(gs, COST, phis, lam))
    np.testing.assert_allclose(D_d, D_s, rtol=1e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), n_dead=st.integers(1, 3))
def test_flow_parity_under_alive_mask(seed, n_dead):
    """Dead nodes (scenario churn) behave identically in both layouts."""
    g, _ = _alive_instance(16, seed, n_dead)
    gs, phi, phis = _sparse_pair(g, seed)
    lam = _lam(g, seed)
    np.testing.assert_allclose(np.asarray(propagate(g, phi, lam)),
                               np.asarray(propagate(gs, phis, lam)),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000),
       cost_name=st.sampled_from(["exp", "mm1", "linear", "quad"]))
def test_marginal_parity(seed, cost_name):
    """δφ and ∂D/∂r agree edge-for-edge across representations."""
    cost = get_cost(cost_name)
    g = _instance(16, 0.35, seed).graph
    gs, phi, phis = _sparse_pair(g, seed)
    lam = _lam(g, seed)
    _, t_d, F_d = cost_and_state(g, cost, phi, lam)
    delta_d, dDdr_d = marginals(g, cost, phi, t_d, F_d)
    _, t_s, F_s = cost_and_state(gs, cost, phis, lam)
    delta_s, dDdr_s = marginals(gs, cost, phis, t_s, F_s)
    np.testing.assert_allclose(np.asarray(dDdr_d), np.asarray(dDdr_s),
                               rtol=1e-5, atol=1e-5)
    m = np.asarray(g.out_mask) > 0
    dense_of_sparse = np.asarray(sp.phi_to_dense(gs, delta_s))
    np.testing.assert_allclose(np.asarray(delta_d)[m], dense_of_sparse[m],
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), eta=st.floats(0.1, 5.0))
def test_omd_step_parity(seed, eta):
    g = _instance(14, 0.35, seed).graph
    gs, phi, phis = _sparse_pair(g, seed)
    lam = _lam(g, seed)
    st_d = omd_step(g, COST, phi, lam, float(eta))
    st_s = omd_step(gs, COST, phis, lam, float(eta))
    np.testing.assert_allclose(float(st_d.cost), float(st_s.cost), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(st_d.phi),
                               np.asarray(sp.phi_to_dense(gs, st_s.phi)),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_solve_routing_trajectory_parity(seed):
    g = _instance(20, 0.3, seed).graph
    gs = sparsify(g)
    lam = _lam(g, seed)
    _, tr_d = solve_routing(g, COST, lam, g.uniform_phi(), 1.0, 40)
    _, tr_s = solve_routing(gs, COST, lam, gs.uniform_phi(), 1.0, 40)
    np.testing.assert_allclose(np.asarray(tr_d), np.asarray(tr_s),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# full solver + auto-dispatch
# ---------------------------------------------------------------------------

@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 10_000),
       kind=st.sampled_from(["log", "sqrt", "linear"]))
def test_solve_jowr_parity(seed, kind):
    """Full OMAD run at N≤64: forced-sparse == dense to 1e-5."""
    n = 24 + seed % 40                      # spans up to N=63
    g = _instance(n, 0.3, seed).graph
    bank = make_bank(kind, 3, seed=seed)
    kw = dict(method="single", outer_iters=8, eta_inner=3.0)
    res_d = solve_jowr(g, bank, 60.0, **kw)
    with dispatch.sparse_dispatch(1):
        res_s = solve_jowr(g, bank, 60.0, **kw)
    np.testing.assert_allclose(np.asarray(res_d.utility_traj),
                               np.asarray(res_s.utility_traj),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(res_d.lam), np.asarray(res_s.lam),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(res_d.phi), np.asarray(res_s.phi),
                               rtol=1e-5, atol=1e-5)


def test_auto_dispatch_policy(small_cec):
    """maybe_sparsify honors the (N, density) policy and tracer guards."""
    assert dispatch.maybe_sparsify(small_cec) is small_cec   # below threshold
    with dispatch.sparse_dispatch(1):
        gs = dispatch.maybe_sparsify(small_cec)
        assert isinstance(gs, CECGraphSparse)
        assert dispatch.maybe_sparsify(gs) is gs             # idempotent
        # tracer companions disable conversion (inside-jit safety)
        traced = []

        @jax.jit
        def probe(x):
            traced.append(dispatch.maybe_sparsify(small_cec, x))
            return x

        probe(jnp.zeros(3))
        assert traced[0] is small_cec
    # density guard: a dense-enough graph stays dense even past the size bar
    with dispatch.sparse_dispatch(1, density_max=1e-9):
        assert dispatch.maybe_sparsify(small_cec) is small_cec


def test_state_key_tracks_sparse_policy(small_cec):
    k0 = dispatch.state_key()
    with dispatch.sparse_dispatch(1):
        assert dispatch.state_key() != k0
    assert dispatch.state_key() == k0


# ---------------------------------------------------------------------------
# padding / batching / kernels
# ---------------------------------------------------------------------------

def test_pad_sparse_graph_solve_equivalent(small_cec):
    gs = sparsify(small_cec)
    padded = pad_sparse_graph(gs, gs.n_phys + 7, depth_max=gs.depth_max + 3,
                              d_max=gs.d_max + 2, d_src=gs.d_src + 2,
                              d_in_max=gs.d_in_max + 2)
    lam = _lam(gs, 0)
    t0 = np.asarray(propagate(gs, gs.uniform_phi(), lam))
    t1 = np.asarray(propagate(padded, padded.uniform_phi(), lam))
    # original physical nodes keep their indices; virtual nodes relocate
    np.testing.assert_allclose(t0[:, : gs.n_phys], t1[:, : gs.n_phys],
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(t0[:, gs.src], t1[:, padded.src], rtol=1e-5)
    np.testing.assert_allclose(
        t0[np.arange(3), np.asarray(gs.sinks)],
        t1[np.arange(3), np.asarray(padded.sinks)], rtol=1e-5)
    _, tr0 = solve_routing(gs, COST, lam, gs.uniform_phi(), 1.0, 20)
    _, tr1 = solve_routing(padded, COST, lam, padded.uniform_phi(), 1.0, 20)
    np.testing.assert_allclose(np.asarray(tr0), np.asarray(tr1),
                               rtol=1e-5, atol=1e-5)


def test_sparse_batch_matches_dense_batch():
    from repro.core import CECGraphBatch

    graphs = [draw_instance(connected_er(n, 0.35, seed=s), 3, 10.0, s).graph
              for s, n in [(0, 12), (1, 16), (2, 10)]]
    db = CECGraphBatch.from_graphs(graphs)
    sb = CECGraphSparseBatch.from_graphs([sparsify(g) for g in graphs])
    lam = jnp.array([8.0, 12.0, 16.0])
    _, tr_d = solve_routing_batch(db, COST, lam, db.uniform_phi(), 1.0, 25)
    _, tr_s = solve_routing_batch(sb, COST, lam, sb.uniform_phi(), 1.0, 25)
    np.testing.assert_allclose(np.asarray(tr_d), np.asarray(tr_s),
                               rtol=1e-5, atol=1e-5)
    inst = sb.instance(1)
    assert isinstance(inst, CECGraphSparse)
    assert inst.n_phys == 16
    # per-instance metadata is exact, not the batch-level upper bound
    for b, g in enumerate(graphs):
        assert sb.instance(b).n_edges == sparsify(g).n_edges


def test_remap_phi_matches_edges_not_slots():
    """Churn can repack CSR slots at unchanged widths — φ must follow the
    *edge*, not the slot position (regression: the router's sparse
    warm-start once reused slot values positionally)."""
    from repro.core import build_augmented
    from repro.topo.churn import rewire_links

    adj = connected_er(20, 0.3, seed=2)
    inst = draw_instance(adj, 3, 10.0, 0)
    g_old = inst.graph
    adj_new = rewire_links(adj, 0.2, seed=1)
    g_new = build_augmented(adj_new, inst.deploy, inst.link_capacity,
                            inst.compute_capacity)
    s_old, s_new = sparsify(g_old), sparsify(g_new)
    phi_old = sp.phi_to_sparse(s_old, random_phi(g_old, 5))
    phi_new = sp.remap_phi(s_old, s_new, phi_old)   # widths may differ
    # edge-identity check against the dense layouts: surviving edges keep
    # their mass, new edges start at zero
    dense_old = np.asarray(sp.phi_to_dense(s_old, phi_old))
    dense_new = np.asarray(sp.phi_to_dense(s_new, phi_new))
    both = (np.asarray(g_old.out_mask) > 0) & (np.asarray(g_new.out_mask) > 0)
    only_new = (np.asarray(g_new.out_mask) > 0) & ~both
    np.testing.assert_allclose(dense_new[both], dense_old[both], atol=1e-6)
    assert (dense_new[only_new] == 0).all()


def test_router_sparse_warm_start_survives_rewire():
    """CECRouter on the sparse path: post-churn φ mass sits on real edges
    of the *new* graph, aligned by identity."""
    from repro.core import build_augmented, make_bank
    from repro.serve.cec_router import CECRouter
    from repro.topo.churn import rewire_links

    adj = connected_er(20, 0.3, seed=2)
    inst = draw_instance(adj, 3, 10.0, 0)
    bank = make_bank("log", 3, seed=0)

    def measured(lams):
        return np.asarray([float(bank.total(jnp.asarray(r)))
                           for r in np.atleast_2d(lams)], np.float32)

    with dispatch.sparse_dispatch(1):
        router = CECRouter(inst.graph, lam_total=45.0)
        for _ in range(3):
            router.control_step(measured)
        pre = sp.phi_to_dense(router.graph, router.phi)
        g_old = router.graph
        adj_new = rewire_links(adj, 0.2, seed=1)
        router.on_topology_change(build_augmented(
            adj_new, inst.deploy, inst.link_capacity, inst.compute_capacity))
        assert isinstance(router.phi, SparsePhi)
        post = np.asarray(sp.phi_to_dense(router.graph, router.phi))
        new_mask = np.asarray(sp.phi_to_dense(
            router.graph, SparsePhi(router.graph.out_mask,
                                    router.graph.src_out_mask))) > 0
        # row-stochastic on the new mask, zero off it
        assert (post[~new_mask] == 0).all()
        rows = post.sum(-1)
        has = new_mask.sum(-1) > 0
        np.testing.assert_allclose(rows[has], 1.0, atol=1e-5)
        # surviving edges dominate their rows' warm-start mass: identity
        # alignment means the (1−ε) component follows the old iterate
        both = new_mask & (np.asarray(sp.phi_to_dense(
            g_old, SparsePhi(g_old.out_mask, g_old.src_out_mask))) > 0)
        pre = np.asarray(pre)
        agree = np.abs(post[both] - pre[both])
        assert np.median(agree) < 0.15      # ε-mix, not a scramble
        router.control_step(measured)       # and the fused step still runs


def test_sparse_kernel_dispatch_parity(small_cec):
    """Pallas sparse kernels (interpret) == jnp sparse path in the solver."""
    gs = sparsify(small_cec)
    phis = gs.uniform_phi()
    lam = _lam(gs, 3)
    t_jnp = propagate(gs, phis, lam)
    st_jnp = omd_step(gs, COST, phis, lam, 1.0)
    with dispatch.kernel_dispatch(1):
        t_k = propagate(gs, phis, lam)
        st_k = omd_step(gs, COST, phis, lam, 1.0)
    np.testing.assert_allclose(np.asarray(t_jnp), np.asarray(t_k),
                               rtol=1e-5, atol=1e-5)
    for a, b in zip(st_jnp.phi, st_k.phi):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
    assert isinstance(st_k.phi, SparsePhi)
