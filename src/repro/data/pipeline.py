"""Deterministic, stateless, shardable synthetic LM data pipeline.

Every batch is a pure function of (step, host_id, n_hosts):

* **restart safety** — resuming from a checkpoint at step k regenerates
  exactly the batches k, k+1, … with no iterator state to persist;
* **elastic rescale** — changing n_hosts re-partitions the *same* global
  token stream deterministically (straggler/failure mitigation re-meshes
  without data loss or duplication, see train/runner.py);
* **prefetch** — a background thread keeps ``depth`` batches ready.

The generator is a counter-mode hash (threefry via jax.random) over
(seed, step, global_row), so any row of any batch is addressable O(1).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


def batch_for_step(seed: int, step: int, global_batch: int, seq_len: int,
                   vocab: int, host_id: int = 0, n_hosts: int = 1):
    """The host's shard of the global batch for ``step`` (pure function)."""
    assert global_batch % n_hosts == 0
    per_host = global_batch // n_hosts
    lo, hi = host_id * per_host, (host_id + 1) * per_host
    rows = np.arange(lo, hi)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    # generate the GLOBAL batch then slice this host's rows: the stream is
    # shape-invariant, so re-partitioning (elastic rescale) reproduces the
    # identical global token stream
    base = jax.random.randint(jax.random.fold_in(key, 0),
                              (global_batch, seq_len), 0, vocab, jnp.int32)
    drift = jnp.cumsum(
        jax.random.bernoulli(jax.random.fold_in(key, 1),
                             0.15, (global_batch, seq_len)), axis=1)
    toks = (base + drift.astype(jnp.int32)
            + np.arange(global_batch)[:, None]) % vocab
    return {"tokens": toks[lo:hi]}


@dataclass
class SyntheticLM:
    seed: int
    global_batch: int
    seq_len: int
    vocab: int
    host_id: int = 0
    n_hosts: int = 1
    prefetch_depth: int = 2

    def batch(self, step: int):
        return batch_for_step(self.seed, step, self.global_batch,
                              self.seq_len, self.vocab, self.host_id,
                              self.n_hosts)

    def iterate(self, start_step: int):
        """Prefetching iterator from ``start_step`` (checkpoint resume)."""
        q: queue.Queue = queue.Queue(maxsize=self.prefetch_depth)
        stop = threading.Event()

        def producer():
            s = start_step
            while not stop.is_set():
                q.put((s, self.batch(s)))
                s += 1

        th = threading.Thread(target=producer, daemon=True)
        th.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
