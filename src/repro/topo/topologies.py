"""Network topologies used in the paper's evaluation (§IV, Figs. 3–6).

All generators return a symmetric boolean adjacency matrix.  Hardcoded
topologies follow the standard published edge lists (Abilene/Internet2,
GEANT (Rossi & Rossini 2011 snapshot), the fog-computing sample of Kamran
et al. 2019); Balanced-tree and Connected-ER follow the paper's text.
"""
from __future__ import annotations

import numpy as np


def _from_edges(n: int, edges: list[tuple[int, int]]) -> np.ndarray:
    adj = np.zeros((n, n), bool)
    for i, j in edges:
        adj[i, j] = adj[j, i] = True
    np.fill_diagonal(adj, False)
    return adj


def connected_er(n: int = 25, p: float = 0.2, seed: int = 0,
                 max_tries: int = 200) -> np.ndarray:
    """Connectivity-guaranteed Erdős–Rényi graph (paper's main topology)."""
    for t in range(max_tries):
        rng = np.random.default_rng(seed + 7919 * t)
        adj = rng.random((n, n)) < p
        adj = np.triu(adj, 1)
        adj = adj | adj.T
        if _connected(adj):
            return adj
    raise RuntimeError("could not draw a connected ER graph")


def _connected(adj: np.ndarray) -> bool:
    n = adj.shape[0]
    seen = np.zeros(n, bool)
    seen[0] = True
    frontier = [0]
    while frontier:
        i = frontier.pop()
        for j in np.nonzero(adj[i])[0]:
            if not seen[j]:
                seen[j] = True
                frontier.append(int(j))
    return bool(seen.all())


def abilene() -> np.ndarray:
    """Abilene / Internet2 predecessor: 11 nodes, 14 links (paper Fig. 3)."""
    # 0 Seattle 1 Sunnyvale 2 LosAngeles 3 Denver 4 KansasCity 5 Houston
    # 6 Chicago 7 Indianapolis 8 Atlanta 9 WashingtonDC 10 NewYork
    edges = [(0, 1), (0, 3), (1, 2), (1, 3), (2, 5), (3, 4), (4, 5), (4, 7),
             (5, 8), (6, 7), (7, 8), (8, 9), (6, 10), (9, 10)]
    return _from_edges(11, edges)


def balanced_tree(branching: int = 2, height: int = 3) -> np.ndarray:
    """Complete tree (paper Fig. 4; 14 nodes at r=2,h=3 minus one leaf)."""
    nodes = sum(branching ** h for h in range(height + 1))
    nodes = min(nodes, 14)                      # paper's |N| = 14
    edges = [((i - 1) // branching, i) for i in range(1, nodes)]
    return _from_edges(nodes, edges)


def fog() -> np.ndarray:
    """3-tier fog sample (Kamran et al., DECO) — 15 nodes, 30 links."""
    # tier0: cloud {0}; tier1: fog nodes {1..4}; tier2: edge devices {5..14}
    edges = [(0, 1), (0, 2), (0, 3), (0, 4),
             (1, 2), (2, 3), (3, 4), (4, 1),          # fog ring
             (1, 3), (2, 4)]                          # fog cross links
    for d in range(5, 15):
        f = 1 + (d - 5) % 4
        edges.append((f, d))                          # primary uplink
        edges.append((1 + (d - 4) % 4, d))            # backup uplink
    return _from_edges(15, edges)


def geant() -> np.ndarray:
    """GEANT pan-European research network: 22 nodes, 33 links (Fig. 6)."""
    edges = [(0, 1), (0, 2), (1, 3), (1, 6), (2, 3), (2, 4), (3, 5), (4, 7),
             (5, 8), (6, 8), (6, 9), (7, 8), (7, 10), (8, 11), (9, 12),
             (10, 13), (11, 13), (11, 14), (12, 14), (12, 15), (13, 16),
             (14, 17), (15, 17), (15, 18), (16, 19), (17, 20), (18, 20),
             (19, 21), (20, 21), (0, 4), (5, 9), (10, 16), (18, 21)]
    return _from_edges(22, edges)


# paper Table II mean link capacities
MEAN_CAPACITY = {"connected_er": 10.0, "abilene": 15.0, "balanced_tree": 10.0,
                 "fog": 10.0, "geant": 10.0}


def make_topology(name: str, **kw) -> tuple[np.ndarray, float]:
    """Returns (adjacency, mean link capacity per paper Table II)."""
    gens = {"connected_er": connected_er, "abilene": abilene,
            "balanced_tree": balanced_tree, "fog": fog, "geant": geant}
    return gens[name](**kw), MEAN_CAPACITY[name]
