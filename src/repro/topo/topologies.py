"""Network topologies used in the paper's evaluation (§IV, Figs. 3–6).

All generators return a symmetric boolean adjacency matrix.  Hardcoded
topologies follow the standard published edge lists (Abilene/Internet2,
GEANT (Rossi & Rossini 2011 snapshot), the fog-computing sample of Kamran
et al. 2019); Balanced-tree and Connected-ER follow the paper's text.
"""
from __future__ import annotations

import numpy as np


def _from_edges(n: int, edges: list[tuple[int, int]]) -> np.ndarray:
    adj = np.zeros((n, n), bool)
    for i, j in edges:
        adj[i, j] = adj[j, i] = True
    np.fill_diagonal(adj, False)
    return adj


def connected_er(n: int = 25, p: float = 0.2, seed: int = 0,
                 max_tries: int = 200) -> np.ndarray:
    """Connectivity-guaranteed Erdős–Rényi graph (paper's main topology)."""
    for t in range(max_tries):
        rng = np.random.default_rng(seed + 7919 * t)
        adj = rng.random((n, n)) < p
        adj = np.triu(adj, 1)
        adj = adj | adj.T
        if _connected(adj):
            return adj
    raise RuntimeError("could not draw a connected ER graph")


def _connected(adj: np.ndarray) -> bool:
    n = adj.shape[0]
    seen = np.zeros(n, bool)
    seen[0] = True
    frontier = [0]
    while frontier:
        i = frontier.pop()
        for j in np.nonzero(adj[i])[0]:
            if not seen[j]:
                seen[j] = True
                frontier.append(int(j))
    return bool(seen.all())


def abilene() -> np.ndarray:
    """Abilene / Internet2 predecessor: 11 nodes, 14 links (paper Fig. 3)."""
    # 0 Seattle 1 Sunnyvale 2 LosAngeles 3 Denver 4 KansasCity 5 Houston
    # 6 Chicago 7 Indianapolis 8 Atlanta 9 WashingtonDC 10 NewYork
    edges = [(0, 1), (0, 3), (1, 2), (1, 3), (2, 5), (3, 4), (4, 5), (4, 7),
             (5, 8), (6, 7), (7, 8), (8, 9), (6, 10), (9, 10)]
    return _from_edges(11, edges)


def balanced_tree(branching: int = 2, height: int = 3) -> np.ndarray:
    """Complete tree (paper Fig. 4; 14 nodes at r=2,h=3 minus one leaf)."""
    nodes = sum(branching ** h for h in range(height + 1))
    nodes = min(nodes, 14)                      # paper's |N| = 14
    edges = [((i - 1) // branching, i) for i in range(1, nodes)]
    return _from_edges(nodes, edges)


def fog() -> np.ndarray:
    """3-tier fog sample (Kamran et al., DECO) — 15 nodes, 30 links."""
    # tier0: cloud {0}; tier1: fog nodes {1..4}; tier2: edge devices {5..14}
    edges = [(0, 1), (0, 2), (0, 3), (0, 4),
             (1, 2), (2, 3), (3, 4), (4, 1),          # fog ring
             (1, 3), (2, 4)]                          # fog cross links
    for d in range(5, 15):
        f = 1 + (d - 5) % 4
        edges.append((f, d))                          # primary uplink
        edges.append((1 + (d - 4) % 4, d))            # backup uplink
    return _from_edges(15, edges)


def geant() -> np.ndarray:
    """GEANT pan-European research network: 22 nodes, 33 links (Fig. 6)."""
    edges = [(0, 1), (0, 2), (1, 3), (1, 6), (2, 3), (2, 4), (3, 5), (4, 7),
             (5, 8), (6, 8), (6, 9), (7, 8), (7, 10), (8, 11), (9, 12),
             (10, 13), (11, 13), (11, 14), (12, 14), (12, 15), (13, 16),
             (14, 17), (15, 17), (15, 18), (16, 19), (17, 20), (18, 20),
             (19, 21), (20, 21), (0, 4), (5, 9), (10, 16), (18, 21)]
    return _from_edges(22, edges)


# ---------------------------------------------------------------------------
# fleet-scale sparse generators (beyond-paper: N ∈ {256, 1024, 4096}, the
# CECGraphSparse regime — degree ≪ N, see DESIGN.md §12)
# ---------------------------------------------------------------------------

def grid_2d(n: int = 256) -> np.ndarray:
    """⌈√n⌉×⌈√n⌉ 4-neighbour lattice truncated to n nodes (metro mesh)."""
    cols = int(np.ceil(np.sqrt(n)))
    edges = []
    for i in range(n):
        r, c = divmod(i, cols)
        if c + 1 < cols and i + 1 < n:
            edges.append((i, i + 1))
        if i + cols < n:
            edges.append((i, i + cols))
    return _from_edges(n, edges)


def random_geometric(n: int = 256, radius: float | None = None,
                     seed: int = 0, max_tries: int = 50) -> np.ndarray:
    """Connected random geometric graph on the unit square (radio range).

    Default radius ~ √(2·ln n / n) sits just above the connectivity
    threshold; retries grow it by 15% until the draw connects.
    """
    rng = np.random.default_rng(seed)
    r = radius if radius is not None else float(np.sqrt(2.0 * np.log(n) / n))
    for _ in range(max_tries):
        pts = rng.random((n, 2))
        d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
        adj = d2 <= r * r
        np.fill_diagonal(adj, False)
        if _connected(adj):
            return adj
        r *= 1.15
    raise RuntimeError("could not draw a connected geometric graph")


def power_law(n: int = 1024, m: int = 2, seed: int = 0) -> np.ndarray:
    """Barabási–Albert preferential attachment (degree-skewed edge fleet).

    Always connected; mean degree ≈ 2m, diameter O(log n) — the shallow
    ``depth_max`` makes it the headline topology of ``bench_sparse``.
    """
    rng = np.random.default_rng(seed)
    adj = np.zeros((n, n), bool)
    targets = list(range(m + 1))            # small connected seed clique
    for i, j in [(a, b) for a in targets for b in targets if a < b]:
        adj[i, j] = adj[j, i] = True
    repeated = [v for v in targets for _ in range(m)]
    for v in range(m + 1, n):
        chosen: set[int] = set()
        while len(chosen) < m:
            chosen.add(int(repeated[rng.integers(len(repeated))]))
        for u in chosen:
            adj[u, v] = adj[v, u] = True
        repeated.extend(chosen)
        repeated.extend([v] * m)
    np.fill_diagonal(adj, False)
    return adj


FLEET_KINDS = ("grid_2d", "random_geometric", "power_law")


def make_fleet(kind: str, n: int, seed: int = 0) -> np.ndarray:
    """Fleet-scale sparse adjacency by kind (``FLEET_KINDS``)."""
    gens = {"grid_2d": lambda: grid_2d(n),
            "random_geometric": lambda: random_geometric(n, seed=seed),
            "power_law": lambda: power_law(n, seed=seed)}
    return gens[kind]()


# paper Table II mean link capacities
MEAN_CAPACITY = {"connected_er": 10.0, "abilene": 15.0, "balanced_tree": 10.0,
                 "fog": 10.0, "geant": 10.0}


def make_topology(name: str, **kw) -> tuple[np.ndarray, float]:
    """Returns (adjacency, mean link capacity per paper Table II)."""
    gens = {"connected_er": connected_er, "abilene": abilene,
            "balanced_tree": balanced_tree, "fog": fog, "geant": geant}
    return gens[name](**kw), MEAN_CAPACITY[name]
