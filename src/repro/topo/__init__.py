from .churn import ChurnError, add_links, drop_links, rewire_links
from .topologies import (FLEET_KINDS, abilene, balanced_tree, connected_er,
                         fog, geant, grid_2d, make_fleet, make_topology,
                         power_law, random_geometric)

__all__ = ["abilene", "balanced_tree", "connected_er", "fog", "geant",
           "make_topology", "ChurnError", "add_links", "drop_links",
           "rewire_links", "FLEET_KINDS", "grid_2d", "make_fleet",
           "power_law", "random_geometric"]
