from .churn import ChurnError, add_links, drop_links, rewire_links
from .topologies import (abilene, balanced_tree, connected_er, fog, geant,
                         make_topology)

__all__ = ["abilene", "balanced_tree", "connected_er", "fog", "geant",
           "make_topology", "ChurnError", "add_links", "drop_links",
           "rewire_links"]
