"""Physical-topology perturbations for the non-stationary scenario engine.

These operate purely on symmetric boolean adjacency matrices — the
node-index space never changes, which is what lets the scenario engine
(``core/scenario.py``, DESIGN.md §10) warm-start routing iterates across
churn events without remapping.  Deployment/capacity bookkeeping lives in
the scenario state, not here.

All helpers are deterministic in ``seed`` and, unless told otherwise,
retry draws until the surviving graph is connected (so the augmented
build never rejects a generated segment).
"""
from __future__ import annotations

import numpy as np

from .topologies import _connected


class ChurnError(RuntimeError):
    """Raised when no connected perturbation is found within ``max_tries``."""


def _undirected_pairs(mask: np.ndarray) -> np.ndarray:
    """[K, 2] upper-triangular index pairs where ``mask`` holds."""
    iu, ju = np.nonzero(np.triu(mask, 1))
    return np.stack([iu, ju], axis=1)


def _apply_pairs(adj: np.ndarray, pairs: np.ndarray, value: bool) -> np.ndarray:
    out = adj.copy()
    for i, j in pairs:
        out[i, j] = out[j, i] = value
    return out


def drop_links(adj: np.ndarray, frac: float, seed: int,
               keep_connected: bool = True, max_tries: int = 100) -> np.ndarray:
    """Remove a ``frac`` share of links uniformly at random."""
    pairs = _undirected_pairs(adj)
    k = int(round(frac * len(pairs)))
    if k == 0:
        return adj.copy()
    for t in range(max_tries):
        rng = np.random.default_rng(seed + 7919 * t)
        sel = pairs[rng.choice(len(pairs), size=k, replace=False)]
        out = _apply_pairs(adj, sel, False)
        if not keep_connected or _connected(out):
            return out
    raise ChurnError(f"no connected graph after dropping {k} links")


def add_links(adj: np.ndarray, count: int, seed: int) -> np.ndarray:
    """Add ``count`` uniformly-random links between non-adjacent pairs."""
    absent = _undirected_pairs(~adj & ~np.eye(adj.shape[0], dtype=bool))
    if len(absent) == 0 or count == 0:
        return adj.copy()
    rng = np.random.default_rng(seed)
    k = min(count, len(absent))
    sel = absent[rng.choice(len(absent), size=k, replace=False)]
    return _apply_pairs(adj, sel, True)


def rewire_links(adj: np.ndarray, frac: float, seed: int,
                 keep_connected: bool = True,
                 max_tries: int = 100) -> np.ndarray:
    """Move a ``frac`` share of links to random new endpoints.

    Link-count preserving (device mobility: the same radios, different
    neighbours): drop ⌈frac·E⌉ links, add the same number elsewhere.
    """
    pairs = _undirected_pairs(adj)
    k = int(round(frac * len(pairs)))
    if k == 0:
        return adj.copy()
    for t in range(max_tries):
        rng = np.random.default_rng(seed + 104729 * t)
        sel = pairs[rng.choice(len(pairs), size=k, replace=False)]
        out = _apply_pairs(adj, sel, False)
        out = add_links(out, k, int(rng.integers(2**31)))
        if not keep_connected or _connected(out):
            return out
    raise ChurnError(f"no connected rewiring of {k} links found")
