"""Three-term roofline from the dry-run's compiled artifacts.

Hardware model (TPU v5e, per chip):
  peak bf16 compute   197 TFLOP/s
  HBM bandwidth       819 GB/s
  ICI                 ~50 GB/s per link

Terms (seconds per step, per chip):
  compute    = HLO_FLOPs / (chips · 197e12)
  memory     = HLO_bytes / (chips · 819e9)
  collective = per-chip wire bytes / 50e9

plus MODEL_FLOPS = 6·N·D (train) or 2·N_active·D (inference) and the
usefulness ratio MODEL_FLOPS / HLO_FLOPs (catches remat/redundancy waste).

Run ``python -m repro.roofline.analysis`` after the dry-run to render the
§Roofline table from experiments/dryrun/*.json.
"""
from __future__ import annotations

import json
import pathlib

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def roofline_terms(flops: float, hbm_bytes: float, wire_bytes: float,
                   chips: int) -> dict:
    compute = flops / (chips * PEAK_FLOPS)
    memory = hbm_bytes / (chips * HBM_BW)
    collective = wire_bytes / ICI_BW     # wire bytes are already per-chip
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    total = max(compute, memory, collective)
    terms["roofline_fraction"] = compute / total if total > 0 else 0.0
    return terms


def analytic_bytes(cfg, shape, chips: int, *, param_bytes: int = 2,
                   kv_bytes: int = 2, moment_bytes: int = 4) -> float:
    """Fusion-aware HBM traffic model (per chip, per step).

    The raw ``cost_analysis`` byte count assumes zero fusion (every
    elementwise op re-reads its operands from HBM), which overstates TPU
    traffic ~5–10×.  This model counts what a fused execution moves:

      params   — read 3× in train (fwd + remat + bwd) or 1× serving,
                 + grads (f32 w+r) + optimizer state r/w in train
      acts     — ~12 HBM-resident tensors of B·S·d per layer per train
                 step (fwd write, bwd read, remat re-write), 4 for prefill
      attn     — flash traffic: Q/O once + KV per q-block pass
      kv cache — decode reads the full cache, writes one token
      states   — recurrent state crosses HBM at chunk boundaries only
                 (the chunkwise kernel keeps it in VMEM within a chunk)
      logits   — B·S·V bf16 + f32 loss pass (train)
    """
    B, S = shape.global_batch, shape.seq_len
    P = cfg.approx_params()
    L = cfg.n_layers
    d = cfg.d_model
    n = cfg.n_periods
    n_attn = sum(1 for m, _ in cfg.period if m == "attn") * n
    kind = shape.kind
    tokens = B * S if kind != "decode" else B

    total = 0.0
    if kind == "train":
        total += P * (3 * param_bytes + 2 * 4 + 3 * 2 * moment_bytes)
        total += tokens * d * L * 2 * 12
        total += B * S * cfg.vocab * (2 + 2 * 4)
    elif kind == "prefill":
        total += P * param_bytes
        total += tokens * d * L * 2 * 4
        total += n_attn * B * S * cfg.n_kv_heads * cfg.hd * 2 * kv_bytes
    else:  # decode
        total += P * param_bytes
        total += n_attn * B * S * cfg.n_kv_heads * cfg.hd * 2 * kv_bytes
        total += B * cfg.vocab * 2

    # flash attention traffic (self-attn, q-block 512)
    if kind in ("train", "prefill") and n_attn:
        passes = 4 if kind == "train" else 1
        bq = 512
        total += passes * n_attn * (
            2 * B * S * cfg.n_heads * cfg.hd * 2
            + max(S // bq, 1) * B * S * cfg.n_kv_heads * cfg.hd * kv_bytes)

    # recurrent state at chunk boundaries (chunk = 64)
    rec_state = {"mamba": cfg.d_inner * cfg.d_state,
                 "mlstm": cfg.n_heads * cfg.hd ** 2,
                 "slstm": 4 * cfg.n_heads * cfg.hd}
    for mixer, _ in cfg.period:
        if mixer in rec_state:
            steps = S if kind != "decode" else 1
            crossings = max(steps // 64, 1) * (4 if kind == "train" else 1)
            total += n * crossings * B * rec_state[mixer] * 4 * 2
    return total / chips


def model_flops(cfg, shape_kind: str, seq: int, batch: int) -> float:
    n = cfg.active_params()
    if shape_kind == "train":
        return 6.0 * n * seq * batch
    if shape_kind == "prefill":
        return 2.0 * n * seq * batch
    return 2.0 * n * batch               # decode: one token per sequence


def summarize(record: dict) -> dict:
    t = roofline_terms(record["flops"], record["hbm_bytes"],
                       record["wire_bytes_per_chip"], record["chips"])
    t["useful_ratio"] = (record["model_flops"] / record["flops"]
                         if record["flops"] else 0.0)
    return t


_NOTES = {
    "compute": "raise arithmetic intensity: larger microbatch per chip or "
               "fewer remat recomputes",
    "memory": "cut HBM traffic: fuse attention (flash), keep KV in bf16, "
              "larger matmul tiles",
    "collective": "reshard to cut gathers: 2D-sharded weights with "
                  "overlapped FSDP prefetch, compressed grads, EP a2a",
}


def _row(r: dict, terms: dict) -> str:
    note = _NOTES[terms["bottleneck"]]
    return (
        f"| {r['arch']} | {r['shape']} | {terms['compute_s']*1e3:.2f} | "
        f"{terms['memory_s']*1e3:.2f} | {terms['collective_s']*1e3:.2f} | "
        f"{terms['bottleneck']} | {terms['useful_ratio']:.3f} | "
        f"{terms['roofline_fraction']:.3f} | {note} |")


def render_table(roofline_dir: str = "experiments/roofline",
                 adjusted: bool = False) -> str:
    rows = []
    for f in sorted(pathlib.Path(roofline_dir).glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"skipped: {r['reason']} | — | — | — |")
            continue
        src = r.get("flash_adjusted", r) if adjusted else r
        flops = src.get("flops", r["flops"])
        hbm = src.get("bytes", r.get("hbm_bytes"))
        wire = src.get("wire", r.get("wire_bytes_per_chip"))
        if adjusted:
            # fusion-aware memory model replaces the no-fusion HLO bytes
            from repro.configs import SHAPES, get_config
            cfg = get_config(r["arch"])
            big = cfg.approx_params() > 100e9
            pb = 2 if (big or r["shape"] != "train_4k") else 4
            mb = 2 if big else 4
            hbm = analytic_bytes(cfg, SHAPES[r["shape"]], r["chips"],
                                 param_bytes=pb, moment_bytes=mb)
        t = roofline_terms(flops, hbm, wire, 1)   # inputs are per chip
        t["useful_ratio"] = r["model_flops"] / flops if flops else 0.0
        rows.append(_row(r, t))
    head = ("| arch | shape | compute ms | memory ms | collective ms | "
            "bottleneck | 6ND/HLO | roofline frac | lever |\n"
            "|---|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


if __name__ == "__main__":
    import sys

    adj = "--adjusted" in sys.argv
    print(render_table(adjusted=adj))
