"""Compiled-cost extraction for the roofline (§Roofline methodology).

Primary mode — **control-kernel roofline** (DESIGN.md §17.5): lower and
compile the fused control megakernel (``kernels/control_megakernel.py``)
and the stitched ``solver.step`` it replaces on the *same* problem shape,
read attained FLOPs and HBM bytes from ``compiled.cost_analysis()``, and
place both programs on the §Roofline axes (arithmetic intensity vs the
ridge point ``PEAK_FLOPS / HBM_BW``).  :func:`control_step_costs` returns
the raw per-program records; :func:`control_roofline_rows` turns them
into trajectory-schema rows that ``benchmarks/bench_megakernel.py``
publishes into ``benchmarks/trajectory/BENCH_<sha>.json``.

Legacy mode — the scan-aware LM-stack analyzer this module started as,
kept because ``benchmarks/perf_iterations.py`` drives it through the
``python -m repro.roofline.extract --arch A --shape S --out DIR`` CLI.
``compiled.cost_analysis()`` counts a ``lax.scan`` body ONCE, so a
62-layer stack reports ~1 layer of FLOPs; every stack is a homogeneous
repetition of one period, so every cost is affine in the period count —
X(L) = X(1) + (L−1)·ΔX — and we compile two shallow variants (1 and 2
periods) and extrapolate.  Analysis mode additionally unchunks attention
so the full O(S²) FLOPs are visible; FLOPs inside per-token recurrent
scans stay counted once (<10% for every assigned arch, EXPERIMENTS.md).

Importing this module has **no side effects**: the legacy path needs a
512-device host platform (``make_production_mesh``), and earlier
revisions forced it by mutating ``XLA_FLAGS`` at import time — poisoning
every later jax user in the process (the CPU backend would shard tiny
control-plane arrays across 512 fake devices).  The forced-device flag
is now scoped to a subprocess: :func:`main` re-execs itself with
``XLA_FLAGS`` set in the child's environment when the legacy sweep needs
it, and in-process callers of :func:`analyze_cell` must pass a ``mesh``
(or arrange the flag themselves *before* jax initialises).
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib

# NOTE: no ``os.environ`` writes at import — see the module docstring.
import jax

from repro.roofline.analysis import (HBM_BW, PEAK_FLOPS, model_flops,
                                     roofline_terms)

#: host-platform device count the legacy LM-stack meshes require; applied
#: only inside the re-exec'd CLI subprocess, never to the importing process
FORCED_DEVICE_FLAG = "--xla_force_host_platform_device_count=512"


# --------------------------------------------------------------------------
# control-kernel roofline (primary): megakernel vs stitched control step
# --------------------------------------------------------------------------

def _cost_record(compiled) -> dict:
    """FLOPs / HBM bytes / arithmetic intensity of one compiled program."""
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    return {"flops": flops, "bytes": hbm,
            "intensity": flops / hbm if hbm else 0.0}


def control_step_costs(n_nodes: int = 24, n_sessions: int = 6, *,
                       k_iters: int = 3, phi_dtype: str = "float32",
                       seed: int = 0) -> dict:
    """Compile the fused megakernel and the stitched step on one shape.

    Builds a random CEC instance (``n_nodes`` physical nodes,
    ``n_sessions`` sessions, K = ``k_iters`` oracle iterations), traces
    both control-step programs on it, and reads each
    ``compiled.cost_analysis()``.  Returns::

        {"megakernel": {flops, bytes, intensity},
         "stitched":   {flops, bytes, intensity},
         "shape": {...}}

    Dispatch overrides are scoped to tracing (``megakernel_dispatch`` and
    the φ-dtype env knob are restored on exit); nothing is executed, so
    this is cheap enough for CI.  Off-TPU the megakernel lowers in
    interpret mode, where ``cost_analysis`` sees the *interpreter*
    program, not the Mosaic kernel — the FLOP/byte record is exact only
    on a real TPU backend and indicative elsewhere (the bench gates its
    real bar on TPU accordingly).
    """
    import jax.numpy as jnp

    from repro.core import build_random_cec, dispatch, solver
    from repro.core.problem import Problem
    from repro.topo import connected_er

    g = build_random_cec(connected_er(n_nodes, 0.35, seed=seed),
                         n_sessions, 10.0, seed=seed)
    problem = Problem.create(g, lam_total=8.0, cost="exp")
    config = solver.SolverConfig(method="nested", delta=0.5, eta_outer=0.05,
                                 eta_inner=0.05, inner_iters=k_iters,
                                 grad_mode="sampled")
    state = solver.init(problem, config)
    tau = jnp.ones((2 * g.n_sessions,), jnp.float32)

    def mega(state, tau):
        return solver._megakernel_step(problem, config, state, tau)

    def stitched(state, tau):
        return solver._sampled_step(problem, config, state, tau,
                                    config.eta_outer, config.eta_inner)

    prev_dtype = os.environ.get("REPRO_MEGAKERNEL_PHI_DTYPE")
    try:
        os.environ["REPRO_MEGAKERNEL_PHI_DTYPE"] = phi_dtype
        with dispatch.megakernel_dispatch(1):
            mk = jax.jit(mega).lower(state, tau).compile()
    finally:
        if prev_dtype is None:
            os.environ.pop("REPRO_MEGAKERNEL_PHI_DTYPE", None)
        else:
            os.environ["REPRO_MEGAKERNEL_PHI_DTYPE"] = prev_dtype
    st = jax.jit(stitched).lower(state, tau).compile()

    return {"megakernel": _cost_record(mk),
            "stitched": _cost_record(st),
            "shape": {"n_nodes": n_nodes, "n_bar": int(g.n_bar),
                      "n_sessions": n_sessions, "k_iters": k_iters,
                      "phi_dtype": phi_dtype,
                      "backend": jax.default_backend()}}


def control_roofline_rows(costs: dict | None = None, **shape_kw) -> list:
    """Trajectory-schema roofline rows for the two control-step programs.

    Each row carries the raw ``cost_analysis`` FLOPs/bytes, the
    arithmetic intensity, its position against the ridge point
    ``PEAK_FLOPS / HBM_BW`` (v5e: ~240 FLOP/byte), and the three-term
    roofline split from :func:`analysis.roofline_terms` (wire bytes are
    zero — the control step is single-chip).  ``attained_peak_fraction``
    is the fraction of peak compute the program can reach at its
    intensity assuming it hits the memory roof — the number the §17
    speedup claim is checked against.
    """
    costs = costs or control_step_costs(**shape_kw)
    ridge = PEAK_FLOPS / HBM_BW
    rows = []
    for variant in ("megakernel", "stitched"):
        c = costs[variant]
        t = roofline_terms(c["flops"], c["bytes"], 0.0, 1)
        rows.append({
            "metric": f"roofline.control_step.{variant}",
            "variant": variant, **costs["shape"],
            "flops": c["flops"], "hbm_bytes": c["bytes"],
            "intensity_flop_per_byte": c["intensity"],
            "ridge_flop_per_byte": ridge,
            "bound": "compute" if c["intensity"] >= ridge else "memory",
            "attained_peak_fraction": min(c["intensity"] / ridge, 1.0),
            "compute_s": t["compute_s"], "memory_s": t["memory_s"],
        })
    mk, st = costs["megakernel"], costs["stitched"]
    if mk["bytes"] and st["bytes"]:
        rows.append({
            "metric": "roofline.control_step.bytes_ratio",
            **costs["shape"],
            "value": st["bytes"] / mk["bytes"],
            "note": "stitched/megakernel HBM-byte ratio — the fused "
                    "kernel's VMEM residency removes per-phase HBM "
                    "round-trips (DESIGN.md §17.2)"})
    return rows


# --------------------------------------------------------------------------
# legacy LM-stack analyzer (scan-aware affine extrapolation)
# --------------------------------------------------------------------------

def _variant(cfg, n_periods: int):
    kw = dict(n_layers=len(cfg.period) * n_periods)
    if cfg.enc_dec:
        kw["n_enc_layers"] = n_periods
    cfg = dataclasses.replace(cfg, **kw)
    # §Perf variant: tighter MoE capacity factor (1.25 → 1.0)
    if "cf10" in os.environ.get("REPRO_PERF_VARIANT", "") and cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.0))
    return cfg


def _cell_costs(arch: str, shape_name: str, n_periods: int, mesh,
                unchunk_attention: bool) -> dict:
    """One analysis compile.

    ``unchunk_attention=True`` exposes the full O(S²) attention FLOPs to
    the cost model but lets GSPMD form (and reshard) S² score tensors that
    the production chunked/flash path never materializes — so FLOPs come
    from the unchunked compile and collective wire bytes from the chunked
    (production) compile.
    """
    from repro.configs import get_config
    from repro.launch import dryrun as D
    from repro.launch.mesh import dp_axes
    from repro.models import layers as L
    from repro.models import model as M
    from repro.parallel.annotate import activation_sharding
    from repro.roofline.hlo import parse_collectives

    cfg_full = get_config(arch)
    cfg = _variant(cfg_full, n_periods)

    # monkey-patch dryrun's registry handle so input_specs builds the variant
    orig = D.get_config
    D.get_config = lambda a, smoke=False: cfg if a == arch else orig(a, smoke)
    old_chunk = L.multihead_attention.__defaults__
    try:
        # layer scan unrolled → exact per-period costs
        M.UNROLL_SCAN = True
        if unchunk_attention:
            L.multihead_attention.__defaults__ = (0, None, 1 << 30)
        cfg2, step, args, kinds = D.input_specs(arch, shape_name)
        in_sh = D.shardings_for(cfg2, mesh, args, kinds)
        pv = os.environ.get("REPRO_PERF_VARIANT", "")
        if "fsdp256" in pv:
            ctx = activation_sharding(mesh, tuple(mesh.axis_names),
                                      model_axis=None)
        else:
            ctx = activation_sharding(mesh, dp_axes(mesh))
        with mesh, ctx:
            compiled = jax.jit(step, in_shardings=in_sh).lower(*args).compile()
    finally:
        M.UNROLL_SCAN = False
        D.get_config = orig
        L.multihead_attention.__defaults__ = old_chunk

    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    coll = parse_collectives(compiled.as_text(),
                             default_group=mesh.shape["model"])
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "wire": float(coll["total"]["wire_bytes"])}


def analyze_cell(arch: str, shape_name: str, outdir="experiments/roofline",
                 mesh=None) -> dict | None:
    """Scan-corrected roofline record for one LM arch × shape cell.

    In-process callers must pass ``mesh`` (the production mesh needs a
    512-device host platform; arrange ``XLA_FLAGS`` before jax
    initialises, or go through the CLI, which scopes the flag to a
    subprocess).  With ``mesh=None`` this builds
    ``make_production_mesh()`` against whatever devices exist and will
    raise on a plain CPU host — by design, instead of silently mutating
    global process state the way earlier revisions did.
    """
    from repro.configs import SHAPES, applicable, get_config
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    ok, why = applicable(cfg, shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": "single"}
    out = pathlib.Path(outdir)
    out.mkdir(parents=True, exist_ok=True)
    if not ok:
        rec.update(status="skipped", reason=why)
        (out / f"{arch}__{shape_name}.json").write_text(json.dumps(rec))
        return rec

    mesh = mesh or make_production_mesh(multi_pod=False)
    has_attn = any(m == "attn" for m, _ in cfg.period)
    shape_k = SHAPES[shape_name].kind
    need_unchunked = has_attn and shape_k in ("train", "prefill")
    x1 = _cell_costs(arch, shape_name, 1, mesh, need_unchunked)
    x2 = _cell_costs(arch, shape_name, 2, mesh, need_unchunked)
    n = cfg.n_periods
    total = {k: x1[k] + (n - 1) * (x2[k] - x1[k]) for k in x1}
    if need_unchunked:
        # wire bytes from the production (chunked) path: the unchunked
        # compile reshards S² score tensors that never exist on TPU
        w1 = _cell_costs(arch, shape_name, 1, mesh, False)
        w2 = _cell_costs(arch, shape_name, 2, mesh, False)
        total["wire"] = w1["wire"] + (n - 1) * (w2["wire"] - w1["wire"])
        total["wire_unchunked"] = (x1["wire"]
                                   + (n - 1) * (x2["wire"] - x1["wire"]))

    shape = SHAPES[shape_name]
    chips = mesh.devices.size
    # analytic correction: FLOPs inside per-token recurrent scans are
    # counted once by cost analysis and cannot be unrolled (S=4k steps);
    # add the state-update arithmetic explicitly (<10% of any cell)
    rec_flops = 0.0
    per_layer = {"mamba": 10.0 * cfg.d_inner * cfg.d_state,
                 "mlstm": 5.0 * cfg.n_heads * cfg.hd ** 2,
                 "slstm": 8.0 * cfg.n_heads * cfg.hd ** 2}
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind in ("train", "prefill") else shape.global_batch)
    mult = 4.0 if shape.kind == "train" else 1.0  # fwd+bwd+remat
    for mixer, _ in cfg.period:
        if mixer in per_layer:
            rec_flops += per_layer[mixer] * n * tokens * mult
    total["flops"] += rec_flops / chips

    # flash-aware attention adjustments (train/prefill, self-attn):
    # the analysis compile materializes S² scores in HBM and computes the
    # full (non-causal-skipped) score matrix; the Pallas flash kernel
    # (kernels/flash_attention.py) keeps scores in VMEM and skips masked
    # blocks.  Record both raw and flash-adjusted numbers.
    n_attn = sum(1 for m, _ in cfg.period if m == "attn") * n
    B, S = shape.global_batch, shape.seq_len
    adj = dict(total)
    if shape.kind in ("train", "prefill") and n_attn and S > 1:
        H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        attn_flops = 4.0 * B * S * S * H * hd * n_attn * mult
        scores_bytes = 4.0 * 4.0 * B * H * S * S * n_attn * mult  # f32 r/w
        bq = 512
        flash_bytes = (2 * B * S * H * hd * 2 +
                       max(S // bq, 1) * B * S * KH * hd * 2) * n_attn * mult
        adj["flops"] = total["flops"] - 0.5 * attn_flops / chips  # causal skip
        adj["bytes"] = max(total["bytes"] - scores_bytes / chips
                           + flash_bytes / chips, flash_bytes / chips)
    rec["flash_adjusted"] = {k: adj[k] for k in ("flops", "bytes", "wire")}
    rec.update(
        status="ok", chips=int(chips),
        flops=total["flops"],                 # per chip, scan-corrected
        hbm_bytes=total["bytes"],
        wire_bytes_per_chip=total["wire"],
        per_period={k: x2[k] - x1[k] for k in x1},
        model_flops=model_flops(cfg, shape.kind, shape.seq_len,
                                shape.global_batch) / chips,
    )
    (out / f"{arch}__{shape_name}.json").write_text(json.dumps(rec, indent=1))
    return rec


def _needs_forced_devices() -> bool:
    return "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", "")


def _reexec_with_forced_devices() -> int:
    """Run the legacy CLI sweep in a child whose env carries the flag.

    This is the *only* place the 512-device host platform is requested,
    and it never leaks into the invoking process (the import-purity
    contract pinned by tests/test_roofline_extract.py).
    """
    import subprocess
    import sys

    flags = (os.environ.get("XLA_FLAGS", "") + " " + FORCED_DEVICE_FLAG)
    env = dict(os.environ, XLA_FLAGS=flags.strip())
    r = subprocess.run([sys.executable, "-m", "repro.roofline.extract",
                        *sys.argv[1:]], env=env)
    return r.returncode


def main() -> int:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default="experiments/roofline")
    ap.add_argument("--control", action="store_true",
                    help="control-kernel mode: megakernel-vs-stitched "
                         "roofline rows for the CEC control step (single "
                         "chip — no forced-device subprocess needed)")
    args = ap.parse_args()

    if args.control:
        out = pathlib.Path(args.out)
        out.mkdir(parents=True, exist_ok=True)
        rows = control_roofline_rows()
        (out / "control_step.json").write_text(json.dumps(rows, indent=1))
        for r in rows:
            if "intensity_flop_per_byte" in r:
                print(f"[control_step × {r['variant']}] "
                      f"flops={r['flops']:.3g} bytes={r['hbm_bytes']:.3g} "
                      f"intensity={r['intensity_flop_per_byte']:.2f} "
                      f"({r['bound']}-bound)", flush=True)
        return 0

    # legacy LM-stack sweep: the production mesh needs 512 host devices —
    # request them in a child process, never in this one
    if _needs_forced_devices():
        return _reexec_with_forced_devices()

    from repro.configs import ARCH_IDS, SHAPES
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=False)
    archs = (args.arch,) if args.arch else ARCH_IDS
    shapes = (args.shape,) if args.shape else tuple(SHAPES)
    for a in archs:
        for s in shapes:
            try:
                r = analyze_cell(a, s, args.out, mesh)
                if r and r.get("status") == "ok":
                    print(f"[{a} × {s}] flops/chip={r['flops']:.3g} "
                          f"bytes/chip={r['hbm_bytes']:.3g} "
                          f"wire/chip={r['wire_bytes_per_chip']:.3g}",
                          flush=True)
                else:
                    print(f"[{a} × {s}] skipped", flush=True)
            except Exception as e:  # noqa: BLE001
                print(f"[{a} × {s}] FAILED: {e!r}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
