"""Scan-aware cost extraction for the roofline (§Roofline methodology).

``compiled.cost_analysis()`` counts a ``lax.scan`` body ONCE, so a
62-layer stack reports ~1 layer of FLOPs.  Because every stack here is a
homogeneous repetition of one period, every cost is affine in the period
count:  X(L) = X(1) + (L−1)·ΔX.  We therefore compile two shallow
variants of each cell (1 and 2 periods, same shapes/sharding) and
extrapolate — exact for compute, HBM bytes and collective wire bytes,
including the out-of-loop terms (embeddings, logits, FSDP all-gathers of
the stacked parameters) which the affine form also captures.

Analysis mode additionally disables attention q-chunking (the chunk loop
is itself a scan) so the full O(S²) attention FLOPs are visible to the
cost model.  Known residual: FLOPs *inside* per-token recurrent scans
(mamba/mLSTM state updates) remain counted once; for every assigned arch
these are <10% of the matmul FLOPs (the projections sit outside the
scan) — noted in EXPERIMENTS.md.
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import dataclasses
import json
import pathlib

import jax

from repro.configs import SHAPES, applicable, get_config
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.parallel.annotate import activation_sharding
from repro.roofline.analysis import model_flops
from repro.roofline.hlo import parse_collectives


def _variant(cfg, n_periods: int):
    kw = dict(n_layers=len(cfg.period) * n_periods)
    if cfg.enc_dec:
        kw["n_enc_layers"] = n_periods
    cfg = dataclasses.replace(cfg, **kw)
    # §Perf variant: tighter MoE capacity factor (1.25 → 1.0)
    if "cf10" in os.environ.get("REPRO_PERF_VARIANT", "") and cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.0))
    return cfg


def _cell_costs(arch: str, shape_name: str, n_periods: int, mesh,
                unchunk_attention: bool) -> dict:
    """One analysis compile.

    ``unchunk_attention=True`` exposes the full O(S²) attention FLOPs to
    the cost model but lets GSPMD form (and reshard) S² score tensors that
    the production chunked/flash path never materializes — so FLOPs come
    from the unchunked compile and collective wire bytes from the chunked
    (production) compile.
    """
    from repro.launch import dryrun as D
    from repro.models import layers as L
    from repro.models import model as M

    cfg_full = get_config(arch)
    cfg = _variant(cfg_full, n_periods)

    # monkey-patch dryrun's registry handle so input_specs builds the variant
    orig = D.get_config
    D.get_config = lambda a, smoke=False: cfg if a == arch else orig(a, smoke)
    old_chunk = L.multihead_attention.__defaults__
    try:
        # layer scan unrolled → exact per-period costs
        M.UNROLL_SCAN = True
        if unchunk_attention:
            L.multihead_attention.__defaults__ = (0, None, 1 << 30)
        cfg2, step, args, kinds = D.input_specs(arch, shape_name)
        in_sh = D.shardings_for(cfg2, mesh, args, kinds)
        pv = os.environ.get("REPRO_PERF_VARIANT", "")
        if "fsdp256" in pv:
            ctx = activation_sharding(mesh, tuple(mesh.axis_names),
                                      model_axis=None)
        else:
            ctx = activation_sharding(mesh, dp_axes(mesh))
        with mesh, ctx:
            compiled = jax.jit(step, in_shardings=in_sh).lower(*args).compile()
    finally:
        M.UNROLL_SCAN = False
        D.get_config = orig
        L.multihead_attention.__defaults__ = old_chunk

    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    coll = parse_collectives(compiled.as_text(),
                             default_group=mesh.shape["model"])
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "wire": float(coll["total"]["wire_bytes"])}


def analyze_cell(arch: str, shape_name: str, outdir="experiments/roofline",
                 mesh=None) -> dict | None:
    cfg = get_config(arch)
    ok, why = applicable(cfg, shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": "single"}
    out = pathlib.Path(outdir)
    out.mkdir(parents=True, exist_ok=True)
    if not ok:
        rec.update(status="skipped", reason=why)
        (out / f"{arch}__{shape_name}.json").write_text(json.dumps(rec))
        return rec

    mesh = mesh or make_production_mesh(multi_pod=False)
    has_attn = any(m == "attn" for m, _ in cfg.period)
    shape_k = SHAPES[shape_name].kind
    need_unchunked = has_attn and shape_k in ("train", "prefill")
    x1 = _cell_costs(arch, shape_name, 1, mesh, need_unchunked)
    x2 = _cell_costs(arch, shape_name, 2, mesh, need_unchunked)
    n = cfg.n_periods
    total = {k: x1[k] + (n - 1) * (x2[k] - x1[k]) for k in x1}
    if need_unchunked:
        # wire bytes from the production (chunked) path: the unchunked
        # compile reshards S² score tensors that never exist on TPU
        w1 = _cell_costs(arch, shape_name, 1, mesh, False)
        w2 = _cell_costs(arch, shape_name, 2, mesh, False)
        total["wire"] = w1["wire"] + (n - 1) * (w2["wire"] - w1["wire"])
        total["wire_unchunked"] = (x1["wire"]
                                   + (n - 1) * (x2["wire"] - x1["wire"]))

    shape = SHAPES[shape_name]
    chips = mesh.devices.size
    # analytic correction: FLOPs inside per-token recurrent scans are
    # counted once by cost analysis and cannot be unrolled (S=4k steps);
    # add the state-update arithmetic explicitly (<10% of any cell)
    rec_flops = 0.0
    per_layer = {"mamba": 10.0 * cfg.d_inner * cfg.d_state,
                 "mlstm": 5.0 * cfg.n_heads * cfg.hd ** 2,
                 "slstm": 8.0 * cfg.n_heads * cfg.hd ** 2}
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind in ("train", "prefill") else shape.global_batch)
    mult = 4.0 if shape.kind == "train" else 1.0  # fwd+bwd+remat
    for mixer, _ in cfg.period:
        if mixer in per_layer:
            rec_flops += per_layer[mixer] * n * tokens * mult
    total["flops"] += rec_flops / chips

    # flash-aware attention adjustments (train/prefill, self-attn):
    # the analysis compile materializes S² scores in HBM and computes the
    # full (non-causal-skipped) score matrix; the Pallas flash kernel
    # (kernels/flash_attention.py) keeps scores in VMEM and skips masked
    # blocks.  Record both raw and flash-adjusted numbers.
    n_attn = sum(1 for m, _ in cfg.period if m == "attn") * n
    B, S = shape.global_batch, shape.seq_len
    adj = dict(total)
    if shape.kind in ("train", "prefill") and n_attn and S > 1:
        H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        attn_flops = 4.0 * B * S * S * H * hd * n_attn * mult
        scores_bytes = 4.0 * 4.0 * B * H * S * S * n_attn * mult  # f32 r/w
        bq = 512
        flash_bytes = (2 * B * S * H * hd * 2 +
                       max(S // bq, 1) * B * S * KH * hd * 2) * n_attn * mult
        adj["flops"] = total["flops"] - 0.5 * attn_flops / chips  # causal skip
        adj["bytes"] = max(total["bytes"] - scores_bytes / chips
                           + flash_bytes / chips, flash_bytes / chips)
    rec["flash_adjusted"] = {k: adj[k] for k in ("flops", "bytes", "wire")}
    rec.update(
        status="ok", chips=int(chips),
        flops=total["flops"],                 # per chip, scan-corrected
        hbm_bytes=total["bytes"],
        wire_bytes_per_chip=total["wire"],
        per_period={k: x2[k] - x1[k] for k in x1},
        model_flops=model_flops(cfg, shape.kind, shape.seq_len,
                                shape.global_batch) / chips,
    )
    (out / f"{arch}__{shape_name}.json").write_text(json.dumps(rec, indent=1))
    return rec


def main():
    import argparse

    from repro.configs import ARCH_IDS

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default="experiments/roofline")
    args = ap.parse_args()
    mesh = make_production_mesh(multi_pod=False)
    archs = (args.arch,) if args.arch else ARCH_IDS
    shapes = (args.shape,) if args.shape else tuple(SHAPES)
    for a in archs:
        for s in shapes:
            try:
                r = analyze_cell(a, s, args.out, mesh)
                if r and r.get("status") == "ok":
                    print(f"[{a} × {s}] flops/chip={r['flops']:.3g} "
                          f"bytes/chip={r['hbm_bytes']:.3g} "
                          f"wire/chip={r['wire_bytes_per_chip']:.3g}",
                          flush=True)
                else:
                    print(f"[{a} × {s}] skipped", flush=True)
            except Exception as e:  # noqa: BLE001
                print(f"[{a} × {s}] FAILED: {e!r}", flush=True)


if __name__ == "__main__":
    main()
