"""HLO-text analysis: collective traffic extraction for the roofline.

``cost_analysis()`` gives FLOPs and HBM bytes but not collective bytes, so
we parse the post-SPMD HLO: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op line carries its result shape and replica
groups.  Per-chip traffic model (ring schedules):

  all-reduce       2·(n−1)/n · bytes     (reduce-scatter + all-gather)
  all-gather       (n−1)/n  · bytes      (bytes = full gathered result)
  reduce-scatter   (n−1)/n  · bytes      (bytes = full input)
  all-to-all       (n−1)/n  · bytes
  collective-permute        1 · bytes
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(?)((?:[a-z0-9]+\[[0-9,]*\][^ ]*(?:,\s*)?)+)\)?\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))        # [num_groups, group_size]<=[N]
    return default


def parse_collectives(hlo_text: str, default_group: int = 16) -> dict:
    """→ {op: {'count', 'result_bytes', 'wire_bytes'}} + totals.

    ``wire_bytes`` is the per-chip traffic under the ring model above.
    Deduplicates fusion-internal repeats by scanning top-level op lines.
    """
    stats: dict = defaultdict(lambda: {"count": 0, "result_bytes": 0,
                                       "wire_bytes": 0.0})
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done" in line:
            continue
        types, op = m.group(1), m.group(2)
        size = _shape_bytes(types)
        n = max(_group_size(line, default_group), 1)
        if op == "all-reduce":
            wire = 2.0 * (n - 1) / n * size
        elif op == "collective-permute":
            wire = float(size)
        else:
            wire = (n - 1) / n * size
        s = stats[op]
        s["count"] += 1
        s["result_bytes"] += size
        s["wire_bytes"] += wire
    total = {"count": sum(s["count"] for s in stats.values()),
             "result_bytes": sum(s["result_bytes"] for s in stats.values()),
             "wire_bytes": sum(s["wire_bytes"] for s in stats.values())}
    out = dict(stats)
    out["total"] = total
    return out
