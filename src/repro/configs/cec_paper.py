"""The paper's own evaluation scenario (§IV Experiment Setup).

Connected-ER(25, 0.2), 3 DNN model versions, total input rate λ=60,
link capacities U[0, 2·C̄] with C̄=10, exp link cost, log utilities.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CECScenario:
    n_nodes: int = 25
    er_p: float = 0.2
    n_versions: int = 3
    lam_total: float = 60.0
    mean_link_capacity: float = 10.0
    cost_name: str = "exp"
    utility_kind: str = "log"
    delta: float = 0.5
    eta_outer: float = 0.05
    eta_inner: float = 3.0


PAPER = CECScenario()


def build(scenario: CECScenario = PAPER, seed: int = 1):
    """(graph, utility bank) for the scenario."""
    from repro.core import build_random_cec, make_bank
    from repro.topo import connected_er

    adj = connected_er(scenario.n_nodes, scenario.er_p, seed=seed)
    graph = build_random_cec(adj, scenario.n_versions,
                             scenario.mean_link_capacity, seed=0)
    bank = make_bank(scenario.utility_kind, scenario.n_versions, seed=0,
                     lam_total=scenario.lam_total)
    return graph, bank


def solver_config(scenario: CECScenario = PAPER, *,
                  method: str = "single"):
    """The §IV evaluation knobs as a named ``SolverConfig`` preset.

    The paper runs its online evaluation with the hot η_inner=3.0 oracle
    (cf. ``solver.serving_defaults``); ``method`` picks GS-OMA
    ("nested") or OMAD ("single").
    """
    from repro.core.solver import SolverConfig

    return SolverConfig(method=method, delta=scenario.delta,
                        eta_outer=scenario.eta_outer,
                        eta_inner=scenario.eta_inner,
                        inner_iters=1 if method == "single" else 50)


def build_problem(scenario: CECScenario = PAPER, seed: int = 1):
    """The §IV instance as a first-class ``Problem`` (graph+bank+cost+λ)."""
    from repro.core.problem import Problem

    graph, bank = build(scenario, seed)
    return Problem.create(graph, bank, lam_total=scenario.lam_total,
                          cost=scenario.cost_name)
