"""Assigned input shapes (one set, shared by all 10 LM-family archs).

  train_4k     train_step   seq 4096,   global_batch 256
  prefill_32k  prefill      seq 32768,  global_batch 32
  decode_32k   decode_step  KV 32768,   global_batch 128
  long_500k    decode_step  KV 524288,  global_batch 1   (sub-quadratic only)
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """(runs?, reason).  long_500k needs sub-quadratic decode (DESIGN.md §6)."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 512k dense decode skipped"
    return True, ""
