"""Assigned architecture config — see the source tag on CONFIG.

FULL config is exercised only via the multi-pod dry-run (no allocation);
SMOKE is the reduced same-family config used in CPU tests.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", n_layers=24, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=1408, vocab=151936,
    period=(("attn", "moe"),),
    moe=MoEConfig(n_experts=60, top_k=4, d_ff_expert=1408, n_shared=4),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B (4 shared + 60 routed top-4)")

SMOKE = ModelConfig(
    name="qwen2-moe-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=96, vocab=256, period=(("attn", "moe"),),
    moe=MoEConfig(n_experts=8, top_k=4, d_ff_expert=96, n_shared=2))
