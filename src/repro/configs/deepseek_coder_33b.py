"""Assigned architecture config — see the source tag on CONFIG.

FULL config is exercised only via the multi-pod dry-run (no allocation);
SMOKE is the reduced same-family config used in CPU tests.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b", n_layers=62, d_model=7168, n_heads=56,
    n_kv_heads=8, d_ff=19200, vocab=32256,
    period=(("attn", "dense"),), rope_theta=100000.0,
    source="arXiv:2401.14196; hf (llama-arch dense, GQA kv=8)")

SMOKE = ModelConfig(
    name="deepseek-coder-33b-smoke", n_layers=2, d_model=64, n_heads=8,
    n_kv_heads=2, d_ff=160, vocab=256, period=(("attn", "dense"),))
