"""Assigned architecture config — see the source tag on CONFIG.

FULL config is exercised only via the multi-pod dry-run (no allocation);
SMOKE is the reduced same-family config used in CPU tests.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", n_layers=32, d_model=1280, n_heads=20,
    n_kv_heads=20, d_ff=5120, vocab=51866,
    period=(("attn", "dense"),), enc_dec=True, n_enc_layers=32,
    enc_seq=1500, frontend="audio", rope="none", norm="ln", mlp_act="gelu",
    source="arXiv:2212.04356 (enc-dec, conv frontend stubbed)")

SMOKE = ModelConfig(
    name="whisper-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, period=(("attn", "dense"),), enc_dec=True,
    n_enc_layers=2, enc_seq=32, frontend="audio", rope="none", norm="ln",
    mlp_act="gelu")
