"""Assigned architecture config — see the source tag on CONFIG.

FULL config is exercised only via the multi-pod dry-run (no allocation);
SMOKE is the reduced same-family config used in CPU tests.
"""
from repro.models.config import ModelConfig, MoEConfig

_PERIOD = tuple(("slstm" if i == 0 else "mlstm", "none") for i in range(8))

CONFIG = ModelConfig(
    name="xlstm-1.3b", n_layers=48, d_model=2048, n_heads=4,
    n_kv_heads=4, d_ff=0, vocab=50304, period=_PERIOD,
    source="arXiv:2405.04517 (sLSTM + mLSTM blocks, 7:1)")

_SMOKE_PERIOD = tuple(("slstm" if i == 0 else "mlstm", "none")
                      for i in range(2))

SMOKE = ModelConfig(
    name="xlstm-smoke", n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
    d_ff=0, vocab=256, period=_SMOKE_PERIOD)
