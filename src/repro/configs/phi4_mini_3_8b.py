"""Assigned architecture config — see the source tag on CONFIG.

FULL config is exercised only via the multi-pod dry-run (no allocation);
SMOKE is the reduced same-family config used in CPU tests.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b", n_layers=32, d_model=3072, n_heads=24,
    n_kv_heads=8, d_ff=8192, vocab=200064,
    period=(("attn", "dense"),),
    source="arXiv:2412.08905; hf (RoPE SwiGLU GQA)")

SMOKE = ModelConfig(
    name="phi4-mini-smoke", n_layers=2, d_model=48, n_heads=6,
    n_kv_heads=2, d_ff=128, vocab=512, period=(("attn", "dense"),))
