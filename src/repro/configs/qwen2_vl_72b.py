"""Assigned architecture config — see the source tag on CONFIG.

FULL config is exercised only via the multi-pod dry-run (no allocation);
SMOKE is the reduced same-family config used in CPU tests.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", n_layers=80, d_model=8192, n_heads=64,
    n_kv_heads=8, d_ff=29568, vocab=152064,
    period=(("attn", "dense"),), rope="mrope", frontend="vision",
    mrope_sections=(16, 24, 24),
    source="arXiv:2409.12191; hf (M-RoPE, vision tower stubbed)")

SMOKE = ModelConfig(
    name="qwen2-vl-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, period=(("attn", "dense"),), rope="mrope",
    frontend="vision", mrope_sections=(2, 3, 3))
