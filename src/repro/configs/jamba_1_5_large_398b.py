"""Assigned architecture config — see the source tag on CONFIG.

FULL config is exercised only via the multi-pod dry-run (no allocation);
SMOKE is the reduced same-family config used in CPU tests.
"""
from repro.models.config import ModelConfig, MoEConfig

_PERIOD = tuple(
    ("attn" if i == 0 else "mamba", "moe" if i % 2 == 1 else "dense")
    for i in range(8))

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", n_layers=72, d_model=8192, n_heads=64,
    n_kv_heads=8, d_ff=24576, vocab=65536, period=_PERIOD,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576),
    source="arXiv:2403.19887; hf (Mamba+attn 1:7 interleave, MoE 16e top-2)")

_SMOKE_PERIOD = tuple(
    ("attn" if i == 0 else "mamba", "moe" if i % 2 == 1 else "dense")
    for i in range(4))

SMOKE = ModelConfig(
    name="jamba-smoke", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, period=_SMOKE_PERIOD,
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128), d_state=8)
