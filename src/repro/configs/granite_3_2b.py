"""Assigned architecture config — see the source tag on CONFIG.

FULL config is exercised only via the multi-pod dry-run (no allocation);
SMOKE is the reduced same-family config used in CPU tests.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-3-2b", n_layers=40, d_model=2048, n_heads=32,
    n_kv_heads=8, d_ff=8192, vocab=49155,
    period=(("attn", "dense"),), tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-2b-base (GQA)")

SMOKE = ModelConfig(
    name="granite-smoke", n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=192, vocab=320, period=(("attn", "dense"),), tie_embeddings=True)
