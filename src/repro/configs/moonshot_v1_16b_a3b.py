"""Assigned architecture config — see the source tag on CONFIG.

FULL config is exercised only via the multi-pod dry-run (no allocation);
SMOKE is the reduced same-family config used in CPU tests.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", n_layers=48, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=1408, vocab=163840,
    period=(("attn", "moe"),),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2),
    source="hf:moonshotai/Moonlight-16B-A3B (64e top-6)")

SMOKE = ModelConfig(
    name="moonshot-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=96, vocab=256, period=(("attn", "moe"),),
    moe=MoEConfig(n_experts=8, top_k=6, d_ff_expert=96, n_shared=2))
