"""Architecture registry: ``--arch <id>`` → ModelConfig.

Also exposes the paper's own CEC scenario config (cec_paper) and the
assigned shape table (shapes.SHAPES).
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

from .shapes import SHAPES, ShapeSpec, applicable  # noqa: F401

_MODULES = {
    "deepseek-coder-33b": "deepseek_coder_33b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "granite-3-2b": "granite_3_2b",
    "smollm-135m": "smollm_135m",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "whisper-large-v3": "whisper_large_v3",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "xlstm-1.3b": "xlstm_1_3b",
    "qwen2-vl-72b": "qwen2_vl_72b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE if smoke else mod.CONFIG
