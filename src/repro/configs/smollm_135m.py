"""Assigned architecture config — see the source tag on CONFIG.

FULL config is exercised only via the multi-pod dry-run (no allocation);
SMOKE is the reduced same-family config used in CPU tests.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="smollm-135m", n_layers=30, d_model=576, n_heads=9,
    n_kv_heads=3, d_ff=1536, vocab=49152,
    period=(("attn", "dense"),), tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M (llama-arch small)")

SMOKE = ModelConfig(
    name="smollm-smoke", n_layers=2, d_model=48, n_heads=3, n_kv_heads=3,
    d_ff=128, vocab=256, period=(("attn", "dense"),), tie_embeddings=True)
