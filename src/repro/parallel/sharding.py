"""Sharding rules: fleet-axis specs for the solver core + LM PartitionSpecs.

Two consumers share this module:

* **The solver core's fleet axis** (DESIGN.md §14): ``run_batch_sharded``
  shards the instance/seed axis of a stacked ``Problem``/``SolverState``
  pytree across a 1-D device mesh.  :func:`fleet_axis` names the mesh
  axis, :func:`fleet_specs` builds the leading-axis PartitionSpec tree,
  and :func:`pad_fleet`/:func:`unpad_fleet` implement uneven-shard
  padding with exact masking (pad lanes replicate the last real
  instance — a feasible solve whose rows are sliced off afterwards, so
  unpad(pad(x)) is bit-identical to x).
* **The vestigial LM stack** (DESIGN.md §5): parameter/batch/cache
  PartitionSpecs below.

LM scheme (DESIGN.md §5):
  * weights — 2-D sharded: the d_model-ish dim FSDP over the data axes
    ('pod','data'), the wide dim (d_ff / flattened heads / vocab) TP over
    'model'.  Flattened head dims (H·hd) are 16-divisible for *all* ten
    archs, unlike raw head counts (56, 24, 9, 20 …) — this is what makes a
    single rule set compile everywhere.
  * MoE experts — expert-parallel over 'model', FSDP over data axes.
  * optimizer moments — sharded exactly like their weights (ZeRO-3).
  * KV caches — batch over data axes, *sequence* over 'model' (kv-head
    counts are ≤ 8 and cannot shard 16 ways; sequence always can).
  * batch — global batch over data axes when divisible (long_500k has
    B=1: batch stays replicated and the cache carries all the sharding).

Rules are keyed on parameter tree paths, so they apply uniformly to the
scan-stacked [n_periods, ...] leaves (leading dim unsharded).
"""
from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

# ---------------------------------------------------------------------------
# fleet-axis helpers (solver core, DESIGN.md §14)
# ---------------------------------------------------------------------------

FLEET_AXIS = "fleet"


def fleet_axis(mesh) -> str:
    """The mesh axis carrying the instance/seed dimension.

    A fleet mesh is 1-D (``launch.mesh.fleet_mesh``); for convenience any
    mesh with a ``"fleet"`` axis qualifies.  Raises on meshes where the
    fleet axis is ambiguous — sharding the instance axis over a silently
    guessed axis would be an invisible wrong answer.
    """
    if FLEET_AXIS in mesh.axis_names:
        return FLEET_AXIS
    if len(mesh.axis_names) == 1:
        return mesh.axis_names[0]
    raise ValueError(
        f"mesh axes {mesh.axis_names} have no '{FLEET_AXIS}' axis and are "
        "not 1-D: name the instance axis explicitly (launch.mesh."
        "fleet_mesh builds the canonical 1-D fleet mesh)")


def fleet_spec(ndim: int, axis: str = FLEET_AXIS) -> P:
    """Leading-axis PartitionSpec for one rank-``ndim`` leaf.

    Rank-0 leaves (scalars) have no instance axis and replicate.
    """
    if ndim == 0:
        return P()
    return P(axis, *([None] * (ndim - 1)))


def fleet_specs(tree: Any, axis: str = FLEET_AXIS, *,
                shard: bool = True) -> Any:
    """PartitionSpec tree sharding every leaf's leading axis over ``axis``.

    ``shard=False`` replicates the whole tree (the broadcast-bank /
    scalar-demand case).  Works on concrete arrays and on
    ``ShapeDtypeStruct`` trees from ``jax.eval_shape`` alike.
    """
    def spec_for(leaf):
        ndim = len(leaf.shape) if hasattr(leaf, "shape") else jnp.ndim(leaf)
        return fleet_spec(ndim, axis) if shard else P()

    return jax.tree_util.tree_map(spec_for, tree)


def fleet_padded_size(size: int, n_shards: int) -> int:
    """The smallest multiple of ``n_shards`` that is ≥ ``size``."""
    if size < 1 or n_shards < 1:
        raise ValueError(f"need size ≥ 1 and n_shards ≥ 1, got "
                         f"({size}, {n_shards})")
    return -(-size // n_shards) * n_shards


def pad_fleet(tree: Any, n_shards: int) -> Any:
    """Pad every leaf's leading axis up to a multiple of ``n_shards``.

    Pad lanes replicate the **last real instance**, so they carry a
    feasible problem (no NaN-generating zero masks enter the solve) and
    every shard runs the same program.  Exactness comes from masking on
    the way out: :func:`unpad_fleet` slices the pad lanes off, making
    ``unpad_fleet(pad_fleet(x, n), B)`` bit-identical to ``x``.
    """
    def pad_leaf(leaf):
        leaf = jnp.asarray(leaf)
        b = leaf.shape[0]
        extra = fleet_padded_size(b, n_shards) - b
        if extra == 0:
            return leaf
        fill = jnp.broadcast_to(leaf[-1:], (extra,) + leaf.shape[1:])
        return jnp.concatenate([leaf, fill], axis=0)

    return jax.tree_util.tree_map(pad_leaf, tree)


def unpad_fleet(tree: Any, size: int) -> Any:
    """Slice every leaf's leading axis back to the true fleet ``size``."""
    return jax.tree_util.tree_map(lambda leaf: leaf[:size], tree)


# ---------------------------------------------------------------------------
# LM parameter/batch/cache rules (DESIGN.md §5)
# ---------------------------------------------------------------------------


def _fsdp(mesh) -> tuple[str, ...] | str | None:
    axes = tuple(a for a in mesh.axis_names if a != "model")
    return axes if len(axes) > 1 else axes[0]


# (path regex, candidate spec builders) — first match wins, then the first
# candidate whose sharded dims all divide evenly is used (e.g. qwen2-moe's
# 60 experts can't split 16-way → falls back to TP inside the experts).
_RULES = [
    (r"embed$",                 lambda F: [P("model", F), P(None, F)]),
    (r"lm_head$",               lambda F: [P(F, "model"), P(F, None)]),
    (r"pos$",                   lambda F: [P(None, F)]),
    (r"(mixer|xattn)/w[qkv]$",  lambda F: [P(F, "model")]),
    (r"(mixer|xattn)/wo$",      lambda F: [P("model", F)]),
    (r"mixer/wo_gate$",         lambda F: [P(F, "model")]),
    (r"mixer/wif$",             lambda F: [P(F, None)]),
    (r"mixer/wx$",              lambda F: [P(F, "model")]),
    (r"mixer/wr$",              lambda F: [P(None, None, "model")]),
    (r"mixer/in_proj$",         lambda F: [P(F, "model")]),
    (r"mixer/out_proj$",        lambda F: [P("model", F)]),
    (r"mixer/conv$",            lambda F: [P(None, "model")]),
    (r"mixer/x_proj$",          lambda F: [P("model", None)]),
    (r"mixer/dt_w$",            lambda F: [P(None, "model")]),
    (r"mixer/dt_bias$",         lambda F: [P("model")]),
    (r"mixer/A_log$",           lambda F: [P("model", None)]),
    (r"mixer/D$",               lambda F: [P("model")]),
    (r"mlp/router$",            lambda F: [P(F, None)]),
    (r"mlp/w[ig]$",             lambda F: [P("model", F, None),    # EP
                                           P(None, "model", F)]),  # TP
    (r"mlp/wo$",                lambda F: [P("model", None, F),
                                           P(None, "model", F)]),
    (r"mlp/shared/w[ig]$",      lambda F: [P(F, "model")]),
    (r"mlp/shared/wo$",         lambda F: [P("model", F)]),
    (r"norm", lambda F: [P()]),          # replicated norms / biases
]

# dense (non-MoE) mlp leaves are 2-D: override the 3-D expert rule
_DENSE_MLP = {
    "mlp/wi": lambda F: [P(F, "model")],
    "mlp/wg": lambda F: [P(F, "model")],
    "mlp/wo": lambda F: [P("model", F)],
}


def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def _first_valid(cands, shape, mesh) -> P:
    """First candidate whose sharded dims divide evenly; axes that never
    divide are dropped entry-wise as a last resort."""
    for spec in cands:
        parts = list(spec) + [None] * (len(shape) - len(spec))
        if all(d % _axis_size(mesh, e) == 0 for d, e in zip(shape, parts)):
            return spec
    parts = list(cands[0]) + [None] * (len(shape) - len(cands[0]))
    fixed = [e if d % _axis_size(mesh, e) == 0 else None
             for d, e in zip(shape, parts)]
    return P(*fixed)


def _path_str(path) -> str:
    out = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            out.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            out.append(str(k.idx))
    return "/".join(out)


def param_specs(cfg: ModelConfig, params_shape: Any, mesh,
                serve_tp_only: bool = False, fsdp_all: bool = False) -> Any:
    """PartitionSpec tree matching ``params_shape`` (a ShapeDtypeStruct
    tree from eval_shape — no allocation needed).

    ``serve_tp_only`` (§Perf variant): replicate weights over the data
    axes, shard only over 'model' — serving then pays ZERO per-step
    parameter all-gathers (decode reads every weight every token, so the
    FSDP gather dominates decode wire traffic).  Applied only when the
    TP-only per-chip footprint fits HBM; oversized models (jamba-398B)
    keep 2-D sharding.

    ``fsdp_all`` (§Perf variant): pure ZeRO-3 over the whole mesh, no
    tensor parallelism.  Per-layer TP partial-sum all-reduces of
    [B,S,d_model] activations dominate dense train cells (~2 TB/chip/step
    on deepseek-33B); pure FSDP replaces them with parameter all-gathers
    (~3× model size), a ~10× wire reduction when params ≪ activations.
    """
    F = _fsdp(mesh)
    if serve_tp_only:
        per_chip = cfg.approx_params() * 2 / mesh.shape["model"]
        if per_chip <= 12e9:
            F = None
    F_dp = F
    if fsdp_all:                    # True/"all" or "hybrid"
        F = tuple(mesh.axis_names)

    def spec_for(path, leaf):
        ps = _path_str(path)
        ndim = len(leaf.shape)
        stacked = "blocks/" in ps          # scan-stacked leading dim
        base_ndim = ndim - 1 if stacked else ndim
        base_shape = leaf.shape[1:] if stacked else leaf.shape
        key = ps.split("blocks/")[-1]
        key = re.sub(r"^b\d+/", "", key)
        dense_mlp = re.search(r"mlp/(wi|wg|wo)$", key)
        fn = None
        if dense_mlp and base_ndim == 2:
            cands = _DENSE_MLP["mlp/" + dense_mlp.group(1)](F)
        else:
            cands = [P()]
            for pat, fn in _RULES:
                if re.search(pat, key):
                    cands = fn(F)
                    break
        if fsdp_all == "hybrid" and dense_mlp and base_ndim == 3:
            # hybridshard: keep expert parallelism over 'model', FSDP the
            # rest — MoE models where pure FSDP would gather 100s of GB
            cands = fn(F_dp)
        elif fsdp_all:
            cands = [P(*[None if e == "model" else e for e in c])
                     for c in cands]
        spec = _first_valid(cands, base_shape, mesh)
        parts = list(spec)
        parts = parts[:base_ndim] + [None] * (base_ndim - len(parts))
        if stacked:
            parts = [None] + parts
        return P(*parts)

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def batch_specs(cfg: ModelConfig, batch_shape: Any, mesh,
                fsdp_all: bool = False) -> Any:
    """Batch dim over data axes when divisible, else replicated."""
    F = tuple(mesh.axis_names) if fsdp_all else _fsdp(mesh)
    ndev = 1
    for a in (F if isinstance(F, tuple) else (F,)):
        ndev *= mesh.shape[a]

    def spec_for(leaf):
        b = leaf.shape[0]
        lead = F if b % ndev == 0 else None
        return P(lead, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map(spec_for, batch_shape)


def cache_specs(cfg: ModelConfig, cache_shape: Any, mesh) -> Any:
    """KV/state cache: [P, B, T, ...] → batch over data axes (if divisible),
    sequence (attn) or inner dim (ssm/rnn) over 'model'."""
    F = _fsdp(mesh)
    ndev = 1
    for a in (F if isinstance(F, tuple) else (F,)):
        ndev *= mesh.shape[a]

    def spec_for(path, leaf):
        ps = _path_str(path)
        if ps.endswith("len"):
            return P()
        nd = len(leaf.shape)
        bdim = leaf.shape[1] if nd > 1 else 0
        bspec = F if (bdim and bdim % ndev == 0) else None
        name = ps.split("/")[-1]
        if name in ("k", "v", "xk", "xv"):            # [P,B,T,KH,hd]
            spec = P(None, bspec, "model", None, None)
        elif name == "conv":                          # [P,B,K-1,di]
            spec = P(None, bspec, None, "model")
        elif name == "h" and nd == 4:                 # mamba [P,B,di,ds]
            spec = P(None, bspec, "model", None)
        elif name == "C":                             # mlstm [P,B,H,hd,hd]
            spec = P(None, bspec, None, "model", None)
        elif nd == 4:                                 # slstm/mlstm [P,B,H,hd]
            spec = P(None, bspec, None, "model")
        elif nd == 3:
            spec = P(None, bspec, None)
        else:
            spec = P(*([None] * nd))
        return _first_valid([spec], leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)


def make_shardings(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s if s is not None else P()),
        spec_tree, is_leaf=lambda x: isinstance(x, P) or x is None)
