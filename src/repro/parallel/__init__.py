from .sharding import (batch_specs, cache_specs, make_shardings, param_specs)

__all__ = ["batch_specs", "cache_specs", "make_shardings", "param_specs"]
