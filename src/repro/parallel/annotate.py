"""Logical activation-sharding annotations.

The model code marks activations with *logical* axes ('batch', 'model',
None); the launcher activates a mesh mapping and the marks become
``with_sharding_constraint``s.  Without an active mapping they are no-ops,
so model code runs unchanged on a single CPU device (tests, benchmarks).

Divisibility guard: a dim that does not divide its mesh axes falls back to
replicated (e.g. long_500k's global_batch=1 over the 16-way data axis).
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

_STATE = threading.local()


def _mapping():
    return getattr(_STATE, "mapping", None)


@contextlib.contextmanager
def activation_sharding(mesh, batch_axes, model_axis: str | None = "model"):
    """Map logical 'batch' → ``batch_axes``, 'model' → ``model_axis``.

    ``model_axis=None`` (fsdp256 §Perf variant) disables TP constraints —
    activations shard on batch only, weights are pure-FSDP."""
    prev = _mapping()
    _STATE.mapping = {"mesh": mesh, "batch": batch_axes, "model": model_axis}
    try:
        yield
    finally:
        _STATE.mapping = prev


def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def data_parallel_size() -> int:
    """Number of data-parallel shards under the active mapping (1 if none).
    Model code uses this to pick shard-local group counts (MoE dispatch)."""
    m = _mapping()
    if m is None or m.get("batch") is None:
        return 1
    return _axis_size(m["mesh"], m["batch"])


def axis_divides(name: str, dim: int) -> bool:
    """Would logical axis ``name`` shard a dim of size ``dim`` evenly?"""
    m = _mapping()
    if m is None or m.get(name) is None:
        return True
    return dim % _axis_size(m["mesh"], m[name]) == 0


def shard(x, *logical):
    """Constrain ``x`` to the logical spec, e.g. shard(h, 'batch', None,
    'model').  Trailing dims default to None."""
    m = _mapping()
    if m is None:
        return x
    mesh = m["mesh"]
    parts = []
    for dim, name in zip(x.shape, logical):
        entry = m.get(name) if name else None
        if entry is not None and dim % _axis_size(mesh, entry) != 0:
            entry = None
        parts.append(entry)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, P(*parts)))
