"""Distributed-optimization collectives: compression + explicit ring AR.

* ``shard_map_compat`` — the one ``jax.shard_map`` entry point of this
  repo, papering over the 0.4.x → current API drift (experimental vs
  public namespace, ``check_rep`` → ``check_vma`` rename).  The solver
  core's fleet engine (``core/batch.run_batch_sharded``) and the ring
  all-reduce below both go through it, and the CI JAX matrix keeps it
  honest on both ends of the supported range.
* ``quantize_dequantize_int8`` — symmetric per-tensor int8 gradient
  compression.  Hooked in before pjit's gradient reduction it cuts the
  cross-pod all-reduce payload 2× vs bf16 / 4× vs f32 (§Perf iteration 3
  uses it on the collective-bound MoE cell).  Error feedback keeps the
  quantization noise unbiased across steps.
* ``ring_all_reduce`` — a bucketized ring all-reduce built from
  shard_map + ppermute: 2(n−1) steps of reduce-scatter + all-gather whose
  per-hop payloads XLA can overlap with compute (each hop is an async
  collective-permute).  This is the hand-rolled schedule used when the
  default all-reduce sits on the critical path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:                                  # newer jax: public API
    from jax import shard_map as _shard_map
except ImportError:                   # jax 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map
# the replication-check kwarg was renamed check_rep -> check_vma in a
# different release than the public export, so feature-detect it
import inspect as _inspect

_SM_KW = {("check_vma" if "check_vma" in
           _inspect.signature(_shard_map).parameters else "check_rep"): False}


def shard_map_compat(fn, mesh, in_specs, out_specs):
    """``jax.shard_map`` across the supported JAX range (see module doc).

    Replication checking is disabled: the fleet engine's per-shard solves
    are embarrassingly parallel (no cross-shard collectives), which the
    0.4.x checker cannot always prove through a scanned solver body.
    """
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **_SM_KW)


def quantize_int8(x):
    """x → (int8 payload, f32 scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def quantize_dequantize_int8(x):
    """Straight-through int8 round trip (what the wire would carry)."""
    if x.ndim == 0:
        return x
    q, s = quantize_int8(x)
    return dequantize_int8(q, s, x.dtype)


def error_feedback_compress(x, residual):
    """(compressed value, new residual): EF-SGD style error feedback."""
    y = x + residual
    out = quantize_dequantize_int8(y)
    return out, y - out


def ring_all_reduce(x, mesh, axis: str = "data"):
    """All-reduce over one mesh axis via reduce-scatter + all-gather rings.

    x must be divisible by the axis size along dim 0.
    """
    n = mesh.shape[axis]

    def ring(block):
        idx = jax.lax.axis_index(axis)
        chunks = jnp.reshape(block, (n, -1))
        perm = [(i, (i + 1) % n) for i in range(n)]

        # reduce-scatter: after n-1 hops, chunk (idx+1) holds the full sum
        def rs_step(k, ch):
            send = (idx - k) % n
            val = ch[send]
            recv = jax.lax.ppermute(val, axis, perm)
            return ch.at[(idx - k - 1) % n].add(recv)

        chunks = jax.lax.fori_loop(0, n - 1, rs_step, chunks)

        # all-gather ring: circulate each node's reduced chunk
        def ag_step(k, ch):
            send = (idx + 1 - k) % n
            recv = jax.lax.ppermute(ch[send], axis, perm)
            return ch.at[(idx - k) % n].set(recv)

        chunks = jax.lax.fori_loop(0, n - 1, ag_step, chunks)
        return jnp.reshape(chunks, block.shape)

    return shard_map_compat(ring, mesh, P(), P())(x)
