"""Expert-parallel MoE with explicit all-to-all dispatch (shard_map).

The GSPMD path (models/layers.moe_apply) replicates each data-shard's
tokens across the 'model' axis so every expert owner sees them — simple,
but the token activations ride the wire E-owners times.  The GShard/Switch
production schedule shards tokens over *both* mesh axes and moves only the
routed tokens, twice, with all-to-alls:

  tokens [T_loc, D] per device
    → route locally (top-1 here; capacity per (device, expert))
    → dispatch buffers [n_exp_shards, E_loc, cap, D]
    → all_to_all over 'model'  (tokens travel to their expert's owner)
    → local expert FFN [E_loc, n_exp_shards·cap, D]
    → all_to_all back, combine with gate weights

Wire per layer ≈ 2 × routed-token bytes — independent of E — vs the
replicated path's (model_axis−1)× token bytes.  This is the "next lever"
identified for the jamba cell in EXPERIMENTS.md §Perf.

Implemented as a standalone layer (top-1 routing) with a dense oracle
test on an 8-device mesh (tests/test_moe_a2a.py); integration into the
jamba config is left switchable (the GSPMD path remains the default).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .collectives import _SM_KW, _shard_map


def moe_a2a_apply(mesh, params, x, *, capacity_factor: float = 1.5):
    """x [B, S, D] (batch over 'data'); params: router [D,E],
    wi/wo [E, D, F]/[E, F, D] (experts over 'model').  Top-1 routing."""
    E = params["router"].shape[1]
    mp = mesh.shape["model"]
    assert E % mp == 0, (E, mp)
    E_loc = E // mp

    def body(x_loc, router, wi, wo):
        # x_loc [b_loc, S, D] ; wi [E_loc, D, F] ; tokens also sharded on
        # 'model' by splitting the local batch sequence-wise
        b, S, D = x_loc.shape
        T = b * S
        xt = x_loc.reshape(T, D)
        midx = jax.lax.axis_index("model")

        gates = jax.nn.softmax((xt @ router).astype(jnp.float32), -1)
        gval = gates.max(-1)
        gidx = gates.argmax(-1)                                # [T]

        cap = max(int(capacity_factor * T / E), 4)
        # slot of each token within its expert's queue (local capacity)
        onehot = jax.nn.one_hot(gidx, E, dtype=jnp.int32)
        slot = jnp.sum(jnp.cumsum(onehot, 0) * onehot, -1) - 1  # [T]
        keep = slot < cap
        gval = gval * keep
        dest_shard = gidx // E_loc
        dest_exp = gidx % E_loc

        # dispatch buffer [mp, E_loc, cap, D] → all_to_all over 'model'
        buf = jnp.zeros((mp, E_loc, cap + 1, D), x_loc.dtype)
        s_ix = jnp.where(keep, slot, cap)
        buf = buf.at[dest_shard, dest_exp, s_ix].add(xt)
        buf = buf[:, :, :cap]
        recv = jax.lax.all_to_all(buf, "model", 0, 0, tiled=False)
        # recv [mp, E_loc, cap, D]: tokens from every peer for MY experts

        h = jax.nn.silu(jnp.einsum("pecd,edf->pecf", recv, wi))
        ye = jnp.einsum("pecf,efd->pecd", h, wo)

        back = jax.lax.all_to_all(ye, "model", 0, 0, tiled=False)
        # back [mp, E_loc, cap, D]: my tokens, processed, per dest shard
        yt = back[dest_shard, dest_exp, jnp.minimum(s_ix, cap - 1)]
        yt = yt * gval[:, None].astype(x_loc.dtype)
        return yt.reshape(b, S, D)

    fn = _shard_map(
        body, mesh=mesh,
        in_specs=(P("data", None, None), P(None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=P("data", None, None), **_SM_KW)
    return fn(x, params["router"], params["wi"], params["wo"])


def moe_dense_oracle(params, x):
    """Dense top-1 reference: every expert on every token, gate-combined."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    gates = jax.nn.softmax((xt @ params["router"]).astype(jnp.float32), -1)
    gval = gates.max(-1)
    gidx = gates.argmax(-1)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, params["wi"]))
    ye = jnp.einsum("tef,efd->ted", h, params["wo"])
    y = jnp.take_along_axis(
        ye, gidx[:, None, None].repeat(D, -1), 1)[:, 0]
    return (y * gval[:, None].astype(x.dtype)).reshape(B, S, D)
