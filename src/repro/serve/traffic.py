"""Arrival-process generators for the multi-tenant control plane.

The paper's controller is *online*: it tracks the optimum while demand
changes underneath it.  The scenario engine (DESIGN.md §10) models the
*infrastructure* side of that non-stationarity — topology churn,
capacity drift, step demand shifts.  This module models the *traffic*
side: per-interval, per-tenant arrival intensities for the shapes the
related work says matter (congestion under bursty admission, arXiv
2205.00714; reuse-induced load skew under skewed arrivals, arXiv
2401.03620).

Semantics (DESIGN.md §15.4): a :class:`TrafficTrace` carries
**multiplicative intensity factors** ``factors[t, k]`` with mean ≈ 1 —
the *shape* of tenant k's arrival process over ``T`` control intervals,
never an absolute demand level.  Absolute demand comes from elsewhere
(a tenant's provisioned ``lam_total``, or the scenario engine's
``DemandShift`` events via :func:`scenario_base_demand`), and the
effective per-interval demand is the **product**::

    demand[t, k] = base[t or k or scalar] * factors[t, k]

Keeping level and shape in separate factors is what makes scenario
events and traces compose without double-counting: a ``DemandShift``
scales the base, a flash-crowd trace scales the factor, and neither is
ever folded into the other.

All generators are seeded and deterministic: same arguments, same trace
(the fixed-seed contract ``tests/test_traffic.py`` pins).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.scenario import DemandShift, Scenario, event_schedule

__all__ = ["TrafficTrace", "poisson_trace", "diurnal_trace",
           "flash_crowd_trace", "named_traces", "scenario_base_demand"]


@dataclasses.dataclass(frozen=True)
class TrafficTrace:
    """A named arrival process: [T, K] multiplicative intensity factors."""

    name: str
    factors: np.ndarray     # [T, K] float32, mean ≈ 1 per tenant

    def __post_init__(self):
        f = np.asarray(self.factors, np.float32)
        if f.ndim != 2:
            raise ValueError(f"factors must be [T, K], got {f.shape}")
        if (f < 0).any():
            raise ValueError("intensity factors must be non-negative")
        object.__setattr__(self, "factors", f)

    @property
    def horizon(self) -> int:
        return self.factors.shape[0]

    @property
    def n_tenants(self) -> int:
        return self.factors.shape[1]

    def demand(self, base) -> np.ndarray:
        """[T, K] effective demand = ``base`` × factors.

        ``base`` broadcasts: a scalar (one provisioned level for every
        tenant), [K] (per-tenant levels), or [T] / [T, 1] (an
        event-driven base series from :func:`scenario_base_demand` —
        the no-double-counting composition rule from the module
        docstring).
        """
        base = np.asarray(base, np.float32)
        if base.ndim == 1 and base.shape[0] == self.horizon \
                and self.horizon != self.n_tenants:
            base = base[:, None]
        return base * self.factors


def poisson_trace(horizon: int, n_tenants: int, *, seed: int = 0,
                  requests_per_interval: float = 400.0) -> TrafficTrace:
    """Poisson arrivals: iid counts per (interval, tenant), normalized.

    Each factor is ``Poisson(requests_per_interval) /
    requests_per_interval`` — mean exactly 1, relative fluctuation
    ``1/sqrt(requests_per_interval)``, so the parameter is the
    burstiness knob (few requests per control interval → spiky; many →
    smooth).  Tenants draw independently from one seeded generator.
    """
    if requests_per_interval <= 0:
        raise ValueError("requests_per_interval must be positive")
    rng = np.random.default_rng(seed)
    counts = rng.poisson(requests_per_interval, size=(horizon, n_tenants))
    return TrafficTrace("poisson", counts / requests_per_interval)


def diurnal_trace(horizon: int, n_tenants: int, *, period: int = 24,
                  amplitude: float = 0.5) -> TrafficTrace:
    """Deterministic day/night cycle, tenants phase-staggered.

    ``factors[t, k] = 1 + amplitude · sin(2π(t/period + k/K))`` — mean 1
    over any whole period, exactly periodic (``factors[t] ==
    factors[t + period]``), and the per-tenant phase stagger ``k/K``
    models tenants in different time zones so fleet-aggregate demand is
    flatter than any single tenant's.
    """
    if not 0 <= amplitude < 1:
        raise ValueError("amplitude must be in [0, 1) to keep factors > 0")
    t = np.arange(horizon)[:, None]
    k = np.arange(n_tenants)[None, :]
    f = 1.0 + amplitude * np.sin(2 * np.pi * (t / period + k / n_tenants))
    return TrafficTrace("diurnal", f)


def flash_crowd_trace(horizon: int, n_tenants: int, *, at: int,
                      magnitude: float = 3.0, width: int = 8,
                      tenant: int | None = 0) -> TrafficTrace:
    """A sudden spike that decays linearly back to baseline.

    At interval ``at`` the hit tenant's factor jumps to ``magnitude``
    and decays linearly to 1 over ``width`` intervals:
    ``excess[i] = (magnitude − 1)(1 − i/width)`` for ``i = 0..width−1``,
    so the total excess mass is exactly ``(magnitude − 1)(width + 1)/2``
    (the closed form ``tests/test_traffic.py`` asserts).  ``tenant=None``
    hits every tenant at once (a correlated, front-page event);
    otherwise only the indexed tenant spikes while the rest stay flat.
    """
    if not 0 <= at < horizon:
        raise ValueError(f"spike at {at} outside [0, {horizon})")
    if magnitude < 1 or width < 1:
        raise ValueError("need magnitude >= 1 and width >= 1")
    f = np.ones((horizon, n_tenants), np.float32)
    i = np.arange(min(width, horizon - at))
    excess = (magnitude - 1.0) * (1.0 - i / width)
    cols = slice(None) if tenant is None else tenant
    f[at + i, cols] = (1.0 + excess)[:, None] if tenant is None \
        else 1.0 + excess
    return TrafficTrace("flash_crowd", f)


def named_traces(horizon: int, n_tenants: int, *, seed: int = 0
                 ) -> dict[str, TrafficTrace]:
    """The standard churn suite (benchmarks/tests): one trace per shape."""
    return {
        "poisson": poisson_trace(horizon, n_tenants, seed=seed),
        "diurnal": diurnal_trace(horizon, n_tenants,
                                 period=max(4, horizon // 4)),
        "flash_crowd": flash_crowd_trace(
            horizon, n_tenants, at=horizon // 2,
            width=max(1, horizon // 8)),
    }


def scenario_base_demand(scenario: Scenario) -> np.ndarray:
    """[T] event-driven base demand series for one scenario timeline.

    Walks :func:`repro.core.scenario.event_schedule` carrying
    ``lam_total`` across ``DemandShift`` events — the step function the
    offline sweeps and the live router both see.  Multiply by a trace's
    factors (``trace.demand(scenario_base_demand(sc))``) to superimpose
    an arrival process on the scenario's demand plan; because the trace
    is a pure shape (mean ≈ 1), the event's step change is applied
    exactly once.
    """
    base = np.empty(scenario.horizon, np.float32)
    lam_total = scenario.lam_total
    schedule = event_schedule(scenario)
    for (start, events), nxt in zip(
            schedule, [s for s, _ in schedule[1:]] + [scenario.horizon]):
        for ev in events:
            if isinstance(ev, DemandShift):
                lam_total = float(ev.lam_total)
        base[start:nxt] = lam_total
    return base
