"""Batched inference engine with continuous batching + CEC dispatch.

One engine instance per model *version*; requests arrive centrally and
the control plane's published decisions pick where they go: the
admission split Λ/λ picks the version (= the paper's workload
allocation λ_w, the ``SolverState.lam`` the fused step maintains), the
replica weights t_i(w)/λ_w pick the serving device (= the routing
iterate φ).  Single-tenant those reads come from
``CECRouter.admission_split()`` / ``replica_weights()`` (driven by
``ServingSim``, DESIGN.md §11.4); multi-tenant they come from the
``RouterFleet``'s published ``FleetView`` — the double-buffered front
the control plane never donates (DESIGN.md §15.2), so engines keep
serving while the next vmapped control step is in flight.  Decode runs
real model steps (reduced configs on CPU; the pjit'd production path is
exercised by the dry-run).

Continuous batching: fixed ``max_batch`` decode slots; finished sequences
free their slot, queued requests claim slots at every step boundary.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # [S] int32
    max_new_tokens: int = 16
    version: int = 0
    replica: int = 0
    output: list = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.output) >= self.max_new_tokens


class InferenceEngine:
    """Continuous-batching decode loop for one model version.

    The engine is deliberately control-plane-agnostic: it serves
    whatever requests are routed to it and exposes throughput
    (``tokens_served``, drained outputs) — the *measured* signal the
    control plane's utility callback folds into û(Λ) (the
    ``CECRouter.control_step`` / ``RouterFleet.control_step`` batched
    contract, DESIGN.md §11.2).  It never reads solver state; the
    version/replica decisions were already taken from the published
    split/weights when a ``Request`` was stamped.
    """

    def __init__(self, cfg: ModelConfig, params: Any, max_batch: int = 8,
                 max_len: int = 128):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.queue: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * max_batch
        self.cache = M.init_cache(cfg, max_batch, max_len)
        self.tokens_served = 0
        self._decode = jax.jit(
            lambda p, t, c: M.decode_step(cfg, p, t, c))

    def submit(self, req: Request):
        """Queue a request (version/replica already chosen by the router)."""
        if len(req.prompt) > self.max_len:
            raise ValueError(
                f"prompt length {len(req.prompt)} exceeds the cache window "
                f"(max_len={self.max_len})")
        self.queue.append(req)

    def _admit(self):
        for i in range(self.max_batch):
            while self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                # prefill this slot (batch-1 prefill, then graft the cache)
                logits, cache1 = M.prefill(
                    self.cfg, self.params,
                    {"tokens": jnp.asarray(req.prompt)[None]},
                    max_len=self.max_len)
                # graft the batch-1 cache into slot i ("len" is [B], layer
                # entries are [P, B, ...])
                self.cache["len"] = self.cache["len"].at[i].set(
                    cache1["len"][0])
                for key in cache1:
                    if key == "len":
                        continue
                    self.cache[key] = jax.tree_util.tree_map(
                        lambda full, one: full.at[:, i:i + 1].set(
                            one.astype(full.dtype)),
                        self.cache[key], cache1[key])
                req.output.append(int(jnp.argmax(logits[0])))
                # prefill holds len(prompt) cache entries and already emitted
                # output[0]; a decode slot is claimed only if the request
                # wants more tokens AND the next decode's cache write (index
                # len(prompt) + len(output) - 1) stays inside the window —
                # otherwise the request completes here and the slot is free
                # for the next queued request
                if not req.done and \
                        len(req.prompt) + len(req.output) <= self.max_len:
                    self.slots[i] = req

    def step(self) -> int:
        """One decode step for all active slots; returns #active."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return 0
        toks = np.zeros((self.max_batch, 1), np.int32)
        for i in active:
            toks[i, 0] = self.slots[i].output[-1]
        logits, self.cache = self._decode(self.params,
                                          jnp.asarray(toks), self.cache)
        nxt = np.asarray(jnp.argmax(logits, -1))
        for i in active:
            req = self.slots[i]
            req.output.append(int(nxt[i]))
            self.tokens_served += 1
            # free the slot when done or when the *next* decode would write
            # at index len(prompt) + len(output) - 1 >= max_len, i.e. past
            # the grafted window; `>` (not `>=`) lets the final window slot
            # max_len - 1 be used instead of wasting it
            if req.done or len(req.output) + len(req.prompt) > self.max_len:
                self.slots[i] = None
        return len(active)

    def drain(self, max_steps: int = 10_000) -> int:
        """Decode until queue and slots are empty; returns steps taken."""
        steps = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return steps
