"""RouterFleet: K tenant control planes multiplexed on one device.

``CECRouter`` holds one (Λ, φ) and drives one tenant.  Production shape
(ROADMAP "million-session serving") is K tenants — K independent
``Problem`` pytrees sharing one ``SolverConfig`` — stacked on a leading
tenant axis and advanced by **one** jitted ``vmap(solver.step)`` call
per control interval (``core.batch.fused_step_batch``).  The solver
core makes this nearly free: the fleet step is the single-tenant step,
vmapped, so every ``CECRouter`` semantic (perturbation order, oracle
pricing, projection, demand rescale) carries over bit-for-bit — the
parity contract ``tests/test_fleet.py`` pins at ≤ 1e-5 per tenant,
churn included (DESIGN.md §15.1).

Two disciplines distinguish the fleet from a loop over routers:

* **Double-buffered state** (DESIGN.md §15.2): the serving plane never
  reads the solver's working iterates.  Each interval publishes a
  :class:`FleetView` — the admission split and replica weights the
  dispatch path reads — and because JAX dispatch is async, the next
  control step's device work overlaps request serving against the
  previously published view.  The view's Λ is a *computed copy*
  (``lam + 0.0``), never an alias of the working buffer, which is what
  makes the second discipline safe:

* **Buffer donation** (DESIGN.md §15.3): the stacked ``SolverState`` is
  donated into the jitted step (``donate_argnums``), so XLA writes
  iteration t+1 into iteration t's buffers and the steady-state control
  loop allocates nothing per interval.  The donated input is dead after
  the call — only the fleet's own reference is ever donated, and the
  published view holds copies.

Measured utilities arrive through one microbatched callback per
interval: a fleet-batched ``fn([K, 2W, W]) -> [K, 2W]`` covering every
tenant's perturbation sweep in one call, or a sequence of K per-tenant
callables (each the ``CECRouter`` batched/scalar contract,
``cec_router._call_utility``).  Traffic traces (``serve/traffic.py``)
drive per-tenant demand between intervals via :meth:`RouterFleet.
set_demand` — only the traced ``lam_total`` leaf changes, never a
retrace (DESIGN.md §15.4).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CECGraphSparse, propagate
from repro.core import solver as _solver
from repro.core.batch import CECGraphBatch, fused_step_batch, pad_graph
from repro.core.dispatch import state_key as _dispatch_key
from repro.core.graph import CECGraph
from repro.core.routing import warm_start_phi
from repro.core.scenario import (DemandShift, Event, ScenarioState,
                                 apply_event)
from repro.core.solver import SolverConfig, SolverState, project_box_simplex
from repro.core.utility import OnlineFitter

from .cec_router import GRAD_POLICIES, _call_utility

__all__ = ["FleetView", "RouterFleet"]


@dataclasses.dataclass(frozen=True)
class FleetView:
    """The published serving-plane snapshot (the double buffer's front).

    Immutable by construction and backed by buffers the control plane
    never donates — valid until the *next* publish, regardless of how
    many donated steps run meanwhile (DESIGN.md §15.2).
    """

    lam: jax.Array       # [K, W] committed admission splits
    weights: jax.Array   # [K, W, n_phys] replica dispatch weights
    verdicts: dict | None = None   # {monitor: Verdict([K] leaves)} — §18.2

    @property
    def n_tenants(self) -> int:
        return self.lam.shape[0]

    def admission_split(self) -> np.ndarray:
        """[K, W] P(version w | tenant k) for incoming requests."""
        lam = np.asarray(self.lam)
        return lam / lam.sum(-1, keepdims=True)

    def replica_weights(self) -> np.ndarray:
        """[K, W, n_phys] share of tenant k / version w traffic per node."""
        return np.asarray(self.weights)


@functools.lru_cache(maxsize=None)
def _publisher(_key, cost_name: str | None = None):
    """Jitted front-buffer builder: (Λ copy, replica weights) per tenant.

    ``state.lam + 0.0`` is a real XLA computation, so the published Λ is
    a fresh buffer — bit-identical in value (Λ ≥ δ > 0, no signed-zero
    corner) but never aliased to the donated working state.  The weights
    math is ``CECRouter.replica_weights`` vmapped over tenants.

    With ``cost_name`` set (a telemetry-enabled fleet) the publish also
    runs the fleet-vmapped paper-invariant monitors
    (``repro.obs.monitors.fleet_verdicts``, DESIGN.md §18.2) in the same
    jitted call — verdicts ride the front buffer as [K]-leaf pytrees, so
    reading them costs no extra dispatch.
    """

    def weights_of(graph, state):
        def one(g, lam, phi):
            t = propagate(g, phi, lam)
            shares = t[:, : g.n_phys] * g.deploy.astype(t.dtype)
            tot = shares.sum(-1, keepdims=True)
            return shares / jnp.where(tot > 0, tot, 1.0)

        return jax.vmap(one)(graph, state.lam, state.phi)

    if cost_name is None:
        def fn(graph, state):
            return state.lam + 0.0, weights_of(graph, state)

        return jax.jit(fn)

    from repro.obs import monitors as _monitors

    def fn(graph, state, lam_totals, tel):
        verdicts = _monitors.fleet_verdicts(graph, lam_totals, state, tel,
                                            cost=cost_name)
        return state.lam + 0.0, weights_of(graph, state), verdicts

    return jax.jit(fn)


class RouterFleet:
    """K live control planes, one vmapped+donated step per interval.

    Construct from K per-tenant graphs (dense ``CECGraph``; tenants of
    different physical size are padded to a common augmented layout by
    ``CECGraphBatch`` — solve-equivalent, DESIGN.md §15.1) and [K]
    provisioned demands.  All tenants share one ``SolverConfig``
    (default ``solver.serving_defaults()``, like ``CECRouter``).

    ``donate=False`` opts out of buffer donation (e.g. to keep old
    states around for debugging); the published view and all results
    are identical either way — donation is purely an allocation
    discipline (DESIGN.md §15.3).
    """

    def __init__(self, graphs: Sequence[CECGraph], lam_totals,
                 *, cost_name: str = "exp",
                 config: SolverConfig | None = None, donate: bool = True,
                 n_phys: int | None = None, depth_max: int | None = None,
                 grad_policy: str = "sampled",
                 util_family: str | None = None, telemetry: int = 0):
        if grad_policy not in GRAD_POLICIES:
            raise ValueError(f"grad_policy must be one of {GRAD_POLICIES}; "
                             f"got {grad_policy!r}")
        graphs = list(graphs)
        if any(isinstance(g, CECGraphSparse) for g in graphs):
            raise NotImplementedError(
                "RouterFleet stacks dense tenants; fleet-scale sparse "
                "tenants go through run_batch / run_batch_sharded")
        if n_phys is not None or depth_max is not None:
            # layout headroom: churn that grows a tenant (rewires can
            # deepen the graph) must fit the fixed stacked layout, so
            # operators provision margin up front — padding is
            # solve-equivalent (core.batch.pad_graph), so headroom costs
            # memory/FLOPs, never accuracy
            graphs = [pad_graph(g,
                                max(n_phys or 0, g.n_phys),
                                max(depth_max or 0, g.depth_max))
                      for g in graphs]
        self.batch = CECGraphBatch.from_graphs(graphs)
        lam_totals = np.asarray(lam_totals, np.float32).reshape(-1)
        if lam_totals.shape != (self.batch.n_instances,):
            raise ValueError(
                f"need one lam_total per tenant: {lam_totals.shape} "
                f"vs {self.batch.n_instances} tenants")
        self.lam_totals = lam_totals
        self.cost_name = cost_name
        self.config = config if config is not None \
            else _solver.serving_defaults()
        if telemetry and self.config.telemetry != telemetry:
            # like CECRouter: the fleet-level ring knob wins over a
            # shared preset config
            self.config = self.config.replace(telemetry=int(telemetry))
        self.donate = bool(donate)
        K, W = self.batch.n_instances, self.batch.n_sessions
        # stacked iterates == vmap of solver.init over tenants
        self.state = SolverState(
            lam=jnp.asarray(np.repeat(lam_totals[:, None] / W, W, axis=1),
                            jnp.float32),
            phi=self.batch.uniform_phi(),
            t=jnp.zeros((K,), jnp.int32))
        if self.config.telemetry > 0:
            from repro.obs import telemetry as _obs_tel

            cap = self.config.telemetry
            # [K]-stacked fresh rings: vmap broadcasts one init over lanes
            self.tel = jax.vmap(
                lambda _: _obs_tel.init_ring(cap, W))(jnp.zeros((K,)))
        else:
            self.tel = None
        self.history: list[dict] = []
        # live sampled→learned migration (DESIGN.md §16.4): one fitter per
        # tenant; the switch is all-or-nothing because the fleet step is one
        # jitted call with a single static grad_mode — a half-migrated fleet
        # would split the batch.
        self.grad_policy = grad_policy
        self.util_family = util_family
        self._migrated = False
        self.fitters: list[OnlineFitter] | None = None
        if grad_policy != "sampled":
            if self.util_family is None:
                self.util_family = "log"
            self.fitters = [OnlineFitter(self.util_family, W, seed=k)
                            for k in range(K)]
        self._publish()

    def _grad_mode_now(self) -> str:
        """Which gradient this interval runs — learned only once *every*
        tenant's fitter is ready (and, under ``"auto"``, none drifted).
        ``"learned"`` is the pinned variant: the switch is one-way."""
        if self.grad_policy == "learned" and self._migrated:
            return "learned"
        if self.fitters is None or not all(f.ready for f in self.fitters):
            return "sampled"
        if self.grad_policy == "auto" \
                and any(f.drifted() for f in self.fitters):
            return "sampled"
        return "learned"

    # -- fleet shape --------------------------------------------------------
    @property
    def n_tenants(self) -> int:
        return self.batch.n_instances

    @property
    def n_sessions(self) -> int:
        return self.batch.n_sessions

    @property
    def view(self) -> FleetView:
        """The current front buffer (serving plane reads go here)."""
        return self._view

    def _publish(self):
        graph = self.batch.stacked_graph()
        if self.tel is None:
            lam, weights = _publisher(_dispatch_key())(graph, self.state)
            self._view = FleetView(lam=lam, weights=weights)
        else:
            lam, weights, verdicts = _publisher(
                _dispatch_key(), self.cost_name)(
                    graph, self.state, jnp.asarray(self.lam_totals),
                    self.tel)
            self._view = FleetView(lam=lam, weights=weights,
                                   verdicts=verdicts)

    # -- measured utilities -------------------------------------------------
    def _measure(self, utility_fn, lams: np.ndarray) -> np.ndarray:
        """[K, m] utilities for a [K, m, W] admission stack.

        A sequence of K callables is evaluated tenant-wise through the
        ``CECRouter`` batched/scalar contract; a single callable must be
        fleet-batched — ``fn([K, m, W]) -> [K, m]`` — and a wrong output
        shape is an error, not a fallback (a per-tenant scalar function
        silently applied to every tenant would be a correctness bug).
        """
        K, m = lams.shape[0], lams.shape[1]
        if isinstance(utility_fn, (list, tuple)):
            if len(utility_fn) != K:
                raise ValueError(f"need {K} per-tenant callbacks, "
                                 f"got {len(utility_fn)}")
            return np.stack([_call_utility(fn, lams[k])
                             for k, fn in enumerate(utility_fn)])
        out = np.asarray(utility_fn(lams), np.float32)
        if out.shape != (K, m):
            raise TypeError(
                f"fleet-batched utility callback must map [K, m, W] -> "
                f"[K, m]; got {out.shape} for K={K}, m={m} (pass a "
                f"sequence of K callables for per-tenant callbacks)")
        return out

    # -- the control interval -----------------------------------------------
    def control_step(self, utility_fn) -> dict:
        """One OMAD outer iteration for every tenant, fused on device.

        The 2W perturbed admissions per tenant are generated from the
        *published* Λ (bit-identical to the working Λ, but donation-safe
        to read), measured through one microbatched callback, and the
        stacked state advances through the donated
        ``core.batch.fused_step_batch`` — after which the old state
        buffers are dead and a fresh :class:`FleetView` is published.
        Returns a record of [K]-shaped arrays (per-tenant cost, measured
        task utility at the committed Λ, net utility), appended to
        ``history`` — the ``CECRouter.control_step`` record, vectorized.

        Under a non-sampled ``grad_policy`` the sweep's measurements feed
        the per-tenant fitters, and once **every** fitter is ready the
        fleet migrates live to learned gradients — one committed
        measurement per tenant per interval, stacked [K, W, P] surrogate
        params threaded through ``fused_step_batch`` as a data leaf
        (refits never retrace; DESIGN.md §16.4).
        """
        from repro.obs import trace as _obs_trace

        mode = self._grad_mode_now()
        K, W = self.n_tenants, self.n_sessions
        with _obs_trace.span("fleet.interval", cat="interval",
                             args={"t": len(self.history), "mode": mode,
                                   "tenants": K}):
            t0 = time.perf_counter()
            if mode == "learned":
                self._migrated = True
                params = jnp.stack([f.params for f in self.fitters])
                step = fused_step_batch(
                    self.config.replace(grad_mode="learned"),
                    cost=self.cost_name, donate=self.donate,
                    util_family=self.util_family)
                zeros = jnp.zeros((K, 2 * W), jnp.float32)
                if self.tel is None:
                    self.state, info = step(
                        self.batch.stacked_graph(),
                        jnp.asarray(self.lam_totals), self.state, zeros,
                        params)
                else:
                    self.state, info, self.tel = step(
                        self.batch.stacked_graph(),
                        jnp.asarray(self.lam_totals), self.state, zeros,
                        self.tel, params)
                oracle_calls = 1
            else:
                delta = self.config.delta
                pert = jax.vmap(lambda l: _solver.perturbed_allocations(
                    l, delta))(self._view.lam)
                pert = np.asarray(pert)
                task_u = self._measure(utility_fn, pert)
                step = fused_step_batch(self.config, cost=self.cost_name,
                                        donate=self.donate)
                if self.tel is None:
                    self.state, info = step(
                        self.batch.stacked_graph(),
                        jnp.asarray(self.lam_totals),
                        self.state, jnp.asarray(task_u))
                else:
                    self.state, info, self.tel = step(
                        self.batch.stacked_graph(),
                        jnp.asarray(self.lam_totals),
                        self.state, jnp.asarray(task_u), self.tel)
                if self.fitters is not None:
                    for k, f in enumerate(self.fitters):
                        f.add(pert[k], task_u[k])
                oracle_calls = 2 * W + 1
            solver_us = (time.perf_counter() - t0) * 1e6
            # measure at the committed Λ (the step's fresh output — value-
            # identical to the view published below, which happens after
            # the ring annotation so the verdicts see this interval's U)
            u_task = self._measure(
                utility_fn, np.asarray(self.state.lam)[:, None, :])[:, 0]
            if self.fitters is not None:
                lam = np.asarray(self._view.lam)
                for k, f in enumerate(self.fitters):
                    f.observe_live(lam[k], float(u_task[k]))
                    f.maybe_fit()
            cost = np.asarray(info.cost, np.float32)
            if self.tel is not None:
                # per-lane net utility; one fused call serves all K
                # lanes, so they share the measured wall-clock
                from repro.obs import telemetry as _obs_tel

                self.tel = _obs_tel.annotate_donated(
                    self.tel, utility=jnp.asarray(u_task - cost),
                    wall_clock_us=jnp.full((K,), solver_us, jnp.float32))
            self._publish()
            rec = {"lam": np.asarray(self._view.lam).copy(),
                   "cost": cost,
                   "utility": u_task - cost,
                   "grad": np.asarray(info.grad).copy(),
                   "mode": mode,
                   "oracle_calls": oracle_calls}
            self.history.append(rec)
        return rec

    # -- churn --------------------------------------------------------------
    def set_demand(self, lam_totals):
        """Re-scale every tenant onto new provisioned demands [K].

        ``CECRouter.on_demand_change`` vectorized: each tenant's Λ
        scales by its demand ratio and re-projects exactly onto its box
        (per-tenant totals via vmapped ``project_box_simplex``).  Demand
        is a traced leaf of the fleet step — no retrace (DESIGN.md
        §15.4)."""
        new = np.asarray(lam_totals, np.float32).reshape(-1)
        if new.shape != (self.n_tenants,):
            raise ValueError(f"need [{self.n_tenants}] demands, "
                             f"got {new.shape}")
        scale = jnp.asarray(new / self.lam_totals)
        lam = self.state.lam * scale[:, None]
        lam = jax.vmap(project_box_simplex, in_axes=(0, 0, None))(
            lam, jnp.asarray(new), self.config.delta)
        self.lam_totals = new
        self.state = self.state._replace(lam=lam)
        self._publish()

    def update_tenant_graph(self, tenant: int,
                            new_graph: CECGraph, explore: float = 0.1):
        """Re-target one tenant onto a changed topology (fail/join/rewire).

        The new graph is padded into the fleet's shared augmented layout
        (``core.batch.pad_graph`` — solve-equivalent) and spliced into
        the stacked leaves; the tenant's φ row is warm-started with an
        exploration mix exactly like ``CECRouter.on_topology_change``.
        Same-shape churn by construction: the fleet step never retraces.
        The fleet's layout is fixed at construction — a tenant outgrowing
        it (more physical nodes, deeper graph) raises rather than
        silently retracing every tenant."""
        if isinstance(new_graph, CECGraphSparse):
            raise NotImplementedError("RouterFleet tenants are dense")
        if new_graph.n_sessions != self.n_sessions:
            raise ValueError("tenant session count W is fixed")
        if (new_graph.n_phys > self.batch.n_phys
                or new_graph.depth_max > self.batch.depth_max):
            raise ValueError(
                f"tenant graph (n_phys={new_graph.n_phys}, depth_max="
                f"{new_graph.depth_max}) exceeds the fleet layout "
                f"(n_phys={self.batch.n_phys}, depth_max="
                f"{self.batch.depth_max}); rebuild the fleet")
        g = pad_graph(new_graph, self.batch.n_phys, self.batch.depth_max)
        self.batch = dataclasses.replace(
            self.batch,
            out_mask=self.batch.out_mask.at[tenant].set(g.out_mask),
            edge_mask=self.batch.edge_mask.at[tenant].set(g.edge_mask),
            capacity=self.batch.capacity.at[tenant].set(g.capacity),
            deploy=self.batch.deploy.at[tenant].set(g.deploy),
            sinks=self.batch.sinks.at[tenant].set(g.sinks))
        phi_row = warm_start_phi(self.state.phi[tenant], g.out_mask, explore)
        self.state = self.state._replace(
            phi=self.state.phi.at[tenant].set(phi_row))
        self._publish()

    def apply_scenario_event(self, tenant: int, state: ScenarioState,
                             event: Event, explore: float = 0.1
                             ) -> ScenarioState:
        """Consume one scenario-engine event against one tenant.

        The per-tenant mirror of ``CECRouter.apply_scenario_event``:
        ``state`` is that tenant's physical description, the event is
        applied there, and the stacked iterates are re-targeted (demand
        events rescale the tenant's Λ row, graph events splice +
        warm-start; bank swaps change only the measured environment).
        Returns the post-event state — thread it into the next call.
        """
        new_state = apply_event(state, event)
        if isinstance(event, DemandShift):
            totals = self.lam_totals.copy()
            totals[tenant] = new_state.lam_total
            self.set_demand(totals)
        elif event.changes_graph:
            self.update_tenant_graph(tenant, new_state.graph(),
                                     explore=explore)
        self.history.append({"event": event.kind, "tenant": tenant,
                             "at": len(self.history)})
        return new_state
