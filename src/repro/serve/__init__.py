from .cec_router import CECRouter
from .engine import InferenceEngine, Request

__all__ = ["CECRouter", "InferenceEngine", "Request"]
