from .cec_router import CECRouter
from .engine import InferenceEngine, Request
from .fleet import FleetView, RouterFleet
from .sim import ServingSim, SimReport
from .traffic import (TrafficTrace, diurnal_trace, flash_crowd_trace,
                      named_traces, poisson_trace, scenario_base_demand)

__all__ = ["CECRouter", "InferenceEngine", "Request", "ServingSim",
           "SimReport", "RouterFleet", "FleetView", "TrafficTrace",
           "poisson_trace", "diurnal_trace", "flash_crowd_trace",
           "named_traces", "scenario_base_demand"]
