from .cec_router import CECRouter
from .engine import InferenceEngine, Request
from .sim import ServingSim, SimReport

__all__ = ["CECRouter", "InferenceEngine", "Request", "ServingSim",
           "SimReport"]
