"""CEC router: the paper's control plane driving live serving decisions.

The router owns the JOWR state (Λ, φ) for a fleet of edge devices, each
hosting one model version.  Every control interval it:

 1. observes the realized network utility (measured quality-weighted
    throughput minus flow-model network cost — a black box to the router,
    exactly the paper's bandit feedback);
 2. advances the OMAD single-loop (Alg. 3) one outer iteration — gradient
    sampling over the perturbed allocations, one mirror-descent routing
    step per observation;
 3. exposes the new admission split Λ/λ (which version serves what share
    of traffic) and per-replica dispatch weights t_i(w)/λ_w (how much of
    version w's traffic each deploying device processes).

Node churn (device joins/leaves) rebuilds the graph and *warm-starts* φ
with an exploration mix (``core.routing.warm_start_phi``) — the Fig. 11
online-adaptation behaviour.  The router also consumes the scenario
engine's event stream directly (``apply_scenario_event``, DESIGN.md §10):
the same declarative events that drive offline scenario sweeps drive the
live control plane, so what is benchmarked is what serves.

The router's observe path runs through ``core.flow`` / ``core.routing``
and therefore inherits the size-based kernel dispatch (core/dispatch.py)
for free: a fleet whose augmented graph clears the threshold serves its
flow-propagation and mirror-descent steps from the Pallas kernels on TPU
backends (off-TPU the kernels engage only under an explicit override, in
interpret mode) with no change here.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import CECGraph, get_cost, propagate, total_cost
from repro.core.allocation import _observe, _project_box_simplex
from repro.core.routing import solve_routing, warm_start_phi
from repro.core.scenario import (DemandShift, Event, ScenarioState,
                                 apply_event)


@dataclasses.dataclass
class CECRouter:
    graph: CECGraph
    lam_total: float
    delta: float = 0.5
    eta_outer: float = 0.05
    eta_inner: float = 3.0
    cost_name: str = "exp"

    def __post_init__(self):
        self.cost = get_cost(self.cost_name)
        W = self.graph.n_sessions
        self.lam = jnp.full((W,), self.lam_total / W)
        self.phi = self.graph.uniform_phi()
        self.history: list[dict] = []

    # -- the bandit observation the paper assumes ---------------------------
    def _utility(self, measured_task_utility: float, lam) -> float:
        return measured_task_utility - float(
            total_cost(self.graph, self.cost, self.phi, lam))

    def control_step(self, utility_fn) -> dict:
        """One OMAD outer iteration.  ``utility_fn(lam) -> float`` returns
        the *measured* task utility for an admitted allocation (the engine
        serves the perturbed split and reports quality-weighted goodput)."""
        W = self.graph.n_sessions
        g = np.zeros(W, np.float32)
        for w in range(W):
            ew = jnp.zeros(W).at[w].set(1.0)
            for sign in (+1.0, -1.0):
                lam_p = self.lam + sign * self.delta * ew
                self.phi, _ = solve_routing(self.graph, self.cost, lam_p,
                                            self.phi, self.eta_inner, 1)
                u = utility_fn(np.asarray(lam_p)) - float(
                    total_cost(self.graph, self.cost, self.phi, lam_p))
                g[w] += sign * u / (2 * self.delta)
        z = self.eta_outer * (g - g.max())
        wts = np.asarray(self.lam) * np.exp(z)
        lam = jnp.asarray(self.lam_total * wts / wts.sum())
        self.lam = _project_box_simplex(lam, self.lam_total, self.delta)
        rec = {"lam": np.asarray(self.lam).copy(),
               "cost": float(total_cost(self.graph, self.cost, self.phi,
                                        self.lam))}
        self.history.append(rec)
        return rec

    # -- dispatch interfaces used by the engine ------------------------------
    def admission_split(self) -> np.ndarray:
        """P(version w) for an incoming request."""
        lam = np.asarray(self.lam)
        return lam / lam.sum()

    def replica_weights(self) -> np.ndarray:
        """[W, n_phys] share of version-w traffic each deployed replica
        processes = t_i(w)/λ_w at the nodes deploying w."""
        t = np.asarray(propagate(self.graph, self.phi, self.lam))
        dep = np.asarray(self.graph.deploy)
        shares = t[:, : self.graph.n_phys] * dep
        tot = shares.sum(-1, keepdims=True)
        return shares / np.where(tot > 0, tot, 1.0)

    # -- fault tolerance: node churn -----------------------------------------
    def on_topology_change(self, new_graph: CECGraph, explore: float = 0.1):
        """Re-target the running iterates onto a new graph (node fail/join).

        φ restarts from an exploration mix so edges that multiplicative
        updates had zeroed can be rediscovered (DESIGN.md §5, §10)."""
        self.graph = new_graph
        if self.phi.shape == new_graph.out_mask.shape:
            self.phi = warm_start_phi(self.phi, new_graph.out_mask, explore)
        else:
            self.phi = new_graph.uniform_phi()

    def on_demand_change(self, lam_total: float):
        """Re-scale the admission split onto a new total demand λ."""
        self.lam = self.lam * (lam_total / self.lam_total)
        self.lam_total = float(lam_total)
        self.lam = _project_box_simplex(self.lam, self.lam_total, self.delta)

    def apply_scenario_event(self, state: ScenarioState,
                             event: Event, explore: float = 0.1
                             ) -> ScenarioState:
        """Consume one scenario-engine event against the live iterates.

        ``state`` is the fleet's physical description (the same
        ``core.scenario.ScenarioState`` the offline sweeps evolve); the
        event is applied there, the augmented graph rebuilt, and the
        running (Λ, φ) warm-started exactly as ``run_scenario`` does.
        Returns the post-event state — thread it into the next call.
        Bank swaps change only the *measured* utility (the environment),
        so the router's iterates carry over untouched."""
        new_state = apply_event(state, event)
        if isinstance(event, DemandShift):
            self.on_demand_change(new_state.lam_total)
        elif event.changes_graph:
            self.on_topology_change(new_state.graph(), explore=explore)
        self.history.append({"event": event.kind, "at": len(self.history)})
        return new_state
