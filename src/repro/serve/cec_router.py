"""CEC router: the paper's control plane driving live serving decisions.

The router is a thin stateful holder over the solver core (DESIGN.md
§13): a :class:`~repro.core.problem.Problem` (graph + cost + demand, no
bank — utilities are *measured*), a :class:`~repro.core.solver.
SolverConfig` (``solver.serving_defaults()`` unless overridden), and a
device-resident :class:`~repro.core.solver.SolverState` (Λ, φ, t).
Every control interval is one jitted fused call —
``core.solver.fused_step``, the exact ``step`` the offline solvers scan
— covering all 2W perturbed observations, the mirror-ascent/projection
update, and the committed observation, with no per-session Python loop
and no solver math of its own.  Each interval it:

 1. admits the 2W perturbed allocations Λ ± δ·e_w and collects their
    *measured* task utilities through the utility callback (batched in one
    call where the utility source allows it — see :func:`_call_utility`);
 2. advances OMAD (Alg. 3) one outer iteration on device, the network-cost
    half of every observation priced at the routing iterate the oracle
    reached for that admission;
 3. exposes the new admission split Λ/λ (which version serves what share
    of traffic) and per-replica dispatch weights t_i(w)/λ_w (how much of
    version w's traffic each deploying device processes).

Node churn (device joins/leaves) rebuilds the graph and *warm-starts* φ
with an exploration mix (``core.routing.warm_start_phi``) — the Fig. 11
online-adaptation behaviour.  The router also consumes the scenario
engine's event stream directly (``apply_scenario_event``, DESIGN.md §10):
the same declarative events that drive offline scenario sweeps drive the
live control plane, and because the scenario engine keeps the node-index
space stable (dead node == isolated index), same-shape churn never
retraces the fused step.  Fleet-scale graphs flip to the edge-list
representation through the same ``Problem.canonical`` policy every other
entry point uses, and demand shifts only swap the traced
``Problem.lam_total`` leaf — never a retrace.

The fused step runs through ``core.flow`` / ``core.routing`` and therefore
inherits the size-based kernel dispatch (core/dispatch.py): a fleet whose
augmented graph clears the threshold serves its flow-propagation and
mirror-descent steps from the Pallas kernels on TPU backends (off-TPU the
kernels engage only under an explicit override, in interpret mode), the
dispatch state being part of the jit-cache key (DESIGN.md §11).

The router is the *single-tenant* control plane.  K tenants multiplexed
on one device are ``serve.fleet.RouterFleet`` (DESIGN.md §15) — the same
``step`` vmapped over stacked ``Problem`` pytrees with double-buffered
state and donated buffers; every semantic here (perturbation order,
``_call_utility`` contract, demand rescale, event consumption) is the
per-tenant slice of the fleet's, and ``tests/test_fleet.py`` holds the
two to ≤1e-5 parity.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import CECGraph, CECGraphSparse, SparsePhi, propagate
from repro.core import solver as _solver
from repro.core.problem import Problem, resolve_cost
from repro.core.routing import warm_start_phi
from repro.core.scenario import (DemandShift, Event, ScenarioState,
                                 apply_event)
from repro.core.solver import SolverConfig, SolverState, project_box_simplex


def _call_utility(utility_fn, lams: np.ndarray) -> np.ndarray:
    """Evaluate the measured-utility callback over a [K, W] admission stack.

    Contract (DESIGN.md §11): ``utility_fn(lams: [K, W]) -> [K]`` measured
    task utilities.  A legacy scalar callable ``fn(lam: [W]) -> float`` is
    detected (wrong output shape, or the batched call raising a shape-type
    error) and evaluated row by row — correct either way, just 2W calls
    instead of 1.  Other exception types propagate: a conforming batched
    callback failing for a real reason must not be silently retried.
    """
    lams = np.asarray(lams)
    try:
        out = np.asarray(utility_fn(lams), np.float32).reshape(-1)
        if out.shape == (lams.shape[0],):
            return out
    except (TypeError, ValueError, IndexError):
        pass
    return np.asarray([float(utility_fn(row)) for row in lams], np.float32)


@dataclasses.dataclass
class CECRouter:
    """Live control plane = ``Problem`` + ``SolverConfig`` + ``SolverState``.

    Construct with a graph and either a ``config`` (the first-class API)
    or the legacy keyword knobs, which default to
    ``solver.serving_defaults()`` — single-loop OMAD with the hot
    η_inner=3.0 oracle (see that preset's docstring for why serving
    diverges from ``paper_defaults()``).
    """

    graph: CECGraph | CECGraphSparse
    lam_total: float
    delta: float = 0.5
    eta_outer: float = 0.05
    eta_inner: float = 3.0
    inner_iters: int = 1
    cost_name: str = "exp"
    config: SolverConfig | None = None

    def __post_init__(self):
        if self.config is None:
            # the legacy knobs, expressed as a config: K=1 is OMAD
            method = "single" if self.inner_iters == 1 else "nested"
            self.config = _solver.serving_defaults().replace(
                method=method, delta=float(self.delta),
                eta_outer=float(self.eta_outer),
                eta_inner=float(self.eta_inner),
                inner_iters=int(self.inner_iters))
        else:
            # keep the legacy attribute reads truthful
            self.delta = self.config.delta
            self.eta_outer = self.config.eta_outer
            self.eta_inner = self.config.eta_inner
            self.inner_iters = self.config.oracle_iters
        # one Problem: representation policy + demand as a traced leaf
        # (Problem.canonical is the same conversion every entry point uses;
        # strong-float32 demand so the fused step never retraces on it)
        self.problem = Problem(
            graph=self.graph, bank=None,
            lam_total=jnp.float32(self.lam_total),
            cost=resolve_cost(self.cost_name)).canonical().validate()
        self.graph = self.problem.graph
        self.state: SolverState = _solver.init(self.problem, self.config)
        self.history: list[dict] = []

    # -- the solver state, exposed under its historical names ---------------
    @property
    def lam(self):
        """[W] current admission allocation Λ (device-resident)."""
        return self.state.lam

    @property
    def phi(self):
        """Current routing iterate (dense tensor or ``SparsePhi``)."""
        return self.state.phi

    def control_step(self, utility_fn) -> dict:
        """One OMAD outer iteration, fused on device.

        ``utility_fn`` reports the *measured* task utility for admitted
        allocations (the engine serves the split and reports
        quality-weighted goodput): called once with the [2W, W] stack of
        perturbed admissions and once with the committed allocation (see
        :func:`_call_utility` for the batched/scalar contract).  Everything
        else — oracle invocations, gradient estimate, mirror ascent, exact
        projection, committed observation — is a single jitted
        ``solver.fused_step`` call; the ``SolverState`` never leaves the
        device.
        """
        pert = _solver.perturbed_allocations(self.state.lam,
                                             self.config.delta)
        task_u = jnp.asarray(_call_utility(utility_fn, np.asarray(pert)))
        self.state, info = _solver.fused_step(self.config)(
            self.problem, self.state, task_u)
        u_task = float(
            _call_utility(utility_fn, np.asarray(self.state.lam)[None])[0])
        rec = {"lam": np.asarray(self.state.lam).copy(),
               "cost": float(info.cost),
               "utility": u_task - float(info.cost),
               "grad": np.asarray(info.grad).copy()}
        self.history.append(rec)
        return rec

    # -- dispatch interfaces used by the engine ------------------------------
    def admission_split(self) -> np.ndarray:
        """P(version w) for an incoming request."""
        lam = np.asarray(self.state.lam)
        return lam / lam.sum()

    def replica_weights(self) -> np.ndarray:
        """[W, n_phys] share of version-w traffic each deployed replica
        processes = t_i(w)/λ_w at the nodes deploying w."""
        t = np.asarray(propagate(self.graph, self.state.phi, self.state.lam))
        dep = np.asarray(self.graph.deploy)
        shares = t[:, : self.graph.n_phys] * dep
        tot = shares.sum(-1, keepdims=True)
        return shares / np.where(tot > 0, tot, 1.0)

    # -- fault tolerance: node churn -----------------------------------------
    def on_topology_change(self, new_graph: CECGraph | CECGraphSparse,
                           explore: float = 0.1):
        """Re-target the running iterates onto a new graph (node fail/join).

        φ restarts from an exploration mix so edges that multiplicative
        updates had zeroed can be rediscovered (DESIGN.md §5, §10).  The
        new graph goes through the same representation policy as the
        constructor (``Problem.canonical``).  On the sparse path the
        running ``SparsePhi`` is first re-expressed on the new slot
        layout by **edge identity** (``core.sparse.remap_phi`` — churn
        can repack CSR slots even at unchanged widths, so positional
        reuse would scramble edges), then warm-started part-wise through
        the same ``warm_start_phi`` row math as the dense tensor."""
        old_graph, phi = self.graph, self.state.phi
        self.problem = dataclasses.replace(
            self.problem, graph=new_graph).canonical().validate()
        new_graph = self.graph = self.problem.graph
        if isinstance(new_graph, CECGraphSparse):
            if (isinstance(phi, SparsePhi)
                    and isinstance(old_graph, CECGraphSparse)
                    and old_graph.n_bar == new_graph.n_bar):
                from repro.core.sparse import remap_phi

                phi = remap_phi(old_graph, new_graph, phi)
                phi = SparsePhi(
                    rows=warm_start_phi(phi.rows, new_graph.out_mask,
                                        explore),
                    src=warm_start_phi(phi.src, new_graph.src_out_mask,
                                       explore))
            else:
                phi = new_graph.uniform_phi()
        elif (not isinstance(phi, SparsePhi)
                and phi.shape == new_graph.out_mask.shape):
            phi = warm_start_phi(phi, new_graph.out_mask, explore)
        else:
            phi = new_graph.uniform_phi()
        self.state = self.state._replace(phi=phi)

    def on_demand_change(self, lam_total: float):
        """Re-scale the admission split onto a new total demand λ.

        Only the ``Problem.lam_total`` leaf changes — the fused step's
        compiled executable is reused as-is.
        """
        lam = self.state.lam * (lam_total / self.lam_total)
        self.lam_total = float(lam_total)
        self.problem = self.problem.with_demand(jnp.float32(lam_total))
        self.state = self.state._replace(
            lam=project_box_simplex(lam, self.lam_total, self.config.delta))

    def apply_scenario_event(self, state: ScenarioState,
                             event: Event, explore: float = 0.1
                             ) -> ScenarioState:
        """Consume one scenario-engine event against the live iterates.

        ``state`` is the fleet's physical description (the same
        ``core.scenario.ScenarioState`` the offline sweeps evolve); the
        event is applied there, the augmented graph rebuilt, and the
        running ``SolverState`` warm-started exactly as ``run_scenario``
        does.  Returns the post-event state — thread it into the next
        call.  Bank swaps change only the *measured* utility (the
        environment), so the router's iterates carry over untouched."""
        new_state = apply_event(state, event)
        if isinstance(event, DemandShift):
            self.on_demand_change(new_state.lam_total)
        elif event.changes_graph:
            self.on_topology_change(new_state.graph(), explore=explore)
        self.history.append({"event": event.kind, "at": len(self.history)})
        return new_state
