"""CEC router: the paper's control plane driving live serving decisions.

The router is a thin stateful holder over the solver core (DESIGN.md
§13): a :class:`~repro.core.problem.Problem` (graph + cost + demand, no
bank — utilities are *measured*), a :class:`~repro.core.solver.
SolverConfig` (``solver.serving_defaults()`` unless overridden), and a
device-resident :class:`~repro.core.solver.SolverState` (Λ, φ, t).
Every control interval is one jitted fused call —
``core.solver.fused_step``, the exact ``step`` the offline solvers scan
— covering all 2W perturbed observations, the mirror-ascent/projection
update, and the committed observation, with no per-session Python loop
and no solver math of its own.  Each interval it:

 1. admits the 2W perturbed allocations Λ ± δ·e_w and collects their
    *measured* task utilities through the utility callback (batched in one
    call where the utility source allows it — see :func:`_call_utility`);
 2. advances OMAD (Alg. 3) one outer iteration on device, the network-cost
    half of every observation priced at the routing iterate the oracle
    reached for that admission;
 3. exposes the new admission split Λ/λ (which version serves what share
    of traffic) and per-replica dispatch weights t_i(w)/λ_w (how much of
    version w's traffic each deploying device processes).

Node churn (device joins/leaves) rebuilds the graph and *warm-starts* φ
with an exploration mix (``core.routing.warm_start_phi``) — the Fig. 11
online-adaptation behaviour.  The router also consumes the scenario
engine's event stream directly (``apply_scenario_event``, DESIGN.md §10):
the same declarative events that drive offline scenario sweeps drive the
live control plane, and because the scenario engine keeps the node-index
space stable (dead node == isolated index), same-shape churn never
retraces the fused step.  Fleet-scale graphs flip to the edge-list
representation through the same ``Problem.canonical`` policy every other
entry point uses, and demand shifts only swap the traced
``Problem.lam_total`` leaf — never a retrace.

The fused step runs through ``core.flow`` / ``core.routing`` and therefore
inherits the size-based kernel dispatch (core/dispatch.py): a fleet whose
augmented graph clears the threshold serves its flow-propagation and
mirror-descent steps from the Pallas kernels on TPU backends (off-TPU the
kernels engage only under an explicit override, in interpret mode), the
dispatch state being part of the jit-cache key (DESIGN.md §11).

The router is the *single-tenant* control plane.  K tenants multiplexed
on one device are ``serve.fleet.RouterFleet`` (DESIGN.md §15) — the same
``step`` vmapped over stacked ``Problem`` pytrees with double-buffered
state and donated buffers; every semantic here (perturbation order,
``_call_utility`` contract, demand rescale, event consumption) is the
per-tenant slice of the fleet's, and ``tests/test_fleet.py`` holds the
two to ≤1e-5 parity.
"""
from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.core import CECGraph, CECGraphSparse, SparsePhi, propagate
from repro.core import solver as _solver
from repro.core.problem import Problem, resolve_cost
from repro.core.routing import warm_start_phi
from repro.core.scenario import (DemandShift, Event, ScenarioState,
                                 apply_event)
from repro.core.solver import SolverConfig, SolverState, project_box_simplex
from repro.core.utility import OnlineFitter

GRAD_POLICIES = ("sampled", "learned", "auto")


def _call_utility(utility_fn, lams: np.ndarray) -> np.ndarray:
    """Evaluate the measured-utility callback over a [K, W] admission stack.

    Contract (DESIGN.md §11): ``utility_fn(lams: [K, W]) -> [K]`` measured
    task utilities.  A legacy scalar callable ``fn(lam: [W]) -> float`` is
    detected (wrong output shape, or the batched call raising a shape-type
    error) and evaluated row by row — correct either way, just 2W calls
    instead of 1.  Other exception types propagate: a conforming batched
    callback failing for a real reason must not be silently retried.
    """
    lams = np.asarray(lams)
    try:
        out = np.asarray(utility_fn(lams), np.float32).reshape(-1)
        if out.shape == (lams.shape[0],):
            return out
    except (TypeError, ValueError, IndexError):
        pass
    return np.asarray([float(utility_fn(row)) for row in lams], np.float32)


@dataclasses.dataclass
class CECRouter:
    """Live control plane = ``Problem`` + ``SolverConfig`` + ``SolverState``.

    Construct with a graph and either a ``config`` (the first-class API)
    or the legacy keyword knobs, which default to
    ``solver.serving_defaults()`` — single-loop OMAD with the hot
    η_inner=3.0 oracle (see that preset's docstring for why serving
    diverges from ``paper_defaults()``).

    ``grad_policy`` picks how the outer gradient is obtained
    (DESIGN.md §16.4):

    * ``"sampled"`` (default) — every interval admits the 2W perturbed
      allocations and two-point-estimates the gradient from measured
      utilities.  Exactly the pre-§16 router.
    * ``"learned"`` — the measured (Λ, û) pairs feed an
      :class:`~repro.core.utility.OnlineFitter`; once the held-out error
      clears its threshold the router *migrates live* to
      ``grad_mode="learned"`` — one committed measurement per interval
      and an analytic gradient of the fitted surrogate through the
      implicit routing layer.  Pinned: once earned it stays learned
      (drift is tracked but does not demote).
    * ``"auto"`` — like ``"learned"``, but :meth:`OnlineFitter.drifted`
      demotes the router back to sampling until a refit re-clears the
      threshold — the safe default for non-stationary environments
      (bank swaps, goodput shifts).

    The per-interval record gains ``mode`` (which gradient ran) and
    ``oracle_calls`` (measured admissions this interval: 2W+1 sampled,
    1 learned — the quantity ``benchmarks/bench_learned.py`` tracks).
    """

    graph: CECGraph | CECGraphSparse
    lam_total: float
    delta: float = 0.5
    eta_outer: float = 0.05
    eta_inner: float = 3.0
    inner_iters: int = 1
    cost_name: str = "exp"
    config: SolverConfig | None = None
    grad_policy: str = "sampled"
    util_family: str | None = None
    telemetry: int = 0

    def __post_init__(self):
        if self.grad_policy not in GRAD_POLICIES:
            raise ValueError(f"grad_policy must be one of {GRAD_POLICIES}; "
                             f"got {self.grad_policy!r}")
        if self.config is None:
            # the legacy knobs, expressed as a config: K=1 is OMAD
            method = "single" if self.inner_iters == 1 else "nested"
            self.config = _solver.serving_defaults().replace(
                method=method, delta=float(self.delta),
                eta_outer=float(self.eta_outer),
                eta_inner=float(self.eta_inner),
                inner_iters=int(self.inner_iters),
                telemetry=int(self.telemetry))
        else:
            # keep the legacy attribute reads truthful
            if self.telemetry and self.config.telemetry != self.telemetry:
                # the router-level knob wins: sizing the ring at the
                # router is the ergonomic path (the config is often a
                # shared preset)
                self.config = self.config.replace(
                    telemetry=int(self.telemetry))
            self.delta = self.config.delta
            self.eta_outer = self.config.eta_outer
            self.eta_inner = self.config.eta_inner
            self.inner_iters = self.config.oracle_iters
        self.telemetry = self.config.telemetry
        # one Problem: representation policy + demand as a traced leaf
        # (Problem.canonical is the same conversion every entry point uses;
        # strong-float32 demand so the fused step never retraces on it)
        self.problem = Problem(
            graph=self.graph, bank=None,
            lam_total=jnp.float32(self.lam_total),
            cost=resolve_cost(self.cost_name)).canonical().validate()
        self.graph = self.problem.graph
        self.state: SolverState = _solver.init(self.problem, self.config)
        if self.telemetry > 0:
            from repro.obs import telemetry as _obs_tel

            self.tel = _obs_tel.init_ring(self.telemetry,
                                          self.graph.n_sessions)
        else:
            self.tel = None
        self.history: list[dict] = []
        self.fitter: OnlineFitter | None = None
        self._migrated = False
        if self.grad_policy != "sampled":
            if self.util_family is None:
                self.util_family = "log"
            self.fitter = OnlineFitter(self.util_family,
                                       self.graph.n_sessions)

    def _grad_mode_now(self) -> str:
        """Which gradient this interval runs (the migration decision)."""
        if self.grad_policy == "learned" and self._migrated:
            return "learned"      # pinned: the switch is one-way
        if self.fitter is None or not self.fitter.ready:
            return "sampled"
        if self.grad_policy == "auto" and self.fitter.drifted():
            return "sampled"
        return "learned"

    # -- the solver state, exposed under its historical names ---------------
    @property
    def lam(self):
        """[W] current admission allocation Λ (device-resident)."""
        return self.state.lam

    @property
    def phi(self):
        """Current routing iterate (dense tensor or ``SparsePhi``)."""
        return self.state.phi

    def control_step(self, utility_fn) -> dict:
        """One OMAD outer iteration, fused on device.

        ``utility_fn`` reports the *measured* task utility for admitted
        allocations (the engine serves the split and reports
        quality-weighted goodput).  In sampled mode it is called once
        with the [2W, W] stack of perturbed admissions and once with the
        committed allocation (see :func:`_call_utility` for the
        batched/scalar contract); in learned mode (``grad_policy`` with
        a :attr:`fitter` that is :attr:`~repro.core.utility.OnlineFitter.
        ready`) only the committed call happens — the gradient is
        analytic through the fitted surrogate and the implicit routing
        layer (DESIGN.md §16.4).  Everything else — oracle invocations,
        gradient, mirror ascent, exact projection, committed observation
        — is a single jitted ``solver.fused_step`` call; the
        ``SolverState`` never leaves the device.
        """
        from repro.obs import trace as _obs_trace

        mode = self._grad_mode_now()
        W = self.graph.n_sessions
        with _obs_trace.span("router.interval", cat="interval",
                             args={"t": len(self.history), "mode": mode}):
            t0 = time.perf_counter()
            if mode == "learned":
                self._migrated = True
                prob = self.problem.with_utilities(self.util_family,
                                                   self.fitter.params)
                cfg = self.config.replace(grad_mode="learned")
                fused = _solver.fused_step(cfg)
                if self.tel is None:
                    self.state, info = fused(
                        prob, self.state, jnp.zeros((2 * W,), jnp.float32))
                else:
                    self.state, info, self.tel = fused(
                        prob, self.state, jnp.zeros((2 * W,), jnp.float32),
                        self.tel)
                oracle_calls = 1
            else:
                pert = _solver.perturbed_allocations(self.state.lam,
                                                     self.config.delta)
                task_u = jnp.asarray(
                    _call_utility(utility_fn, np.asarray(pert)))
                fused = _solver.fused_step(self.config)
                if self.tel is None:
                    self.state, info = fused(self.problem, self.state,
                                             task_u)
                else:
                    self.state, info, self.tel = fused(
                        self.problem, self.state, task_u, self.tel)
                if self.fitter is not None:
                    self.fitter.add(np.asarray(pert), np.asarray(task_u))
                oracle_calls = 2 * W + 1
            solver_us = (time.perf_counter() - t0) * 1e6
            u_task = float(
                _call_utility(utility_fn,
                              np.asarray(self.state.lam)[None])[0])
            if self.fitter is not None:
                self.fitter.observe_live(np.asarray(self.state.lam), u_task)
                self.fitter.maybe_fit()
            rec = {"lam": np.asarray(self.state.lam).copy(),
                   "cost": float(info.cost),
                   "utility": u_task - float(info.cost),
                   "grad": np.asarray(info.grad).copy(),
                   "mode": mode,
                   "oracle_calls": oracle_calls}
            if self.tel is not None:
                # patch the row the jitted step NaN-seeded: the measured
                # net utility and the host-observed solver wall-clock
                # (dispatch-inclusive — the control loop's real budget)
                from repro.obs import telemetry as _obs_tel

                self.tel = _obs_tel.annotate_donated(
                    self.tel, utility=jnp.float32(rec["utility"]),
                    wall_clock_us=jnp.float32(solver_us))
            self.history.append(rec)
        return rec

    def verdicts(self, comparator=None) -> dict:
        """Run the paper-invariant monitors on the live iterates (and the
        telemetry ring when one is enabled): flow conservation, capacity
        slack, Theorem-3 KKT gap, plus the ring's monotone-descent and
        budget-feasibility checks — ``repro.obs.monitors.check_state``
        with default thresholds (DESIGN.md §18.2).  Host-blocking in the
        sense that the caller will read the verdict arrays; the monitors
        themselves are pure jnp."""
        from repro.obs import monitors as _monitors

        return _monitors.check_state(self.problem, self.state, self.tel,
                                     comparator=comparator)

    # -- dispatch interfaces used by the engine ------------------------------
    def admission_split(self) -> np.ndarray:
        """P(version w) for an incoming request."""
        lam = np.asarray(self.state.lam)
        return lam / lam.sum()

    def replica_weights(self) -> np.ndarray:
        """[W, n_phys] share of version-w traffic each deployed replica
        processes = t_i(w)/λ_w at the nodes deploying w."""
        t = np.asarray(propagate(self.graph, self.state.phi, self.state.lam))
        dep = np.asarray(self.graph.deploy)
        shares = t[:, : self.graph.n_phys] * dep
        tot = shares.sum(-1, keepdims=True)
        return shares / np.where(tot > 0, tot, 1.0)

    # -- fault tolerance: node churn -----------------------------------------
    def on_topology_change(self, new_graph: CECGraph | CECGraphSparse,
                           explore: float = 0.1):
        """Re-target the running iterates onto a new graph (node fail/join).

        φ restarts from an exploration mix so edges that multiplicative
        updates had zeroed can be rediscovered (DESIGN.md §5, §10).  The
        new graph goes through the same representation policy as the
        constructor (``Problem.canonical``).  On the sparse path the
        running ``SparsePhi`` is first re-expressed on the new slot
        layout by **edge identity** (``core.sparse.remap_phi`` — churn
        can repack CSR slots even at unchanged widths, so positional
        reuse would scramble edges), then warm-started part-wise through
        the same ``warm_start_phi`` row math as the dense tensor."""
        old_graph, phi = self.graph, self.state.phi
        self.problem = dataclasses.replace(
            self.problem, graph=new_graph).canonical().validate()
        new_graph = self.graph = self.problem.graph
        if isinstance(new_graph, CECGraphSparse):
            if (isinstance(phi, SparsePhi)
                    and isinstance(old_graph, CECGraphSparse)
                    and old_graph.n_bar == new_graph.n_bar):
                from repro.core.sparse import remap_phi

                phi = remap_phi(old_graph, new_graph, phi)
                phi = SparsePhi(
                    rows=warm_start_phi(phi.rows, new_graph.out_mask,
                                        explore),
                    src=warm_start_phi(phi.src, new_graph.src_out_mask,
                                       explore))
            else:
                phi = new_graph.uniform_phi()
        elif (not isinstance(phi, SparsePhi)
                and phi.shape == new_graph.out_mask.shape):
            phi = warm_start_phi(phi, new_graph.out_mask, explore)
        else:
            phi = new_graph.uniform_phi()
        self.state = self.state._replace(phi=phi)

    def on_demand_change(self, lam_total: float):
        """Re-scale the admission split onto a new total demand λ.

        Only the ``Problem.lam_total`` leaf changes — the fused step's
        compiled executable is reused as-is.
        """
        lam = self.state.lam * (lam_total / self.lam_total)
        self.lam_total = float(lam_total)
        self.problem = self.problem.with_demand(jnp.float32(lam_total))
        self.state = self.state._replace(
            lam=project_box_simplex(lam, self.lam_total, self.config.delta))

    def apply_scenario_event(self, state: ScenarioState,
                             event: Event, explore: float = 0.1
                             ) -> ScenarioState:
        """Consume one scenario-engine event against the live iterates.

        ``state`` is the fleet's physical description (the same
        ``core.scenario.ScenarioState`` the offline sweeps evolve); the
        event is applied there, the augmented graph rebuilt, and the
        running ``SolverState`` warm-started exactly as ``run_scenario``
        does.  Returns the post-event state — thread it into the next
        call.  Bank swaps change only the *measured* utility (the
        environment), so the router's iterates carry over untouched."""
        from repro.obs import trace as _obs_trace

        _obs_trace.instant(f"event:{event.kind}", cat="scenario",
                           args={"kind": event.kind,
                                 "at": len(self.history)})
        new_state = apply_event(state, event)
        if isinstance(event, DemandShift):
            self.on_demand_change(new_state.lam_total)
        elif event.changes_graph:
            self.on_topology_change(new_state.graph(), explore=explore)
        self.history.append({"event": event.kind, "at": len(self.history)})
        return new_state
