"""CEC router: the paper's control plane driving live serving decisions.

The router owns the JOWR state (Λ, φ) for a fleet of edge devices, each
hosting one model version.  Every control interval it:

 1. observes the realized network utility (measured quality-weighted
    throughput minus flow-model network cost — a black box to the router,
    exactly the paper's bandit feedback);
 2. advances the OMAD single-loop (Alg. 3) one outer iteration — gradient
    sampling over the perturbed allocations, one mirror-descent routing
    step per observation;
 3. exposes the new admission split Λ/λ (which version serves what share
    of traffic) and per-replica dispatch weights t_i(w)/λ_w (how much of
    version w's traffic each deploying device processes).

Node churn (device joins/leaves) rebuilds the graph and *warm-starts* φ
with an exploration mix — the Fig. 11 online-adaptation behaviour.

The router's observe path runs through ``core.flow`` / ``core.routing``
and therefore inherits the size-based kernel dispatch (core/dispatch.py)
for free: a fleet whose augmented graph clears the threshold serves its
flow-propagation and mirror-descent steps from the Pallas kernels on TPU
backends (off-TPU the kernels engage only under an explicit override, in
interpret mode) with no change here.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import CECGraph, get_cost, propagate, total_cost
from repro.core.allocation import _observe, _project_box_simplex
from repro.core.routing import solve_routing


@dataclasses.dataclass
class CECRouter:
    graph: CECGraph
    lam_total: float
    delta: float = 0.5
    eta_outer: float = 0.05
    eta_inner: float = 3.0
    cost_name: str = "exp"

    def __post_init__(self):
        self.cost = get_cost(self.cost_name)
        W = self.graph.n_sessions
        self.lam = jnp.full((W,), self.lam_total / W)
        self.phi = self.graph.uniform_phi()
        self.history: list[dict] = []

    # -- the bandit observation the paper assumes ---------------------------
    def _utility(self, measured_task_utility: float, lam) -> float:
        return measured_task_utility - float(
            total_cost(self.graph, self.cost, self.phi, lam))

    def control_step(self, utility_fn) -> dict:
        """One OMAD outer iteration.  ``utility_fn(lam) -> float`` returns
        the *measured* task utility for an admitted allocation (the engine
        serves the perturbed split and reports quality-weighted goodput)."""
        W = self.graph.n_sessions
        g = np.zeros(W, np.float32)
        for w in range(W):
            ew = jnp.zeros(W).at[w].set(1.0)
            for sign in (+1.0, -1.0):
                lam_p = self.lam + sign * self.delta * ew
                self.phi, _ = solve_routing(self.graph, self.cost, lam_p,
                                            self.phi, self.eta_inner, 1)
                u = utility_fn(np.asarray(lam_p)) - float(
                    total_cost(self.graph, self.cost, self.phi, lam_p))
                g[w] += sign * u / (2 * self.delta)
        z = self.eta_outer * (g - g.max())
        wts = np.asarray(self.lam) * np.exp(z)
        lam = jnp.asarray(self.lam_total * wts / wts.sum())
        self.lam = _project_box_simplex(lam, self.lam_total, self.delta)
        rec = {"lam": np.asarray(self.lam).copy(),
               "cost": float(total_cost(self.graph, self.cost, self.phi,
                                        self.lam))}
        self.history.append(rec)
        return rec

    # -- dispatch interfaces used by the engine ------------------------------
    def admission_split(self) -> np.ndarray:
        """P(version w) for an incoming request."""
        lam = np.asarray(self.lam)
        return lam / lam.sum()

    def replica_weights(self) -> np.ndarray:
        """[W, n_phys] share of version-w traffic each deployed replica
        processes = t_i(w)/λ_w at the nodes deploying w."""
        t = np.asarray(propagate(self.graph, self.phi, self.lam))
        dep = np.asarray(self.graph.deploy)
        shares = t[:, : self.graph.n_phys] * dep
        tot = shares.sum(-1, keepdims=True)
        return shares / np.where(tot > 0, tot, 1.0)

    # -- fault tolerance: node churn -----------------------------------------
    def on_topology_change(self, new_graph: CECGraph, explore: float = 0.1):
        """Re-target the running iterates onto a new graph (node fail/join).

        φ restarts from an exploration mix so edges that multiplicative
        updates had zeroed can be rediscovered (DESIGN.md §5)."""
        self.graph = new_graph
        uniform = new_graph.uniform_phi()
        if self.phi.shape == uniform.shape:
            mask = new_graph.out_mask
            mixed = (1 - explore) * self.phi * mask + explore * uniform
            rowsum = mixed.sum(-1, keepdims=True)
            self.phi = jnp.where(rowsum > 0, mixed / jnp.where(
                rowsum > 0, rowsum, 1.0), uniform)
        else:
            self.phi = uniform
