"""Request-driven serving simulation: the fleet in the loop (DESIGN.md §11).

Closes the serving loop end-to-end: `InferenceEngine` replicas decode real
(reduced-config) model traffic with continuous batching, the `CECRouter`'s
fused control step decides admission and dispatch from *measured* utility,
and the scenario engine's declarative events churn the fleet underneath —
what is benchmarked offline is what serves here.

One engine per model version.  Each control interval the sim

 1. replays any scenario events scheduled for this interval against the
    live router (`CECRouter.apply_scenario_event`, the same
    `core.scenario.event_schedule` the offline sweeps compile);
 2. admits a batch of requests — version sampled from the router's
    admission split Λ/λ, replica from its dispatch weights t_i(w)/λ_w —
    and runs the engines a fixed number of decode steps;
 3. folds the decoded tokens into a per-version goodput EMA (tokens
    actually served per admitted request: queueing and window truncation
    show up here as congestion);
 4. advances the router one fused control step against the measured task
    utility  û(Λ) = Σ_w λ_w · quality_w · goodput_w — the batched
    measured-utility callback contract of `CECRouter.control_step`.

The quality ladder defaults to linspace(1, 2, W), mirroring
`core.utility.make_bank`: larger versions earn more per token, so the
router faces the paper's trade-off between task utility and network cost.

`ServingSim` drives ONE tenant synchronously — sim and router alternate.
The multi-tenant production shape is `serve.fleet.RouterFleet` (DESIGN.md
§15.5 maps every `ServingSim`/`CECRouter` construct to its fleet
counterpart): K tenants in one vmapped control step, serving reads
against the published `FleetView` while the next step runs, demand shaped
per interval by `serve.traffic` arrival processes instead of this sim's
fixed `requests_per_interval`.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np

from repro.core.scenario import Scenario, ScenarioState, event_schedule, \
    initial_state
from repro.core.solver import SolverConfig

from .cec_router import CECRouter
from .engine import InferenceEngine, Request


class SimReport(NamedTuple):
    utility: np.ndarray      # [T] measured network utility per interval
    lam: np.ndarray          # [T, W] admission split trajectory
    tokens: np.ndarray       # [T] tokens decoded per interval
    goodput: np.ndarray      # [W] final per-version goodput estimate
    events: list             # [(interval, event kind), ...] as fired
    tokens_served: int       # total decode tokens across all engines
    verdicts: dict | None = None   # end-of-run monitor verdicts (§18.2)


@dataclasses.dataclass
class ServingSim:
    """Drive `InferenceEngine` traffic against the router under a scenario.

    ``scenario.horizon`` is the number of control intervals; its events
    replay at their scheduled interval.  ``cfg``/``params`` are a model
    config and initialized parameters shared by every version's engine
    (versions differ by their quality weight, not their weights — the
    control plane only sees quality-weighted goodput either way).
    """

    scenario: Scenario
    cfg: object
    params: object
    seed: int = 0
    requests_per_interval: int = 8
    engine_steps_per_interval: int = 8
    prompt_len: int = 6
    max_new_tokens: int = 4
    max_batch: int = 4
    max_len: int = 64
    quality: np.ndarray | None = None
    goodput_ema: float = 0.5
    delta: float = 0.5
    eta_outer: float = 0.05
    eta_inner: float = 3.0
    config: SolverConfig | None = None     # overrides the three knobs above
    grad_policy: str = "sampled"           # sampled | learned | auto (§16.4)
    util_family: str | None = None         # surrogate family for the fitter
    telemetry: int = 0                     # obs ring capacity (§18); 0 = off

    def __post_init__(self):
        self.state: ScenarioState = initial_state(self.scenario, self.seed)
        # the knobs→config adaptation lives in CECRouter (one mapping);
        # read the resolved config back so both views agree
        self.router = CECRouter(self.state.graph(),
                                lam_total=self.state.lam_total,
                                delta=self.delta, eta_outer=self.eta_outer,
                                eta_inner=self.eta_inner, config=self.config,
                                grad_policy=self.grad_policy,
                                util_family=self.util_family,
                                telemetry=self.telemetry)
        self.config = self.router.config
        self.n_versions = self.state.deploy.shape[0]
        if self.quality is None:
            self.quality = np.linspace(1.0, 2.0, self.n_versions)
        self.engines = [InferenceEngine(self.cfg, self.params,
                                        max_batch=self.max_batch,
                                        max_len=self.max_len)
                        for _ in range(self.n_versions)]
        # optimistic init: assume full generation until measured otherwise
        self.goodput = np.full(self.n_versions, float(self.max_new_tokens))
        self._schedule = {at: evs for at, evs in event_schedule(self.scenario)
                          if evs}
        self._rng = np.random.default_rng(1_000_003 * self.seed + 17)
        self._rid = 0

    # -- the measured-utility callback (batched contract) -------------------
    def measured_task_utility(self, lams: np.ndarray) -> np.ndarray:
        """û over a [K, W] admission stack: quality-weighted goodput."""
        return np.atleast_2d(np.asarray(lams)) @ (self.quality * self.goodput)

    # -- one control interval ------------------------------------------------
    def _pick_replica(self, weights: np.ndarray, version: int) -> int:
        row = weights[version]
        tot = row.sum()
        if tot > 0:
            return int(self._rng.choice(row.shape[0], p=row / tot))
        # no dispatch mass yet (e.g. right after churn): any alive replica
        dep = np.asarray(self.router.graph.deploy[version])
        return int(self._rng.choice(np.nonzero(dep)[0]))

    def _serve_interval(self) -> int:
        split = self.router.admission_split()
        weights = self.router.replica_weights()
        versions = self._rng.choice(self.n_versions,
                                    size=self.requests_per_interval, p=split)
        admitted: list[Request] = []
        for v in versions:
            prompt = self._rng.integers(
                0, self.cfg.vocab, self.prompt_len).astype(np.int32)
            req = Request(self._rid, prompt,
                          max_new_tokens=self.max_new_tokens,
                          version=int(v),
                          replica=self._pick_replica(weights, int(v)))
            self._rid += 1
            self.engines[int(v)].submit(req)
            admitted.append(req)
        tokens = 0
        for _ in range(self.engine_steps_per_interval):
            tokens += sum(e.step() for e in self.engines)
        for w in range(self.n_versions):
            mine = [len(r.output) for r in admitted if r.version == w]
            if mine:
                self.goodput[w] += self.goodput_ema * (np.mean(mine)
                                                       - self.goodput[w])
        return tokens

    def run(self) -> SimReport:
        from repro.obs import trace as _obs_trace

        u, lam_t, tok, fired = [], [], [], []
        for t in range(self.scenario.horizon):
            for ev in self._schedule.get(t, ()):
                self.state = self.router.apply_scenario_event(self.state, ev)
                fired.append((t, ev.kind))
            with _obs_trace.span("sim.serve", cat="serving",
                                 args={"t": t}):
                tokens = self._serve_interval()
            rec = self.router.control_step(self.measured_task_utility)
            u.append(rec["utility"])
            lam_t.append(rec["lam"])
            tok.append(tokens)
        # end-of-run invariant sweep when the router records telemetry —
        # the sim's report is the natural place operators look first
        verdicts = (self.router.verdicts()
                    if self.router.tel is not None else None)
        return SimReport(utility=np.asarray(u), lam=np.asarray(lam_t),
                         tokens=np.asarray(tok),
                         goodput=self.goodput.copy(), events=fired,
                         tokens_served=sum(e.tokens_served
                                           for e in self.engines),
                         verdicts=verdicts)
