"""Pallas kernel: chunkwise selective-SSM (Mamba S6) scan.

The recurrence h_t = exp(dt_t·A)·h_{t-1} + (dt_t·u_t)·B_t, y_t = ⟨h_t, C_t⟩
is sequential in t, but the production trick (mamba_ssm / jamba) is to keep
the [bdi, ds] state resident in VMEM for a whole time *chunk*: HBM traffic
is then one streaming pass over u/dt/B/C/y — the memory-bound optimum —
instead of a state round-trip per step (the naive lax.scan lowering).

Grid (B, di_blocks, S_chunks); the innermost chunk axis runs sequentially
on TPU so the VMEM state scratch carries across chunks.  The channel axis
is blocked at 128 (f32 lane width); dt/u columns are sliced per block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba_kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, o_ref, h_ref, *,
                  ck: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[...].astype(jnp.float32)                 # [bdi, ds]

    def step(t, h):
        dt_t = dt_ref[0, t, :].astype(jnp.float32)     # [bdi]
        u_t = u_ref[0, t, :].astype(jnp.float32)
        B_t = b_ref[0, t, :].astype(jnp.float32)       # [ds]
        C_t = c_ref[0, t, :].astype(jnp.float32)
        dA = jnp.exp(dt_t[:, None] * A)                # [bdi, ds]
        h = dA * h + (dt_t * u_t)[:, None] * B_t[None, :]
        y = jnp.sum(h * C_t[None, :], axis=1)          # [bdi]
        o_ref[0, t, :] = y.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, ck, step, h_ref[...])
    h_ref[...] = h


def mamba_scan(u, dt, A, Bm, Cm, *, ck: int = 128, bdi: int = 128,
               interpret: bool = False):
    """u, dt [B,S,di]; A [di,ds]; Bm, Cm [B,S,ds] → y [B,S,di].

    S must divide by ck, di by bdi (ops.py pads).  Final states are
    recoverable from a trailing step; the training path only needs y.
    """
    B, S, di = u.shape
    ds = A.shape[1]
    assert S % ck == 0 and di % bdi == 0
    grid = (B, di // bdi, S // ck)
    return pl.pallas_call(
        lambda *refs: _mamba_kernel(*refs, ck=ck),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ck, bdi), lambda b, d, c: (b, c, d)),   # u
            pl.BlockSpec((1, ck, bdi), lambda b, d, c: (b, c, d)),   # dt
            pl.BlockSpec((1, ck, ds), lambda b, d, c: (b, c, 0)),    # B
            pl.BlockSpec((1, ck, ds), lambda b, d, c: (b, c, 0)),    # C
            pl.BlockSpec((bdi, ds), lambda b, d, c: (d, 0)),         # A
        ],
        out_specs=pl.BlockSpec((1, ck, bdi), lambda b, d, c: (b, c, d)),
        out_shape=jax.ShapeDtypeStruct(u.shape, u.dtype),
        scratch_shapes=[pltpu.VMEM((bdi, ds), jnp.float32)],
        interpret=interpret,
    )(u, dt, Bm, Cm, A)
