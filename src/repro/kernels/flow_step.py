"""Pallas kernel: one flow-propagation relaxation step (control plane).

t' = inject + t·Φ for all sessions — a batched vector×matrix product, the
inner-loop hot spot of OMD-RT at fleet scale (N = 10³–10⁵ nodes).  Tiled
128×128 over Φ with an f32 VMEM accumulator; the session axis is the
outermost grid dim.

This kernel is live in the solver: ``core.flow.propagate`` dispatches each
relaxation step here when ``dispatch.use_kernels(n_bar)`` holds — threshold
cleared (default 256) on TPU, or an explicit override (see
core/dispatch.py).  Callers go through ``kernels.ops.flow_step_op``, which
zero-pads the node axes to the 128-block constraint asserted below and
slices the result back; off-TPU the dispatch passes ``interpret=True``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flow_kernel(t_ref, phi_ref, inj_ref, o_ref, acc_ref):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        acc_ref[...] = inj_ref[...].astype(jnp.float32)

    t = t_ref[...].astype(jnp.float32)           # [1, bk]
    phi = phi_ref[0].astype(jnp.float32)         # [bk, bj]
    acc_ref[...] += jax.lax.dot_general(
        t, phi, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def flow_step(t, phi, inject, *, bj: int = 128, bk: int = 128,
              interpret: bool = False):
    """t, inject [W, N]; phi [W, N, N] → [W, N].  N multiple of blocks."""
    W, N = t.shape
    bj, bk = min(bj, N), min(bk, N)
    assert N % bj == 0 and N % bk == 0
    return pl.pallas_call(
        _flow_kernel,
        grid=(W, N // bj, N // bk),
        in_specs=[
            pl.BlockSpec((1, bk), lambda w, j, k: (w, k)),
            pl.BlockSpec((1, bk, bj), lambda w, j, k: (w, k, j)),
            pl.BlockSpec((1, bj), lambda w, j, k: (w, j)),
        ],
        out_specs=pl.BlockSpec((1, bj), lambda w, j, k: (w, j)),
        out_shape=jax.ShapeDtypeStruct((W, N), t.dtype),
        scratch_shapes=[pltpu.VMEM((1, bj), jnp.float32)],
        interpret=interpret,
    )(t, phi, inject)
