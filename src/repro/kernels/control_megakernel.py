"""One-kernel fused control step (paper Alg. 1/3) — dense and sparse.

``solver.step``'s sampled path is a scan of 2W+1 oracle observations,
each stitched from separate flow-propagation / marginal / EG kernels
with the iterates bouncing through HBM between phases.  This module
instantiates the *entire* outer iteration as a single ``pallas_call`` —
perturb Λ by ±δ·e_w, propagate flows to the fixed point, price the
links, form the two-point gradient, mirror-ascent + exact box-simplex
projection, committed observation — so φ, F and the gradient accumulator
never leave VMEM between phases (DESIGN.md §17).

Grid layout (§17.1): ``(P, K+1, 2, W)`` with P = 2W+1 observations,
K oracle iterations plus the pricing pass, a propagate/update phase
pair, and the session sweep innermost.  TPU grids execute sequentially
(lexicographic, last axis fastest), so VMEM scratch carries state across
grid steps exactly like the jnp scan carries (g, φ):

* phase 0 (``ph==0``), per session w: load φ_w from the VMEM-resident
  scratch, run ``depth_max`` Jacobi relaxations ``t ← inject + t·φ_w``,
  accumulate link flows F += tᵀ·φ_w (the w==0 step zeroes F).
* phase 1 (``ph==1``), ``k < K``: at w==0 price the links once
  (D' = mask·cost.deriv(F, C)); every w then runs Gallager's reverse
  recursion in column form and the exponentiated-gradient update,
  storing φ_w back to scratch (bf16 when ``phi_dtype`` says so — §17.3).
* phase 1, ``k == K``: at w==0 evaluate D = Σ mask·cost.value(F, C) and
  fold the two-point term sign·(u_w − D)/(2δ)·e_w into the gradient
  scratch; no φ update (this is the observation's pricing pass —
  ``routing.oracle_observe`` prices the *post*-update iterate).
* observation boundary (``k==0, ph==0, w==0``): perturbed admissions
  Λ ± δ·e_w for p < 2W (always from the *unperturbed* Λ), and for the
  final observation the mirror-ascent + exact projection commit
  (:func:`_mirror_project`).

φ lives in a ``[W, Nb, Nb]`` (dense) or ``[W, Nb, D]``+``[W, Ds]``
(sparse) VMEM scratch for the whole kernel — the VMEM residency
contract (§17.2) is enforced by ``dispatch.megakernel_fits``.  With
``phi_dtype="bfloat16"`` only this φ *storage* narrows: every load
upcasts to f32 before any arithmetic, every store rounds once per EG
update, and flows/prices/gradient/Λ stay f32 (§17.3 has the measured
error bounds against the golden trace).

All transposes are emulated with iota-eye contractions (Mosaic has no
cheap 2D transpose for these shapes) and the sort inside the exact
projection is an O(M²) stable rank sort — ``jnp.sort`` does not lower
inside a TPU kernel body.  The sparse variant keeps the session rate
vector 1-D and gathers with ``jnp.take`` over flattened (node·stride +
slot) ids exactly like ``flow_step_sparse.py``; interpret mode is the
only CI-exercised mode, the TPU path additionally relies on Mosaic's
dynamic-gather lowering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30
BIG = 1e30


# ---------------------------------------------------------------------------
# Mosaic-safe helpers (no 1-D iota, no transpose, no sort)
# ---------------------------------------------------------------------------

def _iota(shape, dim):
    return jax.lax.broadcasted_iota(jnp.int32, shape, dim)


def _eye(m, dtype):
    return (_iota((m, m), 0) == _iota((m, m), 1)).astype(dtype)


def _col(row):
    """[1, M] → [M, 1] via an iota-eye contraction (transpose emulation)."""
    return jnp.sum(_eye(row.shape[1], row.dtype) * row, axis=1, keepdims=True)


def _row(col):
    """[M, 1] → [1, M] (same trick, other axis)."""
    return jnp.sum(_eye(col.shape[0], col.dtype) * col, axis=0, keepdims=True)


def _eg(phi, delta, mask, eta):
    """Row-stabilized exponentiated-gradient step (eq. (22)), last axis.

    Mirrors ``core.sparse.eg_update`` term for term: all-zero-mask rows
    fall through to the input φ, so padded rows stay exactly zero.
    """
    logits = jnp.where(mask > 0, -eta * delta, NEG)
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    w = phi * jnp.exp(logits) * mask
    s = w.sum(-1, keepdims=True)
    return jnp.where(s > 0, w / jnp.where(s > 0, s, 1.0), phi)


def _mirror_project(lam, g, lam_total, n_real, eta_outer, delta):
    """Mirror ascent + exact box-simplex projection on a padded (1, Wp) row.

    Replicates ``solver._mirror_ascent`` → ``solver.project_box_simplex``
    with the ``jnp.sort`` over the 2W breakpoints replaced by an O(M²)
    stable rank sort (strict-less count plus earlier-index tie-break) —
    variadic sorts do not lower inside a kernel body.  Padded entries
    ride as +BIG breakpoints and are excluded from the bracketing count,
    so the real entries project exactly as the unpadded jnp expression.
    """
    wp = lam.shape[1]
    real = (_iota((1, wp), 1) < n_real).astype(lam.dtype)
    z = jnp.where(real > 0, eta_outer * g, NEG)
    z = z - jnp.max(z)
    wgt = lam * jnp.exp(z) * real
    y = lam_total * wgt / jnp.sum(wgt)
    lo = delta
    hi = lam_total - delta
    bp = jnp.concatenate(
        [jnp.where(real > 0, y - lo, BIG), jnp.where(real > 0, y - hi, BIG)],
        axis=1)                                              # (1, M)
    m = bp.shape[1]
    bcol = _col(bp)                                          # (M, 1)
    less = (bp < bcol).astype(lam.dtype)                     # a_j < a_i
    tie = ((bp == bcol)
           & (_iota((m, m), 1) < _iota((m, m), 0))).astype(lam.dtype)
    rank = jnp.sum(less + tie, axis=1, keepdims=True)        # (M, 1) unique
    srt = jnp.sum(jnp.where(rank == _iota((1, m), 1), bcol, 0.0),
                  axis=0, keepdims=True)                     # ascending sort
    # Σ_w clip(y_w − bp, lo, hi) at every sorted breakpoint, then the
    # bracketing segment / linear interpolation of project_box_simplex
    scol = jnp.sum(jnp.clip(y - _col(srt), lo, hi) * real, axis=1,
                   keepdims=True)                            # (M, 1)
    kcol = _iota((m, 1), 0)
    n_bp = 2 * n_real
    count = jnp.sum(((scol >= lam_total) & (kcol < n_bp))
                    .astype(jnp.float32))
    k = jnp.clip(count - 1.0, 0.0, float(n_bp - 2))
    krow = _iota((1, m), 1).astype(jnp.float32)
    t0 = jnp.sum(jnp.where(krow == k, srt, 0.0))
    t1 = jnp.sum(jnp.where(krow == k + 1.0, srt, 0.0))
    kcf = kcol.astype(jnp.float32)
    s0 = jnp.sum(jnp.where(kcf == k, scol, 0.0))
    s1 = jnp.sum(jnp.where(kcf == k + 1.0, scol, 0.0))
    drop = jnp.where(s0 > s1, s0 - s1, 1.0)
    frac = jnp.where(s0 > s1, (s0 - lam_total) / drop, 0.0)
    tau = t0 + frac * (t1 - t0)
    return jnp.clip(y - tau, lo, hi) * real


def _sign_dir(p, widx):
    """Observation p's (sign, e_w row): rows (2w, 2w+1) = (+e_w, −e_w)."""
    sign = jnp.where(p % 2 == 0, 1.0, -1.0)
    ew = (widx == p // 2).astype(jnp.float32)
    return sign, ew


def _task_u(tau_ref, p):
    """Scalar u(Λ ± δe_w) for observation p via a one-hot contraction."""
    tidx = _iota((1, tau_ref.shape[1]), 1)
    return jnp.sum(jnp.where(tidx == p, tau_ref[...], 0.0))


# ---------------------------------------------------------------------------
# dense kernel
# ---------------------------------------------------------------------------

def _dense_kernel(lam_ref, phi0_ref, omask_ref, emask_ref, cap_ref, tau_ref,
                  tot_ref, lam_o, phi_o, g_o, d_o,
                  phi_s, f_s, dp_s, g_s, lam_s, d_s, *,
                  n_sessions, k_iters, depth, src, delta, eta_outer,
                  eta_inner, cost):
    W, K = n_sessions, k_iters
    p = pl.program_id(0)
    k = pl.program_id(1)
    ph = pl.program_id(2)
    w = pl.program_id(3)
    P = pl.num_programs(0)
    np_ = f_s.shape[0]
    wp = lam_s.shape[1]
    lam_total = jnp.max(tot_ref[...])
    widx = _iota((1, wp), 1)
    wsl = (pl.ds(w, 1), slice(None), slice(None))

    # --- first visit: seed the VMEM-resident φ and the gradient scratch
    @pl.when((p == 0) & (k == 0) & (ph == 0))
    def _seed_phi():
        pl.store(phi_s, wsl, phi0_ref[...].astype(phi_s.dtype))

    @pl.when((p == 0) & (k == 0) & (ph == 0) & (w == 0))
    def _seed_g():
        g_s[...] = jnp.zeros_like(g_s)

    # --- observation boundary: perturbed admission, or the commit
    @pl.when((k == 0) & (ph == 0) & (w == 0))
    def _admit():
        @pl.when(p < P - 1)
        def _perturb():
            sign, ew = _sign_dir(p, widx)
            lam_s[...] = lam_ref[...] + sign * delta * ew

        @pl.when(p == P - 1)
        def _commit():
            lam_s[...] = _mirror_project(lam_ref[...], g_s[...], lam_total,
                                         W, eta_outer, delta)

    # --- phase 0: Jacobi flow relaxation + link-flow accumulation
    @pl.when(ph == 0)
    def _flow():
        phi_w = pl.load(phi_s, wsl)[0].astype(jnp.float32)
        lam_w = jnp.sum(jnp.where(widx == w, lam_s[...], 0.0))
        inject = jnp.where(_iota((1, np_), 1) == src, lam_w, 0.0)

        def relax(_, t):
            return inject + jax.lax.dot_general(
                t, phi_w, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)

        t = jax.lax.fori_loop(0, depth, relax, inject)

        @pl.when(w == 0)
        def _zero_f():
            f_s[...] = jnp.zeros_like(f_s)

        f_s[...] += _col(t) * phi_w                  # F_ij += t_i·φ_ij

    # --- phase 1, k < K: price once, then marginals + EG per session
    @pl.when((ph == 1) & (w == 0) & (k < K))
    def _prices():
        dp_s[...] = emask_ref[...] * cost.deriv(f_s[...], cap_ref[...])

    @pl.when((ph == 1) & (k < K))
    def _update():
        phi_w = pl.load(phi_s, wsl)[0].astype(jnp.float32)
        mask_w = omask_ref[0]
        pm = phi_w * mask_w
        dp = dp_s[...]
        ones = jnp.ones((np_, 1), jnp.float32)
        b = jax.lax.dot_general(pm * dp, ones, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

        def back(_, r):
            return b + jax.lax.dot_general(pm, r, (((1,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)

        r = jax.lax.fori_loop(0, depth, back, jnp.zeros_like(b))
        delta_w = mask_w * (dp + _row(r))
        pl.store(phi_s, wsl,
                 _eg(phi_w, delta_w, mask_w, eta_inner)[None].astype(
                     phi_s.dtype))

    # --- phase 1, k == K: observe the cost, fold the two-point term
    @pl.when((ph == 1) & (w == 0) & (k == K))
    def _observe():
        D = jnp.sum(emask_ref[...] * cost.value(f_s[...], cap_ref[...]))
        d_s[...] = jnp.zeros_like(d_s) + D

        @pl.when(p < P - 1)
        def _grad():
            sign, ew = _sign_dir(p, widx)
            g_s[...] += sign * ((_task_u(tau_ref, p) - D)
                                / (2.0 * delta)) * ew

    # --- emit: per-w blocked φ at its last visit, rows at the final step
    @pl.when((p == P - 1) & (k == K) & (ph == 1))
    def _emit_phi():
        phi_o[...] = pl.load(phi_s, wsl).astype(phi_o.dtype)

    @pl.when((p == P - 1) & (k == K) & (ph == 1) & (w == W - 1))
    def _emit_rows():
        lam_o[...] = lam_s[...]
        g_o[...] = g_s[...]
        d_o[...] = d_s[...]


def control_step_dense(lam, phi, out_mask, edge_mask, capacity, task_u, tot,
                       *, depth_max, src, k_iters, delta, eta_outer,
                       eta_inner, cost, phi_dtype=jnp.float32,
                       interpret=False):
    """Padded-operand dense megakernel (callers go through ``ops``).

    ``lam``/``tot`` (1, Wp); ``phi``/``out_mask`` [W, Np, Np];
    ``edge_mask``/``capacity`` (Np, Np); ``task_u`` (1, Pp).  Returns
    (Λ' (1, Wp), φ' [W, Np, Np] f32, ĝ (1, Wp), D (1, Wp) broadcast).
    """
    W, np_, _ = phi.shape
    wp = lam.shape[1]
    grid = (2 * W + 1, k_iters + 1, 2, W)
    row = pl.BlockSpec(lam.shape, lambda p, k, ph, w: (0, 0))
    tau_row = pl.BlockSpec(task_u.shape, lambda p, k, ph, w: (0, 0))
    per_w = pl.BlockSpec((1, np_, np_), lambda p, k, ph, w: (w, 0, 0))
    full = pl.BlockSpec((np_, np_), lambda p, k, ph, w: (0, 0))
    kernel = functools.partial(
        _dense_kernel, n_sessions=W, k_iters=k_iters, depth=depth_max,
        src=src, delta=delta, eta_outer=eta_outer, eta_inner=eta_inner,
        cost=cost)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[row, per_w, per_w, full, full, tau_row, row],
        out_specs=[row, per_w, row, row],
        out_shape=[
            jax.ShapeDtypeStruct((1, wp), jnp.float32),
            jax.ShapeDtypeStruct((W, np_, np_), jnp.float32),
            jax.ShapeDtypeStruct((1, wp), jnp.float32),
            jax.ShapeDtypeStruct((1, wp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((W, np_, np_), phi_dtype),    # φ — the resident state
            pltpu.VMEM((np_, np_), jnp.float32),     # F accumulator
            pltpu.VMEM((np_, np_), jnp.float32),     # link prices D'
            pltpu.VMEM((1, wp), jnp.float32),        # gradient ĝ
            pltpu.VMEM((1, wp), jnp.float32),        # admission Λ_p
            pltpu.VMEM((1, wp), jnp.float32),        # observed cost D
        ],
        interpret=interpret,
    )(lam, phi, out_mask, edge_mask, capacity, task_u, tot)


# ---------------------------------------------------------------------------
# sparse (padded-CSR slot layout) kernel
# ---------------------------------------------------------------------------

def _sparse_kernel(lam_ref, rows0_ref, src0_ref, omask_ref, smask_ref,
                   dep_ref, emask_ref, cap_ref, semask_ref, scap_ref,
                   nbr_ref, snbr_ref, sink_ref, insrc_ref, inslot_ref,
                   inmask_ref, smat_ref, tau_ref, tot_ref,
                   lam_o, rows_o, src_o, g_o, d_o,
                   rows_s, srcphi_s, f_s, fsrc_s, dp_s, dpsrc_s, g_s,
                   lam_s, d_s, *,
                   n_sessions, k_iters, depth, src, n_phys, delta,
                   eta_outer, eta_inner, cost):
    W, K = n_sessions, k_iters
    p = pl.program_id(0)
    k = pl.program_id(1)
    ph = pl.program_id(2)
    w = pl.program_id(3)
    P = pl.num_programs(0)
    np_, dmax = f_s.shape
    wp = lam_s.shape[1]
    lam_total = jnp.max(tot_ref[...])
    widx = _iota((1, wp), 1)
    nidx = _iota((1, np_), 1)[0]                     # [Np] node ids (2D-born)
    wsl3 = (pl.ds(w, 1), slice(None), slice(None))
    wsl2 = (pl.ds(w, 1), slice(None))

    @pl.when((p == 0) & (k == 0) & (ph == 0))
    def _seed_phi():
        pl.store(rows_s, wsl3, rows0_ref[...].astype(rows_s.dtype))
        pl.store(srcphi_s, wsl2, src0_ref[...].astype(srcphi_s.dtype))

    @pl.when((p == 0) & (k == 0) & (ph == 0) & (w == 0))
    def _seed_g():
        g_s[...] = jnp.zeros_like(g_s)

    @pl.when((k == 0) & (ph == 0) & (w == 0))
    def _admit():
        @pl.when(p < P - 1)
        def _perturb():
            sign, ew = _sign_dir(p, widx)
            lam_s[...] = lam_ref[...] + sign * delta * ew

        @pl.when(p == P - 1)
        def _commit():
            lam_s[...] = _mirror_project(lam_ref[...], g_s[...], lam_total,
                                         W, eta_outer, delta)

    # --- phase 0: Jacobi relaxation over edge lists (cf. sparse.propagate)
    @pl.when(ph == 0)
    def _flow():
        rows_w = pl.load(rows_s, wsl3)[0].astype(jnp.float32)  # [Np, D]
        src_w = pl.load(srcphi_s, wsl2).astype(jnp.float32)    # (1, Ds)
        lam_w = jnp.sum(jnp.where(widx == w, lam_s[...], 0.0))
        # base inflow: exogenous injection at S plus the admission flow
        # λ_w·φ_S scattered onto the S→D(1) heads by the (Ds, Np) matmul
        # scatter built in ops.py (no in-kernel scatter on TPU)
        admit = lam_w * src_w * smask_ref[...]                 # (1, Ds)
        scat = jax.lax.dot_general(admit, smat_ref[...],
                                   (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)
        inject = jnp.where(nidx == src, lam_w, 0.0)            # [Np]
        base = inject + scat[0]
        flat = rows_w.reshape(-1)
        pv = jnp.take(flat, insrc_ref[...] * dmax + inslot_ref[...])
        psink = jnp.take(flat, nidx * dmax + sink_ref[0])      # [Np]
        dep_w = dep_ref[0]                                     # [Np]
        on_sink = nidx == (n_phys + 1 + w)

        def relax(_, t):
            sval = jnp.sum(dep_w * t * psink)                  # old-t Jacobi
            tn = base + (jnp.take(t, insrc_ref[...]) * pv
                         * inmask_ref[...]).sum(-1)
            return jnp.where(on_sink, sval, tn)

        t = jax.lax.fori_loop(0, depth, relax, inject)

        @pl.when(w == 0)
        def _zero_f():
            f_s[...] = jnp.zeros_like(f_s)
            fsrc_s[...] = jnp.zeros_like(fsrc_s)

        f_s[...] += t[:, None] * rows_w              # F_slots += t_i·φ_i,d
        t_src = jnp.sum(jnp.where(nidx == src, t, 0.0))
        fsrc_s[...] += t_src * src_w

    @pl.when((ph == 1) & (w == 0) & (k < K))
    def _prices():
        dp_s[...] = emask_ref[...] * cost.deriv(f_s[...], cap_ref[...])
        dpsrc_s[...] = semask_ref[...] * cost.deriv(fsrc_s[...],
                                                    scap_ref[...])

    @pl.when((ph == 1) & (k < K))
    def _update():
        rows_w = pl.load(rows_s, wsl3)[0].astype(jnp.float32)
        src_w = pl.load(srcphi_s, wsl2).astype(jnp.float32)
        mask_w = omask_ref[0]                                  # [Np, D]
        smask_w = smask_ref[...]                               # (1, Ds)
        nbr = nbr_ref[...]
        snbr = snbr_ref[...]
        dpr = dp_s[...]
        dps = dpsrc_s[...]

        def back(_, r):
            rn = (rows_w * mask_w * (dpr + jnp.take(r, nbr))).sum(-1)
            rs = jnp.sum(src_w * smask_w * (dps + jnp.take(r, snbr)))
            return jnp.where(nidx == src, rs, rn)

        r = jax.lax.fori_loop(0, depth, back,
                              jnp.zeros((np_,), jnp.float32))
        delta_rows = mask_w * (dpr + jnp.take(r, nbr))
        delta_src = smask_w * (dps + jnp.take(r, snbr))
        pl.store(rows_s, wsl3,
                 _eg(rows_w, delta_rows, mask_w, eta_inner)[None].astype(
                     rows_s.dtype))
        pl.store(srcphi_s, wsl2,
                 _eg(src_w, delta_src, smask_w, eta_inner).astype(
                     srcphi_s.dtype))

    @pl.when((ph == 1) & (w == 0) & (k == K))
    def _observe():
        D = (jnp.sum(emask_ref[...] * cost.value(f_s[...], cap_ref[...]))
             + jnp.sum(semask_ref[...] * cost.value(fsrc_s[...],
                                                    scap_ref[...])))
        d_s[...] = jnp.zeros_like(d_s) + D

        @pl.when(p < P - 1)
        def _grad():
            sign, ew = _sign_dir(p, widx)
            g_s[...] += sign * ((_task_u(tau_ref, p) - D)
                                / (2.0 * delta)) * ew

    @pl.when((p == P - 1) & (k == K) & (ph == 1))
    def _emit_phi():
        rows_o[...] = pl.load(rows_s, wsl3).astype(rows_o.dtype)
        src_o[...] = pl.load(srcphi_s, wsl2).astype(src_o.dtype)

    @pl.when((p == P - 1) & (k == K) & (ph == 1) & (w == W - 1))
    def _emit_rows():
        lam_o[...] = lam_s[...]
        g_o[...] = g_s[...]
        d_o[...] = d_s[...]


def control_step_sparse(lam, rows, src_phi, out_mask, src_out_mask, deploy,
                        edge_mask, capacity, src_edge_mask, src_capacity,
                        nbr, src_nbr, sink_slot, in_src, in_slot, in_mask,
                        smat, task_u, tot, *, depth_max, src, n_phys,
                        k_iters, delta, eta_outer, eta_inner, cost,
                        phi_dtype=jnp.float32, interpret=False):
    """Padded-operand sparse megakernel (callers go through ``ops``).

    Slot layout follows ``CECGraphSparse``; ``smat`` is the (Ds, Np)
    matmul-scatter of the S→D(1) fan-out heads.  Returns (Λ', φ'.rows,
    φ'.src, ĝ, D-row), all f32.
    """
    W, np_, dmax = rows.shape
    dsp = src_phi.shape[1]
    wp = lam.shape[1]
    grid = (2 * W + 1, k_iters + 1, 2, W)
    row = pl.BlockSpec(lam.shape, lambda p, k, ph, w: (0, 0))
    tau_row = pl.BlockSpec(task_u.shape, lambda p, k, ph, w: (0, 0))
    per_w3 = pl.BlockSpec((1, np_, dmax), lambda p, k, ph, w: (w, 0, 0))
    per_w_src = pl.BlockSpec((1, dsp), lambda p, k, ph, w: (w, 0))
    per_w_node = pl.BlockSpec((1, np_), lambda p, k, ph, w: (w, 0))
    full = pl.BlockSpec((np_, dmax), lambda p, k, ph, w: (0, 0))
    full_src = pl.BlockSpec((1, dsp), lambda p, k, ph, w: (0, 0))
    full_node = pl.BlockSpec((1, np_), lambda p, k, ph, w: (0, 0))
    full_in = pl.BlockSpec(in_src.shape, lambda p, k, ph, w: (0, 0))
    full_smat = pl.BlockSpec(smat.shape, lambda p, k, ph, w: (0, 0))
    kernel = functools.partial(
        _sparse_kernel, n_sessions=W, k_iters=k_iters, depth=depth_max,
        src=src, n_phys=n_phys, delta=delta, eta_outer=eta_outer,
        eta_inner=eta_inner, cost=cost)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[row, per_w3, per_w_src, per_w3, per_w_src, per_w_node,
                  full, full, full_src, full_src, full, full_src, full_node,
                  full_in, full_in, full_in, full_smat, tau_row, row],
        out_specs=[row, per_w3, per_w_src, row, row],
        out_shape=[
            jax.ShapeDtypeStruct((1, wp), jnp.float32),
            jax.ShapeDtypeStruct((W, np_, dmax), jnp.float32),
            jax.ShapeDtypeStruct((W, dsp), jnp.float32),
            jax.ShapeDtypeStruct((1, wp), jnp.float32),
            jax.ShapeDtypeStruct((1, wp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((W, np_, dmax), phi_dtype),   # φ rows — resident
            pltpu.VMEM((W, dsp), phi_dtype),         # φ source row
            pltpu.VMEM((np_, dmax), jnp.float32),    # F slot accumulator
            pltpu.VMEM((1, dsp), jnp.float32),       # F source slots
            pltpu.VMEM((np_, dmax), jnp.float32),    # slot prices D'
            pltpu.VMEM((1, dsp), jnp.float32),       # source prices
            pltpu.VMEM((1, wp), jnp.float32),        # gradient ĝ
            pltpu.VMEM((1, wp), jnp.float32),        # admission Λ_p
            pltpu.VMEM((1, wp), jnp.float32),        # observed cost D
        ],
        interpret=interpret,
    )(lam, rows, src_phi, out_mask, src_out_mask, deploy, edge_mask,
      capacity, src_edge_mask, src_capacity, nbr, src_nbr, sink_slot,
      in_src, in_slot, in_mask, smat, task_u, tot)
