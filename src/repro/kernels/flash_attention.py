"""Flash attention for TPU (Pallas): online-softmax tiling, causal, GQA.

Grid (B, H, nq, nk); the innermost kv axis iterates sequentially on TPU so
the running (max, denom, acc) state lives in VMEM scratch across kv blocks.
Fully-masked causal blocks are skipped with ``pl.when`` (≈2× prefill win).
BlockSpecs keep one (bq×hd) query tile + one (bk×hd) KV tile + the f32
accumulator in VMEM: for bq=bk=512, hd=128 that is ≈0.9 MB — well under
the ~16 MB v5e VMEM budget, and all matmul dims are 128-multiples (MXU).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, q_offset: int, kv_len: int,
                  bq: int, bk: int):
    qi, ki = pl.program_id(2), pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_first = q_offset + qi * bq            # first query position of block
    k_first = ki * bk
    # causal skip: whole kv block strictly in the future of every query row
    live = (k_first <= q_first + bq - 1) if causal else (ki >= 0)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)          # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_first + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < kv_len
        if causal:
            qpos = q_first + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            mask &= kpos <= qpos
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                         # [bq, 1]
        m_cur = jnp.max(s, -1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)               # rescale old state
        p = jnp.exp(s - m_new)                        # [bq, bk]
        l_new = alpha * l_ref[:, :1] + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == pl.num_programs(3) - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, q_offset: int = 0,
                    kv_len: int | None = None, bq: int = 512, bk: int = 512,
                    interpret: bool = False):
    """q [B,H,S,hd]; k,v [B,KH,T,hd] → [B,H,S,hd].  S, T multiples of blocks.

    ``kv_len`` masks trailing cache padding; GQA handled via the K/V index
    map (query head h reads kv head h//G — no materialized repeat).
    """
    B, H, S, hd = q.shape
    KH, T = k.shape[1], k.shape[2]
    G = H // KH
    bq, bk = min(bq, S), min(bk, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    kv_len = T if kv_len is None else kv_len
    grid = (B, H, S // bq, T // bk)

    kern = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(hd), causal=causal,
        q_offset=q_offset, kv_len=kv_len, bq=bq, bk=bk)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # running max (col 0 used)
            pltpu.VMEM((bq, 128), jnp.float32),   # running denom
            pltpu.VMEM((bq, hd), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
