"""Pallas kernel: exponentiated-gradient update over padded edge slots.

Identical math to ``omd_update`` (eq. (22), row-stabilized) but over the
sparse slot layout: rows are [R, C] blocks where C is the padded slot
count — ``d_max`` for the per-node CSR rows, ``d_src`` for the virtual
source's admission row — so one VMEM pass costs O(E) instead of O(N̄²).
Rectangular [W, R, C] operands are first-class (the dense kernel assumes
square [W, N, N]); rows whose mask is all zero fall through to the input
φ, which also makes slot padding exact.

Dispatched by ``core.sparse.omd_phi_update`` when ``dispatch.
use_kernels(n_bar)`` holds, through ``kernels.ops.omd_update_sparse_op``
(pads R to the row-block multiple and C to 128 lanes).  η is a static
kernel parameter (Python float), as on the dense path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _omd_sparse_kernel(phi_ref, delta_ref, mask_ref, o_ref, *, eta: float):
    phi = phi_ref[0].astype(jnp.float32)           # [br, C]
    delta = delta_ref[0].astype(jnp.float32)
    mask = mask_ref[0].astype(jnp.float32)
    logits = jnp.where(mask > 0, -eta * delta, NEG)
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    w = phi * jnp.exp(logits) * mask
    s = w.sum(-1, keepdims=True)
    o_ref[0] = jnp.where(s > 0, w / jnp.where(s > 0, s, 1.0),
                         phi).astype(o_ref.dtype)


def omd_update_sparse(phi, delta, mask, eta: float, *, br: int = 128,
                      interpret: bool = False):
    """phi, delta, mask [W, R, C] → updated phi.  R multiple of br."""
    W, R, C = phi.shape
    br = min(br, R)
    assert R % br == 0
    spec = pl.BlockSpec((1, br, C), lambda w, i: (w, i, 0))
    return pl.pallas_call(
        functools.partial(_omd_sparse_kernel, eta=eta),
        grid=(W, R // br),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(phi.shape, phi.dtype),
        interpret=interpret,
    )(phi, delta, mask)
