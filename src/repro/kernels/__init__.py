"""Pallas TPU kernels: flash attention, OMD routing update, flow step —
dense and sparse (segment/edge-list) variants.

Each kernel has a jnp oracle in ref.py and a padded jit wrapper in ops.py;
validated in interpret mode (tests/test_kernels.py)."""
from . import ref
from .ops import (flash_attention_op, flow_step_op, flow_step_sparse_op,
                  omd_update_op, omd_update_sparse_op)

__all__ = ["ref", "flash_attention_op", "flow_step_op",
           "flow_step_sparse_op", "omd_update_op", "omd_update_sparse_op"]
