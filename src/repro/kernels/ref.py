"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each kernel's test sweeps shapes/dtypes and asserts allclose against these.
They are also the CPU / dry-run execution paths.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def flow_step_ref(t: Array, phi: Array, inject: Array) -> Array:
    """One flow-propagation relaxation step: t' = inject + t·Φ (per session).

    t, inject [W, N]; phi [W, N, N] (pre-masked row-stochastic).
    """
    return inject + jnp.einsum("wi,wij->wj", t, phi)


def omd_update_ref(phi: Array, delta: Array, mask: Array, eta: float) -> Array:
    """Exponentiated-gradient routing update (paper eq. (22)), row-stabilized."""
    logits = jnp.where(mask > 0, -eta * delta, -1e30)
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    w = phi * jnp.exp(logits) * mask
    s = w.sum(-1, keepdims=True)
    return jnp.where(s > 0, w / jnp.where(s > 0, s, 1.0), phi)


def flow_step_sparse_ref(t: Array, rows: Array, base: Array, in_src: Array,
                         in_slot: Array, in_mask: Array) -> Array:
    """Sparse relaxation step: gather + masked in-segment sum.

    t, base [W, N]; rows (φ slots) [W, N, D]; in_src/in_slot/in_mask
    [N, Din].  Returns base + Σ_d t[:, in_src]·rows[:, in_src, in_slot]
    — the relay half of ``core.sparse.propagate``'s step (virtual-sink
    entries are overlaid by the caller).
    """
    vals = t[:, in_src] * rows[:, in_src, in_slot]
    return base + (vals * in_mask).sum(-1)


def omd_update_sparse_ref(phi: Array, delta: Array, mask: Array,
                          eta: float) -> Array:
    """Exponentiated-gradient update over [W, R, C] edge-slot rows.

    Same contract as :func:`omd_update_ref` — the row update is
    representation-agnostic; only the trailing-axis meaning differs.
    """
    return omd_update_ref(phi, delta, mask, eta)


def mha_ref(q: Array, k: Array, v: Array, causal: bool = True,
            q_offset: int = 0, kv_len: int | None = None) -> Array:
    """Dense GQA attention. q [B,H,S,hd]; k,v [B,KH,T,hd] → [B,H,S,hd]."""
    B, H, S, hd = q.shape
    KH, T = k.shape[1], k.shape[2]
    G = H // KH
    qg = q.reshape(B, KH, G, S, hd)
    s = jnp.einsum("bkgsd,bktd->bkgst", qg, k) / math.sqrt(hd)
    kpos = jnp.arange(T)
    mask = kpos[None, :] < (kv_len if kv_len is not None else T)
    if causal:
        qpos = q_offset + jnp.arange(S)
        mask = mask & (kpos[None, :] <= qpos[:, None])
    s = jnp.where(mask[None, None, None], s.astype(jnp.float32), -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgst,bktd->bkgsd", p, v)
    return o.reshape(B, H, S, hd)


def mamba_scan_ref(u: Array, dt: Array, A: Array, Bm: Array,
                   Cm: Array) -> Array:
    """Sequential selective-SSM reference: y_t = ⟨h_t, C_t⟩ with
    h_t = exp(dt_t·A)h_{t−1} + (dt_t·u_t)B_t.  u,dt [B,S,di]; A [di,ds]."""
    B, S, di = u.shape

    def step(h, xs):
        u_t, dt_t, B_t, C_t = xs
        dA = jnp.exp(dt_t[..., None] * A)
        h = dA * h + (dt_t * u_t)[..., None] * B_t[:, None, :]
        return h, jnp.einsum("bds,bs->bd", h, C_t)

    h0 = jnp.zeros((B, di, A.shape[1]), jnp.float32)
    xs = tuple(jnp.moveaxis(x.astype(jnp.float32), 1, 0)
               for x in (u, dt, Bm, Cm))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(u.dtype)
