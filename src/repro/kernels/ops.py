"""Jit'd public wrappers around the Pallas kernels.

Each op pads its operands to hardware-aligned tiles, invokes the kernel
(``interpret=True`` on CPU — the TPU path flips the flag), and slices the
padding back off.  The control plane consults ``core.dispatch``:
``flow_step_op`` / ``omd_update_op`` are invoked by ``core.flow.propagate``
and ``core.routing.omd_step`` whenever ``dispatch.use_kernels(n_bar)``
holds (threshold cleared on TPU, or an explicit override), with
``interpret=dispatch.kernel_interpret()`` (True off-TPU).  Padding rules: both node axes go to multiples of 128 with
zeros — zero-padded φ rows contribute nothing to ``flow_step`` accumulation,
and all-zero-mask rows in ``omd_update`` fall through to the input φ before
being sliced off, so padding is exact.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention
from .flow_step import flow_step
from .flow_step_sparse import flow_step_sparse
from .mamba_scan import mamba_scan
from .omd_update import omd_update
from .omd_update_sparse import omd_update_sparse


def _pad_to(x, axis: int, mult: int, value=0.0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@partial(jax.jit, static_argnames=("causal", "q_offset", "kv_len",
                                   "interpret"))
def flash_attention_op(q, k, v, causal=True, q_offset=0, kv_len=None,
                       interpret=True):
    """Padded/sliced flash attention; q [B,H,S,hd], k/v [B,KH,T,hd]."""
    S, T = q.shape[2], k.shape[2]
    kv_len = T if kv_len is None else kv_len
    bq = 512 if S >= 512 else max(8, S)
    bk = 512 if T >= 512 else max(8, T)
    qp = _pad_to(q, 2, bq)
    kp = _pad_to(k, 2, bk)
    vp = _pad_to(v, 2, bk)
    out = flash_attention(qp, kp, vp, causal=causal, q_offset=q_offset,
                          kv_len=kv_len, bq=bq, bk=bk, interpret=interpret)
    return out[:, :, :S]


@partial(jax.jit, static_argnames=("interpret",))
def flow_step_op(t, phi, inject, interpret=True):
    N = t.shape[1]
    tp = _pad_to(t, 1, 128)
    ip = _pad_to(inject, 1, 128)
    pp = _pad_to(_pad_to(phi, 1, 128), 2, 128)
    return flow_step(tp, pp, ip, interpret=interpret)[:, :N]


@partial(jax.jit, static_argnames=("eta", "interpret"))
def omd_update_op(phi, delta, mask, eta, interpret=True):
    N = phi.shape[1]
    pp = _pad_to(_pad_to(phi, 1, 128), 2, 128)
    dp = _pad_to(_pad_to(delta, 1, 128), 2, 128)
    mp = _pad_to(_pad_to(mask, 1, 128), 2, 128)
    out = omd_update(pp, dp, mp, eta, interpret=interpret)
    return out[:, :N, :N]


@partial(jax.jit, static_argnames=("interpret",))
def flow_step_sparse_op(t, rows, base, in_src, in_slot, in_mask,
                        interpret=True):
    """Padded/sliced sparse relaxation step (see flow_step_sparse.py).

    Pads the node axis to 128 and both slot axes (d_max, d_in_max) to 128.
    Slot ids stay valid under padding because ``in_slot`` indexes within
    its row (the kernel flattens with the *padded* slot stride); padded
    in-entries carry mask 0 and point at (0, 0).
    """
    N = t.shape[1]
    tp = _pad_to(t, 1, 128)
    bp = _pad_to(base, 1, 128)
    rp = _pad_to(_pad_to(rows, 1, 128), 2, 128)
    sp = _pad_to(_pad_to(in_src, 0, 128), 1, 128)
    slp = _pad_to(_pad_to(in_slot, 0, 128), 1, 128)
    mp = _pad_to(_pad_to(in_mask, 0, 128), 1, 128)
    return flow_step_sparse(tp, rp, bp, sp, slp, mp,
                            interpret=interpret)[:, :N]


@partial(jax.jit, static_argnames=("eta", "interpret"))
def omd_update_sparse_op(phi, delta, mask, eta, interpret=True):
    """Padded/sliced sparse EG update over [W, R, C] edge-slot rows."""
    R, C = phi.shape[1], phi.shape[2]
    pp = _pad_to(_pad_to(phi, 1, 128), 2, 128)
    dp = _pad_to(_pad_to(delta, 1, 128), 2, 128)
    mp = _pad_to(_pad_to(mask, 1, 128), 2, 128)
    out = omd_update_sparse(pp, dp, mp, eta, interpret=interpret)
    return out[:, :R, :C]


@partial(jax.jit, static_argnames=("interpret",))
def mamba_scan_op(u, dt, A, Bm, Cm, interpret=True):
    """Padded chunkwise SSM scan; pads di→128-multiple, S→chunk multiple."""
    B, S, di = u.shape
    ck = 128 if S >= 128 else S
    up = _pad_to(_pad_to(u, 1, ck), 2, 128)
    dtp = _pad_to(_pad_to(dt, 1, ck), 2, 128)
    Ap = _pad_to(A, 0, 128)
    Bp = _pad_to(Bm, 1, ck)
    Cp = _pad_to(Cm, 1, ck)
    out = mamba_scan(up, dtp, Ap, Bp, Cp, ck=ck, interpret=interpret)
    return out[:, :S, :di]


__all__ = ["flash_attention_op", "flow_step_op", "flow_step_sparse_op",
           "mamba_scan_op", "omd_update_op", "omd_update_sparse_op", "ref"]
