"""Jit'd public wrappers around the Pallas kernels.

Each op pads its operands to hardware-aligned tiles, invokes the kernel
(``interpret=True`` on CPU — the TPU path flips the flag), and slices the
padding back off.  The control plane consults ``core.dispatch``:
``flow_step_op`` / ``omd_update_op`` are invoked by ``core.flow.propagate``
and ``core.routing.omd_step`` whenever ``dispatch.use_kernels(n_bar)``
holds (threshold cleared on TPU, or an explicit override), with
``interpret=dispatch.kernel_interpret()`` (True off-TPU).  Padding rules: both node axes go to multiples of 128 with
zeros — zero-padded φ rows contribute nothing to ``flow_step`` accumulation,
and all-zero-mask rows in ``omd_update`` fall through to the input φ before
being sliced off, so padding is exact.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .control_megakernel import control_step_dense, control_step_sparse
from .flash_attention import flash_attention
from .flow_step import flow_step
from .flow_step_sparse import flow_step_sparse
from .mamba_scan import mamba_scan
from .omd_update import omd_update
from .omd_update_sparse import omd_update_sparse


def _pad_to(x, axis: int, mult: int, value=0.0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _pad_axis_to(x, axis: int, size: int, value=0.0):
    """Pad ``axis`` to exactly ``size`` entries (≥ current length).

    The megakernel needs *one* padded node width shared by arrays whose
    native node axes differ (``sink_slot``/``deploy`` run over ``n_phys``,
    everything else over ``n_bar``) — a per-array multiple-of-128 pad
    would disagree whenever the two cross different 128 boundaries.
    """
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _round_up(n: int, mult: int = 128) -> int:
    return ((n + mult - 1) // mult) * mult


@partial(jax.jit, static_argnames=("causal", "q_offset", "kv_len",
                                   "interpret"))
def flash_attention_op(q, k, v, causal=True, q_offset=0, kv_len=None,
                       interpret=True):
    """Padded/sliced flash attention; q [B,H,S,hd], k/v [B,KH,T,hd]."""
    S, T = q.shape[2], k.shape[2]
    kv_len = T if kv_len is None else kv_len
    bq = 512 if S >= 512 else max(8, S)
    bk = 512 if T >= 512 else max(8, T)
    qp = _pad_to(q, 2, bq)
    kp = _pad_to(k, 2, bk)
    vp = _pad_to(v, 2, bk)
    out = flash_attention(qp, kp, vp, causal=causal, q_offset=q_offset,
                          kv_len=kv_len, bq=bq, bk=bk, interpret=interpret)
    return out[:, :, :S]


@partial(jax.jit, static_argnames=("interpret",))
def flow_step_op(t, phi, inject, interpret=True):
    N = t.shape[1]
    tp = _pad_to(t, 1, 128)
    ip = _pad_to(inject, 1, 128)
    pp = _pad_to(_pad_to(phi, 1, 128), 2, 128)
    return flow_step(tp, pp, ip, interpret=interpret)[:, :N]


@partial(jax.jit, static_argnames=("eta", "interpret"))
def omd_update_op(phi, delta, mask, eta, interpret=True):
    N = phi.shape[1]
    pp = _pad_to(_pad_to(phi, 1, 128), 2, 128)
    dp = _pad_to(_pad_to(delta, 1, 128), 2, 128)
    mp = _pad_to(_pad_to(mask, 1, 128), 2, 128)
    out = omd_update(pp, dp, mp, eta, interpret=interpret)
    return out[:, :N, :N]


@partial(jax.jit, static_argnames=("interpret",))
def flow_step_sparse_op(t, rows, base, in_src, in_slot, in_mask,
                        interpret=True):
    """Padded/sliced sparse relaxation step (see flow_step_sparse.py).

    Pads the node axis to 128 and both slot axes (d_max, d_in_max) to 128.
    Slot ids stay valid under padding because ``in_slot`` indexes within
    its row (the kernel flattens with the *padded* slot stride); padded
    in-entries carry mask 0 and point at (0, 0).
    """
    N = t.shape[1]
    tp = _pad_to(t, 1, 128)
    bp = _pad_to(base, 1, 128)
    rp = _pad_to(_pad_to(rows, 1, 128), 2, 128)
    sp = _pad_to(_pad_to(in_src, 0, 128), 1, 128)
    slp = _pad_to(_pad_to(in_slot, 0, 128), 1, 128)
    mp = _pad_to(_pad_to(in_mask, 0, 128), 1, 128)
    return flow_step_sparse(tp, rp, bp, sp, slp, mp,
                            interpret=interpret)[:, :N]


@partial(jax.jit, static_argnames=("eta", "interpret"))
def omd_update_sparse_op(phi, delta, mask, eta, interpret=True):
    """Padded/sliced sparse EG update over [W, R, C] edge-slot rows."""
    R, C = phi.shape[1], phi.shape[2]
    pp = _pad_to(_pad_to(phi, 1, 128), 2, 128)
    dp = _pad_to(_pad_to(delta, 1, 128), 2, 128)
    mp = _pad_to(_pad_to(mask, 1, 128), 2, 128)
    out = omd_update_sparse(pp, dp, mp, eta, interpret=interpret)
    return out[:, :R, :C]


@partial(jax.jit, static_argnames=("k_iters", "delta", "eta_outer",
                                   "eta_inner", "cost", "phi_dtype",
                                   "interpret"))
def control_step_op(lam, phi, task_u, lam_total, graph, k_iters, delta,
                    eta_outer, eta_inner, cost, phi_dtype="float32",
                    interpret=True):
    """Padded/sliced one-kernel fused control step, dense layout.

    ``lam`` [W], ``phi`` [W, Nb, Nb], ``task_u`` [2W] (the measured task
    utilities in ``perturbed_allocations`` row order), ``lam_total`` a
    traced scalar, ``graph`` a ``CECGraph`` pytree.  η's, δ, ``k_iters``
    (the oracle's OMD iteration count) and the ``CostFn`` are static
    kernel parameters.  Capacity pads with 1.0 — a zero-capacity pad
    entry would put NaN into cost derivatives that the mask multiply
    cannot kill.  Returns (Λ' [W], φ' [W, Nb, Nb], ĝ [W], D scalar).
    """
    W, N, _ = phi.shape
    lp = _pad_to(lam[None, :], 1, 128)
    taup = _pad_to(task_u[None, :], 1, 128)
    tot = jnp.zeros_like(lp) + lam_total
    pp = _pad_to(_pad_to(phi, 1, 128), 2, 128)
    mp = _pad_to(_pad_to(graph.out_mask, 1, 128), 2, 128)
    ep = _pad_to(_pad_to(graph.edge_mask, 0, 128), 1, 128)
    cp = _pad_to(_pad_to(graph.capacity, 0, 128, 1.0), 1, 128, 1.0)
    dt = jnp.bfloat16 if phi_dtype == "bfloat16" else jnp.float32
    lam_o, phi_o, g_o, d_o = control_step_dense(
        lp, pp, mp, ep, cp, taup, tot, depth_max=graph.depth_max,
        src=graph.src, k_iters=k_iters, delta=delta, eta_outer=eta_outer,
        eta_inner=eta_inner, cost=cost, phi_dtype=dt, interpret=interpret)
    return lam_o[0, :W], phi_o[:, :N, :N], g_o[0, :W], d_o[0, 0]


@partial(jax.jit, static_argnames=("k_iters", "delta", "eta_outer",
                                   "eta_inner", "cost", "phi_dtype",
                                   "interpret"))
def control_step_sparse_op(lam, rows, src_phi, task_u, lam_total, graph,
                           k_iters, delta, eta_outer, eta_inner, cost,
                           phi_dtype="float32", interpret=True):
    """Padded/sliced one-kernel fused control step, sparse slot layout.

    ``rows``/``src_phi`` are the ``SparsePhi`` parts, ``graph`` a
    ``CECGraphSparse``.  The node axis of *every* operand pads to one
    shared width (``_pad_axis_to`` — ``sink_slot``/``deploy`` natively
    run over ``n_phys``, not ``n_bar``); slot axes pad to 128 multiples
    and slot ids stay valid because the kernel flattens with the padded
    stride (the ``flow_step_sparse`` convention).  The S→D(1) admission
    scatter is pre-built here as a (Ds, Np) 0/1 matrix so the kernel
    scatters by matmul.  Returns (Λ' [W], rows' , src', ĝ [W], D).
    """
    W, N, D = rows.shape
    Ds = src_phi.shape[1]
    Np = _round_up(N)
    lp = _pad_to(lam[None, :], 1, 128)
    taup = _pad_to(task_u[None, :], 1, 128)
    tot = jnp.zeros_like(lp) + lam_total
    rp = _pad_axis_to(_pad_to(rows, 2, 128), 1, Np)
    sp = _pad_to(src_phi, 1, 128)
    omp = _pad_axis_to(_pad_to(graph.out_mask, 2, 128), 1, Np)
    smp = _pad_to(graph.src_out_mask, 1, 128)
    dep = _pad_axis_to(graph.deploy.astype(jnp.float32), 1, Np)
    emp = _pad_axis_to(_pad_to(graph.edge_mask, 1, 128), 0, Np)
    cap = _pad_axis_to(_pad_to(graph.capacity, 1, 128, 1.0), 0, Np, 1.0)
    semp = _pad_to(graph.src_edge_mask[None, :], 1, 128)
    scap = _pad_to(graph.src_capacity[None, :], 1, 128, 1.0)
    nbr = _pad_axis_to(_pad_to(graph.nbr, 1, 128), 0, Np)
    snbr = _pad_to(graph.src_nbr[None, :], 1, 128)
    sink = _pad_axis_to(graph.sink_slot[None, :], 1, Np)
    isrc = _pad_axis_to(_pad_to(graph.in_src, 1, 128), 0, Np)
    islot = _pad_axis_to(_pad_to(graph.in_slot, 1, 128), 0, Np)
    imask = _pad_axis_to(_pad_to(graph.in_mask, 1, 128), 0, Np)
    # matmul scatter: admit (1, Ds) @ smat (Ds, Np) sums λ_w·φ_S·mask onto
    # the fan-out heads — duplicate heads accumulate exactly like .at.add
    smat = jnp.zeros((Ds, Np), jnp.float32).at[
        jnp.arange(Ds), graph.src_nbr].add(1.0)
    smat = _pad_to(smat, 0, 128)
    dt = jnp.bfloat16 if phi_dtype == "bfloat16" else jnp.float32
    lam_o, rows_o, src_o, g_o, d_o = control_step_sparse(
        lp, rp, sp, omp, smp, dep, emp, cap, semp, scap, nbr, snbr, sink,
        isrc, islot, imask, smat, taup, tot, depth_max=graph.depth_max,
        src=graph.src, n_phys=graph.n_phys, k_iters=k_iters, delta=delta,
        eta_outer=eta_outer, eta_inner=eta_inner, cost=cost, phi_dtype=dt,
        interpret=interpret)
    return (lam_o[0, :W], rows_o[:, :N, :D], src_o[:, :Ds], g_o[0, :W],
            d_o[0, 0])


@partial(jax.jit, static_argnames=("interpret",))
def mamba_scan_op(u, dt, A, Bm, Cm, interpret=True):
    """Padded chunkwise SSM scan; pads di→128-multiple, S→chunk multiple."""
    B, S, di = u.shape
    ck = 128 if S >= 128 else S
    up = _pad_to(_pad_to(u, 1, ck), 2, 128)
    dtp = _pad_to(_pad_to(dt, 1, ck), 2, 128)
    Ap = _pad_to(A, 0, 128)
    Bp = _pad_to(Bm, 1, ck)
    Cp = _pad_to(Cm, 1, ck)
    out = mamba_scan(up, dtp, Ap, Bp, Cp, ck=ck, interpret=interpret)
    return out[:, :S, :di]


__all__ = ["control_step_op", "control_step_sparse_op", "flash_attention_op",
           "flow_step_op", "flow_step_sparse_op", "mamba_scan_op",
           "omd_update_op", "omd_update_sparse_op", "ref"]
