"""Pallas segment kernel: one sparse flow-propagation relaxation step.

The edge-list counterpart of ``flow_step``: instead of a [W, N, N] mat-vec,
each output node j accumulates its padded in-edge segment

    t'[w, j] = base[w, j] + Σ_d t[w, in_src[j, d]] · φ[w, in_src[j, d],
                                                       in_slot[j, d]]

— a gather + masked row reduction, O(E) work per step.  ``base`` is the
precomputed constant inflow (exogenous injection + the virtual source's
admission flow, ``core.sparse.source_inflow``); the W virtual-sink entries
are overlaid by the caller from the analytic compute-edge reduction, so no
hub row ever enters the padded in-lists (DESIGN.md §12.1).

Per grid step (one session) the full t row and φ slot table sit in VMEM —
at the design sizes (N ≤ 16k, d_max ≤ 128 post-padding) both fit with room
to spare — and the two gathers are lane gathers from VMEM-resident
operands.  Dispatched by ``core.sparse.propagate`` when
``dispatch.use_kernels(n_bar)`` holds, through ``kernels.ops.
flow_step_sparse_op`` which pads nodes/slots to the 128-lane constraint
asserted below; off-TPU the dispatch passes ``interpret=True`` (the only
mode exercised in CI — on-TPU compilation additionally relies on Mosaic's
dynamic-gather lowering, like every gather-based TPU kernel).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _flow_sparse_kernel(t_ref, rows_ref, base_ref, src_ref, slot_ref,
                        mask_ref, o_ref):
    t = t_ref[0]                                   # [N]
    rows = rows_ref[0]                             # [N, D]
    src = src_ref[...]                             # [N, Din] int32
    eid = src * rows.shape[-1] + slot_ref[...]     # flattened slot id
    vals = jnp.take(t, src) * jnp.take(rows.reshape(-1), eid)
    o_ref[0] = base_ref[0] + (vals * mask_ref[...]).sum(-1)


def flow_step_sparse(t, rows, base, in_src, in_slot, in_mask, *,
                     interpret: bool = False):
    """t, base [W, N]; rows [W, N, D]; in_* [N, Din] → [W, N].

    N multiple of 128; D, Din multiples of 128 (``ops.py`` pads).  Padded
    in-slots carry mask 0 and point at (0, 0); padded rows are all-zero.
    """
    W, N = t.shape
    D, Din = rows.shape[-1], in_src.shape[-1]
    assert N % 128 == 0 and D % 128 == 0 and Din % 128 == 0
    node = pl.BlockSpec((1, N), lambda w: (w, 0))
    inlist = pl.BlockSpec((N, Din), lambda w: (0, 0))
    return pl.pallas_call(
        _flow_sparse_kernel,
        grid=(W,),
        in_specs=[node,
                  pl.BlockSpec((1, N, D), lambda w: (w, 0, 0)),
                  node, inlist, inlist, inlist],
        out_specs=node,
        out_shape=jax.ShapeDtypeStruct((W, N), t.dtype),
        interpret=interpret,
    )(t, rows, base, in_src, in_slot, in_mask)
