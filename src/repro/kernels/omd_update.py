"""Pallas kernel: fused exponentiated-gradient routing update (eq. (22)).

One VMEM pass per row block: mask → shift by row max → exp → row sum →
renormalize.  Fusing the five elementwise/reduction ops avoids four HBM
round-trips of the [W,N,N] routing tensor — the dominant data movement of
a control-plane iteration at fleet scale.

This kernel is live in the solver: ``core.routing.omd_step`` dispatches the
exponentiated-gradient update here when ``dispatch.use_kernels(n_bar)``
holds — threshold cleared (default 256) on TPU, or an explicit override
(see core/dispatch.py) — via ``kernels.ops.omd_update_op`` which zero-pads
both
node axes to the 128-row block constraint asserted below (padded rows have
all-zero mask and fall through to ``phi`` unchanged before being sliced
off).  η is a static kernel parameter — a Python float, baked into the
compiled grid program.  Off-TPU the dispatch passes ``interpret=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _omd_kernel(phi_ref, delta_ref, mask_ref, o_ref, *, eta: float):
    phi = phi_ref[0].astype(jnp.float32)         # [br, N]
    delta = delta_ref[0].astype(jnp.float32)
    mask = mask_ref[0].astype(jnp.float32)
    logits = jnp.where(mask > 0, -eta * delta, NEG)
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    w = phi * jnp.exp(logits) * mask
    s = w.sum(-1, keepdims=True)
    out = jnp.where(s > 0, w / jnp.where(s > 0, s, 1.0), phi)
    o_ref[0] = out.astype(o_ref.dtype)


def omd_update(phi, delta, mask, eta: float, *, br: int = 128,
               interpret: bool = False):
    """phi, delta, mask [W, N, N] → updated phi.  Rows N multiple of br."""
    W, N, _ = phi.shape
    br = min(br, N)
    assert N % br == 0
    spec = pl.BlockSpec((1, br, N), lambda w, i: (w, i, 0))
    return pl.pallas_call(
        functools.partial(_omd_kernel, eta=eta),
        grid=(W, N // br),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(phi.shape, phi.dtype),
        interpret=interpret,
    )(phi, delta, mask)
