"""The solver half of the core: one functional engine (DESIGN.md §13).

Optax-style API over :class:`~repro.core.problem.Problem`:

    state = init(problem, config)                     # SolverState (Λ, φ, t)
    state, info = step(problem, config, state, u)     # one outer iteration
    result = run(problem, config, iters=T)            # scanned, jit-friendly

``step`` is the paper's fused control iteration (GS-OMA Alg. 1; with
``method="single"`` the oracle runs K=1 and the same code *is* OMAD,
Alg. 3): a ``lax.scan`` over the 2W perturbed observations (each one
oracle invocation, ``routing.oracle_observe``), the two-point gradient
estimate, online mirror ascent on the scaled simplex (eq. (10)), the
exact box-simplex projection, and a final observation at the committed
allocation.  This is the **only** implementation of that update in the
repo: ``gs_oma``/``omad``/``solve_jowr`` delegate to :func:`run`, the
batched ensemble solvers ``jax.vmap`` it, ``run_scenario`` threads
:class:`SolverState` across its segments, and the serving ``CECRouter``
holds a ``SolverState`` and calls the jitted :func:`fused_step`.

Task utilities enter ``step`` as a precomputed [2W] vector in the row
order of :func:`perturbed_allocations` — a closed-form bank evaluates
them under vmap inside the jit (what :func:`run` does), a serving fleet
measures them out-of-band and injects the observations (what the router
does); the solver cannot tell the difference.

:class:`SolverConfig` carries every hyperparameter that used to be
re-declared as keyword soup by each entry point.  The two named presets
document a divergence that previously lived as silently drifted
defaults: :func:`paper_defaults` (the offline evaluation setup,
``eta_inner=0.05``) vs :func:`serving_defaults` (the live router,
``eta_inner=3.0`` with K=1 — the aggressive single-step oracle the
serving plane has always run).  ``configs/cec_paper.py`` exposes the
paper §IV scenario as a third preset.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Literal, NamedTuple

import jax
import jax.numpy as jnp

from . import dispatch
from .graph import CECGraphSparse, SparsePhi
from .problem import Problem
from .routing import oracle_observe

Array = jnp.ndarray

Method = Literal["nested", "single"]
METHODS = ("nested", "single")

GradMode = Literal["sampled", "learned"]
GRAD_MODES = ("sampled", "learned")


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """Hyperparameters of the GS-OMA/OMAD engine (hashable, jit-static).

    ``method="single"`` is OMAD: the oracle advances φ exactly one
    mirror-descent step per observation regardless of ``inner_iters``
    (:attr:`oracle_iters` is the resolved count).  ``eta_inner`` must be
    a Python float — it is a static parameter of the Pallas kernel path
    (DESIGN.md §9.2).

    ``grad_mode`` selects the outer gradient estimator (DESIGN.md §16.2):
    ``"sampled"`` is the paper's 2W two-point perturbation sweep (2W+1
    oracle observations per iteration); ``"learned"`` differentiates a
    fitted utility surrogate (``Problem.util_family``/``util_params``, or
    a closed-form ``bank``) through the implicit routing fixed point —
    one analytic gradient evaluation + the committed observation, 2
    oracle calls per iteration.

    ``telemetry`` is the observability ring capacity (DESIGN.md §18):
    0 (default) records nothing; N > 0 makes :func:`step` accept/return a
    ``repro.obs.Telemetry`` ring of N rows and :func:`run`/
    :func:`fused_step` thread it — static, so each capacity compiles its
    own executable (rings never resize in-flight).
    """

    method: Method = "single"
    delta: float = 0.5            # two-point perturbation radius (Alg. 1)
    eta_outer: float = 0.05       # mirror-ascent step on Λ (eq. (10))
    eta_inner: float = 0.05       # OMD-RT step on φ (eq. (22))
    inner_iters: int = 50         # oracle steps per observation (nested)
    grad_mode: GradMode = "sampled"  # outer gradient estimator (§16.2)
    telemetry: int = 0            # obs ring capacity; 0 = recording off (§18)

    def __post_init__(self):
        if self.method not in METHODS:
            raise ValueError(
                f"unknown method {self.method!r}: valid methods are "
                f"{METHODS}")
        if self.grad_mode not in GRAD_MODES:
            raise ValueError(
                f"unknown grad_mode {self.grad_mode!r}: valid modes are "
                f"{GRAD_MODES}")
        if not self.delta > 0:
            raise ValueError(f"delta must be positive, got {self.delta}")
        if self.inner_iters < 1:
            raise ValueError(
                f"inner_iters must be >= 1, got {self.inner_iters}")
        if self.telemetry < 0:
            raise ValueError(
                f"telemetry (ring capacity) must be >= 0, got "
                f"{self.telemetry}")

    @property
    def oracle_iters(self) -> int:
        """Routing steps per observation: 1 for OMAD, else ``inner_iters``."""
        return 1 if self.method == "single" else self.inner_iters

    def replace(self, **kw) -> "SolverConfig":
        return dataclasses.replace(self, **kw)

    @classmethod
    def from_legacy(cls, *, method: str = "nested", delta: float,
                    eta_outer: float, eta_inner: float,
                    inner_iters: int) -> "SolverConfig":
        """A config from the pre-§13 keyword soup (the shims' adapter)."""
        return cls(method=method, delta=float(delta),
                   eta_outer=float(eta_outer), eta_inner=float(eta_inner),
                   inner_iters=int(inner_iters))


def paper_defaults() -> SolverConfig:
    """The published offline defaults (`solve_jowr`/`gs_oma` signatures):
    nested loop, gentle inner step η_inner=0.05, K=50 oracle steps,
    sampled (two-point) gradients — the paper's information structure."""
    return SolverConfig(method="nested", delta=0.5, eta_outer=0.05,
                        eta_inner=0.05, inner_iters=50,
                        grad_mode="sampled")


def serving_defaults() -> SolverConfig:
    """The live control plane's defaults (`CECRouter`): single-loop OMAD
    with the η_inner=3.0 single-step oracle, sampled gradients (a fresh
    router has no fitted surrogate — it migrates to ``grad_mode=
    "learned"`` live once its ``OnlineFitter`` is ready, DESIGN.md
    §16.4).

    The η_inner gap from :func:`paper_defaults` (3.0 vs 0.05) is no
    longer hand-maintained lore: ``core.hypergrad.tune_etas`` meta-tunes
    both step sizes by hypergradient through the implicit routing layer
    (DESIGN.md §16.3) and lands in this regime — a K=1 oracle needs a
    hot inner step to track churn, a nested K=50 oracle wants many small
    steps.  These literals record that operating point; re-derive them
    for a new topology with ``tune_etas(problem, serving_defaults())``.
    """
    return SolverConfig(method="single", delta=0.5, eta_outer=0.05,
                        eta_inner=3.0, inner_iters=1, grad_mode="sampled")


# ---------------------------------------------------------------------------
# state / results
# ---------------------------------------------------------------------------

class SolverState(NamedTuple):
    """The engine's carried iterates — a pytree; stack it to batch."""

    lam: Array                    # [W] allocation Λ^t
    phi: Any                      # [W, Nb, Nb] dense, or a SparsePhi
    t: Array                      # scalar int32 outer-iteration counter


class StepInfo(NamedTuple):
    """Diagnostics of one outer iteration."""

    grad: Array                   # [W] two-point gradient estimate ĝ^t
    cost: Array                   # scalar D(Λ^{t+1}, φ^{t+1})


class Result(NamedTuple):
    """Unified solve record (supersedes ``JOWRResult``/``ControlStep``/
    the router's ad-hoc history dicts; the legacy shims project it back
    onto those shapes)."""

    lam: Array                    # [W] final allocation
    phi: Any                      # [W, Nb, Nb] (or SparsePhi) final routing
    utility_traj: Array           # [T] observed U(Λ^t, φ^t)
    lam_traj: Array               # [T, W]
    cost_traj: Array              # [T] network cost at the committed iterates
    grad_traj: Array              # [T, W] gradient estimates
    state: SolverState            # final state — thread into the next run
    telemetry: Any = None         # obs ring when config.telemetry > 0 (§18)


# ---------------------------------------------------------------------------
# the exact box-simplex projection (Alg. 1 line 9)
# ---------------------------------------------------------------------------

def project_box_simplex(lam: Array, lam_total, delta: float) -> Array:
    """Exact projection onto {δ ≤ λ_w ≤ λ−δ, Σλ_w = λ}.

    Euclidean projection in closed form: x = clip(y − τ*, δ, λ−δ) where τ*
    solves Σ_w x_w(τ) = λ.  The sum is piecewise linear and non-increasing
    in τ with breakpoints {y_w − δ, y_w − (λ−δ)}; sorting the 2W
    breakpoints and interpolating on the bracketing segment gives the exact
    τ* (water-filling on the dual), no iterative tolerance involved.  For
    infeasible targets (λ outside [Wδ, W(λ−δ)]) the clip saturates at the
    nearest box vertex.

    Last-axis semantics so stacked ``[B, W]`` iterates (the scenario
    engine's per-instance rows) project exactly like a single ``[W]``.
    """
    lo, hi = delta, lam_total - delta
    y = jnp.asarray(lam)
    bp = jnp.sort(jnp.concatenate([y - lo, y - hi], axis=-1), -1)  # [..., 2W]
    # Σ clip(y − τ) evaluated at every breakpoint: non-increasing in τ,
    # from W·(λ−δ) at bp[0] down to W·δ at bp[-1].
    s = jnp.clip(y[..., None, :] - bp[..., :, None], lo, hi).sum(-1)
    # bracketing segment: largest k with s_k ≥ λ (linear on [bp_k, bp_k+1])
    k = jnp.clip((s >= lam_total).sum(-1, keepdims=True) - 1,
                 0, bp.shape[-1] - 2)
    t0 = jnp.take_along_axis(bp, k, -1)
    t1 = jnp.take_along_axis(bp, k + 1, -1)
    s0 = jnp.take_along_axis(s, k, -1)
    s1 = jnp.take_along_axis(s, k + 1, -1)
    drop = jnp.where(s0 > s1, s0 - s1, 1.0)
    frac = jnp.where(s0 > s1, (s0 - lam_total) / drop, 0.0)
    tau = t0 + frac * (t1 - t0)
    return jnp.clip(y - tau, lo, hi)


# ---------------------------------------------------------------------------
# perturbation basis — THE observation order
# ---------------------------------------------------------------------------

def _perturbation_basis(W: int) -> tuple[Array, Array]:
    """([2W] signs, [2W, W] directions) shared by
    :func:`perturbed_allocations` (which callers use to evaluate task
    utilities up front) and :func:`step`'s observation scan (which pairs
    those utilities positionally): rows (2w, 2w+1) are (+e_w, −e_w)."""
    signs = jnp.tile(jnp.asarray([1.0, -1.0], jnp.float32), W)
    dirs = jnp.repeat(jnp.eye(W, dtype=jnp.float32), 2, axis=0)
    return signs, dirs


def perturbed_allocations(lam: Array, delta: float) -> Array:
    """[2W, W] admissions of one outer iteration: rows (2w, 2w+1) = Λ ± δ·e_w.

    The row order is the observation order of :func:`step`'s scan (see
    :func:`_perturbation_basis`).  Callers evaluate task utilities over
    these rows up front — under vmap for a closed-form bank, or batched
    through a measured-utility callback for a live fleet (the 2W
    admissions depend only on Λ^t, never on φ).
    """
    signs, dirs = _perturbation_basis(lam.shape[-1])
    return lam + signs[:, None] * delta * dirs


# ---------------------------------------------------------------------------
# init / step / run
# ---------------------------------------------------------------------------

def init(problem: Problem, config: SolverConfig, *,
         phi0=None, lam0: Array | None = None) -> SolverState:
    """Fresh iterates: uniform allocation, uniform routing, t=0.

    ``phi0``/``lam0`` override the warm start.  A dense ``phi0`` handed
    to a sparse-graph problem is re-laid-out onto the edge slots here —
    the one conversion point (callers never juggle representations).
    Λ is seeded strong-float32 so device-resident consumers (the serving
    router) never retrace when the first update replaces a weak-typed
    seed.
    """
    graph = problem.graph
    W = graph.n_sessions
    if lam0 is None:
        lam = jnp.full((W,), problem.lam_total / W, jnp.float32)
    else:
        lam = jnp.asarray(lam0, jnp.float32)
    if phi0 is None:
        phi = graph.uniform_phi()
    elif isinstance(graph, CECGraphSparse) and not isinstance(phi0, SparsePhi):
        from . import sparse as _sparse

        phi = _sparse.phi_to_sparse(graph, phi0)
    else:
        phi = phi0
    return SolverState(lam=lam, phi=phi, t=jnp.int32(0))


def _mirror_ascent(lam: Array, g: Array, lam_total, eta_outer,
                   delta: float) -> Array:
    """Online mirror ascent on the scaled simplex (eq. (10)) + the exact
    box-simplex projection — the one update site both gradient modes and
    the hypergradient rollout share."""
    z = eta_outer * g
    z = z - z.max()
    w = lam * jnp.exp(z)
    lam_new = lam_total * w / w.sum()
    return project_box_simplex(lam_new, lam_total, delta)


def _sampled_step(problem: Problem, config: SolverConfig, state: SolverState,
                  task_utilities: Array, eta_outer,
                  eta_inner) -> tuple[SolverState, StepInfo]:
    """The two-point estimator body (Alg. 1/3): 2W perturbed observations
    scanned with φ carried through, then commit.  η's are explicit so
    :func:`step_with_etas` can trace them (hypergradient rollouts) while
    :func:`step` passes the config's static floats."""
    graph, cost = problem.graph, problem.cost
    lam, phi = state.lam, state.phi
    lam_total = problem.lam_total
    delta = config.delta
    K = config.oracle_iters
    W = graph.n_sessions
    signs, dirs = _perturbation_basis(W)

    def observe(carry, inp):
        g, phi = carry
        sign, ew, task_u = inp
        lam_p = lam + sign * delta * ew
        phi, D = oracle_observe(graph, cost, lam_p, phi, eta_inner, K)
        g = g + sign * ((task_u - D) / (2.0 * delta)) * ew  # Alg. 1 line 6
        return (g, phi), None

    (g, phi), _ = jax.lax.scan(observe, (jnp.zeros(W), phi),
                               (signs, dirs, task_utilities))
    lam_new = _mirror_ascent(lam, g, lam_total, eta_outer, delta)
    phi, D = oracle_observe(graph, cost, lam_new, phi, eta_inner, K)
    return (SolverState(lam=lam_new, phi=phi, t=state.t + 1),
            StepInfo(grad=g, cost=D))


def _megakernel_step(problem: Problem, config: SolverConfig,
                     state: SolverState,
                     task_utilities: Array) -> tuple[SolverState, StepInfo]:
    """The one-kernel fused control step (DESIGN.md §17).

    Semantically :func:`_sampled_step` — same observation order, same
    oracle, same commit — executed as a single Pallas kernel whose
    iterates stay VMEM-resident across all 2W+1 observations
    (``kernels/control_megakernel.py``).  η's and δ are baked as static
    kernel parameters (the config's Python floats), so this path is only
    reachable from :func:`step`, never :func:`step_with_etas`.  The
    ``REPRO_MEGAKERNEL_PHI_DTYPE=bfloat16`` knob narrows the φ *storage*
    to bf16 (accumulation stays f32 — §17.3 bounds the drift).
    """
    from repro.kernels import ops as kops

    graph, cost = problem.graph, problem.cost
    interpret = dispatch.kernel_interpret()
    phi_dtype = dispatch.megakernel_phi_dtype()
    if isinstance(graph, CECGraphSparse):
        lam, rows, src_phi, g, D = kops.control_step_sparse_op(
            state.lam, state.phi.rows, state.phi.src, task_utilities,
            problem.lam_total, graph, config.oracle_iters, config.delta,
            config.eta_outer, config.eta_inner, cost, phi_dtype=phi_dtype,
            interpret=interpret)
        phi = SparsePhi(rows=rows, src=src_phi)
    else:
        lam, phi, g, D = kops.control_step_op(
            state.lam, state.phi, task_utilities, problem.lam_total, graph,
            config.oracle_iters, config.delta, config.eta_outer,
            config.eta_inner, cost, phi_dtype=phi_dtype,
            interpret=interpret)
    return (SolverState(lam=lam, phi=phi, t=state.t + 1),
            StepInfo(grad=g, cost=D))


def _task_value_fn(problem: Problem):
    """λ ↦ Σ_w u_w(λ_w) for the learned gradient: the fitted surrogate
    when one is attached, else the closed-form bank (genie-gradient
    operation — tests/benchmarks), else a loud error."""
    if problem.util_family is not None and problem.util_params is not None:
        from .utility import get_family

        family = get_family(problem.util_family)
        params = problem.util_params
        return lambda lam: family.total(params, lam)
    if problem.bank is not None:
        return lambda lam: problem.bank.per_session(lam).sum()
    raise ValueError(
        "grad_mode='learned' needs task utilities it can differentiate: "
        "attach a fitted surrogate (Problem.with_utilities / "
        "utility.fit_utilities) or a closed-form bank — a measured-utility "
        "problem with neither must run grad_mode='sampled'")


def _learned_step(problem: Problem, config: SolverConfig, state: SolverState,
                  task_utilities: Array) -> tuple[SolverState, StepInfo]:
    """The analytic-gradient body (DESIGN.md §16.2): one ``jax.grad`` of
    U(Λ) = Σ u_w(λ_w) − D(Λ, φ*(Λ)) through the implicit routing fixed
    point (``core.implicit``), then the same mirror-ascent/projection/
    commit as the sampled path.  2 oracle invocations per iteration — the
    gradient's fixed-point solve and the committed observation — versus
    the sampled path's 2W+1.  ``task_utilities`` is unused (the surrogate
    replaces the perturbation sweep); callers pass zeros.
    """
    del task_utilities
    graph, cost = problem.graph, problem.cost
    task_value = _task_value_fn(problem)
    lam, phi = state.lam, state.phi
    eta_inner = config.eta_inner
    K = config.oracle_iters

    # envelope form of the paper's Theorem-1 gradient: at the oracle's
    # fixed point ∂U/∂λ_w = u'_w(λ_w) − ∂D/∂λ_w |_{φ*}; away from it the
    # implicit VJP's linearization at the returned iterate is the K-step
    # approximation (core/implicit.py caveats)
    def net_utility(lam_in):
        phi1, D = oracle_observe(graph, cost, lam_in, phi, eta_inner, K)
        return task_value(lam_in) - D, phi1

    g, phi = jax.grad(net_utility, has_aux=True)(lam)
    lam_new = _mirror_ascent(lam, g, problem.lam_total, config.eta_outer,
                             config.delta)
    phi, D = oracle_observe(graph, cost, lam_new, phi, eta_inner, K)
    return (SolverState(lam=lam_new, phi=phi, t=state.t + 1),
            StepInfo(grad=g, cost=D))


def step(problem: Problem, config: SolverConfig, state: SolverState,
         task_utilities: Array, telemetry=None
         ) -> tuple[SolverState, StepInfo] | tuple:
    """One fused outer iteration of GS-OMA/OMAD on the current iterates.

    ``task_utilities`` is the [2W] vector of *task* utilities Σ_w u_w(λ_w)
    observed for the perturbed admissions of :func:`perturbed_allocations`
    (same row order); the network-cost half of each observation is computed
    here, at the routing iterate the oracle reached for that admission.
    The scan carries φ through all 2W observations (one oracle invocation
    each), takes the mirror-ascent step, projects exactly onto the
    box-simplex, then observes once more at the committed allocation so
    the returned (Λ, φ, cost) are mutually consistent — the paper's
    U(Λ^t, φ^t).  Pure traceable JAX: :func:`run` scans it, the batch
    engine vmaps it, :func:`fused_step` jits it for the serving router.

    With ``config.grad_mode="learned"`` the perturbation sweep is replaced
    by one analytic gradient through the implicit routing layer
    (``task_utilities`` is ignored — pass zeros); the dispatch is static,
    so each mode compiles its own lean program.

    With ``telemetry`` (a ``repro.obs.Telemetry`` ring — only meaningful
    when ``config.telemetry > 0`` sized it) the committed iterates are
    recorded into the ring *inside* the step (pure, donation-friendly,
    DESIGN.md §18.1) and a third return value carries the updated ring.
    """
    graph = problem.graph
    if config.grad_mode == "learned":
        mode, oracle_calls = "learned", 2
        out = _learned_step(problem, config, state, task_utilities)
    else:
        itemsize = 2 if dispatch.megakernel_phi_dtype() == "bfloat16" else 4
        if dispatch.use_megakernel(graph.n_bar, graph.n_sessions, itemsize):
            mode = "megakernel"
            out = _megakernel_step(problem, config, state, task_utilities)
        else:
            mode = "sampled"
            out = _sampled_step(problem, config, state, task_utilities,
                                config.eta_outer, config.eta_inner)
        oracle_calls = 2 * graph.n_sessions + 1
    _trace_dispatch(mode, graph)
    if telemetry is None:
        return out
    from repro.obs import telemetry as _tel

    st, info = out
    tel = _tel.record(telemetry, st, info, lam_total=problem.lam_total,
                      delta=config.delta, oracle_calls=oracle_calls)
    return st, info, tel


def _trace_dispatch(mode: str, graph) -> None:
    """Emit the dispatch decision on the installed obs tracer (no-op
    without one).  Runs at *trace* time — once per compilation, which is
    exactly when the decision is made; jitted steady-state intervals
    never reach here (DESIGN.md §18.3)."""
    from repro.obs import trace as _trace

    if _trace.current_tracer() is not None:
        _trace.instant(
            f"solver.dispatch:{mode}", cat="dispatch",
            args={"mode": mode, "n_bar": int(graph.n_bar),
                  "n_sessions": int(graph.n_sessions),
                  "sparse": isinstance(graph, CECGraphSparse)})


def step_with_etas(problem: Problem, config: SolverConfig,
                   state: SolverState, task_utilities: Array, eta_outer,
                   eta_inner) -> tuple[SolverState, StepInfo]:
    """:func:`step` with *traced* step sizes — the hypergradient surface.

    ``core.hypergrad`` differentiates rollouts of this function w.r.t.
    (η_outer, η_inner); the config's own η fields are ignored.  jnp path
    only: the Pallas kernel path bakes η as a static kernel parameter
    (``float(eta)``), so meta-tuning under kernel dispatch is refused
    loudly rather than failing inside a trace (DESIGN.md §16.3).
    """
    graph = problem.graph
    if (dispatch.use_kernels(graph.n_bar)
            or dispatch.use_megakernel(graph.n_bar, graph.n_sessions)):
        raise NotImplementedError(
            "step_with_etas traces η through the OMD update, but the "
            "Pallas kernel paths (per-phase and megakernel alike) need a "
            "static Python-float η — run hypergradient tuning with kernel "
            "dispatch off (jnp path)")
    return _sampled_step(problem, config, state, task_utilities,
                         eta_outer, eta_inner)


def run(problem: Problem, config: SolverConfig, *, iters: int,
        state: SolverState | None = None,
        phi0=None, lam0: Array | None = None) -> Result:
    """Scan :func:`step` for ``iters`` outer iterations.

    Requires ``problem.bank`` (closed-form task utilities evaluated under
    vmap inside the scan — measured-utility consumers drive :func:`step`
    directly).  With ``state=None`` the representation policy runs once
    (``Problem.canonical``) and iterates come from :func:`init`; a passed
    ``state`` continues exactly where a previous ``run`` stopped
    (``Result.state``), which is how the scenario engine crosses segment
    boundaries.  A dense problem that auto-sparsifies still returns dense
    ``phi``/``state`` — the representation never leaks to the caller.

    With ``config.telemetry > 0`` a fresh obs ring of that capacity is
    threaded through the scan — recorded by ``step``, utility-annotated
    device-side at the committed Λ — and returned on
    ``Result.telemetry`` (DESIGN.md §18.1).
    """
    bank = problem.bank
    has_surrogate = (problem.util_family is not None
                     and problem.util_params is not None)
    if bank is None and not (config.grad_mode == "learned" and has_surrogate):
        raise ValueError(
            "solver.run needs problem.bank for task utilities; "
            "measured-utility consumers (no bank) drive solver.step with "
            "observed [2W] vectors instead (or attach a fitted surrogate "
            "via Problem.with_utilities and run grad_mode='learned')")
    if state is not None and (phi0 is not None or lam0 is not None):
        raise ValueError(
            "pass either state= (continue a previous run) or phi0=/lam0= "
            "(fresh warm-started iterates), not both — to override part of "
            "a carried state, edit it: state._replace(phi=...)")
    dense_in = problem.graph
    if state is None:
        prob = problem.canonical(phi0, lam0).validate()
        st = init(prob, config, phi0=phi0, lam0=lam0)
    else:
        # continuations re-run the representation policy too — a carried
        # dense state must not silently pin a fleet-scale solve to the
        # O(N²) path (the carried φ is re-laid-out onto the edge slots,
        # exactly like a phi0 warm start)
        prob = problem.canonical(state.lam,
                                 *jax.tree_util.tree_leaves(state.phi))
        prob, st = prob.validate(), state
        if (isinstance(prob.graph, CECGraphSparse)
                and not isinstance(st.phi, SparsePhi)):
            from . import sparse as _sparse

            st = st._replace(phi=_sparse.phi_to_sparse(prob.graph, st.phi))
    converted = prob.graph is not dense_in

    W = prob.graph.n_sessions
    # the recorded U_t prices the *true* environment when one is visible
    # (a bank), else the surrogate — both evaluate at the committed Λ
    record_value = (bank.total if bank is not None
                    else _task_value_fn(prob))

    if config.telemetry > 0:
        from repro.obs import telemetry as _obs_tel

        tel0 = _obs_tel.init_ring(config.telemetry, W)
    else:
        _obs_tel, tel0 = None, None

    def outer(carry, _):
        st, tel = carry
        if config.grad_mode == "learned":
            # the surrogate replaces the perturbation sweep — no bank
            # evaluations, and step ignores the zeros
            task_u = jnp.zeros((2 * W,), jnp.float32)
        else:
            task_u = jax.vmap(bank.total)(
                perturbed_allocations(st.lam, config.delta))
        if tel is None:
            st, info = step(prob, config, st, task_u)
        else:
            st, info, tel = step(prob, config, st, task_u, tel)
        # the recorded U_t is the paper's U(Λ^t, φ^t): task utility and
        # network cost both evaluated at the *committed* iterates, not at
        # the last perturbed observation
        U_t = record_value(st.lam) - info.cost
        if tel is not None:
            # the ring's utility column is NaN-seeded by record (a jitted
            # step cannot know the task side); here the bank is visible,
            # so annotate device-side within the same scan iteration
            tel = _obs_tel.annotate(tel, utility=U_t)
        return (st, tel), (U_t, st.lam, info.cost, info.grad)

    (st, tel), (u_traj, lam_traj, cost_traj, grad_traj) = jax.lax.scan(
        outer, (st, tel0), None, length=iters)
    if converted:
        from . import sparse as _sparse

        st = st._replace(phi=_sparse.phi_to_dense(prob.graph, st.phi))
    return Result(lam=st.lam, phi=st.phi, utility_traj=u_traj,
                  lam_traj=lam_traj, cost_traj=cost_traj,
                  grad_traj=grad_traj, state=st, telemetry=tel)


# ---------------------------------------------------------------------------
# the jitted step for device-resident consumers (the serving router)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _fused_step(config: SolverConfig, donate: bool, _dispatch_key):
    if config.telemetry > 0:
        def fn(problem: Problem, state: SolverState, task_utilities: Array,
               telemetry):
            return step(problem, config, state, task_utilities, telemetry)

        # donate the iterates AND the ring: both are replaced wholesale
        # every interval, so XLA reuses their buffers in place and the
        # recording steady state allocates nothing (DESIGN.md §18.1)
        return jax.jit(fn, donate_argnums=(1, 3) if donate else ())

    def fn(problem: Problem, state: SolverState, task_utilities: Array):
        return step(problem, config, state, task_utilities)

    return jax.jit(fn, donate_argnums=(1,) if donate else ())


def fused_step(config: SolverConfig, *, donate: bool = False):
    """``jit(step)`` with ``config`` static, cached on its knobs.

    Returns ``fn(problem, state, task_utilities) -> (SolverState,
    StepInfo)`` — or, with ``config.telemetry > 0``, ``fn(problem,
    state, task_utilities, telemetry) -> (SolverState, StepInfo,
    Telemetry)``: the obs ring rides the jit as a fourth pytree argument
    and is donated alongside the state (DESIGN.md §18.1).
    ``problem`` and ``state`` are pytree arguments, so
    same-shape topology changes (the scenario engine's stable-index
    churn) reuse the compiled executable and demand shifts
    (``problem.lam_total`` — a traced leaf) never retrace.  The cache is
    additionally keyed on ``dispatch.state_key()`` so tracing inside
    ``dispatch.kernel_dispatch``/``sparse_dispatch`` gets a fresh trace
    instead of a stale one (DESIGN.md §11).

    ``donate=True`` donates the ``state`` argument (and only it — the
    problem's graph leaves are shared, the utilities are the caller's) so
    XLA writes the new iterates into the old iterates' buffers: the
    steady-state control loop allocates nothing per interval.  The caller
    gives up the passed state — any view that must survive the step (the
    serving plane's published front buffer, DESIGN.md §15.2) has to be a
    *copy*, never an alias, and backends that decline donation simply
    fall back to allocate-and-swap (detectable via
    ``state.lam.is_deleted()`` — see ``tests/test_fleet.py``).
    """
    return _fused_step(config, bool(donate), dispatch.state_key())
