"""Task utilities u_w(λ_w): hidden closed forms AND learnable families.

Two layers, one information structure (paper §II-B, Assumptions 1–3):

**The hidden environment** — :class:`UtilityBank` / :func:`make_bank`, the
paper's §IV closed forms.  The allocator never sees these; it only
receives scalar observations U(Λ, φ) (bandit feedback):

  linear     u = a·λ
  sqrt       u = a·(√(λ + b) − √b)
  quadratic  u = −a·λ² + b·λ     (params chosen monotone on [0, λ_total])
  log        u = a·log(b·λ + 1)

**The learnable surrogate** — a registry of parametric
:class:`UtilityFamily` models (DESIGN.md §16.2) the controller may *fit*
to its own observations and then differentiate, replacing the
2W-perturbation gradient sweep with one analytic evaluation
(``solver.step``'s ``grad_mode="learned"``).  Every registered family is
monotone increasing and concave **by construction** (positivity via
exp/softplus transforms, curvature via log1p/power/tanh — not by
projection, so no fitted parameter setting can violate Assumptions 1–3):

  log           u = exp(a)·log1p(softplus(b)·λ)
  alpha-fair    u = exp(c)·((λ+ε)^{1−α} − ε^{1−α})/(1−α),  α=σ(r)∈(0,1)
  softplus-mlp  u = Σ_h exp(w_h)/H · tanh(softplus(k_h)·λ)

:func:`fit_utilities` is the regression step (jitted full-batch Adam on
observed (Λ, U_task) pairs); :class:`OnlineFitter` wraps it with the
serving plane's discipline — ring-buffered observations, a deterministic
interleaved holdout, a relative-RMSE readiness threshold and refit
cadence — so a live router can migrate from sampled to learned gradients
only once the surrogate has earned it (DESIGN.md §16.4).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class UtilityBank:
    """Per-session utility parameters; ``total(lams)`` is the black box."""

    a: jax.Array                # [W]
    b: jax.Array                # [W]
    kind: str = dataclasses.field(metadata=dict(static=True))
    noise: float = dataclasses.field(default=0.0, metadata=dict(static=True))

    def per_session(self, lam: Array) -> Array:
        if self.kind == "linear":
            return self.a * lam
        if self.kind == "sqrt":
            return self.a * (jnp.sqrt(lam + self.b) - jnp.sqrt(self.b))
        if self.kind == "quadratic":
            return -self.a * lam * lam + self.b * lam
        if self.kind == "log":
            return self.a * jnp.log(self.b * lam + 1.0)
        raise ValueError(self.kind)

    def total(self, lam: Array, key: jax.Array | None = None) -> Array:
        u = self.per_session(lam).sum()
        if self.noise > 0.0 and key is not None:
            u = u + self.noise * jax.random.normal(key, ())
        return u


def make_bank(kind: str, n_sessions: int, seed: int = 0,
              lam_total: float = 60.0, noise: float = 0.0) -> UtilityBank:
    """Random monotone-on-domain parameters; larger versions earn more."""
    rng = np.random.default_rng(seed)
    base = np.linspace(1.0, 2.0, n_sessions)        # quality ladder
    if kind == "linear":
        a = base * rng.uniform(0.8, 1.2, n_sessions) * 2.0
        b = np.zeros(n_sessions)
    elif kind == "sqrt":
        a = base * rng.uniform(4.0, 6.0, n_sessions)
        b = rng.uniform(0.5, 2.0, n_sessions)
    elif kind == "quadratic":
        # monotone on [0, λ]: b ≥ 2·a·λ
        a = base * rng.uniform(0.01, 0.02, n_sessions)
        b = 2.0 * a * lam_total + rng.uniform(0.5, 1.5, n_sessions)
    elif kind == "log":
        a = base * rng.uniform(15.0, 25.0, n_sessions)
        b = rng.uniform(0.2, 0.5, n_sessions)
    else:
        raise ValueError(kind)
    return UtilityBank(a=jnp.asarray(a, jnp.float32),
                       b=jnp.asarray(b, jnp.float32), kind=kind, noise=noise)


# ---------------------------------------------------------------------------
# parametric utility families (the learnable surrogates, DESIGN.md §16.2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class UtilityFamily:
    """One parametric family: a per-session scalar model u(p, λ).

    ``_u`` maps ([P] raw params, scalar λ) → scalar utility and must be
    monotone increasing + concave in λ for **every** raw parameter value
    (constrained transforms, not clipping — ``tests/test_utility_registry``
    property-checks this over random params).  Registry singletons compare
    by identity (``eq=False``), so families are hashable jit-cache keys.
    """

    name: str
    n_params: int                                   # P — raw params/session
    _u: Callable[[Array, Array], Array]
    _init: Callable[[np.random.Generator, int], np.ndarray]

    def value(self, params: Array, lam: Array) -> Array:
        """[W] per-session utilities from [W, P] raw params and [W] rates."""
        return jax.vmap(self._u)(params, lam)

    def total(self, params: Array, lam: Array) -> Array:
        """Scalar Σ_w u_w(λ_w) — the learned stand-in for ``bank.total``."""
        return self.value(params, lam).sum()

    def grad(self, params: Array, lam: Array) -> Array:
        """[W] analytic marginal utilities u'_w(λ_w) (what the learned
        gradient mode feeds the mirror-ascent step instead of sampling)."""
        return jax.vmap(jax.grad(self._u, argnums=1))(params, lam)

    def init_params(self, n_sessions: int, seed: int = 0) -> Array:
        """[W, P] raw parameters to start fitting from."""
        rng = np.random.default_rng(seed)
        p = np.asarray(self._init(rng, n_sessions), np.float32)
        return jnp.asarray(p.reshape(n_sessions, self.n_params))


def _u_log(p: Array, lam: Array) -> Array:
    # amplitudes live on a log scale (exp) so fitting traverses decades in
    # a few raw units; rates stay softplus — both transforms keep u
    # increasing + concave for every raw value
    a, b = jnp.exp(p[0]), jax.nn.softplus(p[1])
    return a * jnp.log1p(b * lam)


def _u_alpha_fair(p: Array, lam: Array) -> Array:
    # α ∈ (0, 1): strictly concave, and the ε-shift keeps u(0) = 0 with a
    # finite derivative at the origin (the box keeps λ ≥ δ anyway)
    eps = 1e-3
    c, alpha = jnp.exp(p[0]), jax.nn.sigmoid(p[1])
    return c * ((lam + eps) ** (1.0 - alpha) - eps ** (1.0 - alpha)) \
        / (1.0 - alpha)


_MLP_H = 4


def _u_softplus_mlp(p: Array, lam: Array) -> Array:
    # positive combination of saturating concave ramps: each tanh(k·λ) is
    # increasing + concave on λ ≥ 0, exp/softplus keep every weight ≥ 0
    w = jnp.exp(p[:_MLP_H]) / _MLP_H
    k = jax.nn.softplus(p[_MLP_H:])
    return jnp.sum(w * jnp.tanh(k * lam))


FAMILIES: dict[str, UtilityFamily] = {}


def register_family(family: UtilityFamily) -> UtilityFamily:
    """Add a family to the registry (open for extension, like costs).

    Names are unique: re-registering an existing name raises — a silent
    overwrite would swap the semantics under every ``Problem`` whose
    ``util_family`` string already points at it.
    """
    if family.name in FAMILIES:
        raise ValueError(f"utility family {family.name!r} is already "
                         f"registered")
    FAMILIES[family.name] = family
    return family


register_family(UtilityFamily(
    name="log", n_params=2, _u=_u_log,
    _init=lambda rng, W: np.stack(
        [rng.uniform(0.0, 1.5, W), rng.uniform(-1.0, 0.0, W)], -1)))
register_family(UtilityFamily(
    name="alpha-fair", n_params=2, _u=_u_alpha_fair,
    _init=lambda rng, W: np.stack(
        [rng.uniform(0.0, 1.0, W), rng.normal(0.0, 0.5, W)], -1)))
register_family(UtilityFamily(
    name="softplus-mlp", n_params=2 * _MLP_H, _u=_u_softplus_mlp,
    _init=lambda rng, W: np.concatenate(
        [rng.uniform(0.0, 1.0, (W, _MLP_H)),
         rng.uniform(-1.5, 0.0, (W, _MLP_H))], -1)))


def get_family(name: str | UtilityFamily) -> UtilityFamily:
    """A :class:`UtilityFamily` from its registry name (or pass through).

    Unknown names raise a ``KeyError`` that lists what *is* registered —
    same contract as ``costs.get`` / ``resolve_cost``: an "alpha_fair" vs
    "alpha-fair" typo must not surface as a bare KeyError.
    """
    if isinstance(name, UtilityFamily):
        return name
    try:
        return FAMILIES[name]
    except KeyError:
        raise KeyError(
            f"unknown utility family {name!r}: registered families are "
            f"{sorted(FAMILIES)}") from None


# ---------------------------------------------------------------------------
# online regression: fit a family to observed (Λ, task-utility) pairs
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _fit_program(family: UtilityFamily, steps: int, lr: float):
    """Jitted full-batch Adam on the family's total-utility MSE."""

    def loss_fn(p, lams, us):
        pred = jax.vmap(lambda l: family.total(p, l))(lams)
        return jnp.mean((pred - us) ** 2)

    def fit(p, lams, us):
        b1, b2, eps = 0.9, 0.999, 1e-8
        m0 = jnp.zeros_like(p)

        def one(carry, i):
            p, m, v = carry
            g = jax.grad(loss_fn)(p, lams, us)
            m = b1 * m + (1.0 - b1) * g
            v = b2 * v + (1.0 - b2) * g * g
            t = i + 1.0
            mh = m / (1.0 - b1 ** t)
            vh = v / (1.0 - b2 ** t)
            # exponential decay to lr/100: the big early steps cross the
            # raw-parameter scale gap (softplus⁻¹ of bank-sized a's), the
            # late small ones polish to near-exact recovery
            lr_t = lr * (0.01 ** (i / steps))
            p = p - lr_t * mh / (jnp.sqrt(vh) + eps)
            return (p, m, v), None

        (p, _, _), _ = jax.lax.scan(one, (p, m0, m0),
                                    jnp.arange(steps, dtype=p.dtype))
        return p, loss_fn(p, lams, us)

    return jax.jit(fit)


def fit_utilities(family: str | UtilityFamily, params: Array, lams: Array,
                  utils: Array, *, steps: int = 400,
                  lr: float = 0.1) -> tuple[Array, Array]:
    """One regression step: fit ``params`` to observed (Λ, U_task) pairs.

    ``lams`` is [B, W] admitted allocations, ``utils`` [B] their measured
    *task* utilities Σ_w u_w(λ_w) (network cost excluded — the controller
    prices that itself).  Returns (fitted [W, P] params, final MSE).
    Warm-starts from the passed ``params``, so repeated online calls
    refine rather than restart; the compiled program is cached per
    (family, steps, lr).
    """
    family = get_family(family)
    params = jnp.asarray(params, jnp.float32)
    lams = jnp.asarray(lams, jnp.float32)
    utils = jnp.asarray(utils, jnp.float32).reshape(-1)
    if lams.ndim != 2 or lams.shape[0] != utils.shape[0] \
            or lams.shape[1] != params.shape[0]:
        raise ValueError(
            f"need lams [B, W={params.shape[0]}] and utils [B]; got "
            f"{lams.shape} vs {utils.shape}")
    return _fit_program(family, int(steps), float(lr))(params, lams, utils)


class OnlineFitter:
    """Accumulate live (Λ, û) pairs and decide when "learned" is earned.

    The serving plane's fitting discipline (DESIGN.md §16.4): a ring
    buffer of the most recent ``capacity`` observations, every
    ``holdout_every``-th observation held out of the fit (deterministic
    interleaving — no RNG in the control path), a refit every
    ``refit_every`` new observations, and :attr:`ready` only once the
    held-out relative RMSE clears ``threshold``.  :meth:`drifted` is the
    fallback signal: an EMA of the live prediction error that tells a
    router running learned gradients that the environment moved from
    under its surrogate (bank swap, goodput shift) and it should drop
    back to sampling until re-fit.
    """

    def __init__(self, family: str | UtilityFamily, n_sessions: int, *,
                 capacity: int = 512, holdout_every: int = 4,
                 threshold: float = 0.05, min_samples: int = 24,
                 refit_every: int = 16, fit_steps: int = 400,
                 lr: float = 0.1, drift_ema: float = 0.2,
                 drift_threshold: float | None = None, seed: int = 0):
        self.family = get_family(family)
        self.n_sessions = int(n_sessions)
        self.capacity = int(capacity)
        self.holdout_every = int(holdout_every)
        self.threshold = float(threshold)
        self.min_samples = int(min_samples)
        self.refit_every = int(refit_every)
        self.fit_steps = int(fit_steps)
        self.lr = float(lr)
        self.drift_ema = float(drift_ema)
        self.drift_threshold = float(
            2.0 * threshold if drift_threshold is None else drift_threshold)
        self.params = self.family.init_params(n_sessions, seed)
        self._lams = np.zeros((self.capacity, self.n_sessions), np.float32)
        self._utils = np.zeros(self.capacity, np.float32)
        self.n_seen = 0                 # monotone — drives ring + holdout
        self._since_fit = 0
        self.n_fits = 0
        self.holdout_error = float("inf")   # relative RMSE on held-out rows
        self.drift = 0.0                    # EMA of live relative error

    # -- data path -----------------------------------------------------------
    def add(self, lams, utils) -> None:
        """Record observations: ([W], scalar) or stacked ([B, W], [B])."""
        lams = np.atleast_2d(np.asarray(lams, np.float32))
        utils = np.asarray(utils, np.float32).reshape(-1)
        if lams.shape != (utils.shape[0], self.n_sessions):
            raise ValueError(
                f"need lams [B, {self.n_sessions}] and utils [B]; got "
                f"{lams.shape} vs {utils.shape}")
        for row, u in zip(lams, utils):
            slot = self.n_seen % self.capacity
            self._lams[slot] = row
            self._utils[slot] = u
            self.n_seen += 1
            self._since_fit += 1

    def _split(self):
        n = min(self.n_seen, self.capacity)
        lams, utils = self._lams[:n], self._utils[:n]
        # deterministic interleaved holdout on the *global* observation
        # index, so a row keeps its role for as long as it lives in the ring
        start = self.n_seen - n
        idx = (np.arange(start, self.n_seen)) % self.holdout_every == 0
        return (lams[~idx], utils[~idx]), (lams[idx], utils[idx])

    # -- fitting -------------------------------------------------------------
    def fit(self) -> float:
        """Refit on the buffered train split; returns the holdout error."""
        (tl, tu), (hl, hu) = self._split()
        if len(tu) == 0:
            return self.holdout_error
        self.params, _ = fit_utilities(self.family, self.params, tl, tu,
                                       steps=self.fit_steps, lr=self.lr)
        self.n_fits += 1
        self._since_fit = 0
        if len(hu):
            pred = np.asarray(jax.vmap(
                lambda l: self.family.total(self.params, l))(
                    jnp.asarray(hl)))
            scale = max(float(np.abs(hu).mean()), 1e-6)
            self.holdout_error = float(
                np.sqrt(np.mean((pred - hu) ** 2)) / scale)
        self.drift = 0.0        # fresh fit, fresh drift evidence
        return self.holdout_error

    def maybe_fit(self) -> bool:
        """Refit if enough new data has arrived; returns True when it did."""
        if self.n_seen < self.min_samples:
            return False
        if self.n_fits > 0 and self._since_fit < self.refit_every:
            return False
        self.fit()
        return True

    # -- readiness / fallback ------------------------------------------------
    @property
    def ready(self) -> bool:
        """Held-out relative RMSE cleared the threshold — learned gradients
        are admissible (``grad_mode="learned"`` may engage)."""
        return self.holdout_error <= self.threshold

    def predict(self, lam) -> float:
        """Fitted Σ_w u_w(λ_w) at one [W] allocation."""
        return float(self.family.total(self.params,
                                       jnp.asarray(lam, jnp.float32)))

    def observe_live(self, lam, util) -> None:
        """Record a committed observation AND fold its prediction error
        into the drift EMA (the learned-mode fallback signal)."""
        err = abs(self.predict(lam) - float(util)) \
            / max(abs(float(util)), 1e-6)
        self.drift += self.drift_ema * (err - self.drift)
        self.add(lam, util)

    def drifted(self) -> bool:
        """The environment moved from under the surrogate — fall back to
        sampled gradients until the next successful refit."""
        return self.drift > self.drift_threshold
