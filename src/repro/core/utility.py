"""Unknown task-utility functions u_w(λ_w) (paper §II-B, Assumptions 1–3).

The allocator never sees these closed forms — it only receives scalar
observations U(Λ, φ) (bandit feedback), exactly the paper's information
structure.  The four families match the paper's §IV evaluation:

  linear     u = a·λ
  sqrt       u = a·(√(λ + b) − √b)
  quadratic  u = −a·λ² + b·λ     (params chosen monotone on [0, λ_total])
  log        u = a·log(b·λ + 1)

All are monotone increasing, concave, Lipschitz and bounded on the domain.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class UtilityBank:
    """Per-session utility parameters; ``total(lams)`` is the black box."""

    a: jax.Array                # [W]
    b: jax.Array                # [W]
    kind: str = dataclasses.field(metadata=dict(static=True))
    noise: float = dataclasses.field(default=0.0, metadata=dict(static=True))

    def per_session(self, lam: Array) -> Array:
        if self.kind == "linear":
            return self.a * lam
        if self.kind == "sqrt":
            return self.a * (jnp.sqrt(lam + self.b) - jnp.sqrt(self.b))
        if self.kind == "quadratic":
            return -self.a * lam * lam + self.b * lam
        if self.kind == "log":
            return self.a * jnp.log(self.b * lam + 1.0)
        raise ValueError(self.kind)

    def total(self, lam: Array, key: jax.Array | None = None) -> Array:
        u = self.per_session(lam).sum()
        if self.noise > 0.0 and key is not None:
            u = u + self.noise * jax.random.normal(key, ())
        return u


def make_bank(kind: str, n_sessions: int, seed: int = 0,
              lam_total: float = 60.0, noise: float = 0.0) -> UtilityBank:
    """Random monotone-on-domain parameters; larger versions earn more."""
    rng = np.random.default_rng(seed)
    base = np.linspace(1.0, 2.0, n_sessions)        # quality ladder
    if kind == "linear":
        a = base * rng.uniform(0.8, 1.2, n_sessions) * 2.0
        b = np.zeros(n_sessions)
    elif kind == "sqrt":
        a = base * rng.uniform(4.0, 6.0, n_sessions)
        b = rng.uniform(0.5, 2.0, n_sessions)
    elif kind == "quadratic":
        # monotone on [0, λ]: b ≥ 2·a·λ
        a = base * rng.uniform(0.01, 0.02, n_sessions)
        b = 2.0 * a * lam_total + rng.uniform(0.5, 1.5, n_sessions)
    elif kind == "log":
        a = base * rng.uniform(15.0, 25.0, n_sessions)
        b = rng.uniform(0.2, 0.5, n_sessions)
    else:
        raise ValueError(kind)
    return UtilityBank(a=jnp.asarray(a, jnp.float32),
                       b=jnp.asarray(b, jnp.float32), kind=kind, noise=noise)
