"""Core CEC control plane: the paper's JOWR contribution in JAX.

One solver core (DESIGN.md §13): describe the instance as a
:class:`Problem` (``core/problem.py``), pick a :class:`SolverConfig`
(``core/solver.py`` — or a named preset: ``paper_defaults``,
``serving_defaults``, ``repro.configs.cec_paper.solver_config``), then
``init``/``step``/``run``.  Everything else exported here —
``solve_jowr``, ``gs_oma``/``omad``, the batched ensemble solvers,
``run_scenario``, the serving router — is a shim or consumer of that
engine.
"""
from . import dispatch, solver
from .allocation import (ControlStep, JOWRResult, allocation_kkt_residual,
                         control_step, exact_allocation_gradient,
                         fused_control_step, gs_oma, perturbed_allocations)
from .batch import (CECGraphBatch, CECGraphSparseBatch, pad_graph,
                    pad_sparse_graph, run_batch, run_batch_sharded,
                    solve_jowr_batch, solve_routing_batch, stack_banks)
from .costs import CostFn, get as get_cost
from .flow import cost_and_state, link_flows, propagate, total_cost
from .graph import (CECGraph, CECGraphSparse, InfeasibleTopology,
                    InstanceDraw, SparsePhi, build_augmented,
                    build_augmented_sparse, build_random_cec, draw_instance,
                    sparsify)
from .hypergrad import TuneResult, rollout_objective, tune_etas
from .implicit import fixed_point_solve
from .jowr import solve_jowr
from .marginal import marginals, phi_gradient
from .opt_baseline import exact_gradient_allocation, frank_wolfe_routing
from .problem import Problem, resolve_cost
from .solver import (Result, SolverConfig, SolverState, StepInfo, fused_step,
                     init, paper_defaults, project_box_simplex, run,
                     serving_defaults, step)
from .routing import (RoutingState, kkt_residual, omd_step, oracle_observe,
                      project_simplex_masked, sgp_step, solve_routing,
                      solve_routing_implicit, solve_routing_sgp,
                      warm_start_phi)
from .scenario import (BankSwap, CapacityScale, DemandShift, Event, NodeFail,
                       NodeJoin, Rewire, Scenario, ScenarioResult,
                       ScenarioState, apply_event, compile_segments,
                       event_schedule, initial_state, named_scenarios,
                       run_scenario, scenario_metrics, segment_optima)
from .single_loop import omad
from .utility import (OnlineFitter, UtilityBank, UtilityFamily, fit_utilities,
                      get_family, make_bank, register_family)

__all__ = [
    # the solver core (DESIGN.md §13)
    "Problem", "SolverConfig", "SolverState", "StepInfo", "Result",
    "init", "step", "run", "fused_step", "run_batch", "run_batch_sharded",
    "paper_defaults", "serving_defaults", "project_box_simplex",
    "resolve_cost", "solver",
    # legacy shims + everything they ride on
    "ControlStep", "JOWRResult", "allocation_kkt_residual", "control_step",
    "fused_control_step", "gs_oma", "oracle_observe",
    "perturbed_allocations", "CostFn", "get_cost",
    "cost_and_state", "link_flows", "propagate", "total_cost", "CECGraph",
    "InfeasibleTopology", "InstanceDraw", "build_augmented",
    "build_random_cec", "draw_instance", "solve_jowr",
    "marginals", "phi_gradient", "exact_gradient_allocation",
    "frank_wolfe_routing", "RoutingState", "kkt_residual", "omd_step",
    "project_simplex_masked", "sgp_step", "solve_routing",
    "solve_routing_sgp", "warm_start_phi", "omad", "UtilityBank", "make_bank",
    # differentiable solver core (DESIGN.md §16)
    "fixed_point_solve", "solve_routing_implicit",
    "UtilityFamily", "get_family", "register_family", "fit_utilities",
    "OnlineFitter", "exact_allocation_gradient",
    "TuneResult", "rollout_objective", "tune_etas",
    "CECGraphBatch", "pad_graph", "solve_jowr_batch", "solve_routing_batch",
    "stack_banks", "dispatch",
    "CECGraphSparse", "CECGraphSparseBatch", "SparsePhi",
    "build_augmented_sparse", "pad_sparse_graph", "sparsify",
    "Event", "Rewire", "NodeFail", "NodeJoin", "CapacityScale", "BankSwap",
    "DemandShift", "Scenario", "ScenarioState", "ScenarioResult",
    "apply_event", "initial_state", "compile_segments", "event_schedule",
    "run_scenario", "scenario_metrics", "segment_optima", "named_scenarios",
]
