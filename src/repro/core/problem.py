"""The problem half of the solver core: what is being optimized (DESIGN.md §13).

A :class:`Problem` is the immutable description of one JOWR instance —
the augmented graph (dense ``CECGraph`` or edge-list ``CECGraphSparse``),
the (possibly hidden) task-utility bank, the link-cost model and the
total admitted demand λ.  Every entry point in this repo — ``solve_jowr``
/ ``gs_oma`` / ``omad``, the batched ensemble solvers, ``run_scenario``'s
segments and the serving ``CECRouter`` — builds a ``Problem`` and hands
it to the one functional engine in ``core/solver.py``
(``init``/``step``/``run``); there is no second place where "what the
solver optimizes" is declared.

Design points:

* **Pytree**: ``graph``/``bank``/``lam_total`` are leaves, so a
  ``Problem`` passes through ``jax.jit``/``jax.vmap`` directly — the
  scenario engine re-traces nothing on demand shifts (``lam_total`` is a
  traced scalar, never a closure constant) and the batched engine vmaps
  one ``Problem`` whose leaves carry the instance axis.
* **Cost is static**: a :class:`CostFn` is a registry singleton of
  Python callables — part of the trace, not the data.  Build from a name
  via :func:`resolve_cost`, which raises listing the registry on a typo.
* **Representation handled once**: :meth:`Problem.canonical` applies the
  ``dispatch.maybe_sparsify`` (N, density) policy, so the dense↔sparse
  decision lives here instead of being re-implemented by each entry
  point (as ``gs_oma`` and ``CECRouter.__post_init__`` once did).
* **Fail fast**: :meth:`Problem.validate` checks the cross-field
  invariants (session counts, demand positivity) at construction time —
  shape errors surface with a message, not as a trace-time explosion.
"""
from __future__ import annotations

import dataclasses
import functools

import jax

from . import costs as _costs
from . import dispatch
from .costs import CostFn
from .graph import CECGraph, CECGraphSparse
from .utility import UtilityBank


def resolve_cost(cost: CostFn | str) -> CostFn:
    """A :class:`CostFn` from a registry name (or pass one through).

    Unknown names raise a ``KeyError`` that lists what *is* registered —
    ``costs.REGISTRY`` is open for extension, and "exp" vs "expo" typos
    should not surface as a bare KeyError with no context.
    """
    if isinstance(cost, CostFn):
        return cost
    return _costs.get(cost)   # raises listing the registry on a typo


# fields passed explicitly: the metadata-inferring decorator form needs
# jax >= 0.4.36, and the CI matrix keeps a 0.4.30 leg
@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("graph", "bank", "lam_total", "util_params"),
                   meta_fields=("cost", "util_family"))
@dataclasses.dataclass(frozen=True)
class Problem:
    """One JOWR instance: graph + utility bank + cost model + demand.

    ``bank`` may be ``None`` for measured-utility operation (the serving
    router observes task utilities out-of-band and injects them into
    ``solver.step``); ``solver.run`` requires a bank — it has nobody else
    to ask.  ``lam_total`` is a pytree *leaf* (python float or jnp
    scalar) so jitted consumers treat demand as data.

    ``util_family``/``util_params`` carry a *fitted* parametric utility
    surrogate (``utility.get_family`` / ``fit_utilities``, DESIGN.md
    §16.2): the family name is static metadata, the [W, P] raw params are
    a data leaf — so a serving router swapping in freshly fitted params
    every few intervals never retraces, exactly like a demand shift.
    ``solver.step`` with ``grad_mode="learned"`` differentiates this
    surrogate (falling back to ``bank`` when no surrogate is attached).
    """

    graph: CECGraph | CECGraphSparse
    bank: UtilityBank | None = None
    lam_total: jax.Array | float = 0.0
    cost: CostFn = dataclasses.field(
        default=_costs.EXP, metadata=dict(static=True))
    util_params: jax.Array | None = None
    util_family: str | None = dataclasses.field(
        default=None, metadata=dict(static=True))

    @classmethod
    def create(cls, graph, bank=None, *, lam_total, cost="exp",
               util_params=None, util_family=None) -> "Problem":
        """Validated constructor; ``cost`` may be a registry name."""
        return cls(graph=graph, bank=bank, lam_total=lam_total,
                   cost=resolve_cost(cost), util_params=util_params,
                   util_family=util_family).validate()

    # -- invariants ----------------------------------------------------------
    def validate(self) -> "Problem":
        """Check cross-field invariants; returns ``self`` for chaining.

        Only Python-level (static) facts are checked — the method is safe
        to call on tracer-carrying problems inside jit/vmap.
        """
        if not isinstance(self.graph, (CECGraph, CECGraphSparse)):
            raise TypeError(
                f"Problem.graph must be a CECGraph or CECGraphSparse, got "
                f"{type(self.graph).__name__}")
        if not isinstance(self.cost, CostFn):
            raise TypeError(
                f"Problem.cost must be a CostFn (see costs.REGISTRY), got "
                f"{type(self.cost).__name__}")
        W = self.graph.n_sessions
        if self.bank is not None and self.bank.a.shape[-1] != W:
            raise ValueError(
                f"utility bank is for {self.bank.a.shape[-1]} sessions but "
                f"the graph serves W={W}")
        if self.util_family is not None:
            from .utility import get_family

            family = get_family(self.util_family)   # raises listing registry
            if (self.util_params is not None
                    and hasattr(self.util_params, "shape")
                    and self.util_params.shape[-2:] != (W, family.n_params)):
                raise ValueError(
                    f"util_params for family {family.name!r} must be "
                    f"[W={W}, P={family.n_params}], got "
                    f"{self.util_params.shape}")
        if not isinstance(self.lam_total, jax.core.Tracer):
            import numpy as np

            lt = np.asarray(self.lam_total)
            if lt.ndim == 0 and not lt > 0:
                raise ValueError(f"lam_total must be positive, got {lt}")
        return self

    # -- representation ------------------------------------------------------
    def canonical(self, *companions) -> "Problem":
        """Apply the dense↔sparse representation policy exactly once.

        Returns ``self`` unchanged below the ``dispatch.use_sparse``
        threshold, under jit (tracer leaves), or when any ``companion``
        array (a caller's φ⁰ that would need re-layout) is a tracer;
        otherwise returns a new ``Problem`` on the ``CECGraphSparse``
        edge-list representation.  This is the single conversion point
        all entry points share.
        """
        graph = dispatch.maybe_sparsify(self.graph, *companions)
        if graph is self.graph:
            return self
        return dataclasses.replace(self, graph=graph)

    # -- conveniences --------------------------------------------------------
    @property
    def n_sessions(self) -> int:
        return self.graph.n_sessions

    def with_demand(self, lam_total) -> "Problem":
        """Same instance under a new total demand (a leaf — no retrace)."""
        return dataclasses.replace(self, lam_total=lam_total)

    def with_utilities(self, family: str, params) -> "Problem":
        """Attach (or refresh) a fitted utility surrogate.

        ``params`` is a data leaf: refitting and re-attaching every few
        intervals reuses the compiled step — only a *family* change (new
        static metadata) retraces.
        """
        from .utility import get_family

        return dataclasses.replace(
            self, util_family=get_family(family).name,
            util_params=jax.numpy.asarray(params, jax.numpy.float32)
            if not isinstance(params, jax.core.Tracer) else params).validate()
