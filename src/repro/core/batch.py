"""Batched multi-instance JOWR: solve an ensemble in one XLA program.

The paper's evaluation (§IV, Figs. 7–11, Table II) reports every curve as an
average over many random instance draws.  Solving those draws one at a time
from Python wastes the fact that ``gs_oma``/``omad`` are pure scanned JAX:
``CECGraphBatch`` stacks B augmented graphs into one pytree (padding draws
of different physical size to a common augmented size, DESIGN.md §9.1) and
``solve_jowr_batch`` / ``solve_routing_batch`` ``jax.vmap`` the existing
scan over the instance axis, returning stacked results.

Padding is exact, not approximate: pad nodes get no edges (all-zero masks),
unit capacity on masked-out links (the ``CECGraph`` convention for unused
entries), and the shared ``depth_max`` is the batch maximum — extra Jacobi
relaxation steps past an instance's own longest path are no-ops at the flow
fixed point, so a padded instance reproduces its standalone trajectory.
Virtual nodes are re-indexed so that ``src``/``sinks`` land at the same
(static) positions for every instance; all instances must share the session
count W.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import costs as _costs
from .allocation import JOWRResult
from .graph import CECGraph
from .jowr import Method, solve_jowr
from .routing import solve_routing, solve_routing_sgp
from .utility import UtilityBank

Array = jnp.ndarray


def pad_graph(graph: CECGraph, n_phys: int,
              depth_max: int | None = None) -> CECGraph:
    """Embed ``graph`` into an augmented graph with ``n_phys`` physical nodes.

    Physical nodes keep their indices; pad nodes ``[graph.n_phys, n_phys)``
    are isolated (no allowed out-edges, never deployed); the virtual source
    and sinks are relocated to the tail positions ``n_phys`` and
    ``n_phys + 1 + w``.  The padded instance is solve-equivalent to the
    original (see module docstring).
    """
    if n_phys < graph.n_phys:
        raise ValueError(f"cannot shrink graph: {graph.n_phys} -> {n_phys}")
    depth_max = graph.depth_max if depth_max is None else depth_max
    if depth_max < graph.depth_max:
        raise ValueError("depth_max must not decrease")
    if n_phys == graph.n_phys and depth_max == graph.depth_max:
        return graph

    W = graph.n_sessions
    n_bar = n_phys + 1 + W
    # old augmented index -> new augmented index
    idx = np.concatenate([np.arange(graph.n_phys), [n_phys],
                          n_phys + 1 + np.arange(W)])

    out_mask = np.zeros((W, n_bar, n_bar), np.float32)
    edge_mask = np.zeros((n_bar, n_bar), np.float32)
    capacity = np.ones((n_bar, n_bar), np.float32)
    for w in range(W):
        out_mask[w][np.ix_(idx, idx)] = np.asarray(graph.out_mask[w])
    edge_mask[np.ix_(idx, idx)] = np.asarray(graph.edge_mask)
    capacity[np.ix_(idx, idx)] = np.asarray(graph.capacity)

    deploy = np.zeros((W, n_phys), bool)
    deploy[:, : graph.n_phys] = np.asarray(graph.deploy)

    return CECGraph(
        out_mask=jnp.asarray(out_mask),
        edge_mask=jnp.asarray(edge_mask),
        capacity=jnp.asarray(capacity),
        deploy=jnp.asarray(deploy),
        sinks=jnp.asarray(n_phys + 1 + np.arange(W)),
        n_phys=n_phys,
        n_sessions=W,
        n_bar=n_bar,
        depth_max=depth_max,
        src=n_phys,
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CECGraphBatch:
    """B CEC instances stacked on a leading axis, sharing static metadata.

    Built with :meth:`from_graphs`; consumed by ``solve_jowr_batch`` and
    ``solve_routing_batch`` which vmap the per-instance solvers over axis 0.
    """

    # --- data (pytree leaves, leading axis = instance) ---
    out_mask: jax.Array      # [B, W, Nb, Nb]
    edge_mask: jax.Array     # [B, Nb, Nb]
    capacity: jax.Array      # [B, Nb, Nb]
    deploy: jax.Array        # [B, W, N]
    sinks: jax.Array         # [B, W]
    # --- static metadata (shared across instances) ---
    n_instances: int = dataclasses.field(metadata=dict(static=True))
    n_phys: int = dataclasses.field(metadata=dict(static=True))
    n_sessions: int = dataclasses.field(metadata=dict(static=True))
    n_bar: int = dataclasses.field(metadata=dict(static=True))
    depth_max: int = dataclasses.field(metadata=dict(static=True))
    src: int = dataclasses.field(metadata=dict(static=True))

    @classmethod
    def from_graphs(cls, graphs: Sequence[CECGraph]) -> "CECGraphBatch":
        """Stack instances, padding to the common augmented size."""
        if not graphs:
            raise ValueError("need at least one graph")
        W = graphs[0].n_sessions
        if any(g.n_sessions != W for g in graphs):
            raise ValueError("all instances must share the session count W")
        n_phys = max(g.n_phys for g in graphs)
        depth_max = max(g.depth_max for g in graphs)
        padded = [pad_graph(g, n_phys, depth_max) for g in graphs]
        stack = lambda name: jnp.stack([getattr(g, name) for g in padded])
        return cls(
            out_mask=stack("out_mask"),
            edge_mask=stack("edge_mask"),
            capacity=stack("capacity"),
            deploy=stack("deploy"),
            sinks=stack("sinks"),
            n_instances=len(padded),
            n_phys=n_phys,
            n_sessions=W,
            n_bar=padded[0].n_bar,
            depth_max=depth_max,
            src=padded[0].src,
        )

    def _graph(self, leaves) -> CECGraph:
        return CECGraph(*leaves, n_phys=self.n_phys,
                        n_sessions=self.n_sessions, n_bar=self.n_bar,
                        depth_max=self.depth_max, src=self.src)

    def stacked_graph(self) -> CECGraph:
        """A ``CECGraph`` view whose leaves carry the instance axis.

        Static metadata is shared, so ``jax.vmap(fn)(batch.stacked_graph())``
        maps ``fn`` over instances with zero data movement.
        """
        return self._graph((self.out_mask, self.edge_mask, self.capacity,
                            self.deploy, self.sinks))

    def instance(self, b: int) -> CECGraph:
        """Materialize instance ``b`` as a standalone ``CECGraph``."""
        return self._graph((self.out_mask[b], self.edge_mask[b],
                            self.capacity[b], self.deploy[b], self.sinks[b]))

    def uniform_phi(self) -> jax.Array:
        """[B, W, Nb, Nb] uniform routing per instance."""
        return self.stacked_graph().uniform_phi()


def stack_banks(banks: Sequence[UtilityBank]) -> UtilityBank:
    """Stack per-instance utility banks (same family/noise) along axis 0."""
    kind, noise = banks[0].kind, banks[0].noise
    if any(b.kind != kind or b.noise != noise for b in banks):
        raise ValueError("all banks must share kind and noise level")
    return UtilityBank(a=jnp.stack([b.a for b in banks]),
                       b=jnp.stack([b.b for b in banks]),
                       kind=kind, noise=noise)


def _bank_axis(bank: UtilityBank):
    """0 when the bank carries an instance axis, None to broadcast one."""
    return 0 if bank.a.ndim == 2 else None


def solve_jowr_batch(
    batch: CECGraphBatch,
    banks: UtilityBank | Sequence[UtilityBank],
    lam_total: float,
    *,
    method: Method = "single",
    cost_name: str = "exp",
    delta: float = 0.5,
    eta_outer: float = 0.05,
    eta_inner: float = 0.05,
    outer_iters: int = 100,
    inner_iters: int = 50,
    phi0: Array | None = None,
    lam0: Array | None = None,
) -> JOWRResult:
    """Solve every instance of ``batch`` in one vmapped program.

    ``banks`` is either a list of per-instance banks (stacked internally), a
    pre-stacked bank with ``a``/``b`` of shape [B, W], or a single bank
    (shape [W]) broadcast to every instance.  ``phi0``/``lam0``, when given,
    must carry a leading instance axis.  Returns a ``JOWRResult`` whose
    fields are stacked over instances: ``lam`` [B, W], ``phi``
    [B, W, Nb, Nb], ``utility_traj`` [B, T], ``lam_traj`` [B, T, W].
    """
    if not isinstance(banks, UtilityBank):
        banks = stack_banks(list(banks))

    def one(graph, bank, phi0, lam0):
        return solve_jowr(graph, bank, lam_total, method=method,
                          cost_name=cost_name, delta=delta,
                          eta_outer=eta_outer, eta_inner=eta_inner,
                          outer_iters=outer_iters, inner_iters=inner_iters,
                          phi0=phi0, lam0=lam0)

    in_axes = (0, _bank_axis(banks),
               None if phi0 is None else 0,
               None if lam0 is None else 0)
    return jax.vmap(one, in_axes=in_axes)(
        batch.stacked_graph(), banks, phi0, lam0)


def solve_routing_batch(
    batch: CECGraphBatch,
    cost: _costs.CostFn,
    lam: Array,
    phi0: Array,
    eta: float,
    n_iters: int,
    *,
    method: str = "omd",
) -> tuple[Array, Array]:
    """Vmapped routing oracle: OMD-RT (or SGP) over the instance axis.

    ``lam`` is [W] (broadcast) or [B, W]; ``phi0`` is [B, W, Nb, Nb] (use
    ``batch.uniform_phi()``).  Returns (φ [B, W, Nb, Nb], cost trajectories
    [B, n_iters]).
    """
    solver = {"omd": solve_routing, "sgp": solve_routing_sgp}[method]

    def one(graph, lam, phi0):
        return solver(graph, cost, lam, phi0, eta, n_iters)

    lam = jnp.asarray(lam)
    lam_axis = 0 if lam.ndim == 2 else None
    return jax.vmap(one, in_axes=(0, lam_axis, 0))(
        batch.stacked_graph(), lam, phi0)
