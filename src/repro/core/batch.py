"""Batched multi-instance JOWR: solve an ensemble in one XLA program.

The paper's evaluation (§IV, Figs. 7–11, Table II) reports every curve as an
average over many random instance draws.  Solving those draws one at a time
from Python wastes the fact that ``gs_oma``/``omad`` are pure scanned JAX:
``CECGraphBatch`` stacks B augmented graphs into one pytree (padding draws
of different physical size to a common augmented size, DESIGN.md §9.1) and
``solve_jowr_batch`` / ``solve_routing_batch`` ``jax.vmap`` the existing
scan over the instance axis, returning stacked results.

Padding is exact, not approximate: pad nodes get no edges (all-zero masks),
unit capacity on masked-out links (the ``CECGraph`` convention for unused
entries), and the shared ``depth_max`` is the batch maximum — extra Jacobi
relaxation steps past an instance's own longest path are no-ops at the flow
fixed point, so a padded instance reproduces its standalone trajectory.
Virtual nodes are re-indexed so that ``src``/``sinks`` land at the same
(static) positions for every instance; all instances must share the session
count W.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import costs as _costs
from . import dispatch
from . import solver as _solver
from .allocation import JOWRResult
from .graph import CECGraph, CECGraphSparse
from .problem import Problem, resolve_cost
from .routing import solve_routing, solve_routing_sgp
from .solver import Method, SolverConfig, SolverState
from .utility import UtilityBank

Array = jnp.ndarray


def pad_graph(graph: CECGraph, n_phys: int,
              depth_max: int | None = None) -> CECGraph:
    """Embed ``graph`` into an augmented graph with ``n_phys`` physical nodes.

    Physical nodes keep their indices; pad nodes ``[graph.n_phys, n_phys)``
    are isolated (no allowed out-edges, never deployed); the virtual source
    and sinks are relocated to the tail positions ``n_phys`` and
    ``n_phys + 1 + w``.  The padded instance is solve-equivalent to the
    original (see module docstring).
    """
    if n_phys < graph.n_phys:
        raise ValueError(f"cannot shrink graph: {graph.n_phys} -> {n_phys}")
    depth_max = graph.depth_max if depth_max is None else depth_max
    if depth_max < graph.depth_max:
        raise ValueError("depth_max must not decrease")
    if n_phys == graph.n_phys and depth_max == graph.depth_max:
        return graph

    W = graph.n_sessions
    n_bar = n_phys + 1 + W
    # old augmented index -> new augmented index
    idx = np.concatenate([np.arange(graph.n_phys), [n_phys],
                          n_phys + 1 + np.arange(W)])

    out_mask = np.zeros((W, n_bar, n_bar), np.float32)
    edge_mask = np.zeros((n_bar, n_bar), np.float32)
    capacity = np.ones((n_bar, n_bar), np.float32)
    for w in range(W):
        out_mask[w][np.ix_(idx, idx)] = np.asarray(graph.out_mask[w])
    edge_mask[np.ix_(idx, idx)] = np.asarray(graph.edge_mask)
    capacity[np.ix_(idx, idx)] = np.asarray(graph.capacity)

    deploy = np.zeros((W, n_phys), bool)
    deploy[:, : graph.n_phys] = np.asarray(graph.deploy)

    return CECGraph(
        out_mask=jnp.asarray(out_mask),
        edge_mask=jnp.asarray(edge_mask),
        capacity=jnp.asarray(capacity),
        deploy=jnp.asarray(deploy),
        sinks=jnp.asarray(n_phys + 1 + np.arange(W)),
        n_phys=n_phys,
        n_sessions=W,
        n_bar=n_bar,
        depth_max=depth_max,
        src=n_phys,
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CECGraphBatch:
    """B CEC instances stacked on a leading axis, sharing static metadata.

    Built with :meth:`from_graphs`; consumed by ``solve_jowr_batch`` and
    ``solve_routing_batch`` which vmap the per-instance solvers over axis 0.
    """

    # --- data (pytree leaves, leading axis = instance) ---
    out_mask: jax.Array      # [B, W, Nb, Nb]
    edge_mask: jax.Array     # [B, Nb, Nb]
    capacity: jax.Array      # [B, Nb, Nb]
    deploy: jax.Array        # [B, W, N]
    sinks: jax.Array         # [B, W]
    # --- static metadata (shared across instances) ---
    n_instances: int = dataclasses.field(metadata=dict(static=True))
    n_phys: int = dataclasses.field(metadata=dict(static=True))
    n_sessions: int = dataclasses.field(metadata=dict(static=True))
    n_bar: int = dataclasses.field(metadata=dict(static=True))
    depth_max: int = dataclasses.field(metadata=dict(static=True))
    src: int = dataclasses.field(metadata=dict(static=True))

    @classmethod
    def from_graphs(cls, graphs: Sequence[CECGraph]) -> "CECGraphBatch":
        """Stack instances, padding to the common augmented size."""
        if not graphs:
            raise ValueError("need at least one graph")
        W = graphs[0].n_sessions
        if any(g.n_sessions != W for g in graphs):
            raise ValueError("all instances must share the session count W")
        n_phys = max(g.n_phys for g in graphs)
        depth_max = max(g.depth_max for g in graphs)
        padded = [pad_graph(g, n_phys, depth_max) for g in graphs]
        stack = lambda name: jnp.stack([getattr(g, name) for g in padded])
        return cls(
            out_mask=stack("out_mask"),
            edge_mask=stack("edge_mask"),
            capacity=stack("capacity"),
            deploy=stack("deploy"),
            sinks=stack("sinks"),
            n_instances=len(padded),
            n_phys=n_phys,
            n_sessions=W,
            n_bar=padded[0].n_bar,
            depth_max=depth_max,
            src=padded[0].src,
        )

    def _graph(self, leaves) -> CECGraph:
        return CECGraph(*leaves, n_phys=self.n_phys,
                        n_sessions=self.n_sessions, n_bar=self.n_bar,
                        depth_max=self.depth_max, src=self.src)

    def stacked_graph(self) -> CECGraph:
        """A ``CECGraph`` view whose leaves carry the instance axis.

        Static metadata is shared, so ``jax.vmap(fn)(batch.stacked_graph())``
        maps ``fn`` over instances with zero data movement.
        """
        return self._graph((self.out_mask, self.edge_mask, self.capacity,
                            self.deploy, self.sinks))

    def instance(self, b: int) -> CECGraph:
        """Materialize instance ``b`` as a standalone ``CECGraph``."""
        return self._graph((self.out_mask[b], self.edge_mask[b],
                            self.capacity[b], self.deploy[b], self.sinks[b]))

    def uniform_phi(self) -> jax.Array:
        """[B, W, Nb, Nb] uniform routing per instance."""
        return self.stacked_graph().uniform_phi()


def pad_sparse_graph(graph: CECGraphSparse, n_phys: int,
                     depth_max: int | None = None, d_max: int | None = None,
                     d_src: int | None = None,
                     d_in_max: int | None = None) -> CECGraphSparse:
    """Embed a sparse graph into a larger index/slot space.

    The edge-list counterpart of :func:`pad_graph`: physical nodes keep
    their indices, pad nodes are isolated, the virtual source and sinks
    relocate to the tail positions, and every slot axis grows to the
    requested width (slots keep their positions — crucial for ``in_slot``
    validity).  Node indices stored in ``nbr``/``src_nbr`` are remapped
    through the same relocation.  Solve-equivalent by the same argument as
    the dense pad (extra rows/slots carry zero mask).
    """
    if n_phys < graph.n_phys:
        raise ValueError(f"cannot shrink graph: {graph.n_phys} -> {n_phys}")
    depth_max = max(graph.depth_max, depth_max or 0)
    d_max = max(graph.d_max, d_max or 0)
    d_src = max(graph.d_src, d_src or 0)
    d_in_max = max(graph.d_in_max, d_in_max or 0)
    if (n_phys, depth_max, d_max, d_src, d_in_max) == (
            graph.n_phys, graph.depth_max, graph.d_max, graph.d_src,
            graph.d_in_max):
        return graph

    W = graph.n_sessions
    n_bar = n_phys + 1 + W
    shift = n_phys - graph.n_phys
    idx = np.concatenate([np.arange(graph.n_phys), [n_phys],
                          n_phys + 1 + np.arange(W)])

    def remap(v):
        v = np.asarray(v)
        return np.where(v >= graph.src, v + shift, v).astype(np.int32)

    nbr = np.tile(np.arange(n_bar, dtype=np.int32)[:, None], (1, d_max))
    nbr[idx, : graph.d_max] = remap(graph.nbr)
    out_mask = np.zeros((W, n_bar, d_max), np.float32)
    out_mask[:, idx, : graph.d_max] = np.asarray(graph.out_mask)
    edge_mask = np.zeros((n_bar, d_max), np.float32)
    edge_mask[idx, : graph.d_max] = np.asarray(graph.edge_mask)
    capacity = np.ones((n_bar, d_max), np.float32)
    capacity[idx, : graph.d_max] = np.asarray(graph.capacity)
    sink_slot = np.zeros(n_phys, np.int32)
    sink_slot[: graph.n_phys] = np.asarray(graph.sink_slot)

    src_nbr = np.full(d_src, n_phys, np.int32)
    src_nbr[: graph.d_src] = remap(graph.src_nbr)
    src_out_mask = np.zeros((W, d_src), np.float32)
    src_out_mask[:, : graph.d_src] = np.asarray(graph.src_out_mask)
    src_edge_mask = np.zeros(d_src, np.float32)
    src_edge_mask[: graph.d_src] = np.asarray(graph.src_edge_mask)
    src_capacity = np.ones(d_src, np.float32)
    src_capacity[: graph.d_src] = np.asarray(graph.src_capacity)

    in_src = np.zeros((n_bar, d_in_max), np.int32)
    in_src[idx, : graph.d_in_max] = np.asarray(graph.in_src)
    in_slot = np.zeros((n_bar, d_in_max), np.int32)
    in_slot[idx, : graph.d_in_max] = np.asarray(graph.in_slot)
    in_mask = np.zeros((n_bar, d_in_max), np.float32)
    in_mask[idx, : graph.d_in_max] = np.asarray(graph.in_mask)

    deploy = np.zeros((W, n_phys), bool)
    deploy[:, : graph.n_phys] = np.asarray(graph.deploy)

    return CECGraphSparse(
        nbr=jnp.asarray(nbr), out_mask=jnp.asarray(out_mask),
        edge_mask=jnp.asarray(edge_mask), capacity=jnp.asarray(capacity),
        sink_slot=jnp.asarray(sink_slot),
        src_nbr=jnp.asarray(src_nbr), src_out_mask=jnp.asarray(src_out_mask),
        src_edge_mask=jnp.asarray(src_edge_mask),
        src_capacity=jnp.asarray(src_capacity),
        in_src=jnp.asarray(in_src), in_slot=jnp.asarray(in_slot),
        in_mask=jnp.asarray(in_mask), deploy=jnp.asarray(deploy),
        sinks=jnp.asarray(n_phys + 1 + np.arange(W)),
        n_phys=n_phys, n_sessions=W, n_bar=n_bar, depth_max=depth_max,
        src=n_phys, d_max=d_max, d_src=d_src, d_in_max=d_in_max,
        n_edges=graph.n_edges)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CECGraphSparseBatch:
    """B sparse CEC instances stacked on a leading axis (cf.
    :class:`CECGraphBatch`).

    Instances are padded to common (``n_phys``, ``depth_max``, slot
    widths) via :func:`pad_sparse_graph` and stacked leaf-wise;
    ``solve_jowr_batch`` / ``solve_routing_batch`` accept either batch
    flavor — the vmapped per-instance solver dispatches on the graph type.
    """

    # --- data (pytree leaves, leading axis = instance) ---
    nbr: jax.Array
    out_mask: jax.Array
    edge_mask: jax.Array
    capacity: jax.Array
    sink_slot: jax.Array
    src_nbr: jax.Array
    src_out_mask: jax.Array
    src_edge_mask: jax.Array
    src_capacity: jax.Array
    in_src: jax.Array
    in_slot: jax.Array
    in_mask: jax.Array
    deploy: jax.Array
    sinks: jax.Array
    # --- static metadata (shared across instances) ---
    n_instances: int = dataclasses.field(metadata=dict(static=True))
    n_phys: int = dataclasses.field(metadata=dict(static=True))
    n_sessions: int = dataclasses.field(metadata=dict(static=True))
    n_bar: int = dataclasses.field(metadata=dict(static=True))
    depth_max: int = dataclasses.field(metadata=dict(static=True))
    src: int = dataclasses.field(metadata=dict(static=True))
    d_max: int = dataclasses.field(metadata=dict(static=True))
    d_src: int = dataclasses.field(metadata=dict(static=True))
    d_in_max: int = dataclasses.field(metadata=dict(static=True))
    n_edges: int = dataclasses.field(metadata=dict(static=True))

    _LEAVES = ("nbr", "out_mask", "edge_mask", "capacity", "sink_slot",
               "src_nbr", "src_out_mask", "src_edge_mask", "src_capacity",
               "in_src", "in_slot", "in_mask", "deploy", "sinks")

    @classmethod
    def from_graphs(cls,
                    graphs: Sequence[CECGraphSparse]) -> "CECGraphSparseBatch":
        """Stack sparse instances, padding to the common slot widths."""
        if not graphs:
            raise ValueError("need at least one graph")
        W = graphs[0].n_sessions
        if any(g.n_sessions != W for g in graphs):
            raise ValueError("all instances must share the session count W")
        kw = dict(
            n_phys=max(g.n_phys for g in graphs),
            depth_max=max(g.depth_max for g in graphs),
            d_max=max(g.d_max for g in graphs),
            d_src=max(g.d_src for g in graphs),
            d_in_max=max(g.d_in_max for g in graphs))
        padded = [pad_sparse_graph(g, **kw) for g in graphs]
        leaves = {name: jnp.stack([getattr(g, name) for g in padded])
                  for name in cls._LEAVES}
        return cls(**leaves, n_instances=len(padded), n_sessions=W,
                   n_bar=padded[0].n_bar, src=padded[0].src,
                   n_edges=max(g.n_edges for g in graphs), **kw)

    def _graph(self, leaves, n_edges: int | None = None) -> CECGraphSparse:
        return CECGraphSparse(
            **dict(zip(self._LEAVES, leaves)),
            n_phys=self.n_phys, n_sessions=self.n_sessions, n_bar=self.n_bar,
            depth_max=self.depth_max, src=self.src, d_max=self.d_max,
            d_src=self.d_src, d_in_max=self.d_in_max,
            n_edges=self.n_edges if n_edges is None else n_edges)

    def stacked_graph(self) -> CECGraphSparse:
        """A ``CECGraphSparse`` view whose leaves carry the instance axis.

        The shared ``n_edges`` metadata is the batch maximum (instances
        differ; padding gives them one layout) — an upper bound, fine for
        the solvers, which never read it.
        """
        return self._graph([getattr(self, name) for name in self._LEAVES])

    def instance(self, b: int) -> CECGraphSparse:
        """Materialize instance ``b`` as a standalone ``CECGraphSparse``
        (with its *own* edge count recomputed from the masks, not the
        batch-level upper bound — ``density`` stays truthful)."""
        leaves = [getattr(self, name)[b] for name in self._LEAVES]
        n_edges = int(np.asarray(self.edge_mask[b]).sum()
                      + np.asarray(self.src_edge_mask[b]).sum())
        return self._graph(leaves, n_edges=n_edges)

    def uniform_phi(self):
        """Stacked ``SparsePhi`` — uniform routing per instance."""
        return self.stacked_graph().uniform_phi()


def stack_banks(banks: Sequence[UtilityBank]) -> UtilityBank:
    """Stack per-instance utility banks (same family/noise) along axis 0."""
    kind, noise = banks[0].kind, banks[0].noise
    if any(b.kind != kind or b.noise != noise for b in banks):
        raise ValueError("all banks must share kind and noise level")
    return UtilityBank(a=jnp.stack([b.a for b in banks]),
                       b=jnp.stack([b.b for b in banks]),
                       kind=kind, noise=noise)


def _bank_axis(bank: UtilityBank):
    """0 when the bank carries an instance axis, None to broadcast one."""
    return 0 if bank.a.ndim == 2 else None


def _vmapped_run(batch, banks, lam_total, config, *, iters, costfn,
                 state, phi0, lam0) -> _solver.Result:
    """The vmapped engine both fleet drivers share: each lane builds a
    ``Problem`` from its slice of the stacked graph/banks and scans
    ``solver.step``.  Pure traceable JAX — ``run_batch`` calls it
    directly, ``run_batch_sharded`` wraps it in a ``shard_map`` body
    (so it must not touch the host: no callbacks, no concrete reads)."""

    def one(graph, bank, state, phi0, lam0):
        problem = Problem(graph=graph, bank=bank, lam_total=lam_total,
                          cost=costfn)
        return _solver.run(problem, config, iters=iters, state=state,
                           phi0=phi0, lam0=lam0)

    in_axes = (0, _bank_axis(banks),
               None if state is None else 0,
               None if phi0 is None else 0,
               None if lam0 is None else 0)
    return jax.vmap(one, in_axes=in_axes)(
        batch.stacked_graph(), banks, state, phi0, lam0)


@functools.lru_cache(maxsize=None)
def _fused_step_batch(config: SolverConfig, costfn, donate: bool,
                      util_family: str | None, _dispatch_key):
    def one(g, lt, s, u, p, tel):
        problem = Problem(graph=g, bank=None, lam_total=lt, cost=costfn,
                          util_params=p, util_family=util_family)
        return _solver.step(problem, config, s, u, tel)

    if config.telemetry > 0:
        # telemetry rides as a stacked [K]-ring pytree right after the
        # state so (state, telemetry) donate as a pair — the recording
        # fleet steady state allocates nothing per interval (§18.1)
        def fn(graph, lam_total, state, task_utilities, telemetry,
               util_params=None):
            params_axis = None if util_params is None else 0
            return jax.vmap(one, in_axes=(0, 0, 0, 0, params_axis, 0))(
                graph, lam_total, state, task_utilities, util_params,
                telemetry)

        return jax.jit(fn, donate_argnums=(2, 4) if donate else ())

    def fn(graph, lam_total, state, task_utilities, util_params=None):
        params_axis = None if util_params is None else 0
        return jax.vmap(one, in_axes=(0, 0, 0, 0, params_axis, None))(
            graph, lam_total, state, task_utilities, util_params, None)

    return jax.jit(fn, donate_argnums=(2,) if donate else ())


def fused_step_batch(config: SolverConfig, *, cost="exp",
                     donate: bool = False, util_family: str | None = None):
    """``jit(vmap(step))`` over a tenant/instance axis, measured-utility mode.

    Returns ``fn(graph, lam_total, state, task_utilities) ->
    (SolverState, StepInfo)`` where every argument carries a leading
    instance axis: ``graph`` is a stacked view
    (``CECGraphBatch.stacked_graph()``), ``lam_total`` is [K] per-tenant
    demand (a traced leaf — demand shifts never retrace),
    ``state`` is a stacked ``SolverState`` (``lam`` [K, W]) and
    ``task_utilities`` is [K, 2W] measured utilities in
    ``perturbed_allocations`` row order.  Each lane builds a bank-less
    ``Problem`` from its slice, exactly like ``_vmapped_run`` — the fleet
    step *is* the single-tenant step.

    With ``util_family`` set (and ``config.grad_mode="learned"``) the
    returned fn accepts a trailing argument: stacked [K, W, P] fitted
    ``util_params`` — a data leaf, so per-tenant refits never retrace
    (DESIGN.md §16.4); ``task_utilities`` is then ignored (pass zeros).

    With ``config.telemetry > 0`` the returned fn takes a stacked
    ``[K]``-lane obs ring as its fifth positional argument —
    ``fn(graph, lam_total, state, task_utilities, telemetry,
    util_params=None)`` — records every lane inside the jit, returns the
    updated ring third, and donates it together with the state
    (DESIGN.md §18.1).

    ``donate=True`` donates the stacked ``state`` so the K control
    iterations update in place (the ``RouterFleet`` steady state,
    DESIGN.md §15.3).  Cached on ``(config, cost, donate, util_family,
    dispatch.state_key())`` — ``cost`` must be a registry name or a
    hashable ``CostFn``.
    """
    return _fused_step_batch(config, resolve_cost(cost), bool(donate),
                             util_family, dispatch.state_key())


def run_batch(
    batch: CECGraphBatch | CECGraphSparseBatch,
    banks: UtilityBank | Sequence[UtilityBank],
    lam_total,
    config: SolverConfig,
    *,
    iters: int,
    cost="exp",
    state: SolverState | None = None,
    phi0: Array | None = None,
    lam0: Array | None = None,
) -> _solver.Result:
    """``jax.vmap`` of ``solver.run`` over the instance axis.

    The batched engine *is* the single-instance engine: each vmapped lane
    builds a ``Problem`` from its slice of the stacked graph/banks and
    scans ``solver.step``.  ``banks`` is either a list of per-instance
    banks (stacked internally), a pre-stacked bank with ``a``/``b`` of
    shape [B, W], or a single bank (shape [W]) broadcast to every
    instance.  ``state`` (a stacked ``SolverState`` — e.g. a previous
    ``Result.state``) or ``phi0``/``lam0`` must carry a leading instance
    axis.  Returns a ``solver.Result`` whose fields are stacked over
    instances: ``lam`` [B, W], ``utility_traj`` [B, T], ….

    Fleets larger than one device's memory go through
    :func:`run_batch_sharded` — same engine, instance axis sharded over
    a device mesh.
    """
    if not isinstance(banks, UtilityBank):
        banks = stack_banks(list(banks))
    return _vmapped_run(batch, banks, lam_total, config, iters=iters,
                        costfn=resolve_cost(cost), state=state, phi0=phi0,
                        lam0=lam0)


def run_batch_sharded(
    batch: CECGraphBatch | CECGraphSparseBatch,
    banks: UtilityBank | Sequence[UtilityBank],
    lam_total,
    config: SolverConfig,
    *,
    iters: int,
    cost="exp",
    mesh=None,
    state: SolverState | None = None,
    phi0: Array | None = None,
    lam0: Array | None = None,
) -> _solver.Result:
    """:func:`run_batch` with the instance axis sharded over a device mesh.

    The fleet axis of every stacked pytree — the batch's graph leaves,
    per-instance banks, a carried ``SolverState``, ``phi0``/``lam0``
    overrides — is partitioned across ``mesh`` (default: the 1-D
    ``launch.mesh.fleet_mesh()`` over all visible devices) with
    ``shard_map``; each device vmaps the solver core over its local
    shard.  The per-shard solves are embarrassingly parallel, so the
    mapped body contains no collectives and no host callbacks — the
    whole scan stays device-resident.

    Fleets that do not divide the mesh are padded with replicas of the
    last instance (``parallel.sharding.pad_fleet``) and the pad lanes
    are sliced off the result (``unpad_fleet``) — exact masking, not an
    approximation: the returned ``Result`` matches :func:`run_batch`
    lane-for-lane (bit-identical on a 1-device mesh, ≤1e-6 across
    device counts — the ``tests/test_sharded_fleet.py`` parity tier).

    ``banks`` follows the :func:`run_batch` contract: per-instance banks
    shard with the fleet, a single broadcast bank replicates to every
    device.  ``lam_total`` (scalar demand) always replicates.  Traces
    under ``dispatch.fleet_dispatch(mesh)`` so ``dispatch.state_key()``
    covers the mesh shape and cached jitted consumers never alias
    executables across meshes.
    """
    from repro.launch.mesh import fleet_mesh
    from repro.parallel.collectives import shard_map_compat
    from repro.parallel.sharding import (fleet_axis, fleet_padded_size,
                                         fleet_specs, pad_fleet, unpad_fleet)

    if not isinstance(banks, UtilityBank):
        banks = stack_banks(list(banks))
    costfn = resolve_cost(cost)
    if mesh is None:
        mesh = fleet_mesh()
    axis = fleet_axis(mesh)
    n_shards = int(mesh.shape[axis])
    B = batch.n_instances
    B_pad = fleet_padded_size(B, n_shards)

    bank_sharded = _bank_axis(banks) == 0
    sharded_in = (batch, banks if bank_sharded else None, state, phi0, lam0)
    sharded_in = pad_fleet(sharded_in, n_shards)
    batch_p, banks_p, state_p, phi0_p, lam0_p = sharded_in
    if B_pad != B:
        # pad_fleet grows the pytree leaves; the static instance count is
        # aux data that must follow suit for the batch view to stay honest
        batch_p = dataclasses.replace(batch_p, n_instances=B_pad)
    if not bank_sharded:
        banks_p = banks

    def body(batch, banks, state, phi0, lam0, lam_total):
        return _vmapped_run(batch, banks, lam_total, config, iters=iters,
                            costfn=costfn, state=state, phi0=phi0, lam0=lam0)

    args = (batch_p, banks_p, state_p, phi0_p, lam0_p,
            jnp.asarray(lam_total, jnp.float32))
    in_specs = (fleet_specs(batch_p, axis),
                fleet_specs(banks_p, axis, shard=bank_sharded),
                fleet_specs(state_p, axis),
                fleet_specs(phi0_p, axis),
                fleet_specs(lam0_p, axis),
                fleet_specs(args[-1], axis, shard=False))
    with dispatch.fleet_dispatch(mesh):
        out_specs = fleet_specs(jax.eval_shape(body, *args), axis)
        result = shard_map_compat(body, mesh, in_specs, out_specs)(*args)
    if B_pad != B:
        result = unpad_fleet(result, B)
    return result


def solve_jowr_batch(
    batch: CECGraphBatch | CECGraphSparseBatch,
    banks: UtilityBank | Sequence[UtilityBank],
    lam_total: float,
    *,
    method: Method = "single",
    cost_name: str = "exp",
    delta: float = 0.5,
    eta_outer: float = 0.05,
    eta_inner: float = 0.05,
    outer_iters: int = 100,
    inner_iters: int = 50,
    phi0: Array | None = None,
    lam0: Array | None = None,
) -> JOWRResult:
    """Solve every instance of ``batch`` in one vmapped program.

    Legacy shim over :func:`run_batch` (same banks/overrides contract).
    Returns a ``JOWRResult`` whose fields are stacked over instances:
    ``lam`` [B, W], ``phi`` [B, W, Nb, Nb], ``utility_traj`` [B, T],
    ``lam_traj`` [B, T, W].
    """
    config = SolverConfig.from_legacy(method=method, delta=delta,
                                      eta_outer=eta_outer,
                                      eta_inner=eta_inner,
                                      inner_iters=inner_iters)
    res = run_batch(batch, banks, lam_total, config, iters=outer_iters,
                    cost=cost_name, phi0=phi0, lam0=lam0)
    return JOWRResult.from_result(res)


def solve_routing_batch(
    batch: CECGraphBatch | CECGraphSparseBatch,
    cost: _costs.CostFn,
    lam: Array,
    phi0: Array,
    eta: float,
    n_iters: int,
    *,
    method: str = "omd",
) -> tuple[Array, Array]:
    """Vmapped routing oracle: OMD-RT (or SGP) over the instance axis.

    ``lam`` is [W] (broadcast) or [B, W]; ``phi0`` is [B, W, Nb, Nb] (use
    ``batch.uniform_phi()``).  Returns (φ [B, W, Nb, Nb], cost trajectories
    [B, n_iters]).
    """
    solver = {"omd": solve_routing, "sgp": solve_routing_sgp}[method]

    def one(graph, lam, phi0):
        return solver(graph, cost, lam, phi0, eta, n_iters)

    lam = jnp.asarray(lam)
    lam_axis = 0 if lam.ndim == 2 else None
    return jax.vmap(one, in_axes=(0, lam_axis, 0))(
        batch.stacked_graph(), lam, phi0)
