"""High-level JOWR API — the paper's contribution behind one call.

``solve_jowr`` is the legacy composable entry point used by examples,
benchmarks and the serving engine's CEC router: pick a topology, a cost
model, a (black-box) utility bank, and a method.  It is a shim — the
equivalent first-class call is::

    problem = Problem.create(graph, bank, lam_total=..., cost=cost_name)
    result = solver.run(problem, SolverConfig(method=..., ...), iters=T)

(see ``core/solver.py`` and DESIGN.md §13).
"""
from __future__ import annotations

from . import solver as _solver
from .allocation import JOWRResult
from .graph import CECGraph
from .problem import Problem, resolve_cost
from .solver import METHODS, Method, SolverConfig
from .utility import UtilityBank


def solve_jowr(
    graph: CECGraph,
    bank: UtilityBank,
    lam_total: float,
    *,
    method: Method = "single",
    cost_name: str = "exp",
    delta: float = 0.5,
    eta_outer: float = 0.05,
    eta_inner: float = 0.05,
    outer_iters: int = 100,
    inner_iters: int = 50,
    phi0=None,
    lam0=None,
) -> JOWRResult:
    if method not in METHODS:
        raise ValueError(
            f"unknown method {method!r}: valid methods are {METHODS} "
            f"(\"nested\" = GS-OMA Alg. 1, \"single\" = OMAD Alg. 3)")
    problem = Problem(graph=graph, bank=bank, lam_total=lam_total,
                      cost=resolve_cost(cost_name))
    config = SolverConfig.from_legacy(method=method, delta=delta,
                                      eta_outer=eta_outer,
                                      eta_inner=eta_inner,
                                      inner_iters=inner_iters)
    res = _solver.run(problem, config, iters=outer_iters, phi0=phi0,
                      lam0=lam0)
    return JOWRResult.from_result(res)
