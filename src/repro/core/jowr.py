"""High-level JOWR API — the paper's contribution behind one call.

``solve_jowr`` is the composable entry point used by examples, benchmarks
and the serving engine's CEC router: pick a topology, a cost model, a
(black-box) utility bank, and a method.
"""
from __future__ import annotations

from typing import Literal

from . import costs as _costs
from .allocation import JOWRResult, gs_oma
from .graph import CECGraph
from .single_loop import omad
from .utility import UtilityBank

Method = Literal["nested", "single"]


def solve_jowr(
    graph: CECGraph,
    bank: UtilityBank,
    lam_total: float,
    *,
    method: Method = "single",
    cost_name: str = "exp",
    delta: float = 0.5,
    eta_outer: float = 0.05,
    eta_inner: float = 0.05,
    outer_iters: int = 100,
    inner_iters: int = 50,
    phi0=None,
    lam0=None,
) -> JOWRResult:
    cost = _costs.get(cost_name)
    if method == "nested":
        return gs_oma(graph, cost, bank, lam_total, delta=delta,
                      eta_outer=eta_outer, eta_inner=eta_inner,
                      outer_iters=outer_iters, inner_iters=inner_iters,
                      phi0=phi0, lam0=lam0)
    if method == "single":
        return omad(graph, cost, bank, lam_total, delta=delta,
                    eta_outer=eta_outer, eta_inner=eta_inner,
                    outer_iters=outer_iters, phi0=phi0, lam0=lam0)
    raise ValueError(method)
