"""Implicit fixed-point layer: differentiate *through* the solver (DESIGN.md §16.1).

The routing oracle 𝔒 (``routing.oracle_observe``) iterates a contraction
``x ← f(x, θ)`` toward its fixed point x*(θ).  Differentiating the
unrolled iteration is memory-hungry (O(K) residuals) and, worse, couples
the gradient to the truncation; the implicit function theorem gives the
exact equilibrium sensitivity from the fixed point alone:

    x* = f(x*, θ)   ⇒   ∂x*/∂θ = (I − ∂f/∂x)⁻¹ · ∂f/∂θ

:func:`fixed_point_solve` packages this as a ``jax.custom_vjp``:

* **forward** — the *same* jitted ``lax.scan`` of ``f`` the solver has
  always run (the carry path is bit-identical to the pre-§16 scan, which
  is why the golden trace did not move when ``oracle_observe`` was wired
  through here);
* **backward** — a linearized adjoint solve: the cotangent system
  ``v = x̄ + (∂f/∂x)ᵀ v`` is itself a contraction and is iterated with
  the Neumann series (``bwd_iters`` terms), after which one VJP of ``f``
  at the fixed point maps ``v`` onto the θ-cotangents.  No forward
  residuals are stored — backward memory is O(1) in ``n_iters``.

This is what makes ``solver.run``'s :class:`~repro.core.solver.Result`
differentiable w.r.t. every :class:`~repro.core.problem.Problem` leaf
(``lam_total``, link capacities, utility parameters): the learned
gradient mode (``grad_mode="learned"``, DESIGN.md §16.2) takes
``jax.grad`` of the network cost at the routing fixed point instead of
paying 2W two-point oracle perturbations per interval, and the
hypergradient loop (``core/hypergrad.py``, DESIGN.md §16.3) backprops
its meta-loss through the same layer.

Caveats, stated rather than hidden:

* the cotangent returned for ``x0`` is **zero** — the IFT treats the
  solve's output as the equilibrium, which by definition forgets the
  warm start.  Rollouts that carry φ across observations are therefore
  truncated-backprop in the φ direction (exact as the oracle converges).
* the backward pass linearizes ``f`` at the *returned* iterate.  With a
  generous ``n_iters`` that iterate is the fixed point and the gradient
  is exact (``tests/test_implicit.py`` pins it against finite
  differences at ≤1e-4); with serving's K=1 oracle it is the standard
  one-step equilibrium approximation.
* ``f`` must be differentiable JAX — the Pallas kernel path has no VJP,
  so learned/hypergradient consumers run the jnp expressions (the
  default everywhere off-TPU; see DESIGN.md §9.2).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["fixed_point_solve"]

_tree_map = jax.tree_util.tree_map


def _iterate(f: Callable, n_iters: int, x0, args):
    """``x_{k+1} = f(x_k, *args)`` scanned ``n_iters`` times (jit-friendly)."""

    def body(x, _):
        return f(x, *args), None

    x, _ = jax.lax.scan(body, x0, None, length=n_iters)
    return x


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _fixed_point(f, n_iters: int, bwd_iters: int, x0, args):
    return _iterate(f, n_iters, x0, args)


def _fixed_point_fwd(f, n_iters, bwd_iters, x0, args):
    x_star = _iterate(f, n_iters, x0, args)
    return x_star, (x_star, args)


def _fixed_point_bwd(f, n_iters, bwd_iters, res, x_bar):
    x_star, args = res
    # adjoint solve: v = x̄ + (∂f/∂x)ᵀ v, iterated as a Neumann series —
    # the same contraction that made the forward converge makes this one
    _, vjp_x = jax.vjp(lambda x: f(x, *args), x_star)

    def body(v, _):
        (jtv,) = vjp_x(v)
        return _tree_map(jnp.add, x_bar, jtv), None

    v, _ = jax.lax.scan(body, x_bar, None, length=bwd_iters)
    # one VJP of f at the equilibrium maps the adjoint onto the θ-cotangents
    _, vjp_args = jax.vjp(lambda a: f(x_star, *a), args)
    (args_bar,) = vjp_args(v)
    # the IFT forgets the warm start: zero cotangent for x0 (module docstring)
    x0_bar = _tree_map(jnp.zeros_like, x_star)
    return x0_bar, args_bar


_fixed_point.defvjp(_fixed_point_fwd, _fixed_point_bwd)


def fixed_point_solve(f: Callable, x0, *args: Any, n_iters: int,
                      bwd_iters: int | None = None):
    """Iterate ``x ← f(x, *args)`` with an implicit-function-theorem VJP.

    Parameters
    ----------
    f:
        The iteration map ``f(x, *args) -> x`` — a contraction toward the
        fixed point on the region of interest.  Must not close over
        traced values (pass them through ``args``, where they pick up
        gradients; ``jax.custom_vjp`` rejects closed-over tracers).
    x0:
        Initial iterate (any pytree of float arrays).  Receives a ZERO
        cotangent — see the module docstring.
    args:
        Differentiable parameters of the map; gradients flow to every
        float leaf (integer/bool leaves get symbolic zeros).
    n_iters:
        Forward iterations.  The forward value is exactly the ``n_iters``-
        step scan — truncation is the caller's contract, the VJP assumes
        the result is (close to) the fixed point.
    bwd_iters:
        Neumann terms of the adjoint solve (default: ``n_iters``).

    Works under ``jit``/``vmap``/``lax.scan``; reverse-mode only (the
    custom VJP has no JVP rule).
    """
    return _fixed_point(f, int(n_iters),
                        int(n_iters if bwd_iters is None else bwd_iters),
                        x0, args)
