"""Size-based kernel dispatch for the control-plane hot path (DESIGN.md §9.2).

The jnp einsum implementations in ``core/flow.py`` / ``core/routing.py`` are
the right tool for paper-scale graphs (n̄ ≲ a few hundred): XLA fuses them
and padding to the TPU's 128-lane blocks would only waste work.  At fleet
scale (n̄ = 10³–10⁵) the same steps are served by the Pallas kernels in
``kernels/``: when :func:`use_kernels` says so, ``flow.propagate`` routes
each relaxation step through ``kernels.flow_step`` and ``routing.omd_step``
routes the exponentiated-gradient update through ``kernels.omd_update``.
Operand padding to the 128-block constraint (and slicing back) is handled
by ``kernels/ops.py``.

Dispatch policy: the graph must clear the node-count threshold
(:func:`kernel_threshold`, default 256), **and** the backend must be a real
TPU — *or* the threshold must have been set explicitly (the
``REPRO_KERNEL_NBAR_THRESHOLD`` environment variable,
:func:`set_kernel_threshold`, or the :func:`kernel_dispatch` context
manager).  Off-TPU the kernels run in Pallas ``interpret`` mode, which is
orders of magnitude slower than the fused einsums — correct for validating
the kernel path everywhere (tests and benchmarks opt in via
``kernel_dispatch``), wrong as a silent default for a large graph on CPU.

Environment knobs are **re-read on every policy query** (they used to be
bound once at import, which made late ``os.environ`` mutation a silent
no-op — DESIGN.md §17.4).  Programmatic overrides (setters / context
managers) take precedence over the environment while active; clearing an
override (``set_kernel_threshold(None)``, context exit) falls back to the
live environment, not to a stale import-time snapshot.

The dispatch decision is made at **trace time** against the *static*
``CECGraph.n_bar`` metadata, so both branches stay jit/vmap compatible and
no control flow enters the compiled program.  The flip side: a function
that was already jit-compiled keeps the branch it was traced with —
``kernel_dispatch`` / ``set_kernel_threshold`` only affect functions traced
while the override is active, and are silent no-ops for cached traces.
Trace (or re-jit) inside the override when you need the kernel path.
Every lru-cached jitted entry point keys on :func:`state_key`, which also
re-reads the environment — so a late env mutation *does* reach cached
consumers (a fresh key forces a fresh trace).

A second, orthogonal axis is the **representation** (DESIGN.md §12): past
:func:`use_sparse`'s (N, density) policy, :func:`maybe_sparsify` converts
a dense ``CECGraph`` to the O(E) ``CECGraphSparse`` edge-list layout at
the solver core's single conversion point (``Problem.canonical``,
core/problem.py — every entry point routes through it) and at the raw
routing oracle ``solve_routing``.  Conversion is Python-level only — tracer inputs pass
through untouched — and :func:`state_key` covers both axes so cached
jitted control steps retrace under either override.

A third axis is the **fused control megakernel** (DESIGN.md §17): past
:func:`use_megakernel`'s policy, ``solver.step`` replaces the whole
``lax.scan``-of-observations control iteration with the single Pallas
kernel in ``kernels/control_megakernel.py``.  Its extra condition is the
VMEM residency contract — the per-session routing variables (W·n̄² plus
the flow/marginal scratch) must fit the per-core VMEM budget, checked by
:func:`megakernel_fits` at trace time.
"""
from __future__ import annotations

import contextlib
import os

import jax
import numpy as np

# Defaults when the env knob is absent and no override is active.
DEFAULT_THRESHOLD = 256
SPARSE_DEFAULT_THRESHOLD = 512
SPARSE_DEFAULT_DENSITY = 0.15
MEGAKERNEL_DEFAULT_THRESHOLD = 256

# Programmatic overrides (setter / context manager).  ``None`` means "no
# override: follow the live environment".  The env vars themselves are
# re-read at query time — never cached at import.
_threshold: int | None = None
_explicit: bool | None = None
_sparse_threshold: int | None = None
_sparse_density: float | None = None
_mega_threshold: int | None = None
_mega_explicit: bool | None = None


def _env_int(name: str, default: int) -> int:
    return int(os.environ.get(name, default))


def _env_float(name: str, default: float) -> float:
    return float(os.environ.get(name, default))


def kernel_threshold() -> int:
    """Augmented node count n̄ at which the Pallas path takes over."""
    if _threshold is not None:
        return _threshold
    return _env_int("REPRO_KERNEL_NBAR_THRESHOLD", DEFAULT_THRESHOLD)


def _kernel_explicit() -> bool:
    """Whether the kernel threshold was explicitly configured (env/setter)."""
    if _explicit is not None:
        return _explicit
    return "REPRO_KERNEL_NBAR_THRESHOLD" in os.environ


def set_kernel_threshold(n: int | None) -> None:
    """Set the dispatch threshold explicitly; ``None`` restores defaults.

    An explicit threshold also enables the kernel path off-TPU (interpret
    mode).  Only affects functions traced after the call (see module
    docstring).  ``None`` falls back to the *live* environment — it does
    not pin an import-time snapshot.
    """
    global _threshold, _explicit
    if n is None:
        _threshold = _explicit = None
    else:
        _threshold = int(n)
        _explicit = True


@contextlib.contextmanager
def kernel_dispatch(threshold: int):
    """Temporarily force the dispatch threshold (tests/benchmarks).

    ``with kernel_dispatch(1): ...`` sends every flow/OMD step traced
    inside the block through the Pallas kernels regardless of graph size
    or backend (interpret mode off-TPU).  Functions jit-compiled *before*
    entering the block keep their cached jnp-path trace.
    """
    global _threshold, _explicit
    prev = (_threshold, _explicit)
    _threshold, _explicit = int(threshold), True
    try:
        yield
    finally:
        _threshold, _explicit = prev


def sparse_threshold() -> int:
    """Augmented node count n̄ at which sparsification is considered."""
    if _sparse_threshold is not None:
        return _sparse_threshold
    return _env_int("REPRO_SPARSE_NBAR_THRESHOLD", SPARSE_DEFAULT_THRESHOLD)


def sparse_density_max() -> float:
    """Union edge density |Ē|/n̄² at or below which sparsification engages."""
    if _sparse_density is not None:
        return _sparse_density
    return _env_float("REPRO_SPARSE_DENSITY_MAX", SPARSE_DEFAULT_DENSITY)


def set_sparse_threshold(n: int | None, density_max: float | None = None):
    """Set the sparse-representation policy; ``None`` n restores defaults."""
    global _sparse_threshold, _sparse_density
    if n is None:
        _sparse_threshold = _sparse_density = None
    else:
        _sparse_threshold = int(n)
        if density_max is not None:
            _sparse_density = float(density_max)


@contextlib.contextmanager
def sparse_dispatch(threshold: int, density_max: float = 1.0):
    """Temporarily force the sparse policy (tests/benchmarks).

    ``with sparse_dispatch(1): ...`` sparsifies every dense graph reaching
    :func:`maybe_sparsify` inside the block regardless of size or density.
    Like ``kernel_dispatch`` this only affects *conversion points* entered
    inside the block; already-converted or already-traced state keeps its
    representation.
    """
    global _sparse_threshold, _sparse_density
    prev = (_sparse_threshold, _sparse_density)
    _sparse_threshold, _sparse_density = int(threshold), float(density_max)
    try:
        yield
    finally:
        _sparse_threshold, _sparse_density = prev


def use_sparse(n_bar: int, density: float) -> bool:
    """True when a graph of ``n_bar`` nodes / ``density`` should go sparse."""
    return n_bar >= sparse_threshold() and density <= sparse_density_max()


def maybe_sparsify(graph, *companions):
    """Convert a dense ``CECGraph`` to ``CECGraphSparse`` past the policy.

    The conversion builds numpy edge lists, so it only happens at the
    Python level: if the graph's leaves or any ``companion`` array (e.g. a
    caller's φ⁰ that would need re-layout) is a tracer, the graph is
    returned unchanged — inside jit/vmap/scan the representation is
    whatever the caller traced with.  Sparse graphs and sub-threshold
    dense graphs pass through untouched.
    """
    from .graph import CECGraph, sparsify

    if not isinstance(graph, CECGraph):
        return graph
    if graph.n_bar < sparse_threshold():     # cheap static reject first —
        return graph                         # no device→host mask transfer
    if any(isinstance(x, jax.core.Tracer)
           for x in (graph.edge_mask, *companions) if x is not None):
        return graph
    density = float(np.asarray(graph.edge_mask).sum()) / graph.n_bar ** 2
    if not use_sparse(graph.n_bar, density):
        return graph
    return sparsify(graph)


# --------------------------------------------------------------------------
# megakernel axis (DESIGN.md §17): whether ``solver.step`` should run the
# whole control iteration (perturbation sweep + oracle + mirror ascent +
# projection) as the single fused Pallas kernel instead of the stitched
# lax.scan over per-phase kernels.
# --------------------------------------------------------------------------

# Per-core VMEM the fused kernel may claim for its resident state.  Real
# v5e cores have 128 MiB; we budget well under half of it so the compiler
# retains room for pipeline buffers and spills, and so the policy stays
# conservative in interpret mode (where the "budget" is only a model).
MEGAKERNEL_VMEM_BUDGET = 48 * 1024 * 1024


def megakernel_threshold() -> int:
    """Augmented node count n̄ at which the fused control step engages."""
    if _mega_threshold is not None:
        return _mega_threshold
    return _env_int("REPRO_MEGAKERNEL_NBAR_THRESHOLD",
                    MEGAKERNEL_DEFAULT_THRESHOLD)


def _megakernel_explicit() -> bool:
    if _mega_explicit is not None:
        return _mega_explicit
    return "REPRO_MEGAKERNEL_NBAR_THRESHOLD" in os.environ


def set_megakernel_threshold(n: int | None) -> None:
    """Set the megakernel threshold; ``None`` restores env-following."""
    global _mega_threshold, _mega_explicit
    if n is None:
        _mega_threshold = _mega_explicit = None
    else:
        _mega_threshold = int(n)
        _mega_explicit = True


@contextlib.contextmanager
def megakernel_dispatch(threshold: int):
    """Temporarily force the fused control step (tests/benchmarks).

    ``with megakernel_dispatch(1): ...`` sends every ``solver.step``
    traced inside the block through ``kernels.control_megakernel``
    regardless of graph size or backend (interpret mode off-TPU), VMEM
    fit permitting.  Same trace-time caveat as :func:`kernel_dispatch`.
    """
    global _mega_threshold, _mega_explicit
    prev = (_mega_threshold, _mega_explicit)
    _mega_threshold, _mega_explicit = int(threshold), True
    try:
        yield
    finally:
        _mega_threshold, _mega_explicit = prev


def megakernel_phi_dtype() -> str:
    """Storage dtype for the kernel's VMEM-resident φ (DESIGN.md §17.3).

    ``REPRO_MEGAKERNEL_PHI_DTYPE=bfloat16`` halves the resident footprint
    (doubling the graph size :func:`megakernel_fits` admits); accumulation
    stays f32 regardless.  Re-read per call like every other knob.
    """
    val = os.environ.get("REPRO_MEGAKERNEL_PHI_DTYPE", "float32")
    if val not in ("float32", "bfloat16"):
        raise ValueError(
            f"REPRO_MEGAKERNEL_PHI_DTYPE must be 'float32' or 'bfloat16', "
            f"got {val!r}")
    return val


def _round_up(n: int, mult: int = 128) -> int:
    return ((n + mult - 1) // mult) * mult


def megakernel_fits(n_sessions: int, n_bar: int, itemsize: int = 4) -> bool:
    """VMEM residency check for the fused control step (DESIGN.md §17.2).

    The kernel keeps φ [W, N̄p, N̄p] resident at ``itemsize`` bytes (4 for
    f32 storage, 2 for bf16) plus f32 working state: flows t [W, N̄p],
    link flows F [N̄p, N̄p], marginal prices D′ [N̄p, N̄p], and O(W)
    gradient/allocation vectors.  All sizes use the 128-padded node count
    the kernel actually allocates.
    """
    n_pad = _round_up(max(int(n_bar), 1))
    w = max(int(n_sessions), 1)
    phi_bytes = w * n_pad * n_pad * itemsize
    work_bytes = (2 * n_pad * n_pad + 2 * w * n_pad + 8 * w) * 4
    return phi_bytes + work_bytes <= MEGAKERNEL_VMEM_BUDGET


def use_megakernel(n_bar: int, n_sessions: int, itemsize: int = 4) -> bool:
    """True when the fused control step should replace the stitched sweep.

    Conditions: n̄ clears :func:`megakernel_threshold`; the resident state
    passes :func:`megakernel_fits`; and either a real TPU backend or an
    explicit opt-in (env var / setter / ``megakernel_dispatch``) — same
    interpret-mode policy as :func:`use_kernels`.
    """
    if n_bar < megakernel_threshold():
        return False
    if not megakernel_fits(n_sessions, n_bar, itemsize):
        return False
    return _megakernel_explicit() or jax.default_backend() == "tpu"


# --------------------------------------------------------------------------
# fleet-mesh axis (DESIGN.md §14): which device mesh, if any, the caller is
# tracing sharded fleet solves against.  Part of :func:`state_key` so cached
# jitted consumers never alias executables across mesh shapes (an 8-way
# shard_map program is a different executable from the 1-device one even
# when every pytree shape matches).
# --------------------------------------------------------------------------

_fleet_key: tuple | None = None


def mesh_fingerprint(mesh) -> tuple:
    """Hashable identity of a mesh: axis names, shape, and device ids."""
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(int(d.id) for d in mesh.devices.flat))


def fleet_key() -> tuple | None:
    """The active fleet-mesh fingerprint (``None`` = unsharded vmap path)."""
    return _fleet_key


@contextlib.contextmanager
def fleet_dispatch(mesh):
    """Mark ``mesh`` as the active fleet mesh while tracing.

    ``run_batch_sharded`` / ``run_scenario(mesh=...)`` enter this around
    their shard_map construction so every cache keyed on
    :func:`state_key` (``fused_step``, the scenario segment solver)
    distinguishes mesh shapes instead of replaying a stale trace.
    """
    global _fleet_key
    prev = _fleet_key
    _fleet_key = None if mesh is None else mesh_fingerprint(mesh)
    try:
        yield
    finally:
        _fleet_key = prev


def state_key() -> tuple:
    """Hashable snapshot of the dispatch configuration.

    Callers that *cache jitted functions* (e.g. ``allocation.
    fused_control_step``) must key their cache on this so that tracing
    under ``kernel_dispatch``/``set_kernel_threshold`` gets a fresh trace
    instead of silently reusing a cached jnp-path executable (see the
    module docstring's trace-time caveat).  Includes the sparse policy
    (a router tracing under ``sparse_dispatch`` must not reuse a dense
    trace), the megakernel policy, and the fleet mesh (an executable
    traced for an 8-way ``shard_map`` must not alias the 1-device or vmap
    one).  Every component re-reads its env knob, so mutating
    ``os.environ`` after import changes the key — and with it every
    downstream lru cache entry — on the next call.
    """
    return (kernel_threshold(), _kernel_explicit(),
            sparse_threshold(), sparse_density_max(),
            megakernel_threshold(), _megakernel_explicit(),
            megakernel_phi_dtype(), _fleet_key)


def use_kernels(n_bar: int) -> bool:
    """True when a graph of ``n_bar`` augmented nodes should use kernels.

    Requires clearing the threshold and either a real TPU backend or an
    explicit threshold override (interpret mode is a validation tool, not
    a production fallback — it is far slower than the jnp path).
    """
    if n_bar < kernel_threshold():
        return False
    return _kernel_explicit() or jax.default_backend() == "tpu"


def kernel_interpret() -> bool:
    """Pallas ``interpret`` mode everywhere except real TPU backends."""
    return jax.default_backend() != "tpu"
