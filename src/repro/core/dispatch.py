"""Size-based kernel dispatch for the control-plane hot path (DESIGN.md §9.2).

The jnp einsum implementations in ``core/flow.py`` / ``core/routing.py`` are
the right tool for paper-scale graphs (n̄ ≲ a few hundred): XLA fuses them
and padding to the TPU's 128-lane blocks would only waste work.  At fleet
scale (n̄ = 10³–10⁵) the same steps are served by the Pallas kernels in
``kernels/``: when :func:`use_kernels` says so, ``flow.propagate`` routes
each relaxation step through ``kernels.flow_step`` and ``routing.omd_step``
routes the exponentiated-gradient update through ``kernels.omd_update``.
Operand padding to the 128-block constraint (and slicing back) is handled
by ``kernels/ops.py``.

Dispatch policy: the graph must clear the node-count threshold
(:func:`kernel_threshold`, default 256), **and** the backend must be a real
TPU — *or* the threshold must have been set explicitly (the
``REPRO_KERNEL_NBAR_THRESHOLD`` environment variable,
:func:`set_kernel_threshold`, or the :func:`kernel_dispatch` context
manager).  Off-TPU the kernels run in Pallas ``interpret`` mode, which is
orders of magnitude slower than the fused einsums — correct for validating
the kernel path everywhere (tests and benchmarks opt in via
``kernel_dispatch``), wrong as a silent default for a large graph on CPU.

The dispatch decision is made at **trace time** against the *static*
``CECGraph.n_bar`` metadata, so both branches stay jit/vmap compatible and
no control flow enters the compiled program.  The flip side: a function
that was already jit-compiled keeps the branch it was traced with —
``kernel_dispatch`` / ``set_kernel_threshold`` only affect functions traced
while the override is active, and are silent no-ops for cached traces.
Trace (or re-jit) inside the override when you need the kernel path.
"""
from __future__ import annotations

import contextlib
import os

import jax

DEFAULT_THRESHOLD = int(os.environ.get("REPRO_KERNEL_NBAR_THRESHOLD", "256"))

_threshold = DEFAULT_THRESHOLD
# Explicit configuration (env var / setter / context manager) opts in to the
# interpret-mode kernel path off-TPU; by default kernels need real TPUs.
_explicit = "REPRO_KERNEL_NBAR_THRESHOLD" in os.environ


def kernel_threshold() -> int:
    """Augmented node count n̄ at which the Pallas path takes over."""
    return _threshold


def set_kernel_threshold(n: int | None) -> None:
    """Set the dispatch threshold explicitly; ``None`` restores defaults.

    An explicit threshold also enables the kernel path off-TPU (interpret
    mode).  Only affects functions traced after the call (see module
    docstring).
    """
    global _threshold, _explicit
    if n is None:
        _threshold = DEFAULT_THRESHOLD
        _explicit = "REPRO_KERNEL_NBAR_THRESHOLD" in os.environ
    else:
        _threshold = int(n)
        _explicit = True


@contextlib.contextmanager
def kernel_dispatch(threshold: int):
    """Temporarily force the dispatch threshold (tests/benchmarks).

    ``with kernel_dispatch(1): ...`` sends every flow/OMD step traced
    inside the block through the Pallas kernels regardless of graph size
    or backend (interpret mode off-TPU).  Functions jit-compiled *before*
    entering the block keep their cached jnp-path trace.
    """
    global _threshold, _explicit
    prev = (_threshold, _explicit)
    _threshold, _explicit = int(threshold), True
    try:
        yield
    finally:
        _threshold, _explicit = prev


def state_key() -> tuple[int, bool]:
    """Hashable snapshot of the dispatch configuration.

    Callers that *cache jitted functions* (e.g. ``allocation.
    fused_control_step``) must key their cache on this so that tracing
    under ``kernel_dispatch``/``set_kernel_threshold`` gets a fresh trace
    instead of silently reusing a cached jnp-path executable (see the
    module docstring's trace-time caveat).
    """
    return (_threshold, _explicit)


def use_kernels(n_bar: int) -> bool:
    """True when a graph of ``n_bar`` augmented nodes should use kernels.

    Requires clearing the threshold and either a real TPU backend or an
    explicit threshold override (interpret mode is a validation tool, not
    a production fallback — it is far slower than the jnp path).
    """
    if n_bar < _threshold:
        return False
    return _explicit or jax.default_backend() == "tpu"


def kernel_interpret() -> bool:
    """Pallas ``interpret`` mode everywhere except real TPU backends."""
    return jax.default_backend() != "tpu"
