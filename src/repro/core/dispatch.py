"""Size-based kernel dispatch for the control-plane hot path (DESIGN.md §9.2).

The jnp einsum implementations in ``core/flow.py`` / ``core/routing.py`` are
the right tool for paper-scale graphs (n̄ ≲ a few hundred): XLA fuses them
and padding to the TPU's 128-lane blocks would only waste work.  At fleet
scale (n̄ = 10³–10⁵) the same steps are served by the Pallas kernels in
``kernels/``: when :func:`use_kernels` says so, ``flow.propagate`` routes
each relaxation step through ``kernels.flow_step`` and ``routing.omd_step``
routes the exponentiated-gradient update through ``kernels.omd_update``.
Operand padding to the 128-block constraint (and slicing back) is handled
by ``kernels/ops.py``.

Dispatch policy: the graph must clear the node-count threshold
(:func:`kernel_threshold`, default 256), **and** the backend must be a real
TPU — *or* the threshold must have been set explicitly (the
``REPRO_KERNEL_NBAR_THRESHOLD`` environment variable,
:func:`set_kernel_threshold`, or the :func:`kernel_dispatch` context
manager).  Off-TPU the kernels run in Pallas ``interpret`` mode, which is
orders of magnitude slower than the fused einsums — correct for validating
the kernel path everywhere (tests and benchmarks opt in via
``kernel_dispatch``), wrong as a silent default for a large graph on CPU.

The dispatch decision is made at **trace time** against the *static*
``CECGraph.n_bar`` metadata, so both branches stay jit/vmap compatible and
no control flow enters the compiled program.  The flip side: a function
that was already jit-compiled keeps the branch it was traced with —
``kernel_dispatch`` / ``set_kernel_threshold`` only affect functions traced
while the override is active, and are silent no-ops for cached traces.
Trace (or re-jit) inside the override when you need the kernel path.

A second, orthogonal axis is the **representation** (DESIGN.md §12): past
:func:`use_sparse`'s (N, density) policy, :func:`maybe_sparsify` converts
a dense ``CECGraph`` to the O(E) ``CECGraphSparse`` edge-list layout at
the solver core's single conversion point (``Problem.canonical``,
core/problem.py — every entry point routes through it) and at the raw
routing oracle ``solve_routing``.  Conversion is Python-level only — tracer inputs pass
through untouched — and :func:`state_key` covers both axes so cached
jitted control steps retrace under either override.
"""
from __future__ import annotations

import contextlib
import os

import jax
import numpy as np

DEFAULT_THRESHOLD = int(os.environ.get("REPRO_KERNEL_NBAR_THRESHOLD", "256"))

_threshold = DEFAULT_THRESHOLD
# Explicit configuration (env var / setter / context manager) opts in to the
# interpret-mode kernel path off-TPU; by default kernels need real TPUs.
_explicit = "REPRO_KERNEL_NBAR_THRESHOLD" in os.environ

# Dense-vs-sparse representation policy (DESIGN.md §12.2): a graph whose
# augmented node count clears REPRO_SPARSE_NBAR_THRESHOLD *and* whose union
# edge density is at most REPRO_SPARSE_DENSITY_MAX is converted to the
# edge-list representation by :func:`maybe_sparsify`.  Unlike the kernel
# threshold there is no backend condition — the sparse jnp path beats the
# dense einsums on every backend once the graph is big and sparse enough.
SPARSE_DEFAULT_THRESHOLD = int(
    os.environ.get("REPRO_SPARSE_NBAR_THRESHOLD", "512"))
SPARSE_DEFAULT_DENSITY = float(
    os.environ.get("REPRO_SPARSE_DENSITY_MAX", "0.15"))

_sparse_threshold = SPARSE_DEFAULT_THRESHOLD
_sparse_density = SPARSE_DEFAULT_DENSITY


def kernel_threshold() -> int:
    """Augmented node count n̄ at which the Pallas path takes over."""
    return _threshold


def set_kernel_threshold(n: int | None) -> None:
    """Set the dispatch threshold explicitly; ``None`` restores defaults.

    An explicit threshold also enables the kernel path off-TPU (interpret
    mode).  Only affects functions traced after the call (see module
    docstring).
    """
    global _threshold, _explicit
    if n is None:
        _threshold = DEFAULT_THRESHOLD
        _explicit = "REPRO_KERNEL_NBAR_THRESHOLD" in os.environ
    else:
        _threshold = int(n)
        _explicit = True


@contextlib.contextmanager
def kernel_dispatch(threshold: int):
    """Temporarily force the dispatch threshold (tests/benchmarks).

    ``with kernel_dispatch(1): ...`` sends every flow/OMD step traced
    inside the block through the Pallas kernels regardless of graph size
    or backend (interpret mode off-TPU).  Functions jit-compiled *before*
    entering the block keep their cached jnp-path trace.
    """
    global _threshold, _explicit
    prev = (_threshold, _explicit)
    _threshold, _explicit = int(threshold), True
    try:
        yield
    finally:
        _threshold, _explicit = prev


def sparse_threshold() -> int:
    """Augmented node count n̄ at which sparsification is considered."""
    return _sparse_threshold


def sparse_density_max() -> float:
    """Union edge density |Ē|/n̄² at or below which sparsification engages."""
    return _sparse_density


def set_sparse_threshold(n: int | None, density_max: float | None = None):
    """Set the sparse-representation policy; ``None`` n restores defaults."""
    global _sparse_threshold, _sparse_density
    if n is None:
        _sparse_threshold = SPARSE_DEFAULT_THRESHOLD
        _sparse_density = SPARSE_DEFAULT_DENSITY
    else:
        _sparse_threshold = int(n)
        if density_max is not None:
            _sparse_density = float(density_max)


@contextlib.contextmanager
def sparse_dispatch(threshold: int, density_max: float = 1.0):
    """Temporarily force the sparse policy (tests/benchmarks).

    ``with sparse_dispatch(1): ...`` sparsifies every dense graph reaching
    :func:`maybe_sparsify` inside the block regardless of size or density.
    Like ``kernel_dispatch`` this only affects *conversion points* entered
    inside the block; already-converted or already-traced state keeps its
    representation.
    """
    global _sparse_threshold, _sparse_density
    prev = (_sparse_threshold, _sparse_density)
    _sparse_threshold, _sparse_density = int(threshold), float(density_max)
    try:
        yield
    finally:
        _sparse_threshold, _sparse_density = prev


def use_sparse(n_bar: int, density: float) -> bool:
    """True when a graph of ``n_bar`` nodes / ``density`` should go sparse."""
    return n_bar >= _sparse_threshold and density <= _sparse_density


def maybe_sparsify(graph, *companions):
    """Convert a dense ``CECGraph`` to ``CECGraphSparse`` past the policy.

    The conversion builds numpy edge lists, so it only happens at the
    Python level: if the graph's leaves or any ``companion`` array (e.g. a
    caller's φ⁰ that would need re-layout) is a tracer, the graph is
    returned unchanged — inside jit/vmap/scan the representation is
    whatever the caller traced with.  Sparse graphs and sub-threshold
    dense graphs pass through untouched.
    """
    from .graph import CECGraph, sparsify

    if not isinstance(graph, CECGraph):
        return graph
    if graph.n_bar < _sparse_threshold:      # cheap static reject first —
        return graph                         # no device→host mask transfer
    if any(isinstance(x, jax.core.Tracer)
           for x in (graph.edge_mask, *companions) if x is not None):
        return graph
    density = float(np.asarray(graph.edge_mask).sum()) / graph.n_bar ** 2
    if not use_sparse(graph.n_bar, density):
        return graph
    return sparsify(graph)


# --------------------------------------------------------------------------
# fleet-mesh axis (DESIGN.md §14): which device mesh, if any, the caller is
# tracing sharded fleet solves against.  Part of :func:`state_key` so cached
# jitted consumers never alias executables across mesh shapes (an 8-way
# shard_map program is a different executable from the 1-device one even
# when every pytree shape matches).
# --------------------------------------------------------------------------

_fleet_key: tuple | None = None


def mesh_fingerprint(mesh) -> tuple:
    """Hashable identity of a mesh: axis names, shape, and device ids."""
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(int(d.id) for d in mesh.devices.flat))


def fleet_key() -> tuple | None:
    """The active fleet-mesh fingerprint (``None`` = unsharded vmap path)."""
    return _fleet_key


@contextlib.contextmanager
def fleet_dispatch(mesh):
    """Mark ``mesh`` as the active fleet mesh while tracing.

    ``run_batch_sharded`` / ``run_scenario(mesh=...)`` enter this around
    their shard_map construction so every cache keyed on
    :func:`state_key` (``fused_step``, the scenario segment solver)
    distinguishes mesh shapes instead of replaying a stale trace.
    """
    global _fleet_key
    prev = _fleet_key
    _fleet_key = None if mesh is None else mesh_fingerprint(mesh)
    try:
        yield
    finally:
        _fleet_key = prev


def state_key() -> tuple:
    """Hashable snapshot of the dispatch configuration.

    Callers that *cache jitted functions* (e.g. ``allocation.
    fused_control_step``) must key their cache on this so that tracing
    under ``kernel_dispatch``/``set_kernel_threshold`` gets a fresh trace
    instead of silently reusing a cached jnp-path executable (see the
    module docstring's trace-time caveat).  Includes the sparse policy
    (a router tracing under ``sparse_dispatch`` must not reuse a dense
    trace) and the fleet mesh (an executable traced for an 8-way
    ``shard_map`` must not alias the 1-device or vmap one).
    """
    return (_threshold, _explicit, _sparse_threshold, _sparse_density,
            _fleet_key)


def use_kernels(n_bar: int) -> bool:
    """True when a graph of ``n_bar`` augmented nodes should use kernels.

    Requires clearing the threshold and either a real TPU backend or an
    explicit threshold override (interpret mode is a validation tool, not
    a production fallback — it is far slower than the jnp path).
    """
    if n_bar < _threshold:
        return False
    return _explicit or jax.default_backend() == "tpu"


def kernel_interpret() -> bool:
    """Pallas ``interpret`` mode everywhere except real TPU backends."""
    return jax.default_backend() != "tpu"
