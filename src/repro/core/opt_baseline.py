"""Centralized baselines (paper §IV "OPT").

* ``frank_wolfe_routing`` — global optimum of the routing problem 𝒫2 by
  Frank–Wolfe in session-flow space: the linear subproblem is a shortest
  path per session w.r.t. the current marginal link costs (classic convex
  traffic assignment), the step is an exact 1-D bisection line search.
  This plays the paper's "OPT: centralized convex solver" role and is an
  *independent* method used to validate OMD-RT's optimum.

* ``exact_gradient_allocation`` — the allocation optimum computed with the
  *true* utility gradient ∂U/∂λ_w = u'_w(λ_w) − ∂D/∂r_S(w) (Theorem 1):
  what a genie with known utilities would do.  Used as the U* reference
  line for Fig. 10/11 reproductions.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .costs import CostFn
from .flow import cost_and_state, propagate
from .graph import CECGraph
from .marginal import marginals
from .routing import solve_routing
from .utility import UtilityBank


def _topo_order(edge_mask: np.ndarray) -> list[int]:
    n = edge_mask.shape[0]
    indeg = (edge_mask > 0).sum(0)
    stack = [i for i in range(n) if indeg[i] == 0]
    order = []
    while stack:
        i = stack.pop()
        order.append(i)
        for j in np.nonzero(edge_mask[i])[0]:
            indeg[j] -= 1
            if indeg[j] == 0:
                stack.append(int(j))
    assert len(order) == n, "graph has a cycle"
    return order


def _shortest_path_flow(graph, out_mask_w: np.ndarray, weights: np.ndarray,
                        order: list[int], src: int, sink: int,
                        rate: float) -> np.ndarray:
    """All-or-nothing assignment of ``rate`` along the min-marginal path."""
    n = out_mask_w.shape[0]
    dist = np.full(n, np.inf)
    pred = np.full(n, -1)
    dist[src] = 0.0
    for i in order:
        if not np.isfinite(dist[i]):
            continue
        row = out_mask_w[i] > 0
        cand = dist[i] + weights[i]
        upd = row & (cand < dist)
        dist[upd] = cand[upd]
        pred[upd] = i
    assert np.isfinite(dist[sink]), "sink unreachable"
    f = np.zeros_like(weights)
    j = sink
    while j != src:
        i = int(pred[j])
        f[i, j] = rate
        j = i
    return f


def frank_wolfe_routing(graph: CECGraph, cost: CostFn, lam,
                        n_iters: int = 300) -> tuple[np.ndarray, float]:
    """Global routing optimum; returns (session flows f[W,Nb,Nb], cost D*)."""
    out_mask = np.asarray(graph.out_mask)
    edge_mask = np.asarray(graph.edge_mask)
    cap = np.asarray(graph.capacity)
    lam = np.asarray(lam, np.float64)
    order = _topo_order(edge_mask)
    sinks = np.asarray(graph.sinks)

    # feasible start: flows induced by the uniform routing variables
    phi0 = graph.uniform_phi()
    t0 = propagate(graph, phi0, jnp.asarray(lam, jnp.float32))
    f = np.asarray(t0[:, :, None] * phi0, np.float64)

    def dcost(F):
        return np.asarray(cost.deriv(jnp.asarray(F), jnp.asarray(cap))) * edge_mask

    def value(F):
        return float(jnp.sum(graph.edge_mask
                             * cost.value(jnp.asarray(F), jnp.asarray(cap))))

    for _ in range(n_iters):
        F = f.sum(0)
        m = dcost(F)
        s = np.stack([
            _shortest_path_flow(graph, out_mask[w], m, order, graph.src,
                                int(sinks[w]), float(lam[w]))
            for w in range(graph.n_sessions)
        ])
        d = s - f
        G = d.sum(0)
        # exact line search on the 1-D convex restriction
        def slope(gam):
            return float((dcost(F + gam * G) * G).sum())
        if slope(0.0) >= -1e-12:
            break
        if slope(1.0) <= 0.0:
            gam = 1.0
        else:
            lo, hi = 0.0, 1.0
            for _ in range(40):
                mid = 0.5 * (lo + hi)
                if slope(mid) > 0:
                    hi = mid
                else:
                    lo = mid
            gam = 0.5 * (lo + hi)
        f = f + gam * d
    return f, value(f.sum(0))


def exact_gradient_allocation(
    graph: CECGraph, cost: CostFn, bank: UtilityBank, lam_total: float,
    *, eta: float = 0.05, outer_iters: int = 300, inner_iters: int = 100,
    eta_inner: float = 0.05,
) -> tuple[jnp.ndarray, jnp.ndarray, float]:
    """Genie allocation via true gradients; returns (Λ*, φ*, U*)."""
    W = graph.n_sessions
    lam = jnp.full((W,), lam_total / W)
    phi = graph.uniform_phi()
    du_fn = jax.grad(lambda l: bank.per_session(l).sum())

    @jax.jit
    def step(lam, phi):
        phi, _ = solve_routing(graph, cost, lam, phi, eta_inner, inner_iters)
        D, t, F = cost_and_state(graph, cost, phi, lam)
        _, dDdr = marginals(graph, cost, phi, t, F)
        g = du_fn(lam) - dDdr[:, graph.src]          # Theorem 1 gradient
        z = eta * (g - g.max())
        w = lam * jnp.exp(z)
        lam = lam_total * w / w.sum()
        U = bank.total(lam) - D
        return lam, phi, U

    U = jnp.asarray(0.0)
    for _ in range(outer_iters):
        lam, phi, U = step(lam, phi)
    return lam, phi, float(U)
