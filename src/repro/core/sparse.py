"""Sparse edge-list execution path for the solver stack (DESIGN.md §12).

Every hot-path quantity of the dense solver — flow propagation (paper
eq. (1)/(2)), link flows (eq. (4)), total cost, the marginal-cost
broadcast (eq. (19)–(21)) and the exponentiated-gradient routing update
(eq. (22)) — re-expressed over a :class:`~repro.core.graph.CECGraphSparse`
padded edge-list layout, O(E) state and FLOPs instead of O(N̄²).

The formulation is gather-only in the relaxation loop (TPU-friendly —
no scatters inside the scan):

* per-step relay inflow is a CSC gather + row sum
  (``t[:, in_src] · φ[:, in_src, in_slot]``);
* the virtual source's contribution is **constant across relaxation
  steps** (S has no in-edges, so t_S(w) ≡ λ_w) and is scattered once per
  ``propagate`` into the injection vector (:func:`source_inflow`);
* sink inflow — the one true hub of the augmented graph (in-degree
  Θ(N/W)) — is accumulated analytically as W masked reductions over the
  compute-edge slots, never via padded in-lists.

``core.flow`` / ``core.marginal`` / ``core.routing`` dispatch here on the
graph type, so ``solve_routing``, ``gs_oma``/``omad``, the vmapped batch
solvers and ``CECRouter`` run either representation transparently; when
``core.dispatch.use_kernels`` holds, the inner steps route through the
segment Pallas kernels ``kernels.flow_step_sparse`` /
``kernels.omd_update_sparse`` (interpret mode off-TPU).  Dense↔sparse
parity is property-tested to 1e-5 in ``tests/test_sparse_parity.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import dispatch
from .costs import CostFn
from .graph import CECGraph, CECGraphSparse, SparsePhi

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# φ layout conversions
# ---------------------------------------------------------------------------

def phi_to_sparse(graph: CECGraphSparse, phi: Array) -> SparsePhi:
    """Gather a dense [W, Nb, Nb] routing tensor into the slot layout."""
    W = graph.n_sessions
    idx = jnp.broadcast_to(graph.nbr[None], (W,) + graph.nbr.shape)
    rows = jnp.take_along_axis(phi, idx, axis=2) * graph.out_mask
    src = phi[:, graph.src, graph.src_nbr] * graph.src_out_mask
    return SparsePhi(rows=rows, src=src)


def remap_phi(old: CECGraphSparse, new: CECGraphSparse,
              phi: SparsePhi) -> SparsePhi:
    """Re-express a :class:`SparsePhi` on another graph's slot layout.

    Matches slots by **edge identity** (tail, head), not position: after
    churn the CSR packing can shift even when ``d_max`` is unchanged, so
    positional reuse would silently hand edge (a→b)'s mass to (a→e).
    Edges absent from ``old`` start at zero — exactly what
    ``warm_start_phi``'s exploration mix expects to revive.  Python-level
    numpy (it runs at topology-change time, never inside a trace); both
    graphs must share the augmented index space (``n_bar``).
    """
    if old.n_bar != new.n_bar:
        raise ValueError(f"index spaces differ: {old.n_bar} != {new.n_bar}")

    def match(old_nbr, old_vals, old_mask, new_nbr, new_mask):
        # hit[..., d_new, d_old] — same head ⇒ same edge (rows share tails)
        hit = (np.asarray(new_nbr)[..., :, None]
               == np.asarray(old_nbr)[..., None, :])
        hit &= (np.asarray(old_mask) > 0)[..., None, :]
        hit &= (np.asarray(new_mask) > 0)[..., None]
        found = hit.any(-1)
        slot = hit.argmax(-1)
        vals = np.take_along_axis(np.asarray(old_vals), slot[None], -1)
        return jnp.asarray(np.where(found[None], vals, 0.0))

    rows = match(old.nbr, phi.rows, old.edge_mask, new.nbr, new.edge_mask)
    src = match(old.src_nbr, phi.src, old.src_edge_mask,
                new.src_nbr, new.src_edge_mask)
    return SparsePhi(rows=rows * new.out_mask, src=src * new.src_out_mask)


def phi_to_dense(graph: CECGraphSparse, phi: SparsePhi) -> Array:
    """Scatter a :class:`SparsePhi` back to the dense [W, Nb, Nb] layout."""
    W, n_bar = graph.n_sessions, graph.n_bar
    out = jnp.zeros((W, n_bar, n_bar), phi.rows.dtype)
    rows_i = jnp.broadcast_to(jnp.arange(n_bar)[:, None], graph.nbr.shape)
    out = out.at[:, rows_i, graph.nbr].add(phi.rows * graph.out_mask)
    return out.at[:, graph.src, graph.src_nbr].add(phi.src * graph.src_out_mask)


# ---------------------------------------------------------------------------
# flow propagation (paper eq. (1)/(2)) and cost
# ---------------------------------------------------------------------------

def source_inflow(graph: CECGraphSparse, phi: SparsePhi, lam: Array) -> Array:
    """[W, Nb] per-step constant inflow: exogenous injection at S plus the
    admission flow λ_w·φ_S over the S→D(1) fan-out (t_S(w) ≡ λ_w)."""
    admit = lam[:, None] * phi.src * graph.src_out_mask
    return graph.injection(lam).at[:, graph.src_nbr].add(admit)


def _relay_inflow(graph: CECGraphSparse, rows: Array, t: Array) -> Array:
    """[W, Nb] physical relay inflow: CSC gather + row sum (jnp path)."""
    tv = t[:, graph.in_src]                          # [W, Nb, Din]
    pv = rows[:, graph.in_src, graph.in_slot]        # [W, Nb, Din]
    return (tv * pv * graph.in_mask).sum(-1)


def _sink_inflow(graph: CECGraphSparse, rows: Array, t: Array) -> Array:
    """[W] compute-edge inflow per sink: Σ_{i∈D(w)} t_i(w)·φ_{i,D_w}."""
    tphys = t[:, : graph.n_phys]
    psink = jnp.take_along_axis(
        rows[:, : graph.n_phys], graph.sink_slot[None, :, None], axis=2)[..., 0]
    return (graph.deploy * tphys * psink).sum(-1)


def propagate(graph: CECGraphSparse, phi: SparsePhi, lam: Array) -> Array:
    """Session rates t[W, Nb]: ``depth_max`` Jacobi steps over edge lists.

    Bit-for-bit the dense recursion re-associated over slots: each step is
    ``t' = base + relay_gather(t)`` with the W sink entries overlaid from
    :func:`_sink_inflow` (old ``t``, Jacobi semantics).  Size-dispatched
    like the dense path: past ``dispatch.use_kernels(n_bar)`` the gather
    step runs the Pallas ``flow_step_sparse`` kernel.
    """
    inject = graph.injection(lam)
    base = source_inflow(graph, phi, lam)
    wi, sinks = jnp.arange(graph.n_sessions), graph.sinks

    if dispatch.use_kernels(graph.n_bar):
        from repro.kernels.ops import flow_step_sparse_op

        interpret = dispatch.kernel_interpret()

        def relay(t):
            return flow_step_sparse_op(t, phi.rows, base, graph.in_src,
                                       graph.in_slot, graph.in_mask,
                                       interpret=interpret)
    else:
        def relay(t):
            return base + _relay_inflow(graph, phi.rows, t)

    def step(t, _):
        t_new = relay(t).at[wi, sinks].set(_sink_inflow(graph, phi.rows, t))
        return t_new, None

    t, _ = jax.lax.scan(step, inject, None, length=graph.depth_max)
    return t


def link_flow_slots(graph: CECGraphSparse, phi: SparsePhi,
                    t: Array) -> SparsePhi:
    """Per-edge total flow F (eq. (4)) in the slot layout."""
    rows = jnp.einsum("wi,wid->id", t, phi.rows)
    src = jnp.einsum("w,wd->d", t[:, graph.src], phi.src)
    return SparsePhi(rows=rows, src=src)


def total_cost(graph: CECGraphSparse, cost: CostFn, phi: SparsePhi,
               lam: Array) -> Array:
    """Σ_{e∈Ē} D_e(F_e, C_e) — identical edge set to the dense sum."""
    t = propagate(graph, phi, lam)
    F = link_flow_slots(graph, phi, t)
    return (jnp.sum(graph.edge_mask * cost.value(F.rows, graph.capacity))
            + jnp.sum(graph.src_edge_mask
                      * cost.value(F.src, graph.src_capacity)))


def cost_and_state(graph: CECGraphSparse, cost: CostFn, phi: SparsePhi,
                   lam: Array):
    """(total cost, t, F-slots) in one pass — the routing-iteration bundle."""
    t = propagate(graph, phi, lam)
    F = link_flow_slots(graph, phi, t)
    D = (jnp.sum(graph.edge_mask * cost.value(F.rows, graph.capacity))
         + jnp.sum(graph.src_edge_mask * cost.value(F.src,
                                                    graph.src_capacity)))
    return D, t, F


# ---------------------------------------------------------------------------
# marginal-cost broadcast (paper eq. (19)–(21))
# ---------------------------------------------------------------------------

def marginals(graph: CECGraphSparse, cost: CostFn, phi: SparsePhi, t: Array,
              F: SparsePhi) -> tuple[SparsePhi, Array]:
    """Returns (delta, dDdr) — Gallager's reverse recursion over edge lists.

    ``delta`` is the marginal routing cost δφ (eq. 19) in the slot layout;
    ``dDdr[w, i]`` the broadcast scalar ∂D/∂r_i(w) (eq. 21), covering the
    virtual source row (its own slot set) exactly like the dense scan.
    """
    Dp = graph.edge_mask * cost.deriv(F.rows, graph.capacity)      # [Nb, D]
    Dp_src = graph.src_edge_mask * cost.deriv(F.src, graph.src_capacity)
    mask = graph.out_mask

    def step(r, _):
        nxt = (phi.rows * mask * (Dp[None] + r[:, graph.nbr])).sum(-1)
        r_src = (phi.src * graph.src_out_mask
                 * (Dp_src[None] + r[:, graph.src_nbr])).sum(-1)
        return nxt.at[:, graph.src].set(r_src), None

    zero = jnp.zeros_like(t)
    dDdr, _ = jax.lax.scan(step, zero, None, length=graph.depth_max)
    delta = SparsePhi(
        rows=mask * (Dp[None] + dDdr[:, graph.nbr]),
        src=graph.src_out_mask * (Dp_src[None] + dDdr[:, graph.src_nbr]))
    return delta, dDdr


# ---------------------------------------------------------------------------
# exponentiated-gradient update (eq. (22)) + optimality residual
# ---------------------------------------------------------------------------

def eg_update(phi: Array, delta: Array, mask: Array, eta: float) -> Array:
    """Row-stabilized exponentiated-gradient step on the last axis.

    Shape-generic (the row is whatever the trailing axis holds), so the
    dense [W, Nb, Nb] path (``routing.omd_step``), the sparse [W, Nb, D]
    rows and the [W, Ds] source row all share this one jnp definition.
    ``kernels/ref.py::omd_update_ref`` keeps an intentionally independent
    copy — it is the oracle the Pallas kernels are tested against, and an
    oracle that delegates to the code under test verifies nothing.
    """
    logits = jnp.where(mask > 0, -eta * delta, -1e30)
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    w = phi * jnp.exp(logits) * mask
    s = w.sum(-1, keepdims=True)
    return jnp.where(s > 0, w / jnp.where(s > 0, s, 1.0), phi)


def omd_phi_update(graph: CECGraphSparse, phi: SparsePhi, delta: SparsePhi,
                   eta: float) -> SparsePhi:
    """Apply eq. (22) to both φ parts (kernel-dispatched like the dense path)."""
    if dispatch.use_kernels(graph.n_bar):
        from repro.kernels.ops import omd_update_sparse_op

        interpret = dispatch.kernel_interpret()
        rows = omd_update_sparse_op(phi.rows, delta.rows, graph.out_mask,
                                    float(eta), interpret=interpret)
        src = omd_update_sparse_op(phi.src[:, None], delta.src[:, None],
                                   graph.src_out_mask[:, None], float(eta),
                                   interpret=interpret)[:, 0]
        return SparsePhi(rows=rows, src=src)
    return SparsePhi(
        rows=eg_update(phi.rows, delta.rows, graph.out_mask, eta),
        src=eg_update(phi.src, delta.src, graph.src_out_mask, eta))


def kkt_residual(graph: CECGraphSparse, cost: CostFn, phi: SparsePhi,
                 lam: Array) -> Array:
    """Theorem 3 residual in the slot layout (mirrors the dense metric)."""
    D, t, F = cost_and_state(graph, cost, phi, lam)
    delta, _ = marginals(graph, cost, phi, t, F)

    def row_residual(d, p, m, tt):
        on = (p > 1e-6) & (m > 0)
        big = jnp.where(on, d, -jnp.inf).max(-1)
        small = jnp.where(m > 0, d, jnp.inf).min(-1)
        active = (tt > 1e-6) & (m.sum(-1) > 0)
        return jnp.where(active, jnp.maximum(big - small, 0.0), 0.0).max()

    r_rows = row_residual(delta.rows, phi.rows, graph.out_mask, t)
    r_src = row_residual(delta.src, phi.src, graph.src_out_mask,
                         t[:, graph.src])
    return jnp.maximum(r_rows, r_src)


def state_nbytes(graph: CECGraphSparse | CECGraph, phi) -> int:
    """Total bytes of the graph + routing-state pytree (bench_sparse)."""
    leaves = jax.tree_util.tree_leaves((graph, phi))
    return int(sum(x.size * x.dtype.itemsize for x in leaves))
