"""Marginal-cost broadcast (paper eq. (19)–(21), Gallager's recursion).

∂D/∂r_j(w) — the marginal network cost of one extra unit of session-w traffic
arriving at node j — satisfies the reverse recursion

    ∂D/∂r_{D_w} = 0
    ∂D/∂r_i(w)  = Σ_j φ_ij(w) · [ D'_ij(F_ij) + ∂D/∂r_j(w) ]

In a deployment this is the hop-by-hop "marginal cost broadcast" protocol
(paper §III-B): each node piggybacks its scalar on traffic towards its
upstream neighbours.  Here the same recursion is a ``lax.scan`` on the
reversed DAG, exact after ``depth_max`` steps.  The full marginal routing
cost (eq. (19)) and the gradient w.r.t. φ (eq. (18)) follow elementwise.

``tests/test_core_flow.py`` property-checks this recursion against
``jax.grad`` through the forward propagation — the distributed protocol and
autodiff must agree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .costs import CostFn
from .graph import CECGraph, CECGraphSparse

Array = jnp.ndarray


def marginals(graph: CECGraph | CECGraphSparse, cost: CostFn, phi, t: Array,
              F) -> tuple:
    """Returns (delta, dDdr).

    delta[w,i,j] = D'_ij + ∂D/∂r_j(w)  — marginal routing cost (eq. 19)
    dDdr[w,i]    = ∂D/∂r_i(w)          — broadcast scalar    (eq. 21)

    Sparse graphs take the edge-list recursion (core/sparse.py): φ, F and
    the returned delta are then in the slot layout; dDdr is [W, Nb] in
    both representations.
    """
    if isinstance(graph, CECGraphSparse):
        from . import sparse

        return sparse.marginals(graph, cost, phi, t, F)
    Dp = graph.edge_mask * cost.deriv(F, graph.capacity)   # [Nb, Nb]
    mask = graph.out_mask

    def step(r, _):
        # r_i(w) = Σ_j φ_ij (Dp_ij + r_j);  sinks have no out-edges → stay 0
        nxt = jnp.einsum("wij,wij->wi", phi, mask * (Dp[None] + r[:, None, :]))
        return nxt, None

    zero = jnp.zeros_like(t)
    dDdr, _ = jax.lax.scan(step, zero, None, length=graph.depth_max)
    delta = mask * (Dp[None] + dDdr[:, None, :])
    return delta, dDdr


def phi_gradient(t: Array, delta: Array) -> Array:
    """∂D/∂φ_ij(w) = t_i(w) · δφ_ij(w) (paper eq. (18))."""
    return t[:, :, None] * delta
