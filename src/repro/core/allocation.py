"""Workload allocation under unknown utilities: GS-OMA (paper Alg. 1).

Outer loop over t: for each session w, the controller *admits* the perturbed
allocations Λ ± δ·e_w, lets the routing layer serve them (the oracle 𝔒 =
OMD-RT, Assumption 4), and observes the resulting scalar network utilities
U± — two-point gradient sampling (Flaxman et al.).  The estimated gradient
feeds an online mirror-ascent step on the scaled simplex {Σλ_w = λ}
(eq. (10)), followed by the box projection P_[δ,λ−δ].

The same engine with ``inner_iters=1`` *is* the single-loop OMAD algorithm
(Alg. 3): the routing iterate φ is carried across all oracle invocations and
improves by exactly one mirror-descent step per observation, never waiting
for inner convergence (see single_loop.py).

Everything scans under jit — T outer iterations × W sessions × 2 oracle
calls × K routing steps with zero Python in the loop.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .costs import CostFn
from .flow import total_cost
from .graph import CECGraph
from .routing import solve_routing
from .utility import UtilityBank

Array = jnp.ndarray


class JOWRResult(NamedTuple):
    lam: Array          # [W] final allocation Λ
    phi: Array          # [W, Nb, Nb] final routing
    utility_traj: Array  # [T] observed network utility U(Λ^t, φ^t)
    lam_traj: Array     # [T, W]


def _observe(graph: CECGraph, cost: CostFn, bank: UtilityBank, lam: Array,
             phi: Array, eta_inner: float, inner_iters: int):
    """Admit Λ, run the routing oracle, observe U = Σu_w − ΣD_ij."""
    phi, _ = solve_routing(graph, cost, lam, phi, eta_inner, inner_iters)
    U = bank.total(lam) - total_cost(graph, cost, phi, lam)
    return U, phi


def _project_box_simplex(lam: Array, lam_total: float, delta: float) -> Array:
    """P_[δ,λ−δ] (Alg. 1 line 9) then restore Σλ_w = λ (DESIGN.md §8.3).

    Last-axis semantics so stacked ``[B, W]`` iterates (the scenario
    engine's per-instance rows) project exactly like a single ``[W]``.
    """
    lam = jnp.clip(lam, delta, lam_total - delta)
    lam = lam * (lam_total / lam.sum(-1, keepdims=True))
    return jnp.clip(lam, delta, lam_total - delta)


def gs_oma(
    graph: CECGraph,
    cost: CostFn,
    bank: UtilityBank,
    lam_total: float,
    *,
    delta: float = 0.5,
    eta_outer: float = 0.05,
    eta_inner: float = 0.05,
    outer_iters: int = 100,
    inner_iters: int = 50,
    phi0: Array | None = None,
    lam0: Array | None = None,
) -> JOWRResult:
    """Nested-loop solver (Alg. 1); ``inner_iters=1`` gives OMAD (Alg. 3)."""
    W = graph.n_sessions
    lam0 = jnp.full((W,), lam_total / W) if lam0 is None else lam0
    phi0 = graph.uniform_phi() if phi0 is None else phi0
    eyes = jnp.eye(W)

    def outer(carry, _):
        lam, phi = carry

        def per_session(c, ew):
            grads, phi = c
            up, phi = _observe(graph, cost, bank, lam + delta * ew, phi,
                               eta_inner, inner_iters)
            um, phi = _observe(graph, cost, bank, lam - delta * ew, phi,
                               eta_inner, inner_iters)
            g = (up - um) / (2.0 * delta)            # Alg. 1 line 6
            return (grads + g * ew, phi), None

        (g, phi), _ = jax.lax.scan(per_session, (jnp.zeros(W), phi), eyes)
        # online mirror ascent on the scaled simplex (eq. (10))
        z = eta_outer * g
        z = z - z.max()
        w = lam * jnp.exp(z)
        lam_new = lam_total * w / w.sum()
        lam_new = _project_box_simplex(lam_new, lam_total, delta)
        U_t = bank.total(lam_new) - total_cost(graph, cost, phi, lam_new)
        return (lam_new, phi), (U_t, lam_new)

    (lam, phi), (u_traj, lam_traj) = jax.lax.scan(
        outer, (lam0, phi0), None, length=outer_iters)
    return JOWRResult(lam=lam, phi=phi, utility_traj=u_traj, lam_traj=lam_traj)


def allocation_kkt_residual(graph: CECGraph, cost: CostFn, bank: UtilityBank,
                            lam: Array, phi: Array) -> Array:
    """Theorem 1 check: ∂U/∂λ_w must be equal across sessions at Λ*.

    Uses the *exact* gradient ∂U/∂λ_w = u'_w(λ_w) − ∂D/∂r_S(w) (only
    available to tests/benchmarks — the algorithm itself never sees it).
    """
    from .flow import cost_and_state
    from .marginal import marginals

    du = jax.grad(lambda l: bank.per_session(l).sum())(lam)
    _, t, F = cost_and_state(graph, cost, phi, lam)
    _, dDdr = marginals(graph, cost, phi, t, F)
    g = du - dDdr[:, graph.src]
    return g.max() - g.min()
