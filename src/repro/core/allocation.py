"""Legacy GS-OMA entry points — thin shims over the solver core.

The fused control iteration (paper Alg. 1, and with K=1 Alg. 3) lives in
``core/solver.py`` as ``step``, scanned by ``run`` over a
``core/problem.Problem``; this module keeps the pre-redesign surface —
``gs_oma``, ``control_step``, ``fused_control_step``, ``JOWRResult`` /
``ControlStep`` — as keyword-compatible projections of that one engine.
Nothing here re-implements solver math: every function builds a
``Problem`` + ``SolverConfig`` and delegates (DESIGN.md §13 has the
old-call → new-call migration table).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import dispatch
from . import solver as _solver
from .costs import CostFn
from .graph import CECGraph
from .problem import Problem, resolve_cost
from .solver import SolverConfig, SolverState
# re-exported names that historically lived here (tests, benchmarks and
# the serving plane import them from this module)
from .solver import perturbed_allocations  # noqa: F401
from .solver import _perturbation_basis  # noqa: F401
from .solver import project_box_simplex as _project_box_simplex  # noqa: F401
from .utility import UtilityBank

Array = jnp.ndarray


class JOWRResult(NamedTuple):
    """The pre-redesign solve record (``solver.Result`` minus the state)."""

    lam: Array          # [W] final allocation Λ
    phi: Array          # [W, Nb, Nb] final routing
    utility_traj: Array  # [T] observed network utility U(Λ^t, φ^t)
    lam_traj: Array     # [T, W]

    @classmethod
    def from_result(cls, res: _solver.Result) -> "JOWRResult":
        return cls(lam=res.lam, phi=res.phi,
                   utility_traj=res.utility_traj, lam_traj=res.lam_traj)


class ControlStep(NamedTuple):
    """One fused outer iteration (Alg. 1/3 lines 4–9 + committed observe)."""

    lam: Array          # [W] committed allocation Λ^{t+1}
    phi: Array          # [W, Nb, Nb] routing after the committed observation
    grad: Array         # [W] two-point gradient estimate ĝ^t
    cost: Array         # scalar network cost D(Λ^{t+1}, φ^{t+1})
    t: Array            # scalar int32 — the *advanced* counter t+1; thread
    #                     it into the next call so t-dependent schedules
    #                     see real time instead of a frozen t=0


def control_step(
    graph: CECGraph,
    cost: CostFn,
    lam: Array,
    phi: Array,
    task_utilities: Array,
    *,
    lam_total,
    delta: float = 0.5,
    eta_outer: float = 0.05,
    eta_inner: float = 0.05,
    inner_iters: int = 1,
    t=0,
) -> ControlStep:
    """One fused outer iteration on explicit iterates (``solver.step``).

    Kept for callers that hold raw (Λ, φ) instead of a ``SolverState``;
    see :func:`repro.core.solver.step` for the semantics.  ``t`` is the
    outer-iteration counter: pass the previous call's ``ControlStep.t``
    (it defaults to 0 for the first call) so a legacy loop advances the
    counter exactly like ``solver.run``'s scan — earlier revisions reset
    it to 0 every call, silently freezing every t-dependent schedule.
    """
    config = SolverConfig.from_legacy(delta=delta, eta_outer=eta_outer,
                                      eta_inner=eta_inner,
                                      inner_iters=inner_iters)
    problem = Problem(graph=graph, bank=None, lam_total=lam_total, cost=cost)
    state = SolverState(lam=lam, phi=phi, t=jnp.asarray(t, jnp.int32))
    state, info = _solver.step(problem, config, state, task_utilities)
    return ControlStep(lam=state.lam, phi=state.phi, grad=info.grad,
                       cost=info.cost, t=state.t)


@functools.lru_cache(maxsize=None)
def _fused_control_step(cost_name: str, config: SolverConfig, _dispatch_key):
    cost = resolve_cost(cost_name)
    fused = _solver.fused_step(config)

    def fn(graph, lam, phi, task_utilities, lam_total, t=0):
        problem = Problem(graph=graph, bank=None, lam_total=lam_total,
                          cost=cost)
        state = SolverState(lam=lam, phi=phi, t=jnp.asarray(t, jnp.int32))
        state, info = fused(problem, state, task_utilities)
        return ControlStep(lam=state.lam, phi=state.phi, grad=info.grad,
                           cost=info.cost, t=state.t)

    return fn


def fused_control_step(cost_name: str, *, delta: float = 0.5,
                       eta_outer: float = 0.05, eta_inner: float = 0.05,
                       inner_iters: int = 1):
    """The jitted fused control step, cached on its static knobs.

    Legacy facade over :func:`repro.core.solver.fused_step` — returns
    ``fn(graph, lam, phi, task_utilities, lam_total, t=0) -> ControlStep``.
    ``graph`` is a pytree argument, so same-shape topology changes reuse
    the compiled executable, and ``lam_total`` is traced so demand shifts
    never retrace; the cache is keyed on ``dispatch.state_key()``
    (DESIGN.md §11).  Thread each call's ``ControlStep.t`` back in as
    ``t`` — the counter is a traced int32 leaf, so advancing it never
    retraces (and a python-int 0 first call compiles the same program).
    """
    config = SolverConfig.from_legacy(delta=delta, eta_outer=eta_outer,
                                      eta_inner=eta_inner,
                                      inner_iters=inner_iters)
    return _fused_control_step(cost_name, config, dispatch.state_key())


def gs_oma(
    graph: CECGraph,
    cost: CostFn,
    bank: UtilityBank,
    lam_total: float,
    *,
    delta: float = 0.5,
    eta_outer: float = 0.05,
    eta_inner: float = 0.05,
    outer_iters: int = 100,
    inner_iters: int = 50,
    phi0: Array | None = None,
    lam0: Array | None = None,
) -> JOWRResult:
    """Nested-loop solver (Alg. 1); ``inner_iters=1`` gives OMAD (Alg. 3).

    Shim over ``solver.run`` on a ``Problem`` — the representation policy
    (dense↔sparse, ``dispatch.use_sparse``) is applied by the engine, and
    the returned ``JOWRResult.phi`` keeps the caller's dense contract.
    """
    problem = Problem(graph=graph, bank=bank, lam_total=lam_total,
                      cost=cost)
    config = SolverConfig.from_legacy(delta=delta, eta_outer=eta_outer,
                                      eta_inner=eta_inner,
                                      inner_iters=inner_iters)
    res = _solver.run(problem, config, iters=outer_iters, phi0=phi0,
                      lam0=lam0)
    return JOWRResult.from_result(res)


def exact_allocation_gradient(graph: CECGraph, cost: CostFn,
                              bank: UtilityBank, lam: Array,
                              phi: Array) -> Array:
    """The genie gradient ∂U/∂λ_w = u'_w(λ_w) − ∂D/∂r_S(w) at fixed φ.

    Theorem 1's marginal form: the network half reads the source-row
    marginal costs off one ``core.marginal.marginals`` pass.  Only
    available to tests/benchmarks (the algorithm never sees u'); it is
    also the quantity ``solver.step``'s ``grad_mode="learned"`` recovers
    by differentiating a *fitted* surrogate through the implicit routing
    layer — the envelope-theorem route to the same marginals
    (``tests/test_implicit.py`` pins the two against each other at the
    oracle fixed point).
    """
    from .flow import cost_and_state
    from .marginal import marginals

    du = jax.grad(lambda l: bank.per_session(l).sum())(lam)
    _, t, F = cost_and_state(graph, cost, phi, lam)
    _, dDdr = marginals(graph, cost, phi, t, F)
    return du - dDdr[:, graph.src]


def allocation_kkt_residual(graph: CECGraph, cost: CostFn, bank: UtilityBank,
                            lam: Array, phi: Array) -> Array:
    """Theorem 1 check: ∂U/∂λ_w must be equal across sessions at Λ*.

    Max-minus-min of :func:`exact_allocation_gradient` — zero iff the
    allocation KKT conditions hold on the interior of the box.
    """
    g = exact_allocation_gradient(graph, cost, bank, lam, phi)
    return g.max() - g.min()
