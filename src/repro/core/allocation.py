"""Workload allocation under unknown utilities: GS-OMA (paper Alg. 1).

Outer loop over t: for each session w, the controller *admits* the perturbed
allocations Λ ± δ·e_w, lets the routing layer serve them (the oracle 𝔒 =
OMD-RT, Assumption 4), and observes the resulting scalar network utilities
U± — two-point gradient sampling (Flaxman et al.).  The estimated gradient
feeds an online mirror-ascent step on the scaled simplex {Σλ_w = λ}
(eq. (10)), followed by the exact projection onto the box-simplex
intersection P_[δ,λ−δ].

The single outer iteration is factored out as :func:`control_step` — one
`lax.scan` over the 2W perturbed observations, mirror ascent, projection,
and a final observation at the *committed* allocation — so the offline
solver (`gs_oma`, batched/vmapped by `core/batch.py`) and the live serving
router (`serve/cec_router.py`, via the jitted :func:`fused_control_step`)
run the *same* update math; there is no second implementation anywhere
(DESIGN.md §11).  Task utilities enter `control_step` as a precomputed
[2W] vector: the perturbed admissions of an iteration depend only on Λ^t,
so a bank evaluates them under vmap inside the jit while a serving fleet
measures them out-of-band and injects the observations.

The same engine with ``inner_iters=1`` *is* the single-loop OMAD algorithm
(Alg. 3): the routing iterate φ is carried across all oracle invocations and
improves by exactly one mirror-descent step per observation, never waiting
for inner convergence (see single_loop.py).

Everything scans under jit — T outer iterations × (2W + 1) oracle calls ×
K routing steps with zero Python in the loop.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import costs as _costs
from . import dispatch
from .costs import CostFn
from .graph import CECGraph
from .routing import oracle_observe
from .utility import UtilityBank

Array = jnp.ndarray


class JOWRResult(NamedTuple):
    lam: Array          # [W] final allocation Λ
    phi: Array          # [W, Nb, Nb] final routing
    utility_traj: Array  # [T] observed network utility U(Λ^t, φ^t)
    lam_traj: Array     # [T, W]


class ControlStep(NamedTuple):
    """One fused outer iteration (Alg. 1/3 lines 4–9 + committed observe)."""

    lam: Array          # [W] committed allocation Λ^{t+1}
    phi: Array          # [W, Nb, Nb] routing after the committed observation
    grad: Array         # [W] two-point gradient estimate ĝ^t
    cost: Array         # scalar network cost D(Λ^{t+1}, φ^{t+1})


def _project_box_simplex(lam: Array, lam_total, delta: float) -> Array:
    """Exact projection onto {δ ≤ λ_w ≤ λ−δ, Σλ_w = λ} (Alg. 1 line 9).

    Euclidean projection in closed form: x = clip(y − τ*, δ, λ−δ) where τ*
    solves Σ_w x_w(τ) = λ.  The sum is piecewise linear and non-increasing
    in τ with breakpoints {y_w − δ, y_w − (λ−δ)}; sorting the 2W
    breakpoints and interpolating on the bracketing segment gives the exact
    τ* (water-filling on the dual), no iterative tolerance involved.  For
    infeasible targets (λ outside [Wδ, W(λ−δ)]) the clip saturates at the
    nearest box vertex.

    Last-axis semantics so stacked ``[B, W]`` iterates (the scenario
    engine's per-instance rows) project exactly like a single ``[W]``.
    """
    lo, hi = delta, lam_total - delta
    y = jnp.asarray(lam)
    bp = jnp.sort(jnp.concatenate([y - lo, y - hi], axis=-1), -1)  # [..., 2W]
    # Σ clip(y − τ) evaluated at every breakpoint: non-increasing in τ,
    # from W·(λ−δ) at bp[0] down to W·δ at bp[-1].
    s = jnp.clip(y[..., None, :] - bp[..., :, None], lo, hi).sum(-1)
    # bracketing segment: largest k with s_k ≥ λ (linear on [bp_k, bp_k+1])
    k = jnp.clip((s >= lam_total).sum(-1, keepdims=True) - 1,
                 0, bp.shape[-1] - 2)
    t0 = jnp.take_along_axis(bp, k, -1)
    t1 = jnp.take_along_axis(bp, k + 1, -1)
    s0 = jnp.take_along_axis(s, k, -1)
    s1 = jnp.take_along_axis(s, k + 1, -1)
    drop = jnp.where(s0 > s1, s0 - s1, 1.0)
    frac = jnp.where(s0 > s1, (s0 - lam_total) / drop, 0.0)
    tau = t0 + frac * (t1 - t0)
    return jnp.clip(y - tau, lo, hi)


def _perturbation_basis(W: int) -> tuple[Array, Array]:
    """([2W] signs, [2W, W] directions) — THE observation order.

    Single source of truth shared by :func:`perturbed_allocations` (which
    callers use to evaluate task utilities up front) and
    :func:`control_step`'s scan (which pairs those utilities positionally
    with its observations): rows (2w, 2w+1) are (+e_w, −e_w).
    """
    signs = jnp.tile(jnp.asarray([1.0, -1.0], jnp.float32), W)
    dirs = jnp.repeat(jnp.eye(W, dtype=jnp.float32), 2, axis=0)
    return signs, dirs


def perturbed_allocations(lam: Array, delta: float) -> Array:
    """[2W, W] admissions of one outer iteration: rows (2w, 2w+1) = Λ ± δ·e_w.

    The row order is the observation order of :func:`control_step`'s scan
    (see :func:`_perturbation_basis`).  Callers evaluate task utilities
    over these rows up front — under vmap for a closed-form bank, or
    batched through a measured-utility callback for a live fleet (the 2W
    admissions depend only on Λ^t, never on φ).
    """
    signs, dirs = _perturbation_basis(lam.shape[-1])
    return lam + signs[:, None] * delta * dirs


def control_step(
    graph: CECGraph,
    cost: CostFn,
    lam: Array,
    phi: Array,
    task_utilities: Array,
    *,
    lam_total,
    delta: float = 0.5,
    eta_outer: float = 0.05,
    eta_inner: float = 0.05,
    inner_iters: int = 1,
) -> ControlStep:
    """One fused outer iteration of GS-OMA/OMAD on the current iterates.

    ``task_utilities`` is the [2W] vector of *task* utilities Σ_w u_w(λ_w)
    observed for the perturbed admissions of :func:`perturbed_allocations`
    (same row order); the network-cost half of each observation is computed
    here, at the routing iterate the oracle reached for that admission.
    The scan carries φ through all 2W observations (one oracle invocation
    each), takes the mirror-ascent step, projects exactly onto the
    box-simplex, then observes once more at the committed allocation so
    the returned (lam, phi, cost) are mutually consistent — the paper's
    U(Λ^t, φ^t).  Pure traceable JAX: `gs_oma` scans it, `core/batch.py`
    vmaps it, `fused_control_step` jits it for the serving router.
    """
    W = graph.n_sessions
    signs, dirs = _perturbation_basis(W)

    def observe(carry, inp):
        g, phi = carry
        sign, ew, task_u = inp
        lam_p = lam + sign * delta * ew
        phi, D = oracle_observe(graph, cost, lam_p, phi, eta_inner,
                                inner_iters)
        g = g + sign * ((task_u - D) / (2.0 * delta)) * ew  # Alg. 1 line 6
        return (g, phi), None

    (g, phi), _ = jax.lax.scan(observe, (jnp.zeros(W), phi),
                               (signs, dirs, task_utilities))
    # online mirror ascent on the scaled simplex (eq. (10))
    z = eta_outer * g
    z = z - z.max()
    w = lam * jnp.exp(z)
    lam_new = lam_total * w / w.sum()
    lam_new = _project_box_simplex(lam_new, lam_total, delta)
    phi, D = oracle_observe(graph, cost, lam_new, phi, eta_inner, inner_iters)
    return ControlStep(lam=lam_new, phi=phi, grad=g, cost=D)


@functools.lru_cache(maxsize=None)
def _fused_control_step(cost_name: str, delta: float, eta_outer: float,
                        eta_inner: float, inner_iters: int, _dispatch_key):
    cost = _costs.get(cost_name)

    def fn(graph, lam, phi, task_utilities, lam_total):
        return control_step(graph, cost, lam, phi, task_utilities,
                            lam_total=lam_total, delta=delta,
                            eta_outer=eta_outer, eta_inner=eta_inner,
                            inner_iters=inner_iters)

    return jax.jit(fn)


def fused_control_step(cost_name: str, *, delta: float = 0.5,
                       eta_outer: float = 0.05, eta_inner: float = 0.05,
                       inner_iters: int = 1):
    """The jitted fused control step, cached on its static knobs.

    Returns ``fn(graph, lam, phi, task_utilities, lam_total) ->
    ControlStep``.  ``graph`` is a pytree argument, so same-shape topology
    changes (the scenario engine's stable-index churn) reuse the compiled
    executable, and ``lam_total`` is traced so demand shifts never retrace.
    ``eta_inner`` stays a static Python float — a kernel-path requirement
    (DESIGN.md §9.2).  The cache is additionally keyed on the kernel
    dispatch state so tracing inside ``dispatch.kernel_dispatch`` gets the
    Pallas branch instead of a stale jnp-path trace.
    """
    return _fused_control_step(cost_name, float(delta), float(eta_outer),
                               float(eta_inner), int(inner_iters),
                               dispatch.state_key())


def gs_oma(
    graph: CECGraph,
    cost: CostFn,
    bank: UtilityBank,
    lam_total: float,
    *,
    delta: float = 0.5,
    eta_outer: float = 0.05,
    eta_inner: float = 0.05,
    outer_iters: int = 100,
    inner_iters: int = 50,
    phi0: Array | None = None,
    lam0: Array | None = None,
) -> JOWRResult:
    """Nested-loop solver (Alg. 1); ``inner_iters=1`` gives OMAD (Alg. 3).

    A dense graph past the ``dispatch.use_sparse`` (N, density) policy is
    converted to the edge-list representation before tracing, so the whole
    outer×inner scan runs in O(E); the returned ``JOWRResult.phi`` is
    converted back to the dense layout, keeping the public contract
    representation-independent.  Passing a ``CECGraphSparse`` directly
    (as ``CECRouter`` does) skips both conversions and yields a
    ``SparsePhi``.
    """
    dense_in = graph
    graph = dispatch.maybe_sparsify(graph, phi0, lam0)
    converted = graph is not dense_in
    W = graph.n_sessions
    lam0 = jnp.full((W,), lam_total / W) if lam0 is None else lam0
    if phi0 is None:
        phi0 = graph.uniform_phi()
    elif converted:
        from . import sparse as _sparse

        phi0 = _sparse.phi_to_sparse(graph, phi0)

    def outer(carry, _):
        lam, phi = carry
        task_u = jax.vmap(bank.total)(perturbed_allocations(lam, delta))
        step = control_step(graph, cost, lam, phi, task_u,
                            lam_total=lam_total, delta=delta,
                            eta_outer=eta_outer, eta_inner=eta_inner,
                            inner_iters=inner_iters)
        # the recorded U_t is the paper's U(Λ^t, φ^t): task utility and
        # network cost both evaluated at the *committed* iterates, not at
        # the last perturbed observation
        U_t = bank.total(step.lam) - step.cost
        return (step.lam, step.phi), (U_t, step.lam)

    (lam, phi), (u_traj, lam_traj) = jax.lax.scan(
        outer, (lam0, phi0), None, length=outer_iters)
    if converted:
        from . import sparse as _sparse

        phi = _sparse.phi_to_dense(graph, phi)
    return JOWRResult(lam=lam, phi=phi, utility_traj=u_traj, lam_traj=lam_traj)


def allocation_kkt_residual(graph: CECGraph, cost: CostFn, bank: UtilityBank,
                            lam: Array, phi: Array) -> Array:
    """Theorem 1 check: ∂U/∂λ_w must be equal across sessions at Λ*.

    Uses the *exact* gradient ∂U/∂λ_w = u'_w(λ_w) − ∂D/∂r_S(w) (only
    available to tests/benchmarks — the algorithm itself never sees it).
    """
    from .flow import cost_and_state
    from .marginal import marginals

    du = jax.grad(lambda l: bank.per_session(l).sum())(lam)
    _, t, F = cost_and_state(graph, cost, phi, lam)
    _, dDdr = marginals(graph, cost, phi, t, F)
    g = du - dDdr[:, graph.src]
    return g.max() - g.min()
