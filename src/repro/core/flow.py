"""Flow propagation and total network cost (paper §II-C/D).

Given routing variables φ (row-stochastic over each node's allowed
out-edges) and allocation Λ, the per-node session rates are the linear fixed
point of paper eq. (1)/(2):

    t_j(w) = inject_j(w) + Σ_i t_i(w) · φ_ij(w)

Because φ is loop-free by construction (DAG orientation — see graph.py), the
fixed point is reached exactly after ``depth_max`` Jacobi relaxation steps,
implemented as a ``lax.scan`` of masked batched mat-vecs.  This is the
control-plane hot loop, and it is size-dispatched (core/dispatch.py): when
``dispatch.use_kernels(n_bar)`` holds — graph clears the threshold (default
256, env ``REPRO_KERNEL_NBAR_THRESHOLD``) on a TPU backend, or under an
explicit override like ``dispatch.kernel_dispatch(n)`` — each relaxation
step runs through the Pallas ``flow_step`` kernel, operands zero-padded to
the kernel's 128-lane blocks by ``kernels/ops.py`` and sliced back
(``interpret=True`` off-TPU).  Otherwise graphs keep the fused einsum.  The
dispatch keys on static metadata at trace time, so both branches jit, scan
and vmap (the batched multi-instance path in core/batch.py goes through the
same code).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import dispatch
from .costs import CostFn
from .graph import CECGraph, CECGraphSparse

Array = jnp.ndarray


def propagate(graph: CECGraph | CECGraphSparse, phi, lam: Array) -> Array:
    """Session rates t[W, Nb] induced by routing φ and allocation Λ.

    Accepts either representation: a dense ``CECGraph`` with φ
    ``[W, Nb, Nb]``, or a ``CECGraphSparse`` with a ``SparsePhi`` — the
    sparse branch (core/sparse.py) runs the same Jacobi recursion over
    padded edge lists in O(E) per step.
    """
    if isinstance(graph, CECGraphSparse):
        from . import sparse

        return sparse.propagate(graph, phi, lam)
    inject = graph.injection(lam)

    if dispatch.use_kernels(graph.n_bar):
        from repro.kernels.ops import flow_step_op

        interpret = dispatch.kernel_interpret()

        def step(t, _):
            return flow_step_op(t, phi, inject, interpret=interpret), None
    else:
        def step(t, _):
            return inject + jnp.einsum("wi,wij->wj", t, phi), None

    t, _ = jax.lax.scan(step, inject, None, length=graph.depth_max)
    return t


def link_flows(graph: CECGraph | CECGraphSparse, phi, t: Array):
    """Total flow per augmented link: F_ij = Σ_w t_i(w)·φ_ij(w) (eq. (4)).

    Dense graphs return [Nb, Nb]; sparse graphs return the flows in the
    slot layout (a ``SparsePhi``-shaped container).
    """
    if isinstance(graph, CECGraphSparse):
        from . import sparse

        return sparse.link_flow_slots(graph, phi, t)
    return jnp.einsum("wi,wij->ij", t, phi)


def total_cost(graph: CECGraph | CECGraphSparse, cost: CostFn, phi,
               lam: Array) -> Array:
    """Σ_{(i,j)∈Ē} D_ij(F_ij, C_ij): communication + computation cost."""
    if isinstance(graph, CECGraphSparse):
        from . import sparse

        return sparse.total_cost(graph, cost, phi, lam)
    t = propagate(graph, phi, lam)
    F = link_flows(graph, phi, t)
    return jnp.sum(graph.edge_mask * cost.value(F, graph.capacity))


def cost_and_state(graph: CECGraph | CECGraphSparse, cost: CostFn, phi,
                   lam: Array):
    """(total cost, t, F) in one pass — used by the routing iteration."""
    if isinstance(graph, CECGraphSparse):
        from . import sparse

        return sparse.cost_and_state(graph, cost, phi, lam)
    t = propagate(graph, phi, lam)
    F = link_flows(graph, phi, t)
    D = jnp.sum(graph.edge_mask * cost.value(F, graph.capacity))
    return D, t, F
