"""Link/compute cost functions D_ij(F_ij, C_ij) (paper §II-D).

Every cost is increasing, continuously differentiable and convex in F for
fixed C.  All are implemented with smooth linear extensions past a clip point
so that gradients stay finite when an iterate momentarily overloads a link
(the optimum is always in the well-behaved region).  The ``where``/``where``
pattern avoids NaN cotangents from saturated branches.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax.numpy as jnp

Array = jnp.ndarray


class CostFn(NamedTuple):
    """value(F, C) -> D_ij elementwise; deriv(F, C) -> dD/dF elementwise."""

    name: str
    value: Callable[[Array, Array], Array]
    deriv: Callable[[Array, Array], Array]


_EXP_CLIP = 25.0     # exp cost linearized beyond z = F/C = 25
_MM1_CLIP = 0.95     # M/M/1 cost quadratically extended beyond 95% load


def _exp_value(F: Array, C: Array) -> Array:
    z = F / C
    zs = jnp.minimum(z, _EXP_CLIP)
    ev = jnp.exp(zs)
    return jnp.where(z <= _EXP_CLIP, ev, ev * (1.0 + (z - zs)))


def _exp_deriv(F: Array, C: Array) -> Array:
    z = F / C
    zs = jnp.minimum(z, _EXP_CLIP)
    return jnp.exp(zs) / C


def _mm1_value(F: Array, C: Array) -> Array:
    z = F / C
    zs = jnp.minimum(z, _MM1_CLIP)
    base = zs / (1.0 - zs)
    # C¹ quadratic extension: value' and value'' continuous at the clip point
    g = 1.0 / (1.0 - _MM1_CLIP) ** 2
    h = 2.0 / (1.0 - _MM1_CLIP) ** 3
    dz = jnp.maximum(z - _MM1_CLIP, 0.0)
    return jnp.where(z <= _MM1_CLIP, base, base + g * dz + 0.5 * h * dz * dz)


def _mm1_deriv(F: Array, C: Array) -> Array:
    z = F / C
    zs = jnp.minimum(z, _MM1_CLIP)
    base = 1.0 / (1.0 - zs) ** 2
    h = 2.0 / (1.0 - _MM1_CLIP) ** 3
    dz = jnp.maximum(z - _MM1_CLIP, 0.0)
    return jnp.where(z <= _MM1_CLIP, base, base + h * dz) / C


EXP = CostFn("exp", _exp_value, _exp_deriv)                       # paper §IV
MM1 = CostFn("mm1", _mm1_value, _mm1_deriv)                       # paper eq. (5)
LINEAR = CostFn("linear", lambda F, C: F / C, lambda F, C: 1.0 / C)
QUADRATIC = CostFn("quad", lambda F, C: F * F / C, lambda F, C: 2.0 * F / C)

REGISTRY = {c.name: c for c in (EXP, MM1, LINEAR, QUADRATIC)}


def get(name: str) -> CostFn:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown cost {name!r}: registered costs are "
            f"{sorted(REGISTRY)}") from None
