"""Hypergradient meta-tuning of (η_outer, η_inner) (DESIGN.md §16.3).

The two step sizes used to be hand-maintained lore: ``paper_defaults``
runs the nested oracle gently (η_inner=0.05, K=50) while
``serving_defaults`` runs the K=1 oracle hot (η_inner=3.0), and the gap
was documented as "intentional, not drift" with a paragraph of prose.
This module replaces the prose with a derivation: the steps are *tuned*
by gradient ascent on what the controller actually maximizes — the tail
utility of a short solve rollout — differentiated **through the solver
itself**.

Mechanics.  :func:`rollout_objective` unrolls ``solver.step_with_etas``
(the fused control iteration with the η's as traced inputs) for a few
outer iterations and returns the mean utility over the trailing window.
Every oracle observation inside that rollout runs through the implicit
fixed-point layer (``core.implicit`` via ``routing.oracle_observe``), so
reverse-mode differentiation pays the adjoint solve instead of storing
the inner iteration; :func:`tune_etas` ascends log-η with Adam (log
parametrization keeps the steps positive and makes the search scale-free
across the 0.05-vs-3.0 decades).

Honesty about what the gradient is:

* the implicit layer returns a **zero cotangent for the warm-started φ**
  (``core/implicit.py``), so the hypergradient is truncated in the
  φ-carry direction — each observation contributes its own η
  sensitivity, not the sensitivity of the φ trajectory that led to it.
  This is standard truncated backprop-through-optimization; the
  objective being a *tail mean* over fresh iterations keeps it a useful
  ascent direction (``tests/test_hypergrad.py`` checks monotone
  improvement from deliberately detuned starts).
* at an *exact* OMD fixed point the η-sensitivity of one more inner step
  vanishes (the multiplicative weights are uniform on the support), so
  η_inner's signal comes from the transient — which is precisely the
  regime the K=1 serving oracle lives in, and why tuning lands hot
  η_inner for ``method="single"`` and gentle for deep nested oracles.
* jnp path only: the Pallas kernel bakes η as a static parameter, so
  ``step_with_etas`` refuses to trace under kernel dispatch (tune on the
  jnp path, serve the tuned floats on any path).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import solver as _solver
from .problem import Problem
from .solver import SolverConfig, SolverState

__all__ = ["TuneResult", "rollout_objective", "tune_etas"]


class TuneResult(NamedTuple):
    """What meta-tuning produced (trajectories included — the schedule is
    an artifact worth inspecting, not just a pair of floats)."""

    config: SolverConfig     # the input preset with tuned η's threaded in
    eta_outer: float
    eta_inner: float
    objective: np.ndarray    # [meta_iters + 1] rollout tail utility
    etas: np.ndarray         # [meta_iters + 1, 2] (η_outer, η_inner) visited


def rollout_objective(problem: Problem, config: SolverConfig,
                      state0: SolverState, log_etas: jnp.ndarray, *,
                      iters: int, tail: int) -> jnp.ndarray:
    """Mean utility over the last ``tail`` of ``iters`` outer iterations.

    The meta-objective: a differentiable function of ``log_etas`` ([2] =
    log(η_outer), log(η_inner)) via the unrolled sampled-gradient loop.
    Requires ``problem.bank`` (the rollout must price its own
    observations).  Pure traceable JAX — :func:`tune_etas` jits its
    value-and-grad once per (config, iters, tail).
    """
    if problem.bank is None:
        raise ValueError("hypergradient rollouts need problem.bank — the "
                         "meta-objective prices its own observations")
    eta_outer, eta_inner = jnp.exp(log_etas[0]), jnp.exp(log_etas[1])
    bank = problem.bank

    def outer(st, _):
        task_u = jax.vmap(bank.total)(
            _solver.perturbed_allocations(st.lam, config.delta))
        st, info = _solver.step_with_etas(problem, config, st, task_u,
                                          eta_outer, eta_inner)
        return st, bank.total(st.lam) - info.cost

    _, u_traj = jax.lax.scan(outer, state0, None, length=iters)
    return u_traj[-tail:].mean()


@functools.lru_cache(maxsize=None)
def _meta_program(config: SolverConfig, iters: int, tail: int,
                  meta_lr: float):
    """Jitted Adam ascent step on log-η (cached per meta setup)."""

    def objective(log_etas, problem, state0):
        return rollout_objective(problem, config, state0, log_etas,
                                 iters=iters, tail=tail)

    def ascend(log_etas, m, v, t, problem, state0):
        val, g = jax.value_and_grad(objective)(log_etas, problem, state0)
        b1, b2, eps = 0.9, 0.999, 1e-8
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * g * g
        mh = m / (1.0 - b1 ** t)
        vh = v / (1.0 - b2 ** t)
        new = log_etas + meta_lr * mh / (jnp.sqrt(vh) + eps)   # ascent
        return new, m, v, val

    return jax.jit(ascend)


def tune_etas(problem: Problem, config: SolverConfig | None = None, *,
              meta_iters: int = 20, rollout_iters: int = 10, tail: int = 4,
              meta_lr: float = 0.25) -> TuneResult:
    """Meta-tune the preset's (η_outer, η_inner) for ``problem``.

    Starts from ``config``'s current steps (default
    ``solver.serving_defaults()``), ascends the rollout-tail utility by
    hypergradient for ``meta_iters`` Adam steps, and returns the preset
    with the best-seen η's threaded in — ``tune_etas(problem,
    paper_defaults()).config`` is a drop-in replacement wherever a
    ``SolverConfig`` goes (the tuned values are Python floats, so the
    config stays hashable and jit-static).

    Each meta step re-rolls from the same fresh ``solver.init`` state:
    the objective compares step sizes on identical footing instead of
    chasing a moving warm start.
    """
    if config is None:
        config = _solver.serving_defaults()
    prob = problem.canonical().validate()
    state0 = _solver.init(prob, config)
    log_etas = jnp.log(jnp.asarray(
        [config.eta_outer, config.eta_inner], jnp.float32))
    ascend = _meta_program(config, int(rollout_iters), int(tail),
                           float(meta_lr))

    m = jnp.zeros(2, jnp.float32)
    v = jnp.zeros(2, jnp.float32)
    objective, etas = [], [np.exp(np.asarray(log_etas))]
    for t in range(meta_iters):
        log_etas, m, v, val = ascend(log_etas, m, v, float(t + 1),
                                     prob, state0)
        objective.append(float(val))
        etas.append(np.exp(np.asarray(log_etas)))
    # score the final candidate too, then keep the best-seen pair — meta
    # ascent may overshoot on its last step and the caller gets a config,
    # not a trajectory
    final = float(rollout_objective(prob, config, state0,
                                    jnp.log(jnp.asarray(etas[-1])),
                                    iters=int(rollout_iters),
                                    tail=int(tail)))
    objective.append(final)
    # objective[i] was evaluated AT etas[i] (value-and-grad reads the
    # pre-update point), so the two arrays align index-for-index
    best = int(np.argmax(objective))
    eta_outer, eta_inner = (float(x) for x in etas[best])
    tuned = config.replace(eta_outer=eta_outer, eta_inner=eta_inner)
    return TuneResult(config=tuned, eta_outer=eta_outer,
                      eta_inner=eta_inner,
                      objective=np.asarray(objective, np.float32),
                      etas=np.asarray(etas, np.float32))
