"""Distributed optimal routing: OMD-RT (paper Alg. 2) + SGP baseline.

OMD-RT is one fused update per iteration: forward flow propagation, marginal
cost broadcast, then the exponentiated-gradient (online mirror descent on
each node's out-edge simplex, eq. (22))

    φ_ij ← φ_ij · exp(−η·δφ_ij) / Σ_j φ_ij · exp(−η·δφ_ij)

The row max of −η·δφ is subtracted before exponentiation (renormalization
makes the update shift-invariant) so the step is overflow-free for any η.

The update is size-dispatched (core/dispatch.py): when
``dispatch.use_kernels(n_bar)`` holds — graph clears the threshold (default
256, env ``REPRO_KERNEL_NBAR_THRESHOLD``) on a TPU backend, or under an
explicit override like ``dispatch.kernel_dispatch(n)`` — the update runs
the fused Pallas ``omd_update`` kernel: one VMEM pass over 128-row blocks,
padded/sliced by ``kernels/ops.py``, ``interpret=True`` off-TPU.  Otherwise
it keeps the jnp expression below.  η must be a Python float on the kernel
path (it is a static kernel parameter); every caller in this repo passes a
literal.

SGP is the scaled-gradient-projection baseline (Xi & Yeh 2008 / Bertsekas,
Gafni & Gallager 1984): a diagonally-scaled projected-gradient step whose
projection onto the masked simplex is the closed-form QP solve — this is the
per-node quadratic program the paper contrasts against.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import dispatch
from .costs import CostFn
from .flow import cost_and_state, total_cost
from .graph import CECGraph, CECGraphSparse, SparsePhi
from .marginal import marginals

Array = jnp.ndarray
_NEG = -1e30


class RoutingState(NamedTuple):
    phi: Array      # [W, Nb, Nb] dense, or a SparsePhi slot field
    cost: Array     # scalar — total network cost at phi


def omd_step(graph: CECGraph | CECGraphSparse, cost: CostFn, phi, lam: Array,
             eta: float) -> RoutingState:
    """One OMD-RT iteration (Alg. 2 lines 3–6). Returns (new φ, cost at φ).

    Type-dispatched: a ``CECGraphSparse`` with a ``SparsePhi`` runs the
    identical update over edge slots (core/sparse.py), kernel-dispatched
    through ``omd_update_sparse`` past the size threshold.
    """
    D, t, F = cost_and_state(graph, cost, phi, lam)
    delta, _ = marginals(graph, cost, phi, t, F)
    if isinstance(graph, CECGraphSparse):
        from . import sparse

        return RoutingState(sparse.omd_phi_update(graph, phi, delta, eta), D)
    mask = graph.out_mask
    if dispatch.use_kernels(graph.n_bar):
        from repro.kernels.ops import omd_update_op

        new_phi = omd_update_op(phi, delta, mask, float(eta),
                                interpret=dispatch.kernel_interpret())
        return RoutingState(new_phi, D)
    from .sparse import eg_update      # the one jnp definition of eq. (22)

    return RoutingState(eg_update(phi, delta, mask, eta), D)


def warm_start_phi(phi: Array, out_mask: Array, explore: float = 0.1) -> Array:
    """Re-target routing iterates onto a (possibly changed) edge mask.

    The OMD update is multiplicative, so an edge whose φ has decayed to ~0
    can never revive on its own — after node/link churn the new graph's
    edges must be seeded with exploration mass (DESIGN.md §5, §10):

        φ' ∝ (1−ε)·φ·mask + ε·uniform(mask)

    renormalized per row; rows left with no mass (e.g. a node whose old
    out-edges all vanished) restart uniform.  Accepts stacked ``[B, ...]``
    iterates — everything is elementwise + row reductions.  ``explore=0``
    degenerates to mask-and-renormalize (still required after churn to
    drop deleted edges).
    """
    rowsum = out_mask.sum(-1, keepdims=True)
    uniform = out_mask / jnp.where(rowsum > 0, rowsum, 1.0)
    mixed = (1.0 - explore) * phi * out_mask + explore * uniform
    s = mixed.sum(-1, keepdims=True)
    return jnp.where(s > 0, mixed / jnp.where(s > 0, s, 1.0), uniform)


def solve_routing(graph: CECGraph | CECGraphSparse, cost: CostFn, lam: Array,
                  phi0, eta: float, n_iters: int) -> tuple[Array, Array]:
    """Run OMD-RT for ``n_iters`` (the oracle 𝔒 of Assumption 4).

    Returns (φ_final, per-iteration cost trajectory).  A dense graph past
    the ``dispatch.use_sparse`` (N, density) threshold is converted to the
    edge-list representation up front (concrete inputs only — tracers flow
    through untouched) and φ is converted both ways, so callers keep the
    dense [W, Nb, Nb] contract while the iteration itself runs in O(E).
    Passing a ``CECGraphSparse`` (with a matching ``SparsePhi``) runs
    sparse natively and returns the ``SparsePhi``.
    """
    sgraph = dispatch.maybe_sparsify(graph, phi0)
    if sgraph is not graph:
        from . import sparse

        phi, traj = solve_routing(sgraph, cost, lam,
                                  sparse.phi_to_sparse(sgraph, phi0),
                                  eta, n_iters)
        return sparse.phi_to_dense(sgraph, phi), traj

    def step(phi, _):
        st = omd_step(graph, cost, phi, lam, eta)
        return st.phi, st.cost

    phi, traj = jax.lax.scan(step, phi0, None, length=n_iters)
    return phi, traj


def solve_routing_implicit(graph: CECGraph | CECGraphSparse, cost: CostFn,
                           lam: Array, phi0, eta, n_iters: int, *,
                           bwd_iters: int | None = None):
    """:func:`solve_routing`'s iteration as an implicit layer (DESIGN.md §16.1).

    Forward is the identical ``n_iters``-step OMD-RT scan (same carry, no
    per-iteration cost emission — callers price the final iterate), so the
    value is bit-for-bit what :func:`solve_routing` returns; backward is
    ``core.implicit.fixed_point_solve``'s adjoint solve at the returned
    iterate, making φ* differentiable w.r.t. ``lam``, ``eta`` and the
    graph's float leaves (capacities, masks).  Same representation policy
    as :func:`solve_routing` (dense past the sparse threshold converts both
    ways; the conversions are gathers/scatters, so gradients flow through).
    Returns only φ (no cost trajectory — the scan emits nothing).
    """
    from .implicit import fixed_point_solve

    sgraph = dispatch.maybe_sparsify(graph, phi0)
    if sgraph is not graph:
        from . import sparse

        phi = solve_routing_implicit(sgraph, cost, lam,
                                     sparse.phi_to_sparse(sgraph, phi0),
                                     eta, n_iters, bwd_iters=bwd_iters)
        return sparse.phi_to_dense(sgraph, phi)

    # cost is a static registry singleton (part of the trace) — safe to
    # close over; everything traced rides in args and picks up gradients.
    # A concrete η is closed over too: the Pallas kernel path bakes η as
    # a static parameter (float(eta) inside omd_step), so only a traced η
    # — the hypergradient loop, which refuses kernel dispatch — rides in
    # args (and is then differentiable).
    if isinstance(eta, jax.core.Tracer):
        def omd_map(phi, graph, lam, eta):
            return omd_step(graph, cost, phi, lam, eta).phi

        return fixed_point_solve(omd_map, phi0, graph, lam, eta,
                                 n_iters=n_iters, bwd_iters=bwd_iters)

    eta_static = float(eta)

    def omd_map_static(phi, graph, lam):
        return omd_step(graph, cost, phi, lam, eta_static).phi

    return fixed_point_solve(omd_map_static, phi0, graph, lam,
                             n_iters=n_iters, bwd_iters=bwd_iters)


def oracle_observe(graph: CECGraph, cost: CostFn, lam: Array, phi: Array,
                   eta: float, n_iters: int) -> tuple[Array, Array]:
    """Admit ``lam``, run the oracle 𝔒, price what it served.

    This is the single observation primitive of the bandit loop (Assumption
    4): the routing iterate advances ``n_iters`` mirror-descent steps for
    the admitted allocation, then the network cost D(Λ, φ') at the
    *post-update* iterate is what the controller's scalar feedback is built
    from.  Returns (φ', D).  Every observation of the solver core's fused
    control iteration (``core.solver.step`` — offline scans, batched
    ensembles and the serving router alike) goes through here, so there
    is exactly one definition of "what an observation does to φ".

    The solve runs through :func:`solve_routing_implicit`, so the returned
    (φ', D) are differentiable w.r.t. (Λ, η, graph) — the learned gradient
    mode and the hypergradient loop take ``jax.grad`` of exactly this
    observation (DESIGN.md §16).  Forward-only consumers see the same
    scan as always.
    """
    phi = solve_routing_implicit(graph, cost, lam, phi, eta, n_iters)
    return phi, total_cost(graph, cost, phi, lam)


# --------------------------------------------------------------------------
# masked Euclidean simplex projection (the SGP per-node QP, closed form)
# --------------------------------------------------------------------------

def project_simplex_masked(y: Array, mask: Array) -> Array:
    """Project rows of y onto {v ≥ 0, Σv = 1, v=0 off-mask} (last axis)."""
    neg = jnp.where(mask > 0, y, _NEG)
    ys = jnp.sort(neg, axis=-1)[..., ::-1]                 # descending
    k = jnp.arange(1, y.shape[-1] + 1, dtype=y.dtype)
    csum = jnp.cumsum(ys, axis=-1)
    cond = (ys - (csum - 1.0) / k > 0) & (ys > _NEG / 2)
    rho = jnp.maximum(jnp.sum(cond, axis=-1, keepdims=True), 1)
    tau = (jnp.take_along_axis(csum, rho - 1, axis=-1) - 1.0) / rho.astype(y.dtype)
    return jnp.maximum(y - tau, 0.0) * mask


def sgp_step(graph: CECGraph, cost: CostFn, phi: Array, lam: Array,
             eta: float) -> RoutingState:
    """Scaled gradient projection step (the paper's SGP baseline).

    Scaling matrix M = diag(t_i·h + ε) with h an upper bound on the row
    Hessian diagonal (second-derivative scaling of [39]); the update solves
    min ⟨∇, v−φ⟩ + 1/(2η)·(v−φ)ᵀM(v−φ) on the masked simplex.

    Dense-only: SGP is the paper's comparison baseline, evaluated at paper
    scale — the production path (OMD-RT) is what the sparse representation
    serves.
    """
    if isinstance(graph, CECGraphSparse):
        raise TypeError("sgp_step is dense-only; use OMD-RT on sparse graphs")
    D, t, F = cost_and_state(graph, cost, phi, lam)
    delta, _ = marginals(graph, cost, phi, t, F)
    grad = t[:, :, None] * delta                            # eq. (18)
    # diagonal second-derivative proxy: finite-difference of D' along rows
    h = jnp.sum(graph.out_mask * jnp.abs(delta), -1, keepdims=True) + 1e-3
    scale = t[:, :, None] * h + 1e-3
    y = phi - eta * grad / scale
    upd = graph.out_mask.sum(-1, keepdims=True) > 0
    new_phi = jnp.where(upd, project_simplex_masked(y, graph.out_mask), phi)
    return RoutingState(new_phi, D)


def solve_routing_sgp(graph: CECGraph, cost: CostFn, lam: Array, phi0: Array,
                      eta: float, n_iters: int) -> tuple[Array, Array]:
    def step(phi, _):
        st = sgp_step(graph, cost, phi, lam, eta)
        return st.phi, st.cost

    phi, traj = jax.lax.scan(step, phi0, None, length=n_iters)
    return phi, traj


def kkt_residual(graph: CECGraph | CECGraphSparse, cost: CostFn, phi,
                 lam: Array) -> Array:
    """Theorem 3 optimality residual.

    At φ*, for every row with t_i(w) > 0 the marginal costs δφ_ij(w) on
    edges with φ_ij > 0 are equal (= −α_i(w)) and minimal over the row.
    Returns the max over rows of (max support-δ − min allowed-δ), clipped
    at 0 — zero iff the KKT conditions hold.
    """
    if isinstance(graph, CECGraphSparse):
        from . import sparse

        return sparse.kkt_residual(graph, cost, phi, lam)
    D, t, F = cost_and_state(graph, cost, phi, lam)
    delta, _ = marginals(graph, cost, phi, t, F)
    mask = graph.out_mask
    on = (phi > 1e-6) & (mask > 0)
    big = jnp.where(on, delta, -jnp.inf).max(-1)
    small = jnp.where(mask > 0, delta, jnp.inf).min(-1)
    active = (t > 1e-6) & (mask.sum(-1) > 0)
    res = jnp.where(active, jnp.maximum(big - small, 0.0), 0.0)
    return res.max()
