"""Fleet-scale distributed control plane (beyond-paper extension).

The paper evaluates ≤40-node networks; a production CEC fleet has 10³–10⁵
devices.  Here the OMD-RT state itself is sharded over the TPU mesh:

  φ  [W, N, N]  → P(None, 'data', 'model')   (row-blocks × col-blocks)
  t  [W, N]     → P(None, 'data')            (node blocks)
  δ  [W, N, N]  → like φ

One control iteration is then three SPMD phases, each mapping onto mesh
collectives exactly the way the paper's message passing maps onto the
physical network:

  1. flow propagation  t·Φ  — contraction over the 'data'-sharded node
     axis → reduce-scatter (the "workload forwarding" messages);
  2. marginal-cost broadcast — the same contraction on the reversed graph
     (the paper's hop-by-hop broadcast protocol);
  3. exponentiated-gradient row update — row-local softmax, no comms.

``solve_routing_sharded`` jits the full loop with those shardings; the
Pallas kernels (flow_step / omd_update) are the per-shard compute bodies
on real TPUs.  Tested on a fake 8-device mesh in tests/test_parallel.py
and dry-run-compiled at N=4096 on the 16×16 production mesh.

Sharding and sparsity are complementary scale axes: this module shards the
*dense* [W, N, N] state across a mesh, while ``core/sparse.py`` shrinks
the state itself to O(E) (``CECGraphSparse``, DESIGN.md §12) — the right
tool for single-host fleet topologies whose density is ≪ 1.  The
``dispatch.use_sparse`` policy picks the representation; a sharded sparse
layout (edge-partitioned segments) is the natural composition once both
axes are needed at once.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .costs import CostFn
from .graph import CECGraph
from .routing import solve_routing


def routing_shardings(mesh):
    """(φ/δ sharding, t sharding) for the control-plane state."""
    return (NamedSharding(mesh, P(None, "data", "model")),
            NamedSharding(mesh, P(None, "data")))


def solve_routing_sharded(graph: CECGraph, cost: CostFn, lam, phi0,
                          eta: float, n_iters: int, mesh):
    """pjit'd OMD-RT with mesh-sharded state. Semantics identical to
    core.routing.solve_routing (tested); layout sharded for fleet scale."""
    sh_phi, sh_t = routing_shardings(mesh)
    sh_graph = CECGraph(
        out_mask=sh_phi, edge_mask=NamedSharding(mesh, P("data", "model")),
        capacity=NamedSharding(mesh, P("data", "model")),
        deploy=NamedSharding(mesh, P()), sinks=NamedSharding(mesh, P()),
        n_phys=graph.n_phys, n_sessions=graph.n_sessions,
        n_bar=graph.n_bar, depth_max=graph.depth_max, src=graph.src)

    fn = jax.jit(
        lambda g, l, p: solve_routing(g, cost, l, p, eta, n_iters),
        in_shardings=(sh_graph, NamedSharding(mesh, P()), sh_phi),
        out_shardings=(sh_phi, None),
        static_argnames=())
    with mesh:
        return fn(graph, jnp.asarray(lam), phi0)


def lower_control_plane(n_nodes: int, n_sessions: int, mesh, eta=1.0,
                        n_iters=10):
    """Dry-run lowering of the control plane at fleet scale (no data):
    proves the sharded CEC iteration compiles on the production mesh."""
    import numpy as np

    n_bar = n_nodes + 1 + n_sessions
    pad = (-n_bar) % int(np.prod([mesh.shape[a] for a in ("data",)]) * 1)
    n_bar += pad

    from .costs import get as get_cost

    def sds(shape, dtype=jnp.float32):
        return jax.ShapeDtypeStruct(shape, dtype)

    graph = CECGraph(
        out_mask=sds((n_sessions, n_bar, n_bar)),
        edge_mask=sds((n_bar, n_bar)), capacity=sds((n_bar, n_bar)),
        deploy=sds((n_sessions, n_nodes), jnp.bool_),
        sinks=sds((n_sessions,), jnp.int32),
        n_phys=n_nodes, n_sessions=n_sessions, n_bar=n_bar,
        depth_max=16, src=n_nodes)
    sh_phi, sh_t = routing_shardings(mesh)
    sh_graph = CECGraph(
        out_mask=sh_phi, edge_mask=NamedSharding(mesh, P("data", "model")),
        capacity=NamedSharding(mesh, P("data", "model")),
        deploy=NamedSharding(mesh, P()), sinks=NamedSharding(mesh, P()),
        n_phys=n_nodes, n_sessions=n_sessions, n_bar=n_bar,
        depth_max=16, src=n_nodes)
    cost = get_cost("exp")
    fn = jax.jit(lambda g, l, p: solve_routing(g, cost, l, p, eta, n_iters),
                 in_shardings=(sh_graph, NamedSharding(mesh, P()), sh_phi))
    with mesh:
        lowered = fn.lower(graph, sds((n_sessions,)),
                           sds((n_sessions, n_bar, n_bar)))
        return lowered.compile()
