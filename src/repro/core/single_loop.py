"""OMAD — the single-loop algorithm (paper Alg. 3, Theorem 5).

Identical control flow to GS-OMA except the oracle is invoked with K = 1:
every utility observation advances the shared routing iterate φ̃ by exactly
one online-mirror-descent step, so allocation (ascent) and routing (descent)
move simultaneously through the concave–convex saddle landscape (eq. (25)).

Shim: ``omad(...)`` ≡ ``solver.run(problem, SolverConfig(method="single",
...), iters=T)`` — ``method="single"`` *is* the K=1 oracle.
"""
from __future__ import annotations

from . import solver as _solver
from .allocation import JOWRResult
from .costs import CostFn
from .graph import CECGraph
from .problem import Problem
from .solver import SolverConfig
from .utility import UtilityBank


def omad(
    graph: CECGraph,
    cost: CostFn,
    bank: UtilityBank,
    lam_total: float,
    *,
    delta: float = 0.5,
    eta_outer: float = 0.05,
    eta_inner: float = 0.05,
    outer_iters: int = 100,
    phi0=None,
    lam0=None,
) -> JOWRResult:
    problem = Problem(graph=graph, bank=bank, lam_total=lam_total, cost=cost)
    config = SolverConfig.from_legacy(method="single", delta=delta,
                                      eta_outer=eta_outer,
                                      eta_inner=eta_inner, inner_iters=1)
    res = _solver.run(problem, config, iters=outer_iters, phi0=phi0,
                      lam0=lam0)
    return JOWRResult.from_result(res)
