"""OMAD — the single-loop algorithm (paper Alg. 3, Theorem 5).

Identical control flow to GS-OMA except the oracle is invoked with K = 1:
every utility observation advances the shared routing iterate φ̃ by exactly
one online-mirror-descent step, so allocation (ascent) and routing (descent)
move simultaneously through the concave–convex saddle landscape (eq. (25)).
"""
from __future__ import annotations

from .allocation import JOWRResult, gs_oma
from .costs import CostFn
from .graph import CECGraph
from .utility import UtilityBank


def omad(
    graph: CECGraph,
    cost: CostFn,
    bank: UtilityBank,
    lam_total: float,
    *,
    delta: float = 0.5,
    eta_outer: float = 0.05,
    eta_inner: float = 0.05,
    outer_iters: int = 100,
    phi0=None,
    lam0=None,
) -> JOWRResult:
    return gs_oma(
        graph, cost, bank, lam_total,
        delta=delta, eta_outer=eta_outer, eta_inner=eta_inner,
        outer_iters=outer_iters, inner_iters=1, phi0=phi0, lam0=lam0,
    )
