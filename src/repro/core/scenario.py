"""Non-stationary scenario engine (DESIGN.md §10).

The paper's core claim is *online* operation: the controller tracks the
optimum under bandit feedback while the system changes underneath it.
This module makes that a first-class workload.  A :class:`Scenario` is a
declarative event timeline over a fixed node-index space — link rewiring
(device mobility), node failures/joins, capacity drift, utility-bank
swaps, demand shifts — and :func:`run_scenario` advances OMAD / GS-OMA
across the induced segments with library-grade warm-starting:

* φ re-targets through :func:`core.routing.warm_start_phi` (exploration
  mix — multiplicative OMD can never revive a zeroed edge on its own);
* Λ rescales onto the new total demand and re-projects into the box.

Every segment solves **batched over seeds** on the PR-1
``CECGraphBatch`` path; all segments are padded to one global
(``n_phys``, ``depth_max``) so consecutive segments share a single
compiled XLA program per distinct segment length (graphs differ only in
leaf *values*).  Node churn keeps indices stable by construction: a dead
node is an isolated, never-deployed index — exactly the pad-node
convention of ``core/batch.pad_graph`` — via ``build_augmented``'s
``alive`` mask, so iterates never need remapping.

:func:`scenario_metrics` reports dynamic regret and per-event recovery
times; :func:`segment_optima` computes the genie (true-gradient) per-
segment optima when an absolute comparator is wanted.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.topo import make_topology
from repro.topo.churn import rewire_links

from . import costs as _costs
from . import dispatch
from .batch import CECGraphBatch, pad_graph, stack_banks
from .graph import CECGraph, InfeasibleTopology, build_augmented, draw_instance
from .routing import warm_start_phi
from .solver import Method, SolverConfig, SolverState, project_box_simplex
from .utility import UtilityBank, make_bank

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# events
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Event:
    """Base timeline event; fires *before* outer iteration ``at``."""

    at: int
    # True when the event changes the augmented graph (masks/capacities):
    # only those boundaries re-mix φ with exploration mass.  DemandShift
    # and BankSwap leave the feasible set untouched, so the routing
    # iterate carries over as-is — the same policy the serving router
    # applies (``CECRouter.apply_scenario_event``).
    changes_graph = True

    @property
    def kind(self) -> str:
        return type(self).__name__


@dataclasses.dataclass(frozen=True)
class Rewire(Event):
    """Move a share of physical links to new endpoints (device mobility)."""

    frac: float = 0.3
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class NodeFail(Event):
    """Fail ``count`` random nodes, keeping every version deployed and
    every session admissible (draws are retried until feasible)."""

    count: int = 1
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class NodeJoin(Event):
    """Revive ``count`` failed nodes (all of them when ``count`` is None)."""

    count: int | None = None
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class CapacityScale(Event):
    """Multiply link / compute capacities (interference, thermal drift)."""

    link: float = 1.0
    compute: float = 1.0


@dataclasses.dataclass(frozen=True)
class BankSwap(Event):
    """Swap the (hidden) utility bank — the tasks themselves change."""

    bank_kind: str = "sqrt"
    seed: int = 0
    changes_graph = False


@dataclasses.dataclass(frozen=True)
class DemandShift(Event):
    """Change the total admitted demand λ (flash crowd / lull)."""

    lam_total: float = 60.0
    changes_graph = False


# ---------------------------------------------------------------------------
# scenario + per-seed mutable state
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Scenario:
    """Declarative non-stationary workload: initial draw + event timeline."""

    name: str
    horizon: int
    events: tuple[Event, ...] = ()
    topology: str = "connected_er"
    topo_kwargs: dict = dataclasses.field(default_factory=dict)
    n_sessions: int = 3
    mean_capacity: float | None = None        # None → topology default
    bank_kind: str = "log"
    lam_total: float = 60.0

    def __post_init__(self):
        object.__setattr__(self, "events",
                           tuple(sorted(self.events, key=lambda e: e.at)))
        for e in self.events:
            if not 0 < e.at < self.horizon:
                raise ValueError(f"event {e} outside (0, {self.horizon})")

    @property
    def event_times(self) -> tuple[int, ...]:
        return tuple(sorted({e.at for e in self.events}))


@dataclasses.dataclass
class ScenarioState:
    """Per-seed numpy instance state the events mutate between segments."""

    adj: np.ndarray           # [N, N] bool physical adjacency
    alive: np.ndarray         # [N] bool
    deploy: np.ndarray        # [W, N] bool (dead nodes keep their row —
                              #   masked at build, restored on rejoin)
    link_capacity: np.ndarray   # [N, N]
    compute_capacity: np.ndarray  # [N]
    bank: UtilityBank
    lam_total: float
    seed: int

    def graph(self) -> CECGraph:
        return build_augmented(self.adj, self.deploy, self.link_capacity,
                               self.compute_capacity, alive=self.alive)


def initial_state(scenario: Scenario, seed: int) -> ScenarioState:
    kw = dict(scenario.topo_kwargs)
    if scenario.topology == "connected_er":
        kw.setdefault("seed", 1 + seed)
    adj, cbar = make_topology(scenario.topology, **kw)
    mean_cap = scenario.mean_capacity or cbar
    _, deploy, link_cap, comp_cap = draw_instance(
        adj, scenario.n_sessions, mean_cap, seed)
    bank = make_bank(scenario.bank_kind, scenario.n_sessions, seed=seed,
                     lam_total=scenario.lam_total)
    return ScenarioState(adj=adj, alive=np.ones(adj.shape[0], bool),
                         deploy=deploy, link_capacity=link_cap,
                         compute_capacity=comp_cap, bank=bank,
                         lam_total=scenario.lam_total, seed=seed)


def _event_rng(event_seed: int, state_seed: int, attempt: int = 0):
    return np.random.default_rng(
        1_000_003 * event_seed + 7919 * attempt + state_seed)


def _fail_nodes(state: ScenarioState, ev: NodeFail,
                max_tries: int = 200) -> np.ndarray:
    alive_idx = np.nonzero(state.alive)[0]
    if ev.count >= len(alive_idx):
        raise InfeasibleTopology("cannot fail every alive node")
    for t in range(max_tries):
        rng = _event_rng(ev.seed, state.seed, t)
        down = rng.choice(alive_idx, size=ev.count, replace=False)
        alive = state.alive.copy()
        alive[down] = False
        if not (state.deploy[:, alive].sum(1) > 0).all():
            continue                       # a version lost its last replica
        try:
            build_augmented(state.adj, state.deploy, state.link_capacity,
                            state.compute_capacity, alive=alive)
        except InfeasibleTopology:
            continue                       # some session lost admission
        return alive
    raise InfeasibleTopology(
        f"no feasible {ev.count}-node failure found for seed {state.seed}")


def apply_event(state: ScenarioState, ev: Event) -> ScenarioState:
    """Pure event transition: returns the post-event state (numpy copies)."""
    s = dataclasses.replace(state)
    if isinstance(ev, Rewire):
        # rewire the alive-induced subgraph; links among dead nodes persist
        idx = np.nonzero(state.alive)[0]
        sub = rewire_links(state.adj[np.ix_(idx, idx)], ev.frac,
                           seed=1_000_003 * ev.seed + state.seed)
        adj = state.adj.copy()
        adj[np.ix_(idx, idx)] = sub
        s.adj = adj
    elif isinstance(ev, NodeFail):
        s.alive = _fail_nodes(state, ev)
    elif isinstance(ev, NodeJoin):
        dead = np.nonzero(~state.alive)[0]
        k = len(dead) if ev.count is None else min(ev.count, len(dead))
        if k:
            rng = _event_rng(ev.seed, state.seed)
            up = rng.choice(dead, size=k, replace=False)
            alive = state.alive.copy()
            alive[up] = True
            s.alive = alive
    elif isinstance(ev, CapacityScale):
        s.link_capacity = state.link_capacity * ev.link
        s.compute_capacity = state.compute_capacity * ev.compute
    elif isinstance(ev, BankSwap):
        s.bank = make_bank(ev.bank_kind, state.deploy.shape[0],
                           seed=1_000_003 * ev.seed + state.seed,
                           lam_total=state.lam_total)
    elif isinstance(ev, DemandShift):
        s.lam_total = float(ev.lam_total)
    else:
        raise TypeError(f"unknown event {ev!r}")
    return s


# ---------------------------------------------------------------------------
# segment compilation
# ---------------------------------------------------------------------------

def event_schedule(scenario: Scenario
                   ) -> tuple[tuple[int, tuple[Event, ...]], ...]:
    """(segment_start, events firing there) pairs covering the horizon.

    The first entry is ``(0, ())`` — the initial segment.  This is the one
    definition of "when does what fire": :func:`compile_segments` consumes
    it for the offline batched sweeps and the serving simulation
    (``serve/sim.py``) replays the same schedule against the live router,
    so what is benchmarked is what serves (DESIGN.md §11).
    """
    bounds = (0,) + scenario.event_times + (scenario.horizon,)
    return tuple(
        (start, tuple(e for e in scenario.events if e.at == start))
        for start in bounds[:-1])


class Segment(NamedTuple):
    start: int                  # first outer iteration of the segment
    n_iters: int
    events: tuple[Event, ...]   # events applied at `start` (empty for first)
    batch: CECGraphBatch        # [B] instances, globally padded
    banks: UtilityBank          # stacked [B, W]
    lam_total: float


def compile_segments(scenario: Scenario,
                     seeds: Sequence[int]) -> tuple[Segment, ...]:
    """Evolve per-seed states through the timeline and batch each segment.

    Every graph is padded to the global (``n_phys``, ``depth_max``) over
    all segments and seeds, so all ``CECGraphBatch``es share static
    metadata — segments of equal length reuse one compiled solver.
    """
    states = [initial_state(scenario, s) for s in seeds]
    sched = event_schedule(scenario)
    ends = tuple(start for start, _ in sched[1:]) + (scenario.horizon,)

    raw: list[tuple[int, int, tuple[Event, ...], list[CECGraph],
                    list[UtilityBank], float]] = []
    for (start, evs), end in zip(sched, ends):
        for e in evs:                      # () for the first segment
            states = [apply_event(st, e) for st in states]
        lam_totals = {st.lam_total for st in states}
        assert len(lam_totals) == 1       # events are seed-uniform in λ
        raw.append((start, end - start, evs,
                    [st.graph() for st in states],
                    [st.bank for st in states], lam_totals.pop()))

    n_phys = max(g.n_phys for _, _, _, gs, _, _ in raw for g in gs)
    depth = max(g.depth_max for _, _, _, gs, _, _ in raw for g in gs)
    return tuple(
        Segment(start=start, n_iters=n, events=evs,
                batch=CECGraphBatch.from_graphs(
                    [pad_graph(g, n_phys, depth) for g in graphs]),
                banks=stack_banks(banks), lam_total=lam_total)
        for start, n, evs, graphs, banks, lam_total in raw)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

class ScenarioResult(NamedTuple):
    utility_traj: Array         # [B, horizon]
    lam_traj: Array             # [B, horizon, W]
    lam: Array                  # [B, W] final allocation
    phi: Array                  # [B, W, Nb, Nb] final routing
    segments: tuple[Segment, ...]


@functools.lru_cache(maxsize=None)
def _segment_solver(config: SolverConfig, cost_name: str, outer_iters: int,
                    mesh=None, _dispatch_key=None):
    """One jitted batched segment solve, cached on its static knobs.

    ``lam_total`` is a traced scalar argument (not a closure constant) so
    demand shifts reuse the same executable; the carried iterates enter
    and leave as a stacked ``SolverState`` (``None`` for the cold first
    segment).  ``mesh`` switches the segment onto the sharded fleet
    driver (``run_batch_sharded``) — a ``jax.sharding.Mesh`` is hashable,
    so it participates in the cache key, and ``_dispatch_key`` (pass
    ``dispatch.state_key()``) keeps entries from aliasing across kernel/
    sparse/fleet dispatch overrides active at trace time.
    """
    from .batch import run_batch, run_batch_sharded

    def fn(batch, banks, lam_total, state):
        if mesh is not None:
            return run_batch_sharded(batch, banks, lam_total, config,
                                     iters=outer_iters, cost=cost_name,
                                     mesh=mesh, state=state)
        return run_batch(batch, banks, lam_total, config,
                         iters=outer_iters, cost=cost_name, state=state)

    return jax.jit(fn)


def run_scenario(
    scenario: Scenario,
    *,
    seeds: Sequence[int] = (0,),
    method: Method = "single",
    cost_name: str = "exp",
    delta: float = 0.5,
    eta_outer: float = 0.05,
    eta_inner: float = 3.0,
    inner_iters: int = 1,
    explore: float = 0.1,
    config: SolverConfig | None = None,
    mesh=None,
) -> ScenarioResult:
    """Advance the online solver through the scenario's segments.

    The solver core's :class:`SolverState` is threaded across segment
    boundaries (warm-started at each event: φ through
    ``routing.warm_start_phi``, Λ rescaled and re-projected), so what
    crosses an event is exactly what the engine would carry — no raw
    ``(lam, phi)`` tuple plumbing.  Pass ``config`` (a ``SolverConfig``)
    to use the first-class API; the individual keyword knobs are the
    legacy surface and are ignored when ``config`` is given.  Returns
    stacked trajectories over the full horizon, which is what the
    dynamic-regret / recovery metrics (:func:`scenario_metrics`)
    measure.  An event-free scenario is exactly one batched
    ``solve_jowr`` (the static engine) — asserted to machine precision
    in the tests.

    ``mesh`` (a 1-D fleet mesh, see ``launch.mesh.fleet_mesh``) runs
    every segment on the sharded fleet driver
    (:func:`core.batch.run_batch_sharded`): the seed axis is partitioned
    across the mesh, warm-starts included — large seed ensembles scale
    across devices without touching the timeline logic.  Parity with the
    unsharded driver is part of the sharding test tier (DESIGN.md §14).
    """
    if config is None:
        config = SolverConfig(method=method, delta=float(delta),
                              eta_outer=float(eta_outer),
                              eta_inner=float(eta_inner),
                              inner_iters=int(inner_iters))
    from repro.obs import trace as _obs_trace

    segments = compile_segments(scenario, seeds)
    state: SolverState | None = None
    u_trajs, lam_trajs = [], []
    for k, seg in enumerate(segments):
        if k > 0:
            prev = segments[k - 1]
            for e in seg.events:
                _obs_trace.instant(f"event:{e.kind}", cat="scenario",
                                   args={"kind": e.kind, "segment": k,
                                         "at": seg.start})
            if any(e.changes_graph for e in seg.events):
                state = state._replace(phi=warm_start_phi(
                    state.phi, seg.batch.out_mask, explore))
            if seg.lam_total != prev.lam_total:
                lam = state.lam * (seg.lam_total / prev.lam_total)
                lam = project_box_simplex(lam, seg.lam_total, config.delta)
                state = state._replace(lam=lam)
        solve = _segment_solver(config, cost_name, seg.n_iters, mesh,
                                dispatch.state_key())
        with _obs_trace.span("scenario.segment", cat="scenario",
                             args={"segment": k, "start": seg.start,
                                   "iters": seg.n_iters,
                                   "lam_total": float(seg.lam_total)}):
            res = solve(seg.batch, seg.banks, jnp.float32(seg.lam_total),
                        state)
            if _obs_trace.current_tracer() is not None:
                # make the span cover the solve, not just the dispatch
                res.utility_traj.block_until_ready()
        state = res.state
        u_trajs.append(res.utility_traj)
        lam_trajs.append(res.lam_traj)
    return ScenarioResult(
        utility_traj=jnp.concatenate(u_trajs, axis=1),
        lam_traj=jnp.concatenate(lam_trajs, axis=1),
        lam=state.lam, phi=state.phi, segments=segments)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

class EventReport(NamedTuple):
    at: int
    kinds: tuple[str, ...]
    u_pre: float                # ensemble-mean utility just before the event
    u_drop: float               # ensemble-mean utility at the event iteration
    u_final: float              # ensemble-mean utility at segment end
    recovery_iters: float       # mean iterations to recovery (recovered seeds)
    recovered_frac: float       # share of seeds recovered within the segment


def scenario_metrics(
    result: ScenarioResult,
    *,
    recovery_frac: float = 0.95,
    pre_window: int = 5,
    opt_utilities: np.ndarray | None = None,
) -> dict:
    """Dynamic regret + per-event recovery from a scenario trajectory.

    Recovery for an event at t: first iteration τ ≥ t with
    U_τ ≥ ``recovery_frac`` · mean(U over the ``pre_window`` iterations
    before t), per seed, within the post-event segment.

    Dynamic regret is Σ_t (U*_seg(t) − U_t), averaged over seeds, against
    the per-segment comparator: ``opt_utilities`` ([n_segments] or
    [B, n_segments] genie optima from :func:`segment_optima`) when given,
    else the segment's own best observed utility (a lower bound on the
    true comparator — useful for trend tracking, not absolute claims).
    """
    traj = np.asarray(result.utility_traj)          # [B, T]
    B, T = traj.shape
    segs = result.segments

    if opt_utilities is None:
        comp = np.stack([traj[:, s.start:s.start + s.n_iters].max(-1)
                         for s in segs], axis=1)    # [B, n_segments]
    else:
        comp = np.asarray(opt_utilities, np.float64)
        if comp.ndim == 1:
            comp = np.broadcast_to(comp, (B, len(segs)))
    regret = 0.0
    for j, s in enumerate(segs):
        seg_traj = traj[:, s.start:s.start + s.n_iters]
        regret += (comp[:, j:j + 1] - seg_traj).sum(-1)
    dynamic_regret = float(np.mean(regret))

    reports = []
    for j, s in enumerate(segs):
        if not s.events:
            continue
        t0 = s.start
        pre = traj[:, max(0, t0 - pre_window):t0].mean(-1)      # [B]
        seg_traj = traj[:, t0:t0 + s.n_iters]
        thresh = recovery_frac * pre
        hit = seg_traj >= thresh[:, None]
        rec_iters = np.where(hit.any(-1), hit.argmax(-1), -1)    # [B]
        ok = rec_iters >= 0
        reports.append(EventReport(
            at=t0,
            kinds=tuple(e.kind for e in s.events),
            u_pre=float(pre.mean()),
            u_drop=float(seg_traj[:, 0].mean()),
            u_final=float(seg_traj[:, -1].mean()),
            recovery_iters=float(rec_iters[ok].mean()) if ok.any() else float("inf"),
            recovered_frac=float(ok.mean()),
        ))
    return {"dynamic_regret": dynamic_regret,
            "comparator": "genie" if opt_utilities is not None else "self-max",
            "horizon": T, "n_seeds": B,
            "events": reports}


def segment_optima(scenario: Scenario, seeds: Sequence[int], *,
                   cost_name: str = "exp", outer_iters: int = 150,
                   inner_iters: int = 60, eta: float = 0.05,
                   eta_inner: float = 3.0) -> np.ndarray:
    """[B, n_segments] genie (true-gradient) optimum U* per segment.

    The absolute dynamic-regret comparator: what a controller that *knew*
    the utilities could reach in each segment.  Python-loop expensive —
    meant for benchmarks and offline analysis, not the hot path.
    """
    from .opt_baseline import exact_gradient_allocation

    cost = _costs.get(cost_name)
    segments = compile_segments(scenario, seeds)
    out = np.zeros((len(seeds), len(segments)))
    for j, seg in enumerate(segments):
        for b in range(len(seeds)):
            bank = UtilityBank(a=seg.banks.a[b], b=seg.banks.b[b],
                               kind=seg.banks.kind, noise=seg.banks.noise)
            _, _, u = exact_gradient_allocation(
                seg.batch.instance(b), cost, bank, seg.lam_total,
                eta=eta, outer_iters=outer_iters, inner_iters=inner_iters,
                eta_inner=eta_inner)
            out[b, j] = u
    return out


# ---------------------------------------------------------------------------
# named catalog — the benchmark suite and any "imagine a scenario" consumer
# ---------------------------------------------------------------------------

def named_scenarios(horizon: int = 100, *, n: int = 25, p: float = 0.2,
                    n_sessions: int = 3, lam_total: float = 60.0) -> dict:
    """The standard suite over Connected-ER(n, p) (benchmarks/tests)."""
    base = dict(horizon=horizon, topology="connected_er",
                topo_kwargs={"n": n, "p": p}, n_sessions=n_sessions,
                mean_capacity=10.0, bank_kind="log", lam_total=lam_total)
    h = horizon
    scenarios = [
        Scenario("steady", **base),
        Scenario("link_churn", events=(Rewire(at=h // 2, frac=0.3, seed=5),),
                 **base),
        Scenario("node_failure",
                 events=(NodeFail(at=2 * h // 5, count=3, seed=11),
                         NodeJoin(at=4 * h // 5)), **base),
        Scenario("capacity_drift",
                 events=(CapacityScale(at=h // 4, link=0.6, compute=0.8),
                         CapacityScale(at=3 * h // 4, link=1.5,
                                       compute=1.25)), **base),
        # +25% keeps the surge inside network capacity (a 1.5× surge on the
        # paper instance saturates links into the linearized-exp regime)
        Scenario("demand_surge",
                 events=(DemandShift(at=h // 2, lam_total=1.25 * lam_total),),
                 **base),
        Scenario("utility_swap",
                 events=(BankSwap(at=h // 2, bank_kind="sqrt", seed=3),),
                 **base),
        Scenario("flash_crowd",
                 events=(NodeFail(at=h // 2, count=2, seed=17),
                         DemandShift(at=h // 2, lam_total=1.25 * lam_total)),
                 **base),
    ]
    return {s.name: s for s in scenarios}
