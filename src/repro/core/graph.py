"""Augmented CEC flow graph (paper §II-A, §II-C).

Builds the augmented graph Ḡ = (N̄, Ē) from a physical topology:

* a virtual source ``S`` (the admission controller) with edges to every
  device deploying the *smallest* model version ``D(1)`` (paper §II-C);
* one virtual sink ``D_w`` per model version ``w`` with edges from every
  device in ``D(w)``;  the computation cost of node ``i`` becomes the link
  cost of the virtual edge ``(i, D_w)`` (paper eq. (6)).

Loop-freedom (required by Gallager routing variables) is enforced
structurally: physical edges are oriented along a BFS-layer total order from
``S``, so any row-stochastic φ is automatically loop-free and the flow
propagation fixed point is reached in ≤ ``depth_max`` relaxation steps
(DESIGN.md §3).  Per-session edge masks additionally encode:

* nodes in ``D(w)`` forward session ``w`` only to ``D_w`` (paper constr. (3):
  a deploying node processes, never relays, its own session);
* edges are kept only if the head can still reach ``D_w`` ("useful" nodes),
  so every unit of admitted traffic provably drains into its sink.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import numpy as np
import jax.numpy as jnp


class InfeasibleTopology(RuntimeError):
    """Raised when some session has no S→D_w path in the oriented DAG."""


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CECGraph:
    """Static description of the augmented CEC graph.

    Array fields are pytree leaves; scalar metadata is static (hashable) so a
    ``CECGraph`` can be closed over or passed through ``jax.jit``.
    """

    # --- data (pytree leaves) ---
    out_mask: jax.Array      # [W, Nb, Nb] float {0,1}: session-w allowed out-edges
    edge_mask: jax.Array     # [Nb, Nb]    float {0,1}: union of session masks
    capacity: jax.Array      # [Nb, Nb]    link/compute capacities (1 where unused)
    deploy: jax.Array        # [W, N]      bool: node i hosts version w
    sinks: jax.Array         # [W]         int: index of virtual sink D_w
    # --- static metadata ---
    n_phys: int = dataclasses.field(metadata=dict(static=True))
    n_sessions: int = dataclasses.field(metadata=dict(static=True))
    n_bar: int = dataclasses.field(metadata=dict(static=True))
    depth_max: int = dataclasses.field(metadata=dict(static=True))
    src: int = dataclasses.field(metadata=dict(static=True))

    @property
    def W(self) -> int:
        return self.n_sessions

    def uniform_phi(self) -> jax.Array:
        """Uniform routing over allowed out-edges (Alg. 2 line 1)."""
        rowsum = self.out_mask.sum(-1, keepdims=True)
        return self.out_mask / jnp.where(rowsum > 0, rowsum, 1.0)

    def injection(self, lam: jax.Array) -> jax.Array:
        """[W, Nb] exogenous injection: session w's rate λ_w enters at S."""
        inject = jnp.zeros((self.n_sessions, self.n_bar), lam.dtype)
        return inject.at[:, self.src].set(lam)


class _AugmentedStructure(NamedTuple):
    """Numpy scaffolding shared by the dense and sparse assemblers.

    ``_analyze`` performs every topology decision exactly once — alive
    masking, BFS layering, DAG orientation, per-session usefulness — so
    ``build_augmented`` (dense ``[W, N̄, N̄]`` masks) and
    ``build_augmented_sparse`` (padded edge lists, DESIGN.md §12) cannot
    drift apart structurally.
    """

    adj: np.ndarray       # [N, N] alive-masked physical adjacency
    deploy: np.ndarray    # [W, N] alive-masked deployment
    dag: np.ndarray       # [N, N] BFS-layer oriented physical edges
    useful: np.ndarray    # [W, N] node can still deliver session w to D_w
    d1: np.ndarray        # [N] admission points D(1)
    key: np.ndarray       # [N] total-order key of the DAG orientation


def _bfs_depth(adj: np.ndarray, sources: np.ndarray) -> np.ndarray:
    n = adj.shape[0]
    depth = np.full(n, np.inf)
    depth[sources] = 0.0
    frontier = list(np.nonzero(sources)[0])
    d = 0
    while frontier:
        d += 1
        nxt = []
        for i in frontier:
            for j in np.nonzero(adj[i])[0]:
                if depth[j] == np.inf:
                    depth[j] = d
                    nxt.append(j)
        frontier = nxt
    return depth


def _analyze(adj_undirected: np.ndarray, deploy: np.ndarray,
             alive: np.ndarray | None) -> _AugmentedStructure:
    """Alive masking + BFS layering + DAG orientation + usefulness pruning."""
    adj = np.asarray(adj_undirected, bool)
    deploy = np.asarray(deploy, bool)
    W, N = deploy.shape
    if not (deploy.sum(0) == 1).all():
        raise ValueError("each node must deploy exactly one model version")
    relaxed = alive is not None
    alive = np.ones(N, bool) if alive is None else np.asarray(alive, bool)
    adj = adj & alive[:, None] & alive[None, :]
    deploy = deploy & alive[None, :]
    if (deploy.sum(1) == 0).any():
        raise InfeasibleTopology("some model version has no (alive) deployment")

    # BFS layering from the admission points D(1); S sits at depth -1.
    d1 = deploy[0]
    depth = _bfs_depth(adj, d1)
    unreachable = np.isinf(depth)
    if unreachable.any() and not relaxed:
        raise InfeasibleTopology("physical graph is not connected")
    # Total order key → DAG orientation (strict, ties broken by index).
    # Unreachable/dead nodes sort after every reachable node (max reachable
    # key is < N², edgeless anyway for dead ones).
    key = np.where(unreachable, float(N * N), depth * N) + np.arange(N)
    dag = adj & (key[:, None] < key[None, :])

    # usefulness: can node i still deliver session-w traffic to D_w?
    order = np.argsort(key)                      # topological order of the DAG
    useful = np.zeros((W, N), bool)
    for w in range(W):
        useful[w, deploy[w]] = True
        for i in order[::-1]:
            if deploy[w, i]:
                continue                         # D(w) nodes never relay w
            useful[w, i] = bool((dag[i] & useful[w]).any())

    return _AugmentedStructure(adj=adj, deploy=deploy, dag=dag,
                               useful=useful, d1=d1, key=key)


def _relaxation_depth(any_edge: np.ndarray, key: np.ndarray, N: int,
                      W: int) -> int:
    """Longest path in the augmented union DAG + 1 — the exact Jacobi
    relaxation step count (shared by both assemblers)."""
    n_bar = N + 1 + W
    akey = np.concatenate([key, [-1.0], key.max() + 1 + np.arange(W)])
    aorder = np.argsort(akey)
    lp = np.zeros(n_bar)
    for i in aorder:
        heads = np.nonzero(any_edge[:, i])[0]
        if heads.size:
            lp[i] = lp[heads].max() + 1
    return int(lp.max()) + 1


def build_augmented(
    adj_undirected: np.ndarray,
    deploy: np.ndarray,
    link_capacity: np.ndarray,
    compute_capacity: np.ndarray,
    src_capacity: float = 1e4,
    alive: np.ndarray | None = None,
) -> CECGraph:
    """Build the augmented DAG from a physical topology.

    Args:
      adj_undirected: [N, N] bool symmetric physical adjacency.
      deploy: [W, N] bool, exactly one version per node (paper §II-A).
      link_capacity: [N, N] symmetric positive capacities C_ij.
      compute_capacity: [N] node compute capacities C_i.
      src_capacity: capacity of the virtual admission links (S, i).
      alive: optional [N] bool node-liveness mask (scenario engine,
        DESIGN.md §10).  Dead nodes stay in the index space but get no
        edges and no deployment — exactly the isolated-pad-node convention
        of ``core/batch.pad_graph`` — so iterates warm-start across
        fail/join events without any index remapping.  With an explicit
        ``alive`` the physical graph may be disconnected: unreachable
        nodes are ordered after all reachable ones and usefulness pruning
        inerts them; only session-level reachability from S is enforced.
    """
    s = _analyze(adj_undirected, deploy, alive)
    deploy = s.deploy
    W, N = deploy.shape

    src = N
    sinks = np.arange(W) + N + 1
    n_bar = N + 1 + W

    out_mask = np.zeros((W, n_bar, n_bar), np.float32)
    for w in range(W):
        relay = ~deploy[w]
        # physical relays: DAG edges whose head is still useful for w
        m = s.dag & relay[:, None] & s.useful[w][None, :]
        # ... and whose tail can receive w-traffic at all
        m &= s.useful[w][:, None]
        out_mask[w, :N, :N] = m
        out_mask[w, np.nonzero(deploy[w])[0], sinks[w]] = 1.0  # D(w) → D_w
        out_mask[w, src, :N] = (s.d1 & s.useful[w]).astype(np.float32)
        if out_mask[w, src].sum() == 0:
            raise InfeasibleTopology(f"session {w} unreachable from S")

    edge_mask = (out_mask.sum(0) > 0).astype(np.float32)

    cap = np.ones((n_bar, n_bar), np.float32)
    cap[:N, :N] = np.asarray(link_capacity, np.float32)
    for w in range(W):
        cap[:N, sinks[w]] = np.asarray(compute_capacity, np.float32)
    cap[src, :N] = src_capacity

    depth_max = _relaxation_depth(edge_mask > 0, s.key, N, W)

    return CECGraph(
        out_mask=jnp.asarray(out_mask),
        edge_mask=jnp.asarray(edge_mask),
        capacity=jnp.asarray(cap),
        deploy=jnp.asarray(deploy),
        sinks=jnp.asarray(sinks),
        n_phys=N,
        n_sessions=W,
        n_bar=n_bar,
        depth_max=depth_max,
        src=src,
    )


def random_deployment(n: int, n_versions: int, rng: np.random.Generator) -> np.ndarray:
    """Random one-version-per-node deployment with every version present."""
    assign = rng.integers(0, n_versions, size=n)
    assign[:n_versions] = np.arange(n_versions)    # guarantee coverage
    rng.shuffle(assign)
    deploy = np.zeros((n_versions, n), bool)
    deploy[assign, np.arange(n)] = True
    return deploy


class InstanceDraw(NamedTuple):
    """A feasible random instance: the built graph plus the raw numpy state
    (``deploy``, ``link_capacity``, ``compute_capacity``) the scenario
    engine mutates between segments (DESIGN.md §10)."""

    graph: CECGraph
    deploy: np.ndarray
    link_capacity: np.ndarray
    compute_capacity: np.ndarray


def draw_instance(
    adj: np.ndarray,
    n_versions: int,
    mean_link_capacity: float,
    seed: int,
    mean_compute_capacity: float | None = None,
    max_tries: int = 50,
) -> InstanceDraw:
    """Randomized capacities + deployment (paper §IV experiment setup).

    Link capacities C_ij ~ U[0, 2·C̄] (floored at 0.05·C̄ for numerical
    sanity of the exp link cost), retried until the instance is feasible.
    """
    n = adj.shape[0]
    mean_cc = mean_compute_capacity or mean_link_capacity
    for t in range(max_tries):
        rng = np.random.default_rng(seed + 1000 * t)
        cap = rng.uniform(0.05, 2.0, size=(n, n)) * mean_link_capacity
        cap = np.maximum(cap, cap.T)  # symmetric draw per undirected link
        comp = rng.uniform(0.5, 1.5, size=n) * mean_cc
        deploy = random_deployment(n, n_versions, rng)
        try:
            graph = build_augmented(adj, deploy, cap, comp)
        except InfeasibleTopology:
            continue
        return InstanceDraw(graph, deploy, cap, comp)
    raise InfeasibleTopology(f"no feasible instance after {max_tries} tries")


def build_random_cec(
    adj: np.ndarray,
    n_versions: int,
    mean_link_capacity: float,
    seed: int,
    mean_compute_capacity: float | None = None,
    max_tries: int = 50,
) -> CECGraph:
    """``draw_instance`` returning only the built graph (the common case)."""
    return draw_instance(adj, n_versions, mean_link_capacity, seed,
                         mean_compute_capacity, max_tries).graph


# ---------------------------------------------------------------------------
# sparse edge-list representation (DESIGN.md §12)
# ---------------------------------------------------------------------------

class SparsePhi(NamedTuple):
    """Edge-slot field over a :class:`CECGraphSparse` — routing variables φ,
    and (by structural identity) the marginal-cost field δ.

    ``rows[w, i, d]`` sits on the edge ``(i, nbr[i, d])`` — physical relay
    edges plus each deploying node's compute edge; ``src[w, d]`` sits on the
    admission edge ``(S, src_nbr[d])``.  The virtual source's fan-out is
    Θ(N/W) (every node deploying the smallest version), so it gets its own
    dense row instead of inflating the per-node slot count ``d_max`` — the
    hub-row exception that keeps the padded layout O(E) (DESIGN.md §12.1).
    Invariant: entries on invalid slots (mask 0) are exactly zero.
    """

    rows: jax.Array      # [W, Nb, D]
    src: jax.Array       # [W, Ds]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CECGraphSparse:
    """Sparse (padded edge-list) twin of :class:`CECGraph`.

    Same augmented-node index space (physical ``[0, N)``, source ``N``,
    sinks ``N+1+w`` — ``pad_graph``-compatible, alive-mask-compatible) and
    the same static-metadata/jit contract, but state is O(E) instead of
    O(N̄²): a CSR-style padded out-edge list per node (``nbr``/``out_mask``/
    ``capacity``, ``d_max`` slots), a dedicated admission row for the
    virtual source (``src_*``, ``d_src`` slots), and a padded CSC in-edge
    list over the physical relay edges (``in_*``, ``d_in_max`` slots) that
    turns flow propagation into a gather + row-sum instead of a scatter.
    Compute (sink) edges live in their tail's row (slot ``sink_slot[i]``);
    sink inflow is accumulated analytically (W scalars), never via the
    in-lists, so virtual-node hubs cannot inflate the padded degree.
    Solvers accept either representation (``core.flow`` / ``core.marginal``
    / ``core.routing`` dispatch on the type); ``core.dispatch.
    maybe_sparsify`` converts automatically past the (N, density)
    threshold.
    """

    # --- CSR out-edge rows: physical relay + compute edges ---
    nbr: jax.Array          # [Nb, D] int32 head of slot (i,d); pad → i
    out_mask: jax.Array     # [W, Nb, D] float {0,1} session-allowed slots
    edge_mask: jax.Array    # [Nb, D] float {0,1} union of session slots
    capacity: jax.Array     # [Nb, D] capacities (1 where unused)
    sink_slot: jax.Array    # [N] int32 slot of node i's compute edge (else 0)
    # --- virtual-source admission row ---
    src_nbr: jax.Array      # [Ds] int32 heads of S→D(1) edges; pad → src
    src_out_mask: jax.Array  # [W, Ds]
    src_edge_mask: jax.Array  # [Ds]
    src_capacity: jax.Array  # [Ds]
    # --- CSC in-edge lists over physical relay edges only ---
    in_src: jax.Array       # [Nb, Din] int32 tail; pad → 0
    in_slot: jax.Array      # [Nb, Din] int32 slot in the tail's row; pad → 0
    in_mask: jax.Array      # [Nb, Din] float {0,1}
    # --- shared with the dense twin ---
    deploy: jax.Array       # [W, N] bool
    sinks: jax.Array        # [W] int
    # --- static metadata ---
    n_phys: int = dataclasses.field(metadata=dict(static=True))
    n_sessions: int = dataclasses.field(metadata=dict(static=True))
    n_bar: int = dataclasses.field(metadata=dict(static=True))
    depth_max: int = dataclasses.field(metadata=dict(static=True))
    src: int = dataclasses.field(metadata=dict(static=True))
    d_max: int = dataclasses.field(metadata=dict(static=True))
    d_src: int = dataclasses.field(metadata=dict(static=True))
    d_in_max: int = dataclasses.field(metadata=dict(static=True))
    n_edges: int = dataclasses.field(metadata=dict(static=True))

    @property
    def W(self) -> int:
        return self.n_sessions

    @property
    def density(self) -> float:
        """Union edge count over the dense N̄² slot budget."""
        return self.n_edges / float(self.n_bar * self.n_bar)

    def uniform_phi(self) -> SparsePhi:
        """Uniform routing over allowed slots (Alg. 2 line 1)."""
        rowsum = self.out_mask.sum(-1, keepdims=True)
        rows = self.out_mask / jnp.where(rowsum > 0, rowsum, 1.0)
        ssum = self.src_out_mask.sum(-1, keepdims=True)
        return SparsePhi(rows=rows,
                         src=self.src_out_mask / jnp.where(ssum > 0, ssum, 1.0))

    def injection(self, lam: jax.Array) -> jax.Array:
        """[W, Nb] exogenous injection: session w's rate λ_w enters at S."""
        inject = jnp.zeros((self.n_sessions, self.n_bar), lam.dtype)
        return inject.at[:, self.src].set(lam)


def _pack_sparse(row_heads, row_sess, row_caps, src_heads, src_sess,
                 src_caps, deploy, depth_max: int) -> CECGraphSparse:
    """Assemble a :class:`CECGraphSparse` from per-node edge lists.

    ``row_heads[i]`` is node i's sorted head array (relay heads first,
    compute edge last — sink indices exceed every physical index);
    ``row_sess[i]`` is the matching [W, k] session-membership block and
    ``row_caps[i]`` the [k] capacities.  The source row comes as flat
    arrays.  Padding conventions: out slots point at their own row
    (``nbr`` pad → i), in slots at (0, 0) — all gathers stay in-bounds and
    every padded entry is killed by a zero mask.
    """
    W, N = np.asarray(deploy, bool).shape
    src = N
    n_bar = N + 1 + W

    d_max = max([1] + [len(h) for h in row_heads])
    nbr = np.tile(np.arange(n_bar, dtype=np.int32)[:, None], (1, d_max))
    out_mask = np.zeros((W, n_bar, d_max), np.float32)
    edge_mask = np.zeros((n_bar, d_max), np.float32)
    capacity = np.ones((n_bar, d_max), np.float32)
    sink_slot = np.zeros(N, np.int32)
    in_lists: list[list[tuple[int, int]]] = [[] for _ in range(N)]
    for i, heads in enumerate(row_heads):
        k = len(heads)
        if k == 0:
            continue
        nbr[i, :k] = heads
        out_mask[:, i, :k] = row_sess[i]
        edge_mask[i, :k] = (np.asarray(row_sess[i]).sum(0) > 0)
        capacity[i, :k] = row_caps[i]
        for d, j in enumerate(heads):
            if j > src:                      # compute edge → virtual sink
                sink_slot[i] = d
            elif j < N:                      # physical relay edge
                in_lists[j].append((i, d))

    d_src = max(1, len(src_heads))
    src_nbr = np.full(d_src, src, np.int32)
    src_out_mask = np.zeros((W, d_src), np.float32)
    src_edge_mask = np.zeros(d_src, np.float32)
    src_capacity = np.ones(d_src, np.float32)
    k = len(src_heads)
    if k:
        src_nbr[:k] = src_heads
        src_out_mask[:, :k] = src_sess
        src_edge_mask[:k] = (np.asarray(src_sess).sum(0) > 0)
        src_capacity[:k] = src_caps

    d_in = max([1] + [len(l) for l in in_lists])
    in_src = np.zeros((n_bar, d_in), np.int32)
    in_slot = np.zeros((n_bar, d_in), np.int32)
    in_mask = np.zeros((n_bar, d_in), np.float32)
    for j, lst in enumerate(in_lists):
        for d, (i, sl) in enumerate(lst):
            in_src[j, d], in_slot[j, d], in_mask[j, d] = i, sl, 1.0

    n_edges = int(edge_mask.sum() + src_edge_mask.sum())
    return CECGraphSparse(
        nbr=jnp.asarray(nbr), out_mask=jnp.asarray(out_mask),
        edge_mask=jnp.asarray(edge_mask), capacity=jnp.asarray(capacity),
        sink_slot=jnp.asarray(sink_slot),
        src_nbr=jnp.asarray(src_nbr), src_out_mask=jnp.asarray(src_out_mask),
        src_edge_mask=jnp.asarray(src_edge_mask),
        src_capacity=jnp.asarray(src_capacity),
        in_src=jnp.asarray(in_src), in_slot=jnp.asarray(in_slot),
        in_mask=jnp.asarray(in_mask),
        deploy=jnp.asarray(np.asarray(deploy, bool)),
        sinks=jnp.asarray(N + 1 + np.arange(W)),
        n_phys=N, n_sessions=W, n_bar=n_bar, depth_max=depth_max, src=src,
        d_max=d_max, d_src=d_src, d_in_max=d_in, n_edges=n_edges)


def sparsify(graph: CECGraph) -> CECGraphSparse:
    """Convert a dense :class:`CECGraph` to the edge-list layout.

    Exactly equivalent (``tests/test_sparse_parity.py``): same index
    space, same ``depth_max``, and slot order matching
    :func:`build_augmented_sparse` (heads ascending — the compute edge,
    whose sink index exceeds every physical index, lands last).
    """
    om = np.asarray(graph.out_mask)
    em = np.asarray(graph.edge_mask)
    cap = np.asarray(graph.capacity)
    N, src = graph.n_phys, graph.src

    row_heads, row_sess, row_caps = [], [], []
    for i in range(N):
        heads = np.nonzero(em[i] > 0)[0].astype(np.int32)
        row_heads.append(heads)
        row_sess.append(om[:, i, heads].astype(np.float32))
        row_caps.append(cap[i, heads].astype(np.float32))
    src_heads = np.nonzero(em[src] > 0)[0].astype(np.int32)
    return _pack_sparse(row_heads, row_sess, row_caps,
                        src_heads, om[:, src, src_heads].astype(np.float32),
                        cap[src, src_heads].astype(np.float32),
                        np.asarray(graph.deploy), graph.depth_max)


def build_augmented_sparse(
    adj_undirected: np.ndarray,
    deploy: np.ndarray,
    link_capacity: np.ndarray,
    compute_capacity: np.ndarray,
    src_capacity: float = 1e4,
    alive: np.ndarray | None = None,
) -> CECGraphSparse:
    """Build the augmented DAG directly in the edge-list layout.

    Same arguments and semantics as :func:`build_augmented` but never
    materializes a ``[W, N̄, N̄]`` tensor — O(N² bool + E) working memory —
    so fleet-scale topologies (N ≥ 1024, ``topo.topologies`` generators)
    build without the dense detour.  ``sparsify(build_augmented(x)) ==
    build_augmented_sparse(x)`` array-for-array (tested).
    """
    s = _analyze(adj_undirected, deploy, alive)
    W, N = s.deploy.shape
    sinks = N + 1 + np.arange(W)
    link_capacity = np.asarray(link_capacity, np.float32)
    compute_capacity = np.asarray(compute_capacity, np.float32)

    row_heads, row_sess, row_caps = [], [], []
    for i in range(N):
        heads = np.nonzero(s.dag[i])[0]
        sess = np.zeros((W, len(heads)), bool)
        for w in range(W):
            if not s.deploy[w, i] and s.useful[w, i]:
                sess[w] = s.useful[w][heads]
        keep = sess.any(0)
        heads, sess = heads[keep], sess[:, keep]
        caps = link_capacity[i, heads]
        wdep = np.nonzero(s.deploy[:, i])[0]
        if wdep.size:                            # compute edge D(w) → D_w
            w = int(wdep[0])
            heads = np.concatenate([heads, [sinks[w]]])
            col = np.zeros((W, 1), bool)
            col[w] = True
            sess = np.concatenate([sess, col], axis=1)
            caps = np.concatenate([caps, [compute_capacity[i]]])
        row_heads.append(heads.astype(np.int32))
        row_sess.append(sess.astype(np.float32))
        row_caps.append(caps.astype(np.float32))

    src_sess = np.stack([s.d1 & s.useful[w] for w in range(W)])   # [W, N]
    for w in range(W):
        if src_sess[w].sum() == 0:
            raise InfeasibleTopology(f"session {w} unreachable from S")
    src_heads = np.nonzero(src_sess.any(0))[0].astype(np.int32)

    any_edge = np.zeros((N + 1 + W, N + 1 + W), bool)
    for i in range(N):
        any_edge[i, row_heads[i]] = True
    any_edge[N, src_heads] = True
    depth_max = _relaxation_depth(any_edge, s.key, N, W)

    return _pack_sparse(
        row_heads, row_sess, row_caps, src_heads,
        src_sess[:, src_heads].astype(np.float32),
        np.full(len(src_heads), src_capacity, np.float32),
        s.deploy, depth_max)
