"""Augmented CEC flow graph (paper §II-A, §II-C).

Builds the augmented graph Ḡ = (N̄, Ē) from a physical topology:

* a virtual source ``S`` (the admission controller) with edges to every
  device deploying the *smallest* model version ``D(1)`` (paper §II-C);
* one virtual sink ``D_w`` per model version ``w`` with edges from every
  device in ``D(w)``;  the computation cost of node ``i`` becomes the link
  cost of the virtual edge ``(i, D_w)`` (paper eq. (6)).

Loop-freedom (required by Gallager routing variables) is enforced
structurally: physical edges are oriented along a BFS-layer total order from
``S``, so any row-stochastic φ is automatically loop-free and the flow
propagation fixed point is reached in ≤ ``depth_max`` relaxation steps
(DESIGN.md §3).  Per-session edge masks additionally encode:

* nodes in ``D(w)`` forward session ``w`` only to ``D_w`` (paper constr. (3):
  a deploying node processes, never relays, its own session);
* edges are kept only if the head can still reach ``D_w`` ("useful" nodes),
  so every unit of admitted traffic provably drains into its sink.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import numpy as np
import jax.numpy as jnp


class InfeasibleTopology(RuntimeError):
    """Raised when some session has no S→D_w path in the oriented DAG."""


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CECGraph:
    """Static description of the augmented CEC graph.

    Array fields are pytree leaves; scalar metadata is static (hashable) so a
    ``CECGraph`` can be closed over or passed through ``jax.jit``.
    """

    # --- data (pytree leaves) ---
    out_mask: jax.Array      # [W, Nb, Nb] float {0,1}: session-w allowed out-edges
    edge_mask: jax.Array     # [Nb, Nb]    float {0,1}: union of session masks
    capacity: jax.Array      # [Nb, Nb]    link/compute capacities (1 where unused)
    deploy: jax.Array        # [W, N]      bool: node i hosts version w
    sinks: jax.Array         # [W]         int: index of virtual sink D_w
    # --- static metadata ---
    n_phys: int = dataclasses.field(metadata=dict(static=True))
    n_sessions: int = dataclasses.field(metadata=dict(static=True))
    n_bar: int = dataclasses.field(metadata=dict(static=True))
    depth_max: int = dataclasses.field(metadata=dict(static=True))
    src: int = dataclasses.field(metadata=dict(static=True))

    @property
    def W(self) -> int:
        return self.n_sessions

    def uniform_phi(self) -> jax.Array:
        """Uniform routing over allowed out-edges (Alg. 2 line 1)."""
        rowsum = self.out_mask.sum(-1, keepdims=True)
        return self.out_mask / jnp.where(rowsum > 0, rowsum, 1.0)

    def injection(self, lam: jax.Array) -> jax.Array:
        """[W, Nb] exogenous injection: session w's rate λ_w enters at S."""
        inject = jnp.zeros((self.n_sessions, self.n_bar), lam.dtype)
        return inject.at[:, self.src].set(lam)


def _bfs_depth(adj: np.ndarray, sources: np.ndarray) -> np.ndarray:
    n = adj.shape[0]
    depth = np.full(n, np.inf)
    depth[sources] = 0.0
    frontier = list(np.nonzero(sources)[0])
    d = 0
    while frontier:
        d += 1
        nxt = []
        for i in frontier:
            for j in np.nonzero(adj[i])[0]:
                if depth[j] == np.inf:
                    depth[j] = d
                    nxt.append(j)
        frontier = nxt
    return depth


def build_augmented(
    adj_undirected: np.ndarray,
    deploy: np.ndarray,
    link_capacity: np.ndarray,
    compute_capacity: np.ndarray,
    src_capacity: float = 1e4,
    alive: np.ndarray | None = None,
) -> CECGraph:
    """Build the augmented DAG from a physical topology.

    Args:
      adj_undirected: [N, N] bool symmetric physical adjacency.
      deploy: [W, N] bool, exactly one version per node (paper §II-A).
      link_capacity: [N, N] symmetric positive capacities C_ij.
      compute_capacity: [N] node compute capacities C_i.
      src_capacity: capacity of the virtual admission links (S, i).
      alive: optional [N] bool node-liveness mask (scenario engine,
        DESIGN.md §10).  Dead nodes stay in the index space but get no
        edges and no deployment — exactly the isolated-pad-node convention
        of ``core/batch.pad_graph`` — so iterates warm-start across
        fail/join events without any index remapping.  With an explicit
        ``alive`` the physical graph may be disconnected: unreachable
        nodes are ordered after all reachable ones and usefulness pruning
        inerts them; only session-level reachability from S is enforced.
    """
    adj = np.asarray(adj_undirected, bool)
    deploy = np.asarray(deploy, bool)
    W, N = deploy.shape
    if not (deploy.sum(0) == 1).all():
        raise ValueError("each node must deploy exactly one model version")
    relaxed = alive is not None
    alive = np.ones(N, bool) if alive is None else np.asarray(alive, bool)
    adj = adj & alive[:, None] & alive[None, :]
    deploy = deploy & alive[None, :]
    if (deploy.sum(1) == 0).any():
        raise InfeasibleTopology("some model version has no (alive) deployment")

    src = N
    sinks = np.arange(W) + N + 1
    n_bar = N + 1 + W

    # BFS layering from the admission points D(1); S sits at depth -1.
    d1 = deploy[0]
    depth = _bfs_depth(adj, d1)
    unreachable = np.isinf(depth)
    if unreachable.any() and not relaxed:
        raise InfeasibleTopology("physical graph is not connected")
    # Total order key → DAG orientation (strict, ties broken by index).
    # Unreachable/dead nodes sort after every reachable node (max reachable
    # key is < N², edgeless anyway for dead ones).
    key = np.where(unreachable, float(N * N), depth * N) + np.arange(N)
    dag = adj & (key[:, None] < key[None, :])

    # usefulness: can node i still deliver session-w traffic to D_w?
    order = np.argsort(key)                      # topological order of the DAG
    useful = np.zeros((W, N), bool)
    for w in range(W):
        useful[w, deploy[w]] = True
        for i in order[::-1]:
            if deploy[w, i]:
                continue                         # D(w) nodes never relay w
            useful[w, i] = bool((dag[i] & useful[w]).any())

    out_mask = np.zeros((W, n_bar, n_bar), np.float32)
    for w in range(W):
        relay = ~deploy[w]
        # physical relays: DAG edges whose head is still useful for w
        m = dag & relay[:, None] & useful[w][None, :]
        # ... and whose tail can receive w-traffic at all
        m &= useful[w][:, None]
        out_mask[w, :N, :N] = m
        out_mask[w, np.nonzero(deploy[w])[0], sinks[w]] = 1.0  # D(w) → D_w
        out_mask[w, src, :N] = (d1 & useful[w]).astype(np.float32)  # S → D(1)
        if out_mask[w, src].sum() == 0:
            raise InfeasibleTopology(f"session {w} unreachable from S")

    edge_mask = (out_mask.sum(0) > 0).astype(np.float32)

    cap = np.ones((n_bar, n_bar), np.float32)
    cap[:N, :N] = np.asarray(link_capacity, np.float32)
    for w in range(W):
        cap[:N, sinks[w]] = np.asarray(compute_capacity, np.float32)
    cap[src, :N] = src_capacity

    # longest path in the augmented DAG bounds the relaxation step count
    akey = np.concatenate([key, [-1.0], key.max() + 1 + np.arange(W)])
    aorder = np.argsort(akey)
    any_edge = edge_mask > 0
    lp = np.zeros(n_bar)
    for i in aorder:
        heads = np.nonzero(any_edge[:, i])[0]
        if heads.size:
            lp[i] = lp[heads].max() + 1
    depth_max = int(lp.max()) + 1

    return CECGraph(
        out_mask=jnp.asarray(out_mask),
        edge_mask=jnp.asarray(edge_mask),
        capacity=jnp.asarray(cap),
        deploy=jnp.asarray(deploy),
        sinks=jnp.asarray(sinks),
        n_phys=N,
        n_sessions=W,
        n_bar=n_bar,
        depth_max=depth_max,
        src=src,
    )


def random_deployment(n: int, n_versions: int, rng: np.random.Generator) -> np.ndarray:
    """Random one-version-per-node deployment with every version present."""
    assign = rng.integers(0, n_versions, size=n)
    assign[:n_versions] = np.arange(n_versions)    # guarantee coverage
    rng.shuffle(assign)
    deploy = np.zeros((n_versions, n), bool)
    deploy[assign, np.arange(n)] = True
    return deploy


class InstanceDraw(NamedTuple):
    """A feasible random instance: the built graph plus the raw numpy state
    (``deploy``, ``link_capacity``, ``compute_capacity``) the scenario
    engine mutates between segments (DESIGN.md §10)."""

    graph: CECGraph
    deploy: np.ndarray
    link_capacity: np.ndarray
    compute_capacity: np.ndarray


def draw_instance(
    adj: np.ndarray,
    n_versions: int,
    mean_link_capacity: float,
    seed: int,
    mean_compute_capacity: float | None = None,
    max_tries: int = 50,
) -> InstanceDraw:
    """Randomized capacities + deployment (paper §IV experiment setup).

    Link capacities C_ij ~ U[0, 2·C̄] (floored at 0.05·C̄ for numerical
    sanity of the exp link cost), retried until the instance is feasible.
    """
    n = adj.shape[0]
    mean_cc = mean_compute_capacity or mean_link_capacity
    for t in range(max_tries):
        rng = np.random.default_rng(seed + 1000 * t)
        cap = rng.uniform(0.05, 2.0, size=(n, n)) * mean_link_capacity
        cap = np.maximum(cap, cap.T)  # symmetric draw per undirected link
        comp = rng.uniform(0.5, 1.5, size=n) * mean_cc
        deploy = random_deployment(n, n_versions, rng)
        try:
            graph = build_augmented(adj, deploy, cap, comp)
        except InfeasibleTopology:
            continue
        return InstanceDraw(graph, deploy, cap, comp)
    raise InfeasibleTopology(f"no feasible instance after {max_tries} tries")


def build_random_cec(
    adj: np.ndarray,
    n_versions: int,
    mean_link_capacity: float,
    seed: int,
    mean_compute_capacity: float | None = None,
    max_tries: int = 50,
) -> CECGraph:
    """``draw_instance`` returning only the built graph (the common case)."""
    return draw_instance(adj, n_versions, mean_link_capacity, seed,
                         mean_compute_capacity, max_tries).graph
