"""repro — JAX/Pallas reproduction of JOWR for collaborative edge inference.

The public surface is one solver core (DESIGN.md §13): describe the
instance as a ``Problem``, pick a ``SolverConfig`` (or a named preset),
and drive it with ``init``/``step``/``run``::

    from repro import Problem, SolverConfig, run
    result = run(Problem.create(graph, bank, lam_total=60.0),
                 SolverConfig(method="single", eta_inner=3.0), iters=200)

``solve_jowr`` / ``gs_oma`` / ``omad`` / ``solve_jowr_batch`` are
keyword-compatible shims over the same engine; ``run_scenario`` threads
its state across non-stationary segments and ``CECRouter`` serves it
live.  Everything is re-exported lazily so ``import repro`` stays cheap
— the serving/model stack loads only when touched.

``tests/test_public_api.py`` pins ``__all__`` and the entry-point
signatures; extend both together.
"""
from __future__ import annotations

import importlib

# names resolved from repro.core on first access
_CORE_EXPORTS = (
    "Problem", "SolverConfig", "SolverState", "StepInfo", "Result",
    "init", "step", "run", "fused_step", "run_batch", "run_batch_sharded",
    "paper_defaults", "serving_defaults",
    "solve_jowr", "gs_oma", "omad", "solve_jowr_batch", "solve_routing",
    "run_scenario", "Scenario", "scenario_metrics", "named_scenarios",
    "CECGraph", "CECGraphSparse", "CECGraphBatch", "UtilityBank",
    "build_random_cec", "build_augmented", "build_augmented_sparse",
    "make_bank", "get_cost", "resolve_cost",
    "UtilityFamily", "get_family", "fit_utilities", "OnlineFitter",
    "fixed_point_solve", "tune_etas",
)
# names resolved from repro.serve on first access (pulls the model stack)
_SERVE_EXPORTS = ("CECRouter", "InferenceEngine", "ServingSim")
_SUBMODULES = ("core", "configs", "topo", "kernels", "serve", "parallel",
               "models", "train", "optim", "data", "launch", "roofline",
               "obs")

__all__ = [*_CORE_EXPORTS, *_SERVE_EXPORTS, *_SUBMODULES]


def __getattr__(name: str):
    if name in _CORE_EXPORTS:
        return getattr(importlib.import_module("repro.core"), name)
    if name in _SERVE_EXPORTS:
        return getattr(importlib.import_module("repro.serve"), name)
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.{name}")
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
