"""AdamW in pure JAX with optionally low-precision moments.

``moment_dtype='bfloat16'`` halves optimizer HBM (the ZeRO-3-style sharding
in parallel/sharding.py shards the moments like the weights; together these
are what let the 398B Jamba train cell fit 16 GB/chip — DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    mu: Any            # first moment  (moment_dtype)
    nu: Any            # second moment (moment_dtype)


@dataclasses.dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"

    def init(self, params) -> OptState:
        dt = jnp.dtype(self.moment_dtype)
        zeros = lambda p: jnp.zeros_like(p, dtype=dt)
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=jax.tree_util.tree_map(zeros, params),
                        nu=jax.tree_util.tree_map(zeros, params))

    def update(self, grads, state: OptState, params, lr):
        # global-norm clip
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree_util.tree_leaves(grads)))
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
        step = state.step + 1
        c1 = 1.0 - self.b1 ** step.astype(jnp.float32)
        c2 = 1.0 - self.b2 ** step.astype(jnp.float32)
        dt = jnp.dtype(self.moment_dtype)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m32 = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g
            v32 = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * g * g
            upd = (m32 / c1) / (jnp.sqrt(v32 / c2) + self.eps)
            upd = upd + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * upd
            return new_p.astype(p.dtype), m32.astype(dt), v32.astype(dt)

        out = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, params)
        new_p = jax.tree_util.tree_map(lambda o: o[0], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(lambda o: o[1], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree_util.tree_map(lambda o: o[2], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        return new_p, OptState(step=step, mu=new_m, nu=new_v), gnorm
