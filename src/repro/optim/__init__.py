from .adamw import AdamW, OptState
from .schedule import cosine_schedule

__all__ = ["AdamW", "OptState", "cosine_schedule"]
