"""Mesh construction for the production deployment.

Single pod: 16×16 = 256 v5e chips, axes ('data', 'model').
Multi-pod:  2×16×16 = 512 chips,   axes ('pod', 'data', 'model') — the
'pod' axis carries only data parallelism (gradient reduction crosses the
inter-pod DCN/ICI boundary once per step).

Functions, not module constants: importing this module never touches jax
device state (the dry-run pins XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = np.array(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(devices, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests / elastic rescale use small shapes)."""
    return jax.make_mesh(shape, axes)


def fleet_mesh(n_devices: int | None = None, devices=None):
    """The solver core's 1-D instance-axis mesh: axes ``("fleet",)``.

    ``run_batch_sharded`` (core/batch.py, DESIGN.md §14) shards the
    stacked instance/seed axis of a fleet solve over this mesh.  Uses
    every visible device by default; a 1-device fleet mesh is valid (and
    bit-identical to the plain vmap path — the parity tier asserts it),
    so callers never need a device-count special case.
    """
    devices = list(devices if devices is not None else jax.devices())
    if n_devices is not None:
        if not 1 <= n_devices <= len(devices):
            raise ValueError(
                f"n_devices={n_devices} outside [1, {len(devices)}]")
        devices = devices[:n_devices]
    return jax.sharding.Mesh(np.array(devices), ("fleet",))


def elastic_mesh(n_model: int = 16, devices=None):
    """Build the largest (data, model) mesh from the devices still alive.

    Elastic scaling / failure recovery: after losing hosts we re-mesh with
    whatever is left (dropping the remainder so data axis stays uniform)
    and checkpoint-restore reshards onto it (train/checkpoint.py).
    """
    devices = list(devices if devices is not None else jax.devices())
    n_model = min(n_model, len(devices))
    n_data = len(devices) // n_model
    use = np.array(devices[: n_data * n_model]).reshape(n_data, n_model)
    return jax.sharding.Mesh(use, ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")
