"""End-to-end training driver (CPU-runnable; pjit on real hardware).

``python -m repro.launch.train --arch smollm-135m --smoke --steps 120``
trains the reduced config with the full production loop: deterministic
sharded data pipeline, AdamW + cosine schedule, checkpoint/restart,
simulated transient failure, straggler watermarks.  Drop ``--smoke`` for
the real ~135M-parameter config (slow on this 1-core container; the
production path is the same code under a mesh).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.data import SyntheticLM
from repro.models import model as M
from repro.optim import AdamW
from repro.train.runner import RunnerConfig, TrainRunner
from repro.train.steps import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="checkpoints/train_demo")
    ap.add_argument("--ckpt-every", type=int, default=40)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    print(f"[train] {cfg.name}: ~{cfg.approx_params()/1e6:.1f}M params")
    params = M.init(cfg, jax.random.PRNGKey(0))
    optim = AdamW()
    opt_state = optim.init(params)
    step_fn = jax.jit(make_train_step(cfg, optim, remat=False),
                      donate_argnums=(0, 1))
    data = SyntheticLM(seed=0, global_batch=args.batch, seq_len=args.seq,
                       vocab=cfg.vocab)

    rc = RunnerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir, fail_at=tuple(args.fail_at))
    runner = TrainRunner(rc, step_fn, params, opt_state, data)
    out = runner.run()
    losses = [m["loss"] for m in out["metrics"]]
    print(f"[train] steps={len(losses)} loss {losses[0]:.3f} → {losses[-1]:.3f} "
          f"mean_step={out['mean_step_s']*1e3:.0f}ms "
          f"stragglers={out['stragglers']}")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
