import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves the distribution config is coherent end-to-end:
``jax.jit(step, in_shardings, out_shardings).lower(...).compile()`` on the
production meshes (16×16 single pod, 2×16×16 multi-pod), records
``memory_analysis()`` (fits-in-HBM evidence), ``cost_analysis()`` (per-chip
FLOPs/bytes) and the collective schedule parsed from the partitioned HLO —
the inputs to EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --all                 # 40 cells × 2 meshes
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k --mesh single
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, applicable, get_config
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.parallel.annotate import activation_sharding
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import AdamW
from repro.parallel.sharding import (batch_specs, cache_specs,
                                     make_shardings, param_specs)
from repro.roofline.analysis import model_flops
from repro.roofline.hlo import parse_collectives
from repro.train.steps import make_decode_step, make_train_step

jax.config.update("jax_compilation_cache_dir", "/tmp/jax_cache")
jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
jax.config.update("jax_persistent_cache_min_compile_time_secs", 1.0)


def _sds(tree):
    return jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def _astype(tree, dtype):
    return jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(
            l.shape, dtype if l.dtype == jnp.float32 else l.dtype), tree)


def batch_struct(cfg: ModelConfig, kind: str, B: int, S: int):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    batch = {"tokens": tok}
    if cfg.enc_dec:
        batch["enc_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    elif cfg.frontend:
        batch = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                jnp.dtype(cfg.dtype)),
                 "labels": tok}
    return batch


# §Perf variants (EXPERIMENTS.md): flags flip the optimizations the
# hillclimb iterations introduce, so baseline and optimized lowerings of
# the SAME cell can be compared.
#   bf16params — bf16 weights (+bf16 Adam moments) for ALL train cells:
#                halves every FSDP all-gather / grad reduction payload
#   int8kv     — int8 KV cache for decode cells: halves cache HBM traffic
PERF_VARIANT = os.environ.get("REPRO_PERF_VARIANT", "baseline")


def input_specs(arch: str, shape_name: str):
    """(cfg, step_fn, example args as ShapeDtypeStructs, arg kinds)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    params = jax.eval_shape(lambda k: M.init(cfg, k), jax.random.PRNGKey(0))

    if shape.kind == "train":
        # ≥100B models: bf16 weights + bf16 Adam moments (ZeRO-3 sharded)
        big = cfg.approx_params() > 100e9 or "bf16params" in PERF_VARIANT
        if big:
            params = _astype(params, jnp.bfloat16)
        optim = AdamW(moment_dtype="bfloat16" if big else "float32")
        opt_state = jax.eval_shape(optim.init, params)
        batch = batch_struct(cfg, "train", B, S)
        step = make_train_step(cfg, optim)
        return cfg, step, (params, opt_state, batch), ("params", "opt", "batch")

    params = _astype(params, jnp.bfloat16)          # serving weights
    if shape.kind == "prefill":
        batch = batch_struct(cfg, "prefill", B, S)
        step = lambda p, b: M.prefill(cfg, p, b, max_len=S)
        return cfg, step, (params, batch), ("params", "batch")

    # decode: one new token against a KV/state cache of length S
    kv_dtype = jnp.int8 if "int8kv" in PERF_VARIANT else None
    cache = jax.eval_shape(lambda: M.init_cache(cfg, B, S, dtype=kv_dtype))
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    step = make_decode_step(cfg)
    return cfg, step, (params, tokens, cache), ("params", "tokens", "cache")


def shardings_for(cfg, mesh, args, kinds):
    serve_tp = "tpserve" in PERF_VARIANT and "opt" not in kinds
    fsdp_all = ("hybrid" if "hybridshard" in PERF_VARIANT
                else "fsdp256" in PERF_VARIANT)
    out = []
    for a, k in zip(args, kinds):
        if k == "params":
            out.append(make_shardings(
                mesh, param_specs(cfg, a, mesh, serve_tp_only=serve_tp,
                                  fsdp_all=fsdp_all)))
        elif k == "opt":
            pspec = make_shardings(
                mesh, param_specs(cfg, a.mu, mesh, fsdp_all=fsdp_all))
            out.append(type(a)(step=make_shardings(
                mesh, jax.tree_util.tree_map(lambda _: None, a.step)),
                mu=pspec, nu=pspec))
        elif k in ("batch", "tokens"):
            out.append(make_shardings(
                mesh, batch_specs(cfg, a, mesh, fsdp_all=fsdp_all)))
        elif k == "cache":
            out.append(make_shardings(mesh, cache_specs(cfg, a, mesh)))
    return tuple(out)


def run_cell(arch: str, shape_name: str, mesh_kind: str, outdir: pathlib.Path,
             verbose: bool = True) -> dict:
    cfg_full = get_config(arch)
    ok, why = applicable(cfg_full, shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    if not ok:
        rec.update(status="skipped", reason=why)
        outdir.mkdir(parents=True, exist_ok=True)
        (outdir / f"{arch}__{shape_name}__{mesh_kind}.json").write_text(
            json.dumps(rec, indent=1))
        print(f"[{arch} × {shape_name} × {mesh_kind}] skipped: {why}")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    cfg, step, args, kinds = input_specs(arch, shape_name)
    in_sh = shardings_for(cfg, mesh, args, kinds)

    t0 = time.time()
    if "fsdp256" in PERF_VARIANT:
        # pure FSDP: batch over every axis, no TP activation constraints
        act_ctx = activation_sharding(mesh, tuple(mesh.axis_names),
                                      model_axis=None)
    else:
        # hybridshard changes only WEIGHT sharding; activations as baseline
        act_ctx = activation_sharding(mesh, dp_axes(mesh))
    # donate the state buffers (params/opt for train, cache for decode):
    # outputs alias inputs, halving resident memory — the production setup
    donate = tuple(i for i, k in enumerate(kinds) if k in ("opt", "cache")
                   or (k == "params" and "opt" in kinds))
    with mesh, act_ctx:
        jitted = jax.jit(step, in_shardings=in_sh, donate_argnums=donate)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    t1 = time.time()

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    coll = parse_collectives(compiled.as_text(),
                             default_group=mesh.shape["model"])

    shape = SHAPES[shape_name]
    mf = model_flops(cfg, shape.kind, shape.seq_len, shape.global_batch)
    rec.update(
        status="ok",
        chips=int(chips),
        compile_s=round(t1 - t0, 1),
        flops=float(ca.get("flops", 0.0)),              # per chip
        hbm_bytes=float(ca.get("bytes accessed", 0.0)),  # per chip
        wire_bytes_per_chip=float(coll["total"]["wire_bytes"]),
        collectives={k: {kk: float(vv) for kk, vv in v.items()}
                     for k, v in coll.items()},
        model_flops=mf / chips,                          # per chip
        arg_bytes_per_device=int(mem.argument_size_in_bytes),
        temp_bytes_per_device=int(mem.temp_size_in_bytes),
        output_bytes_per_device=int(mem.output_size_in_bytes),
        alias_bytes_per_device=int(mem.alias_size_in_bytes),
    )
    if verbose:
        print(f"[{arch} × {shape_name} × {mesh_kind}] compiled in "
              f"{rec['compile_s']}s; args/dev="
              f"{rec['arg_bytes_per_device']/2**30:.2f}GiB "
              f"temp/dev={rec['temp_bytes_per_device']/2**30:.2f}GiB "
              f"flops/dev={rec['flops']:.3g} "
              f"wire/dev={rec['wire_bytes_per_chip']/2**20:.1f}MiB")
        print("  memory_analysis:", mem)
        print("  collectives:", {k: v["count"] for k, v in
                                 rec["collectives"].items() if k != "total"})
    outdir.mkdir(parents=True, exist_ok=True)
    fname = f"{arch}__{shape_name}__{mesh_kind}.json".replace("/", "_")
    (outdir / fname).write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    outdir = pathlib.Path(args.out)
    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else (args.arch,)
    shapes = tuple(SHAPES) if (args.all or not args.shape) else (args.shape,)
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))

    failures = []
    for a, s, m in cells:
        try:
            run_cell(a, s, m, outdir)
        except Exception as e:  # noqa: BLE001 — report all failing cells
            failures.append((a, s, m, repr(e)))
            print(f"[{a} × {s} × {m}] FAILED: {e}")
            traceback.print_exc()
    print(f"\n{len(cells) - len(failures)}/{len(cells)} cells compiled")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
