"""End-to-end CEC serving driver — the paper's system, live.

A fleet of edge devices (Connected-ER topology) hosts three versions of a
small LM (quality ladder).  Batched requests arrive; the CEC router runs
the OMAD single-loop online — observing only realized quality-weighted
goodput minus network cost — and steers (i) the admission split across
versions (workload allocation Λ) and (ii) per-replica dispatch (routing
φ).  Real decode steps execute on CPU through the continuous-batching
engines.

``python -m repro.launch.serve --intervals 12 --requests 24``
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core import build_random_cec
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serve import CECRouter, InferenceEngine, Request
from repro.topo import connected_er


def version_ladder() -> list[ModelConfig]:
    """Three sizes of the same family = the paper's DNN version set."""
    base = get_config("smollm-135m", smoke=True)
    return [
        dataclasses.replace(base, name="smol-v0", n_layers=2, d_model=32,
                            n_heads=2, n_kv_heads=2, d_ff=64),
        dataclasses.replace(base, name="smol-v1", n_layers=2, d_model=48,
                            n_heads=3, n_kv_heads=3, d_ff=96),
        dataclasses.replace(base, name="smol-v2", n_layers=4, d_model=64,
                            n_heads=4, n_kv_heads=4, d_ff=128),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--intervals", type=int, default=12)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--nodes", type=int, default=12)
    ap.add_argument("--fail-node-at", type=int, default=-1)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    configs = version_ladder()
    W = len(configs)
    quality = np.array([1.0, 1.6, 2.4])          # per-version QoE weight

    adj = connected_er(args.nodes, 0.35, seed=2)
    graph = build_random_cec(adj, W, mean_link_capacity=30.0, seed=0)
    router = CECRouter(graph, lam_total=float(args.requests))

    engines = [InferenceEngine(c, M.init(c, jax.random.PRNGKey(i)),
                               max_batch=8, max_len=48)
               for i, c in enumerate(configs)]

    rid = 0
    for it in range(args.intervals):
        if it == args.fail_node_at:
            adj2 = adj.copy()
            victim = args.nodes - 1
            adj2[victim, :] = adj2[:, victim] = False
            adj2 = adj2[:victim, :victim]
            graph = build_random_cec(adj2, W, 30.0, seed=0)
            router.on_topology_change(graph)
            print(f"[serve] node {victim} failed — re-meshed to "
                  f"{victim} devices, router re-targeted")

        split = router.admission_split()
        counts = rng.multinomial(args.requests, split)
        replicas = router.replica_weights()

        # serve this interval's batch for real
        for w, n in enumerate(counts):
            for _ in range(n):
                prompt = rng.integers(0, configs[w].vocab, size=8)
                rep = rng.choice(graph.n_phys, p=_safe(replicas[w]))
                engines[w].submit(Request(rid, prompt.astype(np.int32),
                                          max_new_tokens=8, version=w,
                                          replica=int(rep)))
                rid += 1
        served = [0] * W
        for w, e in enumerate(engines):
            before = e.tokens_served
            e.drain()
            served[w] = e.tokens_served - before

        # the unknown utility the router observes: quality-weighted goodput
        def utility_fn(lam, served=tuple(served)):
            lam = np.asarray(lam)
            return float((quality * np.minimum(lam, sum(served) * lam
                                               / max(lam.sum(), 1e-6))).sum())

        rec = router.control_step(utility_fn)
        print(f"[serve] interval {it:02d} split={np.round(split, 2)} "
              f"served={served} net_cost={rec['cost']:.2f} "
              f"lam={np.round(rec['lam'], 2)}")

    print(f"[serve] done: {rid} requests, "
          f"{sum(e.tokens_served for e in engines)} tokens generated")


def _safe(p: np.ndarray) -> np.ndarray:
    s = p.sum()
    return p / s if s > 0 else np.ones_like(p) / len(p)


if __name__ == "__main__":
    main()
