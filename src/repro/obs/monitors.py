"""Paper-derived invariant monitors (DESIGN.md §18.2).

Each monitor is a pure jnp function returning a
:class:`~repro.obs.telemetry.Verdict` — a scalar residual plus
``warn``/``trip`` booleans — so it jits, vmaps over a fleet axis
(:func:`fleet_verdicts`) and rides inside ``shard_map`` bodies
unchanged.  Two input families:

* **ring monitors** (:func:`monotone_descent`, :func:`dynamic_regret`,
  :func:`budget_feasibility`) read the :class:`Telemetry` ring — history
  invariants over the committed trajectory;
* **state monitors** (:func:`flow_conservation`, :func:`capacity_slack`,
  :func:`kkt_gap`) read the *live* ``(problem, state)`` iterates — the
  paper's fixed-point/KKT conditions at one instant.

Semantics (and the theorem each one operationalizes):

``monotone_descent``
    Theorem 4 guarantees the routing oracle's OMD descends network cost
    at fixed Λ; across control intervals (Λ moving by one mirror-ascent
    step) the observable proxy is that committed net utility does not
    *fall* materially in an event-free environment.  Value: the largest
    one-interval utility drop in the ring, in units of the ring's mean
    |U| (scale-free).  The golden ``fig7_gs_oma_traj.npz`` trajectory is
    strictly increasing — this monitor never trips on it (pinned in
    ``tests/test_obs.py``).
``dynamic_regret``
    Σ_t (U*(t) − U_t) against a comparator — the ``segment_optima``
    genie per-segment optimum (§IV's absolute comparator) or any scalar
    baseline.  Agrees with ``scenario_metrics``'s accounting ≤1e-6
    (pinned).  Unbounded in the horizon, so warn/trip default off —
    callers with a regret budget pass thresholds.
``budget_feasibility``
    The box-simplex constraint {δ ≤ λ_w ≤ Λ−δ, Σλ_w = Λ}: max of the
    ring's per-interval projection residuals.  The exact projection
    (Alg. 1 line 9) makes this f32-rounding-sized; growth means someone
    bypassed the projection.
``flow_conservation``
    The session rates must satisfy the paper's flow fixed point
    t = inject + t·φ (eq. (2)–(3) recursion).  Value: max |T(t) − t| of
    one extra Jacobi application, relative to the injected demand — the
    residual the ``depth_max``-step relaxation left behind.
``capacity_slack``
    Max relative link overload (F_ij − C_ij)/C_ij over real edges (eq.
    (4) flows).  Negative = slack everywhere.  The soft exponential cost
    tolerates transient overload; sustained trips mean admission is
    outrunning the network.
``kkt_gap``
    Theorem 3 stationarity: ``routing.kkt_residual`` — at φ* the active
    marginal costs per row are equal and minimal.

Thresholds are keyword arguments with conservative defaults calibrated
on the event-free ``named_scenarios`` suite (no false trips — a property
``tests/test_obs.py`` enforces); ``warn`` is the soft heads-up, ``trip``
the invariant-violation alarm.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import routing as _routing
from repro.core import sparse as _sparse
from repro.core.flow import link_flows, propagate
from repro.core.graph import CECGraphSparse
from repro.core.problem import Problem, resolve_cost

from .telemetry import Telemetry, Verdict, order

Array = jnp.ndarray


def _verdict(value, warn_at, trip_at) -> Verdict:
    value = jnp.asarray(value)
    return Verdict(value=value, warn=value > warn_at, trip=value > trip_at)


# ---------------------------------------------------------------------------
# ring monitors
# ---------------------------------------------------------------------------

def monotone_descent(tel: Telemetry, *, warn: float = 0.02,
                     trip: float = 0.25) -> Verdict:
    """Largest one-interval drop of committed utility, scale-free.

    Value = max_t (U_t − U_{t+1}) / mean|U| over consecutive valid,
    annotated (non-NaN) ring rows; ≤0 on a monotone trajectory.  Rows
    never annotated with a utility are skipped, not treated as drops.
    """
    idx, valid = order(tel)
    u = tel.utility[idx]
    ok = valid & jnp.isfinite(u)
    pair = ok[:-1] & ok[1:]
    drop = jnp.where(pair, u[:-1] - u[1:], -jnp.inf)
    scale = (jnp.abs(jnp.where(ok, u, 0.0)).sum()
             / jnp.maximum(ok.sum(), 1)) + 1e-9
    worst = jnp.where(pair.any(), drop.max() / scale, 0.0)
    return _verdict(worst, warn, trip)


def dynamic_regret(tel: Telemetry, comparator, *, warn: float = jnp.inf,
                   trip: float = jnp.inf) -> Verdict:
    """Σ over valid annotated rows of (comparator − U_t).

    ``comparator`` is a scalar U* or a ``[capacity]`` per-row (chrono-
    logically ordered) comparator — :func:`repro.core.scenario.
    segment_optima` values broadcast per segment.  Defaults never warn:
    regret grows with the horizon by construction; callers with a budget
    (e.g. the sublinearity trend) supply thresholds.
    """
    idx, valid = order(tel)
    u = tel.utility[idx]
    comp = jnp.asarray(comparator)
    comp = jnp.broadcast_to(comp, u.shape) if comp.ndim == 0 else comp
    ok = valid & jnp.isfinite(u)
    regret = jnp.where(ok, comp - u, 0.0).sum()
    return _verdict(regret, warn, trip)


def budget_feasibility(tel: Telemetry, *, warn: float = 1e-3,
                       trip: float = 1e-1) -> Verdict:
    """Max recorded box-simplex projection residual (absolute, in demand
    units) — |ΣΛ − λ_total| + box violations, per ``telemetry.record``."""
    idx, valid = order(tel)
    r = tel.proj_residual[idx]
    ok = valid & jnp.isfinite(r)
    worst = jnp.where(ok.any(), jnp.where(ok, r, -jnp.inf).max(), 0.0)
    return _verdict(worst, warn, trip)


# ---------------------------------------------------------------------------
# state monitors
# ---------------------------------------------------------------------------

def _one_jacobi(graph, phi, lam, t):
    """One application of the flow recursion T(t) — both representations."""
    if isinstance(graph, CECGraphSparse):
        base = _sparse.source_inflow(graph, phi, lam)
        t_new = base + _sparse._relay_inflow(graph, phi.rows, t)
        wi = jnp.arange(graph.n_sessions)
        return t_new.at[wi, graph.sinks].set(
            _sparse._sink_inflow(graph, phi.rows, t))
    return graph.injection(lam) + jnp.einsum("wi,wij->wj", t, phi)


def flow_conservation(problem: Problem, state, *, warn: float = 1e-3,
                      trip: float = 1e-1) -> Verdict:
    """Fixed-point residual max|T(t) − t| / λ_total of the session-rate
    recursion at the solver's routing iterate (eq. (2)–(3))."""
    graph = problem.graph
    t = propagate(graph, state.phi, state.lam)
    resid = jnp.abs(_one_jacobi(graph, state.phi, state.lam, t) - t).max()
    return _verdict(resid / (problem.lam_total + 1e-9), warn, trip)


def capacity_slack(problem: Problem, state, *, warn: float = 0.0,
                   trip: float = 2.0) -> Verdict:
    """Max relative link overload (F − C)/C over real edges; negative
    everywhere means every link has slack."""
    graph = problem.graph
    t = propagate(graph, state.phi, state.lam)
    F = link_flows(graph, state.phi, t)
    if isinstance(graph, CECGraphSparse):
        over_rows = jnp.where(
            graph.edge_mask > 0, (F.rows - graph.capacity) / graph.capacity,
            -jnp.inf)
        over_src = jnp.where(
            graph.src_edge_mask > 0,
            (F.src - graph.src_capacity) / graph.src_capacity, -jnp.inf)
        worst = jnp.maximum(over_rows.max(), over_src.max())
    else:
        worst = jnp.where(graph.edge_mask > 0,
                          (F - graph.capacity) / graph.capacity,
                          -jnp.inf).max()
    return _verdict(worst, warn, trip)


def kkt_gap(problem: Problem, state, *, warn: float = 1.0,
            trip: float = 100.0) -> Verdict:
    """Theorem 3 stationarity residual of the routing iterate
    (``routing.kkt_residual`` — max over rows of support-max minus
    allowed-min marginal cost).  Mid-flight OMAD iterates sit at O(0.1);
    the trip level flags divergence, not mere non-convergence."""
    r = _routing.kkt_residual(problem.graph, problem.cost, state.phi,
                              state.lam)
    return _verdict(r, warn, trip)


# ---------------------------------------------------------------------------
# bundles
# ---------------------------------------------------------------------------

def check_state(problem: Problem, state, tel: Telemetry | None = None, *,
                comparator=None) -> dict[str, Verdict]:
    """Every applicable monitor at once, default thresholds.

    State monitors always run; ring monitors when ``tel`` is given;
    regret when a ``comparator`` is.  Pure — jit/vmap/shard_map it.
    """
    out = {
        "flow_conservation": flow_conservation(problem, state),
        "capacity_slack": capacity_slack(problem, state),
        "kkt_gap": kkt_gap(problem, state),
    }
    if tel is not None:
        out["monotone_descent"] = monotone_descent(tel)
        out["budget_feasibility"] = budget_feasibility(tel)
        if comparator is not None:
            out["dynamic_regret"] = dynamic_regret(tel, comparator)
    return out


def fleet_verdicts(graph, lam_total, state, tel: Telemetry | None = None, *,
                   cost="exp", comparator=None) -> dict[str, Verdict]:
    """:func:`check_state` vmapped over a fleet/tenant axis.

    ``graph`` is a stacked view (``CECGraphBatch.stacked_graph()`` or
    per-leaf-stacked tenants as the ``RouterFleet`` holds them),
    ``lam_total`` is ``[K]``, ``state``/``tel`` are stacked pytrees;
    returns the same dict with ``[K]``-leaf Verdicts.  Lane k's verdicts
    are bit-identical to running the scalar monitors on tenant k alone —
    the vmap axis never mixes lanes (pinned in ``tests/test_obs.py``).
    """
    costfn = resolve_cost(cost)

    def one(g, lt, s, t_r, comp):
        problem = Problem(graph=g, bank=None, lam_total=lt, cost=costfn)
        return check_state(problem, s, t_r, comparator=comp)

    in_axes = (0, 0, 0, None if tel is None else 0,
               None if comparator is None else 0)
    return jax.vmap(one, in_axes=in_axes)(graph, lam_total, state, tel,
                                          comparator)
