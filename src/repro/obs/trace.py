"""Control-plane tracing — Chrome trace-event timelines (DESIGN.md §18.3).

A :class:`Tracer` collects host-side *trace events* — control intervals,
scenario segments and their churn events, kernel-dispatch decisions —
and serializes them as Chrome trace-event JSON (the ``chrome://tracing``
/ Perfetto format: a ``{"traceEvents": [...]}`` object whose entries
carry ``name``/``cat``/``ph``/``ts``/``pid``/``tid``).  Two phases are
emitted: complete spans (``ph: "X"`` with ``ts``+``dur``) and instants
(``ph: "i"``).

The tracer is strictly host-side and strictly optional: the module-level
:func:`span`/:func:`instant` helpers no-op when no tracer is installed,
so instrumented call sites (``run_scenario`` segment boundaries,
``CECRouter.control_step`` intervals, ``solver.step``'s dispatch choice)
cost one global read when tracing is off.  Dispatch instants fire at
*trace* time — once per compilation, which is exactly when a dispatch
decision is made; steady-state jitted intervals never touch the tracer.

Timestamps are ``time.perf_counter`` microseconds relative to tracer
construction.  ``tid`` is assigned per category on first use so each
category renders as its own row in the viewer.

Like :mod:`repro.obs.telemetry`, this module must stay importable from
``repro.core`` — stdlib only, no core imports.
"""
from __future__ import annotations

import contextlib
import json
import pathlib
import time
from typing import Any, Iterator

TRACE_EVENT_KEYS = ("name", "cat", "ph", "ts", "pid", "tid")


class Tracer:
    """Accumulates trace events; write with :meth:`write` / :meth:`to_chrome`.

    Not thread-safe by design — the control plane is a single host loop
    (one interval at a time); a fleet wanting per-worker timelines
    installs one tracer per process (``pid`` disambiguates on merge).
    """

    def __init__(self, *, pid: int = 0) -> None:
        self.events: list[dict[str, Any]] = []
        self.pid = int(pid)
        self._t0 = time.perf_counter()
        self._tids: dict[str, int] = {}

    # -- low-level emitters ------------------------------------------------
    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def _tid(self, cat: str) -> int:
        return self._tids.setdefault(cat, len(self._tids))

    def instant(self, name: str, *, cat: str = "event",
                args: dict[str, Any] | None = None) -> None:
        self.events.append({
            "name": name, "cat": cat, "ph": "i", "s": "t",
            "ts": self._now_us(), "pid": self.pid, "tid": self._tid(cat),
            "args": dict(args or {}),
        })

    @contextlib.contextmanager
    def span(self, name: str, *, cat: str = "interval",
             args: dict[str, Any] | None = None) -> Iterator[None]:
        ts = self._now_us()
        try:
            yield
        finally:
            self.events.append({
                "name": name, "cat": cat, "ph": "X",
                "ts": ts, "dur": self._now_us() - ts,
                "pid": self.pid, "tid": self._tid(cat),
                "args": dict(args or {}),
            })

    # -- serialization -----------------------------------------------------
    def to_chrome(self) -> dict[str, Any]:
        """The trace-event JSON object (``traceEvents`` sorted by ts)."""
        return {
            "traceEvents": sorted(self.events, key=lambda e: e["ts"]),
            "displayTimeUnit": "ms",
            "otherData": {"format": "repro.obs.trace", "version": 1},
        }

    def write(self, path) -> pathlib.Path:
        """Serialize to ``path``; open the file in ``chrome://tracing`` or
        https://ui.perfetto.dev to see the timeline."""
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.to_chrome(), indent=1))
        return p


# ---------------------------------------------------------------------------
# the installed tracer — module-global so call sites need no plumbing
# ---------------------------------------------------------------------------

_TRACER: Tracer | None = None


def install_tracer(tracer: Tracer | None = None) -> Tracer:
    """Install (and return) the process-wide tracer.  Instrumented call
    sites start emitting immediately; install before building routers if
    you want their compile-time dispatch instants."""
    global _TRACER
    _TRACER = tracer if tracer is not None else Tracer()
    return _TRACER


def uninstall_tracer() -> Tracer | None:
    """Remove and return the installed tracer (idempotent)."""
    global _TRACER
    t, _TRACER = _TRACER, None
    return t


def current_tracer() -> Tracer | None:
    return _TRACER


def instant(name: str, *, cat: str = "event",
            args: dict[str, Any] | None = None) -> None:
    """Emit an instant on the installed tracer; no-op when none is."""
    if _TRACER is not None:
        _TRACER.instant(name, cat=cat, args=args)


@contextlib.contextmanager
def span(name: str, *, cat: str = "interval",
         args: dict[str, Any] | None = None) -> Iterator[None]:
    """Span on the installed tracer; transparent no-op when none is."""
    if _TRACER is None:
        yield
    else:
        with _TRACER.span(name, cat=cat, args=args):
            yield
