"""Host-side telemetry export (DESIGN.md §18.3).

The one place device-resident observability crosses to the host:

* :func:`export_ring` — sync a :class:`Telemetry` ring (or a fleet-
  stacked one) to numpy, valid rows only, chronological order;
* :func:`metrics_rows` / :func:`write_metrics_jsonl` — flatten a ring
  (plus optional monitor verdicts) into JSON-lines records aligned with
  the perf-trajectory row schema (``benchmarks/run.py``: one JSON object
  per line, ``name``/scalar fields, nothing nested that a trajectory
  reader would have to special-case);
* :func:`write_chrome_trace` — serialize an installed/passed
  :class:`~repro.obs.trace.Tracer` next to the metrics.

Everything here blocks on device values — by design.  The control loop
never calls this module; benchmarks, CI smoke and operators do, at
whatever cadence they can afford.
"""
from __future__ import annotations

import json
import math
import pathlib
from typing import Any

import numpy as np

from . import trace as _trace
from .telemetry import Telemetry, Verdict, order

_COLUMNS = ("utility", "lam", "cost", "grad_norm", "proj_residual",
            "oracle_calls", "wall_clock_us")


def export_ring(tel: Telemetry) -> dict[str, np.ndarray]:
    """Sync the ring to host numpy: ``{column: [count, ...]}`` oldest →
    newest, invalid (unwritten) slots dropped.

    Accepts a fleet-stacked ring (leaves ``[K, C, ...]``, per-lane
    ``head``/``count``) and returns ``[K, count_min, ...]`` arrays
    truncated to the shortest lane — lanes step in lockstep under
    ``fused_step_batch``, so in practice counts agree.
    """
    head = np.asarray(tel.head)
    if head.ndim == 0:
        idx, valid = order(tel)
        idx = np.asarray(idx)[np.asarray(valid)]
        return {c: np.asarray(getattr(tel, c))[idx] for c in _COLUMNS}
    # fleet-stacked: python-loop the K lanes (host-side, export cadence)
    lanes = []
    for k in range(head.shape[0]):
        lane = Telemetry(
            **{c: getattr(tel, c)[k] for c in _COLUMNS},
            head=tel.head[k], count=tel.count[k], capacity=tel.capacity)
        lanes.append(export_ring(lane))
    n = min(lane["utility"].shape[0] for lane in lanes)
    return {c: np.stack([lane[c][:n] for lane in lanes]) for c in _COLUMNS}


def _scalarize(x) -> Any:
    v = np.asarray(x)
    if v.ndim == 0:
        f = v.item()
        if isinstance(f, float) and not math.isfinite(f):
            return None                       # JSON has no NaN/inf
        return f
    return [_scalarize(e) for e in v]


def metrics_rows(tel: Telemetry, *, verdicts: dict[str, Verdict] | None = None,
                 name: str = "obs") -> list[dict[str, Any]]:
    """JSON-lines records: one per recorded interval, trajectory-schema
    style (flat ``name``/``t``/scalar columns, λ as a list), plus one
    trailing ``{name}.verdicts`` record when monitor output is given."""
    cols = export_ring(tel)
    if cols["utility"].ndim > 1:
        raise ValueError(
            "metrics_rows flattens one ring; export fleet-stacked rings "
            "lane-by-lane (export_ring accepts them) and tag each lane")
    n = cols["utility"].shape[0]
    t0 = int(np.asarray(tel.head)) - n
    rows = []
    for i in range(n):
        rows.append({
            "name": name, "t": t0 + i,
            **{c: _scalarize(cols[c][i]) for c in _COLUMNS},
        })
    if verdicts is not None:
        rows.append({
            "name": f"{name}.verdicts",
            **{k: {"value": _scalarize(v.value),
                   "warn": bool(np.asarray(v.warn).any()),
                   "trip": bool(np.asarray(v.trip).any())}
               for k, v in sorted(verdicts.items())},
        })
    return rows


def write_metrics_jsonl(path, tel: Telemetry, *,
                        verdicts: dict[str, Verdict] | None = None,
                        name: str = "obs") -> pathlib.Path:
    """Write :func:`metrics_rows` as JSON lines; returns the path."""
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    with p.open("w") as fh:
        for row in metrics_rows(tel, verdicts=verdicts, name=name):
            fh.write(json.dumps(row) + "\n")
    return p


def write_chrome_trace(path, tracer: _trace.Tracer | None = None
                       ) -> pathlib.Path:
    """Serialize ``tracer`` (default: the installed one) as Chrome
    trace-event JSON.  Raises if there is nothing to write — a silent
    empty trace would read as 'nothing happened'."""
    tracer = tracer if tracer is not None else _trace.current_tracer()
    if tracer is None:
        raise ValueError(
            "no tracer passed and none installed — obs.install_tracer() "
            "before the run you want a timeline of")
    return tracer.write(path)
