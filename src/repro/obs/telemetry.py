"""Device-resident telemetry ring (DESIGN.md §18.1).

A :class:`Telemetry` is a frozen-pytree ring buffer of per-interval
control-plane signals — utility, per-class Λ, network cost, gradient
norm, box-simplex projection residual, oracle-call count, and solver
wall-clock — updated *inside* the jitted control step by the pure
:func:`record`.  The contract that keeps steady-state recording free:

* **pytree, fixed shapes** — every leaf's shape depends only on the
  static ``capacity`` and the session count W, so a ring threads through
  ``jax.jit`` / ``lax.scan`` / ``vmap`` (the RouterFleet's ``[K]``
  stacking) / ``shard_map`` (the fleet mesh) like any other carry.
* **donation-compatible** — :func:`record` and :func:`annotate` return a
  ring of identical structure, so the fused step can donate the incoming
  ring and XLA writes the new row into the old buffers in place.
* **host sync is explicit** — nothing here calls back to Python; reading
  the ring is :func:`repro.obs.export.export_ring`'s job, and until then
  all values stay device-resident.

Columns a jitted step cannot know (the *measured* task utility U_t, the
host wall-clock) are written as NaN by :func:`record` and patched by the
caller via :func:`annotate` — the router annotates both, ``solver.run``
annotates U_t device-side inside its scan.

This module imports only jax/numpy (never ``repro.core``) so the solver
core can import it without a cycle; the paper-invariant checks that *do*
need the core live in :mod:`repro.obs.monitors`.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


class Verdict(NamedTuple):
    """One monitor's output: a scalar residual plus threshold booleans.

    ``value`` is the monitored quantity (units documented per monitor in
    :mod:`repro.obs.monitors`), ``warn``/``trip`` its comparisons against
    the monitor's thresholds.  A pytree of arrays, so fleet-vmapped
    monitors return Verdicts with ``[K]`` leaves.
    """

    value: Array                  # scalar (or [K] under vmap)
    warn: Array                   # bool — soft threshold crossed
    trip: Array                   # bool — hard invariant violated


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Telemetry:
    """The ring.  ``capacity`` is static metadata (part of the treedef,
    hashable, jit-static); every other field is a fixed-shape leaf.

    Row columns (slot axis first):

    ``utility [C]``
        Net utility U(Λ^t, φ^t) at the committed iterates.  NaN until
        annotated — the jitted step sees network cost but not the
        measured task utility.
    ``lam [C, W]``
        The committed per-class allocation Λ^{t+1}.
    ``cost [C]``
        Network cost D(Λ^{t+1}, φ^{t+1}) at the committed observation.
    ``grad_norm [C]``
        ‖ĝ^t‖₂ of the outer gradient estimate.
    ``proj_residual [C]``
        Feasibility residual of the committed Λ against the box-simplex:
        |ΣΛ − λ_total| + max(0, δ − min Λ) + max(0, max Λ − (λ_total−δ)).
        Zero (to f32 rounding) whenever the exact projection ran last.
    ``oracle_calls [C]``
        Oracle invocations this interval (2W+1 sampled/megakernel, 2
        learned).
    ``wall_clock_us [C]``
        Host-measured solver wall-clock in µs.  NaN until annotated.

    ``head`` is the *next* write slot (monotone int32, slot = head mod C);
    ``count`` saturates at C — together they define the valid window and
    its chronological order (:func:`order`).
    """

    utility: Array
    lam: Array
    cost: Array
    grad_norm: Array
    proj_residual: Array
    oracle_calls: Array
    wall_clock_us: Array
    head: Array                   # scalar int32 — next write slot
    count: Array                  # scalar int32 — valid rows, ≤ capacity
    capacity: int = dataclasses.field(metadata=dict(static=True))


def init_ring(capacity: int, n_sessions: int) -> Telemetry:
    """A fresh ring: NaN value columns, zero counters.

    ``capacity`` rows of ``n_sessions``-wide Λ; both are static — a ring
    never resizes (resize = new ring), which is what lets the fused step
    cache one executable per (config, dispatch) key regardless of how
    long the control loop runs.
    """
    capacity = int(capacity)
    if capacity < 1:
        raise ValueError(f"ring capacity must be >= 1, got {capacity}")
    # one buffer per column: donating a fresh ring must never hand XLA
    # the same buffer twice (`f(donate(a), donate(a))` is rejected)
    nan = lambda: jnp.full((capacity,), jnp.nan, jnp.float32)
    return Telemetry(
        utility=nan(),
        lam=jnp.full((capacity, int(n_sessions)), jnp.nan, jnp.float32),
        cost=nan(),
        grad_norm=nan(),
        proj_residual=nan(),
        oracle_calls=jnp.zeros((capacity,), jnp.int32),
        wall_clock_us=nan(),
        head=jnp.int32(0),
        count=jnp.int32(0),
        capacity=capacity,
    )


def _put(col: Array, slot: Array, value) -> Array:
    return jax.lax.dynamic_update_index_in_dim(
        col, jnp.asarray(value, col.dtype), slot, 0)


def record(tel: Telemetry, state, info, *, lam_total, delta,
           oracle_calls) -> Telemetry:
    """Append one interval's row — pure, traceable, donation-friendly.

    ``state``/``info`` are the solver's post-step ``(SolverState,
    StepInfo)`` (duck-typed on ``.lam``/``.grad``/``.cost`` so this
    module stays core-free); ``lam_total``/``delta`` parameterize the
    feasibility residual; ``oracle_calls`` is the static per-mode count.
    The utility and wall-clock columns are seeded NaN for the caller's
    :func:`annotate`.
    """
    slot = jnp.mod(tel.head, tel.capacity)
    lam = jnp.asarray(state.lam, jnp.float32)
    lo, hi = delta, lam_total - delta
    residual = (jnp.abs(lam.sum() - lam_total)
                + jnp.maximum(lo - lam.min(), 0.0)
                + jnp.maximum(lam.max() - hi, 0.0))
    return dataclasses.replace(
        tel,
        utility=_put(tel.utility, slot, jnp.nan),
        lam=jax.lax.dynamic_update_index_in_dim(
            tel.lam, lam[None, :], slot, 0),
        cost=_put(tel.cost, slot, info.cost),
        grad_norm=_put(tel.grad_norm, slot,
                       jnp.linalg.norm(jnp.asarray(info.grad, jnp.float32))),
        proj_residual=_put(tel.proj_residual, slot, residual),
        oracle_calls=_put(tel.oracle_calls, slot, oracle_calls),
        wall_clock_us=_put(tel.wall_clock_us, slot, jnp.nan),
        head=tel.head + 1,
        count=jnp.minimum(tel.count + 1, tel.capacity),
    )


def annotate(tel: Telemetry, *, utility=None,
             wall_clock_us=None) -> Telemetry:
    """Patch the *most recent* row with values the jitted step could not
    know: the measured task-side utility and/or host wall-clock.  Pure —
    the router wraps it in a cached donated jit (one executable per ring
    shape), ``solver.run`` traces it inline inside its scan.
    """
    slot = jnp.mod(tel.head - 1, tel.capacity)
    kw = {}
    if utility is not None:
        kw["utility"] = _put(tel.utility, slot, utility)
    if wall_clock_us is not None:
        kw["wall_clock_us"] = _put(tel.wall_clock_us, slot, wall_clock_us)
    return dataclasses.replace(tel, **kw) if kw else tel


def order(tel: Telemetry) -> tuple[Array, Array]:
    """(``idx [C]``, ``valid [C]``): slot indices in chronological order
    plus the validity mask — the one place ring arithmetic lives, so
    monitors and the exporter cannot disagree on what "oldest" means.
    ``col[idx]`` reads oldest→newest; the first ``count`` positions are
    the valid window, the tail is unwritten slots masked out by
    ``valid``.
    """
    c = tel.capacity
    start = jnp.mod(tel.head - tel.count, c)
    idx = jnp.mod(start + jnp.arange(c, dtype=jnp.int32), c)
    valid = jnp.arange(c, dtype=jnp.int32) < tel.count
    return idx, valid


_annotate_jit = None
_annotate_fleet_jit = None


def annotate_donated(tel: Telemetry, *, utility, wall_clock_us) -> Telemetry:
    """Jitted :func:`annotate` with the ring donated — the router's
    steady-state path (zero allocation per annotate).  A fleet-stacked
    ring (``head`` of shape [K]) annotates per lane with [K] values.
    Cached executables; further specialization is by ring shape, which
    jit handles.
    """
    global _annotate_jit, _annotate_fleet_jit
    if tel.head.ndim == 0:
        if _annotate_jit is None:
            _annotate_jit = jax.jit(
                lambda t, u, w: annotate(t, utility=u, wall_clock_us=w),
                donate_argnums=(0,))
        return _annotate_jit(tel, utility, wall_clock_us)
    if _annotate_fleet_jit is None:
        _annotate_fleet_jit = jax.jit(
            jax.vmap(lambda t, u, w: annotate(t, utility=u,
                                              wall_clock_us=w)),
            donate_argnums=(0,))
    return _annotate_fleet_jit(tel, utility, wall_clock_us)
