"""Device-resident observability: telemetry rings, paper-invariant
monitors, control-plane tracing (DESIGN.md §18).

The paper's value proposition is *online* optimization — sublinear
dynamic regret, Theorem-4 monotone descent, KKT-optimal fixed points —
and this package turns those analysis-section claims into always-on
signals instead of after-the-fact test assertions:

* :mod:`repro.obs.telemetry` — a :class:`~repro.obs.telemetry.Telemetry`
  frozen-pytree ring buffer updated *inside* the jitted control step by
  a pure ``record``; composes with donation, ``vmap`` (the RouterFleet's
  ``[K]`` tenant stacking) and ``shard_map`` (the fleet mesh), host sync
  deferred to an explicit export.
* :mod:`repro.obs.monitors` — paper-derived invariant monitors as pure
  functions over the ring and the live iterates, each with warn/trip
  thresholds and a fleet-vmapped batch form.
* :mod:`repro.obs.trace` — host-side Chrome-trace (trace-event JSON)
  timelines of control intervals, scenario segments and kernel-dispatch
  decisions.
* :mod:`repro.obs.export` — host-side ring export + JSON-lines metrics
  aligned with the perf-trajectory schema rows.

Import discipline: ``telemetry``/``trace`` depend only on jax/numpy so
``core.solver`` can import them without a cycle; ``monitors``/``export``
may import ``repro.core`` and are therefore loaded lazily here.
"""
from __future__ import annotations

import importlib

from .telemetry import Telemetry, Verdict, annotate, init_ring, record
from .trace import (Tracer, current_tracer, install_tracer, instant, span,
                    uninstall_tracer)

_LAZY = {
    # monitors / export pull repro.core — resolve on first access so that
    # `import repro.obs` from inside core.solver never cycles
    "monitors": "repro.obs.monitors",
    "export": "repro.obs.export",
}
_LAZY_NAMES = {
    "monotone_descent": "monitors", "dynamic_regret": "monitors",
    "budget_feasibility": "monitors", "flow_conservation": "monitors",
    "capacity_slack": "monitors", "kkt_gap": "monitors",
    "check_state": "monitors", "fleet_verdicts": "monitors",
    "export_ring": "export", "metrics_rows": "export",
    "write_metrics_jsonl": "export", "write_chrome_trace": "export",
}

__all__ = [
    "Telemetry", "Verdict", "init_ring", "record", "annotate",
    "Tracer", "install_tracer", "uninstall_tracer", "current_tracer",
    "span", "instant",
    *sorted(_LAZY), *sorted(_LAZY_NAMES),
]


def __getattr__(name: str):
    if name in _LAZY:
        return importlib.import_module(_LAZY[name])
    if name in _LAZY_NAMES:
        mod = importlib.import_module(_LAZY[_LAZY_NAMES[name]])
        return getattr(mod, name)
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
