"""Model configuration for the composable architecture family.

One ``ModelConfig`` describes any of the 10 assigned architectures: a stack
of repeated *periods*, each period a tuple of (mixer, mlp) blocks:

  mixer ∈ {attn, mamba, mlstm, slstm}
  mlp   ∈ {dense, moe, none}

The layer stack is ``n_periods`` repetitions of the period, applied via
``lax.scan`` over stacked parameters (compile time O(1) in depth).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Mixer = Literal["attn", "mamba", "mlstm", "slstm"]
Mlp = Literal["dense", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int              # total decoder blocks (must = n_periods·|period|)
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    period: tuple[tuple[str, str], ...] = (("attn", "dense"),)
    head_dim: int = 0          # 0 → d_model // n_heads
    moe: MoEConfig | None = None
    # encoder–decoder (whisper): encoder is a plain attn/dense stack
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500        # precomputed frame embeddings length
    frontend: str | None = None          # None | 'audio' | 'vision'
    rope: str = "rope"                    # 'rope' | 'mrope' | 'none'
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    norm: str = "rms"                     # 'rms' | 'ln'
    mlp_act: str = "swiglu"               # 'swiglu' | 'gelu'
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    d_state: int = 16          # mamba SSM state size
    d_conv: int = 4            # mamba depthwise conv width
    mamba_expand: int = 2
    dtype: str = "bfloat16"
    # bookkeeping from the assignment table (verified-tier source)
    source: str = ""

    def __post_init__(self):
        assert self.n_layers % len(self.period) == 0, \
            f"{self.name}: n_layers {self.n_layers} % period {len(self.period)}"
        assert self.n_heads % self.n_kv_heads == 0

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.period)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    def has_mixer(self, kind: str) -> bool:
        return any(m == kind for m, _ in self.period)

    @property
    def sub_quadratic(self) -> bool:
        """True iff decode state does not grow quadratically expensive —
        i.e. the arch can run the long_500k shape (DESIGN.md §6)."""
        return self.has_mixer("mamba") or self.has_mixer("mlstm") \
            or self.has_mixer("slstm")

    def approx_params(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.hd
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        for mixer, mlp in self.period:
            n = self.n_periods
            if mixer == "attn":
                qo = d * self.n_heads * hd * 2
                kv = d * self.n_kv_heads * hd * 2
                total += n * (qo + kv)
            elif mixer == "mamba":
                di, ds = self.d_inner, self.d_state
                total += n * (d * 2 * di + di * self.d_conv
                              + di * (2 * ds + 2) + di * ds + di * d)
            elif mixer in ("mlstm", "slstm"):
                total += n * (d * self.n_heads * hd * 4
                              + self.n_heads * hd * d)
            if mlp == "dense":
                mult = 3 if self.mlp_act == "swiglu" else 2
                total += n * mult * d * self.d_ff
            elif mlp == "moe":
                e = self.moe
                total += n * 3 * d * e.d_ff_expert * (e.n_experts + e.n_shared)
                total += n * d * e.n_experts
        if self.enc_dec:
            # encoder layers + decoder cross-attention
            enc = self.n_enc_layers * (4 * d * self.n_heads * hd
                                       + 2 * d * self.d_ff)
            xattn = self.n_layers * 4 * d * self.n_heads * hd
            total += enc + xattn
        return total

    def active_params(self) -> int:
        """Activated parameters per token (MoE-aware) for roofline."""
        if self.moe is None:
            return self.approx_params()
        d = self.d_model
        e = self.moe
        n_moe = sum(1 for _, m in self.period if m == "moe") * self.n_periods
        inactive = n_moe * 3 * d * e.d_ff_expert * (e.n_experts - e.top_k)
        return self.approx_params() - inactive
