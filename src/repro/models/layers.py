"""Neural building blocks shared by all 10 assigned architectures.

Pure-functional JAX: every block is (init(cfg, key) -> params,
apply(cfg, params, x, ...) -> y).  Parameters are plain dict pytrees so the
sharding layer (parallel/sharding.py) can pattern-match on leaf paths.

Hot spots have Pallas TPU twins in repro/kernels (flash attention); the jnp
paths here are the oracles and the CPU/dry-run implementations.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.parallel.annotate import shard

from .config import ModelConfig

Array = jnp.ndarray


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def chunked_scan(step, carry, xs, chunk: int = 64):
    """Two-level lax.scan with rematerialized inner chunks.

    A flat time scan saves every per-step residual for backward — for
    recurrent mixers (mamba/mLSTM/sLSTM) that is O(S·state) and blows HBM
    at S=4k (observed 68 GB/layer for Jamba).  Chunking at √S and
    ``jax.checkpoint``-ing the inner scan stores only chunk-boundary
    carries: O(√S·state) live memory at a ~2× recompute cost.
    """
    leaves = jax.tree_util.tree_leaves(xs)
    S = leaves[0].shape[0]
    if S % chunk != 0 or S <= chunk:
        return jax.lax.scan(step, carry, xs)

    def reshape(x):
        return x.reshape((S // chunk, chunk) + x.shape[1:])

    xs_c = jax.tree_util.tree_map(reshape, xs)

    @jax.checkpoint
    def chunk_fn(c, xc):
        return jax.lax.scan(step, c, xc)

    carry, ys = jax.lax.scan(chunk_fn, carry, xs_c)

    def unshape(y):
        return y.reshape((S,) + y.shape[2:])

    return carry, jax.tree_util.tree_map(unshape, ys)


def dense_init(key, shape, in_axis=0) -> Array:
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape) / math.sqrt(fan_in)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(cfg: ModelConfig):
    p = {"scale": jnp.ones((cfg.d_model,), jnp.float32)}
    if cfg.norm == "ln":
        p["bias"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return p


def norm_apply(cfg: ModelConfig, p, x: Array) -> Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rms":
        var = jnp.mean(xf * xf, -1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"]
    else:
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"] + p["bias"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE / M-RoPE)
# ---------------------------------------------------------------------------

def _rope_angles(positions: Array, dim: int, theta: float) -> tuple[Array, Array]:
    """positions [...,S] -> (sin, cos) of shape [...,S, dim//2]."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(cfg: ModelConfig, x: Array, positions: Array) -> Array:
    """x [B,S,H,hd]; positions [B,S] (RoPE) or [3,B,S] (M-RoPE)."""
    hd = x.shape[-1]
    if cfg.rope == "none":
        return x
    if cfg.rope == "mrope":
        # Qwen2-VL multimodal RoPE: head_dim split into (t,h,w) sections,
        # each rotated by its own position stream (arXiv:2409.12191 §2.1).
        secs = cfg.mrope_sections
        assert sum(secs) * 2 == hd, (secs, hd)
        sins, coss = [], []
        for s, sec in enumerate(secs):
            sn, cs = _rope_angles(positions[s], 2 * sec, cfg.rope_theta)
            sins.append(sn)
            coss.append(cs)
        sin = jnp.concatenate(sins, -1)[:, :, None, :]
        cos = jnp.concatenate(coss, -1)[:, :, None, :]
    else:
        sin, cos = _rope_angles(positions, hd, cfg.rope_theta)
        sin, cos = sin[:, :, None, :], cos[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, chunked online-softmax — the jnp flash oracle)
# ---------------------------------------------------------------------------

def attn_init(cfg: ModelConfig, key, cross: bool = False):
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, h * hd)),
        "wk": dense_init(ks[1], (d, kh * hd)),
        "wv": dense_init(ks[2], (d, kh * hd)),
        "wo": dense_init(ks[3], (h * hd, d)),
    }


def _gqa_scores(q: Array, k: Array) -> Array:
    """q [B,S,KH,G,hd], k [B,T,KH,hd] -> scores [B,KH,G,S,T]."""
    return jnp.einsum("bskgd,btkd->bkgst", q, k)


# int8 KV-cache quantization (REPRO_PERF_VARIANT=int8kv): static scale —
# post-RoPE K and V values are O(1); production would carry per-head scales
KV_QUANT_SCALE = 0.05


def kv_quantize(x: Array, dtype) -> Array:
    if jnp.dtype(dtype) != jnp.int8:
        return x.astype(dtype)
    return jnp.clip(jnp.round(x.astype(jnp.float32) / KV_QUANT_SCALE),
                    -127, 127).astype(jnp.int8)


def kv_dequantize(x: Array, dtype=jnp.bfloat16) -> Array:
    if x.dtype != jnp.int8:
        return x
    return (x.astype(jnp.float32) * KV_QUANT_SCALE).astype(dtype)


def multihead_attention(cfg: ModelConfig, q: Array, k: Array, v: Array,
                        causal: bool, q_offset: Array | int = 0,
                        kv_len: Array | None = None,
                        q_chunk: int = 512) -> Array:
    """Chunked attention: scan over query chunks, full KV per chunk.

    q [B,S,H,hd]; k,v [B,T,KH,hd].  ``q_offset`` positions the query block
    inside the KV timeline (decode/prefill continuation); ``kv_len`` masks
    out unwritten cache slots.  Memory O(S/q_chunk · T) per step.
    """
    k = kv_dequantize(k, q.dtype)
    v = kv_dequantize(v, q.dtype)
    B, S, H, hd = q.shape
    T = k.shape[1]
    KH = k.shape[2]
    G = H // KH
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, S, KH, G, hd)

    nchunks = max(1, S // q_chunk)
    qc = S // nchunks
    qs = qg.reshape(B, nchunks, qc, KH, G, hd)

    kv_pos = jnp.arange(T)
    # per-sequence offsets/lengths (ragged continuous batching) broadcast
    # from scalars for the aligned train/prefill case
    off = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (B,))
    kvl = jnp.broadcast_to(
        jnp.asarray(T if kv_len is None else kv_len, jnp.int32), (B,))
    valid = kv_pos[None, :] < kvl[:, None]              # [B, T]

    @jax.checkpoint  # recompute S² probs in backward: O(S·chunk) live memory
    def one_chunk(c):
        qb = qs[:, c]                                   # [B,qc,KH,G,hd]
        s = jnp.einsum("bskgd,btkd->bkgst", qb, k) * scale
        mask = valid[:, None, None, None, :]
        if causal:
            q_pos = off[:, None] + c * qc + jnp.arange(qc)[None]  # [B,qc]
            mask = mask & (kv_pos[None, None, :]
                           <= q_pos[:, :, None])[:, None, None]
        s = jnp.where(mask, s.astype(jnp.float32), -jnp.inf)
        s = jnp.where(mask.any(-1, keepdims=True), s, 0.0)  # empty rows
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return jnp.einsum("bkgst,btkd->bskgd", p, v)    # [B,qc,KH,G,hd]

    out = jax.lax.map(one_chunk, jnp.arange(nchunks))   # [n,B,qc,KH,G,hd]
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, H, hd)
    return out


# ---------------------------------------------------------------------------
# MLPs: SwiGLU / GELU dense, sort-free capacity-based MoE
# ---------------------------------------------------------------------------

def mlp_init(cfg: ModelConfig, key, d_ff: int | None = None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_act == "swiglu":
        return {"wi": dense_init(ks[0], (d, f)), "wg": dense_init(ks[1], (d, f)),
                "wo": dense_init(ks[2], (f, d))}
    return {"wi": dense_init(ks[0], (d, f)), "wo": dense_init(ks[2], (f, d))}


def mlp_apply(cfg: ModelConfig, p, x: Array) -> Array:
    if cfg.mlp_act == "swiglu":
        h = jax.nn.silu(x @ p["wi"]) * (x @ p["wg"])
    else:
        h = jax.nn.gelu(x @ p["wi"])
    if x.ndim == 3:
        h = shard(h, "batch", None, "model")
    return shard(h @ p["wo"], *(("batch",) if x.ndim == 2 else ("batch", None, None)))


def moe_init(cfg: ModelConfig, key):
    e = cfg.moe
    d, f = cfg.d_model, e.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e.n_experts)),
        "wi": dense_init(ks[1], (e.n_experts, d, f)),
        "wg": dense_init(ks[2], (e.n_experts, d, f)),
        "wo": dense_init(ks[3], (e.n_experts, f, d)),
    }
    if e.n_shared:
        p["shared"] = mlp_init(cfg, ks[4], d_ff=e.n_shared * f)
    return p


def moe_apply(cfg: ModelConfig, p, x: Array) -> tuple[Array, Array]:
    """Capacity-based token-choice MoE with *group-local* dispatch.

    Tokens are split into G = data-parallel-size groups; the cumsum /
    scatter / gather of the dispatch are vmapped over the group axis, so
    under GSPMD every device dispatches only its own tokens (no cross-host
    scatter — the naive global dispatch cost 550 GB of collective traffic
    per step on qwen2-moe, see EXPERIMENTS.md §Perf).  Capacity is
    enforced per (group, expert), matching how per-host capacity works in
    GShard/Switch deployments.  Returns (y, load-balance aux loss).
    """
    from repro.parallel.annotate import data_parallel_size

    e = cfg.moe
    B, S, D = x.shape
    T = B * S
    G = data_parallel_size()
    if T % G != 0 or (T // G) < e.n_experts:
        G = 1
    Tg = T // G
    xt = shard(x.reshape(G, Tg, D), "batch", None, None)
    logits = (xt @ p["router"]).astype(jnp.float32)       # [G, Tg, E]
    probs = jax.nn.softmax(logits, -1)
    gval, gidx = jax.lax.top_k(probs, e.top_k)            # [G, Tg, k]
    gval = gval / jnp.maximum(gval.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary (Switch-style)
    density = jnp.mean(jax.nn.one_hot(gidx[..., 0], e.n_experts), (0, 1))
    aux = e.n_experts * jnp.sum(density * probs.mean((0, 1)))

    cap = int(e.capacity_factor * Tg * e.top_k / e.n_experts)
    cap = max(cap, 4)

    onehot = jax.nn.one_hot(gidx, e.n_experts, dtype=jnp.int32)  # [G,Tg,k,E]
    pos = jnp.cumsum(onehot.reshape(G, Tg * e.top_k, e.n_experts), 1) - 1
    pos = pos.reshape(G, Tg, e.top_k, e.n_experts)
    slot = jnp.sum(pos * onehot, -1)                       # [G, Tg, k]
    keep = slot < cap
    gval = gval * keep

    flat_e = gidx.reshape(G, -1)                           # [G, Tg*k]
    flat_s = jnp.where(keep, slot, cap).reshape(G, -1)

    def dispatch(xg, eg, sg):
        buf = jnp.zeros((e.n_experts, cap + 1, D), x.dtype)
        src = jnp.repeat(xg, e.top_k, 0)
        return buf.at[eg, sg].add(src)[:, :cap]

    xe = jax.vmap(dispatch)(xt, flat_e, flat_s)            # [G, E, cap, D]
    xe = shard(xe, "batch", "model", None, None)

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["wi"]))
    h = shard(h, "batch", "model") * jnp.einsum("gecd,edf->gecf", xe, p["wg"])
    ye = jnp.einsum("gecf,efd->gecd", h, p["wo"])          # [G, E, cap, D]
    ye = shard(ye, "batch", "model", None, None)

    def combine(yg, eg, sg):
        return yg[eg, jnp.minimum(sg, cap - 1)]            # [Tg*k, D]

    yt = jax.vmap(combine)(ye, flat_e, flat_s)
    yt = yt * gval.reshape(G, -1, 1).astype(x.dtype)
    y = yt.reshape(G, Tg, e.top_k, D).sum(2)               # [G, Tg, D]

    if e.n_shared:
        y = y + mlp_apply(cfg, p["shared"], xt.reshape(G * Tg, D)) \
            .reshape(G, Tg, D)
    return shard(y.reshape(B, S, D), "batch", None, None), aux


# ---------------------------------------------------------------------------
# Mamba (S6 selective SSM) — recurrent scan formulation
# ---------------------------------------------------------------------------

def mamba_init(cfg: ModelConfig, key):
    d, di, ds = cfg.d_model, cfg.d_inner, cfg.d_state
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di)),
        "conv": dense_init(ks[1], (cfg.d_conv, di)) * 0.1,
        "x_proj": dense_init(ks[2], (di, 2 * ds + 1)),   # B, C, dt
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "dt_w": dense_init(ks[3], (1, di)),
        "A_log": jnp.log(jnp.arange(1, ds + 1, dtype=jnp.float32))
                 * jnp.ones((di, 1)),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d)),
    }


def _mamba_scan(u: Array, dt: Array, A: Array, Bm: Array, Cm: Array,
                h0: Array | None):
    """Sequential state scan.  u,dt [B,S,di]; Bm,Cm [B,S,ds]; A [di,ds].

    Returns (y [B,S,di], h_last [B,di,ds]).  lax.scan keeps the HLO O(1) in
    sequence length; the TPU-native chunkwise kernel is the optimization
    target (DESIGN.md §4).
    """
    Bsz, S, di = u.shape
    ds = A.shape[-1]
    h = (shard(jnp.zeros((Bsz, di, ds), jnp.float32), "batch", "model", None)
         if h0 is None else h0)

    def step(h, inp):
        u_t, dt_t, B_t, C_t = inp
        dA = jnp.exp(dt_t[..., None] * A)                  # [B,di,ds]
        dBu = (dt_t * u_t)[..., None] * B_t[:, None, :]    # [B,di,ds]
        h = dA * h + dBu
        y = jnp.einsum("bds,bs->bd", h, C_t)
        return h, y

    xs = (jnp.moveaxis(u, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    h, ys = chunked_scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1), h


def mamba_apply(cfg: ModelConfig, p, x: Array, state=None, conv_state=None):
    """state: SSM hidden [B,di,ds]; conv_state: last d_conv-1 inputs."""
    B, S, _ = x.shape
    di, ds, K = cfg.d_inner, cfg.d_state, cfg.d_conv
    xz = shard(x @ p["in_proj"], "batch", None, "model")
    u, z = jnp.split(xz, 2, -1)                            # [B,S,di]

    # depthwise causal conv along S
    if conv_state is None:
        pad = jnp.zeros((B, K - 1, di), u.dtype)
    else:
        pad = conv_state.astype(u.dtype)
    uc = jnp.concatenate([pad, u], 1)
    new_conv = uc[:, -(K - 1):]
    u = sum(uc[:, k:k + S] * p["conv"][k].astype(u.dtype) for k in range(K))
    u = jax.nn.silu(u)

    bcd = (u @ p["x_proj"]).astype(jnp.float32)
    Bm, Cm, dt_in = bcd[..., :ds], bcd[..., ds:2 * ds], bcd[..., -1:]
    dt = jax.nn.softplus(dt_in @ p["dt_w"] + p["dt_bias"])  # [B,S,di]
    A = -jnp.exp(p["A_log"])                                # [di,ds]

    y, h = _mamba_scan(u.astype(jnp.float32), dt, A, Bm, Cm, state)
    y = y.astype(x.dtype) + u * p["D"].astype(x.dtype)
    out = shard((y * jax.nn.silu(z)) @ p["out_proj"], "batch", None, None)
    return out, (h, new_conv)


# ---------------------------------------------------------------------------
# xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) & sLSTM (scalar)
# ---------------------------------------------------------------------------

def mlstm_init(cfg: ModelConfig, key):
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (d, h * hd)),
        "wk": dense_init(ks[1], (d, h * hd)),
        "wv": dense_init(ks[2], (d, h * hd)),
        "wif": dense_init(ks[3], (d, 2 * h)),     # input & forget gate logits
        "wo_gate": dense_init(ks[4], (d, h * hd)),
        "wo": dense_init(ks[5], (h * hd, d)),
    }


def mlstm_apply(cfg: ModelConfig, p, x: Array, state=None):
    """mLSTM with exponential gating and stabilizer state.

    state = (C [B,H,hd,hd], n [B,H,hd], m [B,H]).
    """
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.hd
    q = shard(x @ p["wq"], "batch", None, "model").reshape(B, S, H, hd) / math.sqrt(hd)
    k = shard(x @ p["wk"], "batch", None, "model").reshape(B, S, H, hd) / math.sqrt(hd)
    v = shard(x @ p["wv"], "batch", None, "model").reshape(B, S, H, hd)
    gates = (x @ p["wif"]).astype(jnp.float32).reshape(B, S, 2, H)
    i_log, f_log = gates[:, :, 0], jax.nn.log_sigmoid(gates[:, :, 1])

    if state is None:
        C = shard(jnp.zeros((B, H, hd, hd), jnp.float32),
                  "batch", None, "model", None)
        n = shard(jnp.zeros((B, H, hd), jnp.float32), "batch", None, "model")
        m = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C, n, m = state

    def step(carry, inp):
        C, n, m = carry
        q_t, k_t, v_t, i_t, f_t = inp
        m_new = jnp.maximum(f_t + m, i_t)
        fd = jnp.exp(f_t + m - m_new)[..., None]
        id_ = jnp.exp(i_t - m_new)[..., None]
        kf, vf = k_t.astype(jnp.float32), v_t.astype(jnp.float32)
        C = fd[..., None] * C + id_[..., None] * (vf[..., :, None]
                                                  * kf[..., None, :])
        n = fd * n + id_ * kf
        qf = q_t.astype(jnp.float32)
        num = jnp.einsum("bhvk,bhk->bhv", C, qf)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)), 1.0)
        return (C, n, m_new), num / den[..., None]

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (q, k, v, i_log, f_log))
    (C, n, m), ys = chunked_scan(step, (C, n, m), xs)
    h = jnp.moveaxis(ys, 0, 1).reshape(B, S, H * hd).astype(x.dtype)
    h = h * jax.nn.silu(shard(x @ p["wo_gate"], "batch", None, "model"))
    return shard(h @ p["wo"], "batch", None, None), (C, n, m)


def slstm_init(cfg: ModelConfig, key):
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    ks = jax.random.split(key, 3)
    return {
        "wx": dense_init(ks[0], (d, 4 * h * hd)),          # z,i,f,o from x
        "wr": dense_init(ks[1], (h, hd, 4 * hd)) * 0.1,    # per-head recurrent
        "wo": dense_init(ks[2], (h * hd, d)),
    }


def slstm_apply(cfg: ModelConfig, p, x: Array, state=None):
    """sLSTM: scalar memory, exponential gating, block-diagonal recurrence.

    state = (c, n, m, hprev) each [B,H,hd] (m: stabilizer).
    """
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.hd
    xz = shard(x @ p["wx"], "batch", None, "model").reshape(B, S, H, 4 * hd)
    if state is None:
        z = jnp.zeros((B, H, hd), jnp.float32)
        state = (z, z + 1e-6, z - 1e30, z)
    c0, n0, m0, h0 = state

    def step(carry, x_t):
        c, n, m, hp = carry
        rec = jnp.einsum("bhk,hkj->bhj", hp, p["wr"].astype(jnp.float32))
        pre = x_t.astype(jnp.float32) + rec                 # [B,H,4hd]
        zt, it, ft, ot = jnp.split(pre, 4, -1)
        zt = jnp.tanh(zt)
        ft = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(ft + m, it)
        c = jnp.exp(ft + m - m_new) * c + jnp.exp(it - m_new) * zt
        n = jnp.exp(ft + m - m_new) * n + jnp.exp(it - m_new)
        h = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1e-6)
        return (c, n, m_new, h), h

    (c, n, m, hl), ys = chunked_scan(step, (c0, n0, m0, h0),
                                     jnp.moveaxis(xz, 1, 0))
    h = jnp.moveaxis(ys, 0, 1).reshape(B, S, H * hd).astype(x.dtype)
    return shard(h @ p["wo"], "batch", None, None), (c, n, m, hl)
