"""Composable model assembly: config → init / train_loss / prefill / decode.

The layer stack is ``lax.scan`` over ``n_periods`` repetitions of the
config's period (stacked parameters), so HLO size and compile time are O(1)
in depth — essential for the 62–80-layer dry-run cells.

Entry points (all pure):
  init(cfg, key)                          -> params (fp32 masters)
  train_loss(cfg, params, batch)          -> (loss, aux)
  prefill(cfg, params, batch)             -> (last-token logits, cache)
  decode_step(cfg, params, tokens, cache) -> (logits, cache)
  init_cache(cfg, batch, max_len)         -> zeroed cache pytree
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.parallel.annotate import shard

from . import layers as L
from .config import ModelConfig

Array = jnp.ndarray
PyTree = Any

# roofline/extract.py flips this so shallow analysis variants compile with
# the layer scan fully unrolled (XLA cost analysis counts loop bodies once;
# unrolled HLO makes per-period costs exact)
UNROLL_SCAN = False


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _block_init(cfg: ModelConfig, key, mixer: str, mlp: str, cross: bool):
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"norm1": L.norm_init(cfg)}
    if mixer == "attn":
        p["mixer"] = L.attn_init(cfg, ks[0])
    elif mixer == "mamba":
        p["mixer"] = L.mamba_init(cfg, ks[0])
    elif mixer == "mlstm":
        p["mixer"] = L.mlstm_init(cfg, ks[0])
    elif mixer == "slstm":
        p["mixer"] = L.slstm_init(cfg, ks[0])
    else:
        raise ValueError(mixer)
    if cross:
        p["norm_x"] = L.norm_init(cfg)
        p["xattn"] = L.attn_init(cfg, ks[1], cross=True)
    if mlp == "dense":
        p["norm2"] = L.norm_init(cfg)
        p["mlp"] = L.mlp_init(cfg, ks[2])
    elif mlp == "moe":
        p["norm2"] = L.norm_init(cfg)
        p["mlp"] = L.moe_init(cfg, ks[2])
    return p


def _stack_init(cfg: ModelConfig, key, period, n_periods: int, cross: bool):
    def one_period(k):
        kk = jax.random.split(k, len(period))
        return {f"b{i}": _block_init(cfg, kk[i], m, f, cross)
                for i, (m, f) in enumerate(period)}

    keys = jax.random.split(key, n_periods)
    return jax.vmap(one_period)(keys)


def init(cfg: ModelConfig, key) -> PyTree:
    ks = jax.random.split(key, 6)
    params: dict[str, Any] = {
        "embed": L.dense_init(ks[0], (cfg.vocab, cfg.d_model), in_axis=1),
        "blocks": _stack_init(cfg, ks[1], cfg.period, cfg.n_periods,
                              cross=cfg.enc_dec),
        "final_norm": L.norm_init(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(ks[2], (cfg.d_model, cfg.vocab))
    if cfg.enc_dec:
        enc_period = (("attn", "dense"),)
        params["enc"] = {
            "blocks": _stack_init(cfg, ks[3], enc_period, cfg.n_enc_layers,
                                  cross=False),
            "norm": L.norm_init(cfg),
            "pos": L.dense_init(ks[4], (cfg.enc_seq, cfg.d_model)) * 0.02,
        }
    return params


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> PyTree:
    """Zeroed decode cache, one stacked entry per period position."""
    dt = jnp.dtype(dtype or cfg.dtype)
    P = cfg.n_periods
    B, KH, hd, H = batch, cfg.n_kv_heads, cfg.hd, cfg.n_heads
    cache: dict[str, Any] = {"len": jnp.zeros((batch,), jnp.int32)}
    for i, (mixer, _) in enumerate(cfg.period):
        if mixer == "attn":
            c = {"k": jnp.zeros((P, B, max_len, KH, hd), dt),
                 "v": jnp.zeros((P, B, max_len, KH, hd), dt)}
            if cfg.enc_dec:
                c["xk"] = jnp.zeros((P, B, cfg.enc_seq, KH, hd), dt)
                c["xv"] = jnp.zeros((P, B, cfg.enc_seq, KH, hd), dt)
        elif mixer == "mamba":
            c = {"h": jnp.zeros((P, B, cfg.d_inner, cfg.d_state), jnp.float32),
                 "conv": jnp.zeros((P, B, cfg.d_conv - 1, cfg.d_inner), dt)}
        elif mixer == "mlstm":
            c = {"C": jnp.zeros((P, B, H, hd, hd), jnp.float32),
                 "n": jnp.zeros((P, B, H, hd), jnp.float32),
                 "m": jnp.full((P, B, H), -1e30, jnp.float32)}
        elif mixer == "slstm":
            z = jnp.zeros((P, B, H, hd), jnp.float32)
            c = {"c": z, "n": z + 1e-6, "m": z - 1e30, "h": z}
        cache[f"b{i}"] = c
    return cache


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _positions(cfg: ModelConfig, B: int, S: int, offset) -> Array:
    off = jnp.broadcast_to(jnp.asarray(offset, jnp.int32), (B,))
    pos = off[:, None] + jnp.arange(S)[None, :]        # per-sequence offsets
    if cfg.rope == "mrope":
        return jnp.broadcast_to(pos[None], (3, B, S))  # text: t=h=w stream
    return pos


def _attn_block(cfg: ModelConfig, p, x: Array, positions, cache, offset,
                causal=True):
    """Self-attention with optional cache read/write. Returns (y, new_cache)."""
    import os

    B, S, _ = x.shape
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = shard(x @ p["wq"], "batch", None, "model").reshape(B, S, H, hd)
    k = shard(x @ p["wk"], "batch", None, "model").reshape(B, S, KH, hd)
    v = shard(x @ p["wv"], "batch", None, "model").reshape(B, S, KH, hd)
    if "attnbatch" in os.environ.get("REPRO_PERF_VARIANT", ""):
        # §Perf variant: batch-only attention sharding — one explicit
        # gather of q/k/v over 'model' per layer instead of GSPMD's
        # "involuntary full rematerialization" of score tensors (head
        # counts like 56/8 cannot shard 16-way, so XLA otherwise
        # replicates mid-attention at far higher cost)
        q = shard(q, "batch", None, None, None)
        k = shard(k, "batch", None, None, None)
        v = shard(v, "batch", None, None, None)
    q = L.apply_rope(cfg, q, positions)
    k = L.apply_rope(cfg, k, positions)

    if cache is None:
        out = L.multihead_attention(cfg, q, k, v, causal=causal)
        new = None
    else:
        kq = L.kv_quantize(k, cache["k"].dtype)
        vq = L.kv_quantize(v, cache["v"].dtype)
        if isinstance(offset, int):
            # aligned prefill: contiguous dynamic-update-slice
            ck = jax.lax.dynamic_update_slice(cache["k"], kq, (0, offset, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], vq, (0, offset, 0, 0))
        else:
            # ragged decode: per-sequence write positions (continuous batching)
            rows = jnp.arange(B)[:, None]
            cols = offset[:, None] + jnp.arange(S)[None, :]
            ck = cache["k"].at[rows, cols].set(kq, mode="drop")
            cv = cache["v"].at[rows, cols].set(vq, mode="drop")
        out = L.multihead_attention(cfg, q, ck, cv, causal=True,
                                    q_offset=offset, kv_len=offset + S)
        new = {"k": ck, "v": cv}
    y = shard(out.reshape(B, S, H * hd), "batch", None, "model") @ p["wo"]
    return shard(y, "batch", None, None), new


def _cross_attn(cfg: ModelConfig, p, x: Array, xk, xv):
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.hd
    q = shard(x @ p["wq"], "batch", None, "model").reshape(B, S, H, hd)
    out = L.multihead_attention(cfg, q, xk, xv, causal=False)
    y = shard(out.reshape(B, S, H * hd), "batch", None, "model") @ p["wo"]
    return shard(y, "batch", None, None)


def _apply_block(cfg: ModelConfig, mixer: str, mlp: str, p, x, positions,
                 cache, offset, enc_out=None, causal=True,
                 compute_xkv=False):
    new_cache = {}
    h = L.norm_apply(cfg, p["norm1"], x)
    if mixer == "attn":
        y, kv = _attn_block(cfg, p["mixer"], h, positions, cache, offset,
                            causal)
        if kv is not None:
            new_cache.update(kv)
    elif mixer == "mamba":
        st = (cache["h"], cache["conv"]) if cache is not None else (None, None)
        y, (hs, cs) = L.mamba_apply(cfg, p["mixer"], h, *st)
        if cache is not None:
            new_cache.update({"h": hs, "conv": cs})
    elif mixer == "mlstm":
        st = (cache["C"], cache["n"], cache["m"]) if cache is not None else None
        y, (C, n, m) = L.mlstm_apply(cfg, p["mixer"], h, st)
        if cache is not None:
            new_cache.update({"C": C, "n": n, "m": m})
    elif mixer == "slstm":
        st = ((cache["c"], cache["n"], cache["m"], cache["h"])
              if cache is not None else None)
        y, (c, n, m, hl) = L.slstm_apply(cfg, p["mixer"], h, st)
        if cache is not None:
            new_cache.update({"c": c, "n": n, "m": m, "h": hl})
    x = x + y

    if cfg.enc_dec and "xattn" in p:
        hx = L.norm_apply(cfg, p["norm_x"], x)
        if cache is not None and "xk" in cache and not compute_xkv:
            xk, xv = cache["xk"], cache["xv"]
            new_cache.update({"xk": xk, "xv": xv})
        else:
            B = x.shape[0]
            KH, hd = cfg.n_kv_heads, cfg.hd
            xk = (enc_out @ p["xattn"]["wk"]).reshape(B, -1, KH, hd)
            xv = (enc_out @ p["xattn"]["wv"]).reshape(B, -1, KH, hd)
            if cache is not None:
                dt = jnp.dtype(cfg.dtype)
                new_cache.update({"xk": xk.astype(dt), "xv": xv.astype(dt)})
        x = x + _cross_attn(cfg, p["xattn"], hx, xk, xv)

    aux = jnp.zeros((), jnp.float32)
    if mlp != "none":
        h2 = L.norm_apply(cfg, p["norm2"], x)
        if mlp == "dense":
            y2 = L.mlp_apply(cfg, p["mlp"], h2)
        else:
            y2, aux = L.moe_apply(cfg, p["mlp"], h2)
        x = x + y2
    return x, new_cache, aux


def _run_stack(cfg: ModelConfig, blocks, x, positions, cache, offset,
               period, enc_out=None, causal=True, remat=False,
               compute_xkv=False):
    """scan over stacked periods; cache (if any) scanned alongside."""

    def period_fn(x, xs):
        p_params, p_cache = xs
        aux_tot = jnp.zeros((), jnp.float32)
        new_cache = {}
        for i, (mixer, mlp) in enumerate(period):
            c = None if p_cache is None else p_cache[f"b{i}"]
            x, nc, aux = _apply_block(cfg, mixer, mlp, p_params[f"b{i}"], x,
                                      positions, c, offset, enc_out, causal,
                                      compute_xkv)
            new_cache[f"b{i}"] = nc
            aux_tot = aux_tot + aux
        return x, (new_cache, aux_tot)

    if remat:
        period_fn = jax.checkpoint(period_fn,
                                   policy=jax.checkpoint_policies.nothing_saveable)

    unroll = True if UNROLL_SCAN else 1
    if cache is None:
        x, (_, aux) = jax.lax.scan(lambda c, b: period_fn(c, (b, None)),
                                   x, blocks, unroll=unroll)
        return x, None, aux.sum()
    layer_cache = {k: v for k, v in cache.items() if k != "len"}
    x, (new_cache, aux) = jax.lax.scan(period_fn, x, (blocks, layer_cache),
                                       unroll=unroll)
    return x, new_cache, aux.sum()


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def _embed_in(cfg: ModelConfig, params, batch) -> Array:
    if "embeds" in batch:
        return shard(batch["embeds"].astype(cfg.dtype), "batch", None, None)
    emb = params["embed"].astype(cfg.dtype)
    return shard(emb[batch["tokens"]], "batch", None, None)


def _encode(cfg: ModelConfig, params, batch) -> Array:
    enc = params["enc"]
    x = batch["enc_embeds"].astype(cfg.dtype) + enc["pos"].astype(cfg.dtype)
    pos = _positions(cfg, x.shape[0], x.shape[1], 0)
    x, _, _ = _run_stack(cfg, enc["blocks"], x, pos, None, 0,
                         (("attn", "dense"),), causal=False)
    return L.norm_apply(cfg, enc["norm"], x)


def _logits(cfg: ModelConfig, params, x: Array) -> Array:
    x = L.norm_apply(cfg, params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(cfg.dtype)
    return shard(x @ head, "batch", None, "model")


def _cast(cfg: ModelConfig, params):
    dt = jnp.dtype(cfg.dtype)
    return jax.tree_util.tree_map(
        lambda w: w.astype(dt) if w.dtype == jnp.float32 else w, params)


def forward(cfg: ModelConfig, params, batch, remat=False) -> tuple[Array, Array]:
    """Full-sequence forward → (logits [B,S,V], moe aux loss)."""
    cparams = _cast(cfg, params)
    x = _embed_in(cfg, cparams, batch)
    enc_out = _encode(cfg, cparams, batch) if cfg.enc_dec else None
    pos = _positions(cfg, x.shape[0], x.shape[1], 0)
    x, _, aux = _run_stack(cfg, cparams["blocks"], x, pos, None, 0,
                           cfg.period, enc_out=enc_out, remat=remat)
    return _logits(cfg, cparams, x), aux


def train_loss(cfg: ModelConfig, params, batch, remat=True):
    """Next-token cross entropy (+0.01·moe aux). Labels = shifted tokens."""
    from repro.parallel.annotate import axis_divides

    logits, aux = forward(cfg, params, batch, remat=remat)
    labels = batch.get("labels", batch.get("tokens"))
    # full-S loss with a weight mask (keeps the seq dim mesh-divisible);
    # shard the f32 logits over vocab when it divides (prime-ish vocabs
    # like granite's 49155 fall back to sequence sharding)
    spec = (("batch", None, "model") if axis_divides("model", cfg.vocab)
            else ("batch", "model", None))
    lg = shard(logits.astype(jnp.float32), *spec)
    tg = jnp.concatenate([labels[:, 1:], labels[:, :1]], axis=1)
    w = jnp.ones(tg.shape, jnp.float32).at[:, -1].set(0.0)
    lse = jax.nn.logsumexp(lg, -1)
    # one-hot contraction instead of take_along_axis: the gather over the
    # vocab-sharded axis would force a full-logits all-gather per device
    oh = shard(jax.nn.one_hot(tg, lg.shape[-1], dtype=lg.dtype), *spec)
    ll = jnp.einsum("bsv,bsv->bs", lg, oh)
    loss = jnp.sum((lse - ll) * w) / jnp.sum(w)
    return loss + 0.01 * aux / max(cfg.n_layers, 1), {"ce": loss, "aux": aux}


def prefill(cfg: ModelConfig, params, batch, max_len: int | None = None):
    """Process the prompt, fill the cache, return last-position logits."""
    cparams = _cast(cfg, params)
    x = _embed_in(cfg, cparams, batch)
    B, S = x.shape[0], x.shape[1]
    cache = init_cache(cfg, B, max_len or S)
    enc_out = _encode(cfg, cparams, batch) if cfg.enc_dec else None
    pos = _positions(cfg, B, S, 0)
    x, new_cache, _ = _run_stack(cfg, cparams["blocks"], x, pos, cache, 0,
                                 cfg.period, enc_out=enc_out,
                                 compute_xkv=True)
    new_cache["len"] = jnp.full((B,), S, jnp.int32)
    logits = _logits(cfg, cparams, x[:, -1:])
    return logits[:, 0], new_cache


def decode_step(cfg: ModelConfig, params, tokens: Array, cache):
    """One token for every sequence in the batch. tokens [B, 1] int32."""
    cparams = _cast(cfg, params)
    x = _embed_in(cfg, cparams, {"tokens": tokens})
    B = x.shape[0]
    offset = cache["len"]
    pos = _positions(cfg, B, 1, offset)
    x, new_cache, _ = _run_stack(cfg, cparams["blocks"], x, pos, cache,
                                 offset, cfg.period)
    new_cache["len"] = cache["len"] + 1
    logits = _logits(cfg, cparams, x)
    return logits[:, 0], new_cache
