"""Fault-tolerant training runner.

The loop a real cluster job runs on every host:

* resume from the newest complete checkpoint (restart-after-preemption);
* periodic async-ish checkpointing (save off the step's donated buffers);
* SIGTERM/SIGINT trap → emergency checkpoint before exit (preemption);
* transient step failure → bounded retries with the same deterministic
  batch (the data pipeline is a pure function of step, so a retried step
  is bit-identical);
* straggler watermarks — per-step wall time EMA + p95; a step slower than
  ``straggler_factor``× the EMA is logged.  On real fleets this is the
  signal to re-mesh (mesh.elastic_mesh) and reshard via checkpoint
  restore; the elastic path is exercised in tests/test_checkpoint.py by
  restoring onto a different mesh.
* optional simulated failures (``fail_at``) prove the recovery path in CI.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.data import SyntheticLM
from repro.train import checkpoint as ckpt


@dataclasses.dataclass
class RunnerConfig:
    total_steps: int = 200
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints/run"
    keep: int = 3
    max_retries: int = 2
    straggler_factor: float = 3.0
    fail_at: tuple[int, ...] = ()          # simulated transient failures


class TrainRunner:
    def __init__(self, rc: RunnerConfig, step_fn: Callable, params: Any,
                 opt_state: Any, data: SyntheticLM,
                 shardings: tuple | None = None):
        self.rc = rc
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.data = data
        self.shardings = shardings
        self.metrics_log: list[dict] = []
        self.step_times: list[float] = []
        self.stragglers: list[int] = []
        self._preempted = False
        self._failed_once: set[int] = set()

    # -- lifecycle ----------------------------------------------------------
    def _install_signals(self):
        def handler(signum, frame):
            self._preempted = True
        for sig in (signal.SIGTERM, signal.SIGINT):
            signal.signal(sig, handler)

    def _resume(self) -> int:
        last = ckpt.latest_step(self.rc.ckpt_dir)
        if last is None:
            return 0
        like = {"params": self.params, "opt": self.opt_state}
        sh = None
        if self.shardings is not None:
            sh = {"params": self.shardings[0], "opt": self.shardings[1]}
        tree, extra = ckpt.restore(self.rc.ckpt_dir, last, like, sh)
        self.params, self.opt_state = tree["params"], tree["opt"]
        print(f"[runner] resumed from step {last}")
        return int(extra.get("next_step", last))

    def _save(self, step: int):
        ckpt.save(self.rc.ckpt_dir, step,
                  {"params": self.params, "opt": self.opt_state},
                  extra={"next_step": step}, keep=self.rc.keep)

    # -- main loop ----------------------------------------------------------
    def run(self) -> dict:
        self._install_signals()
        start = self._resume()
        ema = None
        for step in range(start, self.rc.total_steps):
            batch = self.data.batch(step)
            for attempt in range(self.rc.max_retries + 1):
                try:
                    if step in self.rc.fail_at and step not in self._failed_once:
                        self._failed_once.add(step)
                        raise RuntimeError(f"simulated failure @ step {step}")
                    t0 = time.time()
                    self.params, self.opt_state, metrics = self.step_fn(
                        self.params, self.opt_state, batch)
                    jax.block_until_ready(metrics["loss"])
                    dt = time.time() - t0
                    break
                except RuntimeError as e:
                    print(f"[runner] step {step} attempt {attempt} failed: {e}")
                    if attempt == self.rc.max_retries:
                        raise
            ema = dt if ema is None else 0.9 * ema + 0.1 * dt
            self.step_times.append(dt)
            if dt > self.rc.straggler_factor * ema and step > start + 5:
                self.stragglers.append(step)
                print(f"[runner] straggler: step {step} took {dt:.2f}s "
                      f"(ema {ema:.2f}s) — re-mesh candidate")
            self.metrics_log.append(
                {"step": step, **{k: float(v) for k, v in metrics.items()}})
            if (step + 1) % self.rc.ckpt_every == 0 or self._preempted:
                self._save(step + 1)
                if self._preempted:
                    print(f"[runner] preempted — saved at {step + 1}")
                    break
        else:
            self._save(self.rc.total_steps)
        return {"metrics": self.metrics_log, "stragglers": self.stragglers,
                "mean_step_s": float(np.mean(self.step_times or [0]))}
