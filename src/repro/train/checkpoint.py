"""Fault-tolerant checkpointing: atomic, sharded, elastic.

Layout:  <dir>/step_<k>/
           manifest.json       — step, flat key list, shapes/dtypes, config
           shard_<host>.npz    — this host's param/opt shards (here: 1 host)

Guarantees:
* **atomicity** — written to ``step_<k>.tmp`` then ``os.replace``d; a crash
  mid-save never corrupts the latest checkpoint; ``latest_step`` only sees
  completed directories.
* **elastic restore** — ``restore`` rebuilds full arrays then
  ``device_put``s them with *any* target sharding: resume on a different
  mesh shape after losing (or gaining) hosts.
* **retention** — keep-last-k garbage collection.

At 1000+ node scale each host writes only its local shards (the npz file
per host); the manifest is written once by host 0.  This container has one
host, but the format and code paths are per-host already.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> tuple[dict[str, np.ndarray], dict[str, str]]:
    """→ (storable arrays, original dtype names).  Extended dtypes
    (bf16/fp8 via ml_dtypes) are stored as uint views — npz round-trips
    them losslessly and the manifest remembers the real dtype."""
    flat, dtypes = {}, {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey)
            else str(getattr(p, "idx", getattr(p, "name", p)))
            for p in path)
        arr = np.asarray(leaf)
        dtypes[key] = str(arr.dtype)
        if arr.dtype.kind == "V":
            arr = arr.view(f"u{arr.dtype.itemsize}")
        flat[key] = arr
    return flat, dtypes


def _restore_dtype(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    """Undo the uint view for extended dtypes recorded in the manifest."""
    if arr.dtype.kind == "u" and dtype_str not in ("uint8", "uint16",
                                                   "uint32", "uint64"):
        import ml_dtypes
        return arr.view(np.dtype(getattr(ml_dtypes, dtype_str)))
    return arr


def save(ckpt_dir: str | os.PathLike, step: int, tree: Any,
         extra: dict | None = None, keep: int = 3, host_id: int = 0) -> str:
    base = pathlib.Path(ckpt_dir)
    final = base / f"step_{step:08d}"
    tmp = base / f"step_{step:08d}.tmp"
    tmp.mkdir(parents=True, exist_ok=True)

    flat, dtypes = _flatten(tree)
    np.savez(tmp / f"shard_{host_id}.npz", **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": dtypes,
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    for f in tmp.iterdir():                     # durability before rename
        with open(f, "rb") as fh:
            os.fsync(fh.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)

    # retention
    steps = sorted(int(p.name.split("_")[1]) for p in base.glob("step_*")
                   if p.suffix != ".tmp" and not p.name.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(base / f"step_{s:08d}", ignore_errors=True)
    return str(final)


def latest_step(ckpt_dir: str | os.PathLike) -> int | None:
    base = pathlib.Path(ckpt_dir)
    if not base.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in base.glob("step_*")
             if not p.name.endswith(".tmp") and (p / "manifest.json").exists()]
    return max(steps) if steps else None


def restore(ckpt_dir: str | os.PathLike, step: int, like: Any,
            shardings: Any | None = None, host_id: int = 0) -> tuple[Any, dict]:
    """Rebuild ``like``-structured tree; reshard onto ``shardings`` if given.

    ``like`` may be a tree of ShapeDtypeStructs or arrays (defines the
    pytree structure and leaf order)."""
    final = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((final / "manifest.json").read_text())
    data = np.load(final / f"shard_{host_id}.npz")

    leaves_paths = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    out = []
    for path, leaf in leaves_paths:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey)
            else str(getattr(p, "idx", getattr(p, "name", p)))
            for p in path)
        arr = _restore_dtype(data[key], manifest["dtypes"][key])
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape,
                                                       leaf.shape)
        out.append(arr.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, manifest["extra"]
