"""Step factories: the functions the launcher jits with shardings.

``make_train_step`` closes over (config, optimizer, schedule) and returns a
pure (params, opt_state, batch, step) → (params, opt_state, metrics)
function with remat already applied inside the model's layer scan.
Optional gradient compression (int8 + error feedback) hooks in before the
(pjit-inserted) gradient reduction — see parallel/collectives.py.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import AdamW, cosine_schedule


def make_train_step(cfg: ModelConfig, optim: AdamW,
                    lr_fn: Callable | None = None,
                    compress_grads: bool = False,
                    remat: bool = True):
    lr_fn = lr_fn or partial(cosine_schedule, peak=3e-4, warmup=100,
                             total=10_000)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = M.train_loss(cfg, p, batch, remat=remat)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        if compress_grads:
            from repro.parallel.collectives import quantize_dequantize_int8
            grads = jax.tree_util.tree_map(quantize_dequantize_int8, grads)
        lr = lr_fn(opt_state.step)
        params, opt_state, gnorm = optim.update(grads, opt_state, params, lr)
        out = {"loss": loss, "lr": lr, "grad_norm": gnorm, **metrics}
        return params, opt_state, out

    return train_step


def make_prefill_step(cfg: ModelConfig, max_len: int | None = None):
    def prefill_step(params, batch):
        return M.prefill(cfg, params, batch, max_len=max_len)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, tokens, cache):
        return M.decode_step(cfg, params, tokens, cache)

    return decode_step
